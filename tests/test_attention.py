"""Attention unit tests: GQA vs einsum reference, blocked == full, local
window masking, MLA decode absorption, ring-cache equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models import layers as L

CFG = A.AttnConfig(d_model=64, n_heads=8, n_kv_heads=2, d_head=16)


def _ref_attention(q, k, v, window=0):
    """Naive causal GQA reference."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    out = np.zeros_like(np.asarray(q), dtype=np.float32)
    qn, kn, vn = map(lambda t: np.asarray(t, dtype=np.float32), (q, k, v))
    for hi in range(h):
        kv = hi // g
        scores = qn[:, :, hi] @ kn[:, :, kv].transpose(0, 2, 1) / np.sqrt(d)
        mask = np.tril(np.ones((s, s), bool))
        if window:
            mask &= ~np.tril(np.ones((s, s), bool), -window)
        scores = np.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out[:, :, hi] = np.einsum("bqk,bkd->bqd", np.asarray(probs), vn[:, :, kv])
    return out


@pytest.mark.parametrize("window", [0, 8])
def test_sdpa_matches_reference(window):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    b, s = 2, 32
    q = jax.random.normal(ks[0], (b, s, 8, 16))
    k = jax.random.normal(ks[1], (b, s, 2, 16))
    v = jax.random.normal(ks[2], (b, s, 2, 16))
    pos = jnp.arange(s)
    out = A._sdpa(q, k, v, pos, pos, window=window, scale=16 ** -0.5)
    ref = _ref_attention(q, k, v, window)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-3, atol=1e-3)


def test_blocked_equals_full():
    cfg_full = CFG
    cfg_blk = A.AttnConfig(**{**CFG.__dict__, "q_block": 8})
    p = A.gqa_init(jax.random.PRNGKey(1), cfg_full)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 64)) * 0.3
    pos = jnp.arange(32)
    a = A.gqa_forward(p, x, pos, cfg_full)
    b = A.gqa_forward(p, x, pos, cfg_blk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-3, atol=1e-3)


def test_decode_matches_forward_stepwise():
    p = A.gqa_init(jax.random.PRNGKey(3), CFG)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, 64)) * 0.3
    pos = jnp.arange(16)
    full = A.gqa_forward(p, x, pos, CFG)
    cache = A.gqa_init_cache(1, 16, CFG, jnp.float32)
    outs = []
    for t in range(16):
        o, cache = A.gqa_decode_step(p, x[:, t:t + 1], jnp.asarray(t), cache,
                                     CFG)
        outs.append(o)
    stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stream), np.asarray(full),
                               rtol=1e-3, atol=2e-3)


def test_ring_cache_local_attention():
    """Windowed decode with a ring cache == full recompute with window mask."""
    cfg = A.AttnConfig(d_model=32, n_heads=4, n_kv_heads=1, d_head=8, window=8)
    p = A.gqa_init(jax.random.PRNGKey(5), cfg)
    s = 24
    x = jax.random.normal(jax.random.PRNGKey(6), (1, s, 32)) * 0.3
    pos = jnp.arange(s)
    full = A.gqa_forward(p, x, pos, cfg)
    cache = A.gqa_init_cache(1, s, cfg, jnp.float32)   # ring of size 8
    assert cache["k"].shape[1] == 8
    outs = []
    for t in range(s):
        o, cache = A.gqa_decode_step(p, x[:, t:t + 1], jnp.asarray(t), cache,
                                     cfg)
        outs.append(o)
    stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stream), np.asarray(full),
                               rtol=1e-3, atol=2e-3)


def test_mla_decode_absorption():
    """Absorbed-latent decode must match the naive (decompressed) forward."""
    cfg = A.AttnConfig(d_model=64, n_heads=4, n_kv_heads=4, d_head=24,
                       q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                       qk_rope_head_dim=8, v_head_dim=16)
    p = A.mla_init(jax.random.PRNGKey(7), cfg)
    s = 12
    x = jax.random.normal(jax.random.PRNGKey(8), (2, s, 64)) * 0.3
    pos = jnp.arange(s)
    full = A.mla_forward(p, x, pos, cfg)
    cache = A.mla_init_cache(2, s, cfg, jnp.float32)  # fp32 cache: exactness
    outs = []
    for t in range(s):
        o, cache = A.mla_decode_step(p, x[:, t:t + 1], jnp.asarray(t), cache,
                                     cfg)
        outs.append(o)
    stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stream), np.asarray(full),
                               rtol=1e-3, atol=2e-3)


def test_rope_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (1, 8, 2, 16))
    pos = jnp.arange(8)
    y = L.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jax.random.normal(jax.random.PRNGKey(10), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(11), (1, 1, 1, 16))
    def dot_at(m, n):
        qm = L.apply_rope(jnp.broadcast_to(q, (1, 1, 1, 16)), jnp.array([m]), 1e4)
        kn = L.apply_rope(jnp.broadcast_to(k, (1, 1, 1, 16)), jnp.array([n]), 1e4)
        return float(jnp.sum(qm * kn))
    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-3


def test_int8_kv_cache_decode():
    """Quantized KV cache: decode must match the fp cache within int8 error,
    and the cache arrays must actually be int8."""
    cfg_q = A.AttnConfig(d_model=64, n_heads=8, n_kv_heads=2, d_head=16,
                         kv_quant=True)
    p = A.gqa_init(jax.random.PRNGKey(3), CFG)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, 64)) * 0.3
    pos = jnp.arange(16)
    full = A.gqa_forward(p, x, pos, CFG)
    cache = A.gqa_init_cache(1, 16, cfg_q)
    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache
    outs = []
    for t in range(16):
        o, cache = A.gqa_decode_step(p, x[:, t:t + 1], jnp.asarray(t), cache,
                                     cfg_q)
        outs.append(o)
    stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stream), np.asarray(full),
                               rtol=0.05, atol=0.02)
    # cache bytes: int8 k/v + bf16 scales ~= 0.56x of bf16 k/v
    q_bytes = sum(v.size * v.dtype.itemsize for k, v in cache.items()
                  if k != "pos")
    fp_bytes = 2 * 1 * 16 * 2 * 16 * 2
    assert q_bytes < 0.6 * fp_bytes


def test_int8_kv_prefill_then_decode():
    cfg_q = A.AttnConfig(d_model=64, n_heads=8, n_kv_heads=2, d_head=16,
                         kv_quant=True)
    p = A.gqa_init(jax.random.PRNGKey(5), CFG)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 12, 64)) * 0.3
    pos = jnp.arange(12)
    full = A.gqa_forward(p, x, pos, CFG)
    _, cache = A.gqa_prefill_cache(p, x[:, :8], pos[:8], cfg_q, max_len=12)
    outs = []
    for t in range(8, 12):
        o, cache = A.gqa_decode_step(p, x[:, t:t + 1], jnp.asarray(t), cache,
                                     cfg_q)
        outs.append(o)
    stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stream), np.asarray(full[:, 8:]),
                               rtol=0.05, atol=0.02)
