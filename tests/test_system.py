"""End-to-end behaviour tests: full FPPS registration on synthetic LiDAR
frames, matching the paper's protocol (4096-point sampled source, full
target, 50 iters, 1.0 m gate, 1e-5 epsilon)."""
import numpy as np
import pytest

from repro.core import FppsICP
from repro.core.baseline import kdtree_icp
from repro.data.pointcloud import SceneConfig, frame_pair

CFG = SceneConfig(n_ground=9000, n_walls=6000, n_poles=1800, n_clutter=1700,
                  extent=40.0, sensor_range=45.0)


def _pose_error(T_est, T_gt):
    R_err = T_est[:3, :3] @ T_gt[:3, :3].T
    ang = np.arccos(np.clip((np.trace(R_err) - 1.0) / 2.0, -1.0, 1.0))
    trans = np.linalg.norm(T_est[:3, 3] - T_gt[:3, 3])
    return ang, trans


@pytest.mark.parametrize("seq", [0, 3])
def test_full_frame_registration(seq):
    src, dst, T_gt = frame_pair(seq=seq, frame=7, cfg=CFG,
                                n_source_samples=1024)
    reg = FppsICP()
    reg.setInputSource(src)
    reg.setInputTarget(dst)
    reg.setMaxCorrespondenceDistance(1.0)
    reg.setMaxIterationCount(50)
    reg.setTransformationEpsilon(1e-5)
    T = reg.align()
    ang, trans = _pose_error(T, T_gt)
    assert ang < 0.02, f"rotation error {ang} rad"
    assert trans < 0.10, f"translation error {trans} m"
    assert reg.getFitnessScore() < 0.15


def test_accuracy_parity_across_frames():
    """Table III reproduction in miniature: ours vs k-d tree baseline over
    several frames; RMSE deltas must stay within the paper's 0.01 m band."""
    deltas = []
    for frame in (3, 9):
        src, dst, _ = frame_pair(seq=1, frame=frame, cfg=CFG,
                                 n_source_samples=1024)
        reg = FppsICP()
        reg.setInputSource(src)
        reg.setInputTarget(dst)
        T = reg.align()
        base = kdtree_icp(src, dst)
        deltas.append(abs(reg.getFitnessScore() - base.rmse))
    assert max(deltas) < 0.01, deltas
