"""SE(3) utilities + Kabsch estimation properties."""
import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import hypothesis, st
from repro.core import transform as tf


def test_rotation_is_orthogonal():
    key = jax.random.PRNGKey(0)
    for i in range(10):
        key, k1, k2 = jax.random.split(key, 3)
        R = tf.rotation_from_axis_angle(jax.random.normal(k1, (3,)),
                                        jax.random.uniform(k2, (), minval=-3, maxval=3))
        np.testing.assert_allclose(np.asarray(R @ R.T), np.eye(3), atol=1e-6)
        assert abs(float(jnp.linalg.det(R)) - 1.0) < 1e-5


@hypothesis.given(st.integers(0, 2**31 - 1))
@hypothesis.settings(max_examples=30, deadline=None)
def test_kabsch_recovers_random_transform(seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    pts = jax.random.normal(k1, (200, 3)) * 10.0
    T = tf.random_rigid_transform(k2, max_angle=3.0, max_translation=20.0)
    dst = tf.transform_points(T, pts)
    T_est = tf.estimate_rigid_transform(pts, dst)
    np.testing.assert_allclose(np.asarray(T_est), np.asarray(T), atol=2e-3)


def test_kabsch_weighted_ignores_outliers():
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    pts = jax.random.normal(k1, (300, 3)) * 5.0
    T = tf.random_rigid_transform(k2)
    dst = tf.transform_points(T, pts)
    # Corrupt 50 correspondences; zero-weight them.
    dst = dst.at[:50].add(100.0)
    w = jnp.ones(300).at[:50].set(0.0)
    T_est = tf.estimate_rigid_transform(pts, dst, w)
    np.testing.assert_allclose(np.asarray(T_est), np.asarray(T), atol=2e-3)


def test_transform_composition_and_delta():
    key = jax.random.PRNGKey(1)
    T = tf.random_rigid_transform(key)
    eye_delta = tf.transform_delta(jnp.eye(4))
    assert float(eye_delta) == 0.0
    assert float(tf.transform_delta(T)) > 0.0
    pts = jax.random.normal(key, (50, 3))
    out = tf.transform_points(T, tf.transform_points(jnp.linalg.inv(T), pts))
    np.testing.assert_allclose(np.asarray(out), np.asarray(pts), atol=1e-4)


def test_rmse_masked():
    a = jnp.zeros((4, 3))
    b = jnp.ones((4, 3))
    w = jnp.array([1.0, 1.0, 0.0, 0.0])
    assert abs(float(tf.rmse(a, b, w)) - np.sqrt(3.0)) < 1e-6
