"""shard_map expert-parallel MoE == dense reference (subprocess: 8 devices)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_moe_ep_subprocess():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "moe_ep_worker.py")],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": str(ROOT / "src")},
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "MOE-EP-OK" in proc.stdout
