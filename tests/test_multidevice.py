"""Device-sharded serving tests run in a subprocess so the 8-device
host-platform fleet never leaks into this interpreter (the tier-1 sharded
tests in test_service.py must see 1 device)."""
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


@pytest.mark.slow
@pytest.mark.multidevice
def test_sharded_service_multidevice_subprocess():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "multidevice_worker.py")],
        capture_output=True, text=True, timeout=900,
        env={**__import__("os").environ, "PYTHONPATH": str(ROOT / "src")},
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "MULTIDEVICE-OK" in proc.stdout
