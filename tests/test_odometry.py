"""Streaming odometry: recovery cascade tiers, velocity decay, quarantine.

The cascade tests script the engines (ICPResult-shaped fakes) so each
tier's selection logic is exercised in isolation, deterministically, and
without paying registration time; one end-to-end test runs the real stack
on a clean stream to pin the no-fault behaviour (everything tier 0).
"""
import numpy as np

import repro.core.odometry as odometry
from repro.core.health import FAILED, OK, SUSPECT
from repro.core.odometry import (OdometryConfig, OdometryPipeline,
                                 _decay_toward_identity)
from repro.data.pointcloud import SceneConfig, sequence_scans
from repro.data.submap import SubmapParams

TEST_SCENE = SceneConfig(n_ground=800, n_walls=600, n_poles=150,
                         n_clutter=150, extent=15.0, sensor_range=20.0)
TEST_SUBMAP = SubmapParams(voxel_size=0.75, capacity=4096,
                           dims=(64, 64, 24), evict_radius=20.0)


def _result(T=None, rmse=0.05, inlier_frac=0.9, degenerate=False):
    class R:
        pass
    r = R()
    r.T = np.eye(4, dtype=np.float32) if T is None else T
    r.rmse = rmse
    r.inlier_frac = inlier_frac
    r.degenerate = degenerate
    r.iterations = 5
    r.converged = True
    return r


OK_RESULT = dict(rmse=0.05, inlier_frac=0.9, degenerate=False)
BAD_RESULT = dict(rmse=float("inf"), inlier_frac=0.0, degenerate=True)
SUS_RESULT = dict(rmse=0.8, inlier_frac=0.3, degenerate=False)


class ScriptedEngine:
    """Returns the scripted results in order; repeats the last one."""

    def __init__(self, *specs):
        self.specs = list(specs)
        self.calls = 0

    def register(self, *args, **kwargs):
        spec = self.specs[min(self.calls, len(self.specs) - 1)]
        self.calls += 1
        return _result(**spec)


def _scan(n=64, seed=0):
    return np.asarray(np.random.default_rng(seed).uniform(-5, 5, (n, 3)),
                      np.float32)


def _pipe(monkeypatch, primary_specs, tier_engines, **cfg_kwargs):
    """Pipeline whose primary engine and per-tier engines are scripted.

    ``tier_engines`` maps the get_engine kind ("pyramid"/"xla") to a
    ScriptedEngine; the cascade's ``get_engine`` lookups are intercepted.
    """
    cfg = OdometryConfig(submap=TEST_SUBMAP, warmup_frames=1, **cfg_kwargs)
    pipe = OdometryPipeline(cfg)
    pipe.engine = ScriptedEngine(*primary_specs)
    monkeypatch.setattr(odometry, "get_engine",
                        lambda kind, **kw: tier_engines[kind])
    return pipe


def _bootstrap(pipe):
    pipe.process(_scan(seed=100))        # frame 0: map seed, no registration


# -- cascade tiers ---------------------------------------------------------

def test_clean_frame_stays_tier0(monkeypatch):
    pipe = _pipe(monkeypatch, [OK_RESULT], {})
    _bootstrap(pipe)
    _, diag = pipe.process(_scan(seed=1))
    assert diag.recovery_tier == 0
    assert diag.health == OK
    assert diag.accepted and not diag.quarantined
    assert pipe.recovery_count == 0


def test_tier1_widen_recovers(monkeypatch):
    widen = ScriptedEngine(OK_RESULT)
    pipe = _pipe(monkeypatch, [BAD_RESULT], {"pyramid": widen},
                 recovery_tiers=("widen",))
    _bootstrap(pipe)
    _, diag = pipe.process(_scan(seed=1))
    assert diag.recovery_tier == 1
    assert diag.health == OK
    assert diag.accepted
    assert widen.calls == 1
    assert pipe.recovery_count == 1


def test_tier2_fallback_recovers(monkeypatch):
    fallback = ScriptedEngine(OK_RESULT)
    pipe = _pipe(monkeypatch, [BAD_RESULT], {"xla": fallback},
                 recovery_tiers=("fallback",))
    _bootstrap(pipe)
    _, diag = pipe.process(_scan(seed=1))
    assert diag.recovery_tier == 1
    assert diag.accepted
    assert fallback.calls == 1


def test_tier3_wide_basin_recovers(monkeypatch):
    wide = ScriptedEngine(OK_RESULT)
    pipe = _pipe(monkeypatch, [BAD_RESULT], {"pyramid": wide},
                 recovery_tiers=("wide_basin",))
    _bootstrap(pipe)
    _, diag = pipe.process(_scan(seed=1))
    assert diag.recovery_tier == 1
    assert diag.accepted


def test_cascade_stops_at_first_ok_tier(monkeypatch):
    widen = ScriptedEngine(OK_RESULT)
    never = ScriptedEngine(OK_RESULT)
    pipe = _pipe(monkeypatch, [BAD_RESULT],
                 {"pyramid": widen, "xla": never})
    _bootstrap(pipe)
    _, diag = pipe.process(_scan(seed=1))
    assert diag.recovery_tier == 1       # widen (pyramid) wins first
    assert never.calls == 0              # later tiers never ran


def test_least_bad_suspect_accepted_when_no_tier_is_ok(monkeypatch):
    # all tiers SUSPECT with one tripped signal each; ties prefer the
    # earliest tier (never compare inlier mass across different gates)
    shared = ScriptedEngine(SUS_RESULT,                          # widen
                            dict(SUS_RESULT, inlier_frac=0.5))   # wide_basin
    xla = ScriptedEngine(SUS_RESULT)
    pipe = _pipe(monkeypatch, [BAD_RESULT],
                 {"pyramid": shared, "xla": xla})
    _bootstrap(pipe)
    inserted_before = pipe.submap.frames_inserted
    _, diag = pipe.process(_scan(seed=1))
    assert diag.health == SUSPECT
    assert diag.accepted                 # pose is output...
    assert diag.quarantined              # ...but the scan is not fused
    assert diag.recovery_tier == 1       # earliest suspect wins the tie
    assert pipe.submap.frames_inserted == inserted_before


def test_all_failed_coasts_and_quarantines(monkeypatch):
    bad = ScriptedEngine(BAD_RESULT)
    pipe = _pipe(monkeypatch, [BAD_RESULT], {"pyramid": bad, "xla": bad})
    _bootstrap(pipe)
    inserted_before = pipe.submap.frames_inserted
    pose, diag = pipe.process(_scan(seed=1))
    assert diag.recovery_tier == len(pipe.config.recovery_tiers) + 1
    assert diag.quarantined and not diag.accepted
    assert diag.health == FAILED
    assert pipe.submap.frames_inserted == inserted_before  # not fused
    assert pipe.quarantined_count == 1
    np.testing.assert_array_equal(pose, pipe.poses[-1])


def test_recovery_off_keeps_legacy_guard(monkeypatch):
    pipe = _pipe(monkeypatch, [BAD_RESULT], {}, recovery=False)
    _bootstrap(pipe)
    _, diag = pipe.process(_scan(seed=1))
    assert diag.recovery_tier == 0       # no tiers ran
    assert not diag.accepted             # legacy degenerate rejection
    assert pipe.engine.calls == 1


def test_sticky_counters_accumulate(monkeypatch):
    widen = ScriptedEngine(OK_RESULT)
    pipe = _pipe(monkeypatch, [BAD_RESULT, BAD_RESULT, OK_RESULT],
                 {"pyramid": widen, "xla": widen})
    _bootstrap(pipe)
    for s in (1, 2, 3):
        pipe.process(_scan(seed=s))
    assert pipe.recovery_count == 2
    assert pipe.tier_counts()[1] == 2
    assert pipe.health_counts()[OK] >= 3


# -- velocity decay (satellite bugfix) ------------------------------------

def test_decay_toward_identity():
    T = np.eye(4)
    T[:3, 3] = [2.0, 0.0, 0.0]
    D = _decay_toward_identity(T, 0.5)
    np.testing.assert_allclose(D[:3, 3], [1.0, 0.0, 0.0], atol=1e-6)
    np.testing.assert_allclose(D[:3, :3], np.eye(3), atol=1e-6)
    np.testing.assert_allclose(_decay_toward_identity(np.eye(4), 0.5),
                               np.eye(4), atol=1e-7)


def test_dropout_burst_decays_velocity(monkeypatch):
    """The failing-before regression: a 3-frame dropout burst must coast
    at *decaying* speed. The old pipeline re-derived velocity from the
    last two (coasted) poses, so it extrapolated at full speed forever."""
    def moved(x):
        T = np.eye(4, dtype=np.float32)
        T[0, 3] = x
        return dict(OK_RESULT, T=T)

    pipe = _pipe(monkeypatch, [moved(1.0), moved(2.0)], {})
    _bootstrap(pipe)
    pipe.process(_scan(seed=1))          # pose x=1 -> velocity 1 m/frame
    pipe.process(_scan(seed=2))          # pose x=2
    empty = np.full((32, 3), np.nan, np.float32)   # 3-frame sensor dropout
    xs = []
    for s in (3, 4, 5):
        pose, diag = pipe.process(empty)
        assert diag.quarantined and diag.health == FAILED
        xs.append(float(pose[0, 3]))
    # the first coast extrapolates at the last measured speed; every
    # further coast bleeds it by velocity_decay=0.5 — steps 1.0, 0.5,
    # 0.25, NOT the old 1.0, 1.0, 1.0 runaway
    np.testing.assert_allclose(xs, [3.0, 3.5, 3.75], atol=1e-5)


def test_dropped_frame_skips_registration(monkeypatch):
    pipe = _pipe(monkeypatch, [OK_RESULT], {})
    _bootstrap(pipe)
    pipe.process(_scan(seed=1))
    calls_before = pipe.engine.calls
    _, diag = pipe.process(np.zeros((16, 3), np.float32),
                           valid=np.zeros(16, bool))
    assert pipe.engine.calls == calls_before   # no registration spent
    assert diag.quarantined and diag.iterations == 0


# -- sensor-boundary scrub -------------------------------------------------

def test_nan_scan_rows_scrubbed_at_boundary(monkeypatch):
    pipe = _pipe(monkeypatch, [OK_RESULT], {})
    _bootstrap(pipe)
    scan = _scan(seed=1)
    scan[5] = np.nan
    scan[9, 1] = np.inf
    pose, diag = pipe.process(scan)
    assert np.all(np.isfinite(pose))
    assert diag.accepted


# -- end-to-end on the real stack -----------------------------------------

def test_clean_stream_real_engine_all_tier0():
    scans = sequence_scans(2, 6, TEST_SCENE)
    pipe = OdometryPipeline(OdometryConfig(engine="xla", submap=TEST_SUBMAP,
                                           scan_budget=2048))
    poses, diags = pipe.run(scans)
    assert np.all(np.isfinite(poses))
    assert all(d.recovery_tier == 0 for d in diags)
    assert all(d.accepted for d in diags)
    assert pipe.quarantined_count == 0
