"""Subprocess worker: the device-sharded registration service on an
8-device host-platform fleet (DESIGN.md §14).

Run via tests/test_multidevice.py — NOT imported by pytest directly (it
must set XLA_FLAGS before jax initialises, which would poison the main
process). Exits non-zero on any mismatch; prints MULTIDEVICE-OK last.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import ICPParams, get_engine, icp_fixed_iterations  # noqa: E402
from repro.core.distributed import (batched_icp_sharded,  # noqa: E402
                                    shard_inputs, stream_sharded_icp,
                                    streams_mesh)
from repro.core.odometry import OdometryConfig, OdometryPipeline  # noqa: E402
from repro.core.transform import (random_rigid_transform,  # noqa: E402
                                  transform_points)
from repro.data.pointcloud import SceneConfig, sequence_scans  # noqa: E402
from repro.data.submap import SubmapParams  # noqa: E402
from repro.serve.registration_service import (RegistrationService,  # noqa: E402
                                              ServiceConfig)

SCENE = SceneConfig(n_ground=300, n_walls=220, n_poles=60, n_clutter=70,
                    extent=12.0, sensor_range=16.0)
ODO = OdometryConfig(
    params=ICPParams(max_iterations=6, max_correspondence_distance=1.0,
                     chunk=512, robust_kernel="huber", robust_scale=0.3),
    submap=SubmapParams(voxel_size=0.75, capacity=1024, dims=(48, 48, 16),
                        evict_radius=12.0),
    scan_budget=256, recovery=False)
SLOTS = 8


def _drive(svc, fleet):
    out = {sid: [] for sid in fleet}
    frames = max(len(f) for f in fleet.values())
    for f in range(frames):
        for sid, scans in fleet.items():
            if f < len(scans):
                svc.submit(sid, scans[f])
        for sid, res in svc.step().items():
            out[sid].append(res)
    return out


def main():
    assert jax.device_count() == 8, jax.devices()
    fleet = {f"veh{s}": sequence_scans(s, 5, SCENE) for s in range(6)}

    # --- D=8 service == single-device reference, bit for bit -------------
    # Weak-scaling parity is per BLOCK WIDTH (lanes_per_device): a D=8,
    # L=1 lane runs the same (1, ...)-shaped program as a D=1, L=1
    # single-stream pipeline, so per-stream poses AND diagnostics are
    # bit-identical to that single-device reference.
    svc8 = RegistrationService(ServiceConfig(
        slots=SLOTS, scan_capacity=1024, odometry=ODO, devices=8))
    for sid in fleet:
        svc8.admit(sid)
    out8 = _drive(svc8, fleet)
    ref_cfg = svc8.stream_config._replace(
        engine_kwargs=(("lanes_per_device", 1), ("devices", 1)))
    for sid, scans in fleet.items():
        assert len(out8[sid]) == len(scans)
        ref = OdometryPipeline(ref_cfg)
        for f, sc in enumerate(scans):
            pose_ref, diag_ref = ref.process(*svc8.stage_scan(sc))
            np.testing.assert_array_equal(np.asarray(out8[sid][f][0]),
                                          np.asarray(pose_ref))
            assert out8[sid][f][1] == diag_ref, (sid, f)
    rep = svc8.service_report()
    assert rep["devices"] == 8 and rep["frames_processed"] == 30
    print("sharded service D=8 == single-device reference OK")

    # --- D=8 vs a D=1 8-lane service: fp-tolerance agreement --------------
    # Different block widths (L=1 vs L=8) tile the per-lane point-axis
    # reductions differently on CPU, so across WIDTHS agreement is fp-
    # tolerance, not bitwise (the docs state exactly this caveat).
    svc1 = RegistrationService(ServiceConfig(
        slots=SLOTS, scan_capacity=1024, odometry=ODO, devices=1))
    for sid in fleet:
        svc1.admit(sid)
    out1 = _drive(svc1, fleet)
    for sid in fleet:
        for (p1, d1), (p8, d8) in zip(out1[sid], out8[sid]):
            np.testing.assert_allclose(np.asarray(p8), np.asarray(p1),
                                       atol=1e-4)
            assert (d8.accepted, d8.health, d8.quarantined) == \
                   (d1.accepted, d1.health, d1.quarantined)
    print("sharded service D=8 ~= D=1 (cross-width) OK")

    # --- mesh-aware placement spreads streams across device blocks -------
    # 6 streams over 8 devices x 1 lane: every stream gets its own block
    slots = sorted(svc8._streams[sid].slot for sid in fleet)
    assert len(set(slots)) == len(fleet), slots
    print("mesh-aware placement OK")

    # --- churn at D=8: lane reset + join never retrace --------------------
    traces = svc8.engine.trace_count
    svc8.close("veh0")
    svc8.admit("late")
    late = sequence_scans(9, 3, SCENE)
    got = _drive(svc8, {"late": late})
    assert len(got["late"]) == 3
    assert svc8.engine.trace_count == traces
    # the recycled lane replays a fresh standalone pipeline bit-for-bit
    ref = OdometryPipeline(svc8.stream_config)
    for f, sc in enumerate(late):
        pose_ref, diag_ref = ref.process(*svc8.stage_scan(sc))
        np.testing.assert_array_equal(np.asarray(got["late"][f][0]),
                                      np.asarray(pose_ref))
        assert got["late"][f][1] == diag_ref
    print("D=8 churn retrace-free + lane reset OK")

    # --- fp16 resident submaps at D=8 -------------------------------------
    odo16 = ODO._replace(submap=ODO.submap._replace(storage="fp16"))
    svc16 = RegistrationService(ServiceConfig(
        slots=SLOTS, scan_capacity=1024, odometry=odo16, devices=8))
    sub_fleet = {sid: fleet[sid] for sid in list(fleet)[:3]}
    for sid in sub_fleet:
        svc16.admit(sid)
    out16 = _drive(svc16, sub_fleet)
    for sid in sub_fleet:
        assert len(out16[sid]) == 5
        assert out16[sid][-1][1].map_occupancy > 0.0
    print("D=8 fp16 OK")

    # --- stream sharding primitive: D=8 == vmapped single device ----------
    params = ICPParams(max_iterations=10, chunk=256)
    keys = jax.random.split(jax.random.PRNGKey(7), 8)
    srcs, dsts = [], []
    for k in keys:
        ka, kb, kc = jax.random.split(k, 3)
        tgt = jax.random.uniform(ka, (1024, 3), minval=-10, maxval=10)
        T = random_rigid_transform(kb, max_angle=0.1, max_translation=0.3)
        s = transform_points(jnp.linalg.inv(T), tgt)
        srcs.append(s + 0.002 * jax.random.normal(kc, s.shape))
        dsts.append(tgt)
    src_b, dst_b = jnp.stack(srcs), jnp.stack(dsts)
    res8 = stream_sharded_icp(streams_mesh(8), src_b, dst_b, params)
    # weak-scaling parity: each D=8 lane (a width-1 block) is bitwise
    # identical to the same lane run alone on one device (also width 1)
    mesh1 = streams_mesh(1)
    for i in range(8):
        ref = stream_sharded_icp(mesh1, src_b[i:i + 1], dst_b[i:i + 1],
                                 params)
        np.testing.assert_array_equal(np.asarray(res8.T[i]),
                                      np.asarray(ref.T[0]))
        np.testing.assert_array_equal(np.asarray(res8.rmse[i]),
                                      np.asarray(ref.rmse[0]))
    print("stream_sharded_icp D=8 == per-lane single device OK")

    # --- legacy point-sharded path vs the xla engine (2-device mesh) ------
    mesh2 = jax.make_mesh((2, 1), ("data", "model"))
    sb, db = shard_inputs(mesh2, src_b[:4], dst_b[:4])
    res_leg = batched_icp_sharded(mesh2, sb, db, params,
                                  frame_axes=("data",),
                                  target_axes=("model",))
    eng = get_engine("xla")
    for i in range(4):
        ref = icp_fixed_iterations(srcs[i], dsts[i], params)
        np.testing.assert_allclose(np.asarray(res_leg.T[i]),
                                   np.asarray(ref.T), atol=1e-4)
        res_e = eng.register(srcs[i], dsts[i], params)
        np.testing.assert_allclose(np.asarray(res_leg.T[i]),
                                   np.asarray(res_e.T), atol=1e-4)
    print("legacy batched_icp_sharded vs xla engine OK")


if __name__ == "__main__":
    main()
    print("MULTIDEVICE-OK")
