"""Fused single-pass ICP iteration kernel (DESIGN.md §11).

Contracts:

  * **Moment parity** — the fused pass (NN min + gate + IRLS weight +
    moment accumulate in one kernel) must reproduce a plain numpy
    reference computed from the same candidate sets, for both moment
    sets and every robust kernel, prune on and off.
  * **Transform parity** — a full fused ICP run must land on the same
    transform as the unfused engines (the ISSUE-6 ≤1e-3 acceptance
    bound; observed ~1e-7).
  * **Degenerate freeze** — empty neighbourhoods / all-masked sources
    reproduce the PR-5 zero-inlier contract (identity step, rmse inf).
  * **Interpret threading** — every kernel wrapper resolves the shared
    tri-state ``interpret`` flag through ``kernels.common`` so the suite
    executes on CPU-only CI and compiles untouched on TPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ICPParams, get_engine, icp, icp_fixed_iterations
from repro.core.nn_search_grid import _MASK_COORD
from repro.data.voxelize import build_voxel_grid
from repro.kernels.common import default_interpret, pallas_call_kwargs
from repro.kernels.fused_icp import (P2P_MOMENTS, P2PLANE_MOMENTS,
                                     default_fused_fn, fused_moment_sweep,
                                     make_fused_fn, moment_names)

BN, BC = 16, 16  # tiny blocks: exercise padding + multi-tile carries


def _case(seed, n=37, ck=50, scale=3.0):
    """Queries + a shared candidate set (every query sees all CK rows), so
    the fused NN must equal the global brute NN — an exact oracle."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    q = jax.random.uniform(k1, (n, 3), minval=-scale, maxval=scale)
    pts = jax.random.uniform(k2, (ck, 3), minval=-scale, maxval=scale)
    cand = jnp.broadcast_to(pts[None], (n, ck, 3))
    nrm = pts / jnp.linalg.norm(pts, axis=-1, keepdims=True)
    cand_n = jnp.broadcast_to(nrm[None], (n, ck, 3))
    return np.asarray(q), np.asarray(cand), np.asarray(cand_n)


def _ref_moments(q, cand, cand_n=None, sv=None, *, gate=1.0,
                 robust="none", scale=0.5):
    """Plain numpy oracle for the fused pass, first-match argmin."""
    n = q.shape[0]
    sv = np.ones(n) if sv is None else np.asarray(sv, np.float64)
    d2 = ((q[:, None, :] - cand) ** 2).sum(-1)
    j = d2.argmin(1)
    dmin = d2[np.arange(n), j]
    qq = cand[np.arange(n), j]
    w = (dmin <= gate * gate).astype(np.float64) * sv
    plane = cand_n is not None
    if plane:
        nn = cand_n[np.arange(n), j]
        r = (nn * (q - qq)).sum(-1)
        resid = np.abs(r)
    else:
        resid = np.sqrt(dmin)
    if robust == "huber":
        w = w * np.minimum(1.0, scale / np.maximum(resid, 1e-12))
    elif robust == "tukey":
        u = resid / max(scale, 1e-12)
        w = w * np.where(u < 1.0, (1.0 - u ** 2) ** 2, 0.0)
    s = {"w": w.sum()}
    for a, name in enumerate("xyz"):
        s[f"p{name}"] = (w * q[:, a]).sum()
        s[f"q{name}"] = (w * qq[:, a]).sum()
    for a in range(3):
        for b in range(3):
            s[f"pq{a}{b}"] = (w * q[:, a] * qq[:, b]).sum()
    s["pp"] = (w * (q ** 2).sum(-1)).sum()
    s["qq"] = (w * (qq ** 2).sum(-1)).sum()
    if plane:
        a6 = np.concatenate([np.cross(q, nn), nn], axis=-1)
        for k in range(6):
            for li in range(k, 6):
                s[f"a{k}{li}"] = (w * a6[:, k] * a6[:, li]).sum()
            s[f"ra{k}"] = (w * r * a6[:, k]).sum()
    return s


@pytest.mark.parametrize("robust", ["none", "huber", "tukey"])
@pytest.mark.parametrize("plane", [False, True])
def test_moments_match_numpy_reference(robust, plane):
    q, cand, cand_n = _case(0)
    got = fused_moment_sweep(
        jnp.asarray(q), jnp.asarray(cand),
        cand_normals=jnp.asarray(cand_n) if plane else None,
        gate=1.0, robust_kernel=robust, bn=BN, bc=BC, interpret=True)
    ref = _ref_moments(q, cand, cand_n if plane else None,
                       robust=robust)
    assert set(got) == set(moment_names(plane))
    for name in got:
        np.testing.assert_allclose(float(got[name]), ref[name],
                                   rtol=2e-5, atol=2e-5, err_msg=name)


def test_moment_name_sets():
    assert moment_names(False) == P2P_MOMENTS and len(P2P_MOMENTS) == 18
    assert moment_names(True) == P2PLANE_MOMENTS
    assert len(P2PLANE_MOMENTS) == 45


@pytest.mark.parametrize("plane", [False, True])
def test_bf16_prune_preserves_moments(plane):
    """The widened bf16 screen may never drop a true inlier, and winner
    selection among survivors runs on exact fp32 distances: pruned and
    unpruned sweeps produce identical moments."""
    q, cand, cand_n = _case(1)
    kw = dict(cand_normals=jnp.asarray(cand_n) if plane else None,
              gate=1.0, bn=BN, bc=BC, interpret=True)
    base = fused_moment_sweep(jnp.asarray(q), jnp.asarray(cand), **kw)
    pruned = fused_moment_sweep(jnp.asarray(q), jnp.asarray(cand),
                                prune=True, **kw)
    for name in base:
        np.testing.assert_allclose(float(pruned[name]), float(base[name]),
                                   rtol=1e-5, atol=1e-5, err_msg=name)


def test_src_valid_zeroes_rows():
    """Masked source rows contribute nothing: sweep(sv) == sweep(subset)."""
    q, cand, _ = _case(2)
    sv = (np.arange(q.shape[0]) % 3 != 0).astype(np.float32)
    masked = fused_moment_sweep(jnp.asarray(q), jnp.asarray(cand),
                                jnp.asarray(sv), gate=1.0,
                                bn=BN, bc=BC, interpret=True)
    keep = sv > 0
    subset = fused_moment_sweep(jnp.asarray(q[keep]),
                                jnp.asarray(cand[keep]), gate=1.0,
                                bn=BN, bc=BC, interpret=True)
    for name in masked:
        np.testing.assert_allclose(float(masked[name]),
                                   float(subset[name]),
                                   rtol=1e-5, atol=1e-5, err_msg=name)


def test_empty_neighbourhood_zero_moments():
    """All-sentinel candidate slots (empty grid neighbourhood) produce
    exactly zero moments — the input to the PR-5 degenerate freeze."""
    q, cand, _ = _case(3)
    empty = np.full_like(cand, _MASK_COORD)
    s = fused_moment_sweep(jnp.asarray(q), jnp.asarray(empty), gate=1.0,
                           bn=BN, bc=BC, interpret=True)
    for name, v in s.items():
        assert float(v) == 0.0, name


def test_fused_icp_degenerate_freeze(small_scene):
    """Target entirely out of gate range ⇒ identity transform, inf rmse,
    degenerate flag — same contract as the unfused zero-inlier path."""
    src, _, _ = small_scene
    far = jnp.asarray(src, jnp.float32) + 500.0
    params = ICPParams(max_iterations=3, fused=True)
    res = icp(jnp.asarray(src, jnp.float32), far, params)
    np.testing.assert_allclose(np.asarray(res.T), np.eye(4), atol=1e-6)
    assert not bool(res.converged)
    assert np.isinf(float(res.rmse))


@pytest.mark.parametrize("minimizer,robust", [
    ("point_to_point", "none"),
    ("point_to_point", "huber"),
    ("point_to_plane", "none"),
    ("point_to_plane", "tukey"),
])
def test_fused_matches_unfused_icp(small_scene, minimizer, robust):
    """Full-run transform parity, fused vs unfused (ISSUE-6 ≤1e-3)."""
    src, dst, _ = small_scene
    srcj = jnp.asarray(src, jnp.float32)
    dstj = jnp.asarray(dst, jnp.float32)
    params = ICPParams(max_iterations=12, minimizer=minimizer,
                       robust_kernel=robust)
    normals = None
    if minimizer == "point_to_plane":
        from repro.data.normals import estimate_normals
        normals, _ = estimate_normals(dstj)
    ru = icp_fixed_iterations(srcj, dstj, params,
                              target_normals=normals)
    rf = icp_fixed_iterations(srcj, dstj, params._replace(fused=True),
                              target_normals=normals)
    Tu, Tf = np.asarray(ru.T), np.asarray(rf.T)
    assert np.linalg.norm(Tf[:3, :3] - Tu[:3, :3]) <= 1e-3
    assert np.linalg.norm(Tf[:3, 3] - Tu[:3, 3]) <= 1e-3


def test_fused_engine_and_batch(small_scene):
    """pallas engine with params.fused: single and batched registration
    agree with the unfused engine within the acceptance bound."""
    src, dst, _ = small_scene
    params = ICPParams(max_iterations=10)
    eng = get_engine("pallas")
    ru = eng.register(src, dst, params)
    rf = eng.register(src, dst, params._replace(fused=True))
    assert float(jnp.abs(rf.T - ru.T).max()) <= 1e-3
    # batch: two identical lanes must both match the single-cloud result
    sb = jnp.stack([jnp.asarray(src, jnp.float32)] * 2)
    db = jnp.stack([jnp.asarray(dst, jnp.float32)] * 2)
    rb = eng.register_batch(sb, db, params._replace(fused=True))
    assert rb.T.shape == (2, 4, 4)
    for lane in range(2):
        assert float(jnp.abs(rb.T[lane] - rf.T).max()) <= 1e-3


def test_pyramid_fused_polish_parity(small_scene):
    src, dst, _ = small_scene
    params = ICPParams(max_iterations=10)
    eng = get_engine("pyramid")
    ru = eng.register(src, dst, params)
    rf = eng.register(src, dst, params._replace(fused=True))
    assert float(jnp.abs(rf.T - ru.T).max()) <= 1e-3


def test_default_fused_fn_requires_normals_for_plane(small_scene):
    """make_fused_fn must refuse a plane minimiser without a normal
    payload instead of silently producing point moments."""
    src, dst, _ = small_scene
    dstj = jnp.asarray(dst, jnp.float32)
    params = ICPParams(minimizer="point_to_plane")
    grid = build_voxel_grid(dstj, 1.0, (64, 64, 16))
    with pytest.raises(ValueError):
        make_fused_fn(grid, params)
    # and the default builder auto-threads explicit normals fine
    nrm = jnp.zeros_like(dstj).at[:, 2].set(1.0)
    fn = default_fused_fn(dstj, params, target_normals=nrm,
                          grid_dims=(64, 64, 16))
    m = fn(jnp.asarray(src, jnp.float32))
    assert m.A.shape == (6, 6) and m.b.shape == (6,)


def test_interpret_tristate_resolution():
    on_tpu = jax.default_backend() == "tpu"
    assert default_interpret(None) == (not on_tpu)
    assert default_interpret(True) is True
    assert default_interpret(False) is False
    kw = pallas_call_kwargs(None, ("parallel", "arbitrary"))
    assert kw["interpret"] == (not on_tpu)
    assert pallas_call_kwargs(True, ("arbitrary",)) == {"interpret": True}


def test_kernels_accept_tristate_interpret():
    """Every kernel wrapper runs with interpret=None on this backend (the
    CPU-CI contract: auto-resolution, no skips, no hand-rolled checks)."""
    from repro.kernels.normals import estimate_normals_pallas
    from repro.kernels.ops import nn_search_pallas
    key = jax.random.PRNGKey(0)
    src = jax.random.uniform(key, (64, 3), minval=-2, maxval=2)
    dst = jax.random.uniform(jax.random.fold_in(key, 1), (256, 3),
                             minval=-2, maxval=2)
    d2a, ia = nn_search_pallas(src, dst, None, interpret=None)
    d2b, ib = nn_search_pallas(src, dst, None, interpret=True)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    na, va = estimate_normals_pallas(dst, interpret=None)
    nb, vb = estimate_normals_pallas(dst, interpret=True)
    np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
    np.testing.assert_allclose(np.asarray(na), np.asarray(nb),
                               atol=1e-6)
    grid = build_voxel_grid(dst, 1.0, (8, 8, 8))
    params = ICPParams()
    ma = make_fused_fn(grid, params, interpret=None)(src)
    mb = make_fused_fn(grid, params, interpret=True)(src)
    np.testing.assert_allclose(float(ma.sw), float(mb.sw), rtol=1e-6)


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="compiled Mosaic lowering needs a TPU backend")
def test_interpret_matches_compiled_on_tpu(small_scene):
    """Where a compiled backend exists, interpret and compiled runs of the
    fused pass must agree (guards the Mosaic lowering itself)."""
    src, dst, _ = small_scene
    dstj = jnp.asarray(dst, jnp.float32)
    grid = build_voxel_grid(dstj, 1.0, (64, 64, 16))
    fn_i = make_fused_fn(grid, ICPParams(), interpret=True)
    fn_c = make_fused_fn(grid, ICPParams(), interpret=False)
    mi = fn_i(jnp.asarray(src, jnp.float32))
    mc = fn_c(jnp.asarray(src, jnp.float32))
    np.testing.assert_allclose(float(mc.sw), float(mi.sw), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(mc.spq), np.asarray(mi.spq),
                               rtol=1e-4, atol=1e-4)
