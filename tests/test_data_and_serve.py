"""Data pipeline determinism/prefetch + serving engine + modality stubs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.data.tokens import PrefetchLoader, TokenStream
from repro.models import lm
from repro.serve.engine import Engine
from repro.serve.modality import (chameleon_image_stub, musicgen_frame_stub,
                                  rvq_encode, vq_encode)


def test_token_stream_deterministic():
    a = TokenStream(1000, 4, 16, seed=5)
    b = TokenStream(1000, 4, 16, seed=5)
    for s in (0, 3, 10_000):
        np.testing.assert_array_equal(a.batch_at(s)["tokens"],
                                      b.batch_at(s)["tokens"])
    assert not np.array_equal(a.batch_at(0)["tokens"], a.batch_at(1)["tokens"])


def test_token_stream_embeds_mode():
    s = TokenStream(100, 2, 8, embed_dim=32)
    b = s.batch_at(0)
    assert "embeds" in b and b["embeds"].shape == (2, 8, 32)
    assert b["labels"].shape == (2, 8)


def test_prefetch_loader_order():
    stream = TokenStream(100, 2, 8, seed=1)
    loader = PrefetchLoader(stream, prefetch=2)
    steps = [next(loader)[0] for _ in range(5)]
    loader.close()
    assert steps == [0, 1, 2, 3, 4]


def test_engine_generates_and_is_greedy_deterministic():
    cfg = get_smoke("qwen2-0.5b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_len=64)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    out1 = eng.generate(prompts, n_steps=6)
    out2 = Engine(cfg, params, max_len=64).generate(prompts, n_steps=6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    # generated continuation must equal teacher-forced argmax decode
    full = jnp.concatenate([prompts, out1], axis=1)
    logits, _ = lm.forward(params, cfg, tokens=full)
    greedy = jnp.argmax(logits[:, 7:-1], axis=-1)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(greedy))


def test_vq_encode_exact_nn():
    key = jax.random.PRNGKey(2)
    k1, k2 = jax.random.split(key)
    codebook = jax.random.normal(k1, (64, 16))
    latents = jax.random.normal(k2, (4, 10, 16))
    codes, quant = vq_encode(latents, codebook)
    # brute-force reference
    d2 = jnp.sum((latents[..., None, :] - codebook) ** 2, -1)
    np.testing.assert_array_equal(np.asarray(codes),
                                  np.asarray(jnp.argmin(d2, -1)))
    np.testing.assert_allclose(np.asarray(quant),
                               np.asarray(codebook[codes]), rtol=1e-6)


def test_rvq_reduces_residual():
    """Each RVQ level must not increase reconstruction error."""
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    books = jax.random.normal(k1, (4, 128, 8))
    # a zero entry per codebook guarantees quantisation never hurts
    books = books.at[:, 0].set(0.0)
    latents = jax.random.normal(k2, (2, 32, 8))
    errs = []
    for lvl in range(1, 5):
        _, recon = rvq_encode(latents, books[:lvl])
        errs.append(float(jnp.mean((latents - recon) ** 2)))
    assert all(b <= a + 1e-6 for a, b in zip(errs, errs[1:])), errs


def test_modality_stubs_shapes():
    codes, cb = chameleon_image_stub(jax.random.PRNGKey(4), batch=2,
                                     n_patches=16, d_latent=8,
                                     codebook_size=32)
    assert codes.shape == (2, 16) and bool(jnp.all(codes < 32))
    codes, recon = musicgen_frame_stub(jax.random.PRNGKey(5), batch=2,
                                       n_frames=12, d_latent=8, n_books=3,
                                       codebook_size=16)
    assert codes.shape == (3, 2, 12)
    assert recon.shape == (2, 12, 8)
