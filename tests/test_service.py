"""Multi-stream registration service: admission, retirement, drops,
retrace-freedom, and bit-exact parity with the standalone pipeline.

Every test shares ONE service configuration (slots, bucket shapes,
ICPParams), so the slot engine singleton compiles its executables once
for the whole module — the trace-counter assertions then measure the
service's behaviour, not per-test compilation. ``recovery=False`` keeps
the control plane on the legacy accept guard: no cascade tiers means no
extra per-tier engines compile inside the tests.
"""
import numpy as np
import pytest

from repro.core import ICPParams
from repro.core.odometry import OdometryConfig, OdometryPipeline
from repro.data.pointcloud import SceneConfig, sequence_scans
from repro.data.submap import SubmapParams
from repro.serve.registration_service import (RegistrationService,
                                              ServiceConfig)

SCENE = SceneConfig(n_ground=300, n_walls=220, n_poles=60, n_clutter=70,
                    extent=12.0, sensor_range=16.0)
ODO = OdometryConfig(
    params=ICPParams(max_iterations=6, max_correspondence_distance=1.0,
                     chunk=512, robust_kernel="huber", robust_scale=0.3),
    submap=SubmapParams(voxel_size=0.75, capacity=1024, dims=(48, 48, 16),
                        evict_radius=12.0),
    scan_budget=256, recovery=False)
SLOTS = 4


def _service(**over):
    cfg = ServiceConfig(slots=SLOTS, scan_capacity=1024, odometry=ODO,
                        **over)
    return RegistrationService(cfg)


def _fleet_scans(n_streams, frames, base_seq=0):
    return {f"veh{s}": sequence_scans(base_seq + s, frames, SCENE)
            for s in range(n_streams)}


def _drive(svc, fleet):
    """Submit every stream's frames wave-by-wave; returns
    {sid: [(pose, diag), ...]} in frame order."""
    out = {sid: [] for sid in fleet}
    frames = max(len(f) for f in fleet.values())
    for f in range(frames):
        for sid, scans in fleet.items():
            if f < len(scans):
                svc.submit(sid, scans[f])
        for sid, res in svc.step().items():
            out[sid].append(res)
    return out


# -- bit-exact parity ------------------------------------------------------

def test_service_matches_standalone_pipeline_bitwise():
    """The acceptance contract: every stream of a clean fleet produces
    the same poses AND the same diagnostics, bit for bit, as a
    standalone OdometryPipeline(stream_config) replay."""
    svc = _service()
    fleet = _fleet_scans(3, 5)
    for sid in fleet:
        svc.admit(sid)
    staged = {sid: [svc.stage_scan(sc) for sc in scans]
              for sid, scans in fleet.items()}
    out = _drive(svc, fleet)
    for sid, frames in staged.items():
        ref = OdometryPipeline(svc.stream_config)
        for f, (padded, valid) in enumerate(frames):
            pose_ref, diag_ref = ref.process(padded, valid)
            pose_svc, diag_svc = out[sid][f]
            np.testing.assert_array_equal(np.asarray(pose_svc),
                                          np.asarray(pose_ref))
            assert diag_svc == diag_ref


# -- retrace avoidance -----------------------------------------------------

def test_midflight_join_does_not_retrace():
    svc = _service()
    fleet = _fleet_scans(2, 3)
    for sid in fleet:
        svc.admit(sid)
    _drive(svc, fleet)
    traces = svc.engine.trace_count
    svc.admit("late")                    # joins a warm fleet mid-flight
    late_scans = sequence_scans(7, 3, SCENE)
    out = _drive(svc, {"late": late_scans})
    assert len(out["late"]) == 3
    assert svc.engine.trace_count == traces


def test_churn_never_retraces_after_warmup():
    """Joins, retires, drops, and empty queues all ride through the same
    fixed-shape executables: zero trace growth across the whole churn."""
    svc = _service(max_queue=1)
    fleet = _fleet_scans(2, 2)
    for sid in fleet:
        svc.admit(sid)
    _drive(svc, fleet)                   # warmup: compiles everything
    traces = svc.engine.trace_count
    svc.admit("joiner")
    scans = sequence_scans(5, 4, SCENE)
    for f in range(4):
        svc.submit("joiner", scans[f])
        svc.submit("joiner", scans[f])   # overflow: deterministic drop
        svc.step()
    svc.close("veh0")
    svc.step()                           # round with an empty slot
    assert svc.frames_dropped > 0
    assert svc.engine.trace_count == traces


# -- admission / retirement ------------------------------------------------

def test_converged_stream_retires_and_slot_is_reused():
    svc = _service()
    fleet = _fleet_scans(SLOTS, 2)
    for sid in fleet:
        assert svc.admit(sid) is True
    _drive(svc, fleet)
    assert svc.admit("pending") is False          # fleet full: queued
    report = svc.close("veh0")
    assert report.frames_processed == 2
    assert report.final_pose is not None
    # the freed slot rebinds the pending stream immediately
    assert svc.service_report()["active_streams"] == SLOTS
    assert svc.service_report()["pending_streams"] == 0
    out = _drive(svc, {"pending": sequence_scans(9, 2, SCENE)})
    assert len(out["pending"]) == 2
    with pytest.raises(KeyError):
        svc.report("veh0")               # retired streams are gone


def test_admission_reject_policy_raises():
    svc = _service(admission="reject")
    for s in range(SLOTS):
        svc.admit(f"veh{s}")
    with pytest.raises(RuntimeError, match="service full"):
        svc.admit("overflow")


def test_duplicate_admit_raises():
    svc = _service()
    svc.admit("veh0")
    with pytest.raises(ValueError, match="already admitted"):
        svc.admit("veh0")


# -- backpressure ----------------------------------------------------------

def test_drop_oldest_keeps_freshest_frames():
    svc = _service(max_queue=2)
    svc.admit("veh0")
    scans = sequence_scans(0, 4, SCENE)
    assert all(svc.submit("veh0", sc) for sc in scans)  # oldest pay
    report = svc.report("veh0")
    assert report.frames_submitted == 4
    assert report.frames_dropped == 2
    # the survivors are the two freshest: their downsampled sources
    # match a pipeline replay of scans[2:] (frames 2 and 3)
    ref = OdometryPipeline(svc.stream_config)
    for sc in scans[2:]:
        ref.process(*svc.stage_scan(sc))
    out = svc.drain()
    assert len(out["veh0"]) == 2
    np.testing.assert_array_equal(np.asarray(out["veh0"][-1][0]),
                                  np.asarray(ref.poses[-1]))


def test_drop_newest_refuses_submission():
    svc = _service(max_queue=2, drop_policy="newest")
    svc.admit("veh0")
    scans = sequence_scans(0, 4, SCENE)
    results = [svc.submit("veh0", sc) for sc in scans]
    assert results == [True, True, False, False]
    assert svc.report("veh0").frames_dropped == 2


def test_drops_are_deterministic():
    reports = []
    for _ in range(2):
        svc = _service(max_queue=1)
        svc.admit("veh0")
        scans = sequence_scans(0, 4, SCENE)
        for sc in scans:
            svc.submit("veh0", sc)
            svc.submit("veh0", sc)
        svc.drain()
        reports.append(svc.report("veh0"))
    assert reports[0].frames_dropped == reports[1].frames_dropped
    np.testing.assert_array_equal(reports[0].final_pose,
                                  reports[1].final_pose)


def test_close_counts_unstepped_frames_as_dropped():
    svc = _service()
    svc.admit("veh0")
    for sc in sequence_scans(0, 3, SCENE):
        svc.submit("veh0", sc)
    report = svc.close("veh0")
    assert report.frames_dropped == 3
    assert report.frames_processed == 0


# -- degraded input through the service ------------------------------------

def test_empty_scan_coasts_and_quarantines():
    svc = _service()
    svc.admit("veh0")
    scans = sequence_scans(0, 3, SCENE)
    out = _drive(svc, {"veh0": scans})
    empty = np.full((64, 3), np.nan, np.float32)
    svc.submit("veh0", empty)
    pose, diag = svc.step()["veh0"]
    assert diag.quarantined and diag.iterations == 0
    assert np.all(np.isfinite(np.asarray(pose)))
    assert out["veh0"]                   # earlier frames were fine


def test_oversized_scan_rejected_at_submit():
    svc = _service()
    svc.admit("veh0")
    big = np.zeros((svc.config.scan_capacity + 1, 3), np.float32)
    with pytest.raises(ValueError, match="exceeds"):
        svc.submit("veh0", big)


# -- device-sharded mode ---------------------------------------------------
# D=1 here (single-device CI interpreter); tests/test_multidevice.py runs
# the same contracts on an 8-device host-platform fleet in a subprocess.

def _sharded_service(**over):
    over.setdefault("odometry", ODO)
    cfg = ServiceConfig(slots=SLOTS, scan_capacity=1024, devices=1, **over)
    return RegistrationService(cfg)


def test_sharded_service_matches_standalone_pipeline_bitwise():
    """The weak-scaling parity contract at its D=1 corner: the shard_map'd
    round (sharded fleet state, host staging, batched fuse into resident
    submaps) reproduces a standalone replay bit for bit — poses AND
    diagnostics."""
    svc = _sharded_service()
    fleet = _fleet_scans(3, 5)
    for sid in fleet:
        svc.admit(sid)
    staged = {sid: [svc.stage_scan(sc) for sc in scans]
              for sid, scans in fleet.items()}
    out = _drive(svc, fleet)
    for sid, frames in staged.items():
        ref = OdometryPipeline(svc.stream_config)
        for f, (padded, valid) in enumerate(frames):
            pose_ref, diag_ref = ref.process(padded, valid)
            pose_svc, diag_svc = out[sid][f]
            np.testing.assert_array_equal(np.asarray(pose_svc),
                                          np.asarray(pose_ref))
            assert diag_svc == diag_ref


def test_sharded_churn_never_retraces():
    """Joins, retires (with in-place lane resets), drops, and empty
    queues: the sharded executables are fixed-shape too, so churn never
    grows the trace count."""
    svc = _sharded_service(max_queue=1)
    fleet = _fleet_scans(2, 2)
    for sid in fleet:
        svc.admit(sid)
    _drive(svc, fleet)
    traces = svc.engine.trace_count
    svc.admit("joiner")
    scans = sequence_scans(5, 4, SCENE)
    for f in range(4):
        svc.submit("joiner", scans[f])
        svc.submit("joiner", scans[f])
        svc.step()
    svc.close("veh0")                    # lane reset + empty slot round
    svc.step()
    assert svc.frames_dropped > 0
    assert svc.engine.trace_count == traces


def test_sharded_close_resets_lane_state():
    """A stream bound to a retired stream's slot must never see its
    predecessor's resident submap: the successor's whole trajectory
    replays bit-identically against a fresh standalone pipeline (stale
    fleet state would poison its bootstrap fuse and every frame after)."""
    svc = _sharded_service()
    fleet = _fleet_scans(SLOTS, 3)
    for sid in fleet:
        svc.admit(sid)
    _drive(svc, fleet)
    freed = svc._streams["veh0"].slot
    svc.close("veh0")
    svc.admit("fresh")
    assert svc._streams["fresh"].slot == freed   # the lane is reused
    scans = sequence_scans(11, 3, SCENE)
    staged = [svc.stage_scan(sc) for sc in scans]
    out = _drive(svc, {"fresh": scans})
    ref = OdometryPipeline(svc.stream_config)
    for f, (padded, valid) in enumerate(staged):
        pose_ref, diag_ref = ref.process(padded, valid)
        np.testing.assert_array_equal(np.asarray(out["fresh"][f][0]),
                                      np.asarray(pose_ref))
        assert out["fresh"][f][1] == diag_ref


def test_fp16_sharded_service_matches_fp16_standalone():
    """Memory-lean resident submaps through the sharded service: the
    fp16 fleet round is still bit-identical to an fp16 standalone replay
    (both decode, fuse in fp32, re-encode through the same code path)."""
    odo16 = ODO._replace(submap=ODO.submap._replace(storage="fp16"))
    svc = _sharded_service(odometry=odo16)
    fleet = _fleet_scans(2, 4)
    for sid in fleet:
        svc.admit(sid)
    staged = {sid: [svc.stage_scan(sc) for sc in scans]
              for sid, scans in fleet.items()}
    out = _drive(svc, fleet)
    for sid, frames in staged.items():
        ref = OdometryPipeline(svc.stream_config)
        for f, (padded, valid) in enumerate(frames):
            pose_ref, diag_ref = ref.process(padded, valid)
            np.testing.assert_array_equal(np.asarray(out[sid][f][0]),
                                          np.asarray(pose_ref))
            assert out[sid][f][1] == diag_ref


def test_dropped_cells_surface_in_service_diagnostics():
    """A capacity-starved stream's saturation is visible per frame in
    FrameDiagnostics.dropped_cells, identically in the service round and
    the standalone replay (legacy single-device mode)."""
    odo_tiny = ODO._replace(submap=ODO.submap._replace(capacity=64))
    svc = RegistrationService(ServiceConfig(slots=SLOTS, scan_capacity=1024,
                                            odometry=odo_tiny))
    svc.admit("veh0")
    scans = sequence_scans(0, 2, SCENE)
    staged = [svc.stage_scan(sc) for sc in scans]
    out = _drive(svc, {"veh0": scans})
    ref = OdometryPipeline(svc.stream_config)
    diags_ref = [ref.process(p, v)[1] for p, v in staged]
    assert out["veh0"][0][1].dropped_cells > 0   # bootstrap already drops
    for (_, diag_svc), diag_ref in zip(out["veh0"], diags_ref):
        assert diag_svc == diag_ref
