"""Grid-bucketed NN vs the exact brute-force searcher.

The exactness contract (DESIGN.md §8): on a *dense* grid — every occupied
cell under ``max_per_cell``, every true NN within one voxel — grid NN must
reproduce ``core.nn_search`` exactly, including on dst_valid-masked padded
clouds from ``data/collate``. The Pallas candidate-sweep kernel (interpret
mode) must match the XLA gather path bit-for-bit on indices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.nn_search import nn_search
from repro.core.nn_search_grid import gather_candidates, nn_search_grid
from repro.data.collate import collate_pairs
from repro.data.voxelize import build_voxel_grid
from repro.kernels.nn_search_grid import nn_search_grid_pallas

DIMS = (16, 16, 16)
VOXEL = 2.0  # dense uniform clouds below have NN distances << 2 m


def _clouds(seed, n=220, m=3000, scale=10.0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    src = jax.random.uniform(k1, (n, 3), minval=-scale, maxval=scale)
    dst = jax.random.uniform(k2, (m, 3), minval=-scale, maxval=scale)
    return src, dst


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_matches_exact_on_dense_grid(seed):
    src, dst = _clouds(seed)
    grid = build_voxel_grid(dst, VOXEL, DIMS)
    assert int(jnp.max(grid.count)) <= 64, "test premise: no overflow"
    d2_ref, idx_ref = nn_search(src, dst, chunk=512)
    assert float(jnp.sqrt(jnp.max(d2_ref))) < VOXEL, \
        "test premise: all NNs within one voxel"
    d2, idx = nn_search_grid(src, grid, max_per_cell=64)
    # The brute searcher *ranks* via the matmul expansion (~1e-4 absolute
    # cancellation error), the grid searcher ranks exact direct distances:
    # near-ties can resolve to different rows. Require equal distances and
    # every index to be a true argmin, same as the brute-vs-naive test.
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_ref),
                               rtol=1e-5, atol=1e-5)
    gathered = jnp.sum((src - dst[idx]) ** 2, axis=-1)
    np.testing.assert_allclose(np.asarray(gathered), np.asarray(d2_ref),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.mean((idx == idx_ref).astype(jnp.float32))) > 0.99


def test_matches_exact_on_padded_clouds():
    """dst_valid-masked padded clouds from data/collate: the grid excludes
    padded rows entirely and must agree with the masked exact searcher."""
    src, dst = _clouds(3, n=180, m=900)
    batch = collate_pairs([(np.asarray(src), np.asarray(dst))])
    dst_p = jnp.asarray(batch.dst[0])
    dv = jnp.asarray(batch.dst_valid[0])
    grid = build_voxel_grid(dst_p, VOXEL, DIMS, valid=dv)
    d2_ref, idx_ref = nn_search(src, dst_p, chunk=256, dst_valid=dv)
    d2, idx = nn_search_grid(src, grid, max_per_cell=64)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_ref))
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_ref),
                               rtol=1e-6, atol=1e-6)
    assert bool(jnp.all(idx < dst.shape[0]))  # never a padded row


def test_pallas_variant_matches_xla_path():
    src, dst = _clouds(4, n=150, m=2000)
    grid = build_voxel_grid(dst, VOXEL, DIMS)
    d2, idx = nn_search_grid(src, grid, max_per_cell=64)
    d2_k, idx_k = nn_search_grid_pallas(src, grid, max_per_cell=64,
                                        bn=64, bc=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(idx_k), np.asarray(idx))
    np.testing.assert_allclose(np.asarray(d2_k), np.asarray(d2),
                               rtol=1e-6, atol=1e-6)


def test_empty_neighbourhood_returns_inf():
    dst = jnp.asarray(np.random.default_rng(0).uniform(-2, 2, (200, 3)),
                      jnp.float32)
    grid = build_voxel_grid(dst, 1.0, (32, 32, 32),
                            origin=jnp.asarray([-2.0, -2.0, -2.0]))
    far = jnp.asarray([[20.0, 20.0, 20.0]])  # clips to a far empty corner
    d2, idx = nn_search_grid(far, grid, max_per_cell=8)
    assert bool(jnp.isinf(d2[0]))
    assert int(idx[0]) == 0


def test_exact_fallback_rescues_empty_rows():
    dst = jnp.asarray(np.random.default_rng(1).uniform(-2, 2, (300, 3)),
                      jnp.float32)
    grid = build_voxel_grid(dst, 1.0, (32, 32, 32),
                            origin=jnp.asarray([-2.0, -2.0, -2.0]))
    src = jnp.concatenate([dst[:4] + 0.01,
                           jnp.full((2, 3), 25.0)])  # 2 empty-hood rows
    d2, idx = nn_search_grid(src, grid, max_per_cell=16,
                             exact_fallback=True, dst=dst, chunk=64)
    d2_ref, idx_ref = nn_search(src, dst, chunk=64)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_ref))


def test_exact_fallback_accepts_bf16_target():
    """Both lax.cond branches must agree on the matched-points dtype even
    when the fallback target cloud is bf16 (the nn_search bf16 path)."""
    dst = jnp.asarray(np.random.default_rng(2).uniform(-2, 2, (200, 3)),
                      jnp.float32)
    grid = build_voxel_grid(dst, 1.0, (32, 32, 32),
                            origin=jnp.asarray([-2.0, -2.0, -2.0]))
    src = jnp.concatenate([dst[:4] + 0.01, jnp.full((1, 3), 25.0)])
    d2, idx, pts = nn_search_grid(src, grid, max_per_cell=16,
                                  exact_fallback=True,
                                  dst=dst.astype(jnp.bfloat16), chunk=64,
                                  return_points=True)
    assert pts.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(d2)))


def test_overflow_truncation_stays_in_cell():
    """An overflowing cell returns *some* same-cell point: d2 error is
    bounded by the cell diagonal, never a wild match."""
    rng = np.random.default_rng(2)
    clump = rng.uniform(0.0, 1.0, (500, 3)).astype(np.float32)  # one cell
    dst = jnp.asarray(clump)
    grid = build_voxel_grid(dst, 2.0, (4, 4, 4), origin=jnp.zeros(3))
    src = jnp.asarray(rng.uniform(0.2, 0.8, (50, 3)).astype(np.float32))
    d2, idx = nn_search_grid(src, grid, max_per_cell=8)  # truncates hard
    assert float(jnp.max(d2)) <= 3.0 * 2.0 ** 2  # within cell diagonal²
    matched = dst[idx]
    assert bool(jnp.all(jnp.abs(matched - src) <= 2.0))


def test_return_points_matches_indexing():
    src, dst = _clouds(5, n=64, m=500)
    grid = build_voxel_grid(dst, VOXEL, DIMS)
    d2, idx, pts = nn_search_grid(src, grid, max_per_cell=64,
                                  return_points=True)
    np.testing.assert_allclose(np.asarray(pts), np.asarray(dst)[np.asarray(idx)],
                               atol=0)


def test_rings2_covers_wider_radius():
    """rings=2 with half-size cells finds NNs up to 2*voxel away exactly."""
    src, dst = _clouds(6, n=200, m=3000)
    grid = build_voxel_grid(dst, VOXEL / 2, (32, 32, 32))
    d2_ref, idx_ref = nn_search(src, dst, chunk=512)
    d2, idx = nn_search_grid(src, grid, max_per_cell=32, rings=2)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_ref))
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_ref),
                               rtol=1e-6, atol=1e-6)


def test_gather_candidates_mask_semantics():
    src, dst = _clouds(7, n=32, m=400)
    grid = build_voxel_grid(dst, VOXEL, DIMS)
    pts, idx, valid = gather_candidates(src, grid, max_per_cell=16)
    assert pts.shape == (32, 27 * 16, 3)
    # masked slots carry the far sentinel; valid slots carry real points
    assert bool(jnp.all(jnp.where(valid[..., None], jnp.abs(pts) < 1e3,
                                  pts == 1e15)))


def test_overflow_stats_pinned():
    """ISSUE 3 satellite: cell-overflow drops and empty (inf) rows are
    countable instead of silent."""
    from repro.core.nn_search_grid import neighborhood_stats

    rng = np.random.default_rng(3)
    # 100 points clumped inside one 2 m cell of a 4x4x4 lattice.
    clump = rng.uniform(0.0, 1.0, (100, 3)).astype(np.float32)
    grid = build_voxel_grid(jnp.asarray(clump), 2.0, (4, 4, 4),
                            origin=jnp.zeros(3))
    # query A sits in the clump (overflowing cell); query B in an empty
    # far corner whose whole 27-neighbourhood is unoccupied.
    src = jnp.asarray([[0.5, 0.5, 0.5], [7.5, 7.5, 7.5]], jnp.float32)
    stats = jax.jit(lambda s: neighborhood_stats(s, grid, max_per_cell=8))(
        src)
    assert float(stats.overflow_frac) == 0.5   # A only
    assert float(stats.empty_frac) == 0.5      # B only
    # A's neighbourhood holds 100 candidates, 8 kept -> 92 dropped.
    np.testing.assert_allclose(float(stats.dropped_frac), 92.0 / 100.0)

    # the searcher surfaces the same stats inline, and B's row is inf
    d2, idx, stats2 = nn_search_grid(src, grid, max_per_cell=8,
                                     with_stats=True)
    assert float(stats2.overflow_frac) == 0.5
    assert np.isinf(float(d2[1]))

    # with a generous capacity nothing overflows and nothing is dropped
    relaxed = neighborhood_stats(src, grid, max_per_cell=128)
    assert float(relaxed.overflow_frac) == 0.0
    assert float(relaxed.dropped_frac) == 0.0


def test_out_of_lattice_queries_reported_never_boundary_matched():
    """ISSUE 5 regression: queries translated past the grid extent used to
    clip into boundary cells and return confidently-wrong neighbours; they
    must resolve to the d2=inf path and be counted in the stats."""
    src, dst = _clouds(11, n=64, m=1200)
    grid = build_voxel_grid(dst, VOXEL, DIMS)
    # Translate the whole query cloud far past the lattice (dims*voxel =
    # 32 m wide, anchored at the cloud): a moving ego that outran the map.
    far = src + jnp.asarray([200.0, 0.0, 0.0])
    d2, idx, stats = nn_search_grid(far, grid, max_per_cell=64,
                                    with_stats=True)
    assert bool(jnp.all(jnp.isinf(d2)))        # reported miss, not a match
    assert float(stats.out_of_lattice) == 1.0
    assert float(stats.empty_frac) == 1.0
    # The brute fallback rescues exactly these rows with true neighbours.
    d2_fb, idx_fb = nn_search_grid(far, grid, max_per_cell=64,
                                   exact_fallback=True, dst=dst, chunk=256)[:2]
    d2_ref, idx_ref = nn_search(far, dst, chunk=256)
    np.testing.assert_allclose(np.asarray(d2_fb), np.asarray(d2_ref),
                               rtol=1e-4, atol=1e-2)
    # In-lattice queries on the same grid still report zero out-of-lattice.
    stats_in = nn_search_grid(src, grid, max_per_cell=64,
                              with_stats=True)[-1]
    assert float(stats_in.out_of_lattice) == 0.0


def test_just_outside_lattice_still_sees_boundary_cells():
    """A query within ``rings`` cells of the lattice edge genuinely overlaps
    boundary cells — it must still find its true boundary neighbour (the
    fix only removes *fictitious* overlap, not real overlap)."""
    dst = jnp.asarray([[0.5, 0.5, 0.5], [7.5, 7.5, 7.5]], jnp.float32)
    grid = build_voxel_grid(dst, 2.0, (4, 4, 4), origin=jnp.zeros(3))
    # 0.4 m past the lattice edge along x: cell (4, 3, 3) — out of lattice,
    # but its 27-hood overlaps the boundary cell holding dst[1].
    q = jnp.asarray([[8.4, 7.5, 7.5]], jnp.float32)
    d2, idx, stats = nn_search_grid(q, grid, max_per_cell=8, with_stats=True)
    assert int(idx[0]) == 1
    np.testing.assert_allclose(float(d2[0]), 0.9 ** 2, rtol=1e-5)
    assert float(stats.out_of_lattice) == 1.0  # counted, yet still served


def test_pyramid_polish_stats_surface():
    from repro.core.pyramid import PyramidEngine

    src, dst = _clouds(9, n=64, m=2000)
    eng = PyramidEngine(chunk=256)
    stats = eng.polish_stats(src, dst)
    # dense uniform scene, capacity 32 per 1 m cell: nothing drops
    assert float(stats.empty_frac) < 0.2
    assert 0.0 <= float(stats.overflow_frac) <= 1.0
    tight = PyramidEngine(chunk=256, max_per_cell=2)
    stats_tight = tight.polish_stats(src, dst)
    assert float(stats_tight.overflow_frac) > float(stats.overflow_frac) - 1e-9
    assert float(stats_tight.dropped_frac) > 0.0
