"""Rolling submap: fuse/refine, distance eviction, origin re-anchoring,
storage modes (fp32 seed layout vs memory-lean fp16), and saturation
accounting."""
import jax.numpy as jnp
import numpy as np

from repro.core.nn_search_grid import neighborhood_stats, nn_search_grid
from repro.data.collate import PAD_SENTINEL
from repro.data.submap import (Submap, SubmapParams, empty_state,
                               fuse_state, state_bytes, state_views)

PARAMS = SubmapParams(voxel_size=0.5, capacity=4096, dims=(64, 64, 40),
                      evict_radius=14.0)


def _cloud(seed=0, n=2000, half=5.0):
    rng = np.random.default_rng(seed)
    return rng.uniform(-half, half, (n, 3)).astype(np.float32)


def test_insert_populates_and_pads_with_sentinel():
    sm = Submap(PARAMS)
    assert sm.size == 0 and sm.occupancy() == 0.0
    sm.insert(_cloud(), np.zeros(3))
    assert 0 < sm.size <= PARAMS.capacity
    pts, valid = sm.target()
    dead = np.asarray(pts)[~np.asarray(valid)]
    assert np.all(dead == PAD_SENTINEL)          # collate convention
    live = np.asarray(pts)[np.asarray(valid)]
    assert np.all(np.abs(live) < 6.0)


def test_refusing_same_scan_does_not_grow():
    """Revisited cells refine (centroid average), they don't duplicate."""
    sm = Submap(PARAMS)
    c = _cloud(1)
    sm.insert(c, np.zeros(3))
    s0 = sm.size
    sm.insert(c, np.zeros(3))
    assert sm.size == s0
    assert sm.frames_inserted == 2


def test_eviction_by_distance_from_ego():
    sm = Submap(PARAMS)
    sm.insert(_cloud(2), np.zeros(3))
    # Ego jumps 30 m: the old neighbourhood is > evict_radius away.
    far = _cloud(3) + np.asarray([30.0, 0.0, 0.0], np.float32)
    sm.insert(far, np.asarray([30.0, 0.0, 0.0], np.float32))
    live = np.asarray(sm.points)[np.asarray(sm.valid)]
    assert live.shape[0] > 0
    assert live[:, 0].min() > 20.0               # old cells are gone
    d = np.linalg.norm(live - np.asarray([30.0, 0.0, 0.0]), axis=1)
    assert d.max() <= PARAMS.evict_radius + 1e-4


def test_reanchoring_keeps_moving_ego_queries_in_lattice():
    """The system-scale point of the out-of-lattice fix: after re-anchoring,
    queries at the current ego position always resolve in-lattice."""
    sm = Submap(PARAMS)
    for step in range(4):
        center = np.asarray([10.0 * step, 0.0, 0.0], np.float32)
        sm.insert(_cloud(step, half=4.0) + center, center)
        q = jnp.asarray(_cloud(step + 50, n=200, half=4.0) + center)
        stats = neighborhood_stats(q, sm.grid(), max_per_cell=32)
        assert float(stats.out_of_lattice) == 0.0
        d2, _ = nn_search_grid(q, sm.grid(), max_per_cell=32)
        assert float(jnp.mean(jnp.isfinite(d2))) > 0.95
    # and the origin actually moved with the ego
    assert float(sm.origin[0]) > 0.0


def test_capacity_saturation_is_graceful():
    tiny = PARAMS._replace(capacity=256)
    sm = Submap(tiny)
    sm.insert(_cloud(4, n=4000), np.zeros(3))
    assert sm.size <= 256
    assert sm.occupancy() <= 1.0
    pts, valid = sm.target()
    assert pts.shape == (256, 3) and valid.shape == (256,)


# -- saturation accounting -------------------------------------------------

def test_dropped_cells_counter_is_sticky():
    """A capacity-starved fuse reports HOW MANY occupied voxels it could
    not keep, and the counter accumulates across inserts (a saturated map
    must not hide behind a clean-looking occupancy() == 1.0)."""
    tiny = PARAMS._replace(capacity=128)
    sm = Submap(tiny)
    sm.insert(_cloud(5, n=4000), np.zeros(3))
    assert sm.size == 128 and sm.occupancy() == 1.0
    first = sm.dropped_cells
    assert first > 0
    sm.insert(_cloud(6, n=4000), np.zeros(3))
    assert sm.dropped_cells > first              # sticky: running total
    # a map with headroom never reports drops
    roomy = Submap(PARAMS)
    roomy.insert(_cloud(5, n=1000), np.zeros(3))
    assert roomy.dropped_cells == 0


# -- storage modes ---------------------------------------------------------

def test_fp32_storage_is_the_seed_layout_bitwise():
    """fp32 state views ARE the state leaves (no decode, no copy), and the
    functional fuse is the class fuse: the state API added for fleet
    sharding costs the single-stream path nothing."""
    state = empty_state(PARAMS)
    pts, valid, origin = state_views(state, PARAMS)
    assert pts is state[0] and valid is state[1] and origin is state[2]
    c = jnp.asarray(_cloud(7))
    ones = jnp.ones((c.shape[0],), bool)
    st2, occ, dropped = fuse_state(state, c, ones,
                                   jnp.zeros(3, jnp.float32), PARAMS)
    sm = Submap(PARAMS)
    sm.insert(_cloud(7), np.zeros(3))
    for leaf, ref in zip(st2, sm.state):
        np.testing.assert_array_equal(np.asarray(leaf), np.asarray(ref))
    assert int(occ) == sm.size and int(dropped) == sm.dropped_cells == 0


def test_fp16_state_is_memory_lean():
    """The headline of the fp16 mode: >= 1.9x more resident submaps per
    device byte (13 B/cell -> 6 B/cell; the sharded service's capacity
    reporting builds on state_bytes)."""
    ratio = state_bytes(PARAMS) / state_bytes(PARAMS._replace(storage="fp16"))
    assert ratio >= 1.9


def test_fp16_decode_error_is_quantization_scale():
    """One fused scan, both layouts: identical cell membership, and the
    decoded fp16 points sit within half-ulp-at-lattice-edge of the fp32
    ones (offsets are lattice-relative, never world-magnitude)."""
    sm32 = Submap(PARAMS)
    sm16 = Submap(PARAMS._replace(storage="fp16"))
    c = _cloud(8)
    sm32.insert(c, np.zeros(3))
    sm16.insert(c, np.zeros(3))
    v32, v16 = np.asarray(sm32.valid), np.asarray(sm16.valid)
    np.testing.assert_array_equal(v32, v16)
    np.testing.assert_array_equal(np.asarray(sm32.origin),
                                  np.asarray(sm16.origin))
    err = np.abs(np.asarray(sm16.points)[v16] - np.asarray(sm32.points)[v32])
    assert err.max() <= 0.02                     # 32 m lattice: ulp/2 ~ 1.6 cm


def test_fp16_reanchoring_far_from_world_origin():
    """fp16 offsets are origin-relative, so precision does NOT degrade
    with world position: 500 m from the world origin (where raw fp16
    would quantize at 0.25 m) the decode still tracks fp32 at the
    centimetre scale, origins stay bitwise equal, and eviction geometry
    holds."""
    sm32 = Submap(PARAMS)
    sm16 = Submap(PARAMS._replace(storage="fp16"))
    center = None
    for step in range(3):
        center = np.asarray([500.0 + 10.0 * step, -300.0, 0.0], np.float32)
        c = _cloud(step, half=4.0) + center
        sm32.insert(c, center)
        sm16.insert(c, center)
        np.testing.assert_array_equal(np.asarray(sm32.origin),
                                      np.asarray(sm16.origin))
    live16 = np.asarray(sm16.points)[np.asarray(sm16.valid)]
    live32 = np.asarray(sm32.points)[np.asarray(sm32.valid)]
    d = np.linalg.norm(live16 - center, axis=1)
    assert d.max() <= PARAMS.evict_radius + 0.1
    # decoded fp16 cells match fp32 counterparts at quantization scale
    # (nearest-neighbour match: membership flips at voxel boundaries move
    # a few points between cells, so the tail — not the bulk — reflects
    # re-binned centroids rather than precision; assert the bulk)
    nn = np.min(np.linalg.norm(live16[:, None] - live32[None], axis=-1),
                axis=1)
    assert np.percentile(nn, 99) <= 0.02
    assert abs(live16.shape[0] - live32.shape[0]) <= 0.01 * live32.shape[0]


def test_fp16_odometry_tracks_fp32():
    """End-to-end guard for the memory-lean mode: a real scan-to-map
    stream on fp16 submaps stays within centimetres of the fp32 run —
    far inside the 0.5 m drift guard band the benchmark enforces."""
    from repro.core.odometry import OdometryConfig, OdometryPipeline
    from repro.data.pointcloud import SceneConfig, sequence_scans

    scene = SceneConfig(n_ground=800, n_walls=600, n_poles=150,
                        n_clutter=150, extent=15.0, sensor_range=20.0)
    sub = SubmapParams(voxel_size=0.75, capacity=4096, dims=(64, 64, 24),
                       evict_radius=20.0)
    scans = sequence_scans(2, 8, scene)
    finals = {}
    for storage in ("fp32", "fp16"):
        pipe = OdometryPipeline(OdometryConfig(
            engine="xla", submap=sub._replace(storage=storage),
            scan_budget=2048))
        poses, diags = pipe.run(scans)
        assert all(d.accepted for d in diags)
        finals[storage] = poses[-1][:3, 3]
    gap = float(np.linalg.norm(finals["fp16"] - finals["fp32"]))
    assert gap <= 0.1
