"""Rolling submap: fuse/refine, distance eviction, origin re-anchoring."""
import jax.numpy as jnp
import numpy as np

from repro.core.nn_search_grid import neighborhood_stats, nn_search_grid
from repro.data.collate import PAD_SENTINEL
from repro.data.submap import Submap, SubmapParams

PARAMS = SubmapParams(voxel_size=0.5, capacity=4096, dims=(64, 64, 40),
                      evict_radius=14.0)


def _cloud(seed=0, n=2000, half=5.0):
    rng = np.random.default_rng(seed)
    return rng.uniform(-half, half, (n, 3)).astype(np.float32)


def test_insert_populates_and_pads_with_sentinel():
    sm = Submap(PARAMS)
    assert sm.size == 0 and sm.occupancy() == 0.0
    sm.insert(_cloud(), np.zeros(3))
    assert 0 < sm.size <= PARAMS.capacity
    pts, valid = sm.target()
    dead = np.asarray(pts)[~np.asarray(valid)]
    assert np.all(dead == PAD_SENTINEL)          # collate convention
    live = np.asarray(pts)[np.asarray(valid)]
    assert np.all(np.abs(live) < 6.0)


def test_refusing_same_scan_does_not_grow():
    """Revisited cells refine (centroid average), they don't duplicate."""
    sm = Submap(PARAMS)
    c = _cloud(1)
    sm.insert(c, np.zeros(3))
    s0 = sm.size
    sm.insert(c, np.zeros(3))
    assert sm.size == s0
    assert sm.frames_inserted == 2


def test_eviction_by_distance_from_ego():
    sm = Submap(PARAMS)
    sm.insert(_cloud(2), np.zeros(3))
    # Ego jumps 30 m: the old neighbourhood is > evict_radius away.
    far = _cloud(3) + np.asarray([30.0, 0.0, 0.0], np.float32)
    sm.insert(far, np.asarray([30.0, 0.0, 0.0], np.float32))
    live = np.asarray(sm.points)[np.asarray(sm.valid)]
    assert live.shape[0] > 0
    assert live[:, 0].min() > 20.0               # old cells are gone
    d = np.linalg.norm(live - np.asarray([30.0, 0.0, 0.0]), axis=1)
    assert d.max() <= PARAMS.evict_radius + 1e-4


def test_reanchoring_keeps_moving_ego_queries_in_lattice():
    """The system-scale point of the out-of-lattice fix: after re-anchoring,
    queries at the current ego position always resolve in-lattice."""
    sm = Submap(PARAMS)
    for step in range(4):
        center = np.asarray([10.0 * step, 0.0, 0.0], np.float32)
        sm.insert(_cloud(step, half=4.0) + center, center)
        q = jnp.asarray(_cloud(step + 50, n=200, half=4.0) + center)
        stats = neighborhood_stats(q, sm.grid(), max_per_cell=32)
        assert float(stats.out_of_lattice) == 0.0
        d2, _ = nn_search_grid(q, sm.grid(), max_per_cell=32)
        assert float(jnp.mean(jnp.isfinite(d2))) > 0.95
    # and the origin actually moved with the ego
    assert float(sm.origin[0]) > 0.0


def test_capacity_saturation_is_graceful():
    tiny = PARAMS._replace(capacity=256)
    sm = Submap(tiny)
    sm.insert(_cloud(4, n=4000), np.zeros(3))
    assert sm.size <= 256
    assert sm.occupancy() <= 1.0
    pts, valid = sm.target()
    assert pts.shape == (256, 3) and valid.shape == (256,)
