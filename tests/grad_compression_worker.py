"""Subprocess worker: int8 error-feedback gradient compression on an
8-device data-parallel mesh. Checks (1) a single compressed reduction is
close to the exact mean and unbiased over steps thanks to error feedback,
(2) end-to-end DP training with compression tracks uncompressed training."""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.compat import make_mesh, shard_map  # noqa: E402
from repro.optim.compression import (compressed_grad_reduce,  # noqa: E402
                                     init_error_feedback)


def main():
    mesh = make_mesh((8,), ("data",))

    # --- 1. single reduction approximates the exact mean ------------------
    key = jax.random.PRNGKey(0)
    gs = jax.random.normal(key, (8, 1000))  # per-device gradients

    def reduce_once(g, ef):
        out, new_ef = compressed_grad_reduce({"g": g}, "data", {"g": ef})
        return out["g"], new_ef["g"]

    fn = shard_map(reduce_once, mesh=mesh,
                   in_specs=(P("data"), P("data")), out_specs=(P("data"),
                                                               P("data")),
                   check_vma=False)
    g_in = gs.reshape(8000)
    out, ef = fn(g_in, jnp.zeros(8000))
    exact = jnp.mean(gs, axis=0)
    approx = out.reshape(8, 1000)[0]
    rel = float(jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact))
    print("single-step rel err:", rel)
    assert rel < 0.02, rel  # int8: ~1% quantization noise

    # --- 2. error feedback: accumulated mean over steps is ~unbiased ------
    accum_c = jnp.zeros(1000)
    accum_e = jnp.zeros(1000)
    ef = jnp.zeros(8000)
    for step in range(20):
        gstep = jax.random.normal(jax.random.PRNGKey(step), (8, 1000)) + 0.3
        out, ef = fn(gstep.reshape(8000), ef)
        accum_c = accum_c + out.reshape(8, 1000)[0]
        accum_e = accum_e + jnp.mean(gstep, axis=0)
    rel_acc = float(jnp.linalg.norm(accum_c - accum_e)
                    / jnp.linalg.norm(accum_e))
    print("20-step accumulated rel err:", rel_acc)
    assert rel_acc < 0.02, rel_acc

    # --- 3. end-to-end: compressed DP training tracks fp32 ----------------
    def loss_fn(w, x, y):
        pred = jnp.tanh(x @ w["a"]) @ w["b"]
        return jnp.mean((pred - y) ** 2)

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    w0 = {"a": 0.1 * jax.random.normal(k1, (16, 32)),
          "b": 0.1 * jax.random.normal(k2, (32, 4))}
    X = jax.random.normal(k3, (64, 16))
    Y = jnp.tanh(X[:, :4]) * 0.5

    def dp_step(w, ef, x, y, compress):
        g = jax.grad(loss_fn)(w, x, y)
        if compress:
            g, ef = compressed_grad_reduce(g, "data", ef)
        else:
            g = jax.tree_util.tree_map(
                lambda t: jax.lax.pmean(t, "data"), g)
        w = jax.tree_util.tree_map(lambda p, gg: p - 0.2 * gg, w, g)
        return w, ef

    def run(compress):
        step = shard_map(
            functools.partial(dp_step, compress=compress), mesh=mesh,
            in_specs=(P(), {"a": P(), "b": P()}, P("data"), P("data")),
            out_specs=(P(), {"a": P(), "b": P()}), check_vma=False)
        w = jax.tree_util.tree_map(jnp.array, w0)
        ef = init_error_feedback(w)
        for _ in range(40):
            w, ef = step(w, ef, X, Y)
        return float(loss_fn(w, X, Y))

    l_fp32 = run(False)
    l_int8 = run(True)
    print(f"final loss fp32={l_fp32:.5f} int8={l_int8:.5f}")
    assert l_int8 < 1.5 * l_fp32 + 1e-3, (l_fp32, l_int8)
    print("GRAD-COMPRESSION-OK")


if __name__ == "__main__":
    main()
