"""Pallas NN-search kernel vs pure-jnp oracle (interpret mode on CPU).

Per-kernel requirement: sweep shapes/dtypes and assert_allclose against the
ref.py oracle. idx is checked by *distance equivalence* (fp ties may resolve
to either index legally) plus exact match against the blocked oracle, which
replays the kernel's tie-break semantics bit-for-bit at the index level.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.transform import random_rigid_transform
from repro.kernels.nn_search import vmem_bytes
from repro.kernels.ops import make_frame_engine, nn_search_pallas
from repro.kernels.ref import (augment_source, augment_target, nn_search_ref,
                               nn_search_ref_blocked)

SHAPES = [
    (128, 256, 128, 256),      # single tile
    (256, 1024, 128, 256),     # multi-tile both axes
    (300, 1000, 128, 256),     # ragged -> padding path
    (512, 4096, 512, 1024),    # production tile sizes
    (1, 130_000, 128, 1024),   # paper's per-point candidate count (~130k)
    (1024, 313, 256, 256),     # target smaller than one tile
]


@pytest.mark.parametrize("n,m,bn,bm", SHAPES)
def test_kernel_vs_oracle(n, m, bn, bm):
    key = jax.random.PRNGKey(n * 7 + m)
    k1, k2, k3 = jax.random.split(key, 3)
    src = jax.random.uniform(k1, (n, 3), minval=-60, maxval=60)
    dst = jax.random.uniform(k2, (m, 3), minval=-60, maxval=60)
    T = random_rigid_transform(k3)
    d2_k, idx_k = nn_search_pallas(src, dst, T, bn=bn, bm=bm, interpret=True)
    d2_ref, idx_ref = nn_search_ref(src, dst, T)
    np.testing.assert_allclose(np.asarray(d2_k), np.asarray(d2_ref),
                               rtol=1e-5, atol=1e-2)
    # Blocked oracle replays tiling/tie-break exactly -> idx must be equal.
    d2_b, idx_b = nn_search_ref_blocked(src, dst, T, bn, bm)
    np.testing.assert_array_equal(np.asarray(idx_k), np.asarray(idx_b))
    assert idx_k.dtype == jnp.int32
    assert bool(jnp.all((idx_k >= 0) & (idx_k < m)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_kernel_dtype_sweep(dtype):
    """Points arrive in various dtypes; augmentation is fp32 — results must
    match the oracle fed the same (cast) points."""
    key = jax.random.PRNGKey(11)
    k1, k2 = jax.random.split(key)
    src = jax.random.uniform(k1, (256, 3), minval=-20, maxval=20).astype(dtype)
    dst = jax.random.uniform(k2, (512, 3), minval=-20, maxval=20).astype(dtype)
    d2_k, idx_k = nn_search_pallas(src, dst, None, bn=128, bm=256,
                                   interpret=True)
    d2_ref, idx_ref = nn_search_ref(src, dst, None)
    np.testing.assert_allclose(np.asarray(d2_k), np.asarray(d2_ref),
                               rtol=1e-4, atol=1e-2)
    np.testing.assert_array_equal(np.asarray(idx_k), np.asarray(idx_ref))


def test_no_transform_equals_identity_transform():
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    src = jax.random.normal(k1, (128, 3))
    dst = jax.random.normal(k2, (256, 3))
    a = nn_search_pallas(src, dst, None, bn=128, bm=256, interpret=True)
    b = nn_search_pallas(src, dst, jnp.eye(4), bn=128, bm=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_frame_engine_matches_one_shot():
    """The once-per-frame pre-augmented engine must agree with the one-shot
    wrapper (production ICP uses the engine)."""
    key = jax.random.PRNGKey(9)
    k1, k2, k3 = jax.random.split(key, 3)
    src = jax.random.uniform(k1, (200, 3), minval=-10, maxval=10)
    dst = jax.random.uniform(k2, (700, 3), minval=-10, maxval=10)
    T = random_rigid_transform(k3)
    engine = make_frame_engine(dst, bn=128, bm=256, interpret=True)
    d2_e, idx_e = engine(src, T)
    d2_o, idx_o = nn_search_pallas(src, dst, T, bn=128, bm=256, interpret=True)
    # Engine jits the target augmentation separately -> different XLA fusion
    # -> last-ulp differences are legitimate; require distance equivalence.
    np.testing.assert_allclose(np.asarray(d2_e), np.asarray(d2_o),
                               rtol=1e-4, atol=1e-4)
    same = np.asarray(idx_e) == np.asarray(idx_o)
    if not same.all():
        # Any index disagreement must be a floating-point tie.
        np.testing.assert_allclose(np.asarray(d2_e)[~same],
                                   np.asarray(d2_o)[~same],
                                   rtol=1e-4, atol=1e-4)


def test_padded_targets_never_win():
    """All real targets far away + padding nearby-in-index: argmin must still
    land on a real point."""
    src = jnp.zeros((128, 3))
    dst = jnp.full((100, 3), 50.0)  # pads to 256 with +1e30 bias
    d2, idx = nn_search_pallas(src, dst, None, bn=128, bm=256, interpret=True)
    assert bool(jnp.all(idx < 100))
    np.testing.assert_allclose(np.asarray(d2), 7500.0, rtol=1e-5)


def test_augmentation_identities():
    key = jax.random.PRNGKey(21)
    k1, k2 = jax.random.split(key)
    src = jax.random.normal(k1, (64, 3))
    dst = jax.random.normal(k2, (64, 3))
    sa = augment_source(src)
    da = augment_target(dst)
    scores = jax.lax.dot_general(sa, da, (((0,), (0,)), ((), ())))
    ref = jnp.sum((src[:, None] - dst[None]) ** 2, -1)
    np.testing.assert_allclose(np.asarray(scores), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_vmem_budget_fits():
    """Default tiles must fit VMEM with double buffering (v5e ~128 MiB)."""
    b = vmem_bytes(512, 1024)
    assert b["total_double_buffered"] < 16 * 2 ** 20  # << 128 MiB: headroom for
    # the compiler's own buffers and future fusion.
