"""Roofline HLO-analyzer edge cases beyond test_optim.py's basics."""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.roofline.hlo_analysis import _shape_bytes_elems, analyze_hlo
from repro.roofline.report import model_flops, roofline_terms


def test_shape_parsing():
    b, e = _shape_bytes_elems("f32[256,12]{1,0}")
    assert b == 256 * 12 * 4 and e == 256 * 12
    b, e = _shape_bytes_elems("(s32[], bf16[4,4]{1,0})")
    assert b == 4 + 32
    b, _ = _shape_bytes_elems("pred[8]")
    assert b == 8
    b, _ = _shape_bytes_elems("f32[]")
    assert b == 4


def test_dus_counted_at_slice_size():
    """Scan-state saving (dynamic-update-slice into a large buffer) must be
    charged slice bytes, not buffer bytes."""
    def f(xs):
        def step(c, x):
            return c + 1.0, (c * x)
        _, ys = jax.lax.scan(step, jnp.zeros((256, 256)), xs)
        return ys

    s = jax.ShapeDtypeStruct((64, 256, 256), jnp.float32)
    compiled = jax.jit(f).lower(s).compile()
    m = analyze_hlo(compiled.as_text())
    # total traffic must be O(64 * slice), far below O(64 * full buffer)
    full_buffer = 64 * 256 * 256 * 4
    assert m.hbm_bytes < 12 * full_buffer


def test_reduce_scatter_and_permute_counted():
    # covered indirectly by dry-run artifacts; here check the regexes accept
    # async start forms
    hlo = """
ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %ag = f32[128]{0} all-gather-start(%p0), dimensions={0}
  ROOT %r = f32[64]{0} reduce-scatter(%p0), dimensions={0}
}
"""
    m = analyze_hlo(hlo)
    assert m.collective_detail["all-gather"]["count"] == 1
    assert m.collective_detail["reduce-scatter"]["count"] == 1
    assert m.collective_bytes == 2 * 64 * 4


def test_model_flops_definitions():
    cfg = get_config("deepseek-moe-16b")
    train = model_flops(cfg, SHAPES["train_4k"], 256)
    decode = model_flops(cfg, SHAPES["decode_32k"], 256)
    # train: 6*N_active*tokens; decode: 2*N_active per generated token
    assert train / decode == (6 * 256 * 4096) / (2 * 128)


def test_roofline_terms_dominance():
    from repro.roofline.hlo_analysis import HLOCostModel
    cost = HLOCostModel(flops=1e15, hbm_bytes=1e9, collective_bytes=1e9)
    t = roofline_terms(cost, None, None, 1, model_flops_override=5e14)
    assert t.dominant == "compute"
    assert abs(t.useful_fraction - 0.5) < 1e-9
    cost = HLOCostModel(flops=1e12, hbm_bytes=1e13, collective_bytes=1e9)
    t = roofline_terms(cost, None, None, 1, model_flops_override=1e12)
    assert t.dominant == "memory"


def test_loop_artifact_flagging():
    """A >10GB-per-iteration op inside a while body is flagged and excluded
    from the corrected bytes."""
    from repro.roofline.hlo_analysis import HLOCostModel
    hlo = """
%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
%body (arg: (s32[], f32[128,131072,1024], f32[])) -> (s32[], f32[128,131072,1024], f32[]) {
  %arg = (s32[], f32[128,131072,1024]{2,1,0}, f32[]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %big = f32[128,131072,1024]{2,1,0} get-tuple-element(%arg), index=1
  %acc = f32[] get-tuple-element(%arg), index=2
  %c1 = s32[] constant(1)
  %c0 = f32[] constant(0)
  %i2 = s32[] add(%i, %c1)
  %r = f32[] reduce(%big, %c0), dimensions={0,1,2}, to_apply=%sum
  %acc2 = f32[] add(%acc, %r)
  ROOT %t = (s32[], f32[128,131072,1024]{2,1,0}, f32[]) tuple(%i2, %big, %acc2)
}
%cond (arg2: (s32[], f32[128,131072,1024], f32[])) -> pred[] {
  %arg2 = (s32[], f32[128,131072,1024]{2,1,0}, f32[]) parameter(0)
  %j = s32[] get-tuple-element(%arg2), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(%j, %n), direction=LT
}
ENTRY %main (p: f32[128,131072,1024]) -> f32[] {
  %p = f32[128,131072,1024]{2,1,0} parameter(0)
  %z = s32[] constant(0)
  %zf = f32[] constant(0)
  %tup = (s32[], f32[128,131072,1024]{2,1,0}, f32[]) tuple(%z, %p, %zf)
  %w = (s32[], f32[128,131072,1024]{2,1,0}, f32[]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
  ROOT %out = f32[] get-tuple-element(%w), index=2
}
"""
    m = analyze_hlo(hlo)
    assert m.loop_artifact_bytes > 0
    assert m.hbm_bytes_corrected < m.hbm_bytes
