"""ICP behaviour: convergence, parity with the k-d tree CPU baseline, API."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FppsICP, ICPParams, icp, icp_fixed_iterations,
                        random_rigid_transform, transform_points)
from repro.core.baseline import kdtree_icp


def _perturbed_cloud(key, n=800, scale=10.0, max_angle=0.15, max_t=0.5):
    k1, k2, k3 = jax.random.split(key, 3)
    target = jax.random.uniform(k1, (n, 3), minval=-scale, maxval=scale)
    T_gt = random_rigid_transform(k2, max_angle=max_angle, max_translation=max_t)
    # source = inverse-transformed target (+ tiny noise): aligning source onto
    # target should recover T_gt.
    src = transform_points(jnp.linalg.inv(T_gt), target)
    src = src + 0.005 * jax.random.normal(k3, src.shape)
    return src, target, T_gt


def test_identity_on_identical_clouds():
    key = jax.random.PRNGKey(0)
    pts = jax.random.normal(key, (500, 3)) * 5.0
    res = icp(pts, pts, ICPParams(max_iterations=10, chunk=128))
    np.testing.assert_allclose(np.asarray(res.T), np.eye(4), atol=1e-5)
    assert bool(res.converged)
    assert float(res.rmse) < 1e-3


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_recovers_known_transform(seed):
    src, target, T_gt = _perturbed_cloud(jax.random.PRNGKey(seed))
    res = icp(src, target, ICPParams(max_iterations=50, chunk=256))
    np.testing.assert_allclose(np.asarray(res.T), np.asarray(T_gt), atol=0.03)
    assert float(res.rmse) < 0.05


def test_fixed_iterations_matches_while_loop():
    src, target, _ = _perturbed_cloud(jax.random.PRNGKey(3))
    params = ICPParams(max_iterations=30, chunk=256)
    a = icp(src, target, params)
    b = icp_fixed_iterations(src, target, params)
    np.testing.assert_allclose(np.asarray(a.T), np.asarray(b.T), atol=1e-5)
    assert int(a.iterations) <= 30


def test_parity_with_kdtree_baseline(small_scene):
    """Paper Table III claim: accelerator accuracy == software baseline."""
    src, dst, T_gt = small_scene
    params = ICPParams(max_iterations=50, max_correspondence_distance=1.0,
                       transformation_epsilon=1e-5)
    ours = icp(jnp.asarray(src), jnp.asarray(dst), params)
    base = kdtree_icp(src, dst, 50, 1.0, 1e-5)
    # Same correspondences (exact NN both sides) -> near-identical results.
    assert abs(float(ours.rmse) - base.rmse) < 0.01  # paper: within 0.01 m
    np.testing.assert_allclose(np.asarray(ours.T), base.T, atol=5e-3)
    # And both should be near the ground truth.
    np.testing.assert_allclose(np.asarray(ours.T), T_gt, atol=0.05)


def test_max_correspondence_distance_rejects_outliers():
    key = jax.random.PRNGKey(5)
    src, target, T_gt = _perturbed_cloud(key)
    # Add far-away junk to the source cloud.
    junk = jnp.full((100, 3), 500.0)
    src_with_junk = jnp.concatenate([src, junk], axis=0)
    res = icp(src_with_junk, target,
              ICPParams(max_iterations=50, max_correspondence_distance=1.0,
                        chunk=256))
    np.testing.assert_allclose(np.asarray(res.T), np.asarray(T_gt), atol=0.05)
    assert float(res.inlier_frac) < 1.0


@pytest.mark.parametrize("minimizer", ["point_to_point", "point_to_plane"])
def test_zero_inlier_disjoint_clouds_freezes(minimizer):
    """ISSUE 5 regression: when the gate rejects every correspondence the
    iteration must freeze (no singular Kabsch/Gauss-Newton step) and flag
    the result degenerate instead of reporting a perfect rmse=0 lock."""
    src = jax.random.uniform(jax.random.PRNGKey(0), (64, 3),
                             minval=-1.0, maxval=1.0)
    dst = src + jnp.asarray([100.0, 0.0, 0.0])  # disjoint: nothing gates in
    params = ICPParams(max_iterations=10, max_correspondence_distance=1.0,
                       chunk=32, minimizer=minimizer)
    res = icp(src, dst, params)
    assert bool(res.degenerate)
    assert not bool(res.converged)
    assert float(res.inlier_frac) == 0.0
    assert np.isinf(float(res.rmse))          # not a fake-perfect 0.0
    assert np.all(np.isfinite(np.asarray(res.T)))
    np.testing.assert_allclose(np.asarray(res.T), np.eye(4), atol=1e-6)


def test_zero_inlier_gate_below_spacing_keeps_warm_start():
    """Gate smaller than the point spacing: zero inliers even on overlapping
    clouds. The cumulative transform must stay at the initial transform
    (frozen), not step to garbage, and the scan/batch variants must agree."""
    g = jnp.arange(5.0)
    lattice = jnp.stack(jnp.meshgrid(g, g, g), axis=-1).reshape(-1, 3)
    src = lattice + jnp.asarray([0.4, 0.3, 0.2])  # >= 0.29 from any node
    T0 = random_rigid_transform(jax.random.PRNGKey(1), max_angle=0.2,
                                max_translation=0.5)
    params = ICPParams(max_iterations=8, max_correspondence_distance=0.05,
                       chunk=64)
    res = icp(src, lattice, params, initial_transform=T0)
    assert bool(res.degenerate) and not bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.T), np.asarray(T0), atol=1e-6)
    fixed = icp_fixed_iterations(src, lattice, params, initial_transform=T0)
    assert bool(fixed.degenerate)
    np.testing.assert_allclose(np.asarray(fixed.T), np.asarray(T0), atol=1e-6)
    from repro.core import icp_batch
    batch = icp_batch(src[None], lattice[None], params,
                      initial_transforms=np.asarray(T0)[None])
    assert bool(batch.degenerate[0])
    assert batch.degenerate.shape == (1,)


def test_degenerate_flag_false_on_healthy_registration():
    src, target, _ = _perturbed_cloud(jax.random.PRNGKey(4))
    res = icp(src, target, ICPParams(max_iterations=30, chunk=256))
    assert not bool(res.degenerate)
    assert bool(res.converged)


def test_pcl_api_surface():
    key = jax.random.PRNGKey(8)
    src, target, T_gt = _perturbed_cloud(key)
    reg = FppsICP(chunk=256)
    reg.hardwareInitialize()
    reg.setInputSource(np.asarray(src))
    reg.setInputTarget(np.asarray(target))
    reg.setMaxCorrespondenceDistance(1.0)
    reg.setMaxIterationCount(50)
    reg.setTransformationEpsilon(1e-5)
    T = reg.align()
    assert T.shape == (4, 4)
    np.testing.assert_allclose(T, np.asarray(T_gt), atol=0.05)
    assert reg.hasConverged()
    assert reg.getFitnessScore() < 0.05


def test_api_initial_transform_warm_start():
    key = jax.random.PRNGKey(9)
    src, target, T_gt = _perturbed_cloud(key, max_angle=0.4, max_t=2.0)
    reg = FppsICP(chunk=256)
    reg.setInputSource(np.asarray(src))
    reg.setInputTarget(np.asarray(target))
    reg.setTransformationMatrix(np.asarray(T_gt))  # perfect warm start
    reg.setMaxIterationCount(5)
    T = reg.align()
    np.testing.assert_allclose(T, np.asarray(T_gt), atol=0.02)
    assert reg.last_result.iterations <= 5


def test_api_requires_inputs():
    reg = FppsICP()
    with pytest.raises(ValueError):
        reg.align()


def test_icp_with_pallas_engine():
    """Full ICP driven by the Pallas kernel (interpret mode) must agree with
    the XLA engine."""
    key = jax.random.PRNGKey(12)
    src, target, T_gt = _perturbed_cloud(key, n=256)
    xla = FppsICP(engine="xla", chunk=128)
    pal = FppsICP(engine="pallas")
    for reg in (xla, pal):
        reg.setInputSource(np.asarray(src))
        reg.setInputTarget(np.asarray(target))
        reg.setMaxIterationCount(25)
    T_x = xla.align()
    T_p = pal.align()
    np.testing.assert_allclose(T_p, T_x, atol=1e-3)


# -- warm starts (ISSUE 5) --------------------------------------------------

def test_warm_start_cuts_iterations_to_same_fixed_point():
    """A good ``initial_transform`` must reduce the iteration count AND
    land on the same fixed point as the cold solve — a warm start changes
    where the descent begins, never where it ends."""
    src, target, T_gt = _perturbed_cloud(jax.random.PRNGKey(9))
    params = ICPParams(max_iterations=30, chunk=256)
    cold = icp(src, target, params)
    warm = icp(src, target, params, initial_transform=T_gt)
    assert bool(warm.converged)
    assert int(warm.iterations) < int(cold.iterations)
    np.testing.assert_allclose(np.asarray(warm.T), np.asarray(cold.T),
                               atol=5e-3)


def test_icp_batch_warm_start_cuts_iterations():
    """Per-lane ``initial_transforms`` through the batched (scan/freeze)
    path: fewer iterations, same fixed points as the cold batch."""
    from repro.core import icp_batch
    trios = [_perturbed_cloud(k)
             for k in jax.random.split(jax.random.PRNGKey(10), 3)]
    src_b = jnp.stack([s for s, _, _ in trios])
    dst_b = jnp.stack([t for _, t, _ in trios])
    T0 = jnp.stack([T for _, _, T in trios])
    params = ICPParams(max_iterations=30, chunk=256)
    cold = icp_batch(src_b, dst_b, params)
    warm = icp_batch(src_b, dst_b, params, initial_transforms=T0)
    assert int(jnp.sum(warm.iterations)) < int(jnp.sum(cold.iterations))
    np.testing.assert_allclose(np.asarray(warm.T), np.asarray(cold.T),
                               atol=5e-3)
