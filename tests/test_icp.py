"""ICP behaviour: convergence, parity with the k-d tree CPU baseline, API."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FppsICP, ICPParams, icp, icp_fixed_iterations,
                        random_rigid_transform, transform_points)
from repro.core.baseline import kdtree_icp


def _perturbed_cloud(key, n=800, scale=10.0, max_angle=0.15, max_t=0.5):
    k1, k2, k3 = jax.random.split(key, 3)
    target = jax.random.uniform(k1, (n, 3), minval=-scale, maxval=scale)
    T_gt = random_rigid_transform(k2, max_angle=max_angle, max_translation=max_t)
    # source = inverse-transformed target (+ tiny noise): aligning source onto
    # target should recover T_gt.
    src = transform_points(jnp.linalg.inv(T_gt), target)
    src = src + 0.005 * jax.random.normal(k3, src.shape)
    return src, target, T_gt


def test_identity_on_identical_clouds():
    key = jax.random.PRNGKey(0)
    pts = jax.random.normal(key, (500, 3)) * 5.0
    res = icp(pts, pts, ICPParams(max_iterations=10, chunk=128))
    np.testing.assert_allclose(np.asarray(res.T), np.eye(4), atol=1e-5)
    assert bool(res.converged)
    assert float(res.rmse) < 1e-3


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_recovers_known_transform(seed):
    src, target, T_gt = _perturbed_cloud(jax.random.PRNGKey(seed))
    res = icp(src, target, ICPParams(max_iterations=50, chunk=256))
    np.testing.assert_allclose(np.asarray(res.T), np.asarray(T_gt), atol=0.03)
    assert float(res.rmse) < 0.05


def test_fixed_iterations_matches_while_loop():
    src, target, _ = _perturbed_cloud(jax.random.PRNGKey(3))
    params = ICPParams(max_iterations=30, chunk=256)
    a = icp(src, target, params)
    b = icp_fixed_iterations(src, target, params)
    np.testing.assert_allclose(np.asarray(a.T), np.asarray(b.T), atol=1e-5)
    assert int(a.iterations) <= 30


def test_parity_with_kdtree_baseline(small_scene):
    """Paper Table III claim: accelerator accuracy == software baseline."""
    src, dst, T_gt = small_scene
    params = ICPParams(max_iterations=50, max_correspondence_distance=1.0,
                       transformation_epsilon=1e-5)
    ours = icp(jnp.asarray(src), jnp.asarray(dst), params)
    base = kdtree_icp(src, dst, 50, 1.0, 1e-5)
    # Same correspondences (exact NN both sides) -> near-identical results.
    assert abs(float(ours.rmse) - base.rmse) < 0.01  # paper: within 0.01 m
    np.testing.assert_allclose(np.asarray(ours.T), base.T, atol=5e-3)
    # And both should be near the ground truth.
    np.testing.assert_allclose(np.asarray(ours.T), T_gt, atol=0.05)


def test_max_correspondence_distance_rejects_outliers():
    key = jax.random.PRNGKey(5)
    src, target, T_gt = _perturbed_cloud(key)
    # Add far-away junk to the source cloud.
    junk = jnp.full((100, 3), 500.0)
    src_with_junk = jnp.concatenate([src, junk], axis=0)
    res = icp(src_with_junk, target,
              ICPParams(max_iterations=50, max_correspondence_distance=1.0,
                        chunk=256))
    np.testing.assert_allclose(np.asarray(res.T), np.asarray(T_gt), atol=0.05)
    assert float(res.inlier_frac) < 1.0


def test_pcl_api_surface():
    key = jax.random.PRNGKey(8)
    src, target, T_gt = _perturbed_cloud(key)
    reg = FppsICP(chunk=256)
    reg.hardwareInitialize()
    reg.setInputSource(np.asarray(src))
    reg.setInputTarget(np.asarray(target))
    reg.setMaxCorrespondenceDistance(1.0)
    reg.setMaxIterationCount(50)
    reg.setTransformationEpsilon(1e-5)
    T = reg.align()
    assert T.shape == (4, 4)
    np.testing.assert_allclose(T, np.asarray(T_gt), atol=0.05)
    assert reg.hasConverged()
    assert reg.getFitnessScore() < 0.05


def test_api_initial_transform_warm_start():
    key = jax.random.PRNGKey(9)
    src, target, T_gt = _perturbed_cloud(key, max_angle=0.4, max_t=2.0)
    reg = FppsICP(chunk=256)
    reg.setInputSource(np.asarray(src))
    reg.setInputTarget(np.asarray(target))
    reg.setTransformationMatrix(np.asarray(T_gt))  # perfect warm start
    reg.setMaxIterationCount(5)
    T = reg.align()
    np.testing.assert_allclose(T, np.asarray(T_gt), atol=0.02)
    assert reg.last_result.iterations <= 5


def test_api_requires_inputs():
    reg = FppsICP()
    with pytest.raises(ValueError):
        reg.align()


def test_icp_with_pallas_engine():
    """Full ICP driven by the Pallas kernel (interpret mode) must agree with
    the XLA engine."""
    key = jax.random.PRNGKey(12)
    src, target, T_gt = _perturbed_cloud(key, n=256)
    xla = FppsICP(engine="xla", chunk=128)
    pal = FppsICP(engine="pallas")
    for reg in (xla, pal):
        reg.setInputSource(np.asarray(src))
        reg.setInputTarget(np.asarray(target))
        reg.setMaxIterationCount(25)
    T_x = xla.align()
    T_p = pal.align()
    np.testing.assert_allclose(T_p, T_x, atol=1e-3)
