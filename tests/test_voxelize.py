"""Voxel hashing: downsample centroids, counting-sort grid tables."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.collate import PAD_SENTINEL, pad_cloud
from repro.data.voxelize import (build_voxel_grid, cell_coords,
                                 linear_cell_ids, voxel_downsample)


def _cloud(key, n=500, scale=8.0):
    return jax.random.uniform(key, (n, 3), minval=-scale, maxval=scale)


def _np_cells(pts, origin, voxel):
    return np.floor((np.asarray(pts) - np.asarray(origin)) / voxel).astype(
        np.int64)


# -- voxel_downsample --------------------------------------------------------

def test_downsample_centroids_match_numpy():
    pts = _cloud(jax.random.PRNGKey(0), n=400)
    cent, valid = voxel_downsample(pts, 2.0, max_points=400)
    cent, valid = np.asarray(cent), np.asarray(valid)
    occupied = int(valid.sum())
    assert 0 < occupied < 400  # it actually merged something

    # reference: group by integer cell, average
    p = np.asarray(pts)
    origin = np.floor((p.min(axis=0) - 1.0) / 2.0) * 2.0
    cells = _np_cells(p, origin, 2.0)
    ref = {}
    for c, pt in zip(map(tuple, cells), p):
        ref.setdefault(c, []).append(pt)
    ref_centroids = sorted(np.mean(v, axis=0).round(4).tolist()
                           for v in ref.values())
    got = sorted(cent[valid].round(4).tolist())
    assert len(got) == len(ref_centroids)
    np.testing.assert_allclose(got, ref_centroids, atol=1e-3)


def test_downsample_invalid_rows_excluded():
    pts = _cloud(jax.random.PRNGKey(1), n=256)
    padded, valid = pad_cloud(np.asarray(pts), 384)
    cent_p, v_p = voxel_downsample(jnp.asarray(padded), 2.0, max_points=384,
                                   valid=jnp.asarray(valid))
    cent_u, v_u = voxel_downsample(pts, 2.0, max_points=384)
    # padded and unpadded agree on the occupied set
    assert int(v_p.sum()) == int(v_u.sum())
    got_p = sorted(np.asarray(cent_p)[np.asarray(v_p)].round(4).tolist())
    got_u = sorted(np.asarray(cent_u)[np.asarray(v_u)].round(4).tolist())
    np.testing.assert_allclose(got_p, got_u, atol=1e-4)
    # invalid output rows carry the collate sentinel
    assert np.all(np.asarray(cent_p)[~np.asarray(v_p)] == PAD_SENTINEL)


def test_downsample_capacity_truncation_is_graceful():
    pts = _cloud(jax.random.PRNGKey(2), n=512, scale=20.0)
    cent, valid = voxel_downsample(pts, 0.5, max_points=64)  # undersized
    assert cent.shape == (64, 3)
    assert int(valid.sum()) == 64  # full: more occupied cells than capacity
    # surviving rows are real centroids (within the cloud's bounding box)
    kept = np.asarray(cent)[np.asarray(valid)]
    assert np.all(np.abs(kept) <= 20.5)


def test_downsample_jit_and_vmap():
    pts = jnp.stack([_cloud(k, n=128) for k in
                     jax.random.split(jax.random.PRNGKey(3), 4)])
    fn = jax.jit(jax.vmap(lambda p: voxel_downsample(p, 2.0, max_points=128)))
    cent, valid = fn(pts)
    assert cent.shape == (4, 128, 3)
    assert bool(jnp.all(valid.sum(axis=1) > 0))


# -- build_voxel_grid --------------------------------------------------------

def test_grid_tables_consistent():
    pts = _cloud(jax.random.PRNGKey(4), n=300)
    grid = build_voxel_grid(pts, 2.0, (16, 16, 16))
    start, count = np.asarray(grid.start), np.asarray(grid.count)
    assert count.sum() == 300
    # starts are the exclusive prefix sum of counts
    np.testing.assert_array_equal(start, np.concatenate(
        [[0], np.cumsum(count)[:-1]]))
    # every point is reachable through exactly its own cell's range
    sorted_pts = np.asarray(grid.points)
    ids = np.asarray(grid.point_ids)
    p = np.asarray(pts)
    cells = np.asarray(cell_coords(pts, grid.origin, grid.voxel_size,
                                   grid.dims))
    lin = np.asarray(linear_cell_ids(jnp.asarray(cells), grid.dims))
    for c in np.unique(lin):
        rows = sorted_pts[start[c]:start[c] + count[c]]
        orig = p[lin == c]
        np.testing.assert_allclose(sorted(rows.tolist()),
                                   sorted(orig.tolist()), atol=0)
    # point_ids round-trips the reorder
    np.testing.assert_allclose(sorted_pts, p[ids], atol=0)


def test_grid_excludes_invalid_rows():
    pts = _cloud(jax.random.PRNGKey(5), n=200)
    padded, valid = pad_cloud(np.asarray(pts), 256)
    grid = build_voxel_grid(jnp.asarray(padded), 2.0, (16, 16, 16),
                            valid=jnp.asarray(valid))
    assert int(np.asarray(grid.count).sum()) == 200
    # reachable sorted rows never include a sentinel coordinate
    reach = np.asarray(grid.points)[:200]
    assert np.all(np.abs(reach) < PAD_SENTINEL)


def test_grid_crosses_jit_boundary():
    """VoxelGrid is a pytree with static dims: build inside jit, query
    outside (and vice versa) without retracing on metadata."""
    pts = _cloud(jax.random.PRNGKey(6), n=100)
    grid = jax.jit(lambda p: build_voxel_grid(p, 2.0, (8, 8, 8)))(pts)
    assert grid.dims == (8, 8, 8)
    assert grid.num_cells == 512

    @jax.jit
    def total(g):
        return g.count.sum()

    assert int(total(grid)) == 100


def test_out_of_lattice_points_clip_to_boundary():
    pts = jnp.array([[0.0, 0.0, 0.0], [100.0, 100.0, 100.0]])
    grid = build_voxel_grid(pts, 1.0, (4, 4, 4),
                            origin=jnp.zeros(3))
    ic = np.asarray(cell_coords(pts, grid.origin, grid.voxel_size, grid.dims))
    assert ic.max() == 3  # clipped, not wrapped/dropped
    assert int(np.asarray(grid.count).sum()) == 2
