"""Checkpointing + fault-tolerance behaviours."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.optim import adamw, cosine_schedule
from repro.train import checkpoint as ckpt
from repro.train.train_step import init_state


@pytest.fixture
def state():
    cfg = get_smoke("qwen2-0.5b")
    opt = adamw(cosine_schedule(1e-3))
    return init_state(jax.random.PRNGKey(0), cfg, opt)


def _tree_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_save_restore_roundtrip(tmp_path, state):
    ckpt.save(tmp_path, state, step=7, extra={"note": "x"})
    abstract = jax.eval_shape(lambda: state)
    restored, step, extra = ckpt.restore(tmp_path, abstract)
    assert step == 7 and extra == {"note": "x"}
    _tree_equal(state, restored)


def test_atomicity_no_partial_checkpoints(tmp_path, state):
    """A .tmp dir (simulated crash) must not be restorable/visible."""
    ckpt.save(tmp_path, state, step=1)
    # simulate a crashed half-write
    (tmp_path / "step_0000000002.tmp").mkdir()
    assert ckpt.latest_step(tmp_path) == 1
    _, step, _ = ckpt.restore(tmp_path, jax.eval_shape(lambda: state))
    assert step == 1


def test_keep_last_k(tmp_path, state):
    mgr = ckpt.CheckpointManager(tmp_path, keep_last_k=2, save_interval_steps=1)
    for s in (1, 2, 3, 4):
        mgr.save_sync(state, s)
    steps = sorted(p.name for p in tmp_path.iterdir())
    assert steps == ["step_0000000003", "step_0000000004"]


def test_async_save_and_restore(tmp_path, state):
    mgr = ckpt.CheckpointManager(tmp_path, keep_last_k=3)
    mgr.save_async(state, 10)
    mgr.wait()
    restored, step, _ = mgr.restore_latest(jax.eval_shape(lambda: state))
    assert step == 10
    _tree_equal(state, restored)


def test_elastic_restore_with_shardings(tmp_path, state):
    """Restore onto explicit (single-device, stand-in for resized-mesh)
    shardings."""
    ckpt.save(tmp_path, state, step=3)
    dev = jax.devices()[0]
    shardings = jax.tree_util.tree_map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), state)
    restored, step, _ = ckpt.restore(tmp_path, jax.eval_shape(lambda: state),
                                     shardings=shardings)
    _tree_equal(state, restored)


def test_restore_shape_mismatch_raises(tmp_path, state):
    ckpt.save(tmp_path, state, step=1)
    bad = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct((l.shape[0] + 1,) + l.shape[1:],
                                       l.dtype)
        if l.ndim else jax.ShapeDtypeStruct(l.shape, l.dtype),
        jax.eval_shape(lambda: state))
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, bad)


def test_straggler_watchdog():
    wd = ckpt.StragglerWatchdog(threshold=2.0, alpha=0.5)
    for _ in range(5):
        wd.observe(0, 1.0)
    assert not wd.observe(5, 1.5)
    assert wd.observe(6, 10.0)          # 10x the EMA -> flagged
    assert wd.flagged and wd.flagged[-1][0] == 6


def test_train_resume_bit_identical(tmp_path):
    """Crash/restart: training resumed from a checkpoint must produce the
    same params as the uninterrupted run (determinism incl. data stream)."""
    from repro.data.tokens import TokenStream
    from repro.train.train_step import make_train_step
    cfg = get_smoke("qwen2-0.5b")
    opt = adamw(cosine_schedule(1e-3, warmup_steps=2, total_steps=50))
    stream = TokenStream(cfg.vocab_size, batch=2, seq_len=16, seed=3)
    step_fn = jax.jit(make_train_step(cfg, opt))

    def run(n, state, start=0):
        for s in range(start, n):
            state, _ = step_fn(state, stream.batch_at(s))
        return state

    s0 = init_state(jax.random.PRNGKey(1), cfg, opt)
    full = run(6, s0)
    # interrupted at 3, checkpointed, restored, resumed
    s1 = init_state(jax.random.PRNGKey(1), cfg, opt)
    mid = run(3, s1)
    ckpt.save(tmp_path, mid, step=3)
    restored, step, _ = ckpt.restore(tmp_path, jax.eval_shape(lambda: mid))
    resumed = run(6, restored, start=step)
    _tree_equal(full.params, resumed.params)
