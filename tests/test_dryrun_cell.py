"""End-to-end dry-run regression: one real cell on the production 512-device
mesh, in a subprocess (slow; the full 84-cell sweep lives in results/)."""
import json
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_dryrun_qwen2_decode_cell(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-0.5b",
         "--shape", "decode_32k", "--mesh", "single",
         "--out-dir", str(tmp_path)],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": str(ROOT / "src")},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads((tmp_path / "qwen2-0.5b__decode_32k__single.json"
                      ).read_text())
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["memory"]["fits_v5e_16g"]
    r = rec["roofline"]
    assert r["dominant"] == "memory"          # decode is memory-bound
    assert 0 < r["memory_s"] < 10
    assert rec["analyzed"]["unknown_trip_whiles"] == 0


@pytest.mark.slow
def test_dryrun_icp_cell_multi_pod(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "fpps-icp",
         "--shape", "fleet_130k", "--mesh", "multi",
         "--out-dir", str(tmp_path)],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": str(ROOT / "src")},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads((tmp_path / "fpps-icp__fleet_130k__multi.json"
                      ).read_text())
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 512            # the pod axis shards
    assert rec["sharding"]["frame_axes"] == ["pod", "data"]
