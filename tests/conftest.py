"""Shared fixtures. NOTE: no XLA_FLAGS device-count forcing here — smoke
tests and benchmarks must see the real single CPU device; only
launch/dryrun.py (and the subprocess-based distribution tests, which spawn
fresh interpreters) use placeholder device fleets."""
import os

import numpy as np
import pytest

# Keep hypothesis + jax deterministic and CI-friendly.
os.environ.setdefault("JAX_PLATFORMS", "cpu")


@pytest.fixture(scope="session")
def small_scene():
    """A reduced synthetic LiDAR frame pair (fast enough for unit tests)."""
    from repro.data.pointcloud import SceneConfig, frame_pair
    cfg = SceneConfig(n_ground=6000, n_walls=4200, n_poles=1200,
                      n_clutter=1300, extent=40.0, sensor_range=45.0)
    src, dst, T_gt = frame_pair(seq=0, frame=5, cfg=cfg, n_source_samples=1024)
    return src, dst, T_gt


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
