import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.partition import partitioning
from repro.models.moe import MoEConfig, moe_init, moe_forward_dense
from repro.models.moe_ep import moe_forward_ep

from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
cfg = MoEConfig(d_model=32, n_experts=8, top_k=2, d_expert=16, n_shared_experts=1, capacity_factor=8.0)
params = moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32)) * 0.5  # B=4 over data2, S=16 over model4

rules = {"tokens": ("data",), "expert": ("model",), "fsdp": None, "moe_impl": "shard_map_ep"}
with partitioning(mesh, rules) as merged:
    out_ep, m_ep = jax.jit(lambda p, xx: moe_forward_ep(p, xx, cfg, mesh, merged))(params, x)
out_d, m_d = moe_forward_dense(params, x, cfg)
err = float(jnp.max(jnp.abs(out_ep - out_d)))
print("EP vs dense max err:", err)
assert err < 5e-2, err
print("lb loss ep/dense:", float(m_ep["load_balance_loss"]), float(m_d["load_balance_loss"]))
# grad flows
def loss(p):
    with partitioning(mesh, rules) as merged:
        o, m = moe_forward_ep(p, x, cfg, mesh, merged)
    return jnp.sum(o.astype(jnp.float32)**2) + m["moe_aux_total"]
g = jax.grad(loss)(params)
gn = float(jnp.sqrt(sum(jnp.sum(t.astype(jnp.float32)**2) for t in jax.tree_util.tree_leaves(g))))
print("grad norm:", gn)
assert np.isfinite(gn) and gn > 0
print("MOE-EP-OK")

# (run via tests/test_moe_ep.py subprocess)
