"""int8 error-feedback DP gradient compression (subprocess: 8 devices)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_grad_compression_subprocess():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "grad_compression_worker.py")],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": str(ROOT / "src")},
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "GRAD-COMPRESSION-OK" in proc.stdout
