"""Partitioning-rule engine unit tests (no multi-device mesh needed: these
exercise the pure-python rule resolution used by the dry-run)."""
import pytest

from repro.configs import get_config
from repro.configs.registry import cells, get_shape, runnable_cell
from repro.launch.dryrun import ICP_SHAPES, _rules_for, _trim_batch_axes
from repro.launch.mesh import batch_axes_for


class FakeMesh:
    """Duck-typed mesh: .axis_names + .shape mapping (what the rule code uses)."""

    def __init__(self, shape: dict):
        self.axis_names = tuple(shape)
        self.shape = shape


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


@pytest.mark.parametrize("mesh,batch,expect", [
    (SINGLE, 256, ("data",)),
    (SINGLE, 1, ()),                       # long_500k: replicated
    (SINGLE, 128, ("data",)),
    (MULTI, 256, ("pod", "data")),
    (MULTI, 32, ("pod", "data")),          # prefill batch 32 = 2*16
    (MULTI, 2, ("pod",)),
    (MULTI, 3, ()),
])
def test_batch_axes_for(mesh, batch, expect):
    assert batch_axes_for(mesh, batch) == expect


def test_trim_batch_axes_respects_override_order():
    # qwen2 wants DP over everything; batch 256 on single pod = data*model
    got = _trim_batch_axes(SINGLE, ("pod", "data", "model"), 256)
    assert got == ("data", "model")
    # but a batch of 128 can't extend onto model (128 % 256 != 0)
    assert _trim_batch_axes(SINGLE, ("pod", "data", "model"), 128) == ("data",)


def test_rules_for_merges_arch_overrides():
    cfg = get_config("qwen2-0.5b")
    rules = _rules_for(SINGLE, 256, None, cfg)
    assert rules["heads"] is None          # 14 heads: no TP
    assert rules["batch"] == ("data", "model")
    assert rules["tokens"] == rules["batch"]
    cfg405 = get_config("llama3-405b")
    rules = _rules_for(SINGLE, 256, None, cfg405)
    assert rules["kv_heads"] is None       # 8 kv heads < TP=16
    assert rules["heads"] == "model"


def test_cell_registry_complete():
    cs = cells()
    assert len(cs) == 40                   # 10 archs x 4 shapes
    assert len(ICP_SHAPES) == 2            # + the paper's own cells
    skipped = [c for c in cs if not runnable_cell(*c)[0]]
    # long_500k skipped for exactly the 8 non-sub-quadratic archs
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s in skipped)
    runnable_names = {a for a, s in cs if s == "long_500k"
                      and runnable_cell(a, s)[0]}
    assert runnable_names == {"mamba2-780m", "recurrentgemma-9b"}


def test_shapes_registry():
    assert get_shape("train_4k").kind == "train"
    assert get_shape("decode_32k").kind == "decode"
    assert get_shape("long_500k").global_batch == 1
    with pytest.raises(KeyError):
        get_shape("nope")


def test_aconstraint_noop_outside_context():
    from repro.launch.partition import aconstraint
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    y = aconstraint(x, ("batch", "heads"))
    assert y is x  # no partitioning context -> identity
