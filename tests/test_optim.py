"""Optimizer + roofline-analyzer unit tests."""
import jax
import jax.numpy as jnp
import pytest

from repro.optim import adafactor, adamw, clip_by_global_norm, cosine_schedule
from repro.roofline.hlo_analysis import analyze_hlo


def _quadratic_problem():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,)), "m": jnp.zeros((4, 3))}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["m"] ** 2)

    return params, loss


@pytest.mark.parametrize("make", [adamw, adafactor])
def test_optimizer_converges(make):
    params, loss = _quadratic_problem()
    opt = make(lambda s: 0.05, weight_decay=0.0)
    state = opt.init(params)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    total = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    assert abs(float(total) - 1.0) < 1e-4


def test_adafactor_state_is_factored():
    opt = adafactor(lambda s: 1e-3)
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    st = opt.init(params)
    assert st.inner["w"]["vr"].shape == (64,)
    assert st.inner["w"]["vc"].shape == (32,)
    assert st.inner["b"]["v"].shape == (32,)
    # memory: factored state is O(m+n), not O(mn)
    n_state = sum(x.size for x in jax.tree_util.tree_leaves(st.inner))
    assert n_state == 64 + 32 + 32


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100, min_ratio=0.1)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1.0) < 0.11
    assert float(lr(jnp.asarray(100))) <= 0.11
    assert float(lr(jnp.asarray(5))) < float(lr(jnp.asarray(10)))


# ---------------------------------------------------------------------------
# roofline HLO analyzer
# ---------------------------------------------------------------------------
def test_analyzer_counts_scan_trip_counts():
    def body(x, w):
        def step(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(step, x, None, length=7)
        return y

    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(body).lower(s, s).compile()
    m = analyze_hlo(compiled.as_text())
    expect = 7 * 2 * 128 ** 3
    assert abs(m.dot_flops - expect) / expect < 0.01
    assert m.unknown_trip_whiles == 0
    # naive cost_analysis must NOT match (documents why the analyzer exists)
    from repro.compat import cost_analysis
    naive = cost_analysis(compiled)["flops"]
    assert naive < expect / 2


def test_analyzer_nested_scans_multiply():
    def body(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(body).lower(s, s).compile()
    m = analyze_hlo(compiled.as_text())
    expect = 15 * 2 * 64 ** 3
    assert abs(m.dot_flops - expect) / expect < 0.02


def test_analyzer_hbm_model_reasonable():
    """A big matmul's modeled traffic ~= operands + result."""
    def f(a, b):
        return a @ b

    s = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    compiled = jax.jit(f).lower(s, s).compile()
    m = analyze_hlo(compiled.as_text())
    expect = 3 * 1024 * 1024 * 4
    assert m.hbm_bytes <= expect * 2.5
    assert m.hbm_bytes >= expect * 0.9
