"""RegistrationEngine layer: registry semantics, persistent-compile
regression, and batch-vs-loop equivalence (including mixed-size pairs
through the bucketing path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FppsICP, ICPParams, available_engines, get_engine,
                        icp, icp_batch, random_rigid_transform,
                        transform_points)
from repro.core.engine import CallableEngine, RegistrationEngine, XLAEngine
from repro.core.nn_search import nn_search
from repro.data.collate import collate_pairs

PARAMS = ICPParams(max_iterations=15, chunk=256)


def _pair(key, n=220, m=340):
    k1, k2, k3 = jax.random.split(key, 3)
    dst = jax.random.uniform(k1, (m, 3), minval=-10, maxval=10)
    T_gt = random_rigid_transform(k2, max_angle=0.1, max_translation=0.3)
    src = transform_points(jnp.linalg.inv(T_gt), dst)[:n]
    src = src + 0.002 * jax.random.normal(k3, src.shape)
    return np.asarray(src), np.asarray(dst), np.asarray(T_gt)


# -- registry ---------------------------------------------------------------

def test_registry_lists_builtin_engines():
    names = available_engines()
    for name in ("xla", "pallas", "distributed"):
        assert name in names


def test_unknown_engine_raises():
    with pytest.raises(ValueError, match="unknown engine"):
        get_engine("fpga")
    with pytest.raises(ValueError, match="unknown engine"):
        FppsICP(engine="not-an-engine")


def test_bad_engine_type_raises():
    with pytest.raises(TypeError):
        get_engine(42)


def test_callable_engine_accepted():
    """A bare nn_fn(src, dst) -> (d2, idx) still works as an engine."""
    calls = []

    def my_nn(src, dst):
        calls.append(1)
        return nn_search(src, dst, chunk=128)

    eng = get_engine(my_nn)
    assert isinstance(eng, CallableEngine)
    src, dst, T_gt = _pair(jax.random.PRNGKey(0))
    res = eng.register(src, dst, PARAMS)
    ref = icp(jnp.asarray(src), jnp.asarray(dst), PARAMS)
    np.testing.assert_allclose(np.asarray(res.T), np.asarray(ref.T), atol=1e-4)
    assert calls, "user nn_fn was never traced"


def test_engine_instance_passes_through():
    eng = get_engine("xla")
    assert get_engine(eng) is eng
    assert isinstance(eng, RegistrationEngine)


def test_named_engines_are_shared_singletons():
    """Same name+kwargs -> same instance, so FppsICP-per-frame reuses one
    compiled executable; direct class instantiation stays private."""
    assert get_engine("xla", chunk=256) is get_engine("xla", chunk=256)
    assert get_engine("xla", chunk=256) is not get_engine("xla", chunk=512)
    assert XLAEngine(chunk=256) is not XLAEngine(chunk=256)


# -- persistent jit cache / recompile regression ----------------------------

@pytest.mark.parametrize("engine_kwargs", [
    dict(engine="xla"),
    dict(engine="pallas", bn=64, bm=128),
])
def test_no_recompile_across_aligns(engine_kwargs):
    """ISSUE 1 regression: repeated align() calls must reuse one compiled
    executable — the old FppsICP built a fresh unhashable partial per call.

    Engines resolved by name are shared singletons, so we assert the trace
    count *delta*: +1 on the first align of a fresh params/shape combo,
    +0 on every align after — including from a brand-new FppsICP instance
    (the PCL construct-per-frame pattern)."""
    src, dst, _ = _pair(jax.random.PRNGKey(1))

    def make():
        reg = FppsICP(chunk=256, **engine_kwargs)
        reg.setMaxIterationCount(17)  # unique params: fresh cache entry
        reg.setInputSource(src)
        reg.setInputTarget(dst)
        return reg

    reg = make()
    before = reg.engine.trace_count
    T1 = reg.align()
    assert reg.engine.trace_count == before + 1
    for _ in range(3):
        T2 = reg.align()
    # ... and a second FppsICP with the same config shares the executable.
    T2 = make().align()
    assert reg.engine.trace_count == before + 1, (
        f"align() recompiled: {reg.engine.traces}")
    np.testing.assert_allclose(T1, T2, atol=1e-6)


def test_same_bucket_sizes_share_compile():
    """Slightly different cloud sizes land in one shape bucket -> one trace.

    Direct instantiation gives a private cache, so counts start at zero."""
    eng = XLAEngine(chunk=256)
    for n, m in [(200, 300), (220, 340), (190, 310)]:  # all pad to (256, 384)
        src, dst, _ = _pair(jax.random.PRNGKey(n), n=n, m=m)
        eng.register(src, dst, PARAMS)
    assert eng.trace_count == 1, eng.traces


def test_different_params_get_separate_cache_entries():
    eng = XLAEngine(chunk=256)
    src, dst, _ = _pair(jax.random.PRNGKey(2))
    eng.register(src, dst, PARAMS)
    eng.register(src, dst, PARAMS._replace(max_iterations=5))
    assert eng.trace_count == 2


def test_minimizer_fields_get_separate_cache_entries():
    """ISSUE 3 regression: params differing only in the minimizer/robust
    fields must never reuse a stale executable — a cached point-to-point
    program served for a point-to-plane request would silently return the
    wrong math."""
    eng = XLAEngine(chunk=256)
    src, dst, _ = _pair(jax.random.PRNGKey(7))
    variants = [
        PARAMS,
        PARAMS._replace(minimizer="point_to_plane"),
        PARAMS._replace(robust_kernel="huber"),
        PARAMS._replace(robust_kernel="huber", robust_scale=0.1),
        PARAMS._replace(minimizer="point_to_plane", robust_kernel="tukey"),
    ]
    for p in variants:
        eng.register(src, dst, p)
    assert eng.trace_count == len(variants), eng.traces
    assert len(eng._cache) == len(variants)
    # and repeating every variant stays cache-hot
    for p in variants:
        eng.register(src, dst, p)
    assert eng.trace_count == len(variants), eng.traces


def test_engine_chunk_default_feeds_params():
    """get_engine(..., chunk=...) is the default ICPParams chunk when the
    caller passes no explicit params."""
    eng = XLAEngine(chunk=123)
    assert eng._default_params(None).chunk == 123
    assert eng._default_params(PARAMS).chunk == PARAMS.chunk


# -- batch vs loop equivalence ----------------------------------------------

def test_icp_batch_matches_per_pair_icp():
    """Same-size pairs, no padding: icp_batch == per-pair icp to tolerance."""
    pairs = [_pair(k) for k in jax.random.split(jax.random.PRNGKey(3), 4)]
    src_b = jnp.stack([jnp.asarray(s) for s, _, _ in pairs])
    dst_b = jnp.stack([jnp.asarray(d) for _, d, _ in pairs])
    res = icp_batch(src_b, dst_b, PARAMS)
    for i, (s, d, T_gt) in enumerate(pairs):
        single = icp(jnp.asarray(s), jnp.asarray(d), PARAMS)
        np.testing.assert_allclose(np.asarray(res.T[i]),
                                   np.asarray(single.T), atol=1e-4)
        np.testing.assert_allclose(float(res.rmse[i]), float(single.rmse),
                                   atol=1e-5)
        # and both recover the ground truth
        np.testing.assert_allclose(np.asarray(res.T[i]), T_gt, atol=0.05)


@pytest.mark.parametrize("engine_kwargs", [
    dict(spec="xla"),
    dict(spec="pallas", bn=64, bm=128),
    dict(spec="distributed"),
])
def test_register_batch_mixed_sizes_matches_loop(engine_kwargs):
    """Mixed-size pairs through collate bucketing must match the unpadded
    per-pair loop on every engine."""
    kwargs = dict(engine_kwargs)
    spec = kwargs.pop("spec")
    sizes = [(180, 300), (220, 340), (150, 260)]
    pairs = [_pair(k, n=n, m=m) for k, (n, m) in
             zip(jax.random.split(jax.random.PRNGKey(4), len(sizes)), sizes)]
    batch = collate_pairs([(s, d) for s, d, _ in pairs])
    eng = get_engine(spec, chunk=256, **kwargs)
    res = eng.register_batch(batch.src, batch.dst, PARAMS,
                             src_valid=batch.src_valid,
                             dst_valid=batch.dst_valid)
    for i, (s, d, _) in enumerate(pairs):
        single = icp(jnp.asarray(s), jnp.asarray(d), PARAMS)
        np.testing.assert_allclose(np.asarray(res.T[i]),
                                   np.asarray(single.T), atol=1e-4)
        # masks keep the inlier fraction w.r.t. the true point count
        assert float(res.inlier_frac[i]) == pytest.approx(
            float(single.inlier_frac), abs=1e-5)


def test_register_pairs_collates_and_registers():
    pairs = [_pair(k, n=n, m=m) for k, (n, m) in
             zip(jax.random.split(jax.random.PRNGKey(5), 2),
                 [(128, 200), (200, 256)])]
    eng = get_engine("xla", chunk=256)
    res, batch = eng.register_pairs([(s, d) for s, d, _ in pairs], PARAMS)
    assert batch.src_sizes == (128, 200)
    for i, (_, _, T_gt) in enumerate(pairs):
        np.testing.assert_allclose(np.asarray(res.T[i]), T_gt, atol=0.05)


def test_register_batch_warm_start():
    pairs = [_pair(k) for k in jax.random.split(jax.random.PRNGKey(6), 2)]
    src_b = jnp.stack([jnp.asarray(s) for s, _, _ in pairs])
    dst_b = jnp.stack([jnp.asarray(d) for _, d, _ in pairs])
    T0 = jnp.stack([jnp.asarray(T) for _, _, T in pairs])  # perfect start
    eng = get_engine("xla", chunk=256)
    res = eng.register_batch(src_b, dst_b,
                             PARAMS._replace(max_iterations=3),
                             initial_transforms=T0)
    np.testing.assert_allclose(np.asarray(res.T), np.asarray(T0), atol=0.02)


# -- warm starts through every engine (ISSUE 5) -----------------------------

@pytest.mark.parametrize("engine_kwargs", [
    dict(engine="xla"),
    dict(engine="pallas", bn=64, bm=128),
    dict(engine="distributed"),
    dict(engine="pyramid", levels=()),
], ids=lambda kw: kw["engine"])
def test_register_pairs_warm_start_cuts_iterations(engine_kwargs):
    """``initial_transforms`` must thread through every engine's
    ``register_pairs``: a near-perfect warm start cuts the iteration count
    and reaches the same fixed point as the cold solve. T0 is passed as
    float64 on purpose — the engine pins it to f32 (a stray f64 warm start
    must not poison the f32 trace)."""
    kwargs = dict(engine_kwargs)
    name = kwargs.pop("engine")
    pairs = [_pair(k) for k in jax.random.split(jax.random.PRNGKey(11), 2)]
    eng = get_engine(name, chunk=256, **kwargs)
    clouds = [(s, d) for s, d, _ in pairs]
    cold, _ = eng.register_pairs(clouds, PARAMS)
    T0 = np.stack([T for _, _, T in pairs]).astype(np.float64)
    warm, _ = eng.register_pairs(clouds, PARAMS, initial_transforms=T0)
    assert warm.T.dtype == jnp.float32
    assert (int(np.sum(np.asarray(warm.iterations)))
            < int(np.sum(np.asarray(cold.iterations))))
    np.testing.assert_allclose(np.asarray(warm.T), np.asarray(cold.T),
                               atol=1e-2)


def test_register_warm_start_f64_no_retrace():
    """A float64 ``initial_transform`` must reuse the f32 executable (no
    retrace, f32 result) and agree with the f32-warm-started solve."""
    src, dst, T_gt = _pair(jax.random.PRNGKey(12))
    eng = get_engine("xla", chunk=256)
    params = PARAMS._replace(max_iterations=13)  # fresh cache entry
    res32 = eng.register(src, dst, params,
                         initial_transform=np.asarray(T_gt, np.float32))
    before = eng.trace_count
    res64 = eng.register(src, dst, params,
                         initial_transform=np.asarray(T_gt, np.float64))
    assert eng.trace_count == before
    assert res64.T.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(res64.T), np.asarray(res32.T),
                               atol=1e-6)
