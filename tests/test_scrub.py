"""NaN/Inf scrubbing at the registration boundary (satellite bugfix).

Before the scrub, a single NaN row poisoned the whole solve: NaN distances
propagate through the matmul distance expansion into every argmin, NaN
coordinates poison the grid's ``min``-derived origin and ``floor`` cell
coords, and the fused kernel's moment sums go NaN in one step. These tests
encode the failing-before behaviour: corrupt rows must be dropped at the
boundary, leaving the recovered transform (bit-)unchanged vs. masking the
same rows by hand.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ICPParams, get_engine, icp, icp_batch,
                        scrub_nonfinite, transform_points)
from repro.core.transform import random_rigid_transform


def _scene(seed, n=512):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    target = jax.random.uniform(k1, (n, 3), minval=-8.0, maxval=8.0)
    T_gt = random_rigid_transform(k2, max_angle=0.1, max_translation=0.3)
    src = transform_points(jnp.linalg.inv(T_gt), target)
    src = src + 0.005 * jax.random.normal(k3, src.shape)
    return src, target, T_gt


def _poison(points, rows, value=jnp.nan):
    return points.at[jnp.asarray(rows)].set(value)


def test_scrub_nonfinite_masks_and_sentinels():
    pts = jnp.array([[0.0, 0.0, 0.0],
                     [jnp.nan, 1.0, 1.0],
                     [1.0, jnp.inf, 1.0],
                     [2.0, 2.0, 2.0]])
    out, valid = scrub_nonfinite(pts)
    np.testing.assert_array_equal(np.asarray(valid),
                                  [True, False, False, True])
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_array_equal(np.asarray(out[0]), [0.0, 0.0, 0.0])


def test_scrub_composes_with_existing_mask():
    pts = jnp.array([[0.0, 0.0, 0.0], [jnp.nan, 0.0, 0.0],
                     [1.0, 1.0, 1.0]])
    valid = jnp.array([True, True, False])
    _, v = scrub_nonfinite(pts, valid)
    np.testing.assert_array_equal(np.asarray(v), [True, False, False])


def test_scrub_is_identity_on_clean_input():
    """Bit-exactness guard: clean inputs must be untouched, so the scrub
    cannot move any committed benchmark baseline."""
    pts = jax.random.normal(jax.random.PRNGKey(0), (256, 3))
    out, valid = scrub_nonfinite(pts)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(pts))
    assert bool(jnp.all(valid))


def test_single_nan_row_does_not_change_icp_transform():
    """The headline regression: one NaN row in the source must recover the
    same transform as explicitly masking that row (failing before the
    boundary scrub — the solve returned an all-NaN pose)."""
    src, target, _ = _scene(0)
    params = ICPParams(max_iterations=30, chunk=256)
    poisoned = _poison(src, [7])
    mask = jnp.ones(src.shape[0], bool).at[7].set(False)

    res_poisoned = icp(poisoned, target, params)
    res_masked = icp(src, target, params, src_valid=mask)

    assert np.all(np.isfinite(np.asarray(res_poisoned.T)))
    np.testing.assert_allclose(np.asarray(res_poisoned.T),
                               np.asarray(res_masked.T), atol=1e-6)


def test_nan_rows_in_target_are_scrubbed():
    src, target, T_gt = _scene(1)
    poisoned = _poison(target, [3, 100, 400], jnp.inf)
    res = icp(src, poisoned, ICPParams(max_iterations=30, chunk=256))
    assert np.all(np.isfinite(np.asarray(res.T)))
    np.testing.assert_allclose(np.asarray(res.T), np.asarray(T_gt),
                               atol=0.05)


def test_icp_batch_scrubs_per_lane():
    src0, dst0, _ = _scene(2, n=256)
    src1, dst1, _ = _scene(3, n=256)
    srcs = jnp.stack([_poison(src0, [0]), src1])
    dsts = jnp.stack([dst0, _poison(dst1, [5], jnp.inf)])
    res = icp_batch(srcs, dsts, ICPParams(max_iterations=20, chunk=256))
    assert np.all(np.isfinite(np.asarray(res.T)))


@pytest.mark.parametrize("kind", ["xla", "pallas", "pyramid"])
def test_engines_survive_nan_rows(kind):
    src, target, T_gt = _scene(4)
    poisoned = _poison(src, [11, 12])
    engine = get_engine(kind)
    res = engine.register(poisoned, target,
                          ICPParams(max_iterations=30, chunk=256))
    T = np.asarray(res.T)
    assert np.all(np.isfinite(T))
    np.testing.assert_allclose(T, np.asarray(T_gt), atol=0.05)
