"""MoE routing + dispatch tests: sorted dispatch vs dense reference,
router semantics, capacity-drop accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import hypothesis, st
from repro.models.moe import (MoEConfig, capacity, moe_forward,
                              moe_forward_dense, moe_init, route)

CFG = MoEConfig(d_model=32, n_experts=8, top_k=2, d_expert=16,
                n_shared_experts=1, capacity_factor=8.0)  # cf high: no drops


def test_dispatch_matches_dense_reference():
    params = moe_init(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.5
    out_s, m_s = moe_forward(params, x, CFG)
    out_d, m_d = moe_forward_dense(params, x, CFG)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_d),
                               rtol=2e-2, atol=2e-2)
    assert float(m_s["dropped_frac"]) == 0.0


def test_route_topk_semantics():
    logits = jnp.array([[1.0, 5.0, 3.0, 0.0], [0.0, 0.0, 10.0, 9.0]])
    cfg = MoEConfig(d_model=1, n_experts=4, top_k=2, d_expert=1)
    w, idx, metrics = route(logits, cfg)
    np.testing.assert_array_equal(np.asarray(idx), [[1, 2], [2, 3]])
    assert bool(jnp.all(w >= 0)) and bool(jnp.all(w <= 1))
    cfg_n = MoEConfig(d_model=1, n_experts=4, top_k=2, d_expert=1,
                      normalize_topk=True)
    w_n, _, _ = route(logits, cfg_n)
    np.testing.assert_allclose(np.asarray(jnp.sum(w_n, -1)), 1.0, rtol=1e-5)


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform router -> aux loss == n_experts * E[f·P] == 1."""
    cfg = MoEConfig(d_model=1, n_experts=4, top_k=1, d_expert=1)
    t = 4096
    logits = jnp.zeros((t, 4))
    # break ties uniformly
    logits = logits + 1e-4 * jax.random.normal(jax.random.PRNGKey(0), (t, 4))
    _, _, m = route(logits, cfg)
    assert abs(float(m["load_balance_loss"]) - 1.0) < 0.05


def test_capacity_drop_accounting():
    """All tokens to one expert: only `capacity` survive."""
    cfg = MoEConfig(d_model=8, n_experts=4, top_k=1, d_expert=8,
                    capacity_factor=0.5)
    params = moe_init(jax.random.PRNGKey(2), cfg)
    # Force router to expert 0.
    params["router"]["kernel"] = jnp.zeros((8, 4)).at[:, 0].set(100.0)
    x = jnp.ones((1, 64, 8))
    out, m = moe_forward(params, x, cfg)
    c = capacity(64, cfg)
    expected_drop = 1.0 - c / 64.0
    assert abs(float(m["dropped_frac"]) - expected_drop) < 1e-6
    # Dropped tokens contribute nothing beyond shared experts (none here):
    # rows past capacity are zero.
    assert np.count_nonzero(np.asarray(out[0]).sum(-1)) <= c


@hypothesis.given(st.integers(0, 10_000))
@hypothesis.settings(max_examples=20, deadline=None)
def test_property_dispatch_parity(seed):
    cfg = MoEConfig(d_model=16, n_experts=4, top_k=2, d_expert=8,
                    capacity_factor=8.0)
    params = moe_init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 8, 16))
    out_s, _ = moe_forward(params, x, cfg)
    out_d, _ = moe_forward_dense(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_d),
                               rtol=3e-2, atol=3e-2)


def test_grad_flows_through_dispatch():
    params = moe_init(jax.random.PRNGKey(4), CFG)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 16, 32))

    def loss(p):
        out, m = moe_forward(p, x, CFG)
        return jnp.sum(out ** 2) + m["moe_aux_total"]

    g = jax.grad(loss)(params)
    for name in ("router", "wi", "wg", "wo"):
        leaf = g[name]["kernel"] if name == "router" else g[name]
        assert float(jnp.max(jnp.abs(leaf))) > 0.0, name
