"""Shape-bucket collation: padding, masks, bucket ladder."""
import numpy as np
import pytest

from repro.data.collate import (DEFAULT_BUCKETS, PAD_SENTINEL, CollatedBatch,
                                bucket_size, collate_pairs, pad_cloud)


def test_bucket_ladder_properties():
    prev = 0
    for b in DEFAULT_BUCKETS:
        assert b > prev, "ladder must be strictly increasing"
        assert b % 128 == 0, "buckets must be Pallas tile-aligned"
        prev = b
    for n in (1, 255, 256, 257, 4096, 5000, 131072):
        b = bucket_size(n)
        assert b >= n
    # ratio between consecutive rungs bounds padding waste.
    ratios = [b / a for a, b in zip(DEFAULT_BUCKETS, DEFAULT_BUCKETS[1:])]
    assert max(ratios) <= 2.0


def test_bucket_size_beyond_ladder_rounds_to_top_multiple():
    top = DEFAULT_BUCKETS[-1]
    assert bucket_size(top + 1) == 2 * top
    assert bucket_size(3 * top) == 3 * top


def test_bucket_size_rejects_empty():
    with pytest.raises(ValueError):
        bucket_size(0)


def test_pad_cloud_contents_and_mask():
    pts = np.arange(15, dtype=np.float32).reshape(5, 3)
    padded, valid = pad_cloud(pts, 8)
    assert padded.shape == (8, 3) and valid.shape == (8,)
    np.testing.assert_array_equal(padded[:5], pts)
    assert valid[:5].all() and not valid[5:].any()
    # Padding is a finite far sentinel, not inf/NaN (matmul-expansion safe).
    assert np.all(padded[5:] == PAD_SENTINEL)
    assert np.isfinite(padded).all()


def test_pad_cloud_rejects_overflow():
    with pytest.raises(ValueError):
        pad_cloud(np.zeros((10, 3), np.float32), 8)


def test_collate_mixed_sizes_share_buckets():
    rng = np.random.default_rng(0)
    pairs = [(rng.normal(size=(n, 3)).astype(np.float32),
              rng.normal(size=(m, 3)).astype(np.float32))
             for n, m in [(100, 300), (250, 260), (90, 400)]]
    batch = collate_pairs(pairs)
    assert isinstance(batch, CollatedBatch)
    n_b, m_b = bucket_size(250), bucket_size(400)
    assert batch.src.shape == (3, n_b, 3)
    assert batch.dst.shape == (3, m_b, 3)
    assert batch.src_sizes == (100, 250, 90)
    assert batch.dst_sizes == (300, 260, 400)
    for i, (s, d) in enumerate(pairs):
        assert batch.src_valid[i].sum() == s.shape[0]
        assert batch.dst_valid[i].sum() == d.shape[0]
        np.testing.assert_array_equal(batch.src[i, :s.shape[0]], s)
        np.testing.assert_array_equal(batch.dst[i, :d.shape[0]], d)


def test_collate_rejects_empty_list():
    with pytest.raises(ValueError):
        collate_pairs([])
