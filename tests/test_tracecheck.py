"""Fixture tests for tools/tracecheck.py (ISSUE-10).

Every rule gets one minimal true-positive and one near-miss
false-positive guard, as in-memory source snippets through
``analyze_source``. Plus: the repo-wide sweep stays clean against the
committed (empty) baseline, suppressions and the baseline round-trip,
and the two named regression demos — re-introducing the PR-6
hand-rolled interpret check and an array-valued engine cache key are
both caught.
"""
from __future__ import annotations

import importlib.util
import json
import pathlib
import sys
import textwrap

ROOT = pathlib.Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "tracecheck", ROOT / "tools" / "tracecheck.py")
tc = importlib.util.module_from_spec(_spec)
sys.modules["tracecheck"] = tc  # dataclasses resolves module globals
_spec.loader.exec_module(tc)


def rules_of(src: str) -> list[str]:
    return [f.rule for f in tc.analyze_source(textwrap.dedent(src))]


# ---------------------------------------------------------------------------
# TS001 — python control flow on traced values


def test_ts001_if_on_traced_value_in_jit():
    src = """
    import jax
    import jax.numpy as jnp

    @jax.jit
    def f(x):
        if x > 0:
            return x
        while jnp.sum(x) > 1.0:
            x = x * 0.5
        return -x
    """
    assert rules_of(src).count("TS001") == 2


def test_ts001_static_branches_are_clean():
    src = """
    from functools import partial

    import jax

    @partial(jax.jit, static_argnames=("mode",))
    def f(x, mode, y=None):
        if y is None:
            y = x
        if mode == "fast":
            return x + y
        if x.shape[0] > 128:
            return x[:128] + y[:128]
        return x - y
    """
    assert "TS001" not in rules_of(src)


def test_ts001_helper_inherits_traced_scope_interprocedurally():
    src = """
    import jax

    def helper(x: jax.Array):
        if x > 0:
            return x
        return -x

    @jax.jit
    def f(x):
        return helper(x)
    """
    assert "TS001" in rules_of(src)


def test_ts001_helper_with_host_caller_not_inherited():
    src = """
    import jax

    def helper(x: jax.Array):
        if x > 0:
            return x
        return -x

    @jax.jit
    def f(x):
        return helper(x)

    def host_path(arr):
        return helper(arr)
    """
    assert "TS001" not in rules_of(src)


# ---------------------------------------------------------------------------
# TS002 — implicit host syncs inside traced scopes


def test_ts002_float_and_item_on_traced():
    src = """
    import jax
    import jax.numpy as jnp

    @jax.jit
    def f(x):
        s = float(jnp.sum(x))
        t = jnp.max(x).item()
        return x * s * t
    """
    assert rules_of(src).count("TS002") == 2


def test_ts002_static_shape_conversion_is_clean():
    src = """
    import jax

    @jax.jit
    def f(x):
        s = float(x.shape[0])
        return x * s
    """
    assert "TS002" not in rules_of(src)


# ---------------------------------------------------------------------------
# TS003 — unhashable / array-valued static or cache keys


def test_ts003_array_valued_engine_cache_key_caught():
    # the named regression demo: an engine cache keyed on an array value
    src = """
    import jax.numpy as jnp

    class Engine:
        def __init__(self):
            self._cache = {}

        def executable(self, kind, gate):
            key = (kind, jnp.asarray(gate, jnp.float32))
            if key not in self._cache:
                self._cache[key] = object()
            return self._cache[key]
    """
    assert "TS003" in rules_of(src)


def test_ts003_hashable_params_key_is_clean():
    src = """
    class Engine:
        def __init__(self):
            self._cache = {}

        def executable(self, kind, params):
            key = (kind, params)
            if key not in self._cache:
                self._cache[key] = object()
            return self._cache[key]
    """
    assert "TS003" not in rules_of(src)


def test_ts003_array_annotated_static_argname():
    src = """
    from functools import partial

    import jax

    @partial(jax.jit, static_argnames=("w",))
    def f(x, w: jax.Array):
        return x * w
    """
    assert "TS003" in rules_of(src)


# ---------------------------------------------------------------------------
# TS004 — unpinned dtype at a trace boundary


def test_ts004_unpinned_asarray_of_host_value():
    src = """
    import jax.numpy as jnp

    def load(batch):
        return jnp.asarray(batch)
    """
    assert "TS004" in rules_of(src)


def test_ts004_pinned_or_already_traced_is_clean():
    src = """
    import jax
    import jax.numpy as jnp

    def load(batch):
        return jnp.asarray(batch, jnp.float32)

    def passthrough(x: jax.Array):
        return jnp.asarray(x)
    """
    assert "TS004" not in rules_of(src)


# ---------------------------------------------------------------------------
# TS005 — donated buffer read after the donating call


def test_ts005_read_after_donation():
    src = """
    import jax

    def _step(state, batch):
        return state

    step = jax.jit(_step, donate_argnums=(0,))

    def run(state, batch):
        out = step(state, batch)
        return state, out
    """
    assert "TS005" in rules_of(src)


def test_ts005_rebinding_result_is_clean():
    src = """
    import jax

    def _step(state, batch):
        return state, 0.0

    step = jax.jit(_step, donate_argnums=(0,))

    def run(state, batches):
        for batch in batches:
            state, loss = step(state, batch)
        return state
    """
    assert "TS005" not in rules_of(src)


# ---------------------------------------------------------------------------
# TS006 — print() inside a traced scope


def test_ts006_print_under_jit():
    src = """
    import jax

    @jax.jit
    def f(x):
        print("tracing", x)
        return x
    """
    assert "TS006" in rules_of(src)


def test_ts006_host_print_is_clean():
    src = """
    def report(loss):
        print("loss", loss)
    """
    assert "TS006" not in rules_of(src)


# ---------------------------------------------------------------------------
# PK001 — pallas_call plumbing + hand-rolled backend checks


def test_pk001_bypassing_common_kwargs_and_explicit_interpret():
    src = """
    from jax.experimental import pallas as pl

    def launch(kernel, x):
        return pl.pallas_call(kernel, out_shape=x, interpret=True)(x)
    """
    assert rules_of(src).count("PK001") == 2


def test_pk001_reintroduced_pr6_backend_check_caught():
    # the named regression demo: the hand-rolled interpret resolution
    # that PR 6 removed from the kernel launchers
    src = """
    import jax

    class Launcher:
        def _interp(self):
            if self._interpret is None:
                return jax.default_backend() != "tpu"
            return self._interpret
    """
    assert "PK001" in rules_of(src)


def test_pk001_common_plumbing_and_metadata_read_are_clean():
    src = """
    import jax
    from jax.experimental import pallas as pl

    from repro.kernels.common import pallas_call_kwargs

    def launch(kernel, x):
        return pl.pallas_call(
            kernel, out_shape=x,
            **pallas_call_kwargs(None, ("parallel",)))(x)

    def bench_metadata():
        return {"backend": jax.default_backend()}
    """
    assert "PK001" not in rules_of(src)


# ---------------------------------------------------------------------------
# PK002 — BlockSpec/grid contract mismatches


def test_pk002_index_map_arity_mismatch():
    src = """
    from jax.experimental import pallas as pl

    from repro.kernels.common import pallas_call_kwargs

    def launch(kernel, x):
        spec = pl.BlockSpec((128, 128), lambda i: (i, 0))
        return pl.pallas_call(
            kernel, grid=(4, 4), in_specs=[spec], out_specs=spec,
            **pallas_call_kwargs(None, None))(x)
    """
    assert "PK002" in rules_of(src)


def test_pk002_matching_contract_is_clean():
    src = """
    from jax.experimental import pallas as pl

    from repro.kernels.common import pallas_call_kwargs

    def launch(kernel, x):
        spec = pl.BlockSpec((128, 128), lambda i, j: (i, j))
        return pl.pallas_call(
            kernel, grid=(4, 4), in_specs=[spec], out_specs=spec,
            **pallas_call_kwargs(None, None))(x)
    """
    assert "PK002" not in rules_of(src)


# ---------------------------------------------------------------------------
# PK003 — static VMEM footprint vs the modeled budget


def test_pk003_oversized_blocks_flagged():
    src = """
    from jax.experimental import pallas as pl

    from repro.kernels.common import pallas_call_kwargs

    def launch(kernel, x, bn=8192, bc=8192):
        spec = pl.BlockSpec((bn, bc), lambda i, j: (i, j))
        return pl.pallas_call(
            kernel, grid=(4, 4), in_specs=[spec], out_specs=spec,
            **pallas_call_kwargs(None, None))(x)
    """
    assert "PK003" in rules_of(src)


def test_pk003_fitting_blocks_clean():
    src = """
    from jax.experimental import pallas as pl

    from repro.kernels.common import pallas_call_kwargs

    def launch(kernel, x, bn=512, bc=256):
        spec = pl.BlockSpec((bn, bc), lambda i, j: (i, j))
        return pl.pallas_call(
            kernel, grid=(4, 4), in_specs=[spec], out_specs=spec,
            **pallas_call_kwargs(None, None))(x)
    """
    assert "PK003" not in rules_of(src)


# ---------------------------------------------------------------------------
# suppressions, TC000 hygiene, baseline round-trip


def test_suppression_with_reason_silences_finding():
    src = """
    import jax.numpy as jnp

    def load(batch):
        return jnp.asarray(batch)  # tracecheck: ignore[TS004]  # raw feed
    """
    assert rules_of(src) == []


def test_suppression_on_comment_line_above_applies_to_next_line():
    src = """
    import jax.numpy as jnp

    def load(batch):
        # tracecheck: ignore[TS004]  # dtype owned by the caller
        return jnp.asarray(batch)
    """
    assert rules_of(src) == []


def test_tc000_suppression_without_reason_flagged():
    src = """
    import jax.numpy as jnp

    def load(batch):
        return jnp.asarray(batch)  # tracecheck: ignore[TS004]
    """
    assert rules_of(src) == ["TC000"]


def test_suppression_for_other_rule_does_not_apply():
    src = """
    import jax.numpy as jnp

    def load(batch):
        return jnp.asarray(batch)  # tracecheck: ignore[TS001]  # wrong id
    """
    assert "TS004" in rules_of(src)


def test_baseline_round_trip(tmp_path):
    src = textwrap.dedent("""
    import jax.numpy as jnp

    def load(batch):
        return jnp.asarray(batch)
    """)
    findings = tc.analyze_source(src, path="pkg/mod.py")
    assert findings
    bl = tmp_path / "baseline.json"
    tc.write_baseline(findings, bl)
    fingerprints = tc.load_baseline(bl)
    assert {f.fingerprint for f in findings} <= fingerprints
    # a baselined finding no longer counts as new
    assert [f for f in findings if f.fingerprint not in fingerprints] == []
    # fingerprints are line-content based: pure line drift doesn't churn
    drifted = tc.analyze_source("\n\n" + src, path="pkg/mod.py")
    assert {f.fingerprint for f in drifted} <= fingerprints


def test_committed_baseline_is_empty():
    data = json.loads(
        (ROOT / "tools" / "tracecheck_baseline.json").read_text())
    assert data["findings"] == []


def test_repo_wide_sweep_is_clean():
    modules = tc.load_modules()
    assert len(modules) > 50  # src + benchmarks + tools really scanned
    findings, _suppressed = tc.analyze_modules(modules)
    baseline = tc.load_baseline()
    new = [f for f in findings if f.fingerprint not in baseline]
    assert new == [], "\n".join(
        f"{f.path}:{f.line}: {f.rule} {f.message}" for f in new)


def test_rule_registry_complete():
    # >= 8 rules shipped, each with severity and title
    assert len([r for r in tc.RULES if r != "TC000"]) >= 8
    for rule, (severity, title) in tc.RULES.items():
        assert severity in ("error", "warning")
        assert title
