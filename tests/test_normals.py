"""Normal-estimation subsystem: accuracy on analytic surfaces, masking,
orientation, XLA/Pallas parity, and batch vmapping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.collate import PAD_SENTINEL
from repro.data.normals import (NormalParams, estimate_normals,
                                estimate_normals_batch, moments_to_normals,
                                orient_normals)
from repro.kernels.normals import estimate_normals_pallas

GRID = dict(voxel_size=1.0, grid_dims=(32, 32, 16), chunk=512)


def _plane_cloud(n=2500, seed=0, normal=(0.3, -0.2, 1.0), z0=4.0,
                 noise=0.01):
    """Points on the plane n·x = n_z*z0, in a 20 m square patch."""
    rng = np.random.default_rng(seed)
    nv = np.asarray(normal, np.float64)
    nv = nv / np.linalg.norm(nv)
    xy = rng.uniform(-10, 10, (n, 2))
    # solve n_x x + n_y y + n_z z = n_z z0 for z
    z = z0 - (nv[0] * xy[:, 0] + nv[1] * xy[:, 1]) / nv[2]
    pts = np.column_stack([xy, z]) + rng.normal(0, noise, (n, 3))
    return pts.astype(np.float32), nv.astype(np.float32)


@pytest.mark.parametrize("neighborhood", ["knn", "radius"])
def test_plane_normals(neighborhood):
    pts, n_true = _plane_cloud()
    params = NormalParams(neighborhood=neighborhood, k=16, radius=0.8,
                          **GRID)
    normals, valid = jax.jit(
        lambda p: estimate_normals(p, params))(jnp.asarray(pts))
    normals, valid = np.asarray(normals), np.asarray(valid)
    assert valid.mean() > 0.99
    dots = np.abs(normals[valid] @ n_true)
    assert np.median(dots) > 0.999
    # the tail (sparse patch-edge neighbourhoods) may tilt, but not flip
    assert np.quantile(dots, 0.01) > 0.95
    # unit length wherever valid
    np.testing.assert_allclose(np.linalg.norm(normals[valid], axis=1),
                               1.0, atol=1e-5)


def test_orientation_toward_viewpoint():
    pts, n_true = _plane_cloud(z0=5.0)
    normals, valid = estimate_normals(jnp.asarray(pts), NormalParams(**GRID))
    normals, valid = np.asarray(normals), np.asarray(valid)
    # viewpoint (origin) is below the z0=5 plane: normals must face down,
    # i.e. have negative dot with the +z-ish true normal.
    signed = normals[valid] @ n_true
    assert (signed < 0).mean() > 0.99
    # explicit viewpoint above the plane flips them
    up, _ = estimate_normals(jnp.asarray(pts), NormalParams(**GRID),
                             viewpoint=jnp.asarray([0.0, 0.0, 100.0]))
    signed_up = np.asarray(up)[valid] @ n_true
    assert (signed_up > 0).mean() > 0.99


def test_degenerate_neighborhood_invalid():
    # A straight line: no plane is defined; normals must be masked out.
    t = np.linspace(0, 5, 64, dtype=np.float32)
    line = np.stack([t, 0.3 * t, 0.1 * t], axis=1)
    normals, valid = estimate_normals(
        jnp.asarray(line), NormalParams(k=8, **GRID))
    assert not bool(np.asarray(valid).any())
    np.testing.assert_array_equal(np.asarray(normals), 0.0)


def test_padded_rows_masked():
    pts, _ = _plane_cloud(n=500)
    padded = np.concatenate(
        [pts, np.full((100, 3), PAD_SENTINEL, np.float32)])
    valid = np.concatenate([np.ones(500, bool), np.zeros(100, bool)])
    normals, nvalid = estimate_normals(jnp.asarray(padded),
                                       NormalParams(**GRID),
                                       valid=jnp.asarray(valid))
    nvalid = np.asarray(nvalid)
    assert not nvalid[500:].any()
    np.testing.assert_array_equal(np.asarray(normals)[500:], 0.0)
    # padded rows do not perturb the real rows' normals
    ref, ref_valid = estimate_normals(jnp.asarray(pts), NormalParams(**GRID))
    both = nvalid[:500] & np.asarray(ref_valid)
    np.testing.assert_allclose(np.asarray(normals)[:500][both],
                               np.asarray(ref)[both], atol=1e-4)


def test_pallas_moment_sweep_matches_xla_radius():
    pts, _ = _plane_cloud(n=1500, seed=3)
    params = NormalParams(neighborhood="radius", radius=0.8, **GRID)
    n_x, v_x = estimate_normals(jnp.asarray(pts), params)
    n_p, v_p = jax.jit(
        lambda p: estimate_normals_pallas(p, params, interpret=True))(
            jnp.asarray(pts))
    np.testing.assert_array_equal(np.asarray(v_x), np.asarray(v_p))
    both = np.asarray(v_x)
    np.testing.assert_allclose(np.asarray(n_x)[both], np.asarray(n_p)[both],
                               atol=1e-4)


def test_pallas_requires_radius_mode():
    pts, _ = _plane_cloud(n=200)
    with pytest.raises(ValueError, match="radius-mode"):
        estimate_normals_pallas(jnp.asarray(pts),
                                NormalParams(neighborhood="knn", **GRID))


def test_unknown_neighborhood_raises():
    pts, _ = _plane_cloud(n=200)
    with pytest.raises(ValueError, match="unknown neighborhood"):
        estimate_normals(jnp.asarray(pts),
                         NormalParams(neighborhood="ball", **GRID))


def test_batch_matches_per_frame():
    a, _ = _plane_cloud(n=600, seed=1)
    b, _ = _plane_cloud(n=600, seed=2, normal=(0.0, 0.4, 1.0))
    batch = jnp.stack([jnp.asarray(a), jnp.asarray(b)])
    params = NormalParams(**GRID)
    n_b, v_b = jax.jit(
        lambda x: estimate_normals_batch(x, params))(batch)
    for i, cloud in enumerate([a, b]):
        n_1, v_1 = estimate_normals(jnp.asarray(cloud), params)
        np.testing.assert_array_equal(np.asarray(v_b[i]), np.asarray(v_1))
        np.testing.assert_allclose(np.asarray(n_b[i]), np.asarray(n_1),
                                   atol=1e-5)


def test_moments_epilogue_zero_count():
    # Empty neighbourhoods must come back invalid with zero normals, not NaN.
    cnt = jnp.zeros((4,))
    s = jnp.zeros((4, 3))
    ss = jnp.zeros((4, 3, 3))
    normals, valid = moments_to_normals(cnt, s, ss)
    assert not bool(valid.any())
    np.testing.assert_array_equal(np.asarray(normals), 0.0)


def test_orient_normals_identity_when_aligned():
    pts = jnp.asarray([[0.0, 0.0, -1.0]])
    n = jnp.asarray([[0.0, 0.0, 1.0]])  # already faces origin from below
    np.testing.assert_array_equal(np.asarray(orient_normals(pts, n)),
                                  np.asarray(n))
