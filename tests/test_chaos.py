"""Chaos suite (marker: ``chaos``): every fault family of the robustness
matrix streamed through the recovery cascade.

These are survival tests, not quality tests — the >=2x improvement
contract lives in ``benchmarks/robustness.py`` and its regression guard.
Here each family only has to keep the *invariants* that make the cascade
safe to ship: finite poses whatever the sensor emits, quarantine
accounting that adds up, and bit-identical replays at a fixed seed.
"""
import numpy as np
import pytest

from benchmarks.common import QUICK_SCENE
from benchmarks.robustness import FAULT_MATRIX, ROBUST_CONFIG
from repro.core.health import VERDICTS
from repro.core.odometry import OdometryPipeline
from repro.data.corruption import apply_faults, parse_fault_spec
from repro.data.pointcloud import sequence_scans

pytestmark = pytest.mark.chaos

FRAMES = 6
BURST = (3, 4)
CHAOS_CONFIG = ROBUST_CONFIG._replace(
    params=ROBUST_CONFIG.params._replace(max_iterations=12))


def _stream(spec_str: str, seed: int = 0) -> OdometryPipeline:
    scans = sequence_scans(2, FRAMES, QUICK_SCENE)
    spec = parse_fault_spec(spec_str)
    pipe = OdometryPipeline(CHAOS_CONFIG)
    for f, scan in enumerate(scans):
        if f in BURST:
            pts, valid = apply_faults(scan, spec, seed=seed, frame=f)
        else:
            pts, valid = scan, None
        pipe.process(pts, valid=valid)
    return pipe


@pytest.mark.parametrize("family", sorted(FAULT_MATRIX))
def test_family_stream_survives(family):
    pipe = _stream(FAULT_MATRIX[family])
    poses = np.stack(pipe.poses)
    assert np.all(np.isfinite(poses)), f"{family}: non-finite pose escaped"
    # every processed frame got exactly one verdict, and the sticky
    # counters stay consistent with the per-frame diagnostics
    health = pipe.health_counts()
    assert set(health) == set(VERDICTS)
    assert sum(health.values()) == len(pipe.diagnostics)
    assert pipe.quarantined_count == sum(d.quarantined
                                         for d in pipe.diagnostics)
    assert pipe.recovery_count == sum(d.recovery_tier > 0
                                      for d in pipe.diagnostics)


@pytest.mark.parametrize("family", ("crop", "drop"))
def test_family_stream_is_deterministic(family):
    a = _stream(FAULT_MATRIX[family], seed=7)
    b = _stream(FAULT_MATRIX[family], seed=7)
    np.testing.assert_array_equal(np.stack(a.poses), np.stack(b.poses))
    assert [d.recovery_tier for d in a.diagnostics] == \
           [d.recovery_tier for d in b.diagnostics]


def test_stacked_faults_survive():
    # the composable worst case: sector blackout + dropout + NaN rows
    pipe = _stream("occlusion:120deg,dropout:0.5,nan:32")
    assert np.all(np.isfinite(np.stack(pipe.poses)))
