"""svd3x3: reconstruction, orthogonality, singular-value parity, degeneracy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import hnp, hypothesis, st
from repro.core.svd3x3 import svd3x3, svd3x3_batched

DEGENERATE = [
    np.zeros((3, 3)),
    np.ones((3, 3)),
    np.diag([2.0, 1.0, 0.0]),
    np.diag([1.0, 1.0, 1.0]),
    -np.eye(3),
    np.array([[1.0, 1.0, 0.0], [1.0, 1.0, 0.0], [0.0, 0.0, 0.0]]),
    np.diag([1e-20, 1e-20, 1e-20]),
    np.diag([1e4, 1e-4, 1e-8]),
]


def _check(M, atol=2e-5):
    M = jnp.asarray(M, jnp.float32)
    U, S, Vt = svd3x3(M)
    scale = max(float(jnp.max(jnp.abs(M))), 1.0)
    np.testing.assert_allclose(np.asarray(U @ jnp.diag(S) @ Vt), np.asarray(M),
                               atol=atol * scale)
    np.testing.assert_allclose(np.asarray(U @ U.T), np.eye(3), atol=atol)
    np.testing.assert_allclose(np.asarray(Vt @ Vt.T), np.eye(3), atol=atol)
    S_ref = jnp.linalg.svd(M, compute_uv=False)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref),
                               atol=atol * scale)
    assert bool(jnp.all(S >= 0)) and bool(jnp.all(S[:-1] >= S[1:]))


@pytest.mark.parametrize("i", range(len(DEGENERATE)))
def test_degenerate(i):
    _check(DEGENERATE[i])


def test_random_batch():
    key = jax.random.PRNGKey(3)
    Ms = jax.random.normal(key, (64, 3, 3))
    U, S, Vt = svd3x3_batched(Ms)
    rec = jnp.einsum("bij,bj,bjk->bik", U, S, Vt)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(Ms), atol=5e-5)


@hypothesis.given(hnp.arrays(np.float32, (3, 3),
                             elements=st.floats(-100, 100, width=32)))
@hypothesis.settings(max_examples=150, deadline=None)
def test_property_reconstruction(M):
    _check(M, atol=5e-5)


def test_jit_and_grad_safe():
    # svd3x3 must be jittable (used inside the ICP while_loop).
    f = jax.jit(svd3x3)
    U, S, Vt = f(jnp.eye(3) * 2.0)
    np.testing.assert_allclose(np.asarray(S), [2.0, 2.0, 2.0], atol=1e-6)
