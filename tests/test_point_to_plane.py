"""Point-to-plane / robust ICP: solver correctness, parity with the
point-to-point minimiser, iteration savings on planar scenes, robust
kernels, and threading through every engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ICPParams, get_engine, icp, icp_batch
from repro.core.point_to_plane import (point_to_plane_rmse, robust_weights,
                                       solve_point_to_plane)
from repro.core.transform import (random_rigid_transform,
                                  rotation_from_axis_angle,
                                  transform_points)
from repro.data.collate import collate_pairs
from repro.data.normals import NormalParams, estimate_normals


def _structured_scene(seed=0, n_ground=4000, n_wall=2500):
    """Ground plane + two orthogonal walls (sensor-frame-ish, planar)."""
    rng = np.random.default_rng(seed)
    gxy = rng.uniform(-20, 20, (n_ground, 2))
    ground = np.column_stack([gxy, 0.02 * np.sin(0.1 * gxy[:, 0])])
    wy = rng.uniform(-20, 20, n_wall // 2)
    wz = rng.uniform(0, 5, n_wall // 2)
    wall1 = np.column_stack([np.full(n_wall // 2, 8.0), wy, wz])
    wall2 = np.column_stack([wy, np.full(n_wall // 2, -7.0), wz])
    pts = np.concatenate([ground, wall1, wall2]).astype(np.float32)
    return pts + rng.normal(0, 0.01, pts.shape).astype(np.float32)


def _perturbed_pair(dst, mag=0.5, angle=0.04, n_src=2500, seed=0,
                    noise=0.01):
    rng = np.random.default_rng(seed)
    R = np.asarray(rotation_from_axis_angle(
        jnp.asarray([0.1, 0.2, 1.0], jnp.float32),
        jnp.asarray(angle, jnp.float32)))
    T_gt = np.eye(4, dtype=np.float32)
    T_gt[:3, :3] = R
    T_gt[:3, 3] = [mag * 0.8, mag * 0.6, 0.05]
    sel = rng.choice(dst.shape[0], n_src, replace=False)
    src = np.asarray(transform_points(
        jnp.linalg.inv(jnp.asarray(T_gt)), jnp.asarray(dst[sel]))).copy()
    src += rng.normal(0, noise, src.shape).astype(np.float32)
    return src, T_gt


# -- robust kernels ----------------------------------------------------------

def test_robust_weight_values():
    r = jnp.asarray([0.0, 0.1, 0.5, 1.0, 2.0])
    np.testing.assert_array_equal(np.asarray(robust_weights(r, "none", 0.5)),
                                  1.0)
    h = np.asarray(robust_weights(r, "huber", 0.5))
    np.testing.assert_allclose(h, [1.0, 1.0, 1.0, 0.5, 0.25], atol=1e-6)
    t = np.asarray(robust_weights(r, "tukey", 1.0))
    np.testing.assert_allclose(
        t, [1.0, (1 - 0.01) ** 2, (1 - 0.25) ** 2, 0.0, 0.0], atol=1e-6)
    # kernels are sign-blind
    np.testing.assert_allclose(np.asarray(robust_weights(-r, "huber", 0.5)),
                               h, atol=1e-6)


def test_unknown_robust_kernel_raises():
    with pytest.raises(ValueError, match="unknown robust kernel"):
        robust_weights(jnp.ones(3), "cauchy", 0.5)


def test_unknown_minimizer_raises():
    dst = _structured_scene()
    src, _ = _perturbed_pair(dst, n_src=200)
    with pytest.raises(ValueError, match="unknown minimizer"):
        icp(jnp.asarray(src), jnp.asarray(dst),
            ICPParams(minimizer="least_squares"))


# -- solver ------------------------------------------------------------------

def _exact_pair(dst, mag=0.05, angle=0.01):
    """Row-aligned exact correspondences: src[i] maps onto dst[i]."""
    R = np.asarray(rotation_from_axis_angle(
        jnp.asarray([0.1, 0.2, 1.0], jnp.float32),
        jnp.asarray(angle, jnp.float32)))
    T_gt = np.eye(4, dtype=np.float32)
    T_gt[:3, :3] = R
    T_gt[:3, 3] = [mag * 0.8, mag * 0.6, 0.05]
    src = np.asarray(transform_points(
        jnp.linalg.inv(jnp.asarray(T_gt)), jnp.asarray(dst)))
    return src, T_gt


def test_solver_recovers_small_transform():
    """Perfect correspondences + true normals: one Gauss-Newton step lands
    on the ground-truth transform (the objective is exactly quadratic for
    noiseless planar residuals in the small-angle regime)."""
    dst = _structured_scene(seed=1)
    normals, nvalid = estimate_normals(
        jnp.asarray(dst), NormalParams(grid_dims=(64, 64, 16)))
    src, T_gt = _exact_pair(dst)
    T = solve_point_to_plane(jnp.asarray(src), jnp.asarray(dst), normals,
                             nvalid.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(T), T_gt, atol=5e-4)
    rmse_after = point_to_plane_rmse(
        transform_points(T, jnp.asarray(src)), jnp.asarray(dst), normals,
        nvalid.astype(jnp.float32))
    assert float(rmse_after) < 5e-4


def test_zero_normals_are_ignored():
    """Zero-normal rows (invalid estimates) contribute nothing."""
    dst = _structured_scene(seed=2)
    normals, nvalid = estimate_normals(
        jnp.asarray(dst), NormalParams(grid_dims=(64, 64, 16)))
    del nvalid  # exercised by the explicit-kill path below
    src, T_gt = _exact_pair(dst)
    T_ref = solve_point_to_plane(jnp.asarray(src), jnp.asarray(dst), normals)
    # zero out a chunk of normals explicitly: same answer as zero weights
    kill = np.zeros(dst.shape[0], bool)
    kill[::7] = True
    normals_killed = jnp.where(jnp.asarray(kill)[:, None], 0.0, normals)
    w = jnp.asarray(~kill, jnp.float32)
    T_w = solve_point_to_plane(jnp.asarray(src), jnp.asarray(dst), normals,
                               w)
    T_k = solve_point_to_plane(jnp.asarray(src), jnp.asarray(dst),
                               normals_killed)
    np.testing.assert_allclose(np.asarray(T_k), np.asarray(T_w), atol=1e-5)
    np.testing.assert_allclose(np.asarray(T_ref), T_gt, atol=5e-4)


# -- end-to-end ICP ----------------------------------------------------------

def test_p2plane_matches_p2p_and_converges_faster():
    """The ISSUE-3 acceptance pair: same fixed point (rot/trans <= 1e-3),
    >= 2x fewer iterations on a planar-dominant scene."""
    dst = _structured_scene()
    src, T_gt = _perturbed_pair(dst, mag=0.6)
    params = ICPParams(max_iterations=80, transformation_epsilon=1e-6)
    r_pp = jax.jit(lambda s, d: icp(s, d, params))(
        jnp.asarray(src), jnp.asarray(dst))
    r_pl = jax.jit(lambda s, d: icp(
        s, d, params._replace(minimizer="point_to_plane")))(
            jnp.asarray(src), jnp.asarray(dst))
    T_pp, T_pl = np.asarray(r_pp.T), np.asarray(r_pl.T)
    assert np.linalg.norm(T_pp[:3, :3] - T_pl[:3, :3]) <= 1e-3
    assert np.linalg.norm(T_pp[:3, 3] - T_pl[:3, 3]) <= 1e-3
    np.testing.assert_allclose(T_pl, T_gt, atol=0.02)
    assert bool(r_pl.converged) and bool(r_pp.converged)
    assert int(r_pp.iterations) >= 2 * int(r_pl.iterations)


def test_explicit_normals_match_auto():
    dst = _structured_scene(seed=3)
    src, _ = _perturbed_pair(dst, mag=0.3, seed=3)
    params = ICPParams(max_iterations=30, transformation_epsilon=1e-6,
                       minimizer="point_to_plane")
    normals, _ = estimate_normals(jnp.asarray(dst), NormalParams())
    r_auto = icp(jnp.asarray(src), jnp.asarray(dst), params)
    r_expl = icp(jnp.asarray(src), jnp.asarray(dst), params,
                 target_normals=normals)
    np.testing.assert_allclose(np.asarray(r_auto.T), np.asarray(r_expl.T),
                               atol=1e-6)


def test_correspond_fn_without_normals_raises():
    dst = _structured_scene(seed=4)
    src, _ = _perturbed_pair(dst, n_src=500, seed=4)

    def correspond(src_t):  # 2-tuple: no normals channel
        from repro.core.nn_search import nn_search
        d2, _, pts = nn_search(src_t, jnp.asarray(dst), return_points=True)
        return d2, pts

    with pytest.raises(ValueError, match="matched normals"):
        icp(jnp.asarray(src), None,
            ICPParams(minimizer="point_to_plane", max_iterations=2),
            correspond_fn=correspond)


def test_robust_kernels_resist_outliers():
    """Gross in-gate outliers bias the plain minimiser; IRLS reweighting
    recovers the clean transform."""
    dst = _structured_scene(seed=5)
    src, T_gt = _perturbed_pair(dst, mag=0.1, angle=0.02, seed=5)
    rng = np.random.default_rng(5)
    # contaminate 20% of the source with 0.5 m offsets (inside the 1 m gate)
    n_out = src.shape[0] // 5
    idx = rng.choice(src.shape[0], n_out, replace=False)
    src_dirty = src.copy()
    src_dirty[idx] += (rng.normal(0, 0.3, (n_out, 3))
                       .astype(np.float32) + 0.3)
    params = ICPParams(max_iterations=40, transformation_epsilon=1e-6)
    errs = {}
    for kind in ("none", "huber", "tukey"):
        p = params._replace(robust_kernel=kind, robust_scale=0.1)
        res = jax.jit(lambda s, d, p=p: icp(s, d, p))(
            jnp.asarray(src_dirty), jnp.asarray(dst))
        errs[kind] = float(np.linalg.norm(
            np.asarray(res.T)[:3, 3] - T_gt[:3, 3]))
    assert errs["huber"] < errs["none"]
    assert errs["tukey"] < errs["none"]
    assert errs["tukey"] < 0.03


# -- engines -----------------------------------------------------------------

PLANE_PARAMS = ICPParams(max_iterations=15, chunk=256,
                         minimizer="point_to_plane")


def _rand_pair(key, n=200, m=320):
    k1, k2, k3 = jax.random.split(key, 3)
    dst = jax.random.uniform(k1, (m, 3), minval=-10, maxval=10)
    T_gt = random_rigid_transform(k2, max_angle=0.1, max_translation=0.3)
    src = transform_points(jnp.linalg.inv(T_gt), dst)[:n]
    src = src + 0.002 * jax.random.normal(k3, src.shape)
    return np.asarray(src), np.asarray(dst), np.asarray(T_gt)


@pytest.mark.parametrize("engine_kwargs", [
    dict(spec="xla"),
    dict(spec="pallas", bn=64, bm=128),
    dict(spec="distributed"),
    dict(spec="pyramid"),
])
def test_engines_p2plane_batch_matches_single(engine_kwargs):
    """Mixed-size plane-minimiser batches must match the unpadded per-pair
    run on every engine (normals estimated from true valid masks)."""
    kwargs = dict(engine_kwargs)
    spec = kwargs.pop("spec")
    sizes = [(180, 300), (150, 260)]
    pairs = [_rand_pair(k, n=n, m=m) for k, (n, m) in
             zip(jax.random.split(jax.random.PRNGKey(7), len(sizes)),
                 sizes)]
    batch = collate_pairs([(s, d) for s, d, _ in pairs])
    eng = get_engine(spec, chunk=256, **kwargs)
    res = eng.register_batch(batch.src, batch.dst, PLANE_PARAMS,
                             src_valid=batch.src_valid,
                             dst_valid=batch.dst_valid)
    for i, (s, d, T_gt) in enumerate(pairs):
        single = icp(jnp.asarray(s), jnp.asarray(d), PLANE_PARAMS)
        np.testing.assert_allclose(np.asarray(res.T[i]),
                                   np.asarray(single.T), atol=1e-4)
        np.testing.assert_allclose(np.asarray(res.T[i]), T_gt, atol=0.05)


def test_icp_batch_p2plane_matches_per_pair():
    pairs = [_rand_pair(k) for k in
             jax.random.split(jax.random.PRNGKey(8), 3)]
    src_b = jnp.stack([jnp.asarray(s) for s, _, _ in pairs])
    dst_b = jnp.stack([jnp.asarray(d) for _, d, _ in pairs])
    res = icp_batch(src_b, dst_b, PLANE_PARAMS)
    for i, (s, d, _) in enumerate(pairs):
        single = icp(jnp.asarray(s), jnp.asarray(d), PLANE_PARAMS)
        np.testing.assert_allclose(np.asarray(res.T[i]),
                                   np.asarray(single.T), atol=1e-4)


def test_fpps_api_minimizer_setters():
    from repro.core import FppsICP
    reg = FppsICP(chunk=256)
    reg.setMinimizer("point_to_plane")
    reg.setRobustKernel("huber", 0.3)
    assert reg._params().minimizer == "point_to_plane"
    assert reg._params().robust_kernel == "huber"
    assert reg._params().robust_scale == 0.3
    with pytest.raises(ValueError, match="unknown minimizer"):
        reg.setMinimizer("p2pl")
    with pytest.raises(ValueError, match="unknown robust kernel"):
        reg.setRobustKernel("cauchy")
    src, dst, T_gt = _rand_pair(jax.random.PRNGKey(9))
    reg.setInputSource(src)
    reg.setInputTarget(dst)
    reg.setMaxIterationCount(15)
    T = reg.align()
    np.testing.assert_allclose(T, T_gt, atol=0.05)
