"""Per-arch smoke tests (deliverable f): reduced same-family config, one
forward + one train-grad step on CPU; asserts output shapes and no NaNs.
Also checks prefill/decode consistency against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke, list_archs
from repro.models import lm

ARCHS = list_archs()


def _batch(cfg, key, B=2, S=32):
    kt, ke, kl = jax.random.split(key, 3)
    batch = {"labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size)}
    if cfg.embed_inputs:
        batch["tokens"] = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    else:
        batch["embeds"] = jax.random.normal(ke, (B, S, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, aux = lm.forward(params, cfg, tokens=batch.get("tokens"),
                             embeds=batch.get("embeds"))
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss, metrics = lm.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree_util.tree_leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = lm.init_params(key, cfg)
    batch = _batch(cfg, key)
    S_pre = 24
    kw = ({"tokens": batch["tokens"][:, :S_pre]} if cfg.embed_inputs
          else {"embeds": batch["embeds"][:, :S_pre]})
    lg_pre, cache = lm.prefill(params, cfg, max_len=32, **kw)
    kw1 = ({"token": batch["tokens"][:, S_pre]} if cfg.embed_inputs
           else {"embed": batch["embeds"][:, S_pre]})
    lg_dec, cache = lm.decode_step(params, cfg, jnp.asarray(S_pre), cache, **kw1)
    kw_full = ({"tokens": batch["tokens"][:, :S_pre + 1]} if cfg.embed_inputs
               else {"embeds": batch["embeds"][:, :S_pre + 1]})
    full, _ = lm.forward(params, cfg, **kw_full)
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(full[:, S_pre - 1]),
                               atol=1e-4)
    # decode tolerance: bf16 latent cache (MLA) and capacity-drop asymmetry
    # (MoE train path drops over-capacity tokens; 1-token decode cannot).
    tol = 5e-2 if cfg.ffn == "moe" else 1e-2
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(full[:, S_pre]),
                               atol=tol)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_is_well_formed(arch):
    cfg = get_config(arch)
    assert cfg.n_layers >= 1 and cfg.d_model >= 1 and cfg.vocab_size >= 1
    if cfg.n_heads:
        assert cfg.n_heads % max(cfg.n_kv_heads, 1) == 0
    kinds = cfg.layer_kinds
    assert len(kinds) == cfg.n_layers


def test_param_counts_match_published():
    expected = {  # billions, tolerance band
        "granite-34b": (34.0, 1.5), "llama3-405b": (405.0, 3.0),
        "qwen2-0.5b": (0.49, 0.05), "minicpm3-4b": (4.1, 0.4),
        "chameleon-34b": (34.0, 1.5), "recurrentgemma-9b": (8.5, 1.2),
        "mamba2-780m": (0.78, 0.05), "deepseek-moe-16b": (16.4, 0.5),
        "qwen3-moe-235b-a22b": (235.0, 3.0), "musicgen-medium": (1.5, 0.25),
    }
    for arch, (target, tol) in expected.items():
        got = get_config(arch).total_params() / 1e9
        assert abs(got - target) <= tol, (arch, got, target)


def test_moe_active_params():
    c = get_config("qwen3-moe-235b-a22b")
    assert 20.0 < c.active_params() / 1e9 < 24.0
    c = get_config("deepseek-moe-16b")
    assert 2.2 < c.active_params() / 1e9 < 3.3
