"""Registration-health verdicts (core/health.py)."""
import numpy as np
import pytest

from repro.core.health import (FAILED, OK, SUSPECT, HealthThresholds,
                               assess_registration, normal_equation_condition,
                               plane_normal_matrix, pose_jump)
from repro.core.transform import make_transform, rotation_from_axis_angle


class FakeResult:
    """ICPResult-shaped bag for driving the assessor directly."""

    def __init__(self, T=None, rmse=0.05, inlier_frac=0.9, degenerate=False):
        self.T = np.eye(4) if T is None else T
        self.rmse = rmse
        self.inlier_frac = inlier_frac
        self.degenerate = degenerate


def test_clean_result_is_ok():
    h = assess_registration(FakeResult(), predicted=np.eye(4))
    assert h.verdict == OK
    assert h.ok
    assert h.reasons == ()


def test_low_inlier_frac_tiers():
    sus = assess_registration(FakeResult(inlier_frac=0.15))
    bad = assess_registration(FakeResult(inlier_frac=0.05))
    assert sus.verdict == SUSPECT and "inlier_frac:suspect" in sus.reasons
    assert bad.verdict == FAILED and "inlier_frac:failed" in bad.reasons


def test_high_rmse_tiers():
    assert assess_registration(FakeResult(rmse=0.8)).verdict == SUSPECT
    assert assess_registration(FakeResult(rmse=5.0)).verdict == FAILED


def test_degenerate_always_fails():
    h = assess_registration(FakeResult(degenerate=True, rmse=float("inf")))
    assert h.verdict == FAILED
    assert "degenerate:failed" in h.reasons


def test_nonfinite_pose_fails():
    T = np.eye(4)
    T[0, 3] = np.nan
    h = assess_registration(FakeResult(T=T))
    assert h.verdict == FAILED
    assert "nonfinite_pose:failed" in h.reasons


def test_pose_jump_vs_prediction():
    T = make_transform(np.eye(3), np.array([2.0, 0.0, 0.0]))
    h = assess_registration(FakeResult(T=np.asarray(T)),
                            predicted=np.eye(4))
    assert h.verdict == SUSPECT
    assert h.pose_jump_m == pytest.approx(2.0)
    far = make_transform(np.eye(3), np.array([10.0, 0.0, 0.0]))
    assert assess_registration(FakeResult(T=np.asarray(far)),
                               predicted=np.eye(4)).verdict == FAILED


def test_rot_jump_vs_prediction():
    R = np.asarray(rotation_from_axis_angle(np.array([0.0, 0.0, 1.0]), 0.3))
    T = np.asarray(make_transform(R, np.zeros(3)))
    h = assess_registration(FakeResult(T=T), predicted=np.eye(4))
    assert h.verdict == SUSPECT
    assert h.rot_jump_rad == pytest.approx(0.3, abs=1e-6)


def test_no_prediction_skips_jump_signals():
    T = np.asarray(make_transform(np.eye(3), np.array([50.0, 0.0, 0.0])))
    assert assess_registration(FakeResult(T=T)).verdict == OK


def test_out_of_lattice_signal():
    assert assess_registration(FakeResult(),
                               out_of_lattice=0.3).verdict == SUSPECT
    assert assess_registration(FakeResult(),
                               out_of_lattice=0.9).verdict == FAILED
    assert assess_registration(FakeResult(),
                               out_of_lattice=0.1).verdict == OK


def test_condition_signal():
    assert assess_registration(FakeResult(), condition=1e3).verdict == OK
    assert assess_registration(FakeResult(), condition=1e4).verdict == SUSPECT
    # degradation-only by default: even a collapsed normal system never
    # hard-fails a frame (point-to-point can still register it)
    assert assess_registration(FakeResult(), condition=1e30).verdict == SUSPECT
    strict = HealthThresholds(failed_condition=1e8)
    assert assess_registration(FakeResult(), condition=1e9,
                               thresholds=strict).verdict == FAILED


def test_custom_thresholds():
    strict = HealthThresholds(suspect_rmse=0.01, failed_rmse=0.02)
    assert assess_registration(FakeResult(rmse=0.05),
                               thresholds=strict).verdict == FAILED


def test_worst_signal_wins():
    h = assess_registration(FakeResult(inlier_frac=0.15, rmse=5.0))
    assert h.verdict == FAILED
    assert set(h.reasons) == {"inlier_frac:suspect", "rmse:failed"}


def test_pose_jump_helper():
    T = np.asarray(make_transform(
        np.asarray(rotation_from_axis_angle(np.array([1.0, 0.0, 0.0]), 0.5)),
        np.array([3.0, 4.0, 0.0])))
    dt, dr = pose_jump(T, np.eye(4))
    assert dt == pytest.approx(5.0)
    assert dr == pytest.approx(0.5, abs=1e-6)


def test_condition_of_well_observed_scene():
    rng = np.random.default_rng(0)
    pts = rng.uniform(-5, 5, size=(512, 3))
    normals = rng.normal(size=(512, 3))
    normals /= np.linalg.norm(normals, axis=-1, keepdims=True)
    cond = normal_equation_condition(plane_normal_matrix(pts, normals))
    assert cond < 1e3


def test_condition_of_planar_scene_is_degenerate():
    rng = np.random.default_rng(1)
    pts = rng.uniform(-5, 5, size=(512, 3))
    pts[:, 2] = 0.0                       # flat ground, normals all +z
    normals = np.tile(np.array([0.0, 0.0, 1.0]), (512, 1))
    cond = normal_equation_condition(plane_normal_matrix(pts, normals))
    assert cond > 1e6                     # x/y translation + yaw unobserved


def test_plane_normal_matrix_respects_valid_mask():
    rng = np.random.default_rng(2)
    pts = rng.normal(size=(64, 3))
    normals = rng.normal(size=(64, 3))
    valid = np.zeros(64, bool)
    valid[:16] = True
    A = plane_normal_matrix(pts, normals, valid)
    A_ref = plane_normal_matrix(pts[:16], normals[:16])
    np.testing.assert_allclose(A, A_ref, rtol=1e-12)
