"""Optional-hypothesis shim (ISSUE 1 satellite).

``hypothesis`` is an optional dependency of the test suite: property-based
tests use it, deterministic tests don't. Importing it unconditionally made
*collection* fail on hosts without it, killing whole modules' deterministic
coverage. This shim degrades gracefully: when hypothesis is absent the
``@hypothesis.given(...)`` decorator turns into ``pytest.mark.skip``, so the
property tests show up as skipped and everything else still runs.

Usage in a test module::

    from _hypothesis_compat import hypothesis, st      # (+ hnp if needed)
"""
from __future__ import annotations

try:
    import hypothesis
    import hypothesis.strategies as st
    try:
        import hypothesis.extra.numpy as hnp
    except ImportError:  # pragma: no cover - hypothesis without numpy extra
        hnp = None
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    class _StrategyStub:
        """Any ``st.foo(...)`` / ``hnp.foo(...)`` call returns a placeholder;
        the enclosing test is skipped before the value is ever used."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    class _HypothesisStub:
        @staticmethod
        def given(*args, **kwargs):
            return pytest.mark.skip(reason="hypothesis not installed")

        @staticmethod
        def settings(*args, **kwargs):
            return lambda fn: fn

    hypothesis = _HypothesisStub()
    st = _StrategyStub()
    hnp = _StrategyStub()
    HAVE_HYPOTHESIS = False
