"""SSD (Mamba-2) and RG-LRU unit tests: chunked == naive recurrence,
streaming == full, padding exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import hypothesis, st
from repro.models.ssm import (RGLRUConfig, SSMConfig, mamba2_decode_step,
                              mamba2_forward, mamba2_init, mamba2_init_state,
                              rglru_block_forward, rglru_block_init,
                              rglru_init_state, ssd_chunked, ssd_naive)


def _ssd_inputs(key, b=2, s=256, h=4, p=16, n=8):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    return x, dt, A, B, C


@pytest.mark.parametrize("chunk", [32, 64, 256])
def test_ssd_chunked_matches_naive(chunk):
    x, dt, A, B, C = _ssd_inputs(jax.random.PRNGKey(0))
    y_c, st_c = ssd_chunked(x, dt, A, B, C, chunk)
    y_n, st_n = ssd_naive(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_n),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_n),
                               rtol=1e-3, atol=1e-4)


def test_ssd_initial_state():
    x, dt, A, B, C = _ssd_inputs(jax.random.PRNGKey(1), s=128)
    st0 = jax.random.normal(jax.random.PRNGKey(7), (2, 4, 16, 8))
    y_c, f_c = ssd_chunked(x, dt, A, B, C, 32, initial_state=st0)
    y_n, f_n = ssd_naive(x, dt, A, B, C, initial_state=st0)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_n),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(f_c), np.asarray(f_n),
                               rtol=1e-3, atol=1e-4)


@hypothesis.given(st.integers(0, 10_000), st.integers(1, 64))
@hypothesis.settings(max_examples=15, deadline=None)
def test_property_ssd_chunk_invariance(seed, s_mult):
    """Output must not depend on chunk size (property over random shapes)."""
    s = 8 * ((s_mult % 8) + 1)
    x, dt, A, B, C = _ssd_inputs(jax.random.PRNGKey(seed), b=1, s=s, h=2, p=8,
                                 n=4)
    y1, _ = ssd_chunked(x, dt, A, B, C, chunk=8)
    y2, _ = ssd_chunked(x, dt, A, B, C, chunk=s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=1e-3)


def test_mamba2_streaming_matches_full():
    cfg = SSMConfig(d_model=64, d_state=16, expand=2, headdim=16, chunk=32)
    params = mamba2_init(jax.random.PRNGKey(1), cfg)
    u = jax.random.normal(jax.random.PRNGKey(2), (2, 96, 64)) * 0.5
    full = mamba2_forward(params, u, cfg)
    out, state = mamba2_forward(params, u[:, :64], cfg, return_state=True)
    outs = [out]
    for t in range(64, 96):
        o, state = mamba2_decode_step(params, u[:, t:t + 1], state, cfg)
        outs.append(o)
    stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stream), np.asarray(full),
                               rtol=1e-3, atol=2e-3)


def test_mamba2_ragged_seq_padding_exact():
    """S not divisible by chunk: the dt=0 padding must be a no-op."""
    cfg = SSMConfig(d_model=32, d_state=8, expand=2, headdim=8, chunk=16)
    params = mamba2_init(jax.random.PRNGKey(3), cfg)
    u = jax.random.normal(jax.random.PRNGKey(4), (1, 48, 32))
    base = mamba2_forward(params, u, cfg)                      # 48 % 16 == 0
    ragged = mamba2_forward(params, u[:, :41], cfg)            # 41 % 16 != 0
    np.testing.assert_allclose(np.asarray(ragged), np.asarray(base[:, :41]),
                               rtol=1e-4, atol=1e-4)
    # state must also be exact under padding
    _, (_, st_ragged) = mamba2_forward(params, u[:, :41], cfg,
                                       return_state=True)
    y_n, st_ref = None, None
    from repro.models.ssm import _causal_conv, _split_proj  # noqa: F401
    # reference: run naive over 41 steps via decode loop
    state = mamba2_init_state(1, cfg)
    for t in range(41):
        _, state = mamba2_decode_step(params, u[:, t:t + 1], state, cfg)
    np.testing.assert_allclose(np.asarray(st_ragged), np.asarray(state[1]),
                               rtol=1e-3, atol=1e-3)


def test_rglru_streaming_matches_full():
    cfg = RGLRUConfig(d_model=48, lru_width=64)
    p = rglru_block_init(jax.random.PRNGKey(3), cfg)
    u = jax.random.normal(jax.random.PRNGKey(4), (2, 80, 48)) * 0.5
    full = rglru_block_forward(p, u, cfg)
    out, st = rglru_block_forward(p, u[:, :48], cfg,
                                  state=rglru_init_state(2, cfg),
                                  return_state=True)
    outs = [out]
    for t in range(48, 80):
        o, st = rglru_block_forward(p, u[:, t:t + 1], cfg, state=st,
                                    return_state=True)
        outs.append(o)
    stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stream), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_rglru_decay_bounds():
    """RG-LRU decay a_t must stay in (0, 1) — stability invariant."""
    cfg = RGLRUConfig(d_model=16, lru_width=16)
    p = rglru_block_init(jax.random.PRNGKey(5), cfg)
    lam = p["lambda"].astype(jnp.float32)
    a_max = jnp.exp(-cfg.c * jax.nn.softplus(lam) * 0.0)   # r=0
    a_min = jnp.exp(-cfg.c * jax.nn.softplus(lam) * 1.0)   # r=1
    assert bool(jnp.all(a_max <= 1.0)) and bool(jnp.all(a_min > 0.0))
