"""XLA brute-force NN search vs naive reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import hypothesis, st
from repro.core.nn_search import nn_search, pairwise_sq_dists


def _naive(src, dst):
    d2 = jnp.sum((src[:, None, :] - dst[None, :, :]) ** 2, axis=-1)
    return jnp.min(d2, axis=1), jnp.argmin(d2, axis=1).astype(jnp.int32)


@pytest.mark.parametrize("n,m,chunk", [(64, 256, 64), (100, 999, 128),
                                       (1, 1, 16), (17, 4097, 512)])
def test_matches_naive(n, m, chunk):
    key = jax.random.PRNGKey(n * 1000 + m)
    k1, k2 = jax.random.split(key)
    src = jax.random.uniform(k1, (n, 3), minval=-30, maxval=30)
    dst = jax.random.uniform(k2, (m, 3), minval=-30, maxval=30)
    d2, idx = nn_search(src, dst, chunk=chunk)
    d2_ref, idx_ref = _naive(src, dst)
    # idx can differ on exact fp ties; require the *distances* to match and
    # each returned idx to be a true argmin.
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_ref),
                               rtol=1e-4, atol=1e-4)
    gathered = jnp.sum((src - dst[idx]) ** 2, axis=-1)
    np.testing.assert_allclose(np.asarray(gathered), np.asarray(d2_ref),
                               rtol=1e-4, atol=1e-4)
    assert idx.dtype == jnp.int32
    assert bool(jnp.all((idx >= 0) & (idx < m)))


def test_pairwise_matches_naive():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    src = jax.random.normal(k1, (50, 3))
    dst = jax.random.normal(k2, (70, 3))
    d2 = pairwise_sq_dists(src, dst)
    ref = jnp.sum((src[:, None] - dst[None]) ** 2, -1)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(ref), atol=1e-4)
    assert bool(jnp.all(d2 >= 0))


def test_masked_targets():
    key = jax.random.PRNGKey(5)
    k1, k2 = jax.random.split(key)
    src = jax.random.normal(k1, (32, 3))
    dst = jax.random.normal(k2, (128, 3))
    valid = jnp.arange(128) < 64
    d2, idx = nn_search(src, dst, chunk=32, dst_valid=valid)
    assert bool(jnp.all(idx < 64))
    d2_ref, idx_ref = _naive(src, dst[:64])
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_ref), atol=1e-4)


def test_return_points_fuses_winner_gather():
    """Satellite (fused gather): the optional third output must be exactly
    dst[idx], so ICP can skip its own jnp.take over the target."""
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    src = jax.random.normal(k1, (40, 3))
    dst = jax.random.normal(k2, (300, 3))
    d2, idx, pts = nn_search(src, dst, chunk=64, return_points=True)
    d2_2, idx_2 = nn_search(src, dst, chunk=64)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_2))
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_2), atol=0)
    np.testing.assert_allclose(np.asarray(pts),
                               np.asarray(dst)[np.asarray(idx)], atol=0)


def test_bf16_input_clouds_fp32_carry():
    """Satellite (carry dtype): bf16 input clouds must not break the scan
    carry — the running best_d2 is pinned to fp32."""
    key = jax.random.PRNGKey(11)
    k1, k2 = jax.random.split(key)
    src = jax.random.uniform(k1, (50, 3), minval=-5, maxval=5)
    dst = jax.random.uniform(k2, (400, 3), minval=-5, maxval=5)
    d2_bf, idx_bf = nn_search(src.astype(jnp.bfloat16),
                              dst.astype(jnp.bfloat16), chunk=128)
    assert d2_bf.dtype == jnp.float32
    d2_ref, idx_ref = nn_search(src, dst, chunk=128)
    # bf16 *coordinates* quantize the clouds (~1e-2 relative); indices can
    # only differ where candidates are near-tied at that resolution.
    agree = np.mean(np.asarray(idx_bf) == np.asarray(idx_ref))
    assert agree > 0.8
    # and every returned match is near-optimal in exact fp32 terms (the
    # winner was chosen among ~0.03-quantized coordinates, so allow the
    # corresponding d2 slack around the true optimum)
    gathered = np.sum((np.asarray(src) - np.asarray(dst)[idx_bf]) ** 2, -1)
    assert np.all(gathered <= np.asarray(d2_ref) + 0.5)


# -- score_dtype="bf16" (§Perf A2) ------------------------------------------

def _separated_clouds(seed, n=80, m=400):
    """Clouds whose runner-up d2 gap (lattice spacing² = 64) dwarfs the
    bf16 score quantum (~8-32 at these magnitudes), so bf16 rounding
    cannot flip an argmin. Centred so ||p||² stays small."""
    rng = np.random.default_rng(seed)
    ax = (np.arange(8.0) - 3.5) * 8.0               # 8 m lattice, centred
    grid = np.stack(np.meshgrid(ax, ax, ax), -1).reshape(-1, 3)
    rng.shuffle(grid)
    dst = grid[:m].astype(np.float32)
    src = dst[rng.choice(m, n, replace=False)] + rng.uniform(
        -0.3, 0.3, (n, 3)).astype(np.float32)
    return jnp.asarray(src), jnp.asarray(dst)


def test_bf16_scores_agree_on_separated_points():
    src, dst = _separated_clouds(0)
    d2_32, idx_32 = nn_search(src, dst, chunk=128, score_dtype="fp32")
    d2_16, idx_16 = nn_search(src, dst, chunk=128, score_dtype="bf16")
    np.testing.assert_array_equal(np.asarray(idx_16), np.asarray(idx_32))


def test_bf16_returned_d2_is_exact():
    """The epilogue recomputes winner distances in fp32, so the returned d2
    must be exact even when the ranking ran in bf16."""
    src, dst = _separated_clouds(1)
    d2_16, idx_16 = nn_search(src, dst, chunk=128, score_dtype="bf16")
    direct = jnp.sum((src - dst[idx_16]) ** 2, axis=-1)
    np.testing.assert_allclose(np.asarray(d2_16), np.asarray(direct),
                               rtol=1e-6, atol=1e-7)
    assert d2_16.dtype == jnp.float32


def test_bf16_end_to_end_icp_parity():
    """ICP transform parity between fp32 and bf16 score tiles on a
    synthetic frame pair."""
    from repro.core import ICPParams, icp, random_rigid_transform, \
        transform_points
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    dst = jax.random.uniform(k1, (2000, 3), minval=-10, maxval=10)
    T_gt = random_rigid_transform(k2, max_angle=0.1, max_translation=0.3)
    src = transform_points(jnp.linalg.inv(T_gt), dst)[:500]
    src = src + 0.002 * jax.random.normal(k3, src.shape)
    res32 = icp(src, dst, ICPParams(max_iterations=25, chunk=512))
    res16 = icp(src, dst, ICPParams(max_iterations=25, chunk=512,
                                    score_dtype="bf16"))
    # bf16 can mis-rank near-ties (~1e-2 relative, DESIGN.md §6 A2): the
    # transforms agree to that order, and both recover the ground truth.
    np.testing.assert_allclose(np.asarray(res16.T), np.asarray(res32.T),
                               atol=2e-2)
    np.testing.assert_allclose(np.asarray(res16.T), np.asarray(T_gt),
                               atol=0.05)


@hypothesis.given(st.integers(0, 10_000), st.integers(1, 200),
                  st.integers(1, 500))
@hypothesis.settings(max_examples=25, deadline=None)
def test_property_idx_is_argmin(seed, n, m):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    src = jax.random.uniform(k1, (n, 3), minval=-10, maxval=10)
    dst = jax.random.uniform(k2, (m, 3), minval=-10, maxval=10)
    d2, idx = nn_search(src, dst, chunk=64)
    full = jnp.sum((src[:, None] - dst[None]) ** 2, -1)
    best = jnp.min(full, axis=1)
    gathered = full[jnp.arange(n), idx]
    np.testing.assert_allclose(np.asarray(gathered), np.asarray(best),
                               rtol=1e-4, atol=1e-4)
