"""XLA brute-force NN search vs naive reference."""
from _hypothesis_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.nn_search import nn_search, pairwise_sq_dists


def _naive(src, dst):
    d2 = jnp.sum((src[:, None, :] - dst[None, :, :]) ** 2, axis=-1)
    return jnp.min(d2, axis=1), jnp.argmin(d2, axis=1).astype(jnp.int32)


@pytest.mark.parametrize("n,m,chunk", [(64, 256, 64), (100, 999, 128),
                                       (1, 1, 16), (17, 4097, 512)])
def test_matches_naive(n, m, chunk):
    key = jax.random.PRNGKey(n * 1000 + m)
    k1, k2 = jax.random.split(key)
    src = jax.random.uniform(k1, (n, 3), minval=-30, maxval=30)
    dst = jax.random.uniform(k2, (m, 3), minval=-30, maxval=30)
    d2, idx = nn_search(src, dst, chunk=chunk)
    d2_ref, idx_ref = _naive(src, dst)
    # idx can differ on exact fp ties; require the *distances* to match and
    # each returned idx to be a true argmin.
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_ref),
                               rtol=1e-4, atol=1e-4)
    gathered = jnp.sum((src - dst[idx]) ** 2, axis=-1)
    np.testing.assert_allclose(np.asarray(gathered), np.asarray(d2_ref),
                               rtol=1e-4, atol=1e-4)
    assert idx.dtype == jnp.int32
    assert bool(jnp.all((idx >= 0) & (idx < m)))


def test_pairwise_matches_naive():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    src = jax.random.normal(k1, (50, 3))
    dst = jax.random.normal(k2, (70, 3))
    d2 = pairwise_sq_dists(src, dst)
    ref = jnp.sum((src[:, None] - dst[None]) ** 2, -1)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(ref), atol=1e-4)
    assert bool(jnp.all(d2 >= 0))


def test_masked_targets():
    key = jax.random.PRNGKey(5)
    k1, k2 = jax.random.split(key)
    src = jax.random.normal(k1, (32, 3))
    dst = jax.random.normal(k2, (128, 3))
    valid = jnp.arange(128) < 64
    d2, idx = nn_search(src, dst, chunk=32, dst_valid=valid)
    assert bool(jnp.all(idx < 64))
    d2_ref, idx_ref = _naive(src, dst[:64])
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_ref), atol=1e-4)


@hypothesis.given(st.integers(0, 10_000), st.integers(1, 200),
                  st.integers(1, 500))
@hypothesis.settings(max_examples=25, deadline=None)
def test_property_idx_is_argmin(seed, n, m):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    src = jax.random.uniform(k1, (n, 3), minval=-10, maxval=10)
    dst = jax.random.uniform(k2, (m, 3), minval=-10, maxval=10)
    d2, idx = nn_search(src, dst, chunk=64)
    full = jnp.sum((src[:, None] - dst[None]) ** 2, -1)
    best = jnp.min(full, axis=1)
    gathered = full[jnp.arange(n), idx]
    np.testing.assert_allclose(np.asarray(gathered), np.asarray(best),
                               rtol=1e-4, atol=1e-4)
