"""Property-style tests for the sensor-fault injectors (DESIGN.md §12).

Two contracts, pinned per injector:

  * **determinism** — same (cloud, seed) in, byte-identical cloud out; a
    different seed produces a different cloud. No injector may touch
    global RNG state.
  * **collate conventions** — (points, valid) in/out; removed rows are
    masked invalid AND parked at the far ``PAD_SENTINEL``; appended rows
    are flagged valid; ``inject_nonfinite`` is the documented exception
    (corrupt rows stay valid — that is the fault being modelled).
"""
import numpy as np
import pytest

from repro.data.collate import PAD_SENTINEL
from repro.data.corruption import (FAULT_NAMES, apply_faults, duplicate_points,
                                   fault_seed, frame_drop, ghost_points,
                                   inject_nonfinite, low_overlap_crop,
                                   parse_fault_spec, random_dropout,
                                   range_noise, sector_occlusion)

INJECTORS = {
    "occlusion": lambda p, v, s: sector_occlusion(p, v, seed=s,
                                                  width_deg=90.0),
    "dropout": lambda p, v, s: random_dropout(p, v, seed=s, frac=0.3),
    "crop": lambda p, v, s: low_overlap_crop(p, v, seed=s, keep_frac=0.4),
    "drop": lambda p, v, s: frame_drop(p, v, seed=s),
    "noise": lambda p, v, s: range_noise(p, v, seed=s, std=0.05),
    "tnoise": lambda p, v, s: range_noise(p, v, seed=s, std=0.05,
                                          heavy_tail=True),
    "ghost": lambda p, v, s: ghost_points(p, v, seed=s, count=64),
    "dup": lambda p, v, s: duplicate_points(p, v, seed=s, count=64),
    "nan": lambda p, v, s: inject_nonfinite(p, v, seed=s, count=8),
}


@pytest.fixture(scope="module")
def cloud():
    rng = np.random.default_rng(7)
    pts = rng.uniform(-30, 30, (2048, 3)).astype(np.float32)
    valid = np.ones(2048, bool)
    valid[-100:] = False          # a collate-padded tail
    pts[~valid] = PAD_SENTINEL
    return pts, valid


@pytest.mark.parametrize("name", sorted(INJECTORS))
def test_same_seed_identical(cloud, name):
    pts, valid = cloud
    p1, v1 = INJECTORS[name](pts, valid, 42)
    p2, v2 = INJECTORS[name](pts, valid, 42)
    np.testing.assert_array_equal(p1, p2)
    np.testing.assert_array_equal(v1, v2)


@pytest.mark.parametrize("name",
                         sorted(set(INJECTORS) - {"drop"}))
def test_different_seed_differs(cloud, name):
    # frame_drop is seed-independent by design (the whole frame goes).
    pts, valid = cloud
    p1, v1 = INJECTORS[name](pts, valid, 1)
    p2, v2 = INJECTORS[name](pts, valid, 2)
    assert (not np.array_equal(p1, p2)) or (not np.array_equal(v1, v2))


@pytest.mark.parametrize("name", sorted(INJECTORS))
def test_inputs_untouched(cloud, name):
    pts, valid = cloud
    before_p, before_v = pts.copy(), valid.copy()
    INJECTORS[name](pts, valid, 3)
    np.testing.assert_array_equal(pts, before_p)
    np.testing.assert_array_equal(valid, before_v)


@pytest.mark.parametrize("name", sorted(INJECTORS))
def test_collate_conventions(cloud, name):
    pts, valid = cloud
    out_p, out_v = INJECTORS[name](pts, valid, 5)
    assert out_p.dtype == np.float32 and out_v.dtype == bool
    assert out_p.shape[0] == out_v.shape[0] >= pts.shape[0]
    if name == "nan":
        return  # documented exception: corrupt rows stay valid
    # Every invalid row sits at the far sentinel (mask-unaware safe)…
    assert np.all(out_p[~out_v] == PAD_SENTINEL)
    # …and every valid row is finite.
    assert np.all(np.isfinite(out_p[out_v]))


def test_dropout_rate(cloud):
    pts, valid = cloud
    _, v = random_dropout(pts, valid, seed=0, frac=0.3)
    frac = 1.0 - v.sum() / valid.sum()
    assert 0.2 < frac < 0.4


def test_occlusion_removes_sector_only():
    ang = np.linspace(-np.pi, np.pi, 720, endpoint=False)
    pts = np.column_stack([10 * np.cos(ang), 10 * np.sin(ang),
                           np.zeros_like(ang)]).astype(np.float32)
    _, v = sector_occlusion(pts, None, seed=0, width_deg=90.0, center_deg=0.0)
    az = np.degrees(ang)
    inside = np.abs(az) <= 45.0
    assert not v[inside].any()
    assert v[~inside].all()


def test_crop_keeps_contiguous_fraction(cloud):
    pts, valid = cloud
    _, v = low_overlap_crop(pts, valid, seed=0, keep_frac=0.4)
    kept = v.sum() / valid.sum()
    assert 0.25 < kept < 0.55     # azimuth density is not uniform


def test_frame_drop_all_invalid(cloud):
    pts, valid = cloud
    p, v = frame_drop(pts, valid, seed=0)
    assert not v.any()
    assert np.all(p == PAD_SENTINEL)


def test_noise_moves_along_ray(cloud):
    pts, valid = cloud
    p, v = range_noise(pts, valid, seed=0, std=0.1)
    np.testing.assert_array_equal(v, valid)
    delta = p[valid] - pts[valid]
    r = np.linalg.norm(pts[valid], axis=1)
    # Displacement is radial: parallel to the original ray.
    cross = np.linalg.norm(np.cross(delta, pts[valid] / r[:, None]), axis=1)
    assert np.max(cross) < 1e-3
    # Invalid rows untouched.
    np.testing.assert_array_equal(p[~valid], pts[~valid])


def test_heavy_tail_has_outliers(cloud):
    pts, valid = cloud
    pg, _ = range_noise(pts, valid, seed=0, std=0.05)
    pt, _ = range_noise(pts, valid, seed=0, std=0.05, heavy_tail=True)
    dg = np.linalg.norm(pg[valid] - pts[valid], axis=1)
    dt = np.linalg.norm(pt[valid] - pts[valid], axis=1)
    assert dt.max() > 4 * dg.max()


def test_ghost_appends_valid_cluster(cloud):
    pts, valid = cloud
    p, v = ghost_points(pts, valid, seed=0, count=64)
    assert p.shape[0] == pts.shape[0] + 64
    assert v[-64:].all()
    spread = np.std(p[-64:], axis=0)
    assert np.all(spread < 5.0)   # a coherent blob, not uniform noise


def test_duplicates_are_exact_copies(cloud):
    pts, valid = cloud
    p, v = duplicate_points(pts, valid, seed=0, count=64)
    assert p.shape[0] == pts.shape[0] + 64
    orig = {tuple(row) for row in pts[valid]}
    assert all(tuple(row) in orig for row in p[-64:])


def test_nonfinite_rows_stay_valid(cloud):
    pts, valid = cloud
    p, v = inject_nonfinite(pts, valid, seed=0, count=16, inf_frac=0.5)
    np.testing.assert_array_equal(v, valid)
    bad = ~np.isfinite(p).all(axis=1)
    assert bad.sum() == 16
    assert v[bad].all()           # the sensor does NOT flag its garbage
    assert np.isinf(p).any() and np.isnan(p).any()


# -- spec parsing / composition ---------------------------------------------

def test_parse_fault_spec_roundtrip():
    spec = parse_fault_spec("dropout:0.3, occlusion:90deg ,nan:10,drop")
    assert [f.name for f in spec] == ["dropout", "occlusion", "nan", "drop"]
    assert spec[0].kwargs == {"frac": 0.3}
    assert spec[1].kwargs == {"width_deg": 90.0}
    assert spec[2].kwargs == {"count": 10}
    assert parse_fault_spec(spec) == spec       # parsed form passes through


def test_parse_fault_spec_unknown():
    with pytest.raises(ValueError, match="unknown fault"):
        parse_fault_spec("gremlins:3")


def test_apply_faults_deterministic(cloud):
    pts, valid = cloud
    spec = "dropout:0.2,noise:0.05,ghost:32,nan:4"
    p1, v1 = apply_faults(pts, spec, seed=9, frame=3, valid=valid)
    p2, v2 = apply_faults(pts, spec, seed=9, frame=3, valid=valid)
    np.testing.assert_array_equal(p1, p2)
    np.testing.assert_array_equal(v1, v2)
    p3, _ = apply_faults(pts, spec, seed=9, frame=4, valid=valid)
    assert p3.shape != p1.shape or not np.array_equal(p3, p1)


def test_fault_seed_stable():
    assert fault_seed(1, 2, "dropout") == fault_seed(1, 2, "dropout")
    assert fault_seed(1, 2, "dropout") != fault_seed(1, 3, "dropout")
    assert fault_seed(1, 2, "dropout") != fault_seed(1, 2, "noise")


def test_every_spec_name_has_injector():
    assert set(FAULT_NAMES) == set(INJECTORS)
