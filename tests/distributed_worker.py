"""Subprocess worker: validates shard_map ICP on an 8-device host mesh.

Run via tests/test_distributed.py — NOT imported by pytest directly (it must
set XLA_FLAGS before jax initialises, which would poison the main process).
Exits non-zero on any mismatch.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import ICPParams, icp_fixed_iterations  # noqa: E402
from repro.core.distributed import (batched_icp_sharded,  # noqa: E402
                                    distributed_nn_search, icp_sharded,
                                    shard_inputs)
from repro.core.nn_search import nn_search  # noqa: E402
from repro.core.transform import (random_rigid_transform,  # noqa: E402
                                  transform_points)


def main():
    assert jax.device_count() == 8, jax.devices()
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    key = jax.random.PRNGKey(0)

    # --- distributed NN == single-device NN -------------------------------
    k1, k2 = jax.random.split(key)
    src = jax.random.uniform(k1, (256, 3), minval=-20, maxval=20)
    dst = jax.random.uniform(k2, (4096, 3), minval=-20, maxval=20)
    d2_d, idx_d = distributed_nn_search(mesh, src, dst,
                                        target_axes=("data", "model"),
                                        chunk=256)
    d2_s, idx_s = nn_search(src, dst, chunk=256)
    np.testing.assert_allclose(np.asarray(d2_d), np.asarray(d2_s),
                               rtol=1e-4, atol=1e-4)
    mismatch = np.asarray(idx_d) != np.asarray(idx_s)
    if mismatch.any():  # fp ties only
        np.testing.assert_allclose(np.asarray(d2_d)[mismatch],
                                   np.asarray(d2_s)[mismatch], rtol=1e-4,
                                   atol=1e-4)
    print("distributed_nn_search OK")

    # --- giant-frame sharded ICP == single-device ICP ----------------------
    k1, k2, k3 = jax.random.split(key, 3)
    target = jax.random.uniform(k1, (2048, 3), minval=-10, maxval=10)
    T_gt = random_rigid_transform(k2, max_angle=0.1, max_translation=0.3)
    source = transform_points(jnp.linalg.inv(T_gt), target)
    source = source + 0.002 * jax.random.normal(k3, source.shape)
    params = ICPParams(max_iterations=20, chunk=256)
    res_d = icp_sharded(mesh, source, target, params,
                        target_axes=("data", "model"), fixed_iterations=True)
    res_s = icp_fixed_iterations(source, target, params)
    np.testing.assert_allclose(np.asarray(res_d.T), np.asarray(res_s.T),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(res_d.T), np.asarray(T_gt),
                               atol=0.02)
    print("icp_sharded OK")

    # --- fleet mode: 4 frames over data axis, targets over model -----------
    keys = jax.random.split(jax.random.PRNGKey(42), 4)
    srcs, dsts, gts = [], [], []
    for k in keys:
        ka, kb, kc = jax.random.split(k, 3)
        tgt = jax.random.uniform(ka, (1024, 3), minval=-10, maxval=10)
        T = random_rigid_transform(kb, max_angle=0.1, max_translation=0.3)
        s = transform_points(jnp.linalg.inv(T), tgt)
        s = s + 0.002 * jax.random.normal(kc, s.shape)
        srcs.append(s)
        dsts.append(tgt)
        gts.append(T)
    src_b = jnp.stack(srcs)
    dst_b = jnp.stack(dsts)
    src_b, dst_b = shard_inputs(mesh, src_b, dst_b)
    res_b = batched_icp_sharded(mesh, src_b, dst_b, params,
                                frame_axes=("data",), target_axes=("model",))
    for i in range(4):
        ref = icp_fixed_iterations(srcs[i], dsts[i], params)
        np.testing.assert_allclose(np.asarray(res_b.T[i]), np.asarray(ref.T),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(res_b.T[i]), np.asarray(gts[i]),
                                   atol=0.02)
    print("batched_icp_sharded OK")


if __name__ == "__main__":
    main()
    print("ALL-DISTRIBUTED-OK")
