"""Coarse-to-fine pyramid: parity with brute ICP, large-perturbation
recovery, engine registry/batch integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ICPParams, available_engines, get_engine, icp,
                        icp_pyramid)
from repro.core.pyramid import PyramidEngine
from repro.data.collate import collate_pairs

PARAMS = ICPParams(max_iterations=30, chunk=512)
# Small-scene pyramid config: one 2 m coarse level, 32³ lattice.
SMALL = dict(levels=((2.0, 6, 1024),), grid_dims=(32, 32, 32))


def _pair(seed, n=400, m=3000, scale=10.0, max_angle=0.1, max_t=0.3):
    from repro.core import random_rigid_transform, transform_points
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    dst = jax.random.uniform(k1, (m, 3), minval=-scale, maxval=scale)
    T_gt = random_rigid_transform(k2, max_angle=max_angle,
                                  max_translation=max_t)
    src = transform_points(jnp.linalg.inv(T_gt), dst)[:n]
    src = src + 0.002 * jax.random.normal(k3, src.shape)
    return np.asarray(src), np.asarray(dst), np.asarray(T_gt)


def test_pyramid_engine_registered():
    assert "pyramid" in available_engines()
    assert isinstance(get_engine("pyramid"), PyramidEngine)


def test_icp_pyramid_matches_brute_icp():
    """Acceptance: final transforms within 1e-3 rot/trans of brute force."""
    src, dst, T_gt = _pair(0)
    res = jax.jit(lambda s, d: icp_pyramid(s, d, PARAMS, **SMALL))(
        jnp.asarray(src), jnp.asarray(dst))
    ref = icp(jnp.asarray(src), jnp.asarray(dst), PARAMS)
    T, Tr = np.asarray(res.T), np.asarray(ref.T)
    assert np.linalg.norm(T[:3, :3] - Tr[:3, :3]) < 1e-3
    assert np.linalg.norm(T[:3, 3] - Tr[:3, 3]) < 1e-3
    np.testing.assert_allclose(T, T_gt, atol=0.05)


def test_engine_register_matches_function():
    src, dst, _ = _pair(1)
    eng = PyramidEngine(chunk=512, **SMALL)
    res = eng.register(src, dst, PARAMS)
    # engine == direct icp_pyramid call (parity must survive the engine's
    # bucket padding: masks make the padded run numerically equivalent)
    ref = jax.jit(lambda s, d: icp_pyramid(s, d, PARAMS, **SMALL))(
        jnp.asarray(src), jnp.asarray(dst))
    np.testing.assert_allclose(np.asarray(res.T), np.asarray(ref.T),
                               atol=1e-4)
    # ... and both still agree with brute-force ICP
    single = icp(jnp.asarray(src), jnp.asarray(dst), PARAMS)
    np.testing.assert_allclose(np.asarray(res.T), np.asarray(single.T),
                               atol=2e-3)


def test_register_batch_mixed_sizes_matches_loop():
    sizes = [(180, 900), (220, 1100), (150, 800)]
    pairs = [_pair(10 + i, n=n, m=m) for i, (n, m) in enumerate(sizes)]
    batch = collate_pairs([(s, d) for s, d, _ in pairs])
    eng = PyramidEngine(chunk=512, **SMALL)
    res = eng.register_batch(batch.src, batch.dst, PARAMS,
                             src_valid=batch.src_valid,
                             dst_valid=batch.dst_valid)
    for i, (s, d, _) in enumerate(pairs):
        single = icp(jnp.asarray(s), jnp.asarray(d), PARAMS)
        np.testing.assert_allclose(np.asarray(res.T[i]),
                                   np.asarray(single.T), atol=2e-3)
        assert float(res.inlier_frac[i]) == pytest.approx(
            float(single.inlier_frac), abs=1e-3)


def test_persistent_compile_cache():
    eng = PyramidEngine(chunk=512, **SMALL)
    src, dst, _ = _pair(2)
    eng.register(src, dst, PARAMS)
    before = eng.trace_count
    eng.register(src, dst, PARAMS)
    assert eng.trace_count == before


def test_named_engine_kwargs_are_hashable_singletons():
    a = get_engine("pyramid", levels=((2.0, 6, 1024),),
                   grid_dims=(32, 32, 32))
    b = get_engine("pyramid", levels=((2.0, 6, 1024),),
                   grid_dims=(32, 32, 32))
    assert a is b


def test_recovers_beyond_gate_perturbation():
    """The new scenario class: a translation several gates beyond
    max_correspondence_distance. Brute ICP stalls (every pull is capped at
    one gate radius toward locally-wrong neighbours); a two-level coarse
    schedule recovers it through the widened coarse gates."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    dst = jax.random.uniform(k1, (4000, 3), minval=-12, maxval=12)
    src = dst[:1000] + 0.01 * jax.random.normal(k2, (1000, 3))
    shift = jnp.asarray([2.5, 1.0, 0.5])
    src_b = src - shift
    params = ICPParams(max_iterations=60, max_correspondence_distance=1.0,
                       chunk=1024)
    brute = icp(src_b, dst, params)
    pyr = jax.jit(lambda s, d: icp_pyramid(
        s, d, params, levels=((6.0, 12, 1024), (2.0, 10, 4096)),
        grid_dims=(32, 32, 32)))(src_b, dst)
    err_brute = float(jnp.linalg.norm(brute.T[:3, 3] - shift))
    err_pyr = float(jnp.linalg.norm(pyr.T[:3, 3] - shift))
    assert err_brute > 1.0      # brute is stuck far from the truth
    assert err_pyr < 0.05       # pyramid recovers


def test_pallas_kernel_finest_level_matches():
    src, dst, _ = _pair(4, n=200, m=1500)
    xla = jax.jit(lambda s, d: icp_pyramid(s, d, PARAMS, **SMALL))(
        jnp.asarray(src), jnp.asarray(dst))
    ker = jax.jit(lambda s, d: icp_pyramid(s, d, PARAMS, use_kernel=True,
                                           interpret=True, **SMALL))(
        jnp.asarray(src), jnp.asarray(dst))
    np.testing.assert_allclose(np.asarray(ker.T), np.asarray(xla.T),
                               atol=1e-5)
