"""End-to-end driver: LiDAR odometry over a synthetic sequence.

Chains frame-to-frame FPPS registrations into a trajectory and reports
drift vs ground truth — the paper's actual autonomous-driving use case
(KITTI odometry protocol, §IV-A).

    PYTHONPATH=src python examples/odometry.py --frames 8
"""
import argparse
import time

import numpy as np

from repro.core import FppsICP
from repro.data.pointcloud import SceneConfig, ego_pose, frame_pair


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq", type=int, default=2)
    ap.add_argument("--frames", type=int, default=8)
    ap.add_argument("--samples", type=int, default=2048)
    args = ap.parse_args(argv)

    cfg = SceneConfig(n_ground=9000, n_walls=6000, n_poles=1800,
                      n_clutter=1700, extent=40.0, sensor_range=45.0)

    pose = np.eye(4)          # accumulated odometry (frame 0 frame)
    latencies = []
    drift = []
    for frame in range(args.frames):
        src, dst, T_gt = frame_pair(args.seq, frame, cfg, args.samples)
        icp = FppsICP()
        icp.setInputSource(src)
        icp.setInputTarget(dst)
        icp.setMaxCorrespondenceDistance(1.0)
        icp.setMaxIterationCount(50)
        icp.setTransformationEpsilon(1e-5)
        t0 = time.time()
        T = icp.align()
        latencies.append(time.time() - t0)
        # T maps frame f coords into frame f+1: accumulate inverse to get
        # the pose of frame f+1 in frame-0 coordinates.
        pose = pose @ np.linalg.inv(T)
        # ground-truth pose of frame f+1 relative to frame 0
        R0, t0g = ego_pose(args.seq, 0)
        R1, t1g = ego_pose(args.seq, frame + 1)
        gt = np.eye(4)
        gt[:3, :3] = R0.T @ R1
        gt[:3, 3] = R0.T @ (t1g - t0g)
        err = np.linalg.norm(pose[:3, 3] - gt[:3, 3])
        drift.append(err)
        print(f"frame {frame + 1:3d}: latency {latencies[-1]*1e3:7.1f} ms, "
              f"cumulative drift {err:.3f} m")
    print(f"\nmean latency {np.mean(latencies)*1e3:.1f} ms; "
          f"final drift {drift[-1]:.3f} m over {args.frames} frames")
    assert drift[-1] < 0.5, "odometry diverged"
    print("OK")


if __name__ == "__main__":
    main()
