"""End-to-end driver: LiDAR odometry over a synthetic sequence.

Chains frame-to-frame FPPS registrations into a trajectory and reports
drift vs ground truth — the paper's actual autonomous-driving use case
(KITTI odometry protocol, §IV-A).

All frame-pair registrations go through the unified engine layer as ONE
batched call (``register_batch`` via ``register_pairs``): each pair in a
frame-to-frame odometry chain is independent, so the whole sequence
registers in a single compiled program and only the cheap 4x4 pose
composition stays sequential on the host.

    PYTHONPATH=src python examples/odometry.py --frames 8
"""
import argparse
import time

import jax
import numpy as np

from repro.core import ICPParams, get_engine
from repro.data.pointcloud import SceneConfig, ego_pose, frame_pair


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq", type=int, default=2)
    ap.add_argument("--frames", type=int, default=8)
    ap.add_argument("--samples", type=int, default=2048)
    ap.add_argument("--engine", default="xla",
                    choices=["xla", "pallas", "distributed", "pyramid"])
    ap.add_argument("--minimizer", default="point_to_point",
                    choices=["point_to_point", "point_to_plane"])
    ap.add_argument("--robust", default="none",
                    choices=["none", "huber", "tukey"])
    args = ap.parse_args(argv)

    cfg = SceneConfig(n_ground=9000, n_walls=6000, n_poles=1800,
                      n_clutter=1700, extent=40.0, sensor_range=45.0)
    params = ICPParams(max_iterations=50, max_correspondence_distance=1.0,
                       transformation_epsilon=1e-5,
                       minimizer=args.minimizer, robust_kernel=args.robust)

    pairs = [frame_pair(args.seq, f, cfg, args.samples)
             for f in range(args.frames)]

    engine = get_engine(args.engine)
    t0 = time.time()
    res, _ = engine.register_pairs([(s, d) for s, d, _ in pairs], params)
    jax.block_until_ready(res.T)
    t_batch = time.time() - t0

    pose = np.eye(4)          # accumulated odometry (frame 0 frame)
    drift = []
    for frame in range(args.frames):
        T = np.asarray(res.T[frame])
        # T maps frame f coords into frame f+1: accumulate inverse to get
        # the pose of frame f+1 in frame-0 coordinates.
        pose = pose @ np.linalg.inv(T)
        # ground-truth pose of frame f+1 relative to frame 0
        R0, t0g = ego_pose(args.seq, 0)
        R1, t1g = ego_pose(args.seq, frame + 1)
        gt = np.eye(4)
        gt[:3, :3] = R0.T @ R1
        gt[:3, 3] = R0.T @ (t1g - t0g)
        err = np.linalg.norm(pose[:3, 3] - gt[:3, 3])
        drift.append(err)
        print(f"frame {frame + 1:3d}: iters {int(res.iterations[frame]):2d}, "
              f"rmse {float(res.rmse[frame]):.4f}, "
              f"cumulative drift {err:.3f} m")
    print(f"\n{args.frames} registrations in one batched call: {t_batch:.2f}s "
          f"({t_batch / args.frames * 1e3:.1f} ms/frame incl. compile, "
          f"engine={args.engine}); final drift {drift[-1]:.3f} m")
    assert drift[-1] < 0.5, "odometry diverged"
    print("OK")


if __name__ == "__main__":
    main()
