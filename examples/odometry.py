"""End-to-end driver: LiDAR odometry over a synthetic sequence.

Two execution modes over the same synthetic KITTI-like stream (the paper's
autonomous-driving use case, §IV-A):

  * ``--mode scan_to_map`` (default) — streaming scan-to-map odometry:
    every frame registers against the rolling local submap with a
    constant-velocity warm start (``repro.core.odometry``). This is the
    regime the paper's KITTI numbers live in: per-frame error stops
    compounding because the map is the common anchor.
  * ``--mode frame_to_frame`` — the classic chain of consecutive-pair
    registrations. All pairs are independent, so the whole sequence runs
    as ONE batched engine call (``register_pairs``) and only the cheap
    4x4 pose composition stays sequential on the host.

By default the stream *resamples* surface points every frame (a real
LiDAR never hits the same points twice); ``--static-world`` restores the
legacy static-world protocol, whose identical points across frames hand
frame-to-frame ICP an unrealistically exact correspondence. Both modes
share the per-frame iteration cap (``--iters``) so drift is comparable
like-for-like.

    PYTHONPATH=src python examples/odometry.py --frames 30
    PYTHONPATH=src python examples/odometry.py --mode frame_to_frame
"""
import argparse
import time

import jax
import numpy as np

from repro.core import ICPParams, OdometryConfig, OdometryPipeline, get_engine
from repro.data.pointcloud import (SceneConfig, gt_pose,
                                   sample_consecutive_pairs, sequence_scans)


def run_frame_to_frame(args, params, scans, gt):
    pairs = sample_consecutive_pairs(scans, args.samples)
    engine = get_engine(args.engine)
    t0 = time.time()
    res, _ = engine.register_pairs(pairs, params)
    jax.block_until_ready(res.T)
    elapsed = time.time() - t0

    pose = np.eye(4)          # accumulated odometry (frame-0 frame)
    drift = []
    for frame in range(args.frames):
        T = np.asarray(res.T[frame], np.float64)
        # T maps frame f coords into frame f+1: accumulate inverse to get
        # the pose of frame f+1 in frame-0 coordinates.
        pose = pose @ np.linalg.inv(T)
        err = np.linalg.norm(pose[:3, 3] - gt(frame + 1)[:3, 3])
        drift.append(err)
        print(f"frame {frame + 1:3d}: iters {int(res.iterations[frame]):2d}, "
              f"rmse {float(res.rmse[frame]):.4f}, "
              f"cumulative drift {err:.3f} m")
    iters = float(np.mean(np.asarray(res.iterations)))
    print(f"\nframe_to_frame: {args.frames} registrations in one batched "
          f"call: {elapsed:.2f}s ({elapsed / args.frames * 1e3:.1f} ms/frame "
          f"incl. compile, engine={args.engine}); mean iters {iters:.2f}; "
          f"final drift {drift[-1]:.3f} m")
    return drift[-1]


def run_scan_to_map(args, params, scans, gt):
    # engine_kwargs stays at the OdometryConfig default: polish-only
    # pyramid schedule, dropped automatically for other engines.
    pipe = OdometryPipeline(OdometryConfig(
        engine=args.engine, params=params,
        motion_model=not args.no_warm_start))
    t0 = time.time()
    poses, diags = pipe.run(scans)
    elapsed = time.time() - t0
    drift = []
    for frame in range(1, args.frames + 1):
        err = np.linalg.norm(poses[frame][:3, 3] - gt(frame)[:3, 3])
        drift.append(err)
        d = diags[frame]
        flag = "" if d.accepted else "  REJECTED(motion-model pose)"
        print(f"frame {frame:3d}: iters {d.iterations:2d}, "
              f"inliers {d.inlier_frac:.2f}, map occ {d.map_occupancy:.2f}, "
              f"cumulative drift {err:.3f} m{flag}")
    print(f"\nscan_to_map: {args.frames} frames in {elapsed:.2f}s "
          f"({elapsed / args.frames * 1e3:.1f} ms/frame incl. compile, "
          f"engine={args.engine}, warm_start={not args.no_warm_start}); "
          f"mean iters {pipe.mean_iterations():.2f}; "
          f"rejected {pipe.rejected_frames()}; final drift {drift[-1]:.3f} m")
    return drift[-1]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq", type=int, default=2)
    ap.add_argument("--frames", type=int, default=30)
    ap.add_argument("--samples", type=int, default=2048,
                    help="source sample count (frame_to_frame mode)")
    ap.add_argument("--iters", type=int, default=30,
                    help="per-frame iteration cap (both modes)")
    ap.add_argument("--mode", default="scan_to_map",
                    choices=["scan_to_map", "frame_to_frame"])
    ap.add_argument("--engine", default="pyramid",
                    choices=["xla", "pallas", "distributed", "pyramid"])
    ap.add_argument("--minimizer", default="point_to_point",
                    choices=["point_to_point", "point_to_plane"])
    ap.add_argument("--robust", default="huber",
                    choices=["none", "huber", "tukey"],
                    help="IRLS reweighting; huber (default) bounds the "
                         "map-frontier pull that biases streaming odometry "
                         "(DESIGN.md §10)")
    ap.add_argument("--robust-scale", type=float, default=0.3,
                    help="robust kernel scale in metres")
    ap.add_argument("--no-warm-start", action="store_true",
                    help="disable the constant-velocity motion model "
                         "(scan_to_map mode)")
    ap.add_argument("--static-world", action="store_true",
                    help="legacy protocol: identical world points every "
                         "frame (flatters frame_to_frame)")
    args = ap.parse_args(argv)

    cfg = SceneConfig(n_ground=9000, n_walls=6000, n_poles=1800,
                      n_clutter=1700, extent=40.0, sensor_range=45.0)
    params = ICPParams(max_iterations=args.iters,
                       max_correspondence_distance=1.0,
                       transformation_epsilon=1e-5,
                       minimizer=args.minimizer, robust_kernel=args.robust,
                       robust_scale=args.robust_scale)
    scans = sequence_scans(args.seq, args.frames + 1, cfg,
                           resample=not args.static_world)
    gt = gt_pose(args.seq)

    if args.mode == "frame_to_frame":
        final = run_frame_to_frame(args, params, scans, gt)
        # resampled streams random-walk the pairwise chain — the gap this
        # example exists to demonstrate; only gross divergence fails.
        assert final < 3.0, "odometry diverged"
    else:
        final = run_scan_to_map(args, params, scans, gt)
        # --no-warm-start is an ablation: it exists to SHOW the stream
        # degrading without the motion model, so it skips the hard bound.
        if not args.no_warm_start:
            assert final < 0.5, "odometry diverged"
    print("OK")


if __name__ == "__main__":
    main()
