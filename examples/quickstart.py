"""Quickstart: register two LiDAR scans with the FPPS PCL-like API.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import FppsICP
from repro.data.pointcloud import SceneConfig, frame_pair


def main():
    # A reduced synthetic KITTI-like frame pair (fast on CPU).
    cfg = SceneConfig(n_ground=9000, n_walls=6000, n_poles=1800,
                      n_clutter=1700, extent=40.0, sensor_range=45.0)
    source, target, T_gt = frame_pair(seq=0, frame=3, cfg=cfg,
                                      n_source_samples=2048)

    # Exactly the paper's Table I API surface:
    icp = FppsICP()
    icp.hardwareInitialize()
    icp.setInputSource(source)
    icp.setInputTarget(target)
    icp.setMaxCorrespondenceDistance(1.0)
    icp.setMaxIterationCount(50)
    icp.setTransformationEpsilon(1e-5)
    T = icp.align()

    print("estimated transform:\n", np.round(T, 4))
    print("ground truth:\n", np.round(T_gt, 4))
    print(f"converged={icp.hasConverged()} fitness={icp.getFitnessScore():.4f}")
    err = np.linalg.norm(T[:3, 3] - T_gt[:3, 3])
    print(f"translation error: {err:.4f} m")
    assert err < 0.1, "registration failed"
    print("OK")


if __name__ == "__main__":
    main()
