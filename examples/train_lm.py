"""Train a ~100M-param qwen2-family model for a few hundred steps.

The assignment's end-to-end training example. Defaults are sized for this
CPU container (a genuinely ~100M-parameter config would need hours per
hundred steps on one core); pass --full-100m to run the real thing.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

from repro.launch import train as train_driver


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--full-100m", action="store_true",
                    help="12L x 768d x 32k-vocab (~100M params)")
    args = ap.parse_args(argv)

    argv2 = ["--arch", "qwen2-0.5b", "--smoke",
             "--steps", str(args.steps), "--batch", str(args.batch),
             "--seq", str(args.seq), "--ckpt-dir", args.ckpt_dir]
    if args.full_100m:
        # register a one-off 100M config by monkey-patching the smoke entry
        import repro.configs.qwen2_0_5b as q
        base = q.smoke()
        cfg100 = dataclasses.replace(
            base, name="qwen2-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, d_head=64, d_ff=2048, vocab_size=32000)
        q.smoke = lambda: cfg100
    losses = train_driver.main(argv2)
    assert losses[-1] < losses[0], "loss did not improve"
    print("OK")


if __name__ == "__main__":
    main()
