"""Serve a small model with batched requests (prefill + decode engine).

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch import serve as serve_driver


def main():
    serve_driver.main(["--arch", "qwen2-0.5b", "--smoke", "--batch", "4",
                       "--prompt-len", "32", "--gen", "32"])
    print("OK")


if __name__ == "__main__":
    main()
