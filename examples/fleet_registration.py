"""Fleet-scale registration: many frame-pairs in one batched engine call.

Demonstrates the unified engine layer end to end: mixed-size clouds are
collated into shape buckets and registered by one compiled executable via
``RegistrationEngine.register_batch``. With ``--engine distributed`` the
same batch runs through the shard_map fleet mode — on this container that
is 1 device; on a pod, frames shard over ("pod","data") and each target
over "model" (see src/repro/core/distributed.py and the fpps-icp dry-run
cells).

    PYTHONPATH=src python examples/fleet_registration.py --frames 4
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ICPParams, get_engine
from repro.core.transform import random_rigid_transform, transform_points


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=4)
    ap.add_argument("--points", type=int, default=1024)
    ap.add_argument("--engine", default="xla",
                    choices=["xla", "pallas", "distributed", "pyramid"])
    ap.add_argument("--minimizer", default="point_to_point",
                    choices=["point_to_point", "point_to_plane"])
    ap.add_argument("--robust", default="none",
                    choices=["none", "huber", "tukey"])
    args = ap.parse_args(argv)

    keys = jax.random.split(jax.random.PRNGKey(0), args.frames)
    pairs, gts = [], []
    for i, k in enumerate(keys):
        ka, kb, kc = jax.random.split(k, 3)
        # Mixed sizes on purpose: the collator buckets them.
        m = args.points - 37 * (i % 3)
        tgt = jax.random.uniform(ka, (m, 3), minval=-10, maxval=10)
        T = random_rigid_transform(kb, max_angle=0.1, max_translation=0.3)
        s = transform_points(jnp.linalg.inv(T), tgt)
        s = s + 0.002 * jax.random.normal(kc, s.shape)
        pairs.append((np.asarray(s), np.asarray(tgt)))
        gts.append(np.asarray(T))

    engine = get_engine(args.engine, chunk=256)
    params = ICPParams(max_iterations=25, chunk=256,
                       minimizer=args.minimizer, robust_kernel=args.robust)
    t0 = time.time()
    res, batch = engine.register_pairs(pairs, params)
    jax.block_until_ready(res.T)
    dt = time.time() - t0
    errs = [float(np.abs(np.asarray(res.T[i]) - gts[i]).max())
            for i in range(args.frames)]
    print(f"{args.frames} registrations (buckets src={batch.src.shape} "
          f"dst={batch.dst.shape}, engine={args.engine}) in {dt:.2f}s "
          f"({dt / args.frames * 1e3:.0f} ms/frame incl. compile)")
    print("max |T - T_gt| per frame:", [f"{e:.4f}" for e in errs])
    assert max(errs) < 0.05
    print("OK")


if __name__ == "__main__":
    main()
