"""Fleet-scale registration: many frame-pairs in one sharded batch.

Demonstrates the multi-device path (shard_map fleet mode) — on this
container it runs on 1 device; on a pod, frames shard over ("pod","data")
and each target over "model" (see src/repro/core/distributed.py and the
fpps-icp dry-run cells).

    PYTHONPATH=src python examples/fleet_registration.py --frames 4
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ICPParams, icp_fixed_iterations
from repro.core.transform import random_rigid_transform, transform_points


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=4)
    ap.add_argument("--points", type=int, default=1024)
    args = ap.parse_args(argv)

    keys = jax.random.split(jax.random.PRNGKey(0), args.frames)
    srcs, dsts, gts = [], [], []
    for k in keys:
        ka, kb, kc = jax.random.split(k, 3)
        tgt = jax.random.uniform(ka, (args.points, 3), minval=-10, maxval=10)
        T = random_rigid_transform(kb, max_angle=0.1, max_translation=0.3)
        s = transform_points(jnp.linalg.inv(T), tgt)
        srcs.append(s + 0.002 * jax.random.normal(kc, s.shape))
        dsts.append(tgt)
        gts.append(T)
    src_b, dst_b = jnp.stack(srcs), jnp.stack(dsts)

    params = ICPParams(max_iterations=25, chunk=256)
    batched = jax.jit(jax.vmap(
        lambda s, d: icp_fixed_iterations(s, d, params)))
    t0 = time.time()
    res = batched(src_b, dst_b)
    jax.block_until_ready(res.T)
    dt = time.time() - t0
    errs = [float(np.abs(np.asarray(res.T[i]) - np.asarray(gts[i])).max())
            for i in range(args.frames)]
    print(f"{args.frames} registrations in {dt:.2f}s "
          f"({dt / args.frames * 1e3:.0f} ms/frame incl. compile)")
    print("max |T - T_gt| per frame:", [f"{e:.4f}" for e in errs])
    assert max(errs) < 0.05
    print("OK")


if __name__ == "__main__":
    main()
