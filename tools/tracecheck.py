"""tracecheck — trace-safety & kernel-contract static analysis (DESIGN.md §15).

The repo's latency guarantees (zero retraces across fleet churn, fp32-pinned
warm starts, donation-safe fleet state, interpret-mode plumbing) are enforced
dynamically by ``RegistrationEngine.trace_count`` assertions and tests that
must happen to exercise the hazard. This pass proves the *absence* of whole
hazard classes before anything runs, the way HLS parameter checkers gate
synthesis: one AST sweep over ``src/``, ``benchmarks/`` and ``tools/`` with a
rule engine, per-line suppressions, a committed baseline (kept empty), and
JSON findings for CI artifacts.

Rule catalogue (severity in :data:`RULES`; full prose in DESIGN.md §15):

  TS001  Python ``if``/``while``/``assert``/``for`` on a traced value inside
         a jit/vmap/shard_map/scan/pallas scope (concretization error or,
         worse, silent per-value retrace).
  TS002  implicit host sync on a traced value (``float()``, ``int()``,
         ``bool()``, ``.item()``, ``.tolist()``, ``np.asarray``) inside a
         traced scope.
  TS003  unhashable or array-valued jit static/cache keys: ``static_arg*``
         naming an array-annotated parameter, or an engine-style
         ``*cache*[key]`` whose key embeds an array or list/dict/set display
         (the PR-1 per-align recompile bug class).
  TS004  unpinned dtype at a trace boundary: ``jnp.asarray(x)`` /
         ``jnp.array(x)`` of a host name with no dtype argument (the PR-5
         f64-warm-start bug class).
  TS005  an argument at a ``donate_argnums`` position read after the
         donating call (the §14 fleet-state donation contract).
  TS006  ``print()`` inside a traced scope (fires at trace time, not run
         time; use ``jax.debug.print``).
  PK001  ``pl.pallas_call`` bypassing ``kernels.common.pallas_call_kwargs``
         (explicit ``interpret=`` included), or a hand-rolled
         ``jax.default_backend() == ...`` check outside the blessed home.
  PK002  BlockSpec/grid contract mismatch where statically determinable:
         index-map arity vs grid rank, index-map result vs block rank,
         literal block shapes not dividing literal array dims.
  PK003  static per-kernel VMEM footprint (block shapes x dtype, double
         buffered) exceeding the budget modeled in
         ``benchmarks/kernel_resources.py`` (``VMEM_V5E``).
  TC000  suppression hygiene: a ``# tracecheck: ignore[...]`` tag without a
         trailing ``# reason``.

Traced-scope resolution is interprocedural (at least one level, iterated to
a bounded fixpoint): a function is traced if it is decorated with / passed
to / referenced by a tracing wrapper (``jax.jit``, ``jax.vmap``,
``shard_map``, ``lax.scan``/``while_loop``/``cond``/``fori_loop``,
``pl.pallas_call``, ``functools.partial`` chains thereof), is nested inside
a traced function, or if *every* reference to it across the scanned files
sits inside a traced scope. Directly-traced functions treat every non-static
parameter as traced; inherited helpers treat only array-annotated parameters
as traced (static config like ``ICPParams`` legitimately rides through
helper signatures). ``x is None`` tests and ``.shape``/``.dtype`` accesses
are static under jit and never count as traced uses.

Suppression: ``# tracecheck: ignore[TS001]  # reason`` on the finding's
line. The reason is mandatory (TC000). Baseline: ``tracecheck_baseline.json``
next to this file holds fingerprints of grandfathered findings; the repo
policy is an *empty* baseline — fix or justify inline instead.

Usage::

  python tools/tracecheck.py                # sweep, exit 1 on findings
  python tools/tracecheck.py --json out.json
  python tools/tracecheck.py --write-baseline   # grandfather current state
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import hashlib
import json
import pathlib
import re
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BASELINE_PATH = pathlib.Path(__file__).resolve().parent / \
    "tracecheck_baseline.json"

# Sweep scope: the whole jit/Pallas surface (serve/ and launch/ drivers live
# under src/repro). Tests exercise hazards on purpose and are excluded.
SCAN_ROOTS = ("src", "benchmarks", "tools")

RULES = {
    "TS001": ("error", "python control flow on a traced value"),
    "TS002": ("error", "implicit host sync inside a traced scope"),
    "TS003": ("error", "unhashable/array-valued jit static or cache key"),
    "TS004": ("error", "unpinned dtype at a trace boundary"),
    "TS005": ("error", "donated buffer read after the donating call"),
    "TS006": ("warning", "print() inside a traced scope"),
    "PK001": ("error", "pallas_call bypasses kernels.common plumbing"),
    "PK002": ("error", "BlockSpec/grid contract mismatch"),
    "PK003": ("error", "static VMEM footprint exceeds budget"),
    "TC000": ("warning", "suppression without a reason"),
}

_IGNORE_RE = re.compile(
    r"#\s*tracecheck:\s*ignore\[([A-Za-z0-9_,\s]+)\](.*)$")

# Canonical callables whose function-valued arguments become traced scopes.
# Value = indices of the function arguments.
TRACING_WRAPPERS = {
    "jax.jit": (0,),
    "jax.vmap": (0,),
    "jax.pmap": (0,),
    "jax.grad": (0,),
    "jax.value_and_grad": (0,),
    "jax.checkpoint": (0,),
    "jax.remat": (0,),
    "jax.lax.scan": (0,),
    "jax.lax.map": (0,),
    "jax.lax.while_loop": (0, 1),
    "jax.lax.fori_loop": (2,),
    "jax.lax.cond": (1, 2),
    "jax.lax.switch": (1,),
    "jax.lax.associative_scan": (0,),
    "jax.experimental.pallas.pallas_call": (0,),
    "jax.experimental.shard_map.shard_map": (0,),
    "jax.sharding.shard_map": (0,),
    "repro.compat.shard_map": (0,),
}

# jax.* callables whose *result* lives on the host (never a tracer).
_JAX_HOST_RESULTS = {
    "jax.device_get", "jax.block_until_ready", "jax.devices",
    "jax.local_devices", "jax.device_count", "jax.local_device_count",
    "jax.default_backend", "jax.make_mesh", "jax.debug.print",
    "jax.debug.callback", "jax.tree_util.tree_structure",
}

# Attributes of a traced array that are static python values under jit.
_STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "nbytes", "sharding",
                 "weak_type", "aval"}

# Method calls that pull a traced value to the host (TS002) — their results
# are host values either way.
_SYNC_METHODS = {"item", "tolist"}

# Builtins that iterate/measure without concretizing per-element semantics
# (zip/enumerate of a list of tracers is static loop structure).
_STRUCTURAL_BUILTINS = {
    "zip", "enumerate", "range", "reversed", "len", "isinstance", "getattr",
    "hasattr", "sorted", "list", "tuple", "dict", "set", "map", "filter",
    "min", "max", "print", "repr", "str", "format", "type", "id", "super",
    "abs", "round", "sum", "any", "all", "iter", "next", "vars", "dir",
}

_ARRAY_ANNOT_RE = re.compile(r"\b(Array|ndarray|ArrayLike)\b")


def _vmem_budget() -> int:
    """The VMEM budget PK003 checks against — AST-read from the same
    constant the roofline/resource model uses (``VMEM_V5E`` in
    ``benchmarks/kernel_resources.py``) so the two can't drift; falls back
    to 128 MiB when analyzing outside the repo."""
    src = REPO_ROOT / "benchmarks" / "kernel_resources.py"
    try:
        tree = ast.parse(src.read_text())
        for node in tree.body:
            if (isinstance(node, ast.Assign)
                    and any(isinstance(t, ast.Name) and t.id == "VMEM_V5E"
                            for t in node.targets)):
                val = _fold_const(node.value, {}, {})
                if isinstance(val, int):
                    return val
    except OSError:
        pass
    return 128 * 2 ** 20


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation: id, severity, location, message, fingerprint."""
    rule: str
    path: str
    line: int
    col: int
    message: str
    source_line: str = ""

    @property
    def severity(self) -> str:
        return RULES[self.rule][0]

    @property
    def fingerprint(self) -> str:
        """Stable id for baseline matching: rule + path + the *text* of the
        flagged line, so pure line-number drift doesn't churn the baseline."""
        basis = f"{self.rule}|{self.path}|{self.source_line.strip()}"
        return hashlib.sha1(basis.encode()).hexdigest()[:16]

    def to_json(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "path": self.path, "line": self.line, "col": self.col,
                "message": self.message, "fingerprint": self.fingerprint}


# ---------------------------------------------------------------------------
# per-module bookkeeping


class ModuleInfo:
    """Parsed module + alias table + assignment index used by every rule."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.aliases = self._build_aliases()
        # Name -> value node for module-level simple assignments
        self.consts: dict = {}
        for node in self.tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                self.consts[node.targets[0].id] = node.value

    def _build_aliases(self) -> dict:
        """local name -> fully-qualified dotted prefix."""
        aliases: dict = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    if a.name == "*":
                        continue
                    aliases[a.asname or a.name] = f"{node.module}.{a.name}"
        # canonical spellings even when the module aliases differently
        aliases.setdefault("jnp", "jax.numpy")
        return aliases

    def canonical(self, node: ast.AST) -> str | None:
        """Dotted canonical name of a Name/Attribute chain, alias-resolved:
        ``pl.pallas_call`` -> ``jax.experimental.pallas.pallas_call``."""
        parts: list = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.aliases.get(node.id, node.id)
        parts.append(root)
        return ".".join(reversed(parts))

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


def _fold_const(node, local_assigns: dict, param_defaults: dict,
                depth: int = 0):
    """Best-effort constant folding for PK002/PK003: literals, +-*/%**//,
    names resolved through local assignments then parameter defaults."""
    if depth > 12 or node is None:
        return None
    if isinstance(node, ast.Constant):
        return node.value if isinstance(node.value, (int, float)) else None
    if isinstance(node, ast.Name):
        for env in (local_assigns, param_defaults):
            if node.id in env:
                tgt = env[node.id]
                if isinstance(tgt, (int, float)):
                    return tgt
                return _fold_const(tgt, local_assigns, param_defaults,
                                   depth + 1)
        return None
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = _fold_const(node.operand, local_assigns, param_defaults,
                        depth + 1)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        lhs = _fold_const(node.left, local_assigns, param_defaults, depth + 1)
        rhs = _fold_const(node.right, local_assigns, param_defaults,
                          depth + 1)
        if lhs is None or rhs is None:
            return None
        try:
            if isinstance(node.op, ast.Add):
                return lhs + rhs
            if isinstance(node.op, ast.Sub):
                return lhs - rhs
            if isinstance(node.op, ast.Mult):
                return lhs * rhs
            if isinstance(node.op, ast.FloorDiv):
                return lhs // rhs
            if isinstance(node.op, ast.Div):
                return lhs / rhs
            if isinstance(node.op, ast.Mod):
                return lhs % rhs
            if isinstance(node.op, ast.Pow):
                return lhs ** rhs
        except (ZeroDivisionError, OverflowError, ValueError):
            return None
    return None


def _is_array_annotation(annot) -> bool:
    if annot is None:
        return False
    try:
        return bool(_ARRAY_ANNOT_RE.search(ast.unparse(annot)))
    except Exception:
        return False


def _lambda_or_def(node) -> bool:
    return isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda))


def _walk_skip_nested(root):
    """ast.walk that does not descend into function/lambda scopes nested
    inside ``root`` (so each node is attributed to exactly one scope)."""
    stack = [root]
    while stack:
        node = stack.pop()
        if node is not root and _lambda_or_def(node):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


# ---------------------------------------------------------------------------
# traced-scope resolution


class _ScopeCollector(ast.NodeVisitor):
    """Finds directly-traced function objects in one module and records,
    per traced site, which static parameters the wrapper declares."""

    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        # func node -> {"mode": "all"|"annot", "static": set[str],
        #               "static_nums": set[int], "pallas": bool}
        self.traced: dict = {}
        # name -> def node, for module- and function-level defs
        self.defs: dict = {}
        self._local_assign_stack: list = [dict(mod.consts)]
        self._collect_defs(mod.tree)

    def _collect_defs(self, tree):
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs.setdefault(node.name, node)

    # -- helpers ----------------------------------------------------------
    def _statics_from_call(self, call: ast.Call):
        names: set = set()
        nums: set = set()
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                for c in ast.walk(kw.value):
                    if isinstance(c, ast.Constant) and isinstance(c.value,
                                                                  str):
                        names.add(c.value)
            elif kw.arg == "static_argnums":
                for c in ast.walk(kw.value):
                    if isinstance(c, ast.Constant) and isinstance(c.value,
                                                                  int):
                        nums.add(c.value)
        return names, nums

    def _mark(self, func_expr, statics=(set(), set()), pallas=False,
              local_env=None):
        """Mark the function object behind ``func_expr`` as directly
        traced; resolves Name -> local def/lambda/partial chains."""
        env = local_env if local_env is not None else {}
        seen = 0
        node = func_expr
        while seen < 8:
            seen += 1
            if _lambda_or_def(node):
                break
            if isinstance(node, ast.Name):
                if node.id in env:
                    node = env[node.id]
                    continue
                if node.id in self.defs:
                    node = self.defs[node.id]
                    continue
                return
            if isinstance(node, ast.Call):
                canon = self.mod.canonical(node.func)
                if canon in ("functools.partial", "partial") and node.args:
                    node = node.args[0]
                    continue
                if canon in TRACING_WRAPPERS and node.args:
                    node = node.args[0]
                    continue
                return
            return
        if not _lambda_or_def(node):
            return
        entry = self.traced.setdefault(
            node, {"mode": "all", "static": set(), "static_nums": set(),
                   "pallas": False})
        entry["static"] |= statics[0]
        entry["static_nums"] |= statics[1]
        entry["pallas"] = entry["pallas"] or pallas

    # -- visitors ---------------------------------------------------------
    def visit_FunctionDef(self, node):
        for deco in node.decorator_list:
            canon = self.mod.canonical(deco if not isinstance(deco, ast.Call)
                                       else deco.func)
            if canon in TRACING_WRAPPERS:
                statics = (self._statics_from_call(deco)
                           if isinstance(deco, ast.Call) else (set(), set()))
                self._mark(node, statics)
            elif canon in ("functools.partial", "partial") and isinstance(
                    deco, ast.Call) and deco.args:
                inner = self.mod.canonical(deco.args[0])
                if inner in TRACING_WRAPPERS:
                    self._mark(node, self._statics_from_call(deco))
        # new local-assign frame for name -> func resolution inside the body
        frame: dict = {}
        self._local_assign_stack.append(frame)
        self.generic_visit(node)
        self._local_assign_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Assign(self, node):
        if len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            self._local_assign_stack[-1][node.targets[0].id] = node.value
        self.generic_visit(node)

    def visit_Call(self, node):
        canon = self.mod.canonical(node.func)
        if canon in TRACING_WRAPPERS:
            statics = self._statics_from_call(node)
            env: dict = {}
            for frame in self._local_assign_stack:
                env.update(frame)
            pallas = canon.endswith("pallas_call")
            for idx in TRACING_WRAPPERS[canon]:
                if idx < len(node.args):
                    self._mark(node.args[idx], (statics[0], statics[1]),
                               pallas=pallas, local_env=env)
        # pl.when(cond)(fn) / pl.when(cond) used as decorator-factory
        elif (isinstance(node.func, ast.Call)
                and (self.mod.canonical(node.func.func) or "").endswith(
                    "pallas.when")):
            env = {}
            for frame in self._local_assign_stack:
                env.update(frame)
            for a in node.args:
                self._mark(a, pallas=True, local_env=env)
        self.generic_visit(node)


def _function_references(mod: ModuleInfo, name: str):
    """All Name-load references to ``name`` in a module, paired with the
    stack of enclosing function/lambda nodes."""
    refs: list = []

    def walk(node, stack):
        for child in ast.iter_child_nodes(node):
            cstack = stack
            if _lambda_or_def(child):
                cstack = stack + [child]
            if (isinstance(child, ast.Name) and child.id == name
                    and isinstance(child.ctx, ast.Load)):
                refs.append((child, stack))
            walk(child, cstack)

    walk(mod.tree, [])
    return refs


# ---------------------------------------------------------------------------
# traced-value dataflow within one function


class TracedEnv:
    """Set of names bound to traced values inside one function body."""

    def __init__(self, mod: ModuleInfo, func, info: dict | None,
                 outer: set | None = None, outer_tuples: set | None = None):
        self.mod = mod
        self.func = func
        self.names: set = set(outer or ())
        # names bound to *python tuples of traced values* (pallas `*refs`
        # varargs and slices thereof): iterating them is static unrolling,
        # indexing them yields a traced element.
        self.tuples: set = set(outer_tuples or ())
        self.pallas = bool(info and info.get("pallas"))
        args = func.args
        pos_args = list(args.posonlyargs) + list(args.args)
        all_args = pos_args + list(args.kwonlyargs)
        if self.pallas:
            # pallas kernels: positional parameters are Refs (traced);
            # keyword-only params are partial-bound static config; the
            # vararg is a python tuple of Refs.
            for a in pos_args:
                self.names.add(a.arg)
            if args.vararg is not None:
                self.tuples.add(args.vararg.arg)
        elif info is not None and info["mode"] == "all":
            static = info["static"]
            static_nums = info["static_nums"]
            for i, a in enumerate(all_args):
                if a.arg in ("self", "cls") or a.arg in static \
                        or i in static_nums:
                    continue
                self.names.add(a.arg)
            if args.vararg is not None:
                self.tuples.add(args.vararg.arg)
        else:
            for a in all_args:
                if _is_array_annotation(a.annotation):
                    self.names.add(a.arg)

    # -- expression classification ---------------------------------------
    def traced(self, node) -> bool:
        if node is None:
            return False
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, ast.Starred):
            return self.traced(node.value)
        if isinstance(node, ast.Await):
            return self.traced(node.value)
        if isinstance(node, ast.NamedExpr):
            return self.traced(node.value)
        if isinstance(node, (ast.BinOp,)):
            return self.traced(node.left) or self.traced(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.traced(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.traced(v) for v in node.values)
        if isinstance(node, ast.Compare):
            # `x is None` / `x is not None` is a static structure check
            if any(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            return (self.traced(node.left)
                    or any(self.traced(c) for c in node.comparators))
        if isinstance(node, ast.IfExp):
            return self.traced(node.body) or self.traced(node.orelse)
        if isinstance(node, ast.Subscript):
            if self.tuple_like(node.value):
                # element of a static tuple-of-traced: a slice is still a
                # tuple, a plain index yields a traced element
                return not isinstance(node.slice, ast.Slice)
            return self.traced(node.value)
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            return self.traced(node.value)
        if isinstance(node, ast.Call):
            return self._call_traced(node)
        # Tuple/List/Dict/Set displays: static containers; iterating or
        # unpacking them is trace-safe structure (elements keep their own
        # classification when read individually).
        return False

    def _call_traced(self, node: ast.Call) -> bool:
        canon = self.mod.canonical(node.func)
        if canon is not None:
            root = canon.split(".")[0]
            if canon in _JAX_HOST_RESULTS:
                return False
            if root in ("jax",) or canon.startswith("jax.numpy"):
                return True
            if root in ("numpy", "np", "math", "time", "os", "json"):
                return False
            if canon in _STRUCTURAL_BUILTINS or canon in ("float", "int",
                                                          "bool"):
                return False
        if isinstance(node.func, ast.Attribute):
            # method on a traced value: traced unless it's a sync/static
            if node.func.attr in _SYNC_METHODS | _STATIC_ATTRS:
                return False
            if self.traced(node.func.value):
                return True
        # unknown callable: a traced argument usually makes a traced result
        # (correspond_fn(src_t), NamedTuple ctors over traced leaves, ...)
        return any(self.traced(a) for a in node.args) or any(
            self.traced(kw.value) for kw in node.keywords)

    def tuple_like(self, node) -> bool:
        """Static python tuple of traced values: a ``*refs`` vararg name, a
        slice of one, or a tuple concatenation thereof."""
        if isinstance(node, ast.Name):
            return node.id in self.tuples
        if isinstance(node, ast.Subscript) \
                and isinstance(node.slice, ast.Slice):
            return self.tuple_like(node.value)
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
            return self.tuple_like(node.left) or self.tuple_like(node.right)
        return False

    # -- statement walk (assignments update the set) ----------------------
    def bind(self, target, is_traced: bool, value=None):
        if isinstance(target, ast.Name):
            if is_traced:
                self.names.add(target.id)
            else:
                self.names.discard(target.id)
        elif isinstance(target, ast.Starred):
            self.bind(target.value, is_traced)
        elif isinstance(target, (ast.Tuple, ast.List)):
            elts_val = None
            if isinstance(value, (ast.Tuple, ast.List)) \
                    and len(value.elts) == len(target.elts):
                elts_val = value.elts
            for i, t in enumerate(target.elts):
                if elts_val is not None:
                    self.assign(t, elts_val[i])
                else:
                    self.bind(t, is_traced)

    def assign(self, target, value):
        """bind() plus static-tuple tracking: ``a = refs[:3]`` keeps a a
        tuple-of-traced; ``x, y = refs[:2]`` unpacks traced elements."""
        if self.tuple_like(value):
            if isinstance(target, ast.Name):
                self.tuples.add(target.id)
                self.names.discard(target.id)
                return
            if isinstance(target, (ast.Tuple, ast.List)):
                for t in target.elts:
                    self.bind(t, True)
                return
        self.bind(target, self.traced(value), value)

    def process_statements(self, body):
        """One forward pass: update bindings statement by statement."""
        for stmt in body:
            if isinstance(stmt, ast.Assign):
                for target in stmt.targets:
                    self.assign(target, stmt.value)
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                self.assign(stmt.target, stmt.value)
            elif isinstance(stmt, ast.AugAssign):
                if self.traced(stmt.value):
                    self.bind(stmt.target, True)
            elif isinstance(stmt, ast.For):
                self.bind(stmt.target,
                          self.traced(stmt.iter)
                          or self.tuple_like(stmt.iter))
                self.process_statements(stmt.body)
                self.process_statements(stmt.orelse)
            elif isinstance(stmt, (ast.While, ast.If)):
                self.process_statements(stmt.body)
                self.process_statements(stmt.orelse)
            elif isinstance(stmt, ast.With):
                self.process_statements(stmt.body)
            elif isinstance(stmt, ast.Try):
                self.process_statements(stmt.body)
                for h in stmt.handlers:
                    self.process_statements(h.body)
                self.process_statements(stmt.orelse)
                self.process_statements(stmt.finalbody)


# ---------------------------------------------------------------------------
# the analyzer


class Analyzer:
    """Full pipeline over a set of modules: traced-scope resolution, then
    rule checks, returning raw (unsuppressed, unbaselined) findings."""

    def __init__(self, modules: list):
        self.modules = modules
        self.findings: list = []
        # (mod, func node) -> info dict for every traced scope
        self.traced_scopes: dict = {}
        self._collectors = {}
        self._resolve_traced_scopes()

    # -- traced scope resolution ------------------------------------------
    def _resolve_traced_scopes(self):
        for mod in self.modules:
            col = _ScopeCollector(mod)
            col.visit(mod.tree)
            self._collectors[mod.path] = col
            for func, info in col.traced.items():
                self.traced_scopes[(mod.path, func)] = dict(info)
        # nested defs inside traced functions inherit the traced context
        self._propagate_nesting()
        # bounded fixpoint: helpers referenced *only* from traced scopes
        for _ in range(4):
            if not self._inherit_pass():
                break
            self._propagate_nesting()

    def _propagate_nesting(self):
        for mod in self.modules:
            traced_funcs = [f for (p, f) in self.traced_scopes
                            if p == mod.path]
            for func in traced_funcs:
                info = self.traced_scopes[(mod.path, func)]
                for child in ast.walk(func):
                    if child is func or not _lambda_or_def(child):
                        continue
                    self.traced_scopes.setdefault(
                        (mod.path, child),
                        {"mode": "annot", "static": set(),
                         "static_nums": set(),
                         "pallas": info.get("pallas", False)})

    def _enclosing_scopes(self, mod: ModuleInfo, stack) -> bool:
        """True if the innermost enclosing function of a reference site is a
        traced scope."""
        for func in reversed(stack):
            return (mod.path, func) in self.traced_scopes
        return False

    def _inherit_pass(self) -> bool:
        """Mark module-level defs whose every scanned reference is inside a
        traced scope. Returns True if anything new was marked."""
        # map exported name -> (mod, def) for all module-level defs
        def_table: dict = {}
        for mod in self.modules:
            for node in mod.tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    def_table[(mod.path, node.name)] = node
        changed = False
        for (path, name), func in def_table.items():
            mod = next(m for m in self.modules if m.path == path)
            if (path, func) in self.traced_scopes:
                continue
            sites = []
            for rmod in self.modules:
                local = name
                if rmod.path != path:
                    # only references resolved through an import of this def
                    canon = rmod.aliases.get(name, None)
                    if canon is None or not canon.endswith(f".{name}"):
                        continue
                for ref, stack in _function_references(rmod, local):
                    if ref is func:
                        continue
                    sites.append((rmod, stack))
            if not sites:
                continue
            if all(self._enclosing_scopes(rmod, stack) and stack
                   for rmod, stack in sites):
                self.traced_scopes[(path, func)] = {
                    "mode": "annot", "static": set(), "static_nums": set(),
                    "pallas": False}
                changed = True
        return changed

    # -- finding emission --------------------------------------------------
    def emit(self, mod: ModuleInfo, rule: str, node, message: str):
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        self.findings.append(Finding(
            rule=rule, path=mod.path, line=line, col=col, message=message,
            source_line=mod.line_text(line)))

    # -- rule drivers -------------------------------------------------------
    def run(self) -> list:
        for mod in self.modules:
            self._check_traced_scopes(mod)
            self._check_ts003(mod)
            self._check_ts004(mod)
            self._check_ts005(mod)
            self._check_pallas(mod)
            self._check_backend_compare(mod)
        return self.findings

    # TS001 / TS002 / TS006 — need the traced-name env per traced scope
    def _check_traced_scopes(self, mod: ModuleInfo):
        for (path, func), info in list(self.traced_scopes.items()):
            if path != mod.path or isinstance(func, ast.Lambda):
                continue
            outer, outer_tuples = self._closure_names(mod, func)
            env = TracedEnv(mod, func, info, outer, outer_tuples)
            # two passes: loop-carried bindings settle on the second
            env.process_statements(func.body)
            env.process_statements(func.body)
            self._scan_traced_body(mod, func, env)

    def _closure_names(self, mod: ModuleInfo, func):
        """(traced names, tuple-of-traced names) closed over from the
        innermost enclosing traced function."""
        candidates = []
        for (path, parent), info in self.traced_scopes.items():
            if path != mod.path or parent is func \
                    or isinstance(parent, ast.Lambda):
                continue
            if any(child is func for child in ast.walk(parent)):
                candidates.append((parent, info))
        if not candidates:
            return set(), set()
        # innermost enclosing scope: the latest-starting candidate
        parent, info = max(candidates,
                           key=lambda c: (c[0].lineno, c[0].col_offset))
        pouter, ptuples = self._closure_names(mod, parent)
        penv = TracedEnv(mod, parent, info, pouter, ptuples)
        penv.process_statements(parent.body)
        return set(penv.names), set(penv.tuples)

    def _scan_traced_body(self, mod: ModuleInfo, func, env: TracedEnv):
        def walk(node):
            for child in ast.iter_child_nodes(node):
                if _lambda_or_def(child):
                    continue  # nested scopes are analyzed independently
                if isinstance(child, ast.If) and env.traced(child.test):
                    self.emit(mod, "TS001", child,
                              "`if` on a traced value inside a traced "
                              "scope — use jnp.where / lax.cond")
                elif isinstance(child, ast.While) and env.traced(child.test):
                    self.emit(mod, "TS001", child,
                              "`while` on a traced value inside a traced "
                              "scope — use lax.while_loop")
                elif isinstance(child, ast.Assert) and env.traced(child.test):
                    self.emit(mod, "TS001", child,
                              "`assert` on a traced value inside a traced "
                              "scope — use checkify or a host-side check")
                elif isinstance(child, ast.For) and env.traced(child.iter) \
                        and not isinstance(child.iter, (ast.Tuple, ast.List)):
                    self.emit(mod, "TS001", child,
                              "python `for` over a traced array — use "
                              "lax.scan / lax.fori_loop")
                elif isinstance(child, ast.Call):
                    self._check_sync_call(mod, child, env)
                walk(child)

        walk(func)

    def _check_sync_call(self, mod: ModuleInfo, call: ast.Call,
                         env: TracedEnv):
        canon = mod.canonical(call.func)
        if isinstance(call.func, ast.Name) and call.func.id in (
                "float", "int", "bool"):
            if any(env.traced(a) for a in call.args):
                self.emit(mod, "TS002",
                          call, f"`{call.func.id}()` on a traced value "
                          "forces a host sync inside a traced scope")
            return
        if canon in ("numpy.asarray", "numpy.array", "np.asarray",
                     "np.array"):
            if any(env.traced(a) for a in call.args):
                self.emit(mod, "TS002", call,
                          "np.asarray on a traced value forces a host "
                          "sync inside a traced scope — use jnp")
            return
        if canon in ("jax.device_get",):
            if any(env.traced(a) for a in call.args):
                self.emit(mod, "TS002", call,
                          "jax.device_get inside a traced scope")
            return
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in _SYNC_METHODS \
                and env.traced(call.func.value):
            self.emit(mod, "TS002", call,
                      f".{call.func.attr}() on a traced value forces a "
                      "host sync inside a traced scope")
            return
        if isinstance(call.func, ast.Name) and call.func.id == "print":
            self.emit(mod, "TS006", call,
                      "print() inside a traced scope fires at trace "
                      "time only — use jax.debug.print")

    # TS003 — static/cache key hazards
    def _check_ts003(self, mod: ModuleInfo):
        col = self._collectors[mod.path]
        # (a) static_argnames/nums naming an array-annotated parameter
        for func, info in col.traced.items():
            if isinstance(func, ast.Lambda):
                continue
            args = func.args
            all_args = (list(args.posonlyargs) + list(args.args)
                        + list(args.kwonlyargs))
            for i, a in enumerate(all_args):
                if (a.arg in info["static"] or i in info["static_nums"]) \
                        and _is_array_annotation(a.annotation):
                    self.emit(mod, "TS003", a,
                              f"static jit argument {a.arg!r} is "
                              "array-annotated — arrays are unhashable "
                              "and retrace per value")
        # (b) engine-style cache subscripts/gets with array/unhashable keys
        scopes = [mod.tree] + [n for n in ast.walk(mod.tree)
                               if isinstance(n, (ast.FunctionDef,
                                                 ast.AsyncFunctionDef))]
        for scope in scopes:
            local_assigns = dict(mod.consts)
            for stmt in _walk_skip_nested(scope):
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                        and isinstance(stmt.targets[0], ast.Name):
                    local_assigns[stmt.targets[0].id] = stmt.value
            for node in _walk_skip_nested(scope):
                key = None
                if isinstance(node, ast.Subscript) \
                        and self._is_cache_name(node.value):
                    key = node.slice
                elif isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr in ("get", "setdefault", "pop") \
                        and self._is_cache_name(node.func.value) \
                        and node.args:
                    key = node.args[0]
                if key is None:
                    continue
                if isinstance(key, ast.Name) and key.id in local_assigns:
                    key = local_assigns[key.id]
                bad = self._bad_key_part(mod, key)
                if bad is not None:
                    self.emit(mod, "TS003", node, bad)

    def _is_cache_name(self, node) -> bool:
        name = None
        if isinstance(node, ast.Name):
            name = node.id
        elif isinstance(node, ast.Attribute):
            name = node.attr
        return name is not None and "cache" in name.lower()

    def _bad_key_part(self, mod: ModuleInfo, key) -> str | None:
        for sub in ast.walk(key):
            if isinstance(sub, (ast.List, ast.Dict, ast.Set)):
                return ("cache key embeds an unhashable "
                        f"{type(sub).__name__.lower()} display")
            if isinstance(sub, ast.Call):
                canon = mod.canonical(sub.func)
                if canon and (canon.startswith("jax.numpy")
                              or canon.split(".")[0] == "jax"):
                    return ("cache key embeds a jax array value — "
                            "unhashable, and equality-by-id retraces "
                            "per call (PR-1 recompile bug class)")
        return None

    # TS004 — unpinned dtype at a trace boundary
    def _check_ts004(self, mod: ModuleInfo):
        for (path, func), env in self._all_function_envs(mod):
            for call in _walk_skip_nested(func):
                if not isinstance(call, ast.Call):
                    continue
                canon = mod.canonical(call.func)
                if canon not in ("jax.numpy.asarray", "jax.numpy.array"):
                    continue
                if len(call.args) >= 2 or any(kw.arg == "dtype"
                                              for kw in call.keywords):
                    continue
                if len(call.args) != 1 or not isinstance(call.args[0],
                                                         ast.Name):
                    continue
                if env is not None and env.traced(call.args[0]):
                    continue  # already a traced array: dtype is settled
                fn = canon.split(".")[-1]
                self.emit(mod, "TS004", call,
                          f"jnp.{fn}({call.args[0].id}) without a dtype "
                          "pins nothing — a float64 input silently "
                          "poisons the f32 trace (PR-5 bug class)")

    def _all_function_envs(self, mod: ModuleInfo):
        """(path, func) -> TracedEnv for every function in the module (not
        only traced scopes) so TS004/TS005 can tell host names from traced
        ones. Module level is represented by (path, mod.tree) with env
        None."""
        out = []
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                info = self.traced_scopes.get((mod.path, node))
                env = TracedEnv(mod, node, info)
                env.process_statements(node.body)
                out.append(((mod.path, node), env))
        out.append(((mod.path, mod.tree), None))
        return out

    # TS005 — donated buffer read after the donating call
    def _check_ts005(self, mod: ModuleInfo):
        donors = self._donating_callables(mod)
        if not donors:
            return
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            self._scan_donation_reads(mod, node, donors)

    def _donating_callables(self, mod: ModuleInfo) -> dict:
        """name (plain or attribute) -> donated positional indices."""
        donors: dict = {}

        def donate_nums(call: ast.Call):
            for kw in call.keywords:
                if kw.arg == "donate_argnums":
                    nums = set()
                    for c in ast.walk(kw.value):
                        if isinstance(c, ast.Constant) and isinstance(
                                c.value, int):
                            nums.add(c.value)
                    return nums
            return set()

        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for deco in node.decorator_list:
                    if isinstance(deco, ast.Call):
                        canon = mod.canonical(deco.func)
                        nums = donate_nums(deco)
                        if canon in ("functools.partial", "partial") \
                                and deco.args \
                                and mod.canonical(deco.args[0]) == "jax.jit":
                            nums |= donate_nums(deco)
                        if nums and (canon == "jax.jit" or (
                                canon in ("functools.partial", "partial"))):
                            donors[node.name] = nums
            elif isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call) \
                    and mod.canonical(node.value.func) == "jax.jit":
                nums = donate_nums(node.value)
                if not nums:
                    continue
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        donors[t.id] = nums
                    elif isinstance(t, ast.Attribute):
                        donors[t.attr] = nums
        return donors

    def _scan_donation_reads(self, mod, func, donors):
        calls = []  # (call node, donated arg name, position)
        for node in _walk_skip_nested(func):
            if not isinstance(node, ast.Call):
                continue
            name = None
            if isinstance(node.func, ast.Name):
                name = node.func.id
            elif isinstance(node.func, ast.Attribute):
                name = node.func.attr
            if name not in donors:
                continue
            for k in donors[name]:
                if k < len(node.args) and isinstance(node.args[k], ast.Name):
                    calls.append((node, node.args[k].id, k))
        for call, arg_name, k in calls:
            rebind_line = None
            reads = []
            for node in ast.walk(func):
                line = getattr(node, "lineno", None)
                if line is None or line < call.lineno:
                    continue
                if isinstance(node, ast.Name) and node.id == arg_name:
                    if isinstance(node.ctx, (ast.Store,)):
                        # a store on the call line is the idiomatic
                        # `state, aux = step(state, ...)` rebind
                        if rebind_line is None or line < rebind_line:
                            rebind_line = line
                    elif isinstance(node.ctx, ast.Load) \
                            and line > call.lineno:
                        reads.append(node)
            for node in reads:
                if rebind_line is not None and node.lineno >= rebind_line:
                    continue
                self.emit(mod, "TS005", node,
                          f"{arg_name!r} is donated (donate_argnums={k}) "
                          f"at line {call.lineno} and read afterwards — "
                          "the buffer is invalidated by the donating call")

    # PK001 / PK002 / PK003 — pallas_call contracts
    def _check_pallas(self, mod: ModuleInfo):
        budget = _vmem_budget()
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            local_assigns = {}
            for stmt in ast.walk(node):
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                        and isinstance(stmt.targets[0], ast.Name):
                    local_assigns[stmt.targets[0].id] = stmt.value
            param_defaults = self._param_defaults(node, mod, local_assigns)
            for call in _walk_skip_nested(node):
                if not isinstance(call, ast.Call):
                    continue
                canon = mod.canonical(call.func)
                if canon != "jax.experimental.pallas.pallas_call":
                    continue
                self._pk001(mod, call)
                self._pk002(mod, call, local_assigns, param_defaults)
                self._pk003(mod, call, local_assigns, param_defaults,
                            budget)

    def _param_defaults(self, func, mod: ModuleInfo,
                        local_assigns: dict) -> dict:
        out: dict = {}
        args = func.args
        pos = list(args.posonlyargs) + list(args.args)
        for a, d in zip(pos[len(pos) - len(args.defaults):], args.defaults):
            v = _fold_const(d, local_assigns, {})
            if v is not None:
                out[a.arg] = v
        for a, d in zip(args.kwonlyargs, args.kw_defaults):
            v = _fold_const(d, local_assigns, {})
            if v is not None:
                out[a.arg] = v
        return out

    def _pk001(self, mod: ModuleInfo, call: ast.Call):
        has_common = False
        for kw in call.keywords:
            if kw.arg is None and isinstance(kw.value, ast.Call):
                fname = None
                if isinstance(kw.value.func, ast.Name):
                    fname = kw.value.func.id
                elif isinstance(kw.value.func, ast.Attribute):
                    fname = kw.value.func.attr
                if fname == "pallas_call_kwargs":
                    has_common = True
            elif kw.arg == "interpret":
                self.emit(mod, "PK001", kw.value,
                          "explicit interpret= on pallas_call — route "
                          "through kernels.common.pallas_call_kwargs "
                          "(tri-state resolution, PR-6 contract)")
        if not has_common:
            self.emit(mod, "PK001", call,
                      "pallas_call without **pallas_call_kwargs(...) — "
                      "kernels.common is the single home for interpret "
                      "resolution and TPU compiler params")

    def _grid_len(self, call: ast.Call, local_assigns: dict) -> int | None:
        for kw in call.keywords:
            if kw.arg != "grid":
                continue
            g = kw.value
            if isinstance(g, ast.Name) and g.id in local_assigns:
                g = local_assigns[g.id]
            if isinstance(g, ast.Tuple):
                return len(g.elts)
            if isinstance(g, ast.Constant) and isinstance(g.value, int):
                return 1
        return None

    def _iter_blockspecs(self, mod: ModuleInfo, call: ast.Call,
                         local_assigns: dict):
        """Yield every BlockSpec Call reachable from in_specs/out_specs,
        resolving simple Name indirection (vspec = pl.BlockSpec(...))."""
        seen = set()
        for kw in call.keywords:
            if kw.arg not in ("in_specs", "out_specs"):
                continue
            stack = [kw.value]
            while stack:
                node = stack.pop()
                if id(node) in seen:
                    continue
                seen.add(id(node))
                if isinstance(node, ast.Name) \
                        and node.id in local_assigns:
                    stack.append(local_assigns[node.id])
                    continue
                if isinstance(node, ast.Call):
                    canon = mod.canonical(node.func) or ""
                    if canon.endswith("BlockSpec"):
                        yield node
                        continue
                for child in ast.iter_child_nodes(node):
                    stack.append(child)

    def _pk002(self, mod: ModuleInfo, call: ast.Call, local_assigns: dict,
               param_defaults: dict):
        grid_len = self._grid_len(call, local_assigns)
        for spec in self._iter_blockspecs(mod, call, local_assigns):
            shape = spec.args[0] if spec.args else None
            index_map = spec.args[1] if len(spec.args) > 1 else None
            for kw in spec.keywords:
                if kw.arg == "index_map":
                    index_map = kw.value
                elif kw.arg == "block_shape":
                    shape = kw.value
            block_rank = (len(shape.elts)
                          if isinstance(shape, ast.Tuple) else None)
            if not isinstance(index_map, ast.Lambda):
                continue
            arity = len(index_map.args.args)
            if grid_len is not None and arity != grid_len:
                self.emit(mod, "PK002", spec,
                          f"BlockSpec index map takes {arity} grid "
                          f"indices but the grid has rank {grid_len}")
            ret = index_map.body
            if isinstance(ret, ast.Tuple) and block_rank is not None \
                    and len(ret.elts) != block_rank:
                self.emit(mod, "PK002", spec,
                          f"BlockSpec index map returns {len(ret.elts)} "
                          f"coordinates for a rank-{block_rank} block")
            elif not isinstance(ret, ast.Tuple) and block_rank not in (
                    None, 1):
                self.emit(mod, "PK002", spec,
                          "BlockSpec index map returns a scalar for a "
                          f"rank-{block_rank} block")

    def _pk003(self, mod: ModuleInfo, call: ast.Call, local_assigns: dict,
               param_defaults: dict, budget: int):
        total = 0
        resolved_any = False
        for spec in self._iter_blockspecs(mod, call, local_assigns):
            shape = spec.args[0] if spec.args else None
            for kw in spec.keywords:
                if kw.arg == "block_shape":
                    shape = kw.value
            if not isinstance(shape, ast.Tuple):
                return  # unknown layout: stay silent rather than guess
            n = 1
            for elt in shape.elts:
                v = _fold_const(elt, local_assigns, param_defaults)
                if not isinstance(v, int):
                    return
                n *= v
            total += n * 4  # fp32 planes; the conservative common case
            resolved_any = True
        if not resolved_any:
            return
        double = 2 * total  # grid pipeline double-buffers in/out tiles
        if double > budget:
            self.emit(mod, "PK003", call,
                      f"static VMEM estimate {double / 2**20:.1f} MiB "
                      f"(double-buffered block tiles) exceeds the "
                      f"{budget / 2**20:.0f} MiB budget modeled in "
                      "benchmarks/kernel_resources.py — shrink bn/bc "
                      "before autotuning")

    # PK001b — hand-rolled backend checks anywhere in the scanned surface
    def _check_backend_compare(self, mod: ModuleInfo):
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Compare):
                continue
            sides = [node.left] + list(node.comparators)
            has_backend = any(
                isinstance(s, ast.Call)
                and mod.canonical(s.func) == "jax.default_backend"
                for s in sides)
            has_str = any(isinstance(s, ast.Constant)
                          and isinstance(s.value, str) for s in sides)
            if has_backend and has_str:
                self.emit(mod, "PK001", node,
                          "hand-rolled jax.default_backend() check — "
                          "kernels.common.default_interpret is the "
                          "single home for interpret resolution")


# ---------------------------------------------------------------------------
# suppressions, baseline, CLI


def _suppressions(mod: ModuleInfo):
    """line -> set of suppressed rule ids; also returns TC000 findings for
    tags without a reason."""
    table: dict = {}
    hygiene: list = []
    for i, line in enumerate(mod.lines, 1):
        m = _IGNORE_RE.search(line)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        table.setdefault(i, set()).update(rules)
        if line.strip().startswith("#"):
            # a comment-only tag line suppresses the line below it
            table.setdefault(i + 1, set()).update(rules)
        trailer = m.group(2).strip()
        if not trailer.lstrip("#").strip():
            hygiene.append(Finding(
                rule="TC000", path=mod.path, line=i, col=0,
                message="tracecheck suppression without a reason — add "
                        "`# why` after the ignore tag",
                source_line=line))
    return table, hygiene


def analyze_modules(modules: list):
    """Run all rules; apply per-line suppressions. Returns (findings,
    n_suppressed)."""
    analyzer = Analyzer(modules)
    raw = []
    seen = set()
    for f in analyzer.run():
        key = (f.rule, f.path, f.line, f.col, f.message)
        if key not in seen:
            seen.add(key)
            raw.append(f)
    by_path = {m.path: m for m in modules}
    kept: list = []
    suppressed = 0
    sup_tables = {}
    for mod in modules:
        sup_tables[mod.path], hygiene = _suppressions(mod)
        kept.extend(hygiene)
    for f in raw:
        rules_here = sup_tables.get(f.path, {}).get(f.line, set())
        if f.rule in rules_here:
            suppressed += 1
            continue
        kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    return kept, suppressed


def analyze_source(source: str, path: str = "<memory>") -> list:
    """Analyze one in-memory module (the fixture-test entry point)."""
    findings, _ = analyze_modules([ModuleInfo(path, source)])
    return findings


def _scan_paths(paths=None):
    files: list = []
    if paths:
        for p in paths:
            p = pathlib.Path(p)
            files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    else:
        for root in SCAN_ROOTS:
            base = REPO_ROOT / root
            if base.exists():
                files.extend(sorted(base.rglob("*.py")))
    return files


def load_modules(paths=None):
    mods = []
    for f in _scan_paths(paths):
        try:
            rel = str(f.resolve().relative_to(REPO_ROOT))
        except ValueError:
            rel = str(f)
        try:
            mods.append(ModuleInfo(rel, f.read_text()))
        except SyntaxError:
            pass  # E999 is the linter's job; don't double-report
    return mods


def load_baseline(path=BASELINE_PATH) -> set:
    try:
        data = json.loads(pathlib.Path(path).read_text())
    except (OSError, json.JSONDecodeError):
        return set()
    return {f["fingerprint"] for f in data.get("findings", [])}


def write_baseline(findings, path=BASELINE_PATH) -> None:
    payload = {
        "comment": "Grandfathered tracecheck findings. Policy: keep this "
                   "EMPTY — fix the code or add an inline justified "
                   "suppression instead (DESIGN.md §15).",
        "findings": [{"rule": f.rule, "path": f.path,
                      "fingerprint": f.fingerprint,
                      "message": f.message} for f in findings],
    }
    pathlib.Path(path).write_text(json.dumps(payload, indent=2) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to scan (default: src benchmarks "
                         "tools)")
    ap.add_argument("--json", dest="json_out",
                    help="write findings JSON (CI artifact)")
    ap.add_argument("--baseline", default=str(BASELINE_PATH))
    ap.add_argument("--write-baseline", action="store_true",
                    help="grandfather current findings into the baseline")
    args = ap.parse_args(argv)

    modules = load_modules(args.paths or None)
    findings, suppressed = analyze_modules(modules)
    baseline = load_baseline(args.baseline)
    new = [f for f in findings if f.fingerprint not in baseline]
    baselined = len(findings) - len(new)

    if args.write_baseline:
        write_baseline(findings, args.baseline)
        print(f"baseline written: {len(findings)} finding(s)")
        return 0

    for f in new:
        print(f"{f.path}:{f.line}:{f.col}: {f.rule} [{f.severity}] "
              f"{f.message}")
    if args.json_out:
        payload = {
            "findings": [f.to_json() for f in new],
            "suppressed": suppressed,
            "baselined": baselined,
            "scanned_files": len(modules),
            "rules": {k: {"severity": s, "title": t}
                      for k, (s, t) in RULES.items()},
        }
        pathlib.Path(args.json_out).write_text(
            json.dumps(payload, indent=2) + "\n")
    errors = [f for f in new if f.severity == "error"]
    warnings = [f for f in new if f.severity == "warning"]
    if new:
        print(f"\ntracecheck: {len(errors)} error(s), "
              f"{len(warnings)} warning(s) "
              f"({suppressed} suppressed, {baselined} baselined) over "
              f"{len(modules)} files")
        return 1
    print(f"tracecheck clean: {len(modules)} files, 0 findings "
          f"({suppressed} suppressed, {baselined} baselined)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
