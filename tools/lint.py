"""Project linter: ruff when available, a stdlib fallback otherwise.

CI installs ruff and gets the full ``[tool.ruff]`` behaviour from
pyproject.toml. The benchmark container this repo grows in cannot install
packages, so ``make lint`` falls back to this module's stdlib
implementation of the same rule set:

  E999  syntax errors (ast.parse)
  E501  line too long (``line-length`` from pyproject, default 88)
  W191  tab in indentation
  W291  trailing whitespace
  W293  whitespace on blank line
  F401  imported but unused (respects ``__all__`` and ``# noqa``)
  I001  unsorted/unsectioned imports (simplified: module-level order and
        stdlib / third-party / first-party section separation)
  PGH004  blanket ``# noqa`` with no rule code — a suppression that
        hides *everything* on the line documents nothing; name the rule

The fallback is deliberately a *subset* interpreter of the ruff config —
anything it flags, ruff flags too — so a green fallback run is a sound
local approximation and the CI job stays the source of truth.

Independently of which linter runs, the *docstring coverage* check below
(D100/D101/D103-lite: every public module / class / function in the
service surface — ``serve/``, ``core/engine.py``, ``data/collate.py`` —
plus the kernel/submap contract modules ``kernels/common.py`` and
``data/submap.py`` must carry a docstring) always executes: ruff's D
rules are not configured in pyproject, so this check is the single
source of truth in both environments.

Trace-safety and Pallas kernel contracts are the third lint pillar and
live in their own pass: ``tools/tracecheck.py`` (run by ``make lint``).
"""
from __future__ import annotations

import ast
import io
import pathlib
import re
import shutil
import subprocess
import sys
import sysconfig
import tokenize

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
LINE_LENGTH = 88
FIRST_PARTY = ("repro", "benchmarks", "tests", "tools", "examples",
               "_hypothesis_compat")

# Mirror of [tool.ruff.lint.per-file-ignores] in pyproject.toml.
PER_FILE_IGNORES = {
    "tests/*_worker.py": {"E501", "I001"},
    "tests/test_roofline_model.py": {"E501"},
}

_NOQA = re.compile(r"#\s*noqa", re.IGNORECASE)
# A noqa *directive* (comment starting with the tag) that names no rule
# code (PGH004): `# noqa`, `# noqa:` with nothing after it, or
# `# noqa XXX` missing the colon. Checked against tokenized comments so
# prose mentions of noqa in docstrings/comments don't count.
_BARE_NOQA = re.compile(r"^#\s*noqa\b(?!\s*:\s*[A-Z][A-Z0-9]*\d)",
                        re.IGNORECASE)

# Public-API docstring coverage targets (ISSUE-8, ISSUE-10): the
# documented serving surface plus the kernel/submap contract modules.
# Directories are scanned recursively.
DOCSTRING_TARGETS = (
    "src/repro/serve",
    "src/repro/core/engine.py",
    "src/repro/data/collate.py",
    "src/repro/kernels/common.py",
    "src/repro/data/submap.py",
)


def _stdlib_modules() -> frozenset:
    names = set(getattr(sys, "stdlib_module_names", ()))
    if not names:  # pragma: no cover - python < 3.10
        names = {p.stem for p in pathlib.Path(
            sysconfig.get_paths()["stdlib"]).iterdir()}
    return frozenset(names)


STDLIB = _stdlib_modules()


def _import_section(module: str) -> int:
    """0 = __future__, 1 = stdlib, 2 = third-party, 3 = first-party."""
    root = module.split(".")[0]
    if root == "__future__":
        return 0
    if root in FIRST_PARTY:
        return 3
    if root in STDLIB:
        return 1
    return 2


def _iter_files():
    # -co --exclude-standard: tracked AND untracked-but-not-ignored files,
    # so a new module is linted before its first `git add`.
    out = subprocess.run(
        ["git", "ls-files", "-co", "--exclude-standard", "--", "*.py"],
        cwd=REPO_ROOT, capture_output=True, text=True)
    if out.returncode == 0 and out.stdout.strip():
        return [REPO_ROOT / line for line in out.stdout.splitlines()]
    return sorted(REPO_ROOT.rglob("*.py"))  # pragma: no cover - no git


def _check_lines(path, text, problems):
    for i, line in enumerate(text.splitlines(), 1):
        stripped = line.rstrip("\n")
        if len(stripped) > LINE_LENGTH and not _NOQA.search(stripped):
            problems.append((path, i, "E501",
                             f"line too long ({len(stripped)} > "
                             f"{LINE_LENGTH})"))
        if stripped != stripped.rstrip():
            code = "W293" if not stripped.strip() else "W291"
            problems.append((path, i, code, "trailing whitespace"))
        indent = stripped[:len(stripped) - len(stripped.lstrip())]
        if "\t" in indent:
            problems.append((path, i, "W191", "tab in indentation"))


def _check_bare_noqa(path, text, problems):
    """PGH004: a blanket ``# noqa`` suppresses every rule on the line and
    documents none — require the code (``# noqa: E501``)."""
    try:
        tokens = tokenize.generate_tokens(io.StringIO(text).readline)
        for tok in tokens:
            if tok.type == tokenize.COMMENT \
                    and _BARE_NOQA.match(tok.string):
                problems.append((path, tok.start[0], "PGH004",
                                 "blanket `# noqa` — name the rule code "
                                 "(`# noqa: E501`)"))
    except tokenize.TokenizeError:  # pragma: no cover - E999 reports it
        pass


def _dunder_all(tree) -> set:
    names = set()
    for node in tree.body:
        if (isinstance(node, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == "__all__"
                        for t in node.targets)
                and isinstance(node.value, (ast.List, ast.Tuple))):
            for elt in node.value.elts:
                if isinstance(elt, ast.Constant) and isinstance(
                        elt.value, str):
                    names.add(elt.value)
    return names


def _check_unused_imports(path, text, tree, problems):
    lines = text.splitlines()
    exported = _dunder_all(tree)
    used = {n.id for n in ast.walk(tree) if isinstance(n, ast.Name)}
    # names referenced inside string annotations / docstring doctests are
    # out of scope for the fallback; `# noqa` handles intentional ones.
    for node in ast.walk(tree):
        if not isinstance(node, (ast.Import, ast.ImportFrom)):
            continue
        if isinstance(node, ast.ImportFrom) and node.module == "__future__":
            continue
        line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
        if _NOQA.search(line):
            continue
        for alias in node.names:
            if alias.name == "*":
                continue
            bound = alias.asname or alias.name.split(".")[0]
            if alias.asname and alias.asname == alias.name:
                continue  # explicit re-export convention
            if bound in used or bound in exported:
                continue
            problems.append((path, node.lineno, "F401",
                             f"{alias.name!r} imported but unused"))


def _check_import_order(path, text, tree, problems):
    """Simplified I001, mirroring isort's normal form:

    * a *run* is a maximal sequence of top-level imports with no other
      statement in between; blank lines split a run into *blocks*;
    * each block must hold a single section (stdlib / third-party /
      first-party...), sorted with straight imports before from-imports;
    * across the blocks of a run, sections must strictly increase (blank
      line = section boundary; a same-section split is a violation too).
    """
    lines = text.splitlines()
    run: list = []
    runs = [run]
    block: list = []
    last_line = None
    for node in tree.body:
        if not isinstance(node, (ast.Import, ast.ImportFrom)):
            if run:
                run = []
                runs.append(run)
            block = []
            last_line = None
            continue
        if not block or (last_line is not None
                         and node.lineno > last_line + 1):
            block = []
            run.append(block)
        line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
        last_line = getattr(node, "end_lineno", node.lineno)
        if _NOQA.search(line):
            continue
        if isinstance(node, ast.ImportFrom):
            module = "." * node.level + (node.module or "")
            is_from = 1
        else:
            module = node.names[0].name
            is_from = 0
        # isort default: straight imports precede from-imports per section
        block.append((node.lineno, _import_section(module), is_from,
                      module.lower()))
    for run in runs:
        blocks = [b for b in run if b]
        prev_section = -1
        for blk in blocks:
            sections = {sec for _, sec, _, _ in blk}
            keys = [k[1:] for k in blk]
            if len(sections) > 1 or keys != sorted(keys):
                problems.append((path, blk[0][0], "I001",
                                 "imports unsorted within block (one "
                                 "section per block, straight before "
                                 "from-imports, alphabetical)"))
                continue
            sec = next(iter(sections))
            if sec <= prev_section:
                problems.append((path, blk[0][0], "I001",
                                 "import sections out of order across "
                                 "blank-line blocks"))
            prev_section = sec
    return


def _ignored(rel: pathlib.Path, code: str) -> bool:
    import fnmatch
    rel_s = str(rel)
    return any(code in codes for pat, codes in PER_FILE_IGNORES.items()
               if fnmatch.fnmatch(rel_s, pat))


def run_fallback() -> int:
    problems: list = []
    for path in _iter_files():
        text = path.read_text()
        rel = path.relative_to(REPO_ROOT)
        try:
            tree = ast.parse(text)
        except SyntaxError as e:
            problems.append((rel, e.lineno or 0, "E999", e.msg))
            continue
        file_problems: list = []
        _check_lines(rel, text, file_problems)
        _check_bare_noqa(rel, text, file_problems)
        _check_unused_imports(rel, text, tree, file_problems)
        _check_import_order(rel, text, tree, file_problems)
        problems.extend(p for p in file_problems
                        if not _ignored(rel, p[2]))
    for path, line, code, msg in sorted(problems):
        print(f"{path}:{line}: {code} {msg}")
    if problems:
        print(f"\n{len(problems)} problem(s) "
              f"(stdlib fallback linter; install ruff for the full set)")
        return 1
    print("lint clean (stdlib fallback; install ruff for the full set)")
    return 0


def _check_docstrings(rel, tree, problems):
    """Public-def-has-docstring, D-rules-lite: module docstring, public
    class docstrings, public function/method docstrings. Leading
    underscores opt a name (and everything nested in a private class)
    out — private helpers document themselves where it helps, not
    because a linter says so."""
    if ast.get_docstring(tree) is None:
        problems.append((rel, 1, "D100", "public module missing docstring"))

    def visit(body, prefix):
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if node.name.startswith("_"):
                    continue
                if ast.get_docstring(node) is None:
                    problems.append(
                        (rel, node.lineno, "D103",
                         f"public def {prefix}{node.name} missing "
                         f"docstring"))
            elif isinstance(node, ast.ClassDef):
                if node.name.startswith("_"):
                    continue
                if ast.get_docstring(node) is None:
                    problems.append(
                        (rel, node.lineno, "D101",
                         f"public class {node.name} missing docstring"))
                visit(node.body, prefix=f"{node.name}.")

    visit(tree.body, prefix="")


def run_docstring_check() -> int:
    """Enforce docstring coverage on DOCSTRING_TARGETS (both lint
    paths: ruff's D rules are not configured, see module docstring)."""
    files: list[pathlib.Path] = []
    for entry in DOCSTRING_TARGETS:
        p = REPO_ROOT / entry
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    problems: list = []
    for path in files:
        rel = path.relative_to(REPO_ROOT)
        try:
            tree = ast.parse(path.read_text())
        except SyntaxError:
            continue  # E999 is the syntax reporter, not this check
        _check_docstrings(rel, tree, problems)
    for rel, line, code, msg in sorted(problems):
        print(f"{rel}:{line}: {code} {msg}")
    if problems:
        print(f"\n{len(problems)} docstring problem(s) on the public "
              f"service surface (tools/lint.py DOCSTRING_TARGETS)")
        return 1
    return 0


def main() -> int:
    ruff = shutil.which("ruff")
    if ruff:
        rc = subprocess.run([ruff, "check", "."], cwd=REPO_ROOT).returncode
    else:
        rc = run_fallback()
    return rc | run_docstring_check()


if __name__ == "__main__":
    raise SystemExit(main())
