"""Autotune the fused ICP-iteration kernel (DESIGN.md §11).

Sweeps the fused kernel's tiling space — query block ``bn``, candidate
block ``bc``, and the bf16 coarse-distance prune — times one full fused
iteration (moment sweep + O(1) host solve) per config on a synthetic
frame at registration scale, and records the winner:

    PYTHONPATH=src python tools/autotune_fused.py [--m 16384] [--apply]

Writes ``BENCH_fused_autotune.json`` at the repo root (committed next to
the other BENCH baselines). The chosen config is baked into
``FusedConfig`` defaults in ``repro.kernels.fused_icp`` — re-run with
``--apply`` after kernel changes or on new hardware and update the
defaults if the winner moved. The JSON records the backend the sweep ran
on; interpret-mode (CPU) timings rank dispatch cost, not TPU tile
efficiency, so only a TPU run should change the committed defaults.

Every config is also parity-checked against the slowest-common
denominator config (transform diff must stay ≤ 1e-3), so a tiling bug
can never win the sweep.
"""
from __future__ import annotations

import argparse
import itertools
import json
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))
sys.path.insert(0, str(REPO_ROOT))  # benchmarks.common lives at repo root

BN_CANDIDATES = (128, 256, 512)
BC_CANDIDATES = (128, 256)
PRUNE_CANDIDATES = (False, True)


def sweep(m: int = 16_384, samples: int = 4096, seed_frame: int = 5,
          out_json: str | None = None):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import timeit
    from repro.core import ICPParams
    from repro.core.transform import estimate_from_moments
    from repro.data.pointcloud import SceneConfig, frame_pair
    from repro.data.voxelize import build_voxel_grid
    from repro.kernels.fused_icp import DEFAULT_CONFIG, make_fused_fn

    scene = SceneConfig(n_ground=40_000, n_walls=30_000, n_poles=8_000,
                        n_clutter=9_000, extent=40.0, sensor_range=45.0)
    src, dst_full, _ = frame_pair(0, seed_frame, scene, samples)
    rng = np.random.default_rng(0)
    dst = dst_full[rng.choice(dst_full.shape[0], min(m, dst_full.shape[0]),
                              replace=False)]
    srcj = jnp.asarray(src, jnp.float32)
    dstj = jnp.asarray(dst, jnp.float32)

    params = ICPParams()
    voxel = max(1.0, params.max_correspondence_distance)
    grid = jax.jit(
        lambda d: build_voxel_grid(d, voxel, (128, 128, 32)))(dstj)
    jax.block_until_ready(grid.points)

    def iter_fn(bn, bc, prune):
        fused = make_fused_fn(grid, params, bn=bn, bc=bc, prune=prune)

        def step(s):
            mo = fused(s)
            return estimate_from_moments(mo.sw, mo.sp, mo.sq, mo.spq)

        return jax.jit(step)

    T_ref = np.asarray(iter_fn(BN_CANDIDATES[0], BC_CANDIDATES[0],
                               False)(srcj))
    configs = []
    for bn, bc, prune in itertools.product(BN_CANDIDATES, BC_CANDIDATES,
                                           PRUNE_CANDIDATES):
        step = iter_fn(bn, bc, prune)
        T = np.asarray(step(srcj))
        diff = float(np.abs(T - T_ref).max())
        t = timeit(step, srcj, warmup=1, iters=3)
        ok = diff <= 1e-3
        configs.append({"bn": bn, "bc": bc, "prune": prune,
                        "t_iter_s": t, "transform_diff": diff,
                        "parity_ok": ok})
        print(f"bn={bn:4d} bc={bc:4d} prune={int(prune)} "
              f"t={t * 1e3:8.2f} ms diff={diff:.2e}"
              f"{'' if ok else '  PARITY FAIL'}")

    valid = [c for c in configs if c["parity_ok"]]
    if not valid:
        raise RuntimeError("autotune: every config failed parity")
    best = min(valid, key=lambda c: c["t_iter_s"])
    report = {
        "backend": jax.default_backend(),
        "n": int(srcj.shape[0]), "m": int(dstj.shape[0]),
        "gate": params.max_correspondence_distance,
        "configs": configs,
        "best": {k: best[k] for k in ("bn", "bc", "prune", "t_iter_s")},
        "default": {"bn": DEFAULT_CONFIG.bn, "bc": DEFAULT_CONFIG.bc,
                    "prune": DEFAULT_CONFIG.prune},
    }
    report["default_is_best"] = (
        best["bn"] == DEFAULT_CONFIG.bn and best["bc"] == DEFAULT_CONFIG.bc
        and best["prune"] == DEFAULT_CONFIG.prune)
    if out_json:
        pathlib.Path(out_json).write_text(json.dumps(report, indent=2) + "\n")
    print(f"\nbest: bn={best['bn']} bc={best['bc']} "
          f"prune={best['prune']} ({best['t_iter_s'] * 1e3:.2f} ms) "
          f"on backend={report['backend']}"
          + ("" if report["default_is_best"]
             else " — differs from FusedConfig defaults"))
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=16_384,
                    help="target cloud size (default 16384)")
    ap.add_argument("--samples", type=int, default=4096,
                    help="query cloud size (default 4096)")
    ap.add_argument("--out", default=str(REPO_ROOT /
                                         "BENCH_fused_autotune.json"))
    ap.add_argument("--apply", action="store_true",
                    help="exit 1 if the winner differs from the committed "
                         "FusedConfig defaults (reminder to update them)")
    args = ap.parse_args(argv)
    report = sweep(m=args.m, samples=args.samples, out_json=args.out)
    if args.apply and not report["default_is_best"]:
        print("autotune: update FusedConfig defaults in "
              "src/repro/kernels/fused_icp.py", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
