"""Paper Table III reproduction: RMSE parity, ours vs k-d tree CPU baseline,
over 10 synthetic sequences (KITTI stand-ins; see DESIGN.md §7).

Claim validated: accelerated exact-NN ICP matches the software baseline's
registration accuracy within 0.01 m.
"""
from __future__ import annotations

from benchmarks.common import bench_frames, emit
from repro.core import FppsICP
from repro.core.baseline import kdtree_icp


def run(n_seqs: int = 10, samples: int = 2048, scene=None):
    rows = []
    deltas = []
    for seq, (src, dst, T_gt) in enumerate(bench_frames(n_seqs,
                                                        samples=samples,
                                                        scene=scene)):
        reg = FppsICP()
        reg.setInputSource(src)
        reg.setInputTarget(dst)
        reg.setMaxCorrespondenceDistance(1.0)
        reg.setMaxIterationCount(50)
        reg.setTransformationEpsilon(1e-5)
        reg.align()
        ours = reg.getFitnessScore()
        base = kdtree_icp(src, dst).rmse
        deltas.append(abs(ours - base))
        rows.append((f"table3/seq{seq:02d}_rmse", 0.0,
                     f"ours={ours:.4f};kdtree={base:.4f};delta={deltas[-1]:.4f}"))
    rows.append(("table3/max_rmse_delta", 0.0,
                 f"{max(deltas):.4f} (paper claim: <=0.01)"))
    assert max(deltas) <= 0.01, f"accuracy parity violated: {max(deltas)}"
    return rows


if __name__ == "__main__":
    emit(run())
