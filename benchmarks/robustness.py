"""Fault-matrix robustness: recovery cascade ON vs OFF under injected
sensor faults (DESIGN.md §12; writes ``BENCH_robustness.json``).

Protocol: one synthetic odometry stream per fault family. Frames inside a
transient **burst window** are corrupted by ``repro.data.corruption``
(deterministic per (seed, frame, injector)); the frames after the burst
are clean again, so *final* drift measures whether the stream recovered
or was permanently poisoned — the exact failure mode the cascade exists
to prevent: one bad accepted frame contaminates the submap anchor and
every later frame registers against the damage.

Both arms share scans, faults, seeds and the per-frame iteration cap; the
ONLY difference is ``OdometryConfig.recovery``. The OFF arm is the legacy
degenerate/min-inlier guard (which happily accepts a wrong-basin pose
with plausible inlier mass); the ON arm is the health-gated tier ladder.

Per family: final/max drift vs ground truth, failed-frame count (position
error > ``FAIL_ERR_M``), tier/quarantine histograms, and the OFF/ON
improvement ratios. A family "meets 2x" when the cascade at least halves
final drift or the failure rate. A clean arm (no faults, cascade ON) pins
the no-fault cost: its drift must stay within the odometry guard's
absolute bound — the cascade may not tax clean streams.

The benchmark is CI-sized (quick scene, dense-XLA primary engine): the
cascade-vs-legacy differential is architectural, not scene-scale-bound,
and the committed baseline must be cheap enough for the regression guard
to re-run exactly.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks.common import QUICK_SCENE, emit
from repro.core.icp import ICPParams
from repro.core.odometry import OdometryConfig, OdometryPipeline
from repro.data.corruption import apply_faults, parse_fault_spec
from repro.data.pointcloud import SceneConfig, gt_pose, sequence_scans
from repro.data.submap import SubmapParams

JSON_PATH = pathlib.Path("BENCH_robustness.json")

# One spec per fault family — severities sized so the legacy guard
# visibly degrades while the cascade has enough signal left to recover.
# The crop/occlusion/drop severities are past the legacy guard's cliff
# (it diverges or aliases); dropout/noise/ghost at these levels are
# absorbed by the robust kernel in BOTH arms and pin the no-regression
# side of the matrix (the cascade must not tax what ICP already handles).
FAULT_MATRIX = {
    "crop": "crop:0.15",                  # FOV wedge lost (blocked sensor)
    "occlusion": "occlusion:350deg",      # near-total sector blackout
    "drop": "drop",                       # whole frames lost
    "dropout": "dropout:0.85",            # 85% random returns lost
    "noise": "tnoise:0.35",               # heavy-tailed range noise
    "ghost": "ghost:1024",                # coherent dynamic-object blob
}
FAIL_ERR_M = 1.0       # a frame this far off ground truth has failed
BURST = (5, 6, 7, 8)   # transient fault window (frames), recovery after

ROBUST_CONFIG = OdometryConfig(
    engine="xla",
    params=ICPParams(max_iterations=30, max_correspondence_distance=1.0,
                     transformation_epsilon=1e-5,
                     robust_kernel="huber", robust_scale=0.3),
    submap=SubmapParams(voxel_size=0.75, capacity=4096, dims=(96, 96, 36),
                        evict_radius=30.0),
    scan_budget=2048)


def _stream(scans, seq: int, faults, burst, config: OdometryConfig,
            recovery: bool, seed: int) -> dict:
    pipe = OdometryPipeline(config._replace(recovery=recovery))
    t_frames = []
    for f, scan in enumerate(scans):
        if faults is not None and f in burst:
            pts, valid = apply_faults(scan, faults, seed=seed, frame=f)
        else:
            pts, valid = scan, None
        t0 = time.perf_counter()
        pipe.process(pts, valid=valid)
        t_frames.append(time.perf_counter() - t0)
    gt = gt_pose(seq)
    errs = [float(np.linalg.norm(p[:3, 3] - gt(f)[:3, 3]))
            for f, p in enumerate(pipe.poses)]
    steady = t_frames[3:] if len(t_frames) > 3 else t_frames[1:]
    return {
        "final_drift_m": errs[-1],
        "max_drift_m": max(errs),
        "fail_frames": sum(e > FAIL_ERR_M for e in errs),
        "failure_rate": sum(e > FAIL_ERR_M for e in errs) / len(errs),
        "rejected": pipe.rejected_frames(),
        "quarantined": pipe.quarantined_count,
        "recovered": pipe.recovery_count,
        "health": pipe.health_counts(),
        "tiers": {str(k): v for k, v in sorted(pipe.tier_counts().items())},
        "fps": len(steady) / max(sum(steady), 1e-9),
    }


def _improvement(off: float, on: float) -> float:
    """OFF/ON ratio of an error metric; both floored so a perfect ON arm
    (error 0) reports a large-but-finite factor."""
    return (off + 1e-3) / (on + 1e-3)


def run(seq: int = 2, frames: int = 15, families=None, burst=BURST,
        seed: int = 0, scene: SceneConfig | None = None,
        config: OdometryConfig | None = None, out_json: str | None = None):
    """Fault matrix x {cascade ON, cascade OFF} + one clean ON arm."""
    scene = QUICK_SCENE if scene is None else scene
    config = ROBUST_CONFIG if config is None else config
    if families is None:
        families = dict(FAULT_MATRIX)
    elif not isinstance(families, dict):
        families = {k: FAULT_MATRIX[k] for k in families}

    scans = sequence_scans(seq, frames + 1, scene)
    clean = _stream(scans, seq, None, (), config, recovery=True, seed=seed)

    per_family = {}
    for name, spec_str in families.items():
        spec = parse_fault_spec(spec_str)
        off = _stream(scans, seq, spec, burst, config, recovery=False,
                      seed=seed)
        on = _stream(scans, seq, spec, burst, config, recovery=True,
                     seed=seed)
        drift_imp = _improvement(off["final_drift_m"], on["final_drift_m"])
        fail_imp = _improvement(off["failure_rate"], on["failure_rate"])
        per_family[name] = {
            "spec": spec_str,
            "cascade_off": off, "cascade_on": on,
            "drift_improvement": drift_imp,
            "failrate_improvement": fail_imp,
            "meets_2x": bool(drift_imp >= 2.0 or fail_imp >= 2.0),
        }

    summary = {
        "seq": seq, "frames": frames, "burst": list(burst), "seed": seed,
        "engine": config.engine, "fail_err_m": FAIL_ERR_M,
        "clean": clean,
        "per_family": per_family,
        "families_2x": sum(f["meets_2x"] for f in per_family.values()),
        "n_families": len(per_family),
        "drift_improvement_min": min(
            f["drift_improvement"] for f in per_family.values()),
    }
    path = JSON_PATH if out_json is None else pathlib.Path(out_json)
    path.write_text(json.dumps(summary, indent=2))

    rows = [("robustness/clean", 1e6 / clean["fps"],
             f"drift={clean['final_drift_m']:.3f}m;"
             f"quarantined={clean['quarantined']}")]
    for name, fam in per_family.items():
        on, off = fam["cascade_on"], fam["cascade_off"]
        rows.append((f"robustness/{name}", 1e6 / on["fps"],
                     f"on={on['final_drift_m']:.3f}m;"
                     f"off={off['final_drift_m']:.3f}m;"
                     f"drift_x={fam['drift_improvement']:.2f};"
                     f"fail_x={fam['failrate_improvement']:.2f}"))
    rows.append(("robustness/aggregate", 0.0,
                 f"families_2x={summary['families_2x']}"
                 f"/{summary['n_families']}"))
    return rows


def run_quick(out_json: str = "BENCH_robustness_quick.json"):
    """Smoke mode for CI: two families, short stream, scratch JSON.

    The burst sits mid-stream (frames 5-6 of 10) with clean frames on
    both sides — earlier bursts land on a 3-frame map where *neither*
    arm can recover and the smoke reads as a fake cascade regression.
    """
    return run(frames=10, burst=(5, 6),
               families=("crop", "drop"),
               config=ROBUST_CONFIG._replace(
                   params=ROBUST_CONFIG.params._replace(max_iterations=15)),
               out_json=out_json)


if __name__ == "__main__":
    emit(run())
