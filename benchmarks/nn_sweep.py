"""Correspondence-sweep benchmark: brute force vs grid-bucketed NN.

Measures exactly the cost ICP pays per iteration — one full NN sweep of a
4096-point query cloud against an M-point target — for the chunked brute
force (``core.nn_search``) and the voxel-grid searcher
(``core.nn_search_grid``), across target sizes. The grid is built once
outside the timed sweep, matching how the pyramid engine uses it (resident
per frame, amortised over all iterations); its build time is reported as
its own row.

Agreement columns (vs the exact brute result):
  * ``agree_raw``   — fraction of queries with identical d2 anywhere.
  * ``agree_gated`` — fraction agreeing *among queries whose true NN is
    within the ICP gate* (1.0 m). This is the contract that matters for
    registration: with ``voxel >= gate``, disagreements can only come from
    ``max_per_cell`` overflow truncation (dense-surface cells), and the
    mismatched rows still match a same-cell point.

Also registers an end-to-end parity row: the "pyramid" engine vs brute
"xla" ICP final transforms on a synthetic KITTI-like frame pair (the
ISSUE-2 acceptance numbers). Writes ``BENCH_nn.json``.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import ICPParams, get_engine
from repro.core.nn_search import nn_search
from repro.core.nn_search_grid import nn_search_grid
from repro.data.pointcloud import SceneConfig, frame_pair
from repro.data.voxelize import build_voxel_grid

# World dense enough that the range-gated scan exceeds the largest M.
DENSE_SCENE = SceneConfig(n_ground=300_000, n_walls=225_000,
                          n_poles=60_000, n_clutter=65_000)

FULL_SIZES = (16_384, 65_536, 131_072)
QUICK_SIZES = (4_096, 16_384)


def _sweep_case(src, dst, *, max_per_cell, grid_dims, gate=1.0,
                voxel=1.0, rings=1, warmup=1, iters=2, d2_brute=None,
                t_brute=None):
    srcj = jnp.asarray(src, jnp.float32)
    dstj = jnp.asarray(dst, jnp.float32)
    if d2_brute is None:
        brute = jax.jit(lambda s, d: nn_search(s, d, chunk=2048))
        t_brute = timeit(brute, srcj, dstj, warmup=warmup, iters=iters)
        d2_brute, _ = jax.block_until_ready(brute(srcj, dstj))

    build = jax.jit(lambda d: build_voxel_grid(d, voxel, grid_dims))
    t_build = timeit(build, dstj, warmup=warmup, iters=iters)
    grid = build(dstj)
    gsearch = jax.jit(
        lambda s: nn_search_grid(s, grid, max_per_cell=max_per_cell,
                                 rings=rings))
    t_grid = timeit(gsearch, srcj, warmup=warmup, iters=iters)
    d2_g, _ = jax.block_until_ready(gsearch(srcj))

    same = np.abs(np.asarray(d2_g) - np.asarray(d2_brute)) < 1e-6
    in_gate = np.asarray(d2_brute) <= gate * gate
    return {
        "m": int(dst.shape[0]),
        "n": int(src.shape[0]),
        "max_per_cell": int(max_per_cell),
        "voxel": float(voxel),
        "rings": int(rings),
        "t_brute_s": t_brute,
        "t_grid_s": t_grid,
        "t_grid_build_s": t_build,
        "speedup": t_brute / t_grid,
        "agree_raw": float(same.mean()),
        "agree_gated": float(same[in_gate].mean()) if in_gate.any() else 1.0,
        "frac_in_gate": float(in_gate.mean()),
    }, d2_brute


def _icp_parity(src, dst, params):
    """Pyramid / fused-pallas engines vs brute xla engine: final-transform
    agreement (the ISSUE-2 and ISSUE-6 acceptance contracts)."""
    eb = get_engine("xla")
    ep = get_engine("pyramid")
    ef = get_engine("pallas")
    t0 = time.perf_counter()
    rb = eb.register(src, dst, params)
    jax.block_until_ready(rb.T)
    t_b = time.perf_counter() - t0
    t0 = time.perf_counter()
    rp = ep.register(src, dst, params)
    jax.block_until_ready(rp.T)
    t_p = time.perf_counter() - t0
    t0 = time.perf_counter()
    rf = ef.register(src, dst, params._replace(fused=True))
    jax.block_until_ready(rf.T)
    t_f = time.perf_counter() - t0
    Tb, Tp, Tf = np.asarray(rb.T), np.asarray(rp.T), np.asarray(rf.T)
    return {
        "rot_err": float(np.linalg.norm(Tp[:3, :3] - Tb[:3, :3])),
        "trans_err": float(np.linalg.norm(Tp[:3, 3] - Tb[:3, 3])),
        "fused_rot_err": float(np.linalg.norm(Tf[:3, :3] - Tb[:3, :3])),
        "fused_trans_err": float(np.linalg.norm(Tf[:3, 3] - Tb[:3, 3])),
        "t_brute_icp_s": t_b,      # includes compile on first call
        "t_pyramid_icp_s": t_p,
        "t_fused_icp_s": t_f,
        "rmse_brute": float(rb.rmse),
        "rmse_pyramid": float(rp.rmse),
        "rmse_fused": float(rf.rmse),
    }


def run(sizes=FULL_SIZES, samples: int = 4096, max_per_cell: int = 32,
        grid_dims=(128, 128, 32), parity: bool = True, scene=None,
        mitigation: bool = True, out_json: str = "BENCH_nn.json"):
    scene = DENSE_SCENE if scene is None else scene
    src, dst_full, _ = frame_pair(0, 5, scene, samples)
    if dst_full.shape[0] < max(sizes):
        raise ValueError(f"scene scan has {dst_full.shape[0]} points, "
                         f"need {max(sizes)}; use a denser SceneConfig")
    rng = np.random.default_rng(0)
    rows = []
    report = {"sweeps": [], "parity": None}
    from benchmarks.registration_latency import fused_iteration_case
    for m in sizes:
        dst = dst_full[rng.choice(dst_full.shape[0], m, replace=False)]
        case, d2_b = _sweep_case(src, dst, max_per_cell=max_per_cell,
                                 grid_dims=grid_dims)
        # Fused single-pass iteration vs the unfused per-iteration chains
        # (ISSUE-6): same src/dst, resident structures prebuilt.
        fused_rows, fused_case = fused_iteration_case(src, dst)
        case.update({k: v for k, v in fused_case.items()
                     if k not in ("m", "n")})
        report["sweeps"].append(case)
        rows.append((f"nn_sweep/m{m}_brute", case["t_brute_s"] * 1e6,
                     f"M={m};exact"))
        rows.append((f"nn_sweep/m{m}_grid", case["t_grid_s"] * 1e6,
                     f"speedup={case['speedup']:.1f}x;"
                     f"agree_gated={case['agree_gated']:.4f}"))
        rows.append((f"nn_sweep/m{m}_grid_build", case["t_grid_build_s"] * 1e6,
                     "once-per-frame"))
        rows.append((f"nn_sweep/m{m}_fused_iter",
                     case["t_iter_fused_s"] * 1e6,
                     f"speedup_vs_pallas={case['fused_iter_speedup']:.1f}x;"
                     f"vs_grid_chain={case['fused_vs_grid_chain']:.2f}x"))
        if mitigation and m == max(sizes):
            # Overflow mitigation at the densest M: same 1 m exact radius
            # via rings=2 over half-size cells -> ~4x lower cell occupancy
            # (DESIGN.md §8 "exact vs approximate").
            mit, _ = _sweep_case(
                src, dst, max_per_cell=max_per_cell, rings=2, voxel=0.5,
                grid_dims=tuple(2 * d for d in grid_dims),
                d2_brute=d2_b, t_brute=case["t_brute_s"])
            report["sweeps"].append(mit)
            rows.append((f"nn_sweep/m{m}_grid_rings2", mit["t_grid_s"] * 1e6,
                         f"speedup={mit['speedup']:.1f}x;"
                         f"agree_gated={mit['agree_gated']:.4f}"))
    if parity:
        # Standard synthetic KITTI protocol frame pair (DESIGN.md §7).
        psrc, pdst, _ = frame_pair(0, 5, SceneConfig(), samples)
        params = ICPParams(max_iterations=50,
                           max_correspondence_distance=1.0,
                           transformation_epsilon=1e-5)
        par = _icp_parity(psrc, pdst, params)
        report["parity"] = par
        rows.append(("nn_sweep/icp_parity_rot", 0.0,
                     f"{par['rot_err']:.2e} (<=1e-3 target)"))
        rows.append(("nn_sweep/icp_parity_trans", 0.0,
                     f"{par['trans_err']:.2e} (<=1e-3 target)"))
        rows.append(("nn_sweep/icp_parity_fused_rot", 0.0,
                     f"{par['fused_rot_err']:.2e} (<=1e-3 target)"))
        rows.append(("nn_sweep/icp_parity_fused_trans", 0.0,
                     f"{par['fused_trans_err']:.2e} (<=1e-3 target)"))
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    return rows


def run_quick():
    """Smoke-mode: small Ms, no parity loop, throwaway json path."""
    scene = SceneConfig(n_ground=40_000, n_walls=30_000, n_poles=8_000,
                        n_clutter=9_000, extent=40.0, sensor_range=45.0)
    return run(sizes=QUICK_SIZES, samples=1024, parity=False, scene=scene,
               out_json="BENCH_nn_quick.json")


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
