"""Multi-stream service throughput: fleet rounds vs a sequential loop.

The paper's headline number is runtime-weighted across a workload mix
(§IV) — a shared-accelerator claim, not a single-frame one. This
benchmark makes the repo's version of that claim measurable: N odometry
streams through :class:`~repro.serve.registration_service.
RegistrationService` (one compiled fleet round per frame wave) against
the sequential alternative — N standalone per-stream
``OdometryPipeline`` loops fed bit-identical staged frames.

What the service buys on this 1-core CPU container is *host overhead
amortization*: the sequential loop pays per-frame eager dispatches
(scrub + downsample + lattice probe), several ``float()`` sync points,
and a per-frame fuse — roughly a fixed cost per frame regardless of
registration size. A fleet round folds all of that into three batched
executables and one bulk fetch, so the bench sizes registration small
(the streaming regime: warm-started frames need few iterations against
a small local submap) to expose the overhead the service removes. On a
real accelerator the same structure removes MXU idle between streams.

``transformation_epsilon=0`` pins every lane and the sequential path to
the same fixed iteration count, so the comparison isolates execution
shape rather than early-exit luck (same device as the throughput bench).

Also recorded, because they are acceptance criteria, not vibes:

  * retraces after warmup — engine trace-counter delta across the timed
    rounds; MUST be 0 (admissions/drops/retires never change a traced
    shape).
  * parity — max abs pose difference between a service stream and a
    standalone ``OdometryPipeline(svc.stream_config)`` replay of the
    same staged frames; MUST be exactly 0.0 (see DESIGN.md §13).

Writes BENCH_service.json next to the CWD for CI trend tracking
(``--quick`` writes BENCH_service_quick.json to never clobber the
committed baseline).
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks.common import emit
from repro.core import ICPParams
from repro.core.odometry import OdometryConfig, OdometryPipeline
from repro.data.pointcloud import SceneConfig, sequence_scans
from repro.data.submap import SubmapParams
from repro.serve.registration_service import (RegistrationService,
                                              ServiceConfig)

JSON_PATH = pathlib.Path("BENCH_service.json")

# Small scene: the service regime is warm-started streaming against a
# compact local submap, where per-frame host overhead (what the service
# amortizes) is comparable to registration compute.
SERVICE_SCENE = SceneConfig(n_ground=800, n_walls=600, n_poles=150,
                            n_clutter=150, extent=15.0, sensor_range=20.0)
QUICK_SERVICE_SCENE = SceneConfig(n_ground=300, n_walls=220, n_poles=60,
                                  n_clutter=70, extent=12.0,
                                  sensor_range=16.0)


def _bench_odometry(iters: int, budget: int) -> OdometryConfig:
    """Streaming-regime odometry config shared by every path: fixed
    iteration count (eps=0), small downsample budget, compact submap."""
    return OdometryConfig(
        engine="xla", engine_kwargs=(),
        params=ICPParams(max_iterations=iters,
                         max_correspondence_distance=1.0,
                         transformation_epsilon=0.0, chunk=512,
                         robust_kernel="huber", robust_scale=0.3),
        submap=SubmapParams(voxel_size=1.5, capacity=512, dims=(32, 32, 12),
                            evict_radius=12.0),
        scan_voxel=1.5, scan_budget=budget, recovery=False)


def _staged_fleet(svc: RegistrationService, n_streams: int, frames: int,
                  scene: SceneConfig):
    """Per-stream staged (padded, valid) frame lists — the bit-identical
    input both the service and the sequential loops consume."""
    fleet = {}
    for s in range(n_streams):
        scans = sequence_scans(s, frames, scene)
        fleet[f"veh{s}"] = [svc.stage_scan(scan) for scan in scans]
    return fleet


def _run_service(cfg_svc: ServiceConfig, fleet: dict, warm: int,
                 timed: int):
    """Warm the fleet, then time ``timed`` rounds (submit + step + sync).

    Returns (round_times_s, retraces_after_warmup)."""
    svc = RegistrationService(cfg_svc)
    for sid in fleet:
        svc.admit(sid)
    for f in range(warm):
        for sid, staged in fleet.items():
            svc.submit(sid, *staged[f])
        svc.step()
    svc.sync()
    traces_before = svc.engine.trace_count
    rounds = []
    for f in range(warm, warm + timed):
        t0 = time.perf_counter()
        for sid, staged in fleet.items():
            svc.submit(sid, *staged[f])
        svc.step()
        svc.sync()
        rounds.append(time.perf_counter() - t0)
    return rounds, svc.engine.trace_count - traces_before


def _run_sequential(odo: OdometryConfig, fleet: dict, warm: int,
                    timed: int):
    """The baseline: one standalone per-stream pipeline each, processed
    frame-by-frame in a host loop. Returns per-call times (s)."""
    pipes = {sid: OdometryPipeline(odo) for sid in fleet}
    for f in range(warm):
        for sid, staged in fleet.items():
            pipes[sid].process(*staged[f])
    calls = []
    for f in range(warm, warm + timed):
        for sid, staged in fleet.items():
            t0 = time.perf_counter()
            pipes[sid].process(*staged[f])
            calls.append(time.perf_counter() - t0)
    return calls


def _parity_replay(cfg_svc: ServiceConfig, fleet: dict, frames: int):
    """Bit-exactness check: one service stream vs a standalone
    ``OdometryPipeline(stream_config)`` on the same staged frames."""
    svc = RegistrationService(cfg_svc)
    sid = next(iter(fleet))
    svc.admit(sid)
    ref = OdometryPipeline(svc.stream_config)
    worst = 0.0
    for f in range(frames):
        svc.submit(sid, *fleet[sid][f])
        pose_svc, _ = svc.step()[sid]
        pose_ref, _ = ref.process(*fleet[sid][f])
        worst = max(worst, float(np.abs(np.asarray(pose_svc) -
                                        np.asarray(pose_ref)).max()))
    return worst


def run(streams: tuple = (1, 2, 4, 8), frames: int = 12, warm: int = 4,
        iters: int = 4, budget: int = 128, quick: bool = False,
        out_json: str | None = None):
    scene = SERVICE_SCENE
    if quick:
        streams, frames, warm, iters = (4,), 5, 2, 3
        scene = QUICK_SERVICE_SCENE
        if out_json is None:
            # never clobber the committed baseline from smoke mode — the
            # bench-guard diffs against it (scratch name is gitignored)
            out_json = "BENCH_service_quick.json"
    s_max = max(streams)
    odo = _bench_odometry(iters, budget)
    cfg_svc = ServiceConfig(slots=s_max, scan_capacity=2048,
                            max_queue=warm + frames, odometry=odo)
    probe = RegistrationService(cfg_svc)          # stage_scan padder only
    fleet = _staged_fleet(probe, s_max, warm + frames, scene)

    rows, sweep, retraces = [], {}, 0
    for s in streams:
        sub_fleet = dict(list(fleet.items())[:s])
        rounds, delta = _run_service(cfg_svc, sub_fleet, warm, frames)
        if s == s_max:
            retraces = delta
        fps = s * len(rounds) / sum(rounds)
        p99 = float(np.percentile(np.asarray(rounds), 99) * 1e3)
        sweep[s] = {"aggregate_fps": fps, "p99_frame_ms": p99}
        rows.append((f"service/fleet_s{s}", sum(rounds) / len(rounds) /
                     s * 1e6, f"{fps:.1f} frames/s;p99={p99:.1f}ms"))

    calls = _run_sequential(odo, fleet, warm, frames)
    seq_fps = len(calls) / sum(calls)
    seq_p99 = float(np.percentile(np.asarray(calls), 99) * 1e3)
    rows.append((f"service/sequential_s{s_max}",
                 sum(calls) / len(calls) * 1e6,
                 f"{seq_fps:.1f} frames/s;p99={seq_p99:.1f}ms"))

    fps_ratio = sweep[s_max]["aggregate_fps"] / seq_fps
    p99_ratio = sweep[s_max]["p99_frame_ms"] / seq_p99
    parity = _parity_replay(cfg_svc, fleet, min(frames, 6))

    summary = {
        "streams": list(streams), "frames": frames, "warm": warm,
        "iters": iters, "scan_budget": budget,
        "sweep": {str(s): v for s, v in sweep.items()},
        "sequential_fps": seq_fps, "sequential_p99_ms": seq_p99,
        "aggregate_fps": sweep[s_max]["aggregate_fps"],
        "p99_frame_ms": sweep[s_max]["p99_frame_ms"],
        "fps_ratio": fps_ratio, "p99_latency_ratio": p99_ratio,
        "retraces_after_warmup": retraces, "parity_max_abs": parity,
    }
    path = JSON_PATH if out_json is None else pathlib.Path(out_json)
    path.write_text(json.dumps(summary, indent=2))

    rows += [
        (f"service/fps_ratio_s{s_max}", 0.0,
         f"{fps_ratio:.2f}x sequential (must be >=2x at 8 streams)"),
        (f"service/p99_latency_ratio_s{s_max}", 0.0,
         f"{p99_ratio:.2f}x sequential per-frame p99"),
        ("service/retraces_after_warmup", 0.0,
         f"{retraces} (must be 0)"),
        ("service/parity_max_abs", 0.0,
         f"{parity:.1e} vs standalone pipeline (must be 0.0)"),
    ]
    assert retraces == 0, f"service retraced after warmup: {retraces}"
    assert parity == 0.0, f"service/pipeline parity broke: {parity}"
    if not quick:
        assert fps_ratio >= 2.0, \
            f"aggregate fps only {fps_ratio:.2f}x sequential at {s_max}"
    return rows


if __name__ == "__main__":
    emit(run())
