"""Convergence benchmark: point-to-point vs point-to-plane vs pyramid.

The per-iteration speedups already shipped (grid NN, batching) multiply
with *fewer iterations*; this suite measures exactly that trade across
perturbation magnitudes on two synthetic scenes:

  * ``planar`` — ground plane + building facades only (the structured
    geometry KITTI is full of, and where point-to-point ICP slides along
    surfaces for many iterations);
  * ``clean``  — the standard synthetic KITTI mix (poles + clutter too),
    used to pin transform *parity* between the minimisers.

Per (scene, magnitude) case it runs, through the engine layer with
``transformation_epsilon`` convergence:

  * xla / point_to_point        (the paper's minimiser — baseline)
  * xla / point_to_plane        (DESIGN.md §9)
  * pyramid / point_to_plane    (coarse p2p capture + grid-NN plane polish)

and reports iterations-to-epsilon, wall-clock per registration (compiled,
steady-state), and the rot/trans agreement of every variant against the
baseline's fixed point. Writes ``BENCH_convergence.json`` with the ISSUE-3
acceptance fields:

  * ``parity_ok``      — p2plane matches p2p within rot/trans <= 1e-3 on
    the clean scene;
  * ``iter_ratio_min`` — min over planar cases of p2p/p2plane iterations
    (acceptance: >= 2).
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import ICPParams, get_engine
from repro.core.transform import rotation_from_axis_angle, transform_points
from repro.data.pointcloud import SceneConfig, make_world, scan_frame

JSON_PATH = "BENCH_convergence.json"

# Perturbation magnitudes (metres of translation; rotation scales along).
# Frame-to-frame LiDAR motion is ~0.6-2.5 m (KITTI highway: 2.5 m/frame);
# below ~0.5 m both minimisers converge in a handful of iterations and the
# iteration story is flat — the sweep starts where plain ICP starts to
# slide.
FULL_MAGS = (0.6, 0.9, 1.2)
QUICK_MAGS = (0.6,)

PLANAR_SCENE = SceneConfig(n_ground=14_000, n_walls=10_000, n_poles=0,
                           n_clutter=0, extent=45.0, sensor_range=45.0)
CLEAN_SCENE = SceneConfig(n_ground=9_000, n_walls=6_500, n_poles=1_800,
                          n_clutter=1_700, extent=45.0, sensor_range=45.0)

PARITY_TOL = 1e-3  # rot/trans agreement target (acceptance criterion)


def _scan(scene: SceneConfig, seed: int = 0) -> np.ndarray:
    world = make_world(seed, scene)
    return scan_frame(world, seed, 0, scene, seed)


def _perturbed_source(dst: np.ndarray, mag: float, samples: int,
                      seed: int = 0):
    """Sample the scan and displace it by a known transform of magnitude
    ``mag`` (translation metres; rotation 0.06·mag rad about a tilted
    axis), plus sensor-grade noise."""
    rng = np.random.default_rng(seed)
    R = np.asarray(rotation_from_axis_angle(
        jnp.asarray([0.15, 0.25, 1.0], jnp.float32),
        jnp.asarray(0.06 * mag, jnp.float32)))
    T_gt = np.eye(4, dtype=np.float32)
    T_gt[:3, :3] = R
    T_gt[:3, 3] = [0.8 * mag, 0.6 * mag, 0.1 * mag]
    sel = rng.choice(dst.shape[0], min(samples, dst.shape[0]), replace=False)
    src = np.asarray(transform_points(
        jnp.linalg.inv(jnp.asarray(T_gt, jnp.float32)),
        jnp.asarray(dst[sel]))).copy()
    src += rng.normal(0.0, 0.01, src.shape).astype(np.float32)
    return src, T_gt


def _variants(params: ICPParams):
    plane = params._replace(minimizer="point_to_plane")
    return (
        ("p2p", "xla", params),
        ("p2plane", "xla", plane),
        ("pyramid_p2plane", "pyramid", plane),
    )


def _run_case(scene_name: str, dst: np.ndarray, mag: float, samples: int,
              params: ICPParams, timing_iters: int):
    src, T_gt = _perturbed_source(dst, mag, samples)
    case = {"scene": scene_name, "magnitude": float(mag),
            "n": int(src.shape[0]), "m": int(dst.shape[0]),
            "variants": {}}
    T_base = None
    for name, engine_name, p in _variants(params):
        engine = get_engine(engine_name)
        res = engine.register(src, dst, p)          # warmup + result
        jax.block_until_ready(res.T)
        t = timeit(lambda e=engine, pp=p: e.register(src, dst, pp),
                   warmup=0, iters=timing_iters)
        T = np.asarray(res.T)
        row = {
            "iterations": int(res.iterations),
            "converged": bool(res.converged),
            "rmse": float(res.rmse),
            "wall_s": float(t),
            "trans_err_gt": float(np.linalg.norm(T[:3, 3] - T_gt[:3, 3])),
        }
        if T_base is None:
            T_base = T
        else:
            row["rot_diff_vs_p2p"] = float(
                np.linalg.norm(T[:3, :3] - T_base[:3, :3]))
            row["trans_diff_vs_p2p"] = float(
                np.linalg.norm(T[:3, 3] - T_base[:3, 3]))
        case["variants"][name] = row
    v = case["variants"]
    case["iter_ratio"] = v["p2p"]["iterations"] / max(
        v["p2plane"]["iterations"], 1)
    case["speedup_wall"] = v["p2p"]["wall_s"] / v["p2plane"]["wall_s"]
    case["speedup_wall_pyramid"] = (v["p2p"]["wall_s"]
                                    / v["pyramid_p2plane"]["wall_s"])
    return case


def run(mags=FULL_MAGS, samples: int = 1024, timing_iters: int = 2,
        planar_scene: SceneConfig | None = None,
        clean_scene: SceneConfig | None = None,
        out_json: str = JSON_PATH):
    planar_scene = PLANAR_SCENE if planar_scene is None else planar_scene
    clean_scene = CLEAN_SCENE if clean_scene is None else clean_scene
    params = ICPParams(max_iterations=80, max_correspondence_distance=1.0,
                       transformation_epsilon=1e-6)
    report = {"cases": [], "parity_tol": PARITY_TOL}
    rows = []

    dst_planar = _scan(planar_scene, seed=0)
    dst_clean = _scan(clean_scene, seed=1)
    for scene_name, dst in (("planar", dst_planar), ("clean", dst_clean)):
        for mag in mags:
            case = _run_case(scene_name, dst, mag, samples, params,
                             timing_iters)
            report["cases"].append(case)
            v = case["variants"]
            rows.append((
                f"convergence/{scene_name}_m{mag}_p2p",
                v["p2p"]["wall_s"] * 1e6,
                f"iters={v['p2p']['iterations']}"))
            rows.append((
                f"convergence/{scene_name}_m{mag}_p2plane",
                v["p2plane"]["wall_s"] * 1e6,
                f"iters={v['p2plane']['iterations']};"
                f"iter_ratio={case['iter_ratio']:.2f}x;"
                f"wall_speedup={case['speedup_wall']:.2f}x"))
            rows.append((
                f"convergence/{scene_name}_m{mag}_pyramid_p2plane",
                v["pyramid_p2plane"]["wall_s"] * 1e6,
                f"iters={v['pyramid_p2plane']['iterations']};"
                f"wall_speedup={case['speedup_wall_pyramid']:.2f}x"))

    planar_cases = [c for c in report["cases"] if c["scene"] == "planar"]
    clean_cases = [c for c in report["cases"] if c["scene"] == "clean"]
    report["iter_ratio_min"] = min(c["iter_ratio"] for c in planar_cases)
    report["iter_ratio_mean"] = float(np.mean(
        [c["iter_ratio"] for c in planar_cases]))
    parity_rot = max(c["variants"]["p2plane"]["rot_diff_vs_p2p"]
                     for c in clean_cases)
    parity_trans = max(c["variants"]["p2plane"]["trans_diff_vs_p2p"]
                       for c in clean_cases)
    report["parity_rot_max"] = parity_rot
    report["parity_trans_max"] = parity_trans
    report["parity_ok"] = bool(parity_rot <= PARITY_TOL
                               and parity_trans <= PARITY_TOL)
    report["iter_ratio_ok"] = bool(report["iter_ratio_min"] >= 2.0)
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    rows.append(("convergence/parity_rot_max", 0.0,
                 f"{parity_rot:.2e} (<= {PARITY_TOL} target)"))
    rows.append(("convergence/parity_trans_max", 0.0,
                 f"{parity_trans:.2e} (<= {PARITY_TOL} target)"))
    rows.append(("convergence/iter_ratio_min", 0.0,
                 f"{report['iter_ratio_min']:.2f}x (>= 2x target)"))
    return rows


def run_quick():
    """Smoke mode: one magnitude, reduced scenes, throwaway json path."""
    planar = SceneConfig(n_ground=5_000, n_walls=3_600, n_poles=0,
                         n_clutter=0, extent=35.0, sensor_range=40.0)
    clean = SceneConfig(n_ground=3_000, n_walls=2_200, n_poles=600,
                        n_clutter=700, extent=30.0, sensor_range=35.0)
    return run(mags=QUICK_MAGS, samples=512, timing_iters=1,
               planar_scene=planar, clean_scene=clean,
               out_json="BENCH_convergence_quick.json")


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
