"""Shared benchmark utilities: timing, hardware model, synthetic frames."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.data.pointcloud import SceneConfig, frame_pair

# Reduced-but-representative scene for CPU benchmarking (full 130k-point
# frames take minutes per ICP run on this 1-core container; structure and
# per-point candidate count scale linearly and are reported separately).
BENCH_SCENE = SceneConfig(n_ground=18_000, n_walls=13_500, n_poles=3_600,
                          n_clutter=3_900, extent=50.0, sensor_range=50.0)

# Tiny scene for --quick smoke runs: every benchmark entry point must
# complete in seconds so CI can exercise them all.
QUICK_SCENE = SceneConfig(n_ground=3_000, n_walls=2_200, n_poles=600,
                          n_clutter=700, extent=30.0, sensor_range=35.0)

# Power/constants for the modeled (projected) columns — labeled as such.
POWER = {
    "xeon_6246r_paper_w": 16.3,   # paper §IV-D: measured CPU power
    "fpps_total_w": 28.0,         # paper §IV-D: FPGA 14+14 + 2.3 host
    "tpu_v5e_chip_w": 200.0,      # public v5e board-power estimates
}


def timeit(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time (s) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_frames(n_seqs: int = 10, frame: int = 5, samples: int = 4096,
                 scene: SceneConfig | None = None):
    """One frame-pair per synthetic sequence (stand-ins for KITTI 00-09)."""
    scene = BENCH_SCENE if scene is None else scene
    out = []
    for seq in range(n_seqs):
        out.append(frame_pair(seq, frame, scene, samples))
    return out


def emit(rows):
    """Print the harness CSV contract: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
