"""Paper §IV-D reproduction: power efficiency (frames/s/W), modeled.

The paper reports 8.58x higher power efficiency for CPU+FPGA (28 W total)
vs the Xeon baseline (16.3 W measured package power). We reproduce the
metric structure with:
  * CPU column: measured k-d tree ICP latency on this host x the paper's
    16.3 W figure,
  * TPU column: roofline-projected v5e per-frame latency x a 200 W chip
    budget (public v5e estimates).
Both clearly labeled as modeled — no power can be measured in this
container.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import POWER, bench_frames, emit, timeit
from benchmarks.registration_latency import _project_v5e_frame_s
from repro.core.baseline import kdtree_icp


def run(n_seqs: int = 3, samples: int = 2048, iters: int = 50, scene=None):
    rows = []
    effs = []
    for seq, (src, dst, _) in enumerate(bench_frames(n_seqs,
                                                     samples=samples,
                                                     scene=scene)):
        t_cpu = timeit(lambda: kdtree_icp(src, dst, iters), warmup=0, iters=1)
        t_tpu = _project_v5e_frame_s(src.shape[0], dst.shape[0], iters)
        eff_cpu = 1.0 / (t_cpu * POWER["xeon_6246r_paper_w"])   # frames/J
        eff_tpu = 1.0 / (t_tpu * POWER["tpu_v5e_chip_w"])
        effs.append(eff_tpu / eff_cpu)
        rows.append((f"power/seq{seq:02d}", 0.0,
                     f"cpu={eff_cpu:.2f}f/J;tpu_model={eff_tpu:.2f}f/J;"
                     f"ratio={effs[-1]:.2f}x"))
    rows.append(("power/mean_efficiency_gain_modeled", 0.0,
                 f"{np.mean(effs):.1f}x (paper: 8.58x, FPGA 28W vs CPU 16.3W)"))
    return rows


if __name__ == "__main__":
    emit(run())
