"""Frames/sec: looped per-frame FppsICP vs one batched register_batch call.

The paper's pitch is per-frame latency; the production pitch of the unified
engine layer is *throughput* — many frame pairs per second through one
resident executable. This benchmark measures both execution shapes on
identical inputs and identical ICP parameters:

  * looped  — one ``FppsICP.align()`` per frame pair. The engine's
    persistent cache means this compiles once (same shape bucket), so the
    loop pays only per-call dispatch + per-frame host round-trips.
  * batched — one ``register_batch`` over the whole stack: a single device
    program, one dispatch, one round-trip.

``transformation_epsilon=0`` pins both paths to the same fixed iteration
count (the paper's fixed-cap regime), so the speedup isolates the batching
effect rather than early-exit luck. Agreement between the two paths is
reported and must stay within 1e-4.

Default sizes are deliberately small: on this 1-core CPU container the
observable cost of per-frame execution is dispatch + host round-trip
overhead (several ms/call), which is exactly the inter-frame gap the
batched engine removes — the CPU-visible analogue of the idle MXU between
frames that motivates the engine layer. At KITTI scale the per-frame
compute hides the effect in wall clock here, while on a real TPU it
reappears as MXU idle.

Also writes BENCH_throughput.json next to the CWD for CI trend tracking,
and appends the scale-out device-sweep rows (aggregate fleet frames/s vs
device count, per-device bytes per resident submap fp32 vs fp16) from the
committed BENCH_scaleout.json — pass ``device_sweep=True`` to re-measure
them live via the forced-8-device subprocess instead.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import FppsICP, ICPParams, get_engine
from repro.core.transform import random_rigid_transform, transform_points

JSON_PATH = pathlib.Path("BENCH_throughput.json")


def _make_pairs(batch: int, n: int, m: int, seed: int = 0):
    keys = jax.random.split(jax.random.PRNGKey(seed), batch)
    pairs = []
    for k in keys:
        ka, kb, kc = jax.random.split(k, 3)
        dst = jax.random.uniform(ka, (m, 3), minval=-10.0, maxval=10.0)
        T = random_rigid_transform(kb, max_angle=0.1, max_translation=0.3)
        src = transform_points(jnp.linalg.inv(T), dst)[:n]
        src = src + 0.002 * jax.random.normal(kc, src.shape)
        pairs.append((np.asarray(src), np.asarray(dst)))
    return pairs


def _device_sweep_rows(remeasure: bool):
    """The ROADMAP's device-sweep rows: aggregate frames/s vs device
    count plus per-device memory per resident submap (fp32 vs fp16).

    By default reads the committed BENCH_scaleout.json (the sweep needs a
    forced 8-device subprocess — see benchmarks.device_sweep — and its
    median-of-3 timing convention makes it minutes, not seconds).
    ``remeasure=True`` respawns the sweep instead of reading the file.
    """
    scaleout = pathlib.Path(__file__).parent.parent / "BENCH_scaleout.json"
    if remeasure:
        from benchmarks import device_sweep
        s = device_sweep.run_subprocess(quick=True)
    elif scaleout.exists():
        s = json.loads(scaleout.read_text())
    else:
        return []
    rows = [
        (f"throughput/device_sweep_d{d}",
         1e6 / s["sweep"][str(d)]["aggregate_fps"]
         * d * s["lanes_per_device"],
         f"{s['sweep'][str(d)]['aggregate_fps']:.1f} frames/s aggregate;"
         f"{d * s['lanes_per_device']} streams"
         + ("" if remeasure else " (committed BENCH_scaleout.json)"))
        for d in s["devices"]
    ]
    rows.append(("throughput/device_submap_bytes", 0.0,
                 f"fp32={s['bytes_per_submap_fp32']}B "
                 f"fp16={s['bytes_per_submap_fp16']}B per resident submap;"
                 f"{s['submaps_per_gib_fp16']} fp16 submaps/GiB/device"))
    return rows


def run(batch: int = 16, n: int = 128, m: int = 256, iters: int = 8,
        quick: bool = False, device_sweep: bool = False,
        out_json: str | None = None):
    if quick:
        batch, n, m, iters = 8, 128, 256, 6
        if out_json is None:
            # never clobber the committed baseline from smoke mode — the
            # bench-guard diffs against it (scratch name is gitignored)
            out_json = "BENCH_throughput_quick.json"
    assert batch >= 8, "throughput claim is defined at batch >= 8"
    pairs = _make_pairs(batch, n, m)
    params = ICPParams(max_iterations=iters, transformation_epsilon=0.0,
                       chunk=min(1024, m))

    # -- looped path: per-frame Table-I API, persistent engine cache -------
    reg = FppsICP(chunk=params.chunk)
    reg.setMaxCorrespondenceDistance(params.max_correspondence_distance)
    reg.setMaxIterationCount(iters)
    reg.setTransformationEpsilon(0.0)

    def loop_all():
        Ts = []
        for src, dst in pairs:
            reg.setInputSource(src)
            reg.setInputTarget(dst)
            Ts.append(reg.align())
        return Ts

    T_loop = loop_all()                      # warmup: compile once
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        T_loop = loop_all()
        times.append(time.perf_counter() - t0)
    t_loop = float(np.median(times))

    # -- batched path: one compiled program for the whole stack ------------
    engine = get_engine("xla", chunk=params.chunk)
    src_b = jnp.stack([jnp.asarray(s, jnp.float32) for s, _ in pairs])
    dst_b = jnp.stack([jnp.asarray(d, jnp.float32) for _, d in pairs])
    res = engine.register_batch(src_b, dst_b, params)    # warmup
    jax.block_until_ready(res.T)
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        res = engine.register_batch(src_b, dst_b, params)
        jax.block_until_ready(res.T)
        times.append(time.perf_counter() - t0)
    t_batch = float(np.median(times))

    fps_loop = batch / t_loop
    fps_batch = batch / t_batch
    speedup = fps_batch / fps_loop
    agreement = max(float(np.abs(np.asarray(res.T[i]) - T_loop[i]).max())
                    for i in range(batch))

    summary = {
        "batch": batch, "n": n, "m": m, "iters": iters,
        "looped_fps": fps_loop, "batched_fps": fps_batch,
        "speedup": speedup, "max_abs_transform_diff": agreement,
    }
    path = JSON_PATH if out_json is None else pathlib.Path(out_json)
    path.write_text(json.dumps(summary, indent=2))

    rows = [
        (f"throughput/looped_b{batch}", t_loop / batch * 1e6,
         f"{fps_loop:.2f} frames/s"),
        (f"throughput/batched_b{batch}", t_batch / batch * 1e6,
         f"{fps_batch:.2f} frames/s;speedup={speedup:.2f}x"),
        ("throughput/batch_vs_loop_agreement", 0.0,
         f"max|dT|={agreement:.2e} (must be <=1e-4)"),
    ]
    assert agreement <= 1e-4, f"batch and loop disagree: {agreement}"
    rows += _device_sweep_rows(remeasure=device_sweep)
    return rows


if __name__ == "__main__":
    emit(run())
