"""Frames/sec: looped per-frame FppsICP vs one batched register_batch call.

The paper's pitch is per-frame latency; the production pitch of the unified
engine layer is *throughput* — many frame pairs per second through one
resident executable. This benchmark measures both execution shapes on
identical inputs and identical ICP parameters:

  * looped  — one ``FppsICP.align()`` per frame pair. The engine's
    persistent cache means this compiles once (same shape bucket), so the
    loop pays only per-call dispatch + per-frame host round-trips.
  * batched — one ``register_batch`` over the whole stack: a single device
    program, one dispatch, one round-trip.

``transformation_epsilon=0`` pins both paths to the same fixed iteration
count (the paper's fixed-cap regime), so the speedup isolates the batching
effect rather than early-exit luck. Agreement between the two paths is
reported and must stay within 1e-4.

Default sizes are deliberately small: on this 1-core CPU container the
observable cost of per-frame execution is dispatch + host round-trip
overhead (several ms/call), which is exactly the inter-frame gap the
batched engine removes — the CPU-visible analogue of the idle MXU between
frames that motivates the engine layer. At KITTI scale the per-frame
compute hides the effect in wall clock here, while on a real TPU it
reappears as MXU idle.

Also writes BENCH_throughput.json next to the CWD for CI trend tracking.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import FppsICP, ICPParams, get_engine
from repro.core.transform import random_rigid_transform, transform_points

JSON_PATH = pathlib.Path("BENCH_throughput.json")


def _make_pairs(batch: int, n: int, m: int, seed: int = 0):
    keys = jax.random.split(jax.random.PRNGKey(seed), batch)
    pairs = []
    for k in keys:
        ka, kb, kc = jax.random.split(k, 3)
        dst = jax.random.uniform(ka, (m, 3), minval=-10.0, maxval=10.0)
        T = random_rigid_transform(kb, max_angle=0.1, max_translation=0.3)
        src = transform_points(jnp.linalg.inv(T), dst)[:n]
        src = src + 0.002 * jax.random.normal(kc, src.shape)
        pairs.append((np.asarray(src), np.asarray(dst)))
    return pairs


def run(batch: int = 16, n: int = 128, m: int = 256, iters: int = 8,
        quick: bool = False, out_json: str | None = None):
    if quick:
        batch, n, m, iters = 8, 128, 256, 6
        if out_json is None:
            # never clobber the committed baseline from smoke mode — the
            # bench-guard diffs against it (scratch name is gitignored)
            out_json = "BENCH_throughput_quick.json"
    assert batch >= 8, "throughput claim is defined at batch >= 8"
    pairs = _make_pairs(batch, n, m)
    params = ICPParams(max_iterations=iters, transformation_epsilon=0.0,
                       chunk=min(1024, m))

    # -- looped path: per-frame Table-I API, persistent engine cache -------
    reg = FppsICP(chunk=params.chunk)
    reg.setMaxCorrespondenceDistance(params.max_correspondence_distance)
    reg.setMaxIterationCount(iters)
    reg.setTransformationEpsilon(0.0)

    def loop_all():
        Ts = []
        for src, dst in pairs:
            reg.setInputSource(src)
            reg.setInputTarget(dst)
            Ts.append(reg.align())
        return Ts

    T_loop = loop_all()                      # warmup: compile once
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        T_loop = loop_all()
        times.append(time.perf_counter() - t0)
    t_loop = float(np.median(times))

    # -- batched path: one compiled program for the whole stack ------------
    engine = get_engine("xla", chunk=params.chunk)
    src_b = jnp.stack([jnp.asarray(s) for s, _ in pairs])
    dst_b = jnp.stack([jnp.asarray(d) for _, d in pairs])
    res = engine.register_batch(src_b, dst_b, params)    # warmup
    jax.block_until_ready(res.T)
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        res = engine.register_batch(src_b, dst_b, params)
        jax.block_until_ready(res.T)
        times.append(time.perf_counter() - t0)
    t_batch = float(np.median(times))

    fps_loop = batch / t_loop
    fps_batch = batch / t_batch
    speedup = fps_batch / fps_loop
    agreement = max(float(np.abs(np.asarray(res.T[i]) - T_loop[i]).max())
                    for i in range(batch))

    summary = {
        "batch": batch, "n": n, "m": m, "iters": iters,
        "looped_fps": fps_loop, "batched_fps": fps_batch,
        "speedup": speedup, "max_abs_transform_diff": agreement,
    }
    path = JSON_PATH if out_json is None else pathlib.Path(out_json)
    path.write_text(json.dumps(summary, indent=2))

    rows = [
        (f"throughput/looped_b{batch}", t_loop / batch * 1e6,
         f"{fps_loop:.2f} frames/s"),
        (f"throughput/batched_b{batch}", t_batch / batch * 1e6,
         f"{fps_batch:.2f} frames/s;speedup={speedup:.2f}x"),
        ("throughput/batch_vs_loop_agreement", 0.0,
         f"max|dT|={agreement:.2e} (must be <=1e-4)"),
    ]
    assert agreement <= 1e-4, f"batch and loop disagree: {agreement}"
    return rows


if __name__ == "__main__":
    emit(run())
