"""Summarise dry-run artifacts into the §Dry-run / §Roofline tables.

    PYTHONPATH=src python -m benchmarks.roofline_report [--dir results/dryrun]

Emits markdown to stdout (EXPERIMENTS.md embeds the output) and the bench
CSV rows when called from benchmarks.run.
"""
from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load(dir_: pathlib.Path):
    recs = {}
    for p in sorted(dir_.glob("*.json")):
        try:
            recs[p.stem] = json.loads(p.read_text())
        except json.JSONDecodeError:
            continue
    return recs


def markdown(dir_: pathlib.Path = RESULTS, mesh: str = "single") -> str:
    recs = load(dir_)
    lines = [
        "| arch | shape | GB/dev | fits 16G | compute s | memory s | "
        "collective s | dominant | useful frac | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for stem, rec in recs.items():
        if not stem.endswith(f"__{mesh}"):
            continue
        arch, shape, _ = stem.split("__")
        if rec.get("skipped"):
            lines.append(f"| {arch} | {shape} | — | — | — | — | — | "
                         f"skipped | — | — |")
            continue
        if rec.get("status") != "ok":
            lines.append(f"| {arch} | {shape} | — | — | — | — | — | "
                         f"ERROR | — | — |")
            continue
        m = rec["memory"]
        r = rec["roofline"]
        gb = (m["argument_bytes"] + m["temp_bytes"]) / 2 ** 30
        lines.append(
            f"| {arch} | {shape} | {gb:.1f} | "
            f"{'yes' if m['fits_v5e_16g'] else 'NO'} | "
            f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | {r['dominant']} | "
            f"{r['useful_fraction']:.3f} | {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def fused_rows(n: int = 4096, ck: int = 27 * 32):
    """Static roofline of the fused ICP iteration vs the separate-op chain
    (DESIGN.md §11): v5e dominant-term time from the kernel cost model —
    the same kind of MODEL row as the projected Table IV column.
    """
    from repro.kernels.fused_icp import fused_cost_model
    from repro.roofline.report import V5E
    rows = []
    for plane, tag in ((False, "p2p"), (True, "p2plane")):
        cost = fused_cost_model(n, ck, plane=plane)
        for kind in ("fused", "chain"):
            c = cost[kind]
            compute_s = c["flops"] / V5E["peak_flops_bf16"]
            memory_s = c["hbm_bytes"] / V5E["hbm_bw"]
            dominant = "compute" if compute_s >= memory_s else "memory"
            rows.append((f"roofline/fused_icp_{tag}_{kind}_v5e_projected",
                         max(compute_s, memory_s) * 1e6,
                         f"dominant={dominant};"
                         f"intensity={c['flop_per_byte']:.2f}fl/B"))
        rows.append((f"roofline/fused_icp_{tag}_hbm_ratio", 0.0,
                     f"{cost['hbm_ratio']:.2f}x less HBM traffic fused"))
    return rows


def run():
    """Bench-CSV rows: one per completed cell (single-pod mesh), plus the
    static fused-iteration roofline."""
    rows = []
    for stem, rec in load(RESULTS).items():
        if rec.get("status") != "ok" or not stem.endswith("__single"):
            continue
        r = rec["roofline"]
        rows.append((f"roofline/{stem}", r["step_time_s"] * 1e6,
                     f"dominant={r['dominant']};frac={r['roofline_fraction']:.3f}"))
    rows.extend(fused_rows())
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(RESULTS))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()
    print(markdown(pathlib.Path(args.dir), args.mesh))
