"""Odometry drift + streaming throughput: scan-to-map vs frame-to-frame.

The paper's headline numbers (35x peak, 15.95x *runtime-weighted*, §IV)
are measured on KITTI odometry streams, and the weighting matters: each
sequence's speedup counts in proportion to its share of total runtime, so
long sequences dominate exactly as they dominate a real deployment. This
benchmark runs the two execution shapes the repo supports over the same
resampled synthetic streams, at the SAME per-frame iteration cap:

  * **frame_to_frame** — the classic chain: consecutive-pair
    registrations in one batched ``register_pairs`` call, poses composed
    on the host. Per-pair error compounds into a random walk.
  * **scan_to_map** — the streaming ``OdometryPipeline``: rolling submap
    target, constant-velocity warm starts, degenerate-frame rejection.

Reported per sequence: final/max trajectory drift vs ground truth and
steady-state frames/s (first frames excluded — they pay the compile).
Aggregates mirror the paper's weighting:

  * ``fps_weighted`` — runtime-weighted mean of per-sequence scan-to-map
    frames/s (weights = steady-state runtime share, i.e. total steady
    frames / total steady time — compile frames excluded on both sides,
    so the aggregate and the per-sequence fps measure the same regime).
  * ``runtime_weighted_speedup`` — per-sequence fps speedup of the
    streaming pipeline over the batched chain, weighted by each
    sequence's share of the chain's runtime (the §IV 15.95x recipe).
  * ``warm_iter_speedup`` — mean-iteration ratio of a motion-model-off
    stream (each frame starts from the *previous pose*) over the
    constant-velocity warm-started one (first sequence; same executable,
    so the ablation costs only steady-state time).

Also writes ``BENCH_odometry.json`` (committed baseline;
``benchmarks.check_regression`` re-runs this config and guards drift,
warm-start advantage, and the weighted throughput).
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import QUICK_SCENE, emit
from repro.core import ICPParams, get_engine
from repro.core.odometry import OdometryConfig, OdometryPipeline
from repro.data.pointcloud import (SceneConfig, gt_pose,
                                   sample_consecutive_pairs, sequence_scans)
from repro.data.submap import SubmapParams

JSON_PATH = pathlib.Path("BENCH_odometry.json")

# Mid-size scene: big enough that drift dynamics are real (walls + ground
# + clutter at LiDAR-ish density after voxel downsampling), small enough
# that the guard can re-run the full config in CI minutes on 1 CPU core.
ODO_SCENE = SceneConfig(n_ground=4_000, n_walls=3_000, n_poles=800,
                        n_clutter=900, extent=30.0, sensor_range=35.0)
# Submap sized to the scene: the 72 m x/y extent covers the 30 m
# eviction ball (2r = 60 m), and the +-13.5 m z extent covers every real
# point — the scene's tallest walls reach 12 m above the ego plane (the
# eviction sphere itself never fills in z; points don't exist at +-30 m).
# Capacity sits comfortably above the occupied-voxel count so the fuse
# never truncates (see OdometryConfig docstring).
ODO_SUBMAP = SubmapParams(voxel_size=0.75, capacity=12_288,
                          dims=(96, 96, 36), evict_radius=30.0)


def _drift(poses_t: list[np.ndarray], seq: int) -> tuple[float, float]:
    """(final, max) translation drift of a frame-0-anchored trajectory."""
    gt = gt_pose(seq)
    errs = [float(np.linalg.norm(t - gt(f)[:3, 3]))
            for f, t in enumerate(poses_t)]
    return errs[-1], max(errs)


def _run_scan_to_map(scans, seq: int, engine: str, params: ICPParams,
                     config: OdometryConfig, warm: bool) -> dict:
    pipe = OdometryPipeline(config._replace(
        engine=engine, params=params, motion_model=warm))
    t_frames = []
    for scan in scans:
        t0 = time.perf_counter()
        pipe.process(scan)
        t_frames.append(time.perf_counter() - t0)
    final, worst = _drift([p[:3, 3] for p in pipe.poses], seq)
    # Steady state: frames 0-2 pay compiles (frame 0 the fuse, frame 1 the
    # registration executable); the stream's sustained rate is what a
    # deployment sees.
    steady = t_frames[3:] if len(t_frames) > 3 else t_frames[1:]
    return {
        "final_drift_m": final, "max_drift_m": worst,
        "mean_iters": pipe.mean_iterations(),
        "rejected": pipe.rejected_frames(),
        "fps": len(steady) / sum(steady),
        "steady_frames": len(steady),
        "t_steady_s": sum(steady),
        "t_total_s": sum(t_frames),
    }


def _run_frame_to_frame(scans, seq: int, engine: str, params: ICPParams,
                        samples: int) -> dict:
    pairs = sample_consecutive_pairs(scans, samples)
    eng = get_engine(engine)
    res, _ = eng.register_pairs(pairs, params)
    jax.block_until_ready(res.T)                      # warmup + result
    t0 = time.perf_counter()
    res2, _ = eng.register_pairs(pairs, params)       # compiled steady state
    jax.block_until_ready(res2.T)
    t_warm = time.perf_counter() - t0
    pose = np.eye(4)
    poses_t = [pose[:3, 3]]
    for f in range(len(pairs)):
        pose = pose @ np.linalg.inv(np.asarray(res.T[f], np.float64))
        poses_t.append(pose[:3, 3].copy())
    final, worst = _drift(poses_t, seq)
    return {
        "final_drift_m": final, "max_drift_m": worst,
        "mean_iters": float(np.mean(np.asarray(res.iterations))),
        "fps": len(pairs) / t_warm,
        "t_total_s": t_warm,
    }


def run(seqs=(2, 3), frames: int = 15, samples: int = 2048,
        iters: int = 30, engine: str = "pyramid",
        scene: SceneConfig | None = None, config: OdometryConfig | None = None,
        out_json: str | None = None):
    """Both execution shapes over ``seqs``, ``frames`` registrations each.

    ``iters`` caps per-frame iterations identically in both modes, so the
    drift gap isolates the *architecture* (map anchor + warm start), not
    an iteration budget difference.
    """
    scene = ODO_SCENE if scene is None else scene
    if config is None:
        config = OdometryConfig(submap=ODO_SUBMAP, scan_budget=4096)
    params = config.params._replace(max_iterations=iters)

    per_seq = []
    warm_iter_speedup = None
    for i, seq in enumerate(seqs):
        scans = sequence_scans(seq, frames + 1, scene)
        f2f = _run_frame_to_frame(scans, seq, engine, params, samples)
        s2m = _run_scan_to_map(scans, seq, engine, params, config, warm=True)
        if i == 0:
            cold = _run_scan_to_map(scans, seq, engine, params, config,
                                    warm=False)
            warm_iter_speedup = cold["mean_iters"] / max(s2m["mean_iters"],
                                                         1e-9)
        per_seq.append({
            "seq": seq, "frames": frames,
            "frame_to_frame": f2f, "scan_to_map": s2m,
            "fps_speedup": s2m["fps"] / f2f["fps"],
            "drift_advantage": f2f["final_drift_m"]
            / max(s2m["final_drift_m"], 1e-9),
        })

    # Paper §IV weighting: each sequence's speedup counts in proportion to
    # its share of the baseline's total runtime. The fps aggregate is
    # steady-state on both sides (same regime as the per-seq fps), so
    # trend-reading never conflates compile-time with throughput changes.
    t_f2f = np.array([r["frame_to_frame"]["t_total_s"] for r in per_seq])
    s2m_runs = [r["scan_to_map"] for r in per_seq]
    weights = t_f2f / t_f2f.sum()
    summary = {
        "seqs": list(seqs), "frames": frames, "samples": samples,
        "iters": iters, "engine": engine,
        "per_seq": per_seq,
        "fps_weighted": float(sum(r["steady_frames"] for r in s2m_runs)
                              / sum(r["t_steady_s"] for r in s2m_runs)),
        "runtime_weighted_speedup": float(
            np.sum(weights * [r["fps_speedup"] for r in per_seq])),
        "warm_iter_speedup": float(warm_iter_speedup),
        "drift_final_s2m_max": max(
            r["scan_to_map"]["final_drift_m"] for r in per_seq),
        "drift_advantage_min": min(r["drift_advantage"] for r in per_seq),
    }
    path = JSON_PATH if out_json is None else pathlib.Path(out_json)
    path.write_text(json.dumps(summary, indent=2))

    rows = []
    for r in per_seq:
        s2m, f2f = r["scan_to_map"], r["frame_to_frame"]
        rows.append((f"odometry/s2m_seq{r['seq']}", 1e6 / s2m["fps"],
                     f"drift={s2m['final_drift_m']:.3f}m;"
                     f"iters={s2m['mean_iters']:.1f};"
                     f"fps={s2m['fps']:.2f}"))
        rows.append((f"odometry/f2f_seq{r['seq']}", 1e6 / f2f["fps"],
                     f"drift={f2f['final_drift_m']:.3f}m;"
                     f"iters={f2f['mean_iters']:.1f};"
                     f"fps={f2f['fps']:.2f}"))
    rows.append(("odometry/aggregate", 1e6 / summary["fps_weighted"],
                 f"fps_weighted={summary['fps_weighted']:.2f};"
                 f"warm_iter_speedup={summary['warm_iter_speedup']:.2f}x;"
                 f"drift_advantage={summary['drift_advantage_min']:.2f}x"))
    return rows


def run_quick():
    """Smoke mode for CI: one short stream, tiny scene, brute-NN engine
    (cheapest compile). Writes to the gitignored quick scratch path."""
    cfg = OdometryConfig(
        params=ICPParams(max_iterations=10, max_correspondence_distance=1.0,
                         transformation_epsilon=1e-5,
                         robust_kernel="huber", robust_scale=0.3),
        submap=SubmapParams(voxel_size=0.75, capacity=4096, dims=(96, 96, 36),
                            evict_radius=30.0),
        scan_budget=2048)
    return run(seqs=(2,), frames=5, samples=512, iters=10, engine="xla",
               scene=QUICK_SCENE, config=cfg,
               out_json="BENCH_odometry_quick.json")


if __name__ == "__main__":
    emit(run())
