"""Benchmark regression guard: re-run the quick sweeps, compare against
the committed baselines, fail loudly on a >20% regression.

    PYTHONPATH=src python -m benchmarks.check_regression        # or:
    make bench-guard

Baselines are the committed ``BENCH_nn.json`` / ``BENCH_throughput.json``
/ ``BENCH_odometry.json`` / ``BENCH_robustness.json`` /
``BENCH_service.json`` at the repo root. The guard re-measures in quick
mode (small scenes, so it finishes in CI minutes) and compares only
metrics that are *comparable* across the two configurations:

  * **ratio metrics** (grid-NN speedup at a shared M, batched-vs-looped
    throughput speedup, scan-to-map fps speedup) — hardware-speed-
    independent to first order, since numerator and denominator are
    measured in the same process on the same machine. Guarded at
    ``current >= (1 - tolerance) * baseline``. Timed ratio metrics are the
    **median of 3 repeated measurements**: wall clock on this container
    swings ~15% run-to-run against a 20% tolerance, so a single shot is
    one bad scheduler tick from a false red; the repeats share the
    process-wide jit cache, so only the first pays compilation.
  * **correctness metrics** (gated NN agreement, batch-vs-loop transform
    agreement, pyramid parity, odometry drift) — machine-independent and
    deterministic at fixed seeds; taken single-shot from the first run,
    guarded relative to baseline or against absolute error bounds.

Wall-clock *absolute* numbers are deliberately not compared: the committed
baselines may come from a different machine. The quick re-run writes its
reports to ``BENCH_*_guard.json`` scratch paths so the committed baselines
are never clobbered.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
NN_BASELINE = REPO_ROOT / "BENCH_nn.json"
THROUGHPUT_BASELINE = REPO_ROOT / "BENCH_throughput.json"
ODOMETRY_BASELINE = REPO_ROOT / "BENCH_odometry.json"
ROBUSTNESS_BASELINE = REPO_ROOT / "BENCH_robustness.json"
SERVICE_BASELINE = REPO_ROOT / "BENCH_service.json"
SCALEOUT_BASELINE = REPO_ROOT / "BENCH_scaleout.json"
DEFAULT_TOLERANCE = 0.20
# Median-of-N for timed ratio metrics (see module docstring). Absolute /
# correctness metrics stay single-shot — they are deterministic, repeats
# only add CI minutes.
TIMED_REPEATS = 3


def _median(runs: list[dict], extract) -> float:
    return float(statistics.median(extract(r) for r in runs))


class Guard:
    def __init__(self, tolerance: float):
        self.tolerance = tolerance
        self.checks: list[tuple[str, float, float, bool]] = []

    def ratio(self, name: str, current: float, baseline: float,
              tolerance: float | None = None):
        """current may not fall more than ``tolerance`` below baseline.

        Per-metric ``tolerance`` overrides the default for metrics whose
        *measurement* noise exceeds it (documented at the call site).
        """
        tol = self.tolerance if tolerance is None else tolerance
        ok = current >= (1.0 - tol) * baseline
        self.checks.append((name, current, baseline, ok))

    def absolute(self, name: str, current: float, bound: float):
        """current must stay under an absolute bound (error metrics)."""
        ok = current <= bound
        self.checks.append((name, current, bound, ok))

    def report(self) -> bool:
        width = max(len(c[0]) for c in self.checks)
        all_ok = True
        for name, cur, ref, ok in self.checks:
            status = "ok  " if ok else "FAIL"
            print(f"{status} {name:<{width}} current={cur:.4g} "
                  f"ref={ref:.4g}")
            all_ok &= ok
        return all_ok


def check_nn(guard: Guard) -> None:
    from benchmarks import nn_sweep
    from repro.data.pointcloud import SceneConfig

    baseline = json.loads(NN_BASELINE.read_text())
    base_rows = {(s["m"], s["rings"]): s for s in baseline["sweeps"]}
    # Re-measure at M=16384 rings=1 with the baseline's query count
    # (speedup amortises fixed gather cost over N, so N must match for the
    # ratio to be comparable) — but a CI-fast scene.
    scene = SceneConfig(n_ground=40_000, n_walls=30_000, n_poles=8_000,
                        n_clutter=9_000, extent=40.0, sensor_range=45.0)

    def measure() -> dict:
        nn_sweep.run(sizes=(16_384,), samples=4096, parity=False,
                     scene=scene,
                     mitigation=False,  # rings=2 row isn't compared — skip
                     out_json=str(REPO_ROOT / "BENCH_nn_guard.json"))
        return json.loads((REPO_ROOT / "BENCH_nn_guard.json").read_text())

    runs = [measure() for _ in range(TIMED_REPEATS)]
    ref = base_rows[(16_384, 1)]
    guard.ratio("nn/grid_speedup_m16k",
                _median(runs, lambda r: r["sweeps"][0]["speedup"]),
                ref["speedup"])
    # agreement is deterministic, not timed: single-shot from the first run
    guard.ratio("nn/agree_gated_m16k", runs[0]["sweeps"][0]["agree_gated"],
                ref["agree_gated"])
    # Fused single-pass iteration vs the unfused pallas iteration (ISSUE-6):
    # a same-process median-of-3 ratio like grid_speedup. The fused side is
    # a few interpret grid steps whose Python dispatch swings more than big
    # compiled sweeps on shared CI hardware, hence the wider band.
    if "fused_iter_speedup" in ref:
        guard.ratio("nn/fused_iter_speedup_m16k",
                    _median(runs,
                            lambda r: r["sweeps"][0]["fused_iter_speedup"]),
                    ref["fused_iter_speedup"], tolerance=0.5)
    # Pyramid-vs-brute ICP parity from the committed full run is an
    # absolute contract (the ISSUE-2 acceptance bound), re-assert it.
    par = baseline.get("parity")
    if par is not None:
        guard.absolute("nn/parity_rot_committed", par["rot_err"], 1e-3)
        guard.absolute("nn/parity_trans_committed", par["trans_err"], 1e-3)
        if "fused_rot_err" in par:  # ISSUE-6 fused-engine parity contract
            guard.absolute("nn/parity_fused_rot_committed",
                           par["fused_rot_err"], 1e-3)
            guard.absolute("nn/parity_fused_trans_committed",
                           par["fused_trans_err"], 1e-3)


def check_throughput(guard: Guard) -> None:
    from benchmarks import registration_throughput

    baseline = json.loads(THROUGHPUT_BASELINE.read_text())

    def measure() -> dict:
        # full-mode config (tiny clouds, seconds of work) so batch/iters
        # match the committed baseline exactly and the speedup ratio is
        # comparable
        registration_throughput.run(
            batch=baseline["batch"], n=baseline["n"], m=baseline["m"],
            iters=baseline["iters"],
            out_json=str(REPO_ROOT / "BENCH_throughput_guard.json"))
        return json.loads(
            (REPO_ROOT / "BENCH_throughput_guard.json").read_text())

    runs = [measure() for _ in range(TIMED_REPEATS)]
    # The looped path is dispatch-dominated on these tiny clouds and its
    # wall clock swings ~2.5x run-to-run on shared CI hardware, so even
    # the median-of-3 speedup ratio keeps a wider band — a genuine
    # regression (batching collapses toward 1x) still lands far below 40%
    # of any healthy baseline, while scheduler noise does not.
    guard.ratio("throughput/batched_speedup",
                _median(runs, lambda r: r["speedup"]),
                baseline["speedup"], tolerance=0.6)
    # batch-vs-loop agreement is a hard correctness bound, not a trend
    guard.absolute("throughput/transform_agreement",
                   runs[0]["max_abs_transform_diff"], 1e-4)


def check_odometry(guard: Guard) -> None:
    from benchmarks import odometry_drift

    baseline = json.loads(ODOMETRY_BASELINE.read_text())
    # One full re-run of the baseline config (~2 min steady state). No
    # TIMED_REPEATS here: the stream's fps is already a mean over >= 12
    # steady-state frames per sequence, which medians out scheduler ticks
    # the way a single batched-call timing cannot — and drift / iteration
    # counts are deterministic at fixed seeds, so repeats add nothing.
    odometry_drift.run(
        seqs=tuple(baseline["seqs"]), frames=baseline["frames"],
        samples=baseline["samples"], iters=baseline["iters"],
        engine=baseline["engine"],
        out_json=str(REPO_ROOT / "BENCH_odometry_guard.json"))
    current = json.loads(
        (REPO_ROOT / "BENCH_odometry_guard.json").read_text())
    # Deterministic trajectory metrics: tight default tolerance.
    guard.absolute("odometry/final_drift_s2m",
                   current["drift_final_s2m_max"], 0.5)
    guard.ratio("odometry/drift_advantage",
                current["drift_advantage_min"],
                baseline["drift_advantage_min"])
    guard.ratio("odometry/warm_iter_speedup",
                current["warm_iter_speedup"],
                baseline["warm_iter_speedup"])
    # Wall-clock throughput: only the runtime-weighted *speedup* is
    # guarded — a same-process ratio, first-order machine-independent
    # like throughput/batched_speedup. The absolute fps_weighted number
    # is recorded in BENCH_odometry.json for trend reading but never
    # compared across machines (module policy above).
    guard.ratio("odometry/runtime_weighted_speedup",
                current["runtime_weighted_speedup"],
                baseline["runtime_weighted_speedup"], tolerance=0.4)


def check_robustness(guard: Guard) -> None:
    from benchmarks import robustness

    baseline = json.loads(ROBUSTNESS_BASELINE.read_text())
    # One full re-run of the committed config (it is already CI-sized;
    # see benchmarks.robustness). Everything guarded here is
    # deterministic at fixed seeds — drifts, improvement ratios and tier
    # histograms are exact replays, not timings — so no TIMED_REPEATS.
    robustness.run(
        seq=baseline["seq"], frames=baseline["frames"],
        burst=tuple(baseline["burst"]), seed=baseline["seed"],
        out_json=str(REPO_ROOT / "BENCH_robustness_guard.json"))
    current = json.loads(
        (REPO_ROOT / "BENCH_robustness_guard.json").read_text())
    # The cascade may not tax clean streams: same absolute drift bound as
    # the odometry guard.
    guard.absolute("robustness/clean_drift",
                   current["clean"]["final_drift_m"], 0.5)
    # The headline contract: at least as many fault families must keep
    # their >=2x cascade advantage as the committed baseline shows.
    guard.ratio("robustness/families_2x",
                float(current["families_2x"]), float(baseline["families_2x"]),
                tolerance=0.0)
    # Per winning family, the drift-improvement factor may not collapse.
    for name, fam in baseline["per_family"].items():
        if fam["meets_2x"]:
            guard.ratio(f"robustness/{name}_drift_x",
                        current["per_family"][name]["drift_improvement"],
                        fam["drift_improvement"])


def check_service(guard: Guard) -> None:
    from benchmarks import service_throughput

    baseline = json.loads(SERVICE_BASELINE.read_text())
    s_max = max(baseline["streams"])

    def measure() -> dict:
        # Max-stream-count config only (the sweep's smaller fleets are
        # trend rows, not guarded metrics) so a repeat costs seconds
        # after the shared first-compile.
        service_throughput.run(
            streams=(s_max,), frames=baseline["frames"],
            warm=baseline["warm"], iters=baseline["iters"],
            budget=baseline["scan_budget"],
            out_json=str(REPO_ROOT / "BENCH_service_guard.json"))
        return json.loads(
            (REPO_ROOT / "BENCH_service_guard.json").read_text())

    runs = [measure() for _ in range(TIMED_REPEATS)]
    # Aggregate-fps ratio is same-process (service and sequential loop
    # measured back-to-back), but its sequential denominator is a
    # dispatch-dominated per-frame loop with the same run-to-run swing
    # as throughput/batched_speedup — same wide band, same rationale.
    guard.ratio("service/fps_ratio",
                _median(runs, lambda r: r["fps_ratio"]),
                baseline["fps_ratio"], tolerance=0.5)
    # p99 ratio: LOWER is better (service round time vs sequential call
    # time), so it is an absolute ceiling, not a floor. A p99 over 12
    # rounds is a max-like statistic — one scheduler tick doubles it —
    # hence the 2x headroom over the committed baseline.
    guard.absolute("service/p99_latency_ratio",
                   _median(runs, lambda r: r["p99_latency_ratio"]),
                   2.0 * baseline["p99_latency_ratio"])
    # Hard structural contracts, not trends: zero retraces after warmup
    # and bit-exact parity with the standalone pipeline.
    guard.absolute("service/retraces_after_warmup",
                   float(runs[0]["retraces_after_warmup"]), 0.0)
    guard.absolute("service/parity_max_abs",
                   runs[0]["parity_max_abs"], 0.0)


def check_device_sweep(guard: Guard) -> None:
    from benchmarks import device_sweep

    baseline = json.loads(SCALEOUT_BASELINE.read_text())
    # One quick-mode subprocess re-run (the sweep must initialise jax with
    # a forced 8-device host platform, which this already-initialised
    # 1-device process cannot — device_sweep respawns itself). Quick mode
    # sweeps the D=1 and D=8 endpoints, which is exactly what the scaling
    # ratio needs; median-of-repeats lives inside the sweep itself, so no
    # TIMED_REPEATS wrapper — each extra repeat would pay the subprocess's
    # full compile again instead of sharing a jit cache.
    current = device_sweep.run_subprocess(quick=True)
    d_lo, d_hi = min(current["devices"]), max(current["devices"])
    scaling = (current["sweep"][str(d_hi)]["aggregate_fps"]
               / current["sweep"][str(d_lo)]["aggregate_fps"])
    # Weak-scaling retention is same-process fps(D=8)/fps(D=1); its D=1
    # denominator is the same dispatch-dominated per-round regime as
    # service/fps_ratio's sequential loop — same wide band.
    guard.ratio("scaleout/scaling_x", scaling, baseline["scaling_x"],
                tolerance=0.5)
    # The fleet-batching headline: one fused round vs the eager
    # per-stream loop on the same 8-stream workload (dispatch-dominated
    # denominator again — same band as service/fps_ratio).
    guard.ratio("scaleout/fused_vs_sequential",
                current["fused_vs_sequential_x"],
                baseline["fused_vs_sequential_x"], tolerance=0.5)
    # Hard structural contracts, identical to the in-sweep asserts: the
    # guard re-states them so a weakened assert cannot slip a regression
    # past CI.
    guard.absolute("scaleout/parity_max_abs",
                   current["parity_max_abs"], 0.0)
    guard.absolute("scaleout/retraces_after_warmup",
                   float(current["retraces_after_warmup"]), 0.0)
    # Deterministic memory layout: the fp16 headline may not erode below
    # the 1.9x acceptance floor (tolerance=0.0 → hard floor at 1.9).
    guard.ratio("scaleout/submap_bytes_ratio",
                current["submap_bytes_ratio"], 1.9, tolerance=0.0)
    # fp16 drift re-measured on the quick stream: same absolute band the
    # odometry guard enforces for fp32.
    guard.absolute("scaleout/fp16_drift_final",
                   current["fp16_drift_final_m"], 0.5)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="allowed fractional regression (default 0.20)")
    ap.add_argument("--only",
                    choices=["nn", "throughput", "odometry", "robustness",
                             "service", "device_sweep"],
                    default=None)
    args = ap.parse_args(argv)
    guard = Guard(args.tolerance)
    if args.only in (None, "nn"):
        check_nn(guard)
    if args.only in (None, "throughput"):
        check_throughput(guard)
    if args.only in (None, "odometry"):
        check_odometry(guard)
    if args.only in (None, "robustness"):
        check_robustness(guard)
    if args.only in (None, "service"):
        check_service(guard)
    if args.only in (None, "device_sweep"):
        check_device_sweep(guard)
    ok = guard.report()
    if not ok:
        print(f"\nbench-guard: regression beyond "
              f"{args.tolerance:.0%} tolerance", file=sys.stderr)
        return 1
    print("\nbench-guard: all metrics within tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
