"""Paper Table IV reproduction: per-frame latency + acceleration.

Three columns:
  * kdtree_cpu  — the paper's software baseline (scipy cKDTree ICP),
    measured on this host.
  * fpps_xla    — our engine, measured on this host (CPU executes the same
    XLA program the TPU would; absolute numbers reflect 1 CPU core).
  * fpps_v5e_projected — roofline-projected per-frame latency on one TPU
    v5e chip (from the dry-run cost model: dominant-term time of a
    50-iteration frame at this cloud size), with the projected
    acceleration vs the measured CPU baseline — the Table IV analogue for
    our target hardware. Clearly a MODEL, not a measurement.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_frames, emit, timeit
from repro.core import ICPParams, icp_fixed_iterations
from repro.core.baseline import kdtree_icp
from repro.core.nn_search import nn_search
from repro.core.nn_search_grid import nn_search_grid
from repro.core.transform import (estimate_rigid_transform, rmse,
                                  transform_delta, transform_points)
from repro.data.voxelize import build_voxel_grid
from repro.roofline.report import V5E


def _project_v5e_frame_s(n: int, m: int, iters: int) -> float:
    """Dominant roofline term for one frame on one v5e chip, Pallas-kernel
    execution model: distance tiles stay in VMEM (no d2 HBM traffic)."""
    flops = iters * 2.0 * 8 * n * m                 # augmented dot
    hbm = iters * (8 * m * 4 + 8 * n * 4 + n * 8)   # stream target + source
    compute_s = flops / V5E["peak_flops_bf16"]
    memory_s = hbm / V5E["hbm_bw"]
    return max(compute_s, memory_s)


def stage_breakdown(src, dst, params: ICPParams, grid_dims=(128, 128, 32)):
    """Per-stage latency of one ICP iteration, via split jitted programs.

    The fused while-loop hides where the time goes; here each of the
    paper's four stages runs as its own ``jax.block_until_ready``-timed
    executable on real frame data: correspondence (brute force AND the
    grid-bucketed searcher, grid prebuilt per frame), transformation
    estimation (masked Kabsch + 3x3 SVD), and the point-cloud
    update/convergence math. Stage splits add dispatch overhead the fused
    loop doesn't pay, so treat the absolute sum as an upper bound; the
    *ratios* are the point.
    """
    srcj = jnp.asarray(src, jnp.float32)
    dstj = jnp.asarray(dst, jnp.float32)
    rows = []
    corr = jax.jit(lambda s, d: nn_search(s, d, chunk=params.chunk))
    t_corr = timeit(corr, srcj, dstj)
    d2, idx = corr(srcj, dstj)

    # Same voxel rule as the pyramid engine: exactness needs voxel >= gate.
    voxel = max(1.0, params.max_correspondence_distance)
    grid = jax.jit(lambda d: build_voxel_grid(d, voxel, grid_dims))(dstj)
    jax.block_until_ready(grid.points)
    gcorr = jax.jit(lambda s: nn_search_grid(s, grid, max_per_cell=32))
    t_gcorr = timeit(gcorr, srcj)

    matched = jnp.take(dstj, idx, axis=0)
    weights = (d2 <= params.max_correspondence_distance ** 2).astype(
        jnp.float32)
    kabsch = jax.jit(estimate_rigid_transform)
    t_kabsch = timeit(kabsch, srcj, matched, weights)
    T = kabsch(srcj, matched, weights)

    def update(T, s, matched, weights):
        s_t = transform_points(T, s)
        return transform_delta(T), rmse(s_t, matched, weights)

    upd = jax.jit(update)
    t_upd = timeit(upd, T, srcj, matched, weights)

    total = t_corr + t_kabsch + t_upd
    rows.append(("table4/stage_correspondence_brute", t_corr * 1e6,
                 f"share={t_corr / total:.3f};M={dst.shape[0]}"))
    rows.append(("table4/stage_correspondence_grid", t_gcorr * 1e6,
                 f"vs_brute={t_corr / t_gcorr:.1f}x"))
    rows.append(("table4/stage_kabsch_svd", t_kabsch * 1e6,
                 f"share={t_kabsch / total:.3f}"))
    rows.append(("table4/stage_update_convergence", t_upd * 1e6,
                 f"share={t_upd / total:.3f}"))
    return rows


def fused_iteration_case(src, dst, params: ICPParams | None = None,
                         grid_dims=(128, 128, 32)):
    """Fused single-pass iteration vs the unfused per-iteration chains.

    Three timed bodies, all at frame scope (resident target structures are
    prebuilt, exactly as the engines amortise them):

      * ``unfused_pallas`` — the pallas engine's iteration: resident brute
        kernel sweep + winner gather + gate weights + Kabsch (the ISSUE-6
        acceptance comparator, O(N·M) candidate volume).
      * ``unfused_grid``   — the separate-op grid chain: grid candidate
        sweep kernel + winner gather + weights + Kabsch (same candidate
        volume as fused, but four HBM round-trips).
      * ``fused``          — one ``fused_icp`` pass + the O(1) moment solve.

    Returns (rows, case_dict); the case dict feeds BENCH_nn.json and the
    bench-guard ratio metric.
    """
    from repro.core.transform import estimate_from_moments
    from repro.kernels.fused_icp import make_fused_fn
    from repro.kernels.nn_search_grid import grid_kernel_nn_fn
    from repro.kernels.ops import resident_nn_fn

    params = ICPParams() if params is None else params
    srcj = jnp.asarray(src, jnp.float32)
    dstj = jnp.asarray(dst, jnp.float32)
    gate2 = params.max_correspondence_distance ** 2

    nn_brute = resident_nn_fn(dstj)

    def unfused_pallas_iter(s):
        d2, idx = nn_brute(s)
        matched = jnp.take(dstj, idx, axis=0)
        w = (d2 <= gate2).astype(jnp.float32)
        return estimate_rigid_transform(s, matched, w)

    t_pallas = timeit(jax.jit(unfused_pallas_iter), srcj)

    voxel = max(1.0, params.max_correspondence_distance)
    grid = jax.jit(lambda d: build_voxel_grid(d, voxel, grid_dims))(dstj)
    jax.block_until_ready(grid.points)
    nn_grid = grid_kernel_nn_fn(grid)

    def unfused_grid_iter(s):
        d2, idx, matched = nn_grid(s)
        w = (d2 <= gate2).astype(jnp.float32)
        return estimate_rigid_transform(s, matched, w)

    t_grid = timeit(jax.jit(unfused_grid_iter), srcj)

    fused_fn = make_fused_fn(grid, params)

    def fused_iter(s):
        m = fused_fn(s)
        return estimate_from_moments(m.sw, m.sp, m.sq, m.spq)

    t_fused = timeit(jax.jit(fused_iter), srcj)

    m = int(dst.shape[0])
    case = {
        "m": m, "n": int(src.shape[0]),
        "t_iter_unfused_pallas_s": t_pallas,
        "t_iter_unfused_grid_s": t_grid,
        "t_iter_fused_s": t_fused,
        "fused_iter_speedup": t_pallas / t_fused,      # vs the pallas engine
        "fused_vs_grid_chain": t_grid / t_fused,       # vs the fused-size chain
    }
    rows = [
        (f"table4/iter_unfused_pallas_m{m}", t_pallas * 1e6,
         "resident brute kernel + gather + Kabsch"),
        (f"table4/iter_unfused_grid_m{m}", t_grid * 1e6,
         "grid sweep kernel + gather + Kabsch"),
        (f"table4/iter_fused_m{m}", t_fused * 1e6,
         f"speedup_vs_pallas={case['fused_iter_speedup']:.1f}x;"
         f"vs_grid_chain={case['fused_vs_grid_chain']:.2f}x"),
    ]
    return rows, case


def run(n_seqs: int = 5, samples: int = 2048, iters: int = 50, scene=None):
    rows = []
    speedups = []
    frames = bench_frames(n_seqs, samples=samples, scene=scene)
    params = ICPParams(max_iterations=iters, chunk=2048)
    jitted = jax.jit(lambda s, d: icp_fixed_iterations(s, d, params))
    for seq, (src, dst, _) in enumerate(frames):
        t_base = timeit(lambda: kdtree_icp(src, dst, iters), warmup=0, iters=1)
        srcj = jnp.asarray(src, jnp.float32)
        dstj = jnp.asarray(dst, jnp.float32)
        t_ours = timeit(lambda: jitted(srcj, dstj), warmup=1, iters=2)
        t_proj = _project_v5e_frame_s(src.shape[0], dst.shape[0], iters)
        acc_meas = t_base / t_ours
        acc_proj = t_base / t_proj
        speedups.append(acc_proj)
        rows.append((f"table4/seq{seq:02d}_kdtree_cpu", t_base * 1e6,
                     f"per-frame;M={dst.shape[0]}"))
        rows.append((f"table4/seq{seq:02d}_fpps_xla_cpu", t_ours * 1e6,
                     f"acceleration_measured={acc_meas:.2f}x"))
        rows.append((f"table4/seq{seq:02d}_fpps_v5e_projected", t_proj * 1e6,
                     f"acceleration_projected={acc_proj:.2f}x"))
    rows.append(("table4/mean_projected_acceleration", 0.0,
                 f"{np.mean(speedups):.1f}x (paper: 4.8x-35.4x, avg 15.95x)"))
    # Where an iteration's time goes (first frame is representative).
    src0, dst0, _ = frames[0]
    rows.extend(stage_breakdown(src0, dst0, params))
    fused_rows, _ = fused_iteration_case(src0, dst0, params)
    rows.extend(fused_rows)
    return rows


if __name__ == "__main__":
    emit(run())
