"""Paper Table II analogue: accelerator resource budget.

FPGA resources (LUT/FF/BRAM/DSP) map to the TPU kernel's static budget:
VMEM bytes per pipeline stage (BRAM analogue), MXU tile occupancy (DSP
analogue), and the kernel's grid/pipelining configuration. All numbers are
static properties of the BlockSpec tiling — the same table a kernel author
reads before committing a design.

Also times the kernel (interpret mode) against the ref oracle at paper
scale to document functional throughput parity on this container.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.kernels.nn_search import AUG_ROWS, vmem_bytes
from repro.kernels.ops import nn_search_pallas
from repro.kernels.ref import nn_search_ref
from repro.roofline.report import V5E

VMEM_V5E = 128 * 2 ** 20  # ~128 MiB/core


def run():
    rows = []
    bn, bm = 512, 1024
    b = vmem_bytes(bn, bm)
    for stage, size in b.items():
        rows.append((f"table2/vmem_{stage}", 0.0,
                     f"{size} B ({size / VMEM_V5E * 100:.2f}% of VMEM)"))
    # MXU occupancy: the distance matmul is (bn x 8) @ (8 x bm): the 8-deep
    # contraction fills 8/128 of the MXU's systolic depth per pass.
    rows.append(("table2/mxu_contraction_depth", 0.0,
                 f"8/128 ({8 / 128 * 100:.1f}% systolic depth; augmented-"
                 "point layout)"))
    rows.append(("table2/grid_tiles_per_130k_frame", 0.0,
                 f"{(4096 // bn) * (131072 // bm)} (bn={bn}, bm={bm})"))
    # arithmetic intensity of the kernel hot loop (per target element):
    ai = (2 * AUG_ROWS * bn) / (AUG_ROWS * 4)  # flops per target byte
    rows.append(("table2/arithmetic_intensity", 0.0,
                 f"{ai:.0f} flop/byte vs v5e ridge "
                 f"{V5E['peak_flops_bf16'] / V5E['hbm_bw']:.0f}"))
    # Fused ICP-iteration kernel (DESIGN.md §11): VMEM footprint of the
    # tuned config and the FLOP/byte win over the separate-op chain — the
    # "why fusion is fast" numbers, not just the timings.
    from repro.kernels.fused_icp import (DEFAULT_CONFIG, fused_cost_model,
                                         fused_vmem_bytes)
    cfg = DEFAULT_CONFIG
    for plane, tag in ((False, "p2p"), (True, "p2plane")):
        fb = fused_vmem_bytes(cfg.bn, cfg.bc, plane=plane, prune=cfg.prune)
        rows.append((f"table2/fused_{tag}_vmem_double_buffered", 0.0,
                     f"{fb['total_double_buffered']} B "
                     f"({fb['total_double_buffered'] / VMEM_V5E * 100:.2f}% "
                     f"of VMEM; bn={cfg.bn},bc={cfg.bc})"))
    cost = fused_cost_model(4096, 27 * 32)  # 27-cell hood, max_per_cell=32
    rows.append(("table2/fused_flop_per_byte", 0.0,
                 f"{cost['fused']['flop_per_byte']:.2f} fused vs "
                 f"{cost['chain']['flop_per_byte']:.2f} chain "
                 f"(hbm_ratio={cost['hbm_ratio']:.2f}x)"))
    # functional check at paper scale (1 source point vs 130k candidates,
    # interpret mode on CPU — correctness, not speed)
    key = jax.random.PRNGKey(0)
    src = jax.random.uniform(key, (128, 3), minval=-50, maxval=50)
    dst = jax.random.uniform(jax.random.fold_in(key, 1), (131072, 3),
                             minval=-50, maxval=50)
    t = timeit(lambda: nn_search_pallas(src, dst, None, interpret=True),
               warmup=1, iters=1)
    d2k, idxk = nn_search_pallas(src, dst, None, interpret=True)
    d2r, idxr = nn_search_ref(src, dst)
    match = float(jnp.mean((idxk == idxr).astype(jnp.float32)))
    rows.append(("table2/kernel_interpret_128x131072", t * 1e6,
                 f"idx_match={match:.4f}"))
    return rows


if __name__ == "__main__":
    emit(run())
