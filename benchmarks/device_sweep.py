"""Scale-out sweep: the sharded registration service over a device mesh.

Weak-scaling shape (DESIGN.md §14): every device owns ``lanes_per_device``
slot lanes AND their resident submaps, so a D-device fleet serves
``D * lanes_per_device`` streams with the SAME per-device program a
single device runs. The sweep measures, per device count:

  * **aggregate fps** — frames completed per second across the whole
    fleet, median of ``repeats`` full runs (the repo's median-of-3
    convention for timed metrics; repeats share the jit cache).
  * **scaling retention** — ``fps(D) / fps(1)``. Read this number for
    what CPU CI can actually measure: the forced host-platform "devices"
    all share ONE physical core, so per-device executions serialise and
    a D-device round does D devices' worth of compute plus D per-device
    dispatches on the same silicon — wall-clock SPEEDUP from D is
    physically impossible here. What the retention ratio bounds is the
    *sharding tax*: how much aggregate throughput survives spreading the
    fleet across D serialised device runtimes (1.0 = free). Near-linear
    scaling in D is a >=D-core/multi-chip claim; on real hardware the
    per-device executions this sweep serialises run concurrently.
  * **strong 8-stream block + sequential baseline** — the §IV-style
    deployment comparison that IS meaningful on one core: the same
    8-stream workload as one fused fleet round (D=1 x 8 lanes and
    D=8 x 1 lane) vs eight eager per-stream pipelines. The fleet round
    amortises per-frame dispatch + host round-trips; this is where the
    >=3x aggregate-throughput headline lives (cf. BENCH_service.json).

Also recorded, because they are acceptance criteria, not vibes:

  * **parity** — a D=max service stream vs a standalone single-device
    (one-lane) pipeline replay: max abs pose diff MUST be exactly 0.0
    (weak-scaling parity at equal block width, see ``ShardedSlotEngine``).
  * **retraces** — engine trace-count delta across join/retire churn at
    D=max; MUST be 0.
  * **submap bytes** — per-resident-submap device bytes, fp32 vs fp16
    layout; the ratio MUST be >= 1.9 (the memory-lean headline).
  * **fp16 drift** — final trajectory drift of a 30-frame fp16 scan-to-
    map stream vs ground truth; MUST stay inside the 0.5 m guard band
    the odometry benchmark enforces for fp32 (plus the fp16-vs-fp32 final
    pose gap, which should be centimetres).

Run it as a MODULE (``python -m benchmarks.device_sweep``): the
``__main__`` guard below forces an 8-device host platform BEFORE jax
initialises. From an already-initialised (1-device) process, use
:func:`run_subprocess`, which respawns this module cleanly — that is what
``benchmarks.run`` and ``benchmarks.check_regression`` do.

Writes BENCH_scaleout.json (committed baseline; ``--quick`` writes
BENCH_scaleout_quick.json so the baseline is never clobbered).
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

_FORCE_FLAG = "--xla_force_host_platform_device_count=8"

if __name__ == "__main__":
    # Must happen before the jax import below — harmless if already set.
    if _FORCE_FLAG not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " " + _FORCE_FLAG)

import jax  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import QUICK_SCENE, emit  # noqa: E402
from benchmarks.odometry_drift import ODO_SCENE, ODO_SUBMAP  # noqa: E402
from benchmarks.service_throughput import (QUICK_SERVICE_SCENE,  # noqa: E402
                                           SERVICE_SCENE, _bench_odometry,
                                           _run_sequential, _staged_fleet)
from repro.core import ICPParams  # noqa: E402
from repro.core.odometry import OdometryConfig, OdometryPipeline  # noqa: E402
from repro.data.pointcloud import gt_pose, sequence_scans  # noqa: E402
from repro.data.submap import SubmapParams, state_bytes  # noqa: E402
from repro.serve.registration_service import (RegistrationService,  # noqa: E402
                                              ServiceConfig)

JSON_PATH = pathlib.Path("BENCH_scaleout.json")
REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _svc_config(odo, devices: int, lanes: int, max_queue: int,
                storage: str = "fp32") -> ServiceConfig:
    sub = odo.submap._replace(storage=storage)
    return ServiceConfig(slots=devices * lanes, scan_capacity=2048,
                         max_queue=max_queue,
                         odometry=odo._replace(submap=sub), devices=devices)


def _time_fleet(cfg_svc: ServiceConfig, fleet: dict, warm: int,
                timed: int) -> tuple[float, int]:
    """One full fleet run: warm rounds, then ``timed`` timed rounds.
    Returns (aggregate_fps, retraces_after_warmup)."""
    svc = RegistrationService(cfg_svc)
    for sid in fleet:
        svc.admit(sid)
    for f in range(warm):
        for sid, staged in fleet.items():
            svc.submit(sid, *staged[f])
        svc.step()
    svc.sync()
    traces = svc.engine.trace_count
    t0 = time.perf_counter()
    for f in range(warm, warm + timed):
        for sid, staged in fleet.items():
            svc.submit(sid, *staged[f])
        svc.step()
    svc.sync()
    dt = time.perf_counter() - t0
    return len(fleet) * timed / dt, svc.engine.trace_count - traces


def _parity_and_churn(cfg_svc: ServiceConfig, fleet: dict,
                      frames: int) -> tuple[float, int]:
    """D=max parity vs a single-device one-lane standalone replay, then
    join/retire churn on the same warm service. Returns
    (parity_max_abs, churn_retraces)."""
    svc = RegistrationService(cfg_svc)
    sids = list(fleet)
    for sid in sids:
        svc.admit(sid)
    ref_cfg = svc.stream_config._replace(
        engine_kwargs=(("lanes_per_device", 1), ("devices", 1)))
    ref = OdometryPipeline(ref_cfg)
    probe = sids[0]
    worst = 0.0
    for f in range(frames):
        for sid in sids:
            svc.submit(sid, *fleet[sid][f])
        out = svc.step()
        pose_ref, _ = ref.process(*fleet[probe][f])
        worst = max(worst, float(np.abs(np.asarray(out[probe][0]) -
                                        np.asarray(pose_ref)).max()))
    traces = svc.engine.trace_count
    svc.close(sids[-1])                      # retire: in-place lane reset
    svc.admit("churn-join")                  # join a warm fleet
    for f in range(2):
        for sid in (probe, "churn-join"):
            svc.submit(sid, *fleet[sids[-1]][f])
        svc.step()
    return worst, svc.engine.trace_count - traces


def _fp16_drift(frames: int, quick: bool) -> dict:
    """Scan-to-map stream, fp32 vs fp16 resident submap: final drift vs
    ground truth (the odometry benchmark's 0.5 m guard band) and the
    cross-storage final pose gap. Reuses the odometry bench's scene and
    submap sizing so the band means the same thing here."""
    if quick:
        scene = QUICK_SCENE
        sub = SubmapParams(voxel_size=0.75, capacity=4096,
                           dims=(96, 96, 36), evict_radius=30.0)
        cfg = OdometryConfig(
            engine="xla",
            params=ICPParams(max_iterations=10,
                             max_correspondence_distance=1.0,
                             transformation_epsilon=1e-5,
                             robust_kernel="huber", robust_scale=0.3),
            submap=sub, scan_budget=2048)
    else:
        # The odometry benchmark's guarded scan-to-map config exactly
        # (pyramid engine, 30-iteration cap, full scan budget): the
        # 0.5 m band is calibrated against it, so reusing it is what
        # makes "fp16 stays inside the band" mean something.
        scene, sub = ODO_SCENE, ODO_SUBMAP
        base = OdometryConfig(submap=sub, scan_budget=4096)
        cfg = base._replace(engine="pyramid",
                            params=base.params._replace(max_iterations=30))
    scans = sequence_scans(2, frames, scene)
    gt = gt_pose(2)
    out, finals = {}, {}
    for storage in ("fp32", "fp16"):
        pipe = OdometryPipeline(cfg._replace(
            submap=sub._replace(storage=storage)))
        poses, _ = pipe.run(scans)
        finals[storage] = poses[-1]
        out[f"{storage}_drift_final_m"] = float(np.linalg.norm(
            poses[-1][:3, 3] - gt(frames - 1)[:3, 3]))
    out["fp16_vs_fp32_gap_m"] = float(np.linalg.norm(
        finals["fp16"][:3, 3] - finals["fp32"][:3, 3]))
    return out


def run(devices: tuple = (1, 2, 4, 8), lanes_per_device: int = 1,
        frames: int = 12, warm: int = 4, iters: int = 4, budget: int = 128,
        repeats: int = 3, quick: bool = False,
        out_json: str | None = None):
    scene = SERVICE_SCENE
    drift_frames = 12
    if quick:
        devices, frames, warm, iters, repeats = (1, 8), 5, 2, 3, 1
        drift_frames = 5
        scene = QUICK_SERVICE_SCENE
        if out_json is None:
            # never clobber the committed baseline from smoke mode
            out_json = "BENCH_scaleout_quick.json"
    d_max = max(devices)
    if jax.device_count() < d_max:
        raise RuntimeError(
            f"device sweep needs {d_max} devices, found "
            f"{jax.device_count()} — run as 'python -m "
            f"benchmarks.device_sweep' (the __main__ guard forces an "
            f"8-device host platform) or via run_subprocess()")
    odo = _bench_odometry(iters, budget)
    probe = RegistrationService(_svc_config(odo, d_max, lanes_per_device,
                                            warm + frames))
    fleet = _staged_fleet(probe, d_max * lanes_per_device, warm + frames,
                          scene)

    rows, sweep = [], {}
    for d in devices:
        cfg_svc = _svc_config(odo, d, lanes_per_device, warm + frames)
        sub_fleet = dict(list(fleet.items())[:d * lanes_per_device])
        runs = [_time_fleet(cfg_svc, sub_fleet, warm, frames)
                for _ in range(repeats)]
        fps = float(np.median([r[0] for r in runs]))
        retr = max(r[1] for r in runs)
        sweep[d] = {"aggregate_fps": fps, "retraces_after_warmup": retr}
        rows.append((f"scaleout/fleet_d{d}",
                     1e6 / fps * d * lanes_per_device,
                     f"{fps:.1f} frames/s aggregate;"
                     f"{d * lanes_per_device} streams"))

    scaling = sweep[d_max]["aggregate_fps"] / sweep[min(devices)][
        "aggregate_fps"]

    # Strong 8-stream block: the same d_max*L-stream workload fused onto
    # ONE device (d_max*L lanes in one vmap block) plus the eager
    # sequential per-stream baseline. On this serialised host the fused
    # round vs the eager loop is the deployment comparison that can
    # honestly show a >=3x aggregate win (cf. BENCH_service.json).
    n_streams = d_max * lanes_per_device
    cfg_one = _svc_config(odo, 1, n_streams, warm + frames)
    one_runs = [_time_fleet(cfg_one, fleet, warm, frames)
                for _ in range(repeats)]
    one_fps = float(np.median([r[0] for r in one_runs]))
    seq_calls = _run_sequential(odo, fleet, warm, frames)
    seq_fps = len(seq_calls) / sum(seq_calls)
    fused_vs_seq = one_fps / seq_fps

    parity, churn_retraces = _parity_and_churn(
        _svc_config(odo, d_max, lanes_per_device, warm + frames), fleet,
        min(frames, 6))
    retraces = max(churn_retraces, max(r[1] for r in one_runs),
                   max(v["retraces_after_warmup"] for v in sweep.values()))

    b32 = state_bytes(odo.submap)
    b16 = state_bytes(odo.submap._replace(storage="fp16"))
    drift = _fp16_drift(drift_frames, quick)

    summary = {
        "devices": list(devices), "lanes_per_device": lanes_per_device,
        "frames": frames, "warm": warm, "iters": iters,
        "scan_budget": budget, "repeats": repeats,
        "sweep": {str(d): v for d, v in sweep.items()},
        "scaling_x": scaling,
        "scaling_note": "forced host-platform devices share one physical "
                        "core: per-device executions serialise, so "
                        "scaling_x bounds the sharding tax (1.0 = free), "
                        "it cannot show parallel speedup here",
        "strong_8stream": {
            "streams": n_streams,
            "fused_d1_fps": one_fps,
            f"sharded_d{d_max}_fps": sweep[d_max]["aggregate_fps"],
            "sequential_fps": seq_fps,
        },
        "fused_vs_sequential_x": fused_vs_seq,
        "parity_max_abs": parity,
        "retraces_after_warmup": retraces,
        "bytes_per_submap_fp32": b32, "bytes_per_submap_fp16": b16,
        "submap_bytes_ratio": b32 / b16,
        "submaps_per_gib_fp16": int(2**30 / b16),
        "drift_frames": drift_frames, **drift,
    }
    path = JSON_PATH if out_json is None else pathlib.Path(out_json)
    path.write_text(json.dumps(summary, indent=2))

    rows += [
        (f"scaleout/scaling_d{d_max}_vs_d{min(devices)}", 0.0,
         f"{scaling:.2f}x aggregate fps retained (weak scaling, "
         f"{lanes_per_device} lane/device, serialised host devices)"),
        (f"scaleout/fused_d1_s{n_streams}", 1e6 / one_fps * n_streams,
         f"{one_fps:.1f} frames/s aggregate;one fused device round"),
        (f"scaleout/sequential_s{n_streams}", 1e6 / seq_fps * n_streams,
         f"{seq_fps:.1f} frames/s;eager per-stream loop"),
        (f"scaleout/fused_vs_sequential_s{n_streams}", 0.0,
         f"{fused_vs_seq:.2f}x aggregate fps (the fleet-batching win)"),
        ("scaleout/parity_max_abs", 0.0,
         f"{parity:.1e} vs single-device pipeline (must be 0.0)"),
        ("scaleout/retraces_after_warmup", 0.0,
         f"{retraces} across churn (must be 0)"),
        ("scaleout/submap_bytes", 0.0,
         f"fp32={b32}B fp16={b16}B ratio={b32 / b16:.2f}x "
         f"(must be >=1.9x)"),
        ("scaleout/fp16_drift_final", 0.0,
         f"{drift['fp16_drift_final_m']:.3f}m over {drift_frames} frames "
         f"(guard band 0.5m); fp16-vs-fp32 gap "
         f"{drift['fp16_vs_fp32_gap_m']:.3f}m"),
    ]
    assert parity == 0.0, f"sharded parity broke: {parity}"
    assert retraces == 0, f"sharded service retraced: {retraces}"
    assert b32 / b16 >= 1.9, f"fp16 layout only {b32 / b16:.2f}x leaner"
    assert drift["fp16_drift_final_m"] <= 0.5, \
        f"fp16 drift {drift['fp16_drift_final_m']:.3f}m outside guard band"
    if not quick:
        # One core: D devices' rounds serialise, so the honest floors are
        # a bounded sharding tax and the fused-round throughput win over
        # the eager loop (the >=3x aggregate headline lives in the fused
        # round; BENCH_service.json's committed ratio is the precedent).
        assert scaling >= 0.4, \
            f"sharding tax too high: only {scaling:.2f}x retained at " \
            f"D={d_max} on a serialised host"
        assert fused_vs_seq >= 2.0, \
            f"fused fleet round only {fused_vs_seq:.2f}x the eager loop"
    return rows


def run_subprocess(quick: bool = False, timeout: int = 1800,
                   **kwargs) -> dict:
    """Run the sweep in a fresh interpreter (which self-forces the
    8-device host platform) and return the summary dict. This is the
    only way to run it from a process whose jax already initialised with
    1 device. ``kwargs`` forward to :func:`run` via --config."""
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tmp:
        out = tmp.name
    cmd = [sys.executable, "-m", "benchmarks.device_sweep", "--json", out]
    if quick:
        cmd.append("--quick")
    if kwargs:
        cmd += ["--config", json.dumps(kwargs)]
    env = {**os.environ,
           "PYTHONPATH": os.pathsep.join(
               p for p in (str(REPO_ROOT / "src"),
                           os.environ.get("PYTHONPATH")) if p)}
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout, env=env, cwd=str(REPO_ROOT))
    if proc.returncode != 0:
        raise RuntimeError(f"device sweep subprocess failed:\n"
                           f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    summary = json.loads(pathlib.Path(out).read_text())
    os.unlink(out)
    return summary


def run_harness(quick: bool = False):
    """benchmarks.run entry point: subprocess the sweep (the harness
    parent is a 1-device interpreter) and re-emit its headline rows."""
    s = run_subprocess(quick=quick)
    d_lo, d_hi = min(s["devices"]), max(s["devices"])
    return [
        (f"scaleout/fleet_d{d}",
         1e6 / s["sweep"][str(d)]["aggregate_fps"] * d * s[
             "lanes_per_device"],
         f"{s['sweep'][str(d)]['aggregate_fps']:.1f} frames/s aggregate")
        for d in s["devices"]
    ] + [
        (f"scaleout/scaling_d{d_hi}_vs_d{d_lo}", 0.0,
         f"{s['scaling_x']:.2f}x aggregate fps retained"),
        ("scaleout/fused_vs_sequential", 0.0,
         f"{s['fused_vs_sequential_x']:.2f}x aggregate fps"),
        ("scaleout/parity_max_abs", 0.0, f"{s['parity_max_abs']:.1e}"),
        ("scaleout/submap_bytes", 0.0,
         f"ratio={s['submap_bytes_ratio']:.2f}x"),
        ("scaleout/fp16_drift_final", 0.0,
         f"{s['fp16_drift_final_m']:.3f}m"),
    ]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="summary output path (default BENCH_scaleout.json)")
    ap.add_argument("--config", default=None,
                    help="JSON dict of run() kwargs (subprocess plumbing)")
    args = ap.parse_args()
    kw = json.loads(args.config) if args.config else {}
    if "devices" in kw:
        kw["devices"] = tuple(kw["devices"])
    emit(run(quick=args.quick, out_json=args.json, **kw))
