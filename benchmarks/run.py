"""Benchmark harness: one module per paper table. Prints
``name,us_per_call,derived`` CSV.

  table3 -> registration_accuracy  (Table III: RMSE parity)
  table4 -> registration_latency   (Table IV: latency + acceleration)
  table2 -> kernel_resources       (Table II: resource budget)
  power  -> power_efficiency       (§IV-D: perf/W, modeled)
  roofline -> roofline_report      (dry-run roofline summaries)
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (kernel_resources, power_efficiency,
                        registration_accuracy, registration_latency,
                        roofline_report)
from benchmarks.common import emit

SUITES = {
    "table3": registration_accuracy.run,
    "table4": registration_latency.run,
    "table2": kernel_resources.run,
    "power": power_efficiency.run,
    "roofline": roofline_report.run,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(SUITES), default=None)
    args = ap.parse_args(argv)
    failed = []
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        try:
            emit(fn())
        except Exception as e:  # report and continue; fail at the end
            failed.append((name, e))
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmark suites failed: {[n for n, _ in failed]}")


if __name__ == "__main__":
    main()
