"""Benchmark harness: one module per paper table. Prints
``name,us_per_call,derived`` CSV.

  table3 -> registration_accuracy    (Table III: RMSE parity)
  table4 -> registration_latency     (Table IV: latency + acceleration)
  table2 -> kernel_resources         (Table II: resource budget)
  power  -> power_efficiency         (§IV-D: perf/W, modeled)
  roofline -> roofline_report        (dry-run roofline summaries)
  throughput -> registration_throughput (looped vs batched frames/sec;
                                         also writes BENCH_throughput.json)
  nn_sweep -> nn_sweep               (brute vs grid-bucketed NN sweep;
                                         also writes BENCH_nn.json)
  convergence -> convergence         (p2p vs p2plane vs pyramid iteration
                                         counts; writes BENCH_convergence.json)
  odometry -> odometry_drift         (scan-to-map vs frame-to-frame drift +
                                         runtime-weighted frames/s;
                                         writes BENCH_odometry.json)
  robustness -> robustness           (fault matrix x recovery cascade
                                         ON/OFF; writes BENCH_robustness.json)
  service -> service_throughput      (multi-stream fleet rounds vs the
                                         sequential per-stream loop;
                                         writes BENCH_service.json)
  scaleout -> device_sweep           (sharded fleet over a forced 8-device
                                         host platform, run in a subprocess;
                                         writes BENCH_scaleout.json)

``--quick`` runs every suite in smoke mode (reduced scenes, 2 frames,
fewer iterations) so CI can exercise all entry points in seconds.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (convergence, device_sweep, kernel_resources,
                        nn_sweep, odometry_drift, power_efficiency,
                        registration_accuracy, registration_latency,
                        registration_throughput, robustness,
                        roofline_report, service_throughput)
from benchmarks.common import QUICK_SCENE, emit

SUITES = {
    "table3": registration_accuracy.run,
    "table4": registration_latency.run,
    "table2": kernel_resources.run,
    "power": power_efficiency.run,
    "roofline": roofline_report.run,
    "throughput": registration_throughput.run,
    "nn_sweep": nn_sweep.run,
    "convergence": convergence.run,
    "odometry": odometry_drift.run,
    "robustness": robustness.run,
    "service": service_throughput.run,
    # run_harness respawns the sweep in a subprocess: this process's jax
    # is already initialised with 1 device, the sweep needs a forced 8.
    "scaleout": device_sweep.run_harness,
}

# Smoke-mode kwargs per suite (reduced scenes, 2 frames, short loops).
# Suites absent here are already static/fast (table2, roofline).
QUICK_KWARGS = {
    "table3": dict(n_seqs=2, samples=512, scene=QUICK_SCENE),
    "table4": dict(n_seqs=2, samples=512, iters=10, scene=QUICK_SCENE),
    "power": dict(n_seqs=2, samples=512, iters=10, scene=QUICK_SCENE),
    "throughput": dict(quick=True),
    "service": dict(quick=True),
    "scaleout": dict(quick=True),
}
# Suites whose smoke mode is a different entry point, not just kwargs.
QUICK_SUITES = {"nn_sweep": nn_sweep.run_quick,
                "convergence": convergence.run_quick,
                "odometry": odometry_drift.run_quick,
                "robustness": robustness.run_quick}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(SUITES), default=None)
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: reduced scenes / 2 frames per suite")
    args = ap.parse_args(argv)
    failed = []
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        kwargs = QUICK_KWARGS.get(name, {}) if args.quick else {}
        if args.quick and name in QUICK_SUITES:
            fn, kwargs = QUICK_SUITES[name], {}
        try:
            emit(fn(**kwargs))
        except Exception as e:  # report and continue; fail at the end
            failed.append((name, e))
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmark suites failed: {[n for n, _ in failed]}")


if __name__ == "__main__":
    main()
