"""jit'd public wrappers around the Pallas NN kernel.

Handles padding to tile multiples, the once-per-frame target augmentation,
and the per-iteration source augmentation + unpadding. These wrappers have
the same (src, dst[, T]) -> (d2, idx) contract as ``repro.core.nn_search``
so they can be dropped into ``core.icp`` via the ``nn_fn`` hook.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.common import round_up as _round_up
from repro.kernels.nn_search import nn_search_kernel


@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def nn_search_pallas(src: jax.Array, dst: jax.Array,
                     T: jax.Array | None = None,
                     *, bn: int = 512, bm: int = 1024,
                     interpret: bool | None = None):
    """NN of each (optionally T-transformed) src point in dst via the kernel.

    src: (N,3), dst: (M,3); returns ((N,) fp32 d2, (N,) int32 idx).
    Shapes need not be tile-aligned; padding is handled here. Padded target
    slots carry a +1e30 bias so they never win; padded source rows are
    sliced off.
    """
    n, m = src.shape[0], dst.shape[0]
    n_pad, m_pad = _round_up(n, bn), _round_up(m, bm)
    src_aug = ref.augment_source(src, T, pad_to=n_pad)
    dst_aug = ref.augment_target(dst, pad_to=m_pad)
    d2, idx = nn_search_kernel(src_aug, dst_aug, bn=bn, bm=bm,
                               interpret=interpret)
    return jnp.maximum(d2[:n], 0.0), idx[:n]


def resident_nn_fn(dst: jax.Array, *, bn: int = 512, bm: int = 1024,
                   interpret: bool | None = None):
    """In-trace resident-target searcher for use *inside* a jitted program.

    Builds the (8, M') augmented target once at trace position — outside the
    ICP iteration scan/while body that the returned closure is called from —
    so the compiled program augments the target once per frame and only the
    small source cloud per iteration (the BRAM-resident analogue,
    DESIGN.md §2). The closure matches the ``core.icp`` ``nn_fn(src, dst)``
    contract but ignores its second argument in favour of the resident
    augmentation.

    Padded/invalid target rows must already carry far-sentinel coordinates
    (as ``repro.data.collate`` produces) so they cannot win the argmin.
    """
    m = dst.shape[0]
    dst_aug = ref.augment_target(dst, pad_to=_round_up(m, bm))

    def nn_fn(src: jax.Array, _target=None):
        n = src.shape[0]
        src_aug = ref.augment_source(src, pad_to=_round_up(n, bn))
        d2, idx = nn_search_kernel(src_aug, dst_aug, bn=bn, bm=bm,
                                   interpret=interpret)
        return jnp.maximum(d2[:n], 0.0), idx[:n]

    return nn_fn


def make_frame_engine(dst: jax.Array, *, bn: int = 512, bm: int = 1024,
                      interpret: bool | None = None):
    """Pre-augment a target frame once; return nn_fn(src, T) for ICP loops.

    This is the intended production shape: the (8, M) augmented target is
    computed once per frame (the BRAM-resident analogue) and closed over by
    every ICP iteration.
    """
    m = dst.shape[0]
    m_pad = _round_up(m, bm)
    dst_aug = ref.augment_target(dst, pad_to=m_pad)

    @functools.partial(jax.jit, static_argnames=())
    def nn_fn(src: jax.Array, T: jax.Array | None = None):
        n = src.shape[0]
        n_pad = _round_up(n, bn)
        src_aug = ref.augment_source(src, T, pad_to=n_pad)
        d2, idx = nn_search_kernel(src_aug, dst_aug, bn=bn, bm=bm,
                                   interpret=interpret)
        return jnp.maximum(d2[:n], 0.0), idx[:n]

    return nn_fn
