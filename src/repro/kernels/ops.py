"""jit'd public wrappers around the Pallas NN kernel.

Handles padding to tile multiples, the once-per-frame target augmentation,
and the per-iteration source augmentation + unpadding. These wrappers have
the same (src, dst[, T]) -> (d2, idx) contract as ``repro.core.nn_search``
so they can be dropped into ``core.icp`` via the ``nn_fn`` hook.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.nn_search import nn_search_kernel


def _round_up(x: int, mult: int) -> int:
    return x + (-x) % mult


@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def nn_search_pallas(src: jax.Array, dst: jax.Array,
                     T: jax.Array | None = None,
                     *, bn: int = 512, bm: int = 1024,
                     interpret: bool = False):
    """NN of each (optionally T-transformed) src point in dst via the kernel.

    src: (N,3), dst: (M,3); returns ((N,) fp32 d2, (N,) int32 idx).
    Shapes need not be tile-aligned; padding is handled here. Padded target
    slots carry a +1e30 bias so they never win; padded source rows are
    sliced off.
    """
    n, m = src.shape[0], dst.shape[0]
    n_pad, m_pad = _round_up(n, bn), _round_up(m, bm)
    src_aug = ref.augment_source(src, T, pad_to=n_pad)
    dst_aug = ref.augment_target(dst, pad_to=m_pad)
    d2, idx = nn_search_kernel(src_aug, dst_aug, bn=bn, bm=bm,
                               interpret=interpret)
    return jnp.maximum(d2[:n], 0.0), idx[:n]


def make_frame_engine(dst: jax.Array, *, bn: int = 512, bm: int = 1024,
                      interpret: bool = False):
    """Pre-augment a target frame once; return nn_fn(src, T) for ICP loops.

    This is the intended production shape: the (8, M) augmented target is
    computed once per frame (the BRAM-resident analogue) and closed over by
    every ICP iteration.
    """
    m = dst.shape[0]
    m_pad = _round_up(m, bm)
    dst_aug = ref.augment_target(dst, pad_to=m_pad)

    @functools.partial(jax.jit, static_argnames=())
    def nn_fn(src: jax.Array, T: jax.Array | None = None):
        n = src.shape[0]
        n_pad = _round_up(n, bn)
        src_aug = ref.augment_source(src, T, pad_to=n_pad)
        d2, idx = nn_search_kernel(src_aug, dst_aug, bn=bn, bm=bm,
                                   interpret=interpret)
        return jnp.maximum(d2[:n], 0.0), idx[:n]

    return nn_fn
