"""Shared Pallas plumbing: interpret-mode resolution + compiler params.

Every kernel wrapper in ``repro.kernels`` takes an ``interpret`` knob with
the same tri-state meaning:

  * ``None`` (auto) — run compiled on TPU, interpret everywhere else, so
    the full kernel suite *executes* (instead of skipping) on CPU-only CI
    while TPU hosts get the real Mosaic lowering with zero configuration;
  * ``True`` / ``False`` — force one mode (tests pin ``True``; autotuning
    on hardware pins ``False``).

Before this module each kernel hand-rolled the same try/except block for
the TPU ``dimension_semantics`` compiler params and its own interpret
default; :func:`pallas_call_kwargs` is now the single place both live.
"""
from __future__ import annotations

import jax


def round_up(x: int, mult: int) -> int:
    """Smallest multiple of ``mult`` that is >= ``x`` (tile padding)."""
    return x + (-x) % mult


def default_interpret(interpret: bool | None = None) -> bool:
    """Resolve the tri-state ``interpret`` flag (see module docstring)."""
    if interpret is None:
        # tracecheck: ignore[PK001]  # this IS the single blessed home
        return jax.default_backend() != "tpu"
    return bool(interpret)


def pallas_call_kwargs(interpret: bool | None,
                       dimension_semantics: tuple[str, ...]) -> dict:
    """``pl.pallas_call`` kwargs: resolved interpret + TPU compiler params.

    ``dimension_semantics`` labels each grid axis "parallel" or
    "arbitrary" (axes carrying a running min / accumulator must be
    "arbitrary" so Mosaic keeps them sequential). The knob is TPU-only and
    silently skipped on other compiled backends.
    """
    resolved = default_interpret(interpret)
    kwargs: dict = {"interpret": resolved}
    if not resolved:
        try:  # TPU-only knob; harmless to skip elsewhere.
            from jax.experimental.pallas import tpu as pltpu
            params_cls = getattr(pltpu, "CompilerParams", None) or getattr(
                pltpu, "TPUCompilerParams")
            kwargs["compiler_params"] = params_cls(
                dimension_semantics=dimension_semantics)
        except Exception:  # pragma: no cover - non-TPU backends
            pass
    return kwargs
