"""Pallas TPU kernel: grid-bucketed NN candidate sweep.

The brute-force kernel (``kernels/nn_search.py``) streams *target tiles*
through VMEM against a resident source block; this kernel streams
*candidate tiles*. The XLA side gathers each query's 27-neighbourhood from
the :class:`repro.data.voxelize.VoxelGrid` tables into a dense per-query
candidate matrix (a data-dependent gather the XLA scatter/gather engine is
the right tool for), then the kernel does the dense part — distance + the
running (min, argmin) carry — in VMEM:

  * grid = (N/bn, CK/bc): query blocks are "parallel", the candidate axis
    is innermost/"arbitrary" carrying the running min, exactly like the
    brute kernel's target axis.
  * the candidate set is per-query, so the distance tile is an *elementwise*
    (bn, bc) op on coordinate planes (VPU work) rather than a matmul — with
    CK = 27*max_per_cell ≈ a few hundred, there is no shared-operand
    structure for the MXU to exploit, and the whole sweep is tiny compared
    to the O(M) brute tile stream it replaces.
  * masked candidate slots arrive pre-filled with far-sentinel coordinates
    (see ``core.nn_search_grid``), so the kernel needs no mask input and no
    NaN path — the same finite-sentinel trick as everywhere else.

The kernel returns the winning *slot* per query; the wrapper maps slots
back through the gather tables to original target indices and recomputes
the winner distance directly (exact, no cancellation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.nn_search_grid import _MASK_COORD, gather_candidates
from repro.data.voxelize import VoxelGrid
from repro.kernels.common import pallas_call_kwargs, round_up as _round_up


def _grid_nn_kernel(qx_ref, qy_ref, qz_ref, cx_ref, cy_ref, cz_ref,
                    best_d2_ref, best_slot_ref, *, bc: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_d2_ref[...] = jnp.full_like(best_d2_ref, jnp.inf)
        best_slot_ref[...] = jnp.zeros_like(best_slot_ref)

    # (bn, bc) distance tile from coordinate planes: pure VPU.
    dx = qx_ref[...][:, None] - cx_ref[...]
    dy = qy_ref[...][:, None] - cy_ref[...]
    dz = qz_ref[...][:, None] - cz_ref[...]
    d2 = dx * dx + dy * dy + dz * dz
    local_arg = jnp.argmin(d2, axis=1).astype(jnp.int32)
    local_min = jnp.min(d2, axis=1)
    # Strict < keeps the earliest slot on ties (first-match semantics).
    improved = local_min < best_d2_ref[...]
    best_d2_ref[...] = jnp.where(improved, local_min, best_d2_ref[...])
    best_slot_ref[...] = jnp.where(improved, j * bc + local_arg,
                                   best_slot_ref[...])


def candidate_sweep_kernel(q: jax.Array, cand: jax.Array, *,
                           bn: int = 512, bc: int = 256,
                           interpret: bool | None = None):
    """Masked rowwise argmin over per-query candidate sets.

    Args:
      q: (N, 3) queries; N must be a multiple of bn.
      cand: (N, CK, 3) candidate coordinates (masked slots = sentinel);
        CK must be a multiple of bc.
    Returns:
      (best_d2, best_slot): (N,) fp32 (unclamped) and (N,) int32 slot into
      the candidate axis.
    """
    n, ck = cand.shape[0], cand.shape[1]
    assert n % bn == 0, (n, bn)
    assert ck % bc == 0, (ck, bc)
    grid = (n // bn, ck // bc)
    qx, qy, qz = (q[:, a].astype(jnp.float32) for a in range(3))
    cx, cy, cz = (cand[:, :, a].astype(jnp.float32) for a in range(3))
    kernel = functools.partial(_grid_nn_kernel, bc=bc)
    out_shape = (jax.ShapeDtypeStruct((n,), jnp.float32),
                 jax.ShapeDtypeStruct((n,), jnp.int32))
    qspec = pl.BlockSpec((bn,), lambda i, j: (i,))
    cspec = pl.BlockSpec((bn, bc), lambda i, j: (i, j))
    out_specs = (pl.BlockSpec((bn,), lambda i, j: (i,)),
                 pl.BlockSpec((bn,), lambda i, j: (i,)))
    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[qspec, qspec, qspec, cspec, cspec, cspec],
        out_specs=out_specs,
        out_shape=out_shape,
        **pallas_call_kwargs(interpret, ("parallel", "arbitrary")),
    )
    return call(qx, qy, qz, cx, cy, cz)


def nn_search_grid_pallas(src: jax.Array, grid: VoxelGrid, *,
                          max_per_cell: int = 32, rings: int = 1,
                          bn: int = 512, bc: int = 256,
                          interpret: bool | None = None,
                          return_points: bool = False):
    """Grid NN with the candidate sweep run as a Pallas kernel.

    Same contract as ``core.nn_search_grid.nn_search_grid`` (without the
    exact fallback — empty neighbourhoods return ``d2 = +inf``): exact
    wherever the true NN is within ``rings * voxel_size`` and its cell
    didn't overflow.
    """
    n = src.shape[0]
    cand_pts, cand_idx, cand_valid = gather_candidates(src, grid,
                                                       max_per_cell, rings)
    ck = cand_pts.shape[1]
    n_pad, ck_pad = _round_up(n, bn), _round_up(ck, bc)
    if n_pad > n or ck_pad > ck:
        cand_pts = jnp.pad(cand_pts,
                           ((0, n_pad - n), (0, ck_pad - ck), (0, 0)),
                           constant_values=_MASK_COORD)
        src_p = jnp.pad(src.astype(jnp.float32), ((0, n_pad - n), (0, 0)))
    else:
        src_p = src.astype(jnp.float32)
    best_d2, best_slot = candidate_sweep_kernel(src_p, cand_pts, bn=bn,
                                                bc=bc, interpret=interpret)
    best_d2, best_slot = best_d2[:n], jnp.clip(best_slot[:n], 0, ck - 1)
    rows = jnp.arange(n)
    best_idx = cand_idx[rows, best_slot]
    matched = cand_pts[:n][rows, best_slot]
    has_cand = jnp.any(cand_valid, axis=1)
    # Recompute the winner distance directly (exact) where a winner exists.
    diff = src.astype(jnp.float32) - matched
    exact = jnp.sum(diff * diff, axis=-1)
    best_d2 = jnp.where(has_cand, exact, jnp.inf)
    best_idx = jnp.where(has_cand, best_idx, 0)
    if return_points:
        return jnp.maximum(best_d2, 0.0), best_idx, matched
    return jnp.maximum(best_d2, 0.0), best_idx


def grid_kernel_nn_fn(grid: VoxelGrid, *, max_per_cell: int = 32,
                      rings: int = 1, bn: int = 512, bc: int = 256,
                      interpret: bool | None = None):
    """Resident-grid Pallas searcher with the ``core.icp`` nn_fn contract
    (the voxel grid, like the augmented target, lives at trace scope)."""

    def nn_fn(src, _target=None):
        return nn_search_grid_pallas(src, grid, max_per_cell=max_per_cell,
                                     rings=rings, bn=bn, bc=bc,
                                     interpret=interpret,
                                     return_points=True)

    return nn_fn
