"""Pallas TPU kernel: fused single-pass ICP iteration (DESIGN.md §11).

The unfused iteration is four separate XLA ops — grid-candidate sweep →
winner gather → distance gate + robust weight → Kabsch / Gauss-Newton
moment matmuls — and every stage round-trips its intermediates through
HBM. FPPS's whole thesis (§IV: 35x peak) is a streamed dataflow pipeline
where correspondence candidates never leave the chip between search and
accumulation; this kernel is that pipeline on the TPU:

  * grid = (N/bn, CK/bc): query blocks "parallel", the candidate axis
    innermost/"arbitrary". Per candidate tile the kernel computes the
    (bn, bc) distance plane in VMEM, reduces it to a per-query running
    (min, winner-coordinates[, winner-normal]) carry — the winner's
    *values* are selected in-register via a one-hot lane reduction, so no
    index gather ever revisits earlier tiles.
  * on the **last** candidate tile the carried winner is gated
    (``d² ≤ gate²``, recomputed exact in fp32 from the carried
    coordinates), IRLS-weighted (huber/tukey, same formulas as
    ``core.point_to_plane.robust_weights``), and folded into per-query
    moment planes: the Kabsch sums (Σw, Σw·p, Σw·q, Σw·p⊗q, Σw·|p|²,
    Σw·|q|²) for point-to-point, or the 6x6 normal-equation blocks
    (Σw·a⊗a, Σw·r·a with a = [p×n; n]) for point-to-plane — the
    ten-plane running-sum + shared host epilogue template proven in
    ``kernels/normals.py``, widened to the ICP moment set.
  * the host-side epilogue (``core.transform.estimate_from_moments`` /
    ``core.point_to_plane.solve_normal_equations``) reduces the (N,)
    planes to scalars and performs the tiny 3x3-SVD / 6x6 solve — O(1)
    work per iteration, like the paper's result-accumulator stage.
  * masked candidate slots carry far-sentinel coordinates
    (``core.nn_search_grid``) and masked queries carry ``src_valid = 0``,
    so empty neighbourhoods and padded rows fall out of the gate with
    zero weight — no mask inputs, no NaN path, and the PR-5 zero-weight
    freeze triggers naturally when *every* row lands there.

Mixed-precision candidate prune (``prune=True``): a **bf16** distance
screen at a *widened* gate (``prune_margin`` ≥ any bf16 rounding of a
within-gate distance, so no true inlier is ever screened out) decides
which candidates get the exact fp32 update — and, via ``pl.when``, lets
the kernel skip the fp32 pass for whole tiles the screen rejects.
Selection among survivors runs on exact fp32 distances, so the moments
are *identical* to the unpruned pass: a screened candidate is provably
out-of-gate and would carry zero weight regardless of which of them
wins. The prune pays half-width math up front to skip full-width math on
cold tiles; whether that nets out is hardware-dependent, which is
exactly the knob the autotune sweep (``tools/autotune_fused.py``) flips.

Point-to-plane normals ride as candidate payload: three extra (bn, bc)
planes gathered through the same slot tables, selected by the same
one-hot carry — matching Sugiura & Matsutani's feature-payload streaming
(arXiv:2203.05763).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.nn_search_grid import _MASK_COORD, gather_candidates
from repro.data.voxelize import VoxelGrid, build_voxel_grid
from repro.kernels.common import pallas_call_kwargs, round_up

# Default fused-kernel configuration. bn/bc/prune are the autotune axes;
# the committed choice (see BENCH_fused_autotune.json, re-run via
# ``python tools/autotune_fused.py``) is baked in here so library users
# get the tuned config with no file I/O.
class FusedConfig(NamedTuple):
    bn: int = 512            # query tile (rows per grid step)
    bc: int = 256            # candidate plane width (lanes per grid step)
    prune: bool = False      # bf16 coarse-distance screen (see module doc)
    prune_margin: float = 1.1  # gate widening for the bf16 screen


DEFAULT_CONFIG = FusedConfig()

# Finest-lattice default, matching ``core.pyramid.DEFAULT_GRID_DIMS``.
DEFAULT_GRID_DIMS: tuple[int, int, int] = (128, 128, 32)

# Moment-plane order (the kernel's output contract, after the carries).
# The "rmse block" is what the exact post-step RMSE needs: first moments
# of p and q, the raw cross moments Σw·p_i·q_j, and the squared norms.
_RMSE_BLOCK = (
    "px", "py", "pz", "qx", "qy", "qz",
    "pq00", "pq01", "pq02", "pq10", "pq11", "pq12",
    "pq20", "pq21", "pq22", "pp", "qq",
)
_AA = tuple(f"a{k}{li}" for k in range(6) for li in range(k, 6))  # 21
_RA = tuple(f"ra{k}" for k in range(6))
P2P_MOMENTS = ("w",) + _RMSE_BLOCK                         # 18 planes
P2PLANE_MOMENTS = ("w",) + _AA + _RA + _RMSE_BLOCK         # 45 planes


def moment_names(plane: bool) -> tuple[str, ...]:
    return P2PLANE_MOMENTS if plane else P2P_MOMENTS


def _carry_count(plane: bool) -> int:
    # running min + winner coordinates (+ winner normal for p2plane)
    return 7 if plane else 4


def _fused_kernel(*refs, bc: int, nc: int, gate2: float, prune_gate2: float,
                  robust: str, scale: float, plane: bool, prune: bool):
    n_in = 10 if plane else 7
    in_refs, out_refs = refs[:n_in], refs[n_in:]
    qx_ref, qy_ref, qz_ref, sv_ref = in_refs[:4]
    cx_ref, cy_ref, cz_ref = in_refs[4:7]
    ncarry = _carry_count(plane)
    carry_refs, mom_refs = out_refs[:ncarry], out_refs[ncarry:]
    best_ref, bqx_ref, bqy_ref, bqz_ref = carry_refs[:4]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref, jnp.inf)
        for ref in (bqx_ref, bqy_ref, bqz_ref):
            ref[...] = jnp.full_like(ref, _MASK_COORD)
        if plane:
            for ref in carry_refs[4:]:
                ref[...] = jnp.zeros_like(ref)

    qx, qy, qz = qx_ref[...], qy_ref[...], qz_ref[...]
    cx, cy, cz = cx_ref[...], cy_ref[...], cz_ref[...]
    if prune:
        # bf16 coarse *screen* at a widened gate: half-width math decides
        # only which candidates (and, via pl.when, which whole tiles) get
        # the exact fp32 update. prune_margin exceeds bf16 rounding, so no
        # within-gate candidate is ever screened, and selection among the
        # survivors runs on exact fp32 distances — moments are identical
        # to the unpruned pass (screened rows are provably out-of-gate and
        # would carry weight 0 regardless of which of them wins).
        bf = jnp.bfloat16
        dxb = cx.astype(bf) - qx.astype(bf)[:, None]
        dyb = cy.astype(bf) - qy.astype(bf)[:, None]
        dzb = cz.astype(bf) - qz.astype(bf)[:, None]
        d2b = (dxb * dxb + dyb * dyb + dzb * dzb).astype(jnp.float32)
        keep = d2b <= prune_gate2

    def _update():
        dx = cx - qx[:, None]
        dy = cy - qy[:, None]
        dz = cz - qz[:, None]
        d2 = dx * dx + dy * dy + dz * dz
        if prune:
            d2 = jnp.where(keep, d2, jnp.inf)
        local_arg = jnp.argmin(d2, axis=1).astype(jnp.int32)
        local_min = jnp.min(d2, axis=1)
        # In-register winner-value selection: one-hot of the tile argmin,
        # so the carry holds coordinates/normals, never indices to
        # re-gather.
        onehot = jax.lax.broadcasted_iota(
            jnp.int32, d2.shape, 1) == local_arg[:, None]

        def _sel(vals):
            return jnp.sum(jnp.where(onehot, vals, 0.0), axis=1)

        # Strict < keeps the earliest tile/slot on ties (first-match
        # semantics, same as the NN kernels).
        improved = local_min < best_ref[...]
        best_ref[...] = jnp.where(improved, local_min, best_ref[...])
        bqx_ref[...] = jnp.where(improved, _sel(cx), bqx_ref[...])
        bqy_ref[...] = jnp.where(improved, _sel(cy), bqy_ref[...])
        bqz_ref[...] = jnp.where(improved, _sel(cz), bqz_ref[...])
        if plane:
            bnx_ref, bny_ref, bnz_ref = carry_refs[4:]
            nx, ny, nz = in_refs[7][...], in_refs[8][...], in_refs[9][...]
            bnx_ref[...] = jnp.where(improved, _sel(nx), bnx_ref[...])
            bny_ref[...] = jnp.where(improved, _sel(ny), bny_ref[...])
            bnz_ref[...] = jnp.where(improved, _sel(nz), bnz_ref[...])

    if prune:
        # Whole-tile skip: when the bf16 screen rejects every candidate in
        # the (bn, bc) tile, the fp32 pass is provably a no-op (all-inf
        # local_min never improves the carry) — don't execute it.
        pl.when(jnp.any(keep))(_update)
    else:
        _update()

    @pl.when(j == nc - 1)
    def _epilogue():
        px, py, pz = qx, qy, qz
        wqx, wqy, wqz = bqx_ref[...], bqy_ref[...], bqz_ref[...]
        ex, ey, ez = px - wqx, py - wqy, pz - wqz
        d2x = ex * ex + ey * ey + ez * ez       # exact fp32, carried winner
        w = (d2x <= gate2).astype(jnp.float32) * sv_ref[...]
        if plane:
            nxv, nyv, nzv = (carry_refs[4][...], carry_refs[5][...],
                             carry_refs[6][...])
            r = nxv * ex + nyv * ey + nzv * ez  # n·(p − q)
        if robust != "none":
            resid = (jnp.abs(r) if plane
                     else jnp.sqrt(jnp.maximum(d2x, 0.0)))
            if robust == "huber":
                w = w * jnp.minimum(1.0, scale / jnp.maximum(resid, 1e-12))
            else:  # tukey
                u = resid / max(scale, 1e-12)
                w = w * jnp.where(u < 1.0, (1.0 - u * u) ** 2, 0.0)
        rmse_block = [w * px, w * py, w * pz, w * wqx, w * wqy, w * wqz]
        for pi in (px, py, pz):
            for qi in (wqx, wqy, wqz):
                rmse_block.append(w * pi * qi)
        rmse_block.append(w * (px * px + py * py + pz * pz))
        rmse_block.append(w * (wqx * wqx + wqy * wqy + wqz * wqz))
        planes_out = [w]
        if plane:
            a = (py * nzv - pz * nyv, pz * nxv - px * nzv,
                 px * nyv - py * nxv, nxv, nyv, nzv)   # [p×n; n]
            for k in range(6):
                for li in range(k, 6):
                    planes_out.append(w * a[k] * a[li])
            for k in range(6):
                planes_out.append(w * r * a[k])
        planes_out.extend(rmse_block)
        for ref, val in zip(mom_refs, planes_out):
            ref[...] = val


def fused_moment_sweep(q: jax.Array, cand: jax.Array,
                       src_valid: jax.Array | None = None,
                       cand_normals: jax.Array | None = None, *,
                       gate: float, robust_kernel: str = "none",
                       robust_scale: float = 0.5,
                       bn: int = 256, bc: int = 128,
                       prune: bool = False, prune_margin: float = 1.1,
                       interpret: bool | None = None) -> dict:
    """One fused candidate pass: NN min + gate + IRLS weight + moments.

    Args:
      q: (N, 3) transformed source points (the iteration's queries).
      cand: (N, CK, 3) candidate coordinates (masked slots = sentinel).
      src_valid: optional (N,) bool/float mask; invalid rows get weight 0.
      cand_normals: (N, CK, 3) candidate normals — required for (and
        selects) the point-to-plane moment set; invalid slots must be 0.
      gate / robust_kernel / robust_scale: the ``ICPParams`` weighting
        (static — they specialise the kernel).
      bn / bc / prune / prune_margin: kernel config (see ``FusedConfig``).

    Returns:
      dict mapping :func:`moment_names` to scalar fp32 sums over all N
      queries (padded rows contribute zero by construction).
    """
    plane = cand_normals is not None
    n, ck = cand.shape[0], cand.shape[1]
    n_pad, ck_pad = round_up(n, bn), round_up(ck, bc)
    qf = q.astype(jnp.float32)
    sv = (jnp.ones((n,), jnp.float32) if src_valid is None
          else src_valid.astype(jnp.float32))
    candf = cand.astype(jnp.float32)
    if n_pad > n or ck_pad > ck:
        candf = jnp.pad(candf, ((0, n_pad - n), (0, ck_pad - ck), (0, 0)),
                        constant_values=_MASK_COORD)
        qf = jnp.pad(qf, ((0, n_pad - n), (0, 0)))
        sv = jnp.pad(sv, (0, n_pad - n))
        if plane:
            cand_normals = jnp.pad(
                cand_normals.astype(jnp.float32),
                ((0, n_pad - n), (0, ck_pad - ck), (0, 0)))
    grid = (n_pad // bn, ck_pad // bc)
    qx, qy, qz = (qf[:, a] for a in range(3))
    inputs = [qx, qy, qz, sv] + [candf[:, :, a] for a in range(3)]
    if plane:
        inputs += [cand_normals[:, :, a].astype(jnp.float32)
                   for a in range(3)]
    names = moment_names(plane)
    n_out = _carry_count(plane) + len(names)
    kernel = functools.partial(
        _fused_kernel, bc=bc, nc=grid[1], gate2=float(gate) ** 2,
        prune_gate2=(float(gate) * float(prune_margin)) ** 2,
        robust=robust_kernel, scale=float(robust_scale),
        plane=plane, prune=prune)
    vspec = pl.BlockSpec((bn,), lambda i, j: (i,))
    cspec = pl.BlockSpec((bn, bc), lambda i, j: (i, j))
    in_specs = [vspec] * 4 + [cspec] * (6 if plane else 3)
    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=tuple(vspec for _ in range(n_out)),
        out_shape=tuple(jax.ShapeDtypeStruct((n_pad,), jnp.float32)
                        for _ in range(n_out)),
        **pallas_call_kwargs(interpret, ("parallel", "arbitrary")),
    )
    outs = call(*inputs)
    mom_planes = outs[_carry_count(plane):]
    # Padded rows carry sv = 0 ⇒ zero moments, so the sum runs full-width
    # (one fused XLA reduction per plane — the host epilogue's only O(N)).
    return {name: jnp.sum(p) for name, p in zip(names, mom_planes)}


class PointMoments(NamedTuple):
    """Σ-moments of one point-to-point iteration (fused-kernel output)."""
    sw: jax.Array          # Σw
    sp: jax.Array          # (3,) Σw·p
    sq: jax.Array          # (3,) Σw·q
    spq: jax.Array         # (3,3) Σw·p⊗q (raw, uncentred)
    spp: jax.Array         # Σw·|p|²
    sqq: jax.Array         # Σw·|q|²


class PlaneMoments(NamedTuple):
    """Σ-moments of one point-to-plane iteration (fused-kernel output)."""
    sw: jax.Array
    A: jax.Array           # (6,6) Σw·a⊗a, a = [p×n; n]
    b: jax.Array           # (6,) −Σw·r·a (the GN right-hand side)
    sp: jax.Array
    sq: jax.Array
    spq: jax.Array
    spp: jax.Array
    sqq: jax.Array


def _rmse_moments(s: dict):
    sp = jnp.stack([s["px"], s["py"], s["pz"]])
    sq = jnp.stack([s["qx"], s["qy"], s["qz"]])
    spq = jnp.stack([
        jnp.stack([s[f"pq{i}{j}"] for j in range(3)]) for i in range(3)])
    return sp, sq, spq, s["pp"], s["qq"]


def _assemble(s: dict, plane: bool):
    sp, sq, spq, spp, sqq = _rmse_moments(s)
    if not plane:
        return PointMoments(sw=s["w"], sp=sp, sq=sq, spq=spq,
                            spp=spp, sqq=sqq)
    A = jnp.zeros((6, 6), jnp.float32)
    for k in range(6):
        for li in range(k, 6):
            A = A.at[k, li].set(s[f"a{k}{li}"])
            A = A.at[li, k].set(s[f"a{k}{li}"])
    b = -jnp.stack([s[f"ra{k}"] for k in range(6)])
    return PlaneMoments(sw=s["w"], A=A, b=b, sp=sp, sq=sq, spq=spq,
                        spp=spp, sqq=sqq)


def make_fused_fn(grid: VoxelGrid, params, target_normals=None, *,
                  max_per_cell: int = 32, rings: int = 1,
                  bn: int | None = None, bc: int | None = None,
                  prune: bool | None = None,
                  prune_margin: float | None = None,
                  interpret: bool | None = None):
    """Resident-grid fused iteration: ``fused_fn(src_t, src_valid)`` →
    :class:`PointMoments` / :class:`PlaneMoments`.

    Like ``grid_nn_fn``, the voxel grid (and the target normals, for the
    plane minimiser) live at trace scope; per iteration only the
    candidate gather + the single fused pass run. ``params`` is an
    ``core.icp.ICPParams`` (gate / minimizer / robust fields are baked
    into the kernel as static config).
    """
    cfg = DEFAULT_CONFIG
    bn = cfg.bn if bn is None else bn
    bc = cfg.bc if bc is None else bc
    prune = cfg.prune if prune is None else prune
    prune_margin = cfg.prune_margin if prune_margin is None else prune_margin
    plane = params.minimizer == "point_to_plane"
    if plane and target_normals is None:
        raise ValueError("minimizer='point_to_plane' needs target_normals "
                         "for the fused iteration (the kernel streams them "
                         "as candidate payload)")

    def fused_fn(src_t: jax.Array, src_valid: jax.Array | None = None):
        cand_pts, cand_idx, cand_valid = gather_candidates(
            src_t, grid, max_per_cell, rings)
        cand_n = None
        if plane:
            cand_n = jnp.where(cand_valid[..., None],
                               jnp.take(target_normals, cand_idx, axis=0),
                               0.0)
        sums = fused_moment_sweep(
            src_t, cand_pts, src_valid, cand_n,
            gate=params.max_correspondence_distance,
            robust_kernel=params.robust_kernel,
            robust_scale=params.robust_scale,
            bn=bn, bc=bc, prune=prune, prune_margin=prune_margin,
            interpret=interpret)
        return _assemble(sums, plane)

    return fused_fn


def default_fused_fn(target: jax.Array, params, *,
                     dst_valid: jax.Array | None = None,
                     target_normals: jax.Array | None = None,
                     grid_dims: tuple[int, int, int] = DEFAULT_GRID_DIMS,
                     grid_voxel: float | None = None,
                     max_per_cell: int = 32, rings: int = 1,
                     **kw):
    """Build the fused iteration for a raw target cloud: counting-sort
    grid at trace scope (voxel ≥ gate ⇒ every gate-passing correspondence
    is found, same exactness rule as the pyramid polish), then
    :func:`make_fused_fn`."""
    gv = (float(grid_voxel) if grid_voxel is not None
          else max(1.0, params.max_correspondence_distance))
    grid = build_voxel_grid(target.astype(jnp.float32), gv, grid_dims,
                            valid=dst_valid)
    return make_fused_fn(grid, params, target_normals,
                         max_per_cell=max_per_cell, rings=rings, **kw)


# -- static resource / roofline model (Table II analogue) -------------------

def fused_vmem_bytes(bn: int, bc: int, *, plane: bool = False,
                     prune: bool = False) -> dict:
    """Static VMEM budget of one fused grid step."""
    query = 4 * bn * 4                       # qx, qy, qz, sv
    cand = (6 if plane else 3) * bn * bc * 4  # coordinate (+normal) planes
    d2 = bn * bc * (2 if prune else 4)       # distance screen scratch
    carries = _carry_count(plane) * bn * 4
    moments = len(moment_names(plane)) * bn * 4
    total = query + cand + d2 + carries + moments
    return {
        "query_tile": query, "cand_tile": cand, "d2_scratch": d2,
        "carries": carries, "moment_planes": moments,
        "total_single": total,
        # in/out tiles double-buffered by the grid pipeline; d2 is scratch
        "total_double_buffered": 2 * (query + cand + carries + moments) + d2,
    }


def fused_cost_model(n: int, ck: int, *, plane: bool = False) -> dict:
    """FLOP / HBM-byte totals of one iteration: fused pass vs the
    separate-op chain (sweep kernel → winner gather → weight → moment
    matmuls). Both include the XLA-side candidate gather write+read; the
    chain additionally round-trips the winner/weight intermediates and
    re-reads the candidate matrix for the winner gather.
    """
    planes = 6 if plane else 3
    pmoms = len(moment_names(plane))
    dist_flops = 8 * n * ck                    # diff, square, add, min-tree
    select_flops = (2 + planes) * n * ck       # one-hot select reductions
    epilogue_flops = (160 if plane else 60) * n
    cand_bytes = planes * n * ck * 4
    fused = {
        "flops": dist_flops + select_flops + epilogue_flops,
        "hbm_bytes": (2 * cand_bytes            # gather write + kernel read
                      + 4 * n * 4               # queries + src_valid
                      + pmoms * n * 4),         # moment planes out
    }
    chain = {
        "flops": dist_flops + epilogue_flops + 2 * 3 * n * 3,  # + cov matmul
        "hbm_bytes": (2 * cand_bytes            # gather write + sweep read
                      + cand_bytes              # winner-gather re-read
                      + 3 * n * 4               # queries
                      + 2 * (n * 4 + n * 4)     # (d2, slot) out + re-read
                      + 6 * (3 * n * 4)),       # matched/weight/moment passes
    }
    for d in (fused, chain):
        d["flop_per_byte"] = d["flops"] / d["hbm_bytes"]
    return {"fused": fused, "chain": chain,
            "hbm_ratio": chain["hbm_bytes"] / fused["hbm_bytes"]}
