"""Pallas TPU kernel: FPPS brute-force NN search.

FPGA -> TPU mapping (DESIGN.md §2):

  * The paper's systolic PE array computes all pairwise distances between a
    register-buffered source tile and a streamed/broadcast target batch. On
    TPU the distance grid *is* a matmul on the 128x128 MXU once rewritten as
    an augmented inner product:

        d2[i,j] = ||R p_i + t - q_j||²
                = p'·p' - 2 p'·q + q·q                        (p' = R p + t)
                = [p'x p'y p'z 1 ||p'||² 0 0 0] · [-2qx -2qy -2qz ||q||² 1 0 0 0]ᵀ

    Both augmented operands are (8, len) — 8 is the fp32 sublane tile, so the
    contraction is exactly one MXU pass per (bn x bm) tile.

  * The transform stage (paper's "point cloud transformer") is folded
    algebraically into the *source* augmentation: O(N) work per ICP
    iteration on the 4k-point query cloud, while the (8, M) target
    augmentation is built ONCE per frame and stays resident — the analogue
    of FPPS parking the whole target cloud in BRAM across all 50 iterations.

  * The paper's MIN block (running min + candidate-index registers) is the
    (best_d2, best_idx) output pair revisited across the target-block grid
    axis: the output BlockSpec ignores the inner grid index, so the same
    VMEM tile is read-modify-written as target blocks stream through —
    Pallas's grid pipeline provides the FIFO-style overlap of the paper's
    4-stage streaming design (load of block j+1 overlaps compute of j).

  * The paper's comparison tree (CMP TR) is the in-tile `min`/`argmin` lane
    reduction on the VPU.

Grid: (N/bn, M/bm), target axis innermost ("arbitrary" semantics — it
carries the running min; the source axis is "parallel").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import pallas_call_kwargs

AUG_ROWS = 8


def _nn_kernel(src_ref, dst_ref, best_d2_ref, best_idx_ref, *, bm: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_d2_ref[...] = jnp.full_like(best_d2_ref, jnp.inf)
        best_idx_ref[...] = jnp.zeros_like(best_idx_ref)

    # (8, bn) x (8, bm) -> (bn, bm): one MXU tile, fp32 accumulation.
    scores = jax.lax.dot_general(
        src_ref[...], dst_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    # CMP-tree stage: per-row reduction over the bm candidates.
    local_arg = jnp.argmin(scores, axis=1).astype(jnp.int32)
    local_min = jnp.min(scores, axis=1)
    # MIN-block stage: strict < keeps the earliest index on ties, matching
    # the oracle's first-match semantics.
    cand_idx = j * bm + local_arg
    improved = local_min < best_d2_ref[...]
    best_d2_ref[...] = jnp.where(improved, local_min, best_d2_ref[...])
    best_idx_ref[...] = jnp.where(improved, cand_idx, best_idx_ref[...])


def nn_search_kernel(src_aug: jax.Array, dst_aug: jax.Array,
                     *, bn: int = 512, bm: int = 1024,
                     interpret: bool | None = None):
    """Run the NN kernel on pre-augmented operands.

    Args:
      src_aug: (8, N) from ``ref.augment_source`` — N must be a multiple of bn.
      dst_aug: (8, M) from ``ref.augment_target`` — M must be a multiple of bm.
      bn, bm: VMEM tile sizes. Defaults give tiles of
        src 8*512*4 = 16 KiB, dst 8*1024*4 = 32 KiB, scores 512*1024*4 = 2 MiB
        — comfortably double-bufferable in ~128 MiB v5e VMEM while keeping
        the MXU dims (bn, bm) at 128-multiples.
      interpret: tri-state (``kernels.common``): None = auto (compiled on
        TPU, interpreter elsewhere).
    Returns:
      (best_d2, best_idx): (N,) fp32 (unclamped) and (N,) int32.
    """
    n = src_aug.shape[1]
    m = dst_aug.shape[1]
    assert src_aug.shape[0] == AUG_ROWS and dst_aug.shape[0] == AUG_ROWS
    assert n % bn == 0, (n, bn)
    assert m % bm == 0, (m, bm)
    grid = (n // bn, m // bm)

    kernel = functools.partial(_nn_kernel, bm=bm)
    out_shape = (jax.ShapeDtypeStruct((n,), jnp.float32),
                 jax.ShapeDtypeStruct((n,), jnp.int32))
    in_specs = [
        pl.BlockSpec((AUG_ROWS, bn), lambda i, j: (0, i)),
        pl.BlockSpec((AUG_ROWS, bm), lambda i, j: (0, j)),
    ]
    out_specs = (
        pl.BlockSpec((bn,), lambda i, j: (i,)),
        pl.BlockSpec((bn,), lambda i, j: (i,)),
    )
    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        **pallas_call_kwargs(interpret, ("parallel", "arbitrary")),
    )
    return call(src_aug, dst_aug)


def vmem_bytes(bn: int, bm: int) -> dict:
    """Static VMEM budget of one grid step (the Table II analogue)."""
    src = AUG_ROWS * bn * 4
    dst = AUG_ROWS * bm * 4
    scores = bn * bm * 4
    outs = bn * (4 + 4)
    return {
        "src_tile": src, "dst_tile": dst, "scores": scores, "outputs": outs,
        "total_single": src + dst + scores + outs,
        # in/out tiles are double-buffered by the pipeline; scores is scratch.
        "total_double_buffered": 2 * (src + dst + outs) + scores,
    }
