"""Pure-jnp oracles for the FPPS NN-search kernel.

The kernel computes squared distances through an *augmented inner product*
(see nn_search.py for the derivation):

    score[i,j] = src_aug[:, i] · dst_aug[:, j] = ||R p_i + t - q_j||²

The oracle builds the same augmented matrices and takes the full (N, M)
product at once — no tiling, no running reduction — so any kernel bug in
tiling/carry/index bookkeeping diverges from it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

AUG_ROWS = 8  # fp32 sublane-aligned augmentation height


def augment_target(dst: jax.Array, pad_to: int | None = None) -> jax.Array:
    """(M,3) -> (8, M') constant target augmentation.

    rows 0..2 = -2 q, row 3 = ||q||², row 4 = 1, rows 5..7 = 0.
    Padded columns get row3 = +BIG so they can never win the argmin.
    """
    m = dst.shape[0]
    mp = m if pad_to is None else pad_to
    assert mp >= m
    q = dst.astype(jnp.float32)
    out = jnp.zeros((AUG_ROWS, mp), dtype=jnp.float32)
    out = out.at[0:3, :m].set(-2.0 * q.T)
    out = out.at[3, :m].set(jnp.sum(q * q, axis=-1))
    out = out.at[4, :].set(1.0)
    if mp > m:
        out = out.at[3, m:].set(jnp.float32(1e30))
    return out


def augment_source(src: jax.Array, T: jax.Array | None = None,
                   pad_to: int | None = None) -> jax.Array:
    """(N,3) [+ 4x4 T] -> (8, N') transformed source augmentation.

    p' = R p + t (the paper's point-cloud-transformer stage, folded in);
    rows 0..2 = p', row 3 = 1, row 4 = ||p'||², rows 5..7 = 0.
    """
    n = src.shape[0]
    np_ = n if pad_to is None else pad_to
    assert np_ >= n
    p = src.astype(jnp.float32)
    if T is not None:
        p = p @ T[:3, :3].T.astype(jnp.float32) + T[:3, 3].astype(jnp.float32)
    out = jnp.zeros((AUG_ROWS, np_), dtype=jnp.float32)
    out = out.at[0:3, :n].set(p.T)
    out = out.at[3, :n].set(1.0)
    out = out.at[4, :n].set(jnp.sum(p * p, axis=-1))
    return out


def nn_search_ref(src: jax.Array, dst: jax.Array,
                  T: jax.Array | None = None):
    """Oracle: exact NN via the full augmented score matrix.

    Returns (d2, idx): (N,) squared distance of (transformed) src point to
    its NN in dst, and the NN's index. Ties resolve to the lowest index
    (same as the kernel's strict-< block carry).
    """
    src_aug = augment_source(src, T)
    dst_aug = augment_target(dst)
    scores = jax.lax.dot_general(
        src_aug, dst_aug, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (N, M)
    idx = jnp.argmin(scores, axis=1).astype(jnp.int32)
    d2 = jnp.take_along_axis(scores, idx[:, None], axis=1)[:, 0]
    return jnp.maximum(d2, 0.0), idx


def nn_search_ref_blocked(src, dst, T, bn: int, bm: int):
    """Oracle with the kernel's exact blocking/carry semantics (padding, block
    argmin, strict-< cross-block update) but in pure jnp — isolates pure
    Pallas issues (BlockSpec, revisiting, program_id) from math issues."""
    n, m = src.shape[0], dst.shape[0]
    n_pad, m_pad = -n % bn, -m % bm
    src_aug = augment_source(src, T, pad_to=n + n_pad)
    dst_aug = augment_target(dst, pad_to=m + m_pad)
    best_d2 = jnp.full((n + n_pad,), jnp.inf, jnp.float32)
    best_idx = jnp.zeros((n + n_pad,), jnp.int32)
    for j in range((m + m_pad) // bm):
        dblk = dst_aug[:, j * bm:(j + 1) * bm]
        scores = jax.lax.dot_general(src_aug, dblk, (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
        larg = jnp.argmin(scores, axis=1).astype(jnp.int32)
        lmin = jnp.take_along_axis(scores, larg[:, None], 1)[:, 0]
        upd = lmin < best_d2
        best_d2 = jnp.where(upd, lmin, best_d2)
        best_idx = jnp.where(upd, j * bm + larg, best_idx)
    return jnp.maximum(best_d2[:n], 0.0), best_idx[:n]
