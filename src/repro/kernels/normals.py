"""Pallas TPU kernel: neighbourhood moment sweep for normal estimation.

Same streaming shape as the grid-NN candidate sweep
(``kernels/nn_search_grid.py``): the XLA side gathers each query's
(2·rings+1)³ candidate ring from the :class:`repro.data.voxelize.VoxelGrid`
tables, and the kernel does the dense part in VMEM — here a **radius-gated
moment accumulation** instead of a running min:

  * grid = (N/bn, CK/bc): query blocks "parallel", the candidate axis
    innermost/"arbitrary" carrying ten running sums per query — the count
    and the first/second moments of the *query-relative* offsets
    (Σw, Σw·d, Σw·d·dᵀ with d = x − p).
  * relative coordinates are formed in-kernel (candidate plane minus the
    query's broadcast column), so the accumulated second moments are
    ~radius² in magnitude — no scene-scale cancellation, and the fp32 sums
    stay exact to ~1e-6 relative even over hundreds of candidates.
  * masked candidate slots arrive pre-filled with far-sentinel coordinates
    (``core.nn_search_grid``), so the radius gate ``d² ≤ r²`` rejects them
    with no separate mask input — the finite-sentinel trick again.
  * per (bn, bc) tile the work is elementwise multiplies + a lane
    reduction (VPU); there is no shared operand for the MXU, exactly like
    the NN candidate sweep it mirrors.

The eigen-decomposition epilogue is shared with the XLA path
(:func:`repro.data.normals.moments_to_normals`), so the kernel's contract
ends at the ten moment planes and parity holds to fp32 tolerance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.nn_search_grid import _MASK_COORD, gather_candidates
from repro.data.normals import (NormalParams, moments_to_normals,
                                orient_normals)
from repro.data.voxelize import VoxelGrid, build_voxel_grid
from repro.kernels.common import pallas_call_kwargs, round_up as _round_up

# Output order of the moment planes: count, Σdx, Σdy, Σdz, then the six
# unique entries of the symmetric second-moment matrix.
_MOMENTS = ("cnt", "sx", "sy", "sz", "sxx", "syy", "szz", "sxy", "sxz", "syz")


def _moment_sweep_kernel(qx_ref, qy_ref, qz_ref, cx_ref, cy_ref, cz_ref,
                         *out_refs, r2: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        for ref in out_refs:
            ref[...] = jnp.zeros_like(ref)

    dx = cx_ref[...] - qx_ref[...][:, None]
    dy = cy_ref[...] - qy_ref[...][:, None]
    dz = cz_ref[...] - qz_ref[...][:, None]
    d2 = dx * dx + dy * dy + dz * dz
    w = (d2 <= r2).astype(jnp.float32)
    planes = (w, w * dx, w * dy, w * dz,
              w * dx * dx, w * dy * dy, w * dz * dz,
              w * dx * dy, w * dx * dz, w * dy * dz)
    for ref, plane in zip(out_refs, planes):
        ref[...] += jnp.sum(plane, axis=1)


def moment_sweep_kernel(q: jax.Array, cand: jax.Array, radius: float, *,
                        bn: int = 256, bc: int = 128,
                        interpret: bool | None = None):
    """Radius-gated moment sums over per-query candidate sets.

    Args:
      q: (N, 3) queries; N must be a multiple of bn.
      cand: (N, CK, 3) candidate coordinates (masked slots = far sentinel);
        CK must be a multiple of bc.
      radius: neighbourhood gate in metres (static).

    Returns:
      (cnt, s, ss): (N,) counts, (N, 3) first moments, (N, 3, 3) symmetric
      second moments — all of the query-relative offsets.
    """
    n, ck = cand.shape[0], cand.shape[1]
    assert n % bn == 0, (n, bn)
    assert ck % bc == 0, (ck, bc)
    grid = (n // bn, ck // bc)
    qx, qy, qz = (q[:, a].astype(jnp.float32) for a in range(3))
    cx, cy, cz = (cand[:, :, a].astype(jnp.float32) for a in range(3))
    kernel = functools.partial(_moment_sweep_kernel,
                               r2=float(radius) ** 2)
    out_shape = tuple(jax.ShapeDtypeStruct((n,), jnp.float32)
                      for _ in _MOMENTS)
    qspec = pl.BlockSpec((bn,), lambda i, j: (i,))
    cspec = pl.BlockSpec((bn, bc), lambda i, j: (i, j))
    out_specs = tuple(pl.BlockSpec((bn,), lambda i, j: (i,))
                      for _ in _MOMENTS)
    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[qspec, qspec, qspec, cspec, cspec, cspec],
        out_specs=out_specs,
        out_shape=out_shape,
        **pallas_call_kwargs(interpret, ("parallel", "arbitrary")),
    )
    cnt, sx, sy, sz, sxx, syy, szz, sxy, sxz, syz = call(qx, qy, qz,
                                                         cx, cy, cz)
    s = jnp.stack([sx, sy, sz], axis=-1)
    ss = jnp.stack([
        jnp.stack([sxx, sxy, sxz], axis=-1),
        jnp.stack([sxy, syy, syz], axis=-1),
        jnp.stack([sxz, syz, szz], axis=-1),
    ], axis=-2)
    return cnt, s, ss


def estimate_normals_pallas(points: jax.Array,
                            params: NormalParams = NormalParams(
                                neighborhood="radius"), *,
                            valid: jax.Array | None = None,
                            viewpoint: jax.Array | None = None,
                            grid: VoxelGrid | None = None,
                            bn: int = 256, bc: int = 128,
                            interpret: bool | None = None):
    """Radius-mode normal estimation with the moment sweep as a kernel.

    Same contract as ``repro.data.normals.estimate_normals`` with
    ``neighborhood="radius"`` (the k-NN top-k selection is data-dependent
    control flow the streaming kernel deliberately avoids); parity with the
    XLA radius path is pinned in ``tests/test_normals.py``.
    """
    if params.neighborhood != "radius":
        raise ValueError("the Pallas moment sweep is radius-mode only; "
                         f"got neighborhood={params.neighborhood!r}")
    pts = points.astype(jnp.float32)
    if grid is None:
        grid = build_voxel_grid(pts, params.voxel_size, params.grid_dims,
                                valid=valid)
    cand_pts, _, _ = gather_candidates(pts, grid, params.max_per_cell,
                                       params.rings)
    n, ck = cand_pts.shape[0], cand_pts.shape[1]
    n_pad, ck_pad = _round_up(n, bn), _round_up(ck, bc)
    if n_pad > n or ck_pad > ck:
        cand_pts = jnp.pad(cand_pts,
                           ((0, n_pad - n), (0, ck_pad - ck), (0, 0)),
                           constant_values=_MASK_COORD)
        pts_p = jnp.pad(pts, ((0, n_pad - n), (0, 0)))
    else:
        pts_p = pts
    cnt, s, ss = moment_sweep_kernel(pts_p, cand_pts, params.radius,
                                     bn=bn, bc=bc, interpret=interpret)
    cnt, s, ss = cnt[:n], s[:n], ss[:n]
    normals, nvalid = moments_to_normals(cnt, s, ss,
                                         min_neighbors=params.min_neighbors)
    normals = orient_normals(pts, normals, viewpoint)
    if valid is not None:
        nvalid = nvalid & valid
        normals = jnp.where(nvalid[..., None], normals, 0.0)
    return normals, nvalid
