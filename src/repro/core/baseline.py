"""The paper's CPU baseline: PCL-style k-d tree ICP, in numpy/scipy.

FPPS compares against a software-only PCL ICP on a Xeon (paper §IV-A). PCL's
``IterativeClosestPoint`` uses a k-d tree (FLANN) for correspondence
estimation and SVD for transform estimation. We reimplement that faithfully:
scipy.spatial.cKDTree (same complexity class and the de-facto CPU reference)
+ numpy Kabsch, with identical convergence semantics to ``core.icp``.

This gives the benchmark harness a genuine like-for-like baseline for the
Table III (accuracy parity) and Table IV (latency/speedup) reproductions.
"""
from __future__ import annotations

import dataclasses

import numpy as np
from scipy.spatial import cKDTree


@dataclasses.dataclass
class BaselineResult:
    T: np.ndarray
    rmse: float
    iterations: int
    converged: bool
    inlier_frac: float


def _kabsch(src: np.ndarray, dst: np.ndarray, w: np.ndarray) -> np.ndarray:
    wsum = max(w.sum(), 1e-12)
    src_mean = (src * w[:, None]).sum(0) / wsum
    dst_mean = (dst * w[:, None]).sum(0) / wsum
    src_c = src - src_mean
    dst_c = dst - dst_mean
    H = (src_c * w[:, None]).T @ dst_c
    U, _, Vt = np.linalg.svd(H)
    D = np.eye(3)
    D[2, 2] = np.linalg.det(Vt.T @ U.T)
    R = Vt.T @ D @ U.T
    t = dst_mean - R @ src_mean
    T = np.eye(4)
    T[:3, :3] = R
    T[:3, 3] = t
    return T


def kdtree_icp(source: np.ndarray, target: np.ndarray,
               max_iterations: int = 50,
               max_correspondence_distance: float = 1.0,
               transformation_epsilon: float = 1e-5,
               initial_transform: np.ndarray | None = None) -> BaselineResult:
    """PCL-equivalent ICP: k-d tree NN + SVD, same stopping rules as core.icp."""
    source = np.asarray(source, dtype=np.float64)
    target = np.asarray(target, dtype=np.float64)
    tree = cKDTree(target)  # built once: the target frame is static
    T = np.eye(4) if initial_transform is None else np.asarray(
        initial_transform, dtype=np.float64)
    rmse = float("inf")
    inlier_frac = 0.0
    it = 0
    converged = False
    for it in range(1, max_iterations + 1):
        src_t = source @ T[:3, :3].T + T[:3, 3]
        dist, idx = tree.query(src_t, k=1)
        matched = target[idx]
        w = (dist <= max_correspondence_distance).astype(np.float64)
        T_delta = _kabsch(src_t, matched, w)
        T = T_delta @ T
        delta = (np.sum((T_delta[:3, :3] - np.eye(3)) ** 2)
                 + np.sum(T_delta[:3, 3] ** 2))
        src_new = src_t @ T_delta[:3, :3].T + T_delta[:3, 3]
        d2 = np.sum((src_new - matched) ** 2, axis=1)
        rmse = float(np.sqrt((d2 * w).sum() / max(w.sum(), 1e-12)))
        inlier_frac = float(w.mean())
        if delta <= transformation_epsilon:
            converged = True
            break
    return BaselineResult(T=T, rmse=rmse, iterations=it,
                          converged=converged, inlier_frac=inlier_frac)
