"""Custom-call-free 3x3 SVD via one-sided Jacobi rotations.

Why this exists: ``jnp.linalg.svd`` lowers to a LAPACK/cuSolver custom call on
CPU/GPU and a large QR-iteration HLO on TPU — both have data-dependent or
platform-dependent behaviour. FPPS dedicates a small fixed-latency SVD unit on
the FPGA; the TPU-native analogue is a *fixed iteration count* one-sided
Jacobi sweep: pure element-wise math + 3x3 matmuls, identical HLO on every
backend, deterministic latency, trivially vmappable over batches of
covariances (one per frame-pair in fleet-scale registration).

One-sided Jacobi: orthogonalise the columns of A by right-multiplying Givens
rotations; then ``A V = U Σ``. For 3x3, 8 sweeps x 3 pivots reaches fp32
machine precision (tested against jnp.linalg.svd in tests/test_svd3x3.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_PIVOTS = ((0, 1), (0, 2), (1, 2))


def _jacobi_rotation(a_pp, a_qq, a_pq, eps):
    """Givens (c, s) zeroing the (p,q) off-diagonal of the implicit Gram matrix."""
    # Classic stable formulation (Golub & Van Loan §8.4).
    tau = (a_qq - a_pp) / (2.0 * jnp.where(jnp.abs(a_pq) < eps, eps, a_pq))
    # sign(0) must be +1 here: a_pp == a_qq with a_pq != 0 needs a 45° rotation.
    sgn = jnp.where(tau >= 0.0, 1.0, -1.0)
    t = sgn / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
    t = jnp.where(jnp.abs(a_pq) < eps, 0.0, t)
    c = 1.0 / jnp.sqrt(1.0 + t * t)
    s = c * t
    return c, s


def _apply_right_rotation(A, V, p, q, c, s):
    """A <- A G, V <- V G where G rotates columns p,q."""
    Ap, Aq = A[:, p], A[:, q]
    A = A.at[:, p].set(c * Ap - s * Aq)
    A = A.at[:, q].set(s * Ap + c * Aq)
    Vp, Vq = V[:, p], V[:, q]
    V = V.at[:, p].set(c * Vp - s * Vq)
    V = V.at[:, q].set(s * Vp + c * Vq)
    return A, V


def svd3x3(M: jax.Array, sweeps: int = 8):
    """SVD of a 3x3 matrix: returns (U, S, Vt) with M = U @ diag(S) @ Vt.

    Singular values are returned sorted descending, matching
    ``jnp.linalg.svd``. U, Vt are orthogonal; no sign convention beyond
    S >= 0 is imposed (same contract as LAPACK).
    """
    dtype = M.dtype
    work = M.astype(jnp.float32)
    eps = jnp.asarray(1e-30, jnp.float32)
    V = jnp.eye(3, dtype=jnp.float32)

    def sweep(carry, _):
        A, V = carry
        for (p, q) in _PIVOTS:
            col_p, col_q = A[:, p], A[:, q]
            a_pp = col_p @ col_p
            a_qq = col_q @ col_q
            a_pq = col_p @ col_q
            c, s = _jacobi_rotation(a_pp, a_qq, a_pq, eps)
            A, V = _apply_right_rotation(A, V, p, q, c, s)
        return (A, V), None

    (work, V), _ = jax.lax.scan(sweep, (work, V), None, length=sweeps)

    # Column norms are the singular values; normalised columns are U.
    s = jnp.sqrt(jnp.sum(work * work, axis=0))
    # Sort descending.
    order = jnp.argsort(-s)
    s = s[order]
    work = work[:, order]
    V = V[:, order]
    # Guard rank-deficient columns (zero singular value -> arbitrary orthonormal
    # dir). Keep Jacobi's columns wherever they are valid — forcing det(U)=+1
    # would corrupt reconstruction for reflections — and only synthesise
    # replacements for (near-)zero singular values, sign-matched to the
    # original column so U @ diag(S) @ Vt is unchanged.
    safe = jnp.maximum(s, 1e-30)
    U = work / safe[None, :]
    tol = 1e-12 * jnp.maximum(s[0], 1e-30)
    u0 = jnp.where(s[0] > tol, U[:, 0], jnp.array([1.0, 0.0, 0.0], jnp.float32))
    u1_raw = U[:, 1] - (U[:, 1] @ u0) * u0
    u1_norm = jnp.linalg.norm(u1_raw)
    u1 = jnp.where(jnp.logical_and(s[1] > tol, u1_norm > 1e-20),
                   u1_raw / jnp.maximum(u1_norm, 1e-30), _any_orthogonal(u0))
    u2_cross = jnp.cross(u0, u1)
    sign = jnp.where(u2_cross @ U[:, 2] < 0.0, -1.0, 1.0)
    u2 = jnp.where(s[2] > tol, sign * u2_cross, u2_cross)
    U = jnp.stack([u0, u1, u2], axis=1)
    return U.astype(dtype), s.astype(dtype), V.T.astype(dtype)


def _any_orthogonal(u: jax.Array) -> jax.Array:
    """A unit vector orthogonal to u (u assumed unit, possibly axis-aligned)."""
    # Pick the axis least aligned with u, Gram-Schmidt it.
    axis = jnp.eye(3, dtype=u.dtype)[jnp.argmin(jnp.abs(u))]
    v = axis - (axis @ u) * u
    return v / jnp.maximum(jnp.linalg.norm(v), 1e-30)


svd3x3_batched = jax.vmap(svd3x3, in_axes=(0,))
