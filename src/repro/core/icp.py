"""Jittable ICP — the full FPPS pipeline as one fused XLA computation.

Mirrors the paper's four stages per iteration (§II):
  1. correspondence estimation  -> nn_search (brute force, exact)
  2. transformation estimation  -> masked Kabsch (covariance accumulator + SVD)
  3. point-cloud update         -> transform_points (kept implicit: we always
                                   transform the *original* source by the
                                   cumulative T, avoiding drift from repeated
                                   rounding of the cloud itself)
  4. convergence check          -> transform_delta(T_j) < epsilon, or
                                   iteration cap (paper: 50)

The whole loop is a ``lax.while_loop`` so a frame registration is a single
device program — the TPU analogue of the paper's "all data stays on-chip".

Correspondence rejection: the paper's setMaxCorrespondenceDistance filter is
a weight mask fed to the weighted Kabsch step (zero-weight pairs contribute
nothing to the covariance), exactly like PCL's behaviour of dropping
out-of-range pairs.

Minimiser choice (DESIGN.md §9): ``minimizer="point_to_plane"`` swaps the
Kabsch step for the linearised point-to-plane Gauss-Newton step
(``core.point_to_plane``), which needs per-correspondence target normals —
either supplied by the caller (``target_normals``) or estimated once at
trace scope from the target cloud (``repro.data.normals``). Robust
reweighting (``robust_kernel``) applies to either minimiser, on top of the
distance gate.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import transform as tf
from repro.core.nn_search import nn_search
from repro.core.point_to_plane import (robust_weights, solve_normal_equations,
                                       solve_point_to_plane)
from repro.data.collate import PAD_SENTINEL

MINIMIZERS = ("point_to_point", "point_to_plane")


def scrub_nonfinite(points: jax.Array | None,
                    valid: jax.Array | None = None):
    """Sentinel-mask non-finite rows at the engine boundary (DESIGN.md §12).

    A single NaN/Inf row would otherwise poison every downstream
    accumulation it touches: the matmul distance expansion (NaN spreads
    along its whole row), voxel-grid origin/cell assignment
    (``floor(NaN)``), and the fused moment sums. One elementwise pass
    replaces such rows with the far ``PAD_SENTINEL`` (the exact convention
    collate pads already use — never wins an argmin, always fails the
    gate) and drops them from ``valid`` so minimiser weights and inlier
    denominators exclude them.

    Works on (..., N, 3) clouds with (..., N) masks; ``points=None``
    passes through (engines with correspond/fused closures may have no
    target cloud). For all-finite inputs the rewrite is the identity, so
    clean-path results are bit-identical.
    """
    if points is None:
        return None, valid
    finite = jnp.all(jnp.isfinite(points), axis=-1)
    valid = finite if valid is None else jnp.logical_and(valid, finite)
    points = jnp.where(valid[..., None], points,
                       jnp.asarray(PAD_SENTINEL, points.dtype))
    return points, valid


class ICPParams(NamedTuple):
    max_iterations: int = 50
    max_correspondence_distance: float = 1.0
    transformation_epsilon: float = 1e-5
    chunk: int = 2048  # target-cloud tile size for the NN sweep
    score_dtype: str = "fp32"  # "bf16": half-width distance tiles (§Perf A2)
    minimizer: str = "point_to_point"  # | "point_to_plane" (DESIGN.md §9)
    robust_kernel: str = "none"        # | "huber" | "tukey"
    robust_scale: float = 0.5          # huber delta / tukey cutoff, metres
    fused: bool = False  # single-pass Pallas iteration (DESIGN.md §11)


class ICPState(NamedTuple):
    T: jax.Array           # (4,4) cumulative transform
    delta: jax.Array       # last incremental transform_delta
    rmse: jax.Array        # inlier RMSE of the last iteration
    iteration: jax.Array   # int32
    inlier_frac: jax.Array
    degenerate: jax.Array  # bool: an iteration saw zero gate/robust weight


class ICPResult(NamedTuple):
    T: jax.Array
    rmse: jax.Array
    iterations: jax.Array
    converged: jax.Array
    inlier_frac: jax.Array
    degenerate: jax.Array


# Total weight below this is "no correspondence evidence at all": the
# minimiser systems are singular (Kabsch covariance / Gauss-Newton normal
# matrix of all-zero weights), so the iteration freezes instead of solving.
_DEGENERATE_WEIGHT_SUM = 1e-6


def _icp_iteration(source, state: ICPState, params: ICPParams,
                   correspond_fn: Callable,
                   src_valid: jax.Array | None = None):
    """One ICP iteration. ``correspond_fn(src_t) -> (d2, matched)`` — or
    ``(d2, matched, normals)`` for the point-to-plane minimiser — supplies
    correspondences; for the distributed engine ``matched`` (and the winner
    normals) are gathered *values* (cross-shard index gathers never happen).

    ``src_valid`` (N,) masks padded source rows (shape-bucketed batching):
    they get zero minimiser weight and are excluded from the inlier
    fraction's denominator, so a padded registration is numerically
    identical to the unpadded one.
    """
    src_t = tf.transform_points(state.T, source)
    out = correspond_fn(src_t)
    normals = out[2] if len(out) == 3 else None
    d2, matched = out[0], out[1]
    weights = (d2 <= params.max_correspondence_distance ** 2).astype(source.dtype)
    if src_valid is not None:
        weights = weights * src_valid.astype(source.dtype)
    plane = params.minimizer == "point_to_plane"
    if plane and normals is None:
        raise ValueError("minimizer='point_to_plane' needs matched normals: "
                         "pass target_normals (or a correspond_fn returning "
                         "a (d2, matched, normals) triple)")
    if params.robust_kernel != "none":
        # IRLS weight from the residual the active minimiser optimises.
        if plane:
            residual = jnp.abs(jnp.sum(normals * (src_t - matched), axis=-1))
        else:
            residual = jnp.sqrt(jnp.maximum(d2, 0.0))
        weights = weights * robust_weights(residual, params.robust_kernel,
                                           params.robust_scale)
    # Zero-inlier freeze: when the gate (or robust reweighting) rejects
    # every correspondence the minimiser systems are singular — the Kabsch
    # covariance and the Gauss-Newton normal matrix are all zeros, so a
    # solve would produce an arbitrary (or NaN) step and the cumulative
    # product would lock it in. Freeze instead: identity delta (the loop
    # terminates), rmse = +inf (there is no inlier error to report), and a
    # sticky ``degenerate`` flag so callers can tell this apart from
    # genuine convergence.
    degenerate = jnp.sum(weights) <= _DEGENERATE_WEIGHT_SUM
    if plane:
        T_step = solve_point_to_plane(src_t, matched, normals, weights)
    else:
        T_step = tf.estimate_rigid_transform(src_t, matched, weights)
    T_delta = jnp.where(degenerate, jnp.eye(4, dtype=source.dtype), T_step)
    T_new = T_delta @ state.T  # cumulative product, paper eq. (3)
    delta = tf.transform_delta(T_delta)
    err = jnp.where(degenerate, jnp.asarray(jnp.inf, source.dtype),
                    tf.rmse(tf.transform_points(T_delta, src_t), matched,
                            weights))
    if src_valid is None:
        inlier_frac = jnp.mean(weights)
    else:
        denom = jnp.maximum(jnp.sum(src_valid.astype(source.dtype)), 1.0)
        inlier_frac = jnp.sum(weights) / denom
    return ICPState(T=T_new, delta=delta, rmse=err,
                    iteration=state.iteration + 1, inlier_frac=inlier_frac,
                    degenerate=jnp.logical_or(state.degenerate, degenerate))


def _fused_icp_iteration(source, state: ICPState, params: ICPParams,
                         fused_fn: Callable,
                         src_valid: jax.Array | None = None):
    """One ICP iteration through the fused Pallas moment kernel
    (``repro.kernels.fused_icp``, DESIGN.md §11).

    ``fused_fn(src_t, src_valid)`` runs correspondence search, gating,
    IRLS weighting and moment accumulation as a single device pass and
    returns the Σ-moments (:class:`PointMoments` / :class:`PlaneMoments`);
    this host epilogue only does the O(1) solve and bookkeeping. The
    semantics mirror :func:`_icp_iteration` exactly: same weights, same
    degenerate freeze, same post-step rmse against the *pre-step*
    correspondences (computed algebraically via ``rmse_from_moments``).
    """
    src_t = tf.transform_points(state.T, source)
    m = fused_fn(src_t, src_valid)
    degenerate = m.sw <= _DEGENERATE_WEIGHT_SUM
    if params.minimizer == "point_to_plane":
        T_step = solve_normal_equations(m.A, m.b).astype(source.dtype)
    else:
        T_step = tf.estimate_from_moments(m.sw, m.sp, m.sq,
                                          m.spq).astype(source.dtype)
    T_delta = jnp.where(degenerate, jnp.eye(4, dtype=source.dtype), T_step)
    T_new = T_delta @ state.T
    delta = tf.transform_delta(T_delta)
    err = jnp.where(degenerate, jnp.asarray(jnp.inf, source.dtype),
                    tf.rmse_from_moments(T_delta, m.sw, m.sp, m.sq, m.spq,
                                         m.spp, m.sqq).astype(source.dtype))
    if src_valid is None:
        denom = jnp.asarray(source.shape[0], source.dtype)
    else:
        denom = jnp.maximum(jnp.sum(src_valid.astype(source.dtype)), 1.0)
    inlier_frac = (m.sw / denom).astype(source.dtype)
    return ICPState(T=T_new, delta=delta, rmse=err,
                    iteration=state.iteration + 1, inlier_frac=inlier_frac,
                    degenerate=jnp.logical_or(state.degenerate, degenerate))


def _resolve_fused_fn(target, params: ICPParams, fused_fn,
                      dst_valid, target_normals):
    """Default fused iteration when ``params.fused`` is set without an
    explicit ``fused_fn``: resident counting-sort grid over the target
    (+ trace-scope normals for the plane minimiser)."""
    if fused_fn is not None:
        return fused_fn
    if target is None:
        raise ValueError("params.fused needs a target cloud (or an explicit "
                         "fused_fn) to build the resident grid from")
    if params.minimizer == "point_to_plane" and target_normals is None:
        target_normals = _auto_target_normals(target, dst_valid)
    from repro.kernels.fused_icp import default_fused_fn
    return default_fused_fn(target, params, dst_valid=dst_valid,
                            target_normals=target_normals)


def _default_correspond_fn(target: jax.Array, params: ICPParams,
                           nn_fn: Callable | None,
                           dst_valid: jax.Array | None = None,
                           target_normals: jax.Array | None = None) -> Callable:
    if nn_fn is None:
        # Fused winner gather: the exact-d2 epilogue inside nn_search
        # already gathers dst[idx], so ask for the points and skip the
        # second jnp.take over the target that the generic path needs.
        def nn_fn(s, t):
            return nn_search(s, t, chunk=params.chunk,
                             score_dtype=params.score_dtype,
                             dst_valid=dst_valid, return_points=True)
    elif dst_valid is not None:
        # Custom searchers (Pallas kernel, user callables) take only
        # (src, dst): mask padded target rows by moving them far outside any
        # metric scene, so they can never win the argmin nor pass the gate.
        target = jnp.where(dst_valid[:, None], target,
                           jnp.asarray(1e6, target.dtype))

    def correspond(src_t):
        # Searchers may return (d2, idx) or the fused (d2, idx, points).
        out = nn_fn(src_t, target)
        if len(out) == 3:
            d2, idx, matched = out
        else:
            d2, idx = out
            matched = jnp.take(target, idx, axis=0)
        if target_normals is None:
            return d2, matched
        # Winner normals ride the same index gather (invalid-normal rows
        # are zero vectors, which the plane solve ignores by construction).
        return d2, matched, jnp.take(target_normals, idx, axis=0)

    return correspond


def _check_minimizer(params: ICPParams) -> None:
    if params.minimizer not in MINIMIZERS:
        raise ValueError(f"unknown minimizer {params.minimizer!r}; "
                         f"expected one of {MINIMIZERS}")


def _auto_target_normals(target: jax.Array | None,
                         dst_valid: jax.Array | None):
    """Estimate target normals at trace scope (once per frame) when the
    plane minimiser is selected but the caller supplied none."""
    if target is None:
        raise ValueError("minimizer='point_to_plane' needs a target cloud "
                         "(or explicit target_normals) to estimate normals "
                         "from")
    from repro.data.normals import default_target_normals
    return default_target_normals(target, dst_valid)


def icp(source: jax.Array, target: jax.Array | None,
        params: ICPParams = ICPParams(),
        initial_transform: jax.Array | None = None,
        nn_fn: Callable | None = None,
        correspond_fn: Callable | None = None,
        src_valid: jax.Array | None = None,
        dst_valid: jax.Array | None = None,
        target_normals: jax.Array | None = None,
        fused_fn: Callable | None = None) -> ICPResult:
    """Run ICP aligning ``source`` (N,3) onto ``target`` (M,3).

    ``nn_fn`` lets callers swap the correspondence engine: the local XLA
    brute force (default), the Pallas kernel wrapper, or the shard_map
    distributed searcher. It must return (d2, idx) for (src, target).
    ``correspond_fn`` overrides the whole correspondence stage (src_t ->
    (d2, matched points[, matched normals])); target may then be None.
    ``src_valid`` (N,) / ``dst_valid`` (M,) mask padded rows of
    shape-bucketed clouds (see ``repro.data.collate``).
    ``target_normals`` (M,3) feeds the point-to-plane minimiser; when the
    plane minimiser is selected without them they are estimated from the
    target once at trace scope (``repro.data.normals`` defaults).

    With ``params.fused`` the whole iteration body runs through the fused
    Pallas moment kernel instead (``repro.kernels.fused_icp``):
    ``fused_fn(src_t, src_valid) -> moments`` replaces the correspondence
    stage entirely (``nn_fn``/``correspond_fn`` are then unused); when no
    ``fused_fn`` is supplied a resident-grid default is built from
    ``target`` at trace scope.

    Non-finite rows in either cloud are sentinel-masked at this boundary
    (:func:`scrub_nonfinite`) — a NaN point changes the inlier
    denominator, never the transform.
    """
    _check_minimizer(params)
    source, src_valid = scrub_nonfinite(source, src_valid)
    target, dst_valid = scrub_nonfinite(target, dst_valid)
    if params.fused:
        fused_fn = _resolve_fused_fn(target, params, fused_fn, dst_valid,
                                     target_normals)
    elif correspond_fn is None:
        if params.minimizer == "point_to_plane" and target_normals is None:
            target_normals = _auto_target_normals(target, dst_valid)
        correspond_fn = _default_correspond_fn(target, params, nn_fn,
                                               dst_valid, target_normals)
    if initial_transform is None:
        initial_transform = jnp.eye(4, dtype=source.dtype)

    init = ICPState(T=initial_transform,
                    delta=jnp.asarray(jnp.inf, source.dtype),
                    rmse=jnp.asarray(jnp.inf, source.dtype),
                    iteration=jnp.asarray(0, jnp.int32),
                    inlier_frac=jnp.asarray(0.0, source.dtype),
                    degenerate=jnp.asarray(False))

    def cond(state: ICPState):
        return jnp.logical_and(state.iteration < params.max_iterations,
                               state.delta > params.transformation_epsilon)

    def body(state: ICPState):
        if params.fused:
            return _fused_icp_iteration(source, state, params, fused_fn,
                                        src_valid)
        return _icp_iteration(source, state, params, correspond_fn, src_valid)

    final = jax.lax.while_loop(cond, body, init)
    converged = jnp.logical_and(final.delta <= params.transformation_epsilon,
                                jnp.logical_not(final.degenerate))
    return ICPResult(T=final.T, rmse=final.rmse, iterations=final.iteration,
                     converged=converged, inlier_frac=final.inlier_frac,
                     degenerate=final.degenerate)


def icp_fixed_iterations(source, target, params: ICPParams = ICPParams(),
                         initial_transform=None, nn_fn=None,
                         correspond_fn=None, src_valid=None,
                         dst_valid=None, target_normals=None,
                         fused_fn=None) -> ICPResult:
    """Unrolled-depth variant via lax.scan — fixed cost, used for the dry-run
    and roofline (while_loop trip counts are data-dependent; scan gives the
    compiler a static schedule, mirroring the paper's fixed 50-iteration cap)."""
    _check_minimizer(params)
    source, src_valid = scrub_nonfinite(source, src_valid)
    target, dst_valid = scrub_nonfinite(target, dst_valid)
    if params.fused:
        fused_fn = _resolve_fused_fn(target, params, fused_fn, dst_valid,
                                     target_normals)
    elif correspond_fn is None:
        if params.minimizer == "point_to_plane" and target_normals is None:
            target_normals = _auto_target_normals(target, dst_valid)
        correspond_fn = _default_correspond_fn(target, params, nn_fn,
                                               dst_valid, target_normals)
    if initial_transform is None:
        initial_transform = jnp.eye(4, dtype=source.dtype)
    init = ICPState(T=initial_transform,
                    delta=jnp.asarray(jnp.inf, source.dtype),
                    rmse=jnp.asarray(jnp.inf, source.dtype),
                    iteration=jnp.asarray(0, jnp.int32),
                    inlier_frac=jnp.asarray(0.0, source.dtype),
                    degenerate=jnp.asarray(False))

    def step(state, _):
        # Freeze once converged (weights of the no-op: keep state).
        active = state.delta > params.transformation_epsilon
        if params.fused:
            new = _fused_icp_iteration(source, state, params, fused_fn,
                                       src_valid)
        else:
            new = _icp_iteration(source, state, params, correspond_fn,
                                 src_valid)
        state = jax.tree_util.tree_map(
            lambda a, b: jnp.where(active, b, a), state, new)
        return state, None

    final, _ = jax.lax.scan(step, init, None, length=params.max_iterations)
    converged = jnp.logical_and(final.delta <= params.transformation_epsilon,
                                jnp.logical_not(final.degenerate))
    return ICPResult(T=final.T, rmse=final.rmse, iterations=final.iteration,
                     converged=converged, inlier_frac=final.inlier_frac,
                     degenerate=final.degenerate)


def icp_batch(sources: jax.Array, targets: jax.Array,
              params: ICPParams = ICPParams(),
              initial_transforms: jax.Array | None = None,
              nn_fn: Callable | None = None,
              src_valid: jax.Array | None = None,
              dst_valid: jax.Array | None = None,
              target_normals: jax.Array | None = None) -> ICPResult:
    """Batched multi-frame ICP: vmap of the scan-based fixed-iteration loop.

    Registers ``sources[k]`` (B,N,3) onto ``targets[k]`` (B,M,3) in one
    device program — the "target stays resident, iterations stream" shape of
    the paper (§II) lifted to a whole frame sequence, so one compiled
    executable amortises dispatch and keeps the MXU fed between frames.

    Uses ``icp_fixed_iterations`` because under vmap a while_loop would run
    every lane for the worst lane's trip count anyway; the per-pair freeze
    mask inside the scan body preserves each pair's early-convergence
    semantics, so results match per-pair ``icp`` to float tolerance.

    ``src_valid`` (B,N) / ``dst_valid`` (B,M) mask bucket padding from
    ``repro.data.collate.collate_pairs``; ``initial_transforms`` is an
    optional (B,4,4) warm start; ``target_normals`` is an optional (B,M,3)
    normal batch (auto-estimated per frame at trace scope when the plane
    minimiser is on). Returns an ``ICPResult`` whose every leaf has a
    leading batch axis.
    """
    b = sources.shape[0]
    if initial_transforms is None:
        initial_transforms = jnp.broadcast_to(
            jnp.eye(4, dtype=sources.dtype), (b, 4, 4))

    def one(src, dst, T0, sv, dv, tn):
        return icp_fixed_iterations(src, dst, params, T0, nn_fn=nn_fn,
                                    src_valid=sv, dst_valid=dv,
                                    target_normals=tn)

    return jax.vmap(one)(sources, targets, initial_transforms,
                         src_valid, dst_valid, target_normals)
