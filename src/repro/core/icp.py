"""Jittable ICP — the full FPPS pipeline as one fused XLA computation.

Mirrors the paper's four stages per iteration (§II):
  1. correspondence estimation  -> nn_search (brute force, exact)
  2. transformation estimation  -> masked Kabsch (covariance accumulator + SVD)
  3. point-cloud update         -> transform_points (kept implicit: we always
                                   transform the *original* source by the
                                   cumulative T, avoiding drift from repeated
                                   rounding of the cloud itself)
  4. convergence check          -> transform_delta(T_j) < epsilon, or
                                   iteration cap (paper: 50)

The whole loop is a ``lax.while_loop`` so a frame registration is a single
device program — the TPU analogue of the paper's "all data stays on-chip".

Correspondence rejection: the paper's setMaxCorrespondenceDistance filter is
a weight mask fed to the weighted Kabsch step (zero-weight pairs contribute
nothing to the covariance), exactly like PCL's behaviour of dropping
out-of-range pairs.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import transform as tf
from repro.core.nn_search import nn_search


class ICPParams(NamedTuple):
    max_iterations: int = 50
    max_correspondence_distance: float = 1.0
    transformation_epsilon: float = 1e-5
    chunk: int = 2048  # target-cloud tile size for the NN sweep
    score_dtype: str = "fp32"  # "bf16": half-width distance tiles (§Perf A2)


class ICPState(NamedTuple):
    T: jax.Array           # (4,4) cumulative transform
    delta: jax.Array       # last incremental transform_delta
    rmse: jax.Array        # inlier RMSE of the last iteration
    iteration: jax.Array   # int32
    inlier_frac: jax.Array


class ICPResult(NamedTuple):
    T: jax.Array
    rmse: jax.Array
    iterations: jax.Array
    converged: jax.Array
    inlier_frac: jax.Array


def _icp_iteration(source, state: ICPState, params: ICPParams,
                   correspond_fn: Callable):
    """One ICP iteration. ``correspond_fn(src_t) -> (d2, matched)`` supplies
    correspondences; for the distributed engine ``matched`` are the gathered
    winner *points* (cross-shard index gathers never happen)."""
    src_t = tf.transform_points(state.T, source)
    d2, matched = correspond_fn(src_t)
    weights = (d2 <= params.max_correspondence_distance ** 2).astype(source.dtype)
    T_delta = tf.estimate_rigid_transform(src_t, matched, weights)
    T_new = T_delta @ state.T  # cumulative product, paper eq. (3)
    delta = tf.transform_delta(T_delta)
    err = tf.rmse(tf.transform_points(T_delta, src_t), matched, weights)
    inlier_frac = jnp.mean(weights)
    return ICPState(T=T_new, delta=delta, rmse=err,
                    iteration=state.iteration + 1, inlier_frac=inlier_frac)


def _default_correspond_fn(target: jax.Array, params: ICPParams,
                           nn_fn: Callable | None) -> Callable:
    if nn_fn is None:
        def nn_fn(s, t):
            return nn_search(s, t, chunk=params.chunk,
                             score_dtype=params.score_dtype)

    def correspond(src_t):
        d2, idx = nn_fn(src_t, target)
        return d2, jnp.take(target, idx, axis=0)

    return correspond


def icp(source: jax.Array, target: jax.Array | None,
        params: ICPParams = ICPParams(),
        initial_transform: jax.Array | None = None,
        nn_fn: Callable | None = None,
        correspond_fn: Callable | None = None) -> ICPResult:
    """Run ICP aligning ``source`` (N,3) onto ``target`` (M,3).

    ``nn_fn`` lets callers swap the correspondence engine: the local XLA
    brute force (default), the Pallas kernel wrapper, or the shard_map
    distributed searcher. It must return (d2, idx) for (src, target).
    ``correspond_fn`` overrides the whole correspondence stage (src_t ->
    (d2, matched points)); target may then be None.
    """
    if correspond_fn is None:
        correspond_fn = _default_correspond_fn(target, params, nn_fn)
    if initial_transform is None:
        initial_transform = jnp.eye(4, dtype=source.dtype)

    init = ICPState(T=initial_transform,
                    delta=jnp.asarray(jnp.inf, source.dtype),
                    rmse=jnp.asarray(jnp.inf, source.dtype),
                    iteration=jnp.asarray(0, jnp.int32),
                    inlier_frac=jnp.asarray(0.0, source.dtype))

    def cond(state: ICPState):
        return jnp.logical_and(state.iteration < params.max_iterations,
                               state.delta > params.transformation_epsilon)

    def body(state: ICPState):
        return _icp_iteration(source, state, params, correspond_fn)

    final = jax.lax.while_loop(cond, body, init)
    converged = final.delta <= params.transformation_epsilon
    return ICPResult(T=final.T, rmse=final.rmse, iterations=final.iteration,
                     converged=converged, inlier_frac=final.inlier_frac)


def icp_fixed_iterations(source, target, params: ICPParams = ICPParams(),
                         initial_transform=None, nn_fn=None,
                         correspond_fn=None) -> ICPResult:
    """Unrolled-depth variant via lax.scan — fixed cost, used for the dry-run
    and roofline (while_loop trip counts are data-dependent; scan gives the
    compiler a static schedule, mirroring the paper's fixed 50-iteration cap)."""
    if correspond_fn is None:
        correspond_fn = _default_correspond_fn(target, params, nn_fn)
    if initial_transform is None:
        initial_transform = jnp.eye(4, dtype=source.dtype)
    init = ICPState(T=initial_transform,
                    delta=jnp.asarray(jnp.inf, source.dtype),
                    rmse=jnp.asarray(jnp.inf, source.dtype),
                    iteration=jnp.asarray(0, jnp.int32),
                    inlier_frac=jnp.asarray(0.0, source.dtype))

    def step(state, _):
        # Freeze once converged (weights of the no-op: keep state).
        active = state.delta > params.transformation_epsilon
        new = _icp_iteration(source, state, params, correspond_fn)
        state = jax.tree_util.tree_map(
            lambda a, b: jnp.where(active, b, a), state, new)
        return state, None

    final, _ = jax.lax.scan(step, init, None, length=params.max_iterations)
    converged = final.delta <= params.transformation_epsilon
    return ICPResult(T=final.T, rmse=final.rmse, iterations=final.iteration,
                     converged=converged, inlier_frac=final.inlier_frac)
