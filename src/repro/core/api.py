"""PCL-like API — faithful to FPPS Table I.

The paper ships PCL-style setters so software developers can swap the
accelerator into existing pipelines. We reproduce that surface exactly
(camelCase and all), backed by the jittable ICP. ``hardwareInitialize``
stands in for the .xclbin load: it builds the device mesh / compiles the
registration executable for the configured engine.

    icp = FppsICP()
    icp.hardwareInitialize()
    icp.setInputSource(src)      # (N,3) array-like
    icp.setInputTarget(dst)      # (M,3) array-like
    icp.setMaxCorrespondenceDistance(1.0)
    icp.setMaxIterationCount(50)
    icp.setTransformationEpsilon(1e-5)
    T = icp.align()
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.icp import ICPParams, ICPResult, icp


class FppsICP:
    """Drop-in ICP object mirroring the FPPS / PCL interface (paper Table I)."""

    def __init__(self, engine: str = "xla", chunk: int = 2048):
        """engine: 'xla' (default), 'pallas' (TPU kernel; interpret on CPU),
        or a callable nn_fn(src, dst) -> (d2, idx)."""
        self._engine = engine
        self._chunk = chunk
        self._source: jax.Array | None = None
        self._target: jax.Array | None = None
        self._initial_T: jax.Array | None = None
        self._max_corr = 1.0
        self._max_iter = 50
        self._eps = 1e-5
        self._initialized = False
        self._last_result: ICPResult | None = None

    # -- Table I surface ---------------------------------------------------
    def hardwareInitialize(self) -> None:
        """Initialise the backend (paper: load .xclbin). Here: verify devices
        and pre-build the jitted alignment executable cache."""
        _ = jax.devices()
        self._initialized = True

    def setTransformationMatrix(self, transformationMatrix) -> None:
        self._initial_T = jnp.asarray(transformationMatrix, dtype=jnp.float32)

    def setInputSource(self, inputSource) -> None:
        self._source = jnp.asarray(inputSource, dtype=jnp.float32)

    def setInputTarget(self, inputTarget) -> None:
        self._target = jnp.asarray(inputTarget, dtype=jnp.float32)

    def setMaxCorrespondenceDistance(self, maxCorrespondenceDistance: float) -> None:
        self._max_corr = float(maxCorrespondenceDistance)

    def setMaxIterationCount(self, maxIterationCount: int) -> None:
        self._max_iter = int(maxIterationCount)

    def setTransformationEpsilon(self, transformationEpsilon: float) -> None:
        self._eps = float(transformationEpsilon)

    def align(self) -> np.ndarray:
        """Run registration; returns the final 4x4 transformation matrix."""
        if not self._initialized:
            self.hardwareInitialize()
        if self._source is None or self._target is None:
            raise ValueError("setInputSource/setInputTarget must be called before align()")
        params = ICPParams(max_iterations=self._max_iter,
                           max_correspondence_distance=self._max_corr,
                           transformation_epsilon=self._eps,
                           chunk=self._chunk)
        nn_fn = self._make_nn_fn()
        result = _aligned(self._source, self._target, params,
                          self._initial_T, nn_fn)
        self._last_result = jax.tree_util.tree_map(np.asarray, result)
        return np.asarray(result.T)

    # -- extras (not in Table I but needed by callers/tests) ----------------
    @property
    def last_result(self) -> ICPResult | None:
        return self._last_result

    def hasConverged(self) -> bool:
        return bool(self._last_result.converged) if self._last_result else False

    def getFitnessScore(self) -> float:
        return float(self._last_result.rmse) if self._last_result else float("inf")

    def _make_nn_fn(self) -> Callable | None:
        if callable(self._engine):
            return self._engine
        if self._engine == "xla":
            return None  # icp() default
        if self._engine == "pallas":
            from repro.kernels.ops import nn_search_pallas
            interpret = jax.default_backend() != "tpu"
            return functools.partial(nn_search_pallas, interpret=interpret)
        raise ValueError(f"unknown engine {self._engine!r}")


@functools.partial(jax.jit, static_argnames=("params", "nn_fn"))
def _aligned(source, target, params: ICPParams, initial_T, nn_fn):
    return icp(source, target, params, initial_T, nn_fn=nn_fn)
