"""PCL-like API — faithful to FPPS Table I.

The paper ships PCL-style setters so software developers can swap the
accelerator into existing pipelines. We reproduce that surface exactly
(camelCase and all), backed by the unified registration engine layer
(``repro.core.engine``). ``hardwareInitialize`` stands in for the .xclbin
load: it initialises the configured engine's backend.

    icp = FppsICP()
    icp.hardwareInitialize()
    icp.setInputSource(src)      # (N,3) array-like
    icp.setInputTarget(dst)      # (M,3) array-like
    icp.setMaxCorrespondenceDistance(1.0)
    icp.setMaxIterationCount(50)
    icp.setTransformationEpsilon(1e-5)
    T = icp.align()

``FppsICP`` is a thin adapter: all compilation caching lives on the engine
instance, so repeated ``align()`` calls (the production shape: one per
incoming frame) reuse one compiled executable per shape bucket instead of
recompiling per call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import RegistrationEngine, get_engine
from repro.core.icp import ICPParams, ICPResult


class FppsICP:
    """Drop-in ICP object mirroring the FPPS / PCL interface (paper Table I)."""

    def __init__(self, engine: str | RegistrationEngine = "xla",
                 chunk: int = 2048, **engine_kwargs):
        """engine: 'xla' (default), 'pallas' (TPU kernel; interpret on CPU),
        'distributed', 'pyramid' (coarse-to-fine + grid NN), a
        ``RegistrationEngine`` instance, or a callable
        nn_fn(src, dst) -> (d2, idx)."""
        self._engine = get_engine(engine, chunk=chunk, **engine_kwargs)
        self._source: jax.Array | None = None
        self._target: jax.Array | None = None
        self._initial_T: jax.Array | None = None
        self._max_corr = 1.0
        self._max_iter = 50
        self._eps = 1e-5
        self._minimizer = "point_to_point"
        self._robust_kernel = "none"
        self._robust_scale = 0.5
        self._chunk = chunk
        self._initialized = False
        self._last_result: ICPResult | None = None

    # -- Table I surface ---------------------------------------------------
    def hardwareInitialize(self) -> None:
        """Initialise the backend (paper: load .xclbin). Here: engine setup —
        device discovery plus whatever the engine pre-builds."""
        self._engine.setup()
        self._initialized = True

    def setTransformationMatrix(self, transformationMatrix) -> None:
        self._initial_T = jnp.asarray(transformationMatrix, dtype=jnp.float32)

    def setInputSource(self, inputSource) -> None:
        self._source = jnp.asarray(inputSource, dtype=jnp.float32)

    def setInputTarget(self, inputTarget) -> None:
        self._target = jnp.asarray(inputTarget, dtype=jnp.float32)

    def setMaxCorrespondenceDistance(self, maxCorrespondenceDistance: float) -> None:
        self._max_corr = float(maxCorrespondenceDistance)

    def setMaxIterationCount(self, maxIterationCount: int) -> None:
        self._max_iter = int(maxIterationCount)

    def setTransformationEpsilon(self, transformationEpsilon: float) -> None:
        self._eps = float(transformationEpsilon)

    def setMinimizer(self, minimizer: str) -> None:
        """'point_to_point' (paper default) or 'point_to_plane'
        (DESIGN.md §9; PCL's IterativeClosestPointWithNormals analogue)."""
        from repro.core.icp import MINIMIZERS
        if minimizer not in MINIMIZERS:
            raise ValueError(f"unknown minimizer {minimizer!r}; "
                             f"expected one of {MINIMIZERS}")
        self._minimizer = minimizer

    def setRobustKernel(self, kind: str, scale: float | None = None) -> None:
        """IRLS reweighting: 'none', 'huber' or 'tukey' (+ optional scale
        in metres — huber's delta / tukey's cutoff)."""
        from repro.core.point_to_plane import ROBUST_KERNELS
        if kind not in ROBUST_KERNELS:
            raise ValueError(f"unknown robust kernel {kind!r}; "
                             f"expected one of {ROBUST_KERNELS}")
        self._robust_kernel = kind
        if scale is not None:
            self._robust_scale = float(scale)

    def align(self) -> np.ndarray:
        """Run registration; returns the final 4x4 transformation matrix."""
        if not self._initialized:
            self.hardwareInitialize()
        if self._source is None or self._target is None:
            raise ValueError(
                "setInputSource/setInputTarget must be called before align()")
        result = self._engine.register(self._source, self._target,
                                       self._params(), self._initial_T)
        self._last_result = jax.tree_util.tree_map(np.asarray, result)
        return np.asarray(result.T)

    # -- extras (not in Table I but needed by callers/tests) ----------------
    @property
    def engine(self) -> RegistrationEngine:
        return self._engine

    @property
    def last_result(self) -> ICPResult | None:
        return self._last_result

    def hasConverged(self) -> bool:
        return bool(self._last_result.converged) if self._last_result else False

    def getFitnessScore(self) -> float:
        return float(self._last_result.rmse) if self._last_result else float("inf")

    def _params(self) -> ICPParams:
        return ICPParams(max_iterations=self._max_iter,
                         max_correspondence_distance=self._max_corr,
                         transformation_epsilon=self._eps,
                         chunk=self._chunk,
                         minimizer=self._minimizer,
                         robust_kernel=self._robust_kernel,
                         robust_scale=self._robust_scale)
