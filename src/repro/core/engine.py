"""Unified registration engine layer (DESIGN.md §3).

One abstraction owns everything that used to be scattered across
``core/api.py`` (per-call nn_fn construction), ``kernels/ops.py`` (target
residency) and ``core/distributed.py`` (fleet sharding):

  * **engine selection** — a string registry ("xla", "pallas",
    "distributed") plus user callables, resolved by :func:`get_engine`;
  * **persistent compilation caches** — each engine instance holds its
    jitted registration executables keyed by ``(kind, ICPParams)``; jit's
    own per-shape cache supplies the shape dimension, and shape-bucketed
    padding (``repro.data.collate``) keeps the number of distinct shapes
    small. Trace-time counters (:attr:`RegistrationEngine.trace_count`)
    make recompiles observable, which is what the regression tests assert;
  * **once-per-frame target preparation** — the Pallas engine builds the
    (8, M) augmented target at frame scope, outside the per-iteration
    loop body (the paper's target-cloud-in-BRAM analogue);
  * **batched multi-frame ICP** — :meth:`RegistrationEngine.register_batch`
    runs a whole padded frame-pair batch as one device program via
    ``core.icp.icp_batch``.

Typical use::

    engine = get_engine("pallas")
    batch = collate_pairs([(src0, dst0), (src1, dst1), ...])
    res = engine.register_batch(batch.src, batch.dst, params,
                                src_valid=batch.src_valid,
                                dst_valid=batch.dst_valid)
    # res.T[k] is the 4x4 transform of pair k
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.icp import (ICPParams, ICPResult, icp, icp_batch,
                            icp_fixed_iterations, scrub_nonfinite)
from repro.data.collate import PAD_SENTINEL, bucket_size


def _mask_invalid(points: jax.Array, valid: jax.Array | None) -> jax.Array:
    """Move masked rows to the far sentinel so no searcher can match them."""
    if valid is None:
        return points
    return jnp.where(valid[..., None], points,
                     jnp.asarray(PAD_SENTINEL, points.dtype))


def _pad_device(points: jax.Array, size: int):
    """Device-side analogue of ``collate.pad_cloud`` — no host round-trip."""
    n = points.shape[0]
    padded = jnp.concatenate(
        [points, jnp.full((size - n, 3), PAD_SENTINEL, points.dtype)], axis=0)
    valid = jnp.arange(size) < n
    return padded, valid


def _target_normals(dst: jax.Array, params: ICPParams,
                    valid: jax.Array | None):
    """Trace-scope target normals for the plane minimiser, or None.

    Engines that sentinel-mask their target *before* the ICP loop (pallas,
    distributed) must estimate normals first, from the true valid mask —
    see ``default_target_normals``.
    """
    if params.minimizer != "point_to_plane":
        return None
    from repro.data.normals import default_target_normals
    return default_target_normals(dst, valid)


class RegistrationEngine:
    """Base engine: owns jit caches, bucketing, and the register API.

    Subclasses pick the correspondence searcher by overriding
    :meth:`_nn_fn` (simple swaps) or the ``_build_single``/``_build_batch``
    factories (engines that need frame-scope target preparation).
    """

    name = "base"

    def __init__(self, chunk: int = 2048):
        self._chunk = chunk
        self._cache: dict = {}     # (kind, ICPParams) -> jitted executable
        self._traces: list = []    # (kind, ICPParams, shapes) per (re)trace

    # -- introspection -----------------------------------------------------
    @property
    def trace_count(self) -> int:
        """Number of times any cached executable was (re)traced — i.e.
        compiled. Stable across repeated same-shape calls; grows by one per
        new (kind, params, shape-bucket) combination."""
        return len(self._traces)

    @property
    def traces(self) -> tuple:
        """The trace log behind :attr:`trace_count`: one ``(kind,
        params-key, *shape-buckets)`` tuple per compilation, in order —
        what the retrace-freedom tests diff before and after a run."""
        return tuple(self._traces)

    def setup(self) -> None:
        """Backend init hook (the paper's .xclbin load). Idempotent."""
        _ = jax.devices()

    # -- subclass hooks ----------------------------------------------------
    def _nn_fn(self, params: ICPParams) -> Callable | None:
        """Correspondence searcher ``(src, dst) -> (d2, idx)``; None means
        the default XLA brute force inside ``core.icp``."""
        return None

    def _note_trace(self, kind: str, params: ICPParams, *shapes) -> None:
        self._traces.append((kind, params, shapes))

    def _build_single(self, params: ICPParams):
        nn_fn = self._nn_fn(params)

        def run(src, dst, T0, sv, dv):
            self._note_trace("single", params, src.shape, dst.shape)
            return icp(src, dst, params, T0, nn_fn=nn_fn,
                       src_valid=sv, dst_valid=dv)

        return jax.jit(run)

    def _build_batch(self, params: ICPParams):
        nn_fn = self._nn_fn(params)

        def run(src_b, dst_b, T0, sv, dv):
            self._note_trace("batch", params, src_b.shape, dst_b.shape)
            return icp_batch(src_b, dst_b, params, T0, nn_fn=nn_fn,
                             src_valid=sv, dst_valid=dv)

        return jax.jit(run)

    def _executable(self, kind: str, params: ICPParams):
        key = (kind, params)
        fn = self._cache.get(key)
        if fn is None:
            build = self._build_single if kind == "single" else self._build_batch
            fn = build(params)
            self._cache[key] = fn
        return fn

    def _default_params(self, params: ICPParams | None) -> ICPParams:
        if params is None:
            return ICPParams(chunk=self._chunk)
        return params

    # -- public API --------------------------------------------------------
    def register(self, source, target, params: ICPParams | None = None,
                 initial_transform=None, *, src_valid=None, dst_valid=None,
                 bucket: bool = True) -> ICPResult:
        """Register one (N,3) source onto one (M,3) target.

        With ``bucket=True`` (default) both clouds are padded up to the next
        shape bucket before hitting the jitted executable, so a stream of
        slightly-varying frame sizes reuses one compilation per bucket
        instead of one per exact size. Padding happens device-side — an
        already-bucket-sized device array passes through with zero copies.

        ``src_valid``/``dst_valid`` let callers who manage their own
        static-capacity padding (e.g. the rolling submap of
        ``repro.data.submap``, whose invalid rows already carry the far
        sentinel) pass masks directly; the clouds then go through at their
        given shapes, no re-bucketing. ``initial_transform`` is cast to
        f32 so a float64 warm start cannot poison the f32 trace.
        """
        params = self._default_params(params)
        src = jnp.asarray(source, dtype=jnp.float32)
        dst = jnp.asarray(target, dtype=jnp.float32)
        if initial_transform is not None:
            initial_transform = jnp.asarray(initial_transform, jnp.float32)
        if src_valid is not None or dst_valid is not None:
            sv = None if src_valid is None else jnp.asarray(src_valid, bool)
            dv = None if dst_valid is None else jnp.asarray(dst_valid, bool)
        else:
            sv = dv = None
            if bucket:
                n_b = bucket_size(src.shape[0])
                m_b = bucket_size(dst.shape[0])
                if (src.shape[0], dst.shape[0]) != (n_b, m_b):
                    src, sv = _pad_device(src, n_b)
                    dst, dv = _pad_device(dst, m_b)
        fn = self._executable("single", params)
        return fn(src, dst, initial_transform, sv, dv)

    def register_batch(self, sources, targets,
                       params: ICPParams | None = None, *,
                       src_valid=None, dst_valid=None,
                       initial_transforms=None) -> ICPResult:
        """Register a (B,N,3) source batch onto a (B,M,3) target batch in a
        single compiled program. Masks come from ``collate_pairs``; every
        ``ICPResult`` leaf gains a leading batch axis."""
        fn = self._executable("batch", self._default_params(params))
        if initial_transforms is not None:
            # f32 pin: a float64 warm-start batch must not poison the trace
            initial_transforms = jnp.asarray(initial_transforms, jnp.float32)
        return fn(jnp.asarray(sources, dtype=jnp.float32),
                  jnp.asarray(targets, dtype=jnp.float32),
                  initial_transforms,
                  None if src_valid is None else jnp.asarray(src_valid, bool),
                  None if dst_valid is None else jnp.asarray(dst_valid, bool))

    def register_pairs(self, pairs, params: ICPParams | None = None,
                       initial_transforms=None):
        """Collate variable-size [(src, dst), ...] and register as one batch.

        Returns (ICPResult, CollatedBatch) — the batch carries the true
        per-frame sizes for unpadding downstream.
        """
        from repro.data.collate import collate_pairs
        batch = collate_pairs(pairs)
        res = self.register_batch(batch.src, batch.dst, params,
                                  src_valid=batch.src_valid,
                                  dst_valid=batch.dst_valid,
                                  initial_transforms=initial_transforms)
        return res, batch


class XLAEngine(RegistrationEngine):
    """Default engine: chunked brute-force NN in pure XLA (runs anywhere)."""

    name = "xla"
    # Base behaviour is exactly this engine; _nn_fn -> None selects the
    # chunked searcher in core.icp with native dst_valid masking.


class PallasEngine(RegistrationEngine):
    """TPU Pallas kernel engine (interpret mode off-TPU).

    The target augmentation is built once per frame at trace scope via
    ``kernels.ops.resident_nn_fn`` — each ICP iteration only augments the
    small source cloud and runs the MXU kernel against the resident target.

    ``params.fused`` swaps the whole iteration body for the single-pass
    moment kernel (``repro.kernels.fused_icp``, DESIGN.md §11): a resident
    counting-sort grid replaces the augmented target, and search + gate +
    IRLS weight + moment accumulation run as one Pallas pass per
    iteration; the unfused path above stays the fallback. The fused tile
    config defaults to the autotuned ``DEFAULT_CONFIG`` — override with
    the ``fused_*`` constructor kwargs.
    """

    name = "pallas"

    def __init__(self, chunk: int = 2048, bn: int = 512, bm: int = 1024,
                 interpret: bool | None = None,
                 grid_dims: tuple[int, int, int] = (128, 128, 32),
                 grid_voxel: float | None = None, max_per_cell: int = 32,
                 rings: int = 1, fused_bn: int | None = None,
                 fused_bc: int | None = None,
                 fused_prune: bool | None = None):
        super().__init__(chunk)
        self._bn, self._bm = bn, bm
        self._interpret = interpret  # None: auto (interpret unless on TPU)
        self._grid_dims = tuple(grid_dims)
        self._grid_voxel = grid_voxel
        self._max_per_cell = max_per_cell
        self._rings = rings
        self._fused_bn, self._fused_bc = fused_bn, fused_bc
        self._fused_prune = fused_prune

    def _interp(self) -> bool:
        from repro.kernels.common import default_interpret
        return default_interpret(self._interpret)

    def _fused_kwargs(self) -> dict:
        return dict(grid_dims=self._grid_dims, grid_voxel=self._grid_voxel,
                    max_per_cell=self._max_per_cell, rings=self._rings,
                    bn=self._fused_bn, bc=self._fused_bc,
                    prune=self._fused_prune, interpret=self._interpret)

    def _make_fused_fn(self, dst, params: ICPParams, dv, normals):
        from repro.kernels.fused_icp import default_fused_fn
        return default_fused_fn(dst, params, dst_valid=dv,
                                target_normals=normals,
                                **self._fused_kwargs())

    def _build_single(self, params: ICPParams):
        from repro.kernels.ops import resident_nn_fn
        interpret = self._interp()

        def run(src, dst, T0, sv, dv):
            self._note_trace("single", params, src.shape, dst.shape)
            # Scrub before any frame-scope prep: a NaN row would poison
            # the normal estimation and the resident target/grid builds.
            src, sv = scrub_nonfinite(src, sv)
            dst, dv = scrub_nonfinite(dst, dv)
            normals = _target_normals(dst, params, dv)
            if params.fused:
                fused_fn = self._make_fused_fn(dst, params, dv, normals)
                return icp(src, dst, params, T0, fused_fn=fused_fn,
                           src_valid=sv, target_normals=normals)
            dst = _mask_invalid(dst, dv)
            nn_fn = resident_nn_fn(dst, bn=self._bn, bm=self._bm,
                                   interpret=interpret)
            return icp(src, dst, params, T0, nn_fn=nn_fn, src_valid=sv,
                       target_normals=normals)

        return jax.jit(run)

    def _build_batch(self, params: ICPParams):
        from repro.kernels.ops import resident_nn_fn
        interpret = self._interp()

        def run(src_b, dst_b, T0, sv, dv):
            self._note_trace("batch", params, src_b.shape, dst_b.shape)
            if T0 is None:
                T0 = jnp.broadcast_to(jnp.eye(4, dtype=src_b.dtype),
                                      (src_b.shape[0], 4, 4))

            def one(src, dst, T0_, sv_, dv_):
                src, sv_ = scrub_nonfinite(src, sv_)
                dst, dv_ = scrub_nonfinite(dst, dv_)
                normals = _target_normals(dst, params, dv_)
                if params.fused:
                    fused_fn = self._make_fused_fn(dst, params, dv_, normals)
                    return icp_fixed_iterations(src, dst, params, T0_,
                                                fused_fn=fused_fn,
                                                src_valid=sv_,
                                                target_normals=normals)
                dst = _mask_invalid(dst, dv_)
                nn_fn = resident_nn_fn(dst, bn=self._bn, bm=self._bm,
                                       interpret=interpret)
                return icp_fixed_iterations(src, dst, params, T0_,
                                            nn_fn=nn_fn, src_valid=sv_,
                                            target_normals=normals)

            return jax.vmap(one)(src_b, dst_b, T0, sv, dv)

        return jax.jit(run)


class DistributedEngine(RegistrationEngine):
    """Fleet-mode engine: frames shard over "data", targets over "model".

    Wraps ``core.distributed.batched_icp_sharded`` on a mesh spanning the
    available devices (or a caller-supplied mesh). Warm starts are applied
    by pre-transforming sources and composing the result (mathematically
    identical to an initial transform).
    """

    name = "distributed"

    def __init__(self, chunk: int = 2048, mesh=None,
                 frame_axes=("data",), target_axes=("model",)):
        super().__init__(chunk)
        self._mesh = mesh
        self._frame_axes = tuple(frame_axes)
        self._target_axes = tuple(target_axes)

    def _get_mesh(self):
        if self._mesh is None:
            from jax.sharding import Mesh
            devs = np.array(jax.devices())
            self._mesh = Mesh(devs.reshape(len(devs), 1), ("data", "model"))
        return self._mesh

    def setup(self) -> None:
        """Backend init hook: build the (data, model) device mesh once."""
        self._get_mesh()

    def _build_batch(self, params: ICPParams):
        from repro.core.distributed import batched_icp_sharded
        mesh = self._get_mesh()
        frame_div = 1
        for ax in self._frame_axes:
            frame_div *= mesh.shape[ax]

        def run(src_b, dst_b, T0, sv, dv):
            self._note_trace("batch", params, src_b.shape, dst_b.shape)
            # Scrub before sharding/normals: NaN rows must not cross the
            # shard_map boundary or reach the per-frame normal estimate.
            src_b, sv = scrub_nonfinite(src_b, sv)
            dst_b, dv = scrub_nonfinite(dst_b, dv)
            b = src_b.shape[0]
            # The frame axis must divide the mesh's frame_axes extent; pad
            # by repeating frame 0 and slice the results back off.
            pad = (-b) % frame_div

            def rep(x):
                if x is None or pad == 0:
                    return x
                return jnp.concatenate(
                    [x, jnp.repeat(x[:1], pad, axis=0)], axis=0)

            src_b, dst_b, T0, sv, dv = map(rep, (src_b, dst_b, T0, sv, dv))
            if params.minimizer == "point_to_plane":
                # Normals come from the *unsharded* per-frame targets (and
                # the true valid mask), before the sentinel masking below.
                normals = jax.vmap(
                    lambda d, v: _target_normals(d, params, v))(
                        dst_b, dv if dv is not None
                        else jnp.ones(dst_b.shape[:2], bool))
            else:
                normals = None
            dst_b = _mask_invalid(dst_b, dv)
            if T0 is not None:
                # warm start: register T0(src) and compose T_result @ T0.
                R, t = T0[:, :3, :3], T0[:, :3, 3]
                src_b = jnp.einsum("bnj,bij->bni", src_b, R) + t[:, None, :]
            res = batched_icp_sharded(mesh, src_b, dst_b, params,
                                      frame_axes=self._frame_axes,
                                      target_axes=self._target_axes,
                                      src_valid=sv, dst_normals=normals)
            if T0 is not None:
                res = res._replace(T=jnp.einsum("bij,bjk->bik", res.T, T0))
            if pad:
                res = jax.tree_util.tree_map(lambda x: x[:b], res)
            return res

        return jax.jit(run)

    def _build_single(self, params: ICPParams):
        batch_fn = self._build_batch(params)

        def run(src, dst, T0, sv, dv):
            res = batch_fn(src[None], dst[None],
                           None if T0 is None else T0[None],
                           None if sv is None else sv[None],
                           None if dv is None else dv[None])
            return jax.tree_util.tree_map(lambda x: x[0], res)

        return run  # batch_fn is already jitted


class SlotEngine(RegistrationEngine):
    """Fixed-width slot-batch engine backing the multi-stream registration
    service (DESIGN.md §13).

    Every registration — the service's S-stream fleet step AND a lone
    single-frame :meth:`register` call — runs through ONE jitted
    ``vmap(icp)`` executable of exactly ``slots`` lanes. The batched
    ``while_loop`` stops when every lane's convergence predicate is false,
    and per-lane freeze masks (the ``vmap``-induced ``select`` on each
    state update) keep converged or inactive lanes bit-frozen while live
    lanes iterate. Single-frame calls embed the frame at lane 0 among
    sentinel-masked inactive lanes (which degenerate-freeze after one
    iteration) and slice lane 0 back out; a vmapped lane is bitwise
    independent of lane position and of the other lanes' contents, so a
    per-stream :class:`~repro.core.odometry.OdometryPipeline` on this
    engine reproduces the service's poses bit-for-bit — the service
    parity contract.
    """

    name = "slots"

    def __init__(self, chunk: int = 2048, slots: int = 8):
        super().__init__(chunk)
        self.slots = int(slots)

    def _build_batch(self, params: ICPParams):
        nn_fn = self._nn_fn(params)

        def run(src_b, dst_b, T0, sv, dv):
            self._note_trace("batch", params, src_b.shape, dst_b.shape)
            if T0 is None:
                T0 = jnp.broadcast_to(jnp.eye(4, dtype=src_b.dtype),
                                      (src_b.shape[0], 4, 4))

            def one(src, dst, T0_, sv_, dv_):
                return icp(src, dst, params, T0_, nn_fn=nn_fn,
                           src_valid=sv_, dst_valid=dv_)

            return jax.vmap(one)(src_b, dst_b, T0, sv, dv)

        return jax.jit(run)

    def register(self, source, target, params: ICPParams | None = None,
                 initial_transform=None, *, src_valid=None, dst_valid=None,
                 bucket: bool = True) -> ICPResult:
        """Register one (N,3)/(M,3) pair through the S-lane slot
        executable: the pair occupies lane 0, the remaining ``slots - 1``
        lanes carry sentinel rows with all-False masks (degenerate-frozen
        after one iteration), and lane 0 of the batched result is
        returned. Same bucketing semantics as the base engine; crucially
        the executable is the SAME one the service's fleet step compiles,
        so this path never adds a trace."""
        params = self._default_params(params)
        src = jnp.asarray(source, dtype=jnp.float32)
        dst = jnp.asarray(target, dtype=jnp.float32)
        if src_valid is None and dst_valid is None and bucket:
            n_b = bucket_size(src.shape[0])
            m_b = bucket_size(dst.shape[0])
            if (src.shape[0], dst.shape[0]) != (n_b, m_b):
                src, src_valid = _pad_device(src, n_b)
                dst, dst_valid = _pad_device(dst, m_b)
        sv = (jnp.ones(src.shape[0], bool) if src_valid is None
              else jnp.asarray(src_valid, bool))
        dv = (jnp.ones(dst.shape[0], bool) if dst_valid is None
              else jnp.asarray(dst_valid, bool))
        T0 = (jnp.eye(4, dtype=jnp.float32) if initial_transform is None
              else jnp.asarray(initial_transform, jnp.float32))
        lane = jnp.arange(self.slots) == 0
        sentinel = jnp.asarray(PAD_SENTINEL, jnp.float32)
        src_b = jnp.where(lane[:, None, None], src[None], sentinel)
        dst_b = jnp.where(lane[:, None, None], dst[None], sentinel)
        sv_b = jnp.logical_and(lane[:, None], sv[None])
        dv_b = jnp.logical_and(lane[:, None], dv[None])
        T0_b = jnp.broadcast_to(T0[None], (self.slots, 4, 4))
        fn = self._executable("batch", params)
        res = fn(src_b, dst_b, T0_b, sv_b, dv_b)
        return jax.tree_util.tree_map(lambda x: x[0], res)


class ShardedSlotEngine(SlotEngine):
    """Device-parallel slot engine: the ``slots`` executable under
    ``shard_map`` over a 1-D ``("streams",)`` mesh (DESIGN.md §14).

    The fleet width is ``devices * lanes_per_device``; each device runs
    the SAME ``vmap(icp)`` block over its own ``lanes_per_device`` lanes,
    with zero collectives in the body (streams are independent). Because
    the per-device block program is fixed by ``lanes_per_device`` alone,
    a lane's result is bitwise identical across mesh sizes at EQUAL block
    width — a D=8, L=1 fleet reproduces a single-device (D=1, L=1)
    reference's per-stream poses exactly (weak-scaling parity), which is
    the sharded service's acceptance contract. Across different widths
    (say L=1 vs L=8) XLA may tile each lane's point-axis reductions
    differently, so agreement is fp-tolerance, not bitwise.

    Inherits the ``SlotEngine`` lane-0 embedding: a single-frame
    :meth:`register` call runs through the same S-lane sharded
    executable, so a standalone ``OdometryPipeline`` on this engine is
    still the service's bit-exact reference. ``devices=0`` (the default,
    kept an int so the ``get_engine`` singleton key stays hashable) means
    all local devices.
    """

    name = "sharded-slots"

    def __init__(self, chunk: int = 2048, lanes_per_device: int = 2,
                 devices: int = 0):
        from repro.core.distributed import streams_mesh
        self.devices = int(devices) or jax.device_count()
        self.lanes_per_device = int(lanes_per_device)
        super().__init__(chunk, slots=self.devices * self.lanes_per_device)
        self._mesh = streams_mesh(self.devices)

    @property
    def mesh(self):
        """The ``("streams",)`` mesh every executable is sharded over."""
        return self._mesh

    def sharding(self):
        """``NamedSharding`` for lane-major fleet arrays: place ``(S,...)``
        inputs with this to avoid a reshard at the jit boundary."""
        from jax.sharding import NamedSharding, PartitionSpec
        return NamedSharding(self._mesh, PartitionSpec("streams"))

    def _build_batch(self, params: ICPParams):
        from repro.core.distributed import stream_sharded_icp
        nn_fn = self._nn_fn(params)
        mesh = self._mesh

        def run(src_b, dst_b, T0, sv, dv):
            self._note_trace("batch", params, src_b.shape, dst_b.shape)
            # shard_map's in_specs are fixed-arity: normalize the optional
            # masks/warm starts here (identical defaults to SlotEngine's).
            if sv is None:
                sv = jnp.ones(src_b.shape[:2], bool)
            if dv is None:
                dv = jnp.ones(dst_b.shape[:2], bool)
            return stream_sharded_icp(mesh, src_b, dst_b, params,
                                      initial_transforms=T0,
                                      src_valid=sv, dst_valid=dv,
                                      nn_fn=nn_fn)

        return jax.jit(run)


class CallableEngine(RegistrationEngine):
    """Adapter for a user-supplied ``nn_fn(src, dst) -> (d2, idx)``."""

    name = "callable"

    def __init__(self, nn_fn: Callable, chunk: int = 2048):
        super().__init__(chunk)
        self._user_nn_fn = nn_fn

    def _nn_fn(self, params: ICPParams) -> Callable:
        return self._user_nn_fn


# -- registry ---------------------------------------------------------------
_ENGINES: dict[str, Callable[..., RegistrationEngine]] = {}
_SHARED: dict = {}  # (name, sorted kwargs) -> engine instance


def register_engine(name: str, factory: Callable[..., RegistrationEngine]):
    """Register an engine factory under ``name`` (last write wins)."""
    _ENGINES[name] = factory
    _SHARED.clear()
    return factory


def available_engines() -> tuple[str, ...]:
    """Registered engine names, sorted — the valid ``get_engine`` specs
    (and the CLI ``--engine`` choices)."""
    return tuple(sorted(_ENGINES))


def get_engine(spec, **kwargs) -> RegistrationEngine:
    """Resolve an engine spec: a RegistrationEngine instance (passed
    through), a registered name, or a bare ``nn_fn`` callable.

    Named engines with hashable kwargs are process-wide singletons, so the
    compilation caches are shared: constructing ``FppsICP()`` per frame
    (the PCL-style pattern the drivers use) reuses one compiled executable
    instead of recompiling per instance. Instantiate the engine class
    directly for a private cache.
    """
    if isinstance(spec, RegistrationEngine):
        return spec
    if isinstance(spec, str):
        try:
            factory = _ENGINES[spec]
        except KeyError:
            raise ValueError(
                f"unknown engine {spec!r}; available: {available_engines()}"
            ) from None
        try:
            key = (spec, tuple(sorted(kwargs.items())))
            engine = _SHARED.get(key)
            if engine is None:
                engine = _SHARED[key] = factory(**kwargs)
            return engine
        except TypeError:  # unhashable kwarg (e.g. an explicit mesh)
            return factory(**kwargs)
    if callable(spec):
        return CallableEngine(spec, **kwargs)
    raise TypeError(f"engine spec must be a name, callable or "
                    f"RegistrationEngine, got {type(spec).__name__}")


register_engine("xla", XLAEngine)
register_engine("pallas", PallasEngine)
register_engine("distributed", DistributedEngine)
register_engine("slots", SlotEngine)
register_engine("sharded-slots", ShardedSlotEngine)

# Imported for its side effect: registers the "pyramid" engine. Lives in
# its own module (it pulls in the voxel/grid-NN stack); bottom import keeps
# the pyramid -> engine -> pyramid cycle harmless.
from repro.core import pyramid as _pyramid  # noqa: E402,F401
