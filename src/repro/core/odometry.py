"""Streaming scan-to-map odometry on the engine layer (DESIGN.md §10, §12).

The paper's headline numbers are measured on KITTI odometry *streams*, not
isolated frame pairs; this module is the streaming subsystem that turns
per-frame registration into a trajectory:

  * **scan-to-map** — each incoming scan registers against the rolling
    local submap (``repro.data.submap``) instead of the previous scan, so
    per-frame error stops compounding into a random walk: the map is the
    common anchor, and revisited structure refines it.
  * **constant-velocity warm start** — the motion model predicts each
    frame's pose from the tracked inter-frame velocity
    (``T_pred = T_k @ v``, ``v = T_{k-1}^{-1} T_k`` after each accepted
    frame) and feeds it through ``initial_transform``, cutting iterations
    on smooth motion and keeping the basin of attraction centred under
    fast motion. On a *rejected* frame the velocity **decays toward
    identity** (``velocity_decay`` per frame) — without the decay a
    multi-frame sensor dropout has the platform coasting at full speed
    forever, and the prediction error compounds geometrically.
  * **health-gated recovery cascade** (§12) — every registration is
    distilled into a :class:`~repro.core.health.RegistrationHealth`
    verdict (inlier mass, final RMSE, degeneracy, pose jump vs. the
    motion model, scan-outside-map fraction). A non-OK frame walks a
    bounded retry ladder instead of being trusted or dropped outright:

      tier 1 ``widen``      same map, widened gate + coarser pyramid
                            schedule (occlusion/dropout shrink overlap;
                            a wider basin re-acquires it)
      tier 2 ``fallback``   engine fallback to the unfused dense-XLA
                            path with the warm start *discarded* (a
                            poisoned motion-model prediction is the
                            failure being escaped)
      tier 3 ``wide_basin`` wide-basin relocalization: very coarse-to-
                            fine schedule, 4x gate, restarted from the
                            last accepted pose
      tier 4 (implicit)     coast on the decayed motion model and
                            **quarantine** the frame — the pose is a
                            prediction, the scan is NOT fused, so one
                            bad frame cannot poison the anchor every
                            later frame registers against

    The first tier that comes back OK wins; if none does, the least-bad
    SUSPECT attempt (fewest tripped signals, then smallest jump from the
    prediction) is accepted as the *output* pose but the scan is
    **quarantined** — not fused into the map — so a merely-plausible
    pose cannot poison the anchor; only an all-FAILED ladder coasts.
    The ladder is bounded: at most ``1 + len(recovery_tiers)``
    registrations per frame.
  * **sensor-boundary scrubbing** — NaN/Inf rows are scrubbed off the
    scan before anything (even the voxel downsample's min-derived lattice
    origin) can see them.

Per-frame diagnostics (iterations, inlier fraction, map occupancy,
health verdict, recovery tier, accept/quarantine) are first-class
outputs — a stream you cannot observe is a stream you cannot trust.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import get_engine
from repro.core.health import (FAILED, OK, SUSPECT, HealthThresholds,
                               RegistrationHealth, assess_registration,
                               normal_equation_condition)
from repro.core.icp import ICPParams, scrub_nonfinite
from repro.core.transform import transform_points
from repro.data.normals import NormalParams, estimate_normals
from repro.data.submap import Submap, SubmapParams
from repro.data.voxelize import voxel_downsample

# Retry-tier pyramid schedules: ((voxel_m, iters, max_points), ...).
# ``widen`` doubles the basin (coarse 8 m level re-acquires overlap lost
# to occlusion/dropout); ``wide_basin`` starts at 16 m — a relocalization
# sweep for when even the widened gate cannot see the map.
_WIDEN_LEVELS = ((8.0, 8, 4096), (4.0, 6, 8192))
_WIDE_BASIN_LEVELS = ((16.0, 8, 2048), (8.0, 8, 4096), (4.0, 6, 8192))

DEFAULT_RECOVERY_TIERS = ("widen", "fallback", "wide_basin")


class OdometryConfig(NamedTuple):
    """Pipeline configuration. ``params.max_iterations`` is the per-frame
    iteration cap (the paper's 50 is generous for warm-started streaming;
    30 keeps worst-case latency bounded). ``scan_voxel``/``scan_budget``
    shape the voxel-downsampled registration source — the same cloud that
    is fused into the map on acceptance.

    Size ``scan_budget`` ABOVE the scan's occupied-voxel count:
    ``voxel_downsample`` drops overflow cells deterministically from the
    cell-id sort tail, which is a *spatially biased* truncation (the +x
    end of the scene vanishes first) — poison for odometry. Same for
    ``submap.capacity`` vs the eviction ball (watch ``map_occupancy``).

    With ``recovery=True`` the health thresholds decide accept/reject
    (``min_inlier_frac`` is subsumed by ``thresholds``); with it off the
    pipeline keeps the legacy degenerate/``min_inlier_frac`` guard. The
    velocity decay applies either way — it fixes a bug, not a feature.
    """

    # Pyramid engine, polish-only: the finest-level grid NN gives O(27K)
    # correspondence against the resident map AND gates scan points whose
    # map neighbourhood is empty (the frontier a moving ego constantly
    # creates) through the honest d2=inf path instead of dragging the pose
    # toward the map boundary.
    engine: str = "pyramid"
    engine_kwargs: tuple = (("levels", ()),)
    # Huber by default: residual frontier points that DO land within one
    # grid cell of mapped space still pull backward; huber bounds that pull
    # (a redescending kernel or a tight gate instead lets the ground plane
    # slide on cold starts — see DESIGN.md §10).
    params: ICPParams = ICPParams(max_iterations=30,
                                  max_correspondence_distance=1.0,
                                  transformation_epsilon=1e-5,
                                  robust_kernel="huber", robust_scale=0.3)
    submap: SubmapParams = SubmapParams(voxel_size=0.75, capacity=24576,
                                        dims=(128, 128, 32),
                                        evict_radius=40.0)
    scan_voxel: float = 0.75
    scan_budget: int = 8192
    motion_model: bool = True
    min_inlier_frac: float = 0.2
    # -- degradation & recovery (§12) -------------------------------------
    recovery: bool = True
    recovery_tiers: tuple = DEFAULT_RECOVERY_TIERS
    thresholds: HealthThresholds = HealthThresholds()
    velocity_decay: float = 0.5
    # Cold-start grace: frames below this index are health-labelled but
    # never retried — frame 1 registers against a one-scan map with no
    # velocity estimate, so its rmse/jump signals read SUSPECT on clean
    # input (cold-start truth, not a fault).
    warmup_frames: int = 2
    # Per-frame scan-observability probe: normals on the downsampled scan
    # -> 6x6 plane normal-equation conditioning. Pose-independent (sensor
    # frame), computed once per frame; it is the only signal that sees a
    # sector crop *before* the pose slides (residual metrics read fine
    # while the unconstrained direction drifts).
    observability_probe: bool = True


class FrameDiagnostics(NamedTuple):
    frame: int
    iterations: int
    inlier_frac: float
    rmse: float
    degenerate: bool
    accepted: bool          # False: pose fell back to the motion model
    map_occupancy: float    # submap capacity in use after this frame
    health: str = OK        # RegistrationHealth verdict for this frame
    recovery_tier: int = 0  # 0 primary; 1..N retry tier; N+1 coasted
    pose_jump: float = 0.0  # metres vs. the motion-model prediction
    quarantined: bool = False   # scan withheld from the map
    dropped_cells: int = 0  # sticky submap-saturation counter (running
                            # total of occupied voxels the capacity budget
                            # dropped — distinguishes a clean 1.0
                            # occupancy from silent truncation)


# Frame classification out of prepare_frame: which half of the frame
# lifecycle (register / bootstrap-the-map / coast-an-empty-scan) the
# completion step must run. Strings, not an enum, so diagnostics and the
# service's per-lane bookkeeping stay greppable.
KIND_BOOTSTRAP = "bootstrap"
KIND_EMPTY = "empty"
KIND_REGISTER = "register"


class PreparedFrame(NamedTuple):
    """Device-side half of one frame, produced by
    :meth:`OdometryPipeline.prepare_frame`: the scrubbed + voxel-
    downsampled scan, its validity mask, and the host-side classification
    (bootstrap / empty / register) plus warm-start prediction. The
    registration service batches many of these through one executable;
    :meth:`OdometryPipeline.complete_frame` consumes one plus its
    registration result."""

    frame: int
    kind: str               # KIND_BOOTSTRAP | KIND_EMPTY | KIND_REGISTER
    src: jax.Array          # (scan_budget, 3) downsampled sensor-frame scan
    sv: jax.Array           # (scan_budget,) validity mask
    T0: np.ndarray          # warm-start prediction (identity off-register)
    reacquire: bool = False     # first frame after a coast streak
    skip_primary: bool = False  # reacquire + tiers: ladder only, no primary


class FuseRequest(NamedTuple):
    """Deferred map-fusion work order returned by
    :meth:`OdometryPipeline.complete_frame` under ``defer_fuse=True``: the
    accepted frame's downsampled scan (sensor frame), mask, and output
    pose. The service executes these as ONE vmapped submap fuse across
    all streams instead of per-stream inserts."""

    src: jax.Array
    sv: jax.Array
    pose: np.ndarray


@functools.partial(jax.jit, static_argnames=("nparams",))
def _scan_plane_system(src: jax.Array, sv: jax.Array,
                       nparams: NormalParams) -> jax.Array:
    """6x6 plane normal matrix of the scan against its own estimated
    normals — the observability probe's device half (one executable per
    scan-budget shape)."""
    normals, nvalid = estimate_normals(src, nparams, valid=sv)
    w = jnp.logical_and(sv, nvalid).astype(jnp.float32)
    a = jnp.concatenate([jnp.cross(src, normals), normals], axis=-1)
    return (a * w[:, None]).T @ a


def _decay_toward_identity(T: np.ndarray, factor: float) -> np.ndarray:
    """Shrink a rigid motion: translation scaled by ``factor``, rotation
    angle scaled by ``factor`` about the same axis (Rodrigues)."""
    T = np.asarray(T, np.float64)
    R = T[:3, :3]
    cos = np.clip((np.trace(R) - 1.0) / 2.0, -1.0, 1.0)
    angle = float(np.arccos(cos))
    out = np.eye(4)
    if angle > 1e-8:
        axis = np.array([R[2, 1] - R[1, 2], R[0, 2] - R[2, 0],
                         R[1, 0] - R[0, 1]])
        axis /= max(np.linalg.norm(axis), 1e-12)
        K = np.array([[0.0, -axis[2], axis[1]],
                      [axis[2], 0.0, -axis[0]],
                      [-axis[1], axis[0], 0.0]])
        a = angle * factor
        out[:3, :3] = np.eye(3) + np.sin(a) * K + (1 - np.cos(a)) * (K @ K)
    out[:3, 3] = factor * T[:3, 3]
    return out.astype(np.float32)


class OdometryPipeline:
    """Stateful scan-to-map odometry: feed sensor-frame scans in order,
    read back poses (sensor -> frame-0/map) and per-frame diagnostics.

        pipe = OdometryPipeline(OdometryConfig(engine="xla"))
        for scan in scans:                       # (N_k, 3) numpy, any N_k
            pose, diag = pipe.process(scan)

    All heavy work runs through the shared engine layer: the submap's
    static capacity means every frame after the first hits one compiled
    executable (one shape, one ``ICPParams``), and the warm start is
    threaded through the engine's ``initial_transform`` argument. Retry
    tiers are named ``get_engine`` singletons, so their jit caches persist
    across frames and across pipeline instances.
    """

    def __init__(self, config: OdometryConfig = OdometryConfig(),
                 submap: Submap | None = None):
        self.config = config
        kwargs = dict(config.engine_kwargs)
        if config.engine != "pyramid":
            # the default engine_kwargs select the pyramid's polish-only
            # schedule; they don't apply to other engine constructors
            kwargs.pop("levels", None)
        self.engine = get_engine(config.engine, **kwargs)
        # ``submap`` lets a fleet owner substitute a view over shared
        # device state (the sharded service's lane views) for the default
        # per-stream map; anything duck-typing Submap's read surface works.
        self.submap = Submap(config.submap) if submap is None else submap
        self.poses: list[np.ndarray] = []
        self.diagnostics: list[FrameDiagnostics] = []
        # inter-frame velocity v = T_{k-1}^{-1} T_k, decayed on rejection
        self._velocity = np.eye(4, dtype=np.float32)
        self._coast_streak = 0       # consecutive frames without a pose fix
        self.recovery_count = 0      # sticky: frames that left tier 0
        self.quarantined_count = 0   # sticky: frames withheld from the map

    # -- motion model ------------------------------------------------------
    def _predict(self) -> np.ndarray:
        """Constant-velocity pose prediction for the incoming frame."""
        if len(self.poses) < 2 or not self.config.motion_model:
            return self.poses[-1]
        return (self.poses[-1] @ self._velocity).astype(np.float32)

    # -- health ------------------------------------------------------------
    def _out_of_lattice_frac(self, res, src, sv) -> float:
        """Fraction of the (pose-transformed) scan outside the submap
        lattice — the low-overlap/teleport signal. Pure bounds check
        against the rolling lattice; no grid build."""
        p = self.submap.params
        pts = transform_points(jnp.asarray(res.T, jnp.float32), src)
        c = jnp.floor((pts - self.submap.origin) / p.voxel_size)
        inb = jnp.all((c >= 0) & (c < jnp.asarray(p.dims, jnp.float32)),
                      axis=-1)
        n_valid = jnp.maximum(jnp.sum(sv), 1)
        return float(jnp.sum(jnp.logical_and(sv, ~inb)) / n_valid)

    def _assess(self, res, T0, src, sv, condition: float | None = None,
                trust_prediction: bool = True,
                out_of_lattice: float | None = None) -> RegistrationHealth:
        # The jump signal needs a real prediction: with <2 poses (or the
        # motion model off) T0 is just the last pose, and "jump" would
        # penalize genuine ego motion. Reacquire mode also drops it —
        # after a coast the prediction is exactly what is no longer
        # trusted, and a *correct* re-acquisition necessarily jumps away
        # from it. ``out_of_lattice`` lets the service supply the probe
        # from its batched (vmapped) evaluation; per-frame callers leave
        # it None and pay the eager probe here.
        predicted = (T0 if trust_prediction and self.config.motion_model
                     and len(self.poses) >= 2 else None)
        if out_of_lattice is None:
            out_of_lattice = self._out_of_lattice_frac(res, src, sv)
        return assess_registration(
            res, predicted=predicted, thresholds=self.config.thresholds,
            out_of_lattice=out_of_lattice, condition=condition)

    def _scan_condition(self, src, sv) -> float | None:
        """Observability of the scan itself (pose-independent, once per
        frame): conditioning of its 6x6 plane system."""
        if not self.config.observability_probe:
            return None
        A = np.asarray(_scan_plane_system(src, sv, NormalParams()),
                       np.float64)
        return normal_equation_condition(A)

    # -- recovery tiers ----------------------------------------------------
    def _tier_attempt(self, name: str, src, sv, map_pts, map_valid, T0):
        """Run one named retry tier; returns its ICPResult."""
        cfg = self.config
        if name == "widen":
            engine = get_engine("pyramid", levels=_WIDEN_LEVELS)
            params = cfg.params._replace(
                max_correspondence_distance=(
                    2.0 * cfg.params.max_correspondence_distance),
                robust_scale=2.0 * cfg.params.robust_scale)
            init = T0                      # keep the warm start
        elif name == "fallback":
            engine = get_engine("xla")
            params = cfg.params
            init = self.poses[-1]          # warm start discarded
        elif name == "wide_basin":
            engine = get_engine("pyramid", levels=_WIDE_BASIN_LEVELS)
            params = cfg.params._replace(
                max_correspondence_distance=(
                    4.0 * cfg.params.max_correspondence_distance),
                robust_scale=2.0 * cfg.params.robust_scale)
            init = self.poses[-1]          # relocalize from last good pose
        else:
            raise ValueError(f"unknown recovery tier {name!r}; "
                             f"known: {DEFAULT_RECOVERY_TIERS}")
        return engine.register(src, map_pts, params, initial_transform=init,
                               src_valid=sv, dst_valid=map_valid)

    def _cascade(self, src, sv, map_pts, map_valid, T0,
                 condition: float | None = None, reacquire: bool = False,
                 primary=None, out_of_lattice: float | None = None):
        """Primary attempt + bounded retry ladder. Returns
        (result_or_None, health, tier): ``None`` result means coast.

        ``reacquire=True`` (the frame after a coast) skips the primary:
        the prediction's uncertainty has outgrown the narrow gate, so the
        primary's basin need not contain the truth — it locks onto an
        alias that *reads* healthy (small jump vs. the equally-stale
        prediction, ordinary rmse). The coarse-first retry schedules are
        built for exactly this uncertainty, so the ladder starts there.

        ``primary`` (with its batched ``out_of_lattice`` probe) is the
        service path: the tier-0 registration already ran inside the
        fleet-wide executable, so the ladder only spends per-stream
        registrations when that result's health gates it here.
        """
        cfg = self.config
        attempts = []
        if not (reacquire and cfg.recovery_tiers):
            res = primary
            if res is None:
                res = self.engine.register(src, map_pts, cfg.params,
                                           initial_transform=T0,
                                           src_valid=sv, dst_valid=map_valid)
            health = self._assess(res, T0, src, sv, condition,
                                  out_of_lattice=out_of_lattice)
            if health.ok or not cfg.recovery:
                return res, health, 0
            attempts.append((0, res, health))
        for tier, name in enumerate(cfg.recovery_tiers, start=1):
            r = self._tier_attempt(name, src, sv, map_pts, map_valid, T0)
            h = self._assess(r, T0, src, sv, condition,
                             trust_prediction=not reacquire)
            if h.ok:
                return r, h, tier
            attempts.append((tier, r, h))
        # No rung is OK: take the least-bad SUSPECT — fewest tripped
        # signals, then smallest jump from the prediction. NEVER compare
        # inlier mass across tiers: a widened gate inflates it by
        # construction, so the worst pose would win. Ties keep the
        # earliest tier (the primary's narrow-gate estimate).
        suspects = [a for a in attempts if a[2].verdict == SUSPECT]
        if suspects:
            tier, r, h = min(suspects,
                             key=lambda a: (len(a[2].reasons),
                                            a[2].pose_jump_m))
            return r, h, tier
        # every rung FAILED: coast (tier N+1), report the primary's health
        return None, attempts[0][2], len(cfg.recovery_tiers) + 1

    # -- streaming API -----------------------------------------------------
    def prepare_frame(self, scan, valid=None,
                      downsampled=None) -> PreparedFrame:
        """Device-side frame ingest + host classification, without the
        registration: scrub NaN/Inf rows, voxel-downsample to the scan
        budget, predict the warm start, and decide whether this frame
        bootstraps the map, coasts (no usable returns), or registers.

        ``downsampled=(src, sv, n_valid)`` skips the scrub/downsample —
        the service path, which runs that stage as one vmapped executable
        across every stream and hands each pipeline its own lane. The
        lane must be bit-identical to what this method would compute
        (guaranteed: a vmapped lane of the same program is).
        """
        cfg = self.config
        if downsampled is None:
            pts = jnp.asarray(scan, jnp.float32)
            if valid is not None:
                valid = jnp.asarray(valid, bool)
            pts, valid = scrub_nonfinite(pts, valid)
            src, sv = voxel_downsample(pts, cfg.scan_voxel,
                                       max_points=cfg.scan_budget,
                                       valid=valid)
            n_valid = int(jnp.sum(sv))
        else:
            src, sv, n_valid = downsampled
        frame = len(self.poses)
        if frame == 0:
            return PreparedFrame(frame=frame, kind=KIND_BOOTSTRAP, src=src,
                                 sv=sv, T0=np.eye(4, dtype=np.float32))
        if n_valid == 0:
            return PreparedFrame(frame=frame, kind=KIND_EMPTY, src=src,
                                 sv=sv, T0=np.asarray(self._predict(),
                                                      np.float32))
        reacquire = (cfg.recovery and frame >= cfg.warmup_frames
                     and self._coast_streak > 0)
        return PreparedFrame(frame=frame, kind=KIND_REGISTER, src=src,
                             sv=sv, T0=np.asarray(self._predict(),
                                                  np.float32),
                             reacquire=reacquire,
                             skip_primary=(reacquire
                                           and bool(cfg.recovery_tiers)))

    def complete_frame(self, prep: PreparedFrame, result=None, *,
                       lattice_frac: float | None = None,
                       defer_fuse: bool = False,
                       defer_bootstrap: bool = False):
        """Host-side frame completion: health assessment, recovery
        cascade, accept/quarantine bookkeeping, map fusion. Returns
        ``(pose, diagnostics, fuse_request)``.

        ``result`` is the primary registration's ICPResult for
        ``KIND_REGISTER`` frames (None when ``prep.skip_primary`` — the
        cascade ladder runs without a tier 0). ``lattice_frac``
        optionally supplies the out-of-lattice probe for that primary
        result (the service's batched probe). With ``defer_fuse=True`` an
        accepted fusable frame returns a :class:`FuseRequest` instead of
        inserting into the submap — the caller owns the fuse and must
        then patch ``diag.map_occupancy`` (reported here as the pre-fuse
        value). ``defer_bootstrap=True`` (sharded service: the fleet's
        submaps live in sharded device state no per-stream insert can
        write) extends the deferral to the bootstrap frame's first
        insert, as a ``FuseRequest`` with the identity pose.
        """
        cfg = self.config
        frame, src, sv, T0 = prep.frame, prep.src, prep.sv, prep.T0
        fuse_req = None
        if prep.kind == KIND_BOOTSTRAP:
            pose = np.eye(4, dtype=np.float32)
            if defer_fuse and defer_bootstrap:
                fuse_req = FuseRequest(src=src, sv=sv, pose=pose)
                occ = -1.0
            else:
                self.submap.insert(src, center=np.zeros(3, np.float32),
                                   valid=sv)
                occ = self.submap.occupancy()
            diag = FrameDiagnostics(frame=0, iterations=0, inlier_frac=1.0,
                                    rmse=0.0, degenerate=False, accepted=True,
                                    map_occupancy=occ,
                                    dropped_cells=self.submap.dropped_cells)
        elif prep.kind == KIND_EMPTY:
            # dropped frame (no usable returns): coast without spending a
            # registration, quarantine, decay the velocity
            pose = np.asarray(T0, np.float32)
            self._velocity = _decay_toward_identity(self._velocity,
                                                    cfg.velocity_decay)
            self._coast_streak += 1
            tier = len(cfg.recovery_tiers) + 1 if cfg.recovery else 0
            if tier > 0:
                self.recovery_count += 1
            self.quarantined_count += 1
            diag = FrameDiagnostics(frame=frame, iterations=0,
                                    inlier_frac=0.0, rmse=float("inf"),
                                    degenerate=True, accepted=False,
                                    map_occupancy=self.submap.occupancy(),
                                    health=FAILED, recovery_tier=tier,
                                    quarantined=True,
                                    dropped_cells=self.submap.dropped_cells)
        else:
            reacquire = prep.reacquire
            if cfg.recovery and frame >= cfg.warmup_frames:
                condition = self._scan_condition(src, sv)
                map_pts, map_valid = self.submap.target()
                res, health, tier = self._cascade(
                    src, sv, map_pts, map_valid, T0, condition,
                    reacquire=reacquire, primary=result,
                    out_of_lattice=lattice_frac)
                accepted = res is not None
            else:
                res = result
                health = self._assess(res, T0, src, sv,
                                      out_of_lattice=lattice_frac)
                tier = 0
                accepted = (not bool(res.degenerate)
                            and float(res.inlier_frac)
                            >= cfg.min_inlier_frac)
            # A SUSPECT pose is good enough to *output* (jump-bounded by
            # the thresholds) but not good enough to FUSE: one wrong scan
            # in the submap poisons the anchor every later frame registers
            # against, which is how transient faults become permanent
            # drift. Legacy mode (recovery off) keeps fuse == accept.
            fused = accepted and (not cfg.recovery or health.verdict == OK)
            self._coast_streak = 0 if accepted else self._coast_streak + 1
            if accepted:
                pose = np.asarray(res.T, np.float32)
                prev = self.poses[-1]
                if not reacquire:
                    self._velocity = (np.linalg.inv(prev) @ pose).astype(
                        np.float32)
                # else: the previous (coasted) pose was wrong, so the pose
                # delta is correction + motion entangled — the decayed
                # coast velocity is the better motion estimate; keep it.
                if fused:
                    if defer_fuse:
                        fuse_req = FuseRequest(src=src, sv=sv, pose=pose)
                    else:
                        self.submap.insert(
                            transform_points(jnp.asarray(pose, jnp.float32),
                                             src),
                            center=pose[:3, 3], valid=sv)
            else:
                pose = np.asarray(T0, np.float32)
                # decay the motion model: coasting frames must bleed speed
                # or a dropout burst extrapolates at full velocity forever
                self._velocity = _decay_toward_identity(self._velocity,
                                                        cfg.velocity_decay)
            if tier > 0:
                self.recovery_count += 1
            if not fused:
                self.quarantined_count += 1
            last = res if res is not None else None
            diag = FrameDiagnostics(
                frame=frame,
                iterations=int(last.iterations) if last is not None else 0,
                inlier_frac=(float(last.inlier_frac)
                             if last is not None else 0.0),
                rmse=float(last.rmse) if last is not None else float("inf"),
                degenerate=(bool(last.degenerate)
                            if last is not None else True),
                accepted=accepted,
                map_occupancy=(-1.0 if fuse_req is not None
                               else self.submap.occupancy()),
                health=health.verdict, recovery_tier=tier,
                pose_jump=health.pose_jump_m,
                quarantined=not fused,
                dropped_cells=self.submap.dropped_cells)
        self.poses.append(pose)
        self.diagnostics.append(diag)
        return pose, diag, fuse_req

    def amend_diagnostics(self, frame: int,
                          **fields) -> FrameDiagnostics:
        """Patch the stored diagnostics for ``frame`` (service use: fill
        ``map_occupancy`` after a deferred batched fuse). Returns the
        amended record."""
        idx = next(i for i, d in enumerate(self.diagnostics)
                   if d.frame == frame)
        self.diagnostics[idx] = self.diagnostics[idx]._replace(**fields)
        return self.diagnostics[idx]

    def process(self, scan, valid=None) -> tuple[np.ndarray, FrameDiagnostics]:
        """Ingest one sensor-frame scan; returns (pose, diagnostics).

        ``valid`` is an optional (N,) row mask (collate conventions).
        NaN/Inf rows are scrubbed here, before even the voxel downsample's
        min-derived lattice origin can see them. This is
        :meth:`prepare_frame` + primary registration +
        :meth:`complete_frame` in sequence — the single-stream spelling of
        the same lifecycle the registration service runs batched.
        """
        prep = self.prepare_frame(scan, valid)
        res = None
        if prep.kind == KIND_REGISTER and not prep.skip_primary:
            map_pts, map_valid = self.submap.target()
            res = self.engine.register(prep.src, map_pts, self.config.params,
                                       initial_transform=prep.T0,
                                       src_valid=prep.sv,
                                       dst_valid=map_valid)
        pose, diag, _ = self.complete_frame(prep, res)
        return pose, diag

    def run(self, scans) -> tuple[np.ndarray, list[FrameDiagnostics]]:
        """Process a whole sequence; returns ((F,4,4) poses, diagnostics)."""
        for scan in scans:
            self.process(scan)
        return np.stack(self.poses), list(self.diagnostics)

    # -- stream-level summaries -------------------------------------------
    def mean_iterations(self) -> float:
        """Mean ICP iterations over registered frames (frame 0 excluded)."""
        its = [d.iterations for d in self.diagnostics if d.frame > 0]
        return float(np.mean(its)) if its else 0.0

    def rejected_frames(self) -> int:
        return sum(1 for d in self.diagnostics if not d.accepted)

    def health_counts(self) -> dict[str, int]:
        """Verdict histogram over the stream (``{"ok": ..., ...}``)."""
        out = {OK: 0, SUSPECT: 0, FAILED: 0}
        for d in self.diagnostics:
            out[d.health] += 1
        return out

    def tier_counts(self) -> dict[int, int]:
        """Histogram of the recovery tier each frame settled at."""
        out: dict[int, int] = {}
        for d in self.diagnostics:
            out[d.recovery_tier] = out.get(d.recovery_tier, 0) + 1
        return out
