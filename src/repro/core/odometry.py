"""Streaming scan-to-map odometry on the engine layer (DESIGN.md §10).

The paper's headline numbers are measured on KITTI odometry *streams*, not
isolated frame pairs; this module is the streaming subsystem that turns
per-frame registration into a trajectory:

  * **scan-to-map** — each incoming scan registers against the rolling
    local submap (``repro.data.submap``) instead of the previous scan, so
    per-frame error stops compounding into a random walk: the map is the
    common anchor, and revisited structure refines it.
  * **constant-velocity warm start** — the motion model predicts each
    frame's pose from the last two (``T_pred = T_k @ (T_{k-1}^{-1} T_k)``)
    and feeds it through ``initial_transform``, cutting iterations on
    smooth motion and keeping the basin of attraction centred under fast
    motion.
  * **degeneracy guard** — a frame whose registration comes back
    ``degenerate`` (zero-inlier freeze, ``core.icp``) or under
    ``min_inlier_frac`` is *rejected*: the pose falls back to the motion
    model's prediction and the scan is NOT fused into the map, so one bad
    frame cannot poison the anchor every later frame registers against.

Per-frame diagnostics (iterations, inlier fraction, map occupancy,
accept/reject) are first-class outputs — a stream you cannot observe is a
stream you cannot trust.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.engine import get_engine
from repro.core.icp import ICPParams
from repro.core.transform import transform_points
from repro.data.submap import Submap, SubmapParams
from repro.data.voxelize import voxel_downsample


class OdometryConfig(NamedTuple):
    """Pipeline configuration. ``params.max_iterations`` is the per-frame
    iteration cap (the paper's 50 is generous for warm-started streaming;
    30 keeps worst-case latency bounded). ``scan_voxel``/``scan_budget``
    shape the voxel-downsampled registration source — the same cloud that
    is fused into the map on acceptance.

    Size ``scan_budget`` ABOVE the scan's occupied-voxel count:
    ``voxel_downsample`` drops overflow cells deterministically from the
    cell-id sort tail, which is a *spatially biased* truncation (the +x
    end of the scene vanishes first) — poison for odometry. Same for
    ``submap.capacity`` vs the eviction ball (watch ``map_occupancy``).
    """

    # Pyramid engine, polish-only: the finest-level grid NN gives O(27K)
    # correspondence against the resident map AND gates scan points whose
    # map neighbourhood is empty (the frontier a moving ego constantly
    # creates) through the honest d2=inf path instead of dragging the pose
    # toward the map boundary.
    engine: str = "pyramid"
    engine_kwargs: tuple = (("levels", ()),)
    # Huber by default: residual frontier points that DO land within one
    # grid cell of mapped space still pull backward; huber bounds that pull
    # (a redescending kernel or a tight gate instead lets the ground plane
    # slide on cold starts — see DESIGN.md §10).
    params: ICPParams = ICPParams(max_iterations=30,
                                  max_correspondence_distance=1.0,
                                  transformation_epsilon=1e-5,
                                  robust_kernel="huber", robust_scale=0.3)
    submap: SubmapParams = SubmapParams(voxel_size=0.75, capacity=24576,
                                        dims=(128, 128, 32),
                                        evict_radius=40.0)
    scan_voxel: float = 0.75
    scan_budget: int = 8192
    motion_model: bool = True
    min_inlier_frac: float = 0.2


class FrameDiagnostics(NamedTuple):
    frame: int
    iterations: int
    inlier_frac: float
    rmse: float
    degenerate: bool
    accepted: bool          # False: pose fell back to the motion model
    map_occupancy: float    # submap capacity in use after this frame


class OdometryPipeline:
    """Stateful scan-to-map odometry: feed sensor-frame scans in order,
    read back poses (sensor -> frame-0/map) and per-frame diagnostics.

        pipe = OdometryPipeline(OdometryConfig(engine="xla"))
        for scan in scans:                       # (N_k, 3) numpy, any N_k
            pose, diag = pipe.process(scan)

    All heavy work runs through the shared engine layer: the submap's
    static capacity means every frame after the first hits one compiled
    executable (one shape, one ``ICPParams``), and the warm start is
    threaded through the engine's ``initial_transform`` argument.
    """

    def __init__(self, config: OdometryConfig = OdometryConfig()):
        self.config = config
        kwargs = dict(config.engine_kwargs)
        if config.engine != "pyramid":
            # the default engine_kwargs select the pyramid's polish-only
            # schedule; they don't apply to other engine constructors
            kwargs.pop("levels", None)
        self.engine = get_engine(config.engine, **kwargs)
        self.submap = Submap(config.submap)
        self.poses: list[np.ndarray] = []
        self.diagnostics: list[FrameDiagnostics] = []

    # -- motion model ------------------------------------------------------
    def _predict(self) -> np.ndarray:
        """Constant-velocity pose prediction for the incoming frame."""
        if len(self.poses) < 2 or not self.config.motion_model:
            return self.poses[-1]
        prev, last = self.poses[-2], self.poses[-1]
        return last @ np.linalg.inv(prev) @ last

    # -- streaming API -----------------------------------------------------
    def process(self, scan) -> tuple[np.ndarray, FrameDiagnostics]:
        """Ingest one sensor-frame scan; returns (pose, diagnostics)."""
        cfg = self.config
        src, sv = voxel_downsample(jnp.asarray(scan, jnp.float32),
                                   cfg.scan_voxel,
                                   max_points=cfg.scan_budget)
        frame = len(self.poses)
        if frame == 0:
            pose = np.eye(4, dtype=np.float32)
            self.submap.insert(src, center=np.zeros(3, np.float32), valid=sv)
            diag = FrameDiagnostics(frame=0, iterations=0, inlier_frac=1.0,
                                    rmse=0.0, degenerate=False, accepted=True,
                                    map_occupancy=self.submap.occupancy())
        else:
            T0 = self._predict()
            map_pts, map_valid = self.submap.target()
            res = self.engine.register(src, map_pts, cfg.params,
                                       initial_transform=T0,
                                       src_valid=sv, dst_valid=map_valid)
            degenerate = bool(res.degenerate)
            inlier_frac = float(res.inlier_frac)
            accepted = (not degenerate
                        and inlier_frac >= cfg.min_inlier_frac)
            pose = (np.asarray(res.T, np.float32) if accepted
                    else np.asarray(T0, np.float32))
            if accepted:
                self.submap.insert(transform_points(jnp.asarray(pose), src),
                                   center=pose[:3, 3], valid=sv)
            diag = FrameDiagnostics(frame=frame,
                                    iterations=int(res.iterations),
                                    inlier_frac=inlier_frac,
                                    rmse=float(res.rmse),
                                    degenerate=degenerate,
                                    accepted=accepted,
                                    map_occupancy=self.submap.occupancy())
        self.poses.append(pose)
        self.diagnostics.append(diag)
        return pose, diag

    def run(self, scans) -> tuple[np.ndarray, list[FrameDiagnostics]]:
        """Process a whole sequence; returns ((F,4,4) poses, diagnostics)."""
        for scan in scans:
            self.process(scan)
        return np.stack(self.poses), list(self.diagnostics)

    # -- stream-level summaries -------------------------------------------
    def mean_iterations(self) -> float:
        """Mean ICP iterations over registered frames (frame 0 excluded)."""
        its = [d.iterations for d in self.diagnostics if d.frame > 0]
        return float(np.mean(its)) if its else 0.0

    def rejected_frames(self) -> int:
        return sum(1 for d in self.diagnostics if not d.accepted)
