"""Grid-bucketed nearest-neighbour search (DESIGN.md §8).

The brute-force sweep (``repro.core.nn_search``) scans all M target points
per query; this module scans only the **27-neighbourhood** of the query's
voxel — a bounded candidate set gathered through the counting-sort tables
of :class:`repro.data.voxelize.VoxelGrid`. With ``K = max_per_cell`` the
per-query cost drops from O(M) to O(27·K), and everything stays
static-shape/dense so it vectorizes exactly like the brute sweep.

Exactness contract (the one the tests pin down):

  * If the query's true nearest neighbour lies within ``voxel_size`` of it
    and its cell did not overflow ``max_per_cell``, grid NN returns the
    *identical* (d2, idx) as the exact searcher: a point within one voxel
    length is necessarily inside the 3x3x3 neighbourhood.
  * In ICP terms: choose ``voxel_size >= max_correspondence_distance`` and
    every correspondence that can pass the gate is found exactly; pairs the
    grid misses are pairs the gate would reject anyway, so they carry zero
    Kabsch weight either way.
  * Overflowing cells truncate to their first ``max_per_cell`` points (in
    stable original order) — the returned neighbour is then still inside
    the same cell, i.e. within one cell diagonal of the true one.

Queries with an *empty* neighbourhood get ``d2 = +inf`` (gated out of ICP),
or — with ``exact_fallback=True`` — a brute-force answer computed lazily
via ``lax.cond`` only when at least one such row exists. Queries *outside*
the ``dims`` lattice resolve through the same path: their cell coords are
kept unclipped (``cell_coords(..., clip=False)``), so only lattice cells
their neighbourhood window genuinely overlaps contribute candidates — a
query more than ``rings`` cells past the lattice edge reports an empty
hood (counted by ``GridQueryStats.out_of_lattice``) instead of being
silently matched against boundary-cell residents. The fallback is
meant for standalone/query use; inside vmapped ICP both branches of a cond
execute, so the pyramid engine relies on the gate semantics instead.

Distances are computed directly as ``sum((p - q)²)`` — the candidate tile
is too narrow for the matmul expansion to pay off, and the direct form is
exact (no cancellation), so no epilogue recompute is needed.
"""
from __future__ import annotations

import functools
import itertools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.data.voxelize import VoxelGrid, cell_coords, linear_cell_ids


class GridQueryStats(NamedTuple):
    """Diagnostics of one candidate-gather pass (all scalars, jittable).

    ``overflow_frac``: fraction of queries with at least one in-bounds
    neighbour cell truncated by ``max_per_cell`` — the silent-drop case the
    exactness contract documents. ``empty_frac``: fraction of queries with
    an empty neighbourhood (the rows that come back ``d2 = inf``).
    ``dropped_frac``: truncated candidates as a fraction of all candidates
    the neighbourhoods actually hold — how much of the scene the sweep
    never saw. ``out_of_lattice``: fraction of queries whose own cell lies
    outside the ``dims`` lattice entirely — the moving-ego failure mode
    (ISSUE 5): such rows used to clip into boundary cells and return
    confidently-wrong neighbours; they now resolve to the empty-hood path
    and this counter makes the miss observable per frame.
    """

    overflow_frac: jax.Array
    empty_frac: jax.Array
    dropped_frac: jax.Array
    out_of_lattice: jax.Array


@functools.lru_cache(maxsize=None)
def _neighbor_offsets(rings: int) -> tuple:
    """Static (2r+1)³ neighbourhood offsets; rings=1 is the 27-cell case."""
    span = range(-rings, rings + 1)
    return tuple(itertools.product(span, span, span))

# Far-but-finite coordinate for masked candidate slots: d2 ~ 1e30 stays
# inside fp32 and never wins against any metric-scale candidate (same
# reasoning as the collate/nn_search sentinels — no inf, no NaN path).
_MASK_COORD = 1.0e15


def gather_candidates(src: jax.Array, grid: VoxelGrid, max_per_cell: int,
                      rings: int = 1):
    """Gather each query's (2·rings+1)³-neighbourhood candidate set.

    Returns ``(cand_pts, cand_idx, cand_valid)`` with shapes
    ((N, C*K, 3), (N, C*K), (N, C*K)) for C = (2·rings+1)³; masked slots
    carry far-sentinel coordinates so consumers may skip the mask in the
    distance argmin. ``cand_idx`` is in the *original* target ordering.
    ``rings`` trades cell occupancy against neighbourhood width: the
    guaranteed-exact radius is ``rings * voxel_size``, so rings=2 with a
    half-size voxel covers the same radius with ~4x fewer points per cell
    (useful against ``max_per_cell`` overflow on dense surfaces).
    """
    dims = grid.dims
    # clip=False: a query outside the lattice keeps its true out-of-range
    # cell, so its neighbourhood window only picks up lattice cells it
    # *geometrically* overlaps (none, once it is > rings cells away). The
    # old clipped coords teleported far queries into boundary cells and
    # returned their residents as confident neighbours.
    icq = cell_coords(src, grid.origin, grid.voxel_size, dims,
                      clip=False)                                # (N, 3)
    off = jnp.asarray(_neighbor_offsets(rings), jnp.int32)       # (C, 3)
    nbr = icq[:, None, :] + off[None]                            # (N, 27, 3)
    in_bounds = jnp.all(
        (nbr >= 0) & (nbr < jnp.asarray(dims, jnp.int32)), axis=-1)
    cid = linear_cell_ids(jnp.clip(nbr, 0, jnp.asarray(dims, jnp.int32) - 1),
                          dims)                                  # (N, 27)
    start = grid.start[cid]
    cnt = jnp.where(in_bounds, jnp.minimum(grid.count[cid], max_per_cell), 0)
    k = jnp.arange(max_per_cell, dtype=jnp.int32)
    pos = start[..., None] + k                                   # (N, 27, K)
    cand_valid = k < cnt[..., None]
    pos = jnp.where(cand_valid, pos, 0)
    n = src.shape[0]
    ck = off.shape[0] * max_per_cell
    pos = pos.reshape(n, ck)
    cand_valid = cand_valid.reshape(n, ck)
    cand_pts = jnp.where(cand_valid[..., None], grid.points[pos],
                         jnp.asarray(_MASK_COORD, jnp.float32))
    cand_idx = grid.point_ids[pos]
    return cand_pts, cand_idx, cand_valid


def neighborhood_stats(src: jax.Array, grid: VoxelGrid,
                       max_per_cell: int = 32,
                       rings: int = 1) -> GridQueryStats:
    """Quantify what :func:`gather_candidates` would drop for these queries.

    Pure table lookups on the grid's per-cell counts — no candidate gather,
    so it is cheap enough to run per frame as a quality signal (the pyramid
    engine exposes it as :meth:`~repro.core.pyramid.PyramidEngine.polish_stats`).
    """
    dims = grid.dims
    dims_arr = jnp.asarray(dims, jnp.int32)
    icq = cell_coords(src, grid.origin, grid.voxel_size, dims, clip=False)
    off = jnp.asarray(_neighbor_offsets(rings), jnp.int32)
    nbr = icq[:, None, :] + off[None]
    in_bounds = jnp.all((nbr >= 0) & (nbr < dims_arr), axis=-1)
    cid = linear_cell_ids(jnp.clip(nbr, 0, dims_arr - 1), dims)
    cnt = jnp.where(in_bounds, grid.count[cid], 0)               # (N, C)
    kept = jnp.minimum(cnt, max_per_cell)
    dropped = jnp.sum(cnt - kept, axis=1).astype(jnp.float32)    # (N,)
    total = jnp.sum(cnt, axis=1).astype(jnp.float32)
    n = jnp.asarray(src.shape[0], jnp.float32)
    in_lattice = jnp.all((icq >= 0) & (icq < dims_arr), axis=-1)
    return GridQueryStats(
        overflow_frac=jnp.sum(jnp.any(cnt > max_per_cell, axis=1)) / n,
        empty_frac=jnp.sum(jnp.sum(kept, axis=1) == 0) / n,
        dropped_frac=jnp.sum(dropped) / jnp.maximum(jnp.sum(total), 1.0),
        out_of_lattice=jnp.sum(jnp.logical_not(in_lattice)) / n)


def nn_search_grid(src: jax.Array, grid: VoxelGrid, *,
                   max_per_cell: int = 32,
                   rings: int = 1,
                   exact_fallback: bool = False,
                   dst: jax.Array | None = None,
                   dst_valid: jax.Array | None = None,
                   chunk: int = 2048,
                   return_points: bool = False,
                   with_stats: bool = False):
    """NN of each src point among its grid neighbourhood candidates.

    Args:
      src: (N, 3) queries.
      grid: the target's :func:`build_voxel_grid` result (built once per
        frame — the spatial analogue of the Pallas resident target).
      max_per_cell: static per-cell candidate capacity (K). C*K is the
        whole per-query sweep (C = 27 for rings=1).
      rings: neighbourhood half-width in cells; exact radius is
        ``rings * voxel_size`` (see :func:`gather_candidates`).
      exact_fallback: brute-force rows whose neighbourhood is empty (needs
        ``dst``; runs under ``lax.cond`` so the full sweep only executes
        when such a row exists).
      dst / dst_valid / chunk: fallback inputs, matching ``nn_search``.
      return_points: additionally return the matched points (fused winner
        gather — see ``core.icp._default_correspond_fn``).
      with_stats: additionally return a :class:`GridQueryStats` — the
        overflow/empty/dropped diagnostics that were previously invisible
        (inf rows and truncated cells fail silently otherwise).

    Returns:
      (d2, idx[, matched][, stats]): exact squared distances (``+inf`` for
      empty neighbourhoods without fallback), int32 indices into the
      original target ordering, optionally the (N, 3) matched points, and
      optionally the gather diagnostics.
    """
    cand_pts, cand_idx, cand_valid = gather_candidates(src, grid,
                                                       max_per_cell, rings)
    srcf = src.astype(jnp.float32)
    diff = srcf[:, None, :] - cand_pts
    d2 = jnp.sum(diff * diff, axis=-1)                           # (N, 27K)
    slot = jnp.argmin(d2, axis=1)
    rows = jnp.arange(src.shape[0])
    best_d2 = d2[rows, slot]
    best_idx = cand_idx[rows, slot]
    matched = cand_pts[rows, slot]
    has_cand = jnp.any(cand_valid, axis=1)
    best_d2 = jnp.where(has_cand, best_d2, jnp.inf)
    best_idx = jnp.where(has_cand, best_idx, 0)

    if exact_fallback:
        if dst is None:
            raise ValueError("exact_fallback=True requires dst")
        from repro.core.nn_search import nn_search

        def brute(_):
            d2_b, idx_b, pts_b = nn_search(srcf, dst, chunk=chunk,
                                           dst_valid=dst_valid,
                                           return_points=True)
            # both cond branches must agree on dtype; the grid path's
            # candidate points are always f32
            return d2_b, idx_b, pts_b.astype(jnp.float32)

        def keep(_):
            return best_d2, best_idx, matched

        fb_d2, fb_idx, fb_pts = jax.lax.cond(
            jnp.any(~has_cand), brute, keep, operand=None)
        best_d2 = jnp.where(has_cand, best_d2, fb_d2)
        best_idx = jnp.where(has_cand, best_idx, fb_idx)
        matched = jnp.where(has_cand[:, None], matched, fb_pts)

    out = [jnp.maximum(best_d2, 0.0), best_idx]
    if return_points:
        out.append(matched)
    if with_stats:
        out.append(neighborhood_stats(src, grid, max_per_cell, rings))
    return tuple(out)


def grid_nn_fn(grid: VoxelGrid, *, max_per_cell: int = 32, rings: int = 1):
    """Resident-grid searcher with the ``core.icp`` ``nn_fn`` contract.

    Like ``kernels.ops.resident_nn_fn``, the expensive per-frame structure
    (here: the voxel grid) is closed over at trace scope, outside the ICP
    iteration loop; the returned closure ignores its second argument. It
    returns the fused 3-tuple so the hot loop does a single winner gather.
    """

    def nn_fn(src, _target=None):
        return nn_search_grid(src, grid, max_per_cell=max_per_cell,
                              rings=rings, return_points=True)

    return nn_fn
