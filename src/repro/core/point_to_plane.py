"""Point-to-plane transformation estimation with robust reweighting.

The Kabsch step (``core.transform.estimate_rigid_transform``) minimises the
point-to-*point* error — the FPPS paper's variant. On the structured scenes
LiDAR actually produces (ground planes, facades), the registration
literature's workhorse is the point-to-*plane* error

    E(T) = Σ w_i ( n_iᵀ (T p_i − q_i) )²

which lets correspondences slide along their local surface instead of
pinning them to a sampled point — typically several-fold fewer iterations
on planar-dominant scenes (DESIGN.md §9; validated by
``benchmarks/convergence.py``).

There is no closed-form SVD solution for E, so we take the standard single
Gauss-Newton step per ICP iteration under the small-angle parameterisation
``R ≈ I + [ω]×``: with the 6-vector ``x = (ω, t)`` and the per-pair
Jacobian row ``a_i = [p_i × n_i ; n_i]`` the normal equations are

    (Σ w_i a_i a_iᵀ) x = − Σ w_i r_i a_i,       r_i = n_iᵀ (p_i − q_i)

a 6×6 solve (``jnp.linalg.solve`` — tiny, deterministic, fully inside the
fused ICP iteration). The step is exponentiated exactly (Rodrigues on ω) so
the returned delta is a proper rigid transform at any step size.

Robust reweighting: IRLS weights from the per-pair residual, applied *on
top of* the max-correspondence-distance gate. ``huber`` downweights the
tail linearly, ``tukey`` rejects it entirely (redescending) — the classic
trade: huber keeps gross-outlier bias bounded, tukey removes it but needs a
sane initialisation. Both operate on whichever residual the active
minimiser actually optimises (euclidean distance for point-to-point, plane
distance for point-to-plane).

Everything is pure JAX, shape-static, and (like the Kabsch path) runs
unchanged under jit / vmap / shard_map.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import transform as tf

ROBUST_KERNELS = ("none", "huber", "tukey")


def robust_weights(residual: jax.Array, kind: str,
                   scale: float) -> jax.Array:
    """IRLS weight per residual. ``residual`` is the *unsigned* per-pair
    error in metres; ``scale`` is the kernel's tuning constant (huber's
    delta / tukey's cutoff c).

      none:  w = 1
      huber: w = min(1, scale / |r|)          (linear tail)
      tukey: w = (1 - (r/scale)²)² for |r|<scale, else 0  (redescending)
    """
    if kind == "none":
        return jnp.ones_like(residual)
    r = jnp.abs(residual)
    s = jnp.asarray(scale, residual.dtype)
    if kind == "huber":
        return jnp.minimum(1.0, s / jnp.maximum(r, 1e-12))
    if kind == "tukey":
        u = r / jnp.maximum(s, 1e-12)
        w = (1.0 - u * u) ** 2
        return jnp.where(u < 1.0, w, 0.0)
    raise ValueError(
        f"unknown robust kernel {kind!r}; expected one of {ROBUST_KERNELS}")


def solve_normal_equations(A: jax.Array, b: jax.Array,
                           damping: float = 1e-6) -> jax.Array:
    """Damped 6x6 Gauss-Newton solve + exact exponentiation — the shared
    epilogue of the XLA path (:func:`solve_point_to_plane`) and the fused
    kernel's pre-accumulated ``(A, b)`` moments (DESIGN.md §11).

    ``A = Σ w a aᵀ`` and ``b = −Σ w r a`` with ``a = [p×n; n]``; the
    damping is Levenberg-style, scaled by mean(diag(A)) so it is
    unit-consistent across the rotation and translation blocks. Returns
    the (4,4) incremental rigid transform (fp32).
    """
    A = A.astype(jnp.float32)
    b = b.astype(jnp.float32)
    lam = damping * jnp.maximum(jnp.trace(A) / 6.0, 1e-12)
    x = jnp.linalg.solve(A + lam * jnp.eye(6, dtype=A.dtype), b)
    omega, t = x[:3], x[3:]
    angle = jnp.linalg.norm(omega)
    R = tf.rotation_from_axis_angle(omega, angle)
    return tf.make_transform(R, t)


def solve_point_to_plane(src: jax.Array, dst: jax.Array,
                         normals: jax.Array,
                         weights: jax.Array | None = None,
                         damping: float = 1e-6) -> jax.Array:
    """One Gauss-Newton step of the point-to-plane objective.

    Args:
      src: (N, 3) source points already carrying the cumulative transform
        (the step is computed about the identity, like the Kabsch path).
      dst: (N, 3) matched target points (dst[i] is src[i]'s NN).
      normals: (N, 3) unit normals at the matched target points. Zero rows
        (invalid normals) contribute nothing — their Jacobian row is zero.
      weights: (N,) gate/robust weights; None means all-ones.
      damping: Levenberg-style diagonal damping, scaled by the mean of
        diag(A) so it is unit-consistent across the rotation and
        translation blocks.

    Returns:
      (4, 4) incremental rigid transform.
    """
    if weights is None:
        weights = jnp.ones(src.shape[:-1], dtype=src.dtype)
    w = weights.astype(jnp.float32)
    p = src.astype(jnp.float32)
    q = dst.astype(jnp.float32)
    n = normals.astype(jnp.float32)
    r = jnp.sum(n * (p - q), axis=-1)                       # (N,)
    a = jnp.concatenate([jnp.cross(p, n), n], axis=-1)      # (N, 6)
    aw = a * w[:, None]
    A = aw.T @ a                                            # (6, 6) MXU
    b = -(aw.T @ r)                                         # (6,)
    return solve_normal_equations(A, b, damping).astype(src.dtype)


def point_to_plane_rmse(src: jax.Array, dst: jax.Array, normals: jax.Array,
                        weights: jax.Array | None = None) -> jax.Array:
    """Weighted RMS of the plane residual n·(p − q) (diagnostic metric)."""
    r = jnp.sum(normals * (src - dst), axis=-1)
    if weights is None:
        return jnp.sqrt(jnp.mean(r * r))
    w = weights.astype(src.dtype)
    return jnp.sqrt(jnp.sum(r * r * w) / jnp.maximum(jnp.sum(w), 1e-12))
