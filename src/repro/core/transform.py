"""SE(3) rigid-transform utilities and Kabsch transform estimation.

Implements the math of FPPS §II: the rigid transform ``T = [[R, t], [0, 1]]``,
its composition/application, and the SVD-based transformation-estimation step
(paper step 2: minimise ``E(R,t) = Σ ||q_i - (R p_i + t)||²``).

Everything here is pure JAX (jit/vmap/scan friendly) and runs identically on
CPU/TPU. The 3×3 SVD uses the custom-call-free Jacobi routine in
``svd3x3.py`` so the whole ICP iteration is a single fused XLA computation
with deterministic latency — the TPU analogue of the paper's dedicated
hardware SVD path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.svd3x3 import svd3x3


def make_transform(R: jax.Array, t: jax.Array) -> jax.Array:
    """Build a 4x4 homogeneous transform from rotation R (3,3), translation
    t (3,)."""
    T = jnp.eye(4, dtype=R.dtype)
    T = T.at[:3, :3].set(R)
    T = T.at[:3, 3].set(t.reshape(3))
    return T


def transform_points(T: jax.Array, points: jax.Array) -> jax.Array:
    """Apply homogeneous transform T (4,4) to points (..., 3).

    This is the paper's "point cloud transformer" stage. Implemented as a
    single matmul so XLA maps it to the MXU.
    """
    R = T[:3, :3]
    t = T[:3, 3]
    return points @ R.T + t


def rotation_from_axis_angle(axis: jax.Array, angle: jax.Array) -> jax.Array:
    """Rodrigues' formula. axis (3,) need not be normalised."""
    axis = axis / (jnp.linalg.norm(axis) + 1e-12)
    kx, ky, kz = axis[0], axis[1], axis[2]
    K = jnp.array([[0.0, -kz, ky], [kz, 0.0, -kx], [-ky, kx, 0.0]], dtype=axis.dtype)
    eye = jnp.eye(3, dtype=axis.dtype)
    return eye + jnp.sin(angle) * K + (1.0 - jnp.cos(angle)) * (K @ K)


def random_rigid_transform(key: jax.Array, max_angle: float = 0.5,
                           max_translation: float = 1.0,
                           dtype=jnp.float32) -> jax.Array:
    """Sample a random SE(3) transform (for tests / synthetic data)."""
    k1, k2, k3 = jax.random.split(key, 3)
    axis = jax.random.normal(k1, (3,), dtype=dtype)
    angle = jax.random.uniform(k2, (), dtype=dtype, minval=-max_angle, maxval=max_angle)
    t = jax.random.uniform(k3, (3,), dtype=dtype, minval=-max_translation,
                           maxval=max_translation)
    return make_transform(rotation_from_axis_angle(axis, angle), t)


def estimate_rigid_transform(src: jax.Array, dst: jax.Array,
                             weights: jax.Array | None = None) -> jax.Array:
    """Weighted Kabsch: the rigid T minimising Σ w_i ||dst_i - (R src_i + t)||².

    ``src``/``dst`` are (N, 3) corresponding points (dst[i] is the NN of
    src[i] found by the searcher); ``weights`` (N,) masks out
    correspondences rejected by max_correspondence_distance — this is the
    paper's outlier filter folded into the accumulator.

    This is the "result accumulator" + SVD stage: the cross-covariance is a
    (3,N)@(N,3) matmul (MXU work), the SVD is 3×3 Jacobi (VPU work).
    """
    if weights is None:
        weights = jnp.ones(src.shape[:-1], dtype=src.dtype)
    w = weights.astype(src.dtype)
    wsum = jnp.maximum(jnp.sum(w), 1e-12)
    src_mean = jnp.sum(src * w[..., None], axis=0) / wsum
    dst_mean = jnp.sum(dst * w[..., None], axis=0) / wsum
    src_c = src - src_mean
    dst_c = dst - dst_mean
    # Cross-covariance H = Σ w_i src_c_i dst_c_iᵀ  — a (3,N)x(N,3) matmul.
    H = (src_c * w[..., None]).T @ dst_c
    U, _, Vt = svd3x3(H)
    # Proper rotation: flip the axis with the smallest singular value if det<0.
    det = jnp.linalg.det(Vt.T @ U.T)
    D = jnp.diag(jnp.array([1.0, 1.0, 1.0], dtype=src.dtype)).at[2, 2].set(det)
    R = Vt.T @ D @ U.T
    t = dst_mean - R @ src_mean
    return make_transform(R, t)


def estimate_from_covariance(H: jax.Array, src_mean: jax.Array,
                             dst_mean: jax.Array) -> jax.Array:
    """Kabsch from a pre-accumulated cross-covariance (distributed path).

    In the sharded ICP the per-device partial sums of H / means are psum'd
    first (tiny 3x3 + 3-vector collectives), then every device runs this
    replicated epilogue.
    """
    U, _, Vt = svd3x3(H)
    det = jnp.linalg.det(Vt.T @ U.T)
    D = jnp.diag(jnp.array([1.0, 1.0, 1.0], dtype=H.dtype)).at[2, 2].set(det)
    R = Vt.T @ D @ U.T
    t = dst_mean - R @ src_mean
    return make_transform(R, t)


def estimate_from_moments(sw: jax.Array, sp: jax.Array, sq: jax.Array,
                          spq: jax.Array) -> jax.Array:
    """Weighted Kabsch from *raw* (uncentred) moment sums — the fused
    kernel's epilogue (DESIGN.md §11).

    With sw = Σw, sp = Σw·p, sq = Σw·q and spq = Σw·p⊗q, the centred
    cross-covariance is ``H = spq − sp⊗sq / sw`` and the centroids are
    ``sp/sw``, ``sq/sw`` — after which this is exactly
    :func:`estimate_from_covariance`. The subtraction happens on O(1)
    scalars, so the only accumulation error is the kernel's fp32 plane
    sums (same magnitude as the unfused (3,N)@(N,3) matmul).
    """
    wsum = jnp.maximum(sw, 1e-12)
    p_mean = sp / wsum
    q_mean = sq / wsum
    H = spq - jnp.outer(sp, sq) / wsum
    return estimate_from_covariance(H, p_mean, q_mean)


def transform_delta(T: jax.Array) -> jax.Array:
    """Scalar 'how far from identity' metric used for the convergence check.

    Matches PCL's transformationEpsilon semantics: squared norm of the
    incremental transform's deviation from identity (rotation part measured
    by ||R - I||_F², translation by ||t||²).
    """
    R = T[:3, :3]
    t = T[:3, 3]
    return jnp.sum((R - jnp.eye(3, dtype=T.dtype)) ** 2) + jnp.sum(t ** 2)


def rmse(src: jax.Array, dst: jax.Array, weights: jax.Array | None = None) -> jax.Array:
    """Root mean square correspondence error (paper Table III metric)."""
    d2 = jnp.sum((src - dst) ** 2, axis=-1)
    if weights is None:
        return jnp.sqrt(jnp.mean(d2))
    w = weights.astype(src.dtype)
    return jnp.sqrt(jnp.sum(d2 * w) / jnp.maximum(jnp.sum(w), 1e-12))


def rmse_from_moments(T_delta: jax.Array, sw: jax.Array, sp: jax.Array,
                      sq: jax.Array, spq: jax.Array, spp: jax.Array,
                      sqq: jax.Array) -> jax.Array:
    """Post-step weighted RMSE from the fused kernel's moment sums.

    Expands Σw‖Rp + t − q‖² algebraically so the per-point residual never
    has to be materialised:

        Σw‖Rp+t−q‖² = spp + sqq + sw‖t‖² + 2 t·(R sp) − 2 tr(R spq)
                      − 2 t·sq

    (using Σw qᵀRp = tr(R · spq) with spq[i,j] = Σw p_i q_j). Matches
    :func:`rmse` of the transformed pairs to fp32 accumulation tolerance.
    """
    R = T_delta[:3, :3].astype(jnp.float32)
    t = T_delta[:3, 3].astype(jnp.float32)
    total = (spp + sqq + sw * jnp.dot(t, t)
             + 2.0 * jnp.dot(t, R @ sp)
             - 2.0 * jnp.trace(R @ spq)
             - 2.0 * jnp.dot(t, sq))
    return jnp.sqrt(jnp.maximum(total, 0.0) / jnp.maximum(sw, 1e-12))
