"""Coarse-to-fine ICP pyramid over voxel-downsampled clouds (DESIGN.md §8).

The brute-force engines spend every one of their (up to) 50 iterations on
the full O(N·M) sweep, even the early ones that only need a rough gradient
direction. The pyramid splits the schedule:

  * **coarse levels** — both clouds are voxel-downsampled to centroids
    (``repro.data.voxelize.voxel_downsample``) and a few cheap iterations
    run brute force on the tiny clouds, with the correspondence gate
    widened proportionally to the voxel size. Large initial misalignments
    (a scenario class plain ICP handles poorly — its basin of attraction
    is roughly one gate radius) converge here for a fraction of a full
    sweep's cost.
  * **finest level** — full-resolution polish where the O(M) sweep is
    replaced by grid-bucketed NN (``repro.core.nn_search_grid``): the
    voxel grid is built once per frame at trace scope — the spatial
    analogue of the Pallas engine's resident augmented target — and each
    iteration gathers only 27-neighbourhood candidates. With
    ``grid_voxel >= max_correspondence_distance`` every gate-passing
    correspondence is found exactly, so the fixed point matches brute
    force (validated in ``benchmarks/nn_sweep.py``).

Exposed both as :func:`icp_pyramid` (drop-in next to ``core.icp.icp``) and
as the ``"pyramid"`` entry in the engine registry, so drivers opt in with
``get_engine("pyramid")`` / ``FppsICP(engine="pyramid")``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import RegistrationEngine, register_engine
from repro.core.icp import (ICPParams, ICPResult, icp, icp_fixed_iterations,
                            scrub_nonfinite)
from repro.core.nn_search_grid import (GridQueryStats, grid_nn_fn,
                                       neighborhood_stats)
from repro.data.voxelize import build_voxel_grid, voxel_downsample

# Coarse schedule entries: (voxel_size_m, iterations[, max_points]).
# Default: ONE coarse pass — 4 m centroids, capped at 8192 points — then
# the full-resolution grid polish. Frame-to-frame motion needs nothing
# coarser (and coarse iterations are pure overhead once the finest level
# is grid-accelerated); large-misalignment workloads should widen the
# schedule, e.g. ((8.0, 8, 4096), (4.0, 8, 8192)). max_points is the
# static downsample capacity (clamped to the cloud size at trace time).
DEFAULT_LEVELS: tuple = ((4.0, 6, 8192),)

# Finest-level voxel-grid lattice: 128 m x 128 m x 32 m at 1 m cells covers
# the synthetic KITTI protocol's range-gated frames; anchored per-cloud.
DEFAULT_GRID_DIMS: tuple[int, int, int] = (128, 128, 32)


def _norm_level(level, cloud_size: int):
    """(voxel, iters[, max_points]) -> (voxel, iters, capacity<=cloud)."""
    if len(level) == 2:
        voxel, iters = level
        cap = cloud_size
    else:
        voxel, iters, cap = level
    return float(voxel), int(iters), min(int(cap), cloud_size)


def icp_pyramid(source: jax.Array, target: jax.Array,
                params: ICPParams = ICPParams(), *,
                levels: tuple = DEFAULT_LEVELS,
                grid_dims: tuple[int, int, int] = DEFAULT_GRID_DIMS,
                grid_voxel: float | None = None,
                max_per_cell: int = 32,
                rings: int = 1,
                initial_transform: jax.Array | None = None,
                src_valid: jax.Array | None = None,
                dst_valid: jax.Array | None = None,
                fixed: bool = False,
                use_kernel: bool = False,
                interpret: bool = False) -> ICPResult:
    """Coarse-to-fine ICP: ``levels`` coarse passes, then a full-resolution
    grid-NN polish of ``params.max_iterations`` iterations.

    Each coarse level voxel-downsamples *both* clouds, widens the gate to
    ``max(gate, 1.5 * voxel)`` (centroids sit up to half a cell diagonal
    from the surface they summarise), and warm-starts the next level with
    its cumulative transform. ``fixed=True`` selects the scan-based finest
    loop (for vmap/batching); ``use_kernel=True`` routes the finest-level
    candidate sweep through the Pallas kernel
    (``repro.kernels.nn_search_grid``), interpretable off-TPU.

    Returns the finest level's :class:`ICPResult` (its iteration count and
    rmse describe the polish stage, like the engines' results describe
    their single loop).
    """
    n, m = source.shape[0], target.shape[0]
    # Scrub before the coarse downsamples and the polish grid build: a
    # single NaN row would poison the lattice origin (min over a NaN is
    # NaN) and every centroid its cell touches.
    source, src_valid = scrub_nonfinite(source, src_valid)
    target, dst_valid = scrub_nonfinite(target, dst_valid)
    T = (jnp.eye(4, dtype=source.dtype) if initial_transform is None
         else initial_transform)

    for level in levels:
        voxel, iters, cap = _norm_level(level, m)
        src_l, sv_l = voxel_downsample(source, voxel,
                                       max_points=min(cap, n),
                                       valid=src_valid)
        dst_l, dv_l = voxel_downsample(target, voxel, max_points=cap,
                                       valid=dst_valid)
        # Coarse levels stay point-to-point, no robust reweighting: voxel
        # centroids don't lie on the surfaces they summarise, so plane
        # residuals (and fine-scale robust scales) are meaningless there —
        # the coarse job is a cheap basin capture, the polish does quality.
        # (fused=False too: the tiny downsampled clouds would waste the
        # fused kernel's grid build; only the full-resolution polish fuses.)
        p_l = params._replace(
            max_iterations=iters,
            max_correspondence_distance=max(
                params.max_correspondence_distance, 1.5 * voxel),
            minimizer="point_to_point", robust_kernel="none", fused=False)
        res = icp_fixed_iterations(src_l, dst_l, p_l, initial_transform=T,
                                   src_valid=sv_l, dst_valid=dv_l)
        T = res.T

    gv = (float(grid_voxel) if grid_voxel is not None
          else max(1.0, params.max_correspondence_distance))
    grid = build_voxel_grid(target, gv, grid_dims, valid=dst_valid)
    if params.fused:
        nn_fn = None  # the fused kernel replaces the whole polish stage
    elif use_kernel:
        from repro.kernels.nn_search_grid import grid_kernel_nn_fn
        nn_fn = grid_kernel_nn_fn(grid, max_per_cell=max_per_cell,
                                  rings=rings, interpret=interpret)
    else:
        nn_fn = grid_nn_fn(grid, max_per_cell=max_per_cell, rings=rings)

    if params.minimizer == "point_to_plane":
        # Polish goes plane: estimate target normals once at trace scope,
        # reusing the resident polish grid as the neighbourhood structure
        # (one counting-sort build serves both NN and normals).
        from repro.data.normals import NormalParams, estimate_normals
        np_l = NormalParams(voxel_size=gv, grid_dims=tuple(grid_dims),
                            max_per_cell=max_per_cell, rings=rings)
        normals, _ = estimate_normals(target, np_l, valid=dst_valid,
                                      grid=grid)
    else:
        normals = None

    runner = icp_fixed_iterations if fixed else icp
    if params.fused:
        # Fused polish: the resident grid (and the normals, for the plane
        # minimiser) feed the single-pass moment kernel directly — same
        # exactness contract as the grid searcher it replaces.
        from repro.kernels.fused_icp import make_fused_fn
        fused_fn = make_fused_fn(grid, params, normals,
                                 max_per_cell=max_per_cell, rings=rings,
                                 interpret=interpret)
        return runner(source, None, params, initial_transform=T,
                      fused_fn=fused_fn, src_valid=src_valid)

    def correspond(src_t):
        d2, idx, matched = nn_fn(src_t)
        if normals is None:
            return d2, matched
        return d2, matched, jnp.take(normals, idx, axis=0)

    return runner(source, None, params, initial_transform=T,
                  correspond_fn=correspond, src_valid=src_valid)


def polish_stats(source: jax.Array, target: jax.Array,
                 params: ICPParams = ICPParams(), *,
                 grid_dims: tuple[int, int, int] = DEFAULT_GRID_DIMS,
                 grid_voxel: float | None = None,
                 max_per_cell: int = 32, rings: int = 1,
                 dst_valid: jax.Array | None = None) -> GridQueryStats:
    """Overflow/empty diagnostics of the polish stage's candidate gather.

    The grid NN silently truncates overflowing cells and returns ``inf``
    for empty neighbourhoods (the documented exactness contract); this
    builds the exact grid the polish would use and counts both effects for
    the given source, so callers can check a scene/config before trusting
    the pyramid result — or log it per frame in production.
    """
    gv = (float(grid_voxel) if grid_voxel is not None
          else max(1.0, params.max_correspondence_distance))
    grid = build_voxel_grid(target, gv, grid_dims, valid=dst_valid)
    return neighborhood_stats(source, grid, max_per_cell, rings)


class PyramidEngine(RegistrationEngine):
    """Coarse-to-fine engine: voxel pyramid + resident-grid finest level.

    All pyramid knobs are static constructor kwargs (hashable, so named
    ``get_engine("pyramid", ...)`` instances stay shared singletons with
    persistent jit caches):

      levels:        coarse schedule, ((voxel_m, iters[, max_points]), ...)
      grid_dims:     finest-level lattice extent (cells per axis)
      grid_voxel:    finest-level cell size; None -> max(1.0, gate) so the
                     27-neighbourhood provably covers the gate radius
      max_per_cell:  candidate capacity per cell (overflow truncates)
      use_kernel:    run the finest candidate sweep as the Pallas kernel
                     (interpret mode off-TPU, like the "pallas" engine)
    """

    name = "pyramid"

    def __init__(self, chunk: int = 2048, levels: tuple = DEFAULT_LEVELS,
                 grid_dims: tuple[int, int, int] = DEFAULT_GRID_DIMS,
                 grid_voxel: float | None = None, max_per_cell: int = 32,
                 rings: int = 1, use_kernel: bool = False,
                 interpret: bool | None = None):
        super().__init__(chunk)
        self._levels = tuple(tuple(lv) for lv in levels)
        self._grid_dims = tuple(grid_dims)
        self._grid_voxel = grid_voxel
        self._max_per_cell = max_per_cell
        self._rings = rings
        self._use_kernel = use_kernel
        self._interpret = interpret

    def _interp(self) -> bool:
        from repro.kernels.common import default_interpret
        return default_interpret(self._interpret)

    def _pyramid_kwargs(self):
        return dict(levels=self._levels, grid_dims=self._grid_dims,
                    grid_voxel=self._grid_voxel,
                    max_per_cell=self._max_per_cell, rings=self._rings,
                    use_kernel=self._use_kernel, interpret=self._interp())

    def polish_stats(self, source, target,
                     params: ICPParams | None = None, *,
                     dst_valid=None) -> GridQueryStats:
        """Candidate-gather diagnostics of this engine's polish stage (see
        :func:`polish_stats`) — counts the cell-overflow drops and empty
        (inf) rows the registration itself absorbs silently."""
        params = self._default_params(params)
        return polish_stats(jnp.asarray(source, jnp.float32),
                            jnp.asarray(target, jnp.float32), params,
                            grid_dims=self._grid_dims,
                            grid_voxel=self._grid_voxel,
                            max_per_cell=self._max_per_cell,
                            rings=self._rings, dst_valid=dst_valid)

    def _build_single(self, params: ICPParams):
        kw = self._pyramid_kwargs()

        def run(src, dst, T0, sv, dv):
            self._note_trace("single", params, src.shape, dst.shape)
            return icp_pyramid(src, dst, params, initial_transform=T0,
                               src_valid=sv, dst_valid=dv, **kw)

        return jax.jit(run)

    def _build_batch(self, params: ICPParams):
        kw = self._pyramid_kwargs()

        def run(src_b, dst_b, T0, sv, dv):
            self._note_trace("batch", params, src_b.shape, dst_b.shape)
            if T0 is None:
                T0 = jnp.broadcast_to(jnp.eye(4, dtype=src_b.dtype),
                                      (src_b.shape[0], 4, 4))

            def one(src, dst, T0_, sv_, dv_):
                # fixed=True: under vmap a while_loop would run every lane
                # to the worst trip count anyway; the scan's freeze mask
                # keeps per-pair early-convergence semantics.
                return icp_pyramid(src, dst, params, initial_transform=T0_,
                                   src_valid=sv_, dst_valid=dv_,
                                   fixed=True, **kw)

            return jax.vmap(one)(src_b, dst_b, T0, sv, dv)

        return jax.jit(run)


register_engine("pyramid", PyramidEngine)
