"""Per-frame registration health: OK / SUSPECT / FAILED verdicts (§12).

A streaming registration stack has plenty of per-frame quality signals
lying around — it just never reads them together. This module distils the
signals already latent in the pipeline into one
:class:`RegistrationHealth` verdict the recovery cascade
(``repro.core.odometry``) can act on:

  * **inlier fraction** — gate+robust weight mass over valid source rows
    (``ICPResult.inlier_frac``). Collapses under occlusion, dropout and
    low overlap.
  * **final inlier RMSE** — the last iteration's weighted residual
    (``ICPResult.rmse``). A converged-but-high value means the optimiser
    stalled on a biased fixed point (ghost clusters, heavy-tailed noise);
    the per-iteration *trend* ends here, so this is the trend's endpoint.
  * **degenerate flag** — the zero-inlier freeze (``core.icp``): no
    correspondence evidence at all.
  * **pose jump vs. the motion model** — translation / rotation distance
    between the registered pose and the constant-velocity prediction. A
    physically implausible jump on a smooth platform is the classic
    symptom of a wrong-basin convergence, *invisible* to residual metrics
    (the wrong basin often fits tightly).
  * **grid out-of-lattice fraction** — ``GridQueryStats.out_of_lattice``
    of the scan against the submap grid: how much of the scan falls
    outside mapped space (low overlap / teleport symptom).
  * **normal-equation conditioning** — eigenvalue ratio of the 6x6
    Gauss-Newton normal matrix ``A = Σ w·a aᵀ`` (``a = [p×n; n]``, the
    point-to-plane system of ``core.point_to_plane``; the fused kernel's
    ``PlaneMoments.A`` is exactly this matrix). A near-singular A means
    the scene does not constrain all six DoF — corridors, tunnels, open
    fields — and the solve amplifies noise along the null directions.

Thresholds are two-tier (``suspect_*`` / ``failed_*``): any FAILED signal
fails the frame, any SUSPECT signal marks it suspect, otherwise OK. All
inputs are host scalars/arrays — assessment happens between frames, off
the device hot path.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

OK = "ok"
SUSPECT = "suspect"
FAILED = "failed"
VERDICTS = (OK, SUSPECT, FAILED)

_RANK = {OK: 0, SUSPECT: 1, FAILED: 2}


class HealthThresholds(NamedTuple):
    """Two-tier signal thresholds. ``suspect_*`` trips the cascade's
    retry tiers; ``failed_*`` means the result must not be trusted even
    as a hint. Defaults are sized for the synthetic KITTI protocol
    (metre-scale scenes, ~1 m gates); ``inf`` disables a signal."""

    # Inlier mass is robust-weight mass, not a correspondence count: with
    # a huber kernel healthy streaming frames sit near 0.3-0.5, so the
    # suspect line must sit well below the healthy band.
    suspect_inlier_frac: float = 0.22   # below → SUSPECT
    failed_inlier_frac: float = 0.08    # below → FAILED
    suspect_rmse: float = 0.60          # above → SUSPECT (metres)
    failed_rmse: float = 1.50
    suspect_pose_jump: float = 1.00     # metres vs. motion-model prediction
    failed_pose_jump: float = 3.00
    suspect_rot_jump: float = 0.20      # radians vs. prediction
    failed_rot_jump: float = 0.60
    suspect_out_of_lattice: float = 0.25
    failed_out_of_lattice: float = 0.60
    # 6x6 normal-equation eigenvalue ratio of the scan's own plane
    # system: a clean 360° LiDAR-like scan sits near 1e3; sector crops /
    # heavy occlusion push past 1e4 (pose under-constrained along the
    # unseen directions). Degradation-only by default (failed = inf): a
    # sparse scan whose *normals* collapse (extreme dropout reads 1e30+)
    # can still be registered point-to-point, so conditioning justifies
    # quarantine, not a hard reject.
    suspect_condition: float = 6.0e3
    failed_condition: float = float("inf")


class RegistrationHealth(NamedTuple):
    """One frame's verdict plus the signals that produced it. ``reasons``
    names every non-OK signal as ``"signal:level"`` so logs and tests can
    see *why* a frame tripped, not just that it did."""

    verdict: str
    inlier_frac: float
    rmse: float
    degenerate: bool
    pose_jump_m: float
    rot_jump_rad: float
    out_of_lattice: float
    condition: float
    reasons: tuple[str, ...]

    @property
    def ok(self) -> bool:
        return self.verdict == OK


def pose_jump(T: np.ndarray, T_ref: np.ndarray) -> tuple[float, float]:
    """(translation metres, rotation radians) between two 4x4 poses."""
    T = np.asarray(T, np.float64)
    T_ref = np.asarray(T_ref, np.float64)
    dt = float(np.linalg.norm(T[:3, 3] - T_ref[:3, 3]))
    R = T[:3, :3] @ T_ref[:3, :3].T
    cos = np.clip((np.trace(R) - 1.0) / 2.0, -1.0, 1.0)
    return dt, float(np.arccos(cos))


def plane_normal_matrix(points: np.ndarray, normals: np.ndarray,
                        valid: np.ndarray | None = None,
                        weights: np.ndarray | None = None) -> np.ndarray:
    """The 6x6 Gauss-Newton normal matrix ``A = Σ w·a aᵀ``, ``a=[p×n; n]``.

    This is the matrix the point-to-plane step solves
    (``core.point_to_plane``) and the fused kernel accumulates as
    ``PlaneMoments.A`` — built host-side from a cloud + normals so health
    probes (and tests) can measure observability without running a solve.
    """
    p = np.asarray(points, np.float64)
    n = np.asarray(normals, np.float64)
    a = np.concatenate([np.cross(p, n), n], axis=-1)          # (N, 6)
    w = np.ones(p.shape[0]) if weights is None else np.asarray(weights,
                                                               np.float64)
    if valid is not None:
        w = w * np.asarray(valid, np.float64)
    return (a * w[:, None]).T @ a


def normal_equation_condition(A: np.ndarray) -> float:
    """Eigenvalue ratio λ_max/λ_min of a symmetric PSD 6x6 system.

    ~1e0–1e3: well-observed pose. Beyond ``suspect_condition`` the scene
    leaves some rigid motion unconstrained (corridor: translation along
    the axis; plane: the two in-plane translations + yaw) and the solve
    amplifies noise along those directions.
    """
    w = np.linalg.eigvalsh(np.asarray(A, np.float64))
    lo = max(float(w[0]), 1e-30)
    return float(w[-1]) / lo


def _grade(reasons: list, name: str, value: float, suspect: float,
           failed: float, *, above: bool = True) -> str:
    """Grade one scalar signal; non-finite values of an *error-like*
    signal (above=True) are FAILED outright."""
    if not np.isfinite(value):
        level = FAILED if above else OK
    elif above:
        level = (FAILED if value >= failed
                 else SUSPECT if value >= suspect else OK)
    else:
        level = (FAILED if value <= failed
                 else SUSPECT if value <= suspect else OK)
    if level != OK:
        reasons.append(f"{name}:{level}")
    return level


def assess_registration(result, *, predicted: np.ndarray | None = None,
                        thresholds: HealthThresholds = HealthThresholds(),
                        out_of_lattice: float | None = None,
                        condition: float | None = None) -> RegistrationHealth:
    """Distil one registration into a :class:`RegistrationHealth`.

    ``result`` is an ``ICPResult``-shaped object (``T``, ``rmse``,
    ``inlier_frac``, ``degenerate`` — host or device scalars).
    ``predicted`` is the motion model's pose prediction; without it the
    jump signals are skipped (first frames, pairwise protocol).
    ``out_of_lattice`` / ``condition`` are optional probe results
    (``neighborhood_stats`` / :func:`normal_equation_condition`) — pass
    what the call site has; absent signals never trip.
    """
    t = thresholds
    inlier = float(result.inlier_frac)
    rmse = float(result.rmse)
    degenerate = bool(result.degenerate)
    pose = np.asarray(result.T, np.float64)

    reasons: list[str] = []
    levels = [
        _grade(reasons, "inlier_frac", inlier, t.suspect_inlier_frac,
               t.failed_inlier_frac, above=False),
        _grade(reasons, "rmse", rmse, t.suspect_rmse, t.failed_rmse),
    ]
    if degenerate:
        levels.append(FAILED)
        reasons.append("degenerate:failed")
    if not np.all(np.isfinite(pose)):
        levels.append(FAILED)
        reasons.append("nonfinite_pose:failed")
        jump_t = jump_r = float("inf")
    elif predicted is not None:
        jump_t, jump_r = pose_jump(pose, predicted)
        levels.append(_grade(reasons, "pose_jump", jump_t,
                             t.suspect_pose_jump, t.failed_pose_jump))
        levels.append(_grade(reasons, "rot_jump", jump_r,
                             t.suspect_rot_jump, t.failed_rot_jump))
    else:
        jump_t = jump_r = 0.0
    if out_of_lattice is not None:
        levels.append(_grade(reasons, "out_of_lattice",
                             float(out_of_lattice),
                             t.suspect_out_of_lattice,
                             t.failed_out_of_lattice))
    if condition is not None:
        levels.append(_grade(reasons, "condition", float(condition),
                             t.suspect_condition, t.failed_condition))

    verdict = max(levels, key=_RANK.get, default=OK)
    return RegistrationHealth(
        verdict=verdict, inlier_frac=inlier, rmse=rmse,
        degenerate=degenerate, pose_jump_m=float(jump_t),
        rot_jump_rad=float(jump_r),
        out_of_lattice=float(out_of_lattice or 0.0),
        condition=float(condition or 1.0), reasons=tuple(reasons))
