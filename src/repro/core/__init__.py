"""FPPS core: the paper's contribution as composable JAX modules."""
from repro.core.api import FppsICP
from repro.core.engine import (RegistrationEngine, available_engines,
                               get_engine, register_engine)
from repro.core.health import (HealthThresholds, RegistrationHealth,
                               assess_registration)
from repro.core.icp import (ICPParams, ICPResult, icp, icp_batch,
                            icp_fixed_iterations, scrub_nonfinite)
from repro.core.nn_search import nn_search, pairwise_sq_dists
from repro.core.nn_search_grid import (GridQueryStats, grid_nn_fn,
                                       neighborhood_stats, nn_search_grid)
from repro.core.odometry import (FrameDiagnostics, OdometryConfig,
                                 OdometryPipeline)
from repro.core.point_to_plane import (point_to_plane_rmse, robust_weights,
                                       solve_normal_equations,
                                       solve_point_to_plane)
from repro.core.pyramid import PyramidEngine, icp_pyramid
from repro.core.svd3x3 import svd3x3
from repro.core.transform import (estimate_rigid_transform, make_transform,
                                  random_rigid_transform, transform_points)

__all__ = [
    "FppsICP", "ICPParams", "ICPResult", "RegistrationEngine",
    "available_engines", "get_engine", "register_engine",
    "icp", "icp_batch", "icp_fixed_iterations", "icp_pyramid",
    "scrub_nonfinite", "HealthThresholds", "RegistrationHealth",
    "assess_registration",
    "PyramidEngine", "grid_nn_fn", "nn_search_grid",
    "OdometryPipeline", "OdometryConfig", "FrameDiagnostics",
    "GridQueryStats", "neighborhood_stats",
    "nn_search", "pairwise_sq_dists", "svd3x3", "estimate_rigid_transform",
    "make_transform", "random_rigid_transform", "transform_points",
    "point_to_plane_rmse", "robust_weights", "solve_normal_equations",
    "solve_point_to_plane",
]
