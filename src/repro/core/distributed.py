"""Multi-device FPPS: shard_map registration entry points.

The production scale-out path is **stream sharding** (DESIGN.md §14): a
1-D ``("streams",)`` device mesh where each device owns a contiguous
block of independent odometry streams — their scans, their registrations,
AND their resident submaps. Streams never exchange data, so the shard
body (:func:`stream_sharded_icp`: ``vmap(icp)`` over the device's lane
block) contains **zero collectives**; the only device-boundary traffic is
the host's bulk result fetch, once per fleet round. This is what the
``sharded-slots`` engine and the sharded registration service run on.

Two **legacy single-frame** configurations predate it (DESIGN.md §4) and
are kept for the workloads stream sharding does not cover — registrations
whose *individual* target cloud outgrows one device:

1. **Point-sharded fleet mode** (`batched_icp_sharded`): a batch of
   frame-pairs sharded over ``("pod", "data")``; within each frame the
   *target* cloud is sharded over ``"model"``. Per ICP iteration the only
   collectives are an all-gather of per-shard winner (distance, point)
   candidates over ``model`` — the cross-shard generalisation of the
   paper's CMP comparison tree; the Kabsch moments are computed
   redundantly on every model-rank from the gathered winners (replicated
   math on 4k points beats a psum round-trip).

2. **Giant-frame mode** (`icp_sharded`): one registration whose target
   cloud is sharded over *every* device — city-scale map-to-scan
   alignment. Same combine, wider axis.

Design note (legacy combine): we gather winner *points*, never indices. A
global-index gather (`dst[idx]` across shards) would be an all-to-all with
data-dependent addressing; gathering the (d2, xyz) winner tuple is a
dense, fixed-size all-gather of n·4 floats per shard — exactly the kind
of regular collective the paper's streaming philosophy calls for.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import axis_size as _axis_size, shard_map
from repro.core.icp import ICPParams, ICPResult, icp, icp_fixed_iterations
from repro.core.nn_search import nn_search


# -- stream sharding (the production scale-out path) ------------------------

def streams_mesh(devices: int | None = None) -> Mesh:
    """The 1-D ``("streams",)`` device mesh stream sharding runs on.

    ``devices`` takes the first N local devices (None = all). Device ``d``
    owns lane block ``[d*L, (d+1)*L)`` of every ``(S, ...)`` fleet array
    placed with ``P("streams")`` — the slot->device mapping the sharded
    registration service builds its placement policy on.
    """
    devs = jax.devices()
    n = len(devs) if devices is None else int(devices)
    if not 1 <= n <= len(devs):
        raise ValueError(f"devices must be in [1, {len(devs)}], got {n}")
    return Mesh(np.array(devs[:n]), ("streams",))


def stream_sharded_icp(mesh: Mesh, src_b: jax.Array, dst_b: jax.Array,
                       params: ICPParams = ICPParams(), *,
                       initial_transforms: jax.Array | None = None,
                       src_valid: jax.Array | None = None,
                       dst_valid: jax.Array | None = None,
                       nn_fn=None) -> ICPResult:
    """S independent registrations sharded over a ``("streams",)`` mesh.

    Every ``(S, ...)`` input shards along its lane axis; each device runs
    ``vmap(icp)`` over its own contiguous block of ``S / D`` lanes. There
    are NO collectives in the body — lanes are independent by
    construction — so a lane's result is bitwise identical for any mesh
    size serving the same lanes-per-device block width (the weak-scaling
    parity contract the sharded service's tests assert). Masks/warm
    starts default to all-ones / identity; ``nn_fn`` swaps the
    correspondence searcher exactly as in ``core.icp.icp``.

    Call it inside ``jax.jit`` for one fused executable (the
    ``sharded-slots`` engine does); inputs not already placed with
    ``P("streams")`` are resharded automatically at the jit boundary.
    """
    S = src_b.shape[0]
    D = mesh.shape["streams"]
    if S % D:
        raise ValueError(f"lane count {S} must divide the streams mesh "
                         f"size {D}")
    if initial_transforms is None:
        initial_transforms = jnp.broadcast_to(
            jnp.eye(4, dtype=src_b.dtype), (S, 4, 4))
    if src_valid is None:
        src_valid = jnp.ones(src_b.shape[:2], bool)
    if dst_valid is None:
        dst_valid = jnp.ones(dst_b.shape[:2], bool)

    def body(src_l, dst_l, T0_l, sv_l, dv_l):
        def one(src, dst, T0, sv, dv):
            return icp(src, dst, params, T0, nn_fn=nn_fn,
                       src_valid=sv, dst_valid=dv)
        return jax.vmap(one)(src_l, dst_l, T0_l, sv_l, dv_l)

    spec = P("streams")
    out_specs = ICPResult(T=spec, rmse=spec, iterations=spec,
                          converged=spec, inlier_frac=spec, degenerate=spec)
    fn = shard_map(body, mesh=mesh, in_specs=(spec,) * 5,
                   out_specs=out_specs, check_vma=False)
    return fn(src_b, dst_b, initial_transforms, src_valid, dst_valid)


# -- legacy point-sharded paths ---------------------------------------------


def _local_correspond(src_t: jax.Array, dst_local: jax.Array,
                      chunk: int, axis_names: Sequence[str],
                      score_dtype: str = "fp32",
                      normals_local: jax.Array | None = None):
    """Local exact NN + cross-shard winner combine.

    Returns (d2, matched_points[, matched_normals]) replicated across
    ``axis_names``. Winner normals ride the same dense all-gather as the
    winner points — the (d2, xyz, nxyz) tuple is still a fixed-size
    regular collective, no cross-shard index gather.
    """
    d2, idx_local = nn_search(src_t, dst_local, chunk=chunk,
                              score_dtype=score_dtype)
    matched_local = jnp.take(dst_local, idx_local, axis=0)        # (n, 3)
    cols = [d2[:, None], matched_local]
    if normals_local is not None:
        cols.append(jnp.take(normals_local, idx_local, axis=0))   # (n, 3)
    cand = jnp.concatenate(cols, axis=1)                          # (n, 4|7)
    for ax in axis_names:  # combine one axis at a time: live buffer stays (S,n,C)
        gathered = jax.lax.all_gather(cand, ax)                   # (S, n, C)
        win = jnp.argmin(gathered[..., 0], axis=0)                # (n,)
        cand = jnp.take_along_axis(gathered, win[None, :, None], axis=0)[0]
    if normals_local is None:
        return cand[:, 0], cand[:, 1:4]
    return cand[:, 0], cand[:, 1:4], cand[:, 4:7]


def distributed_nn_search(mesh: Mesh, src: jax.Array, dst: jax.Array,
                          *, target_axes: Sequence[str] = ("model",),
                          chunk: int = 2048):
    """Sharded exact NN (d2, global idx) — for tests/benchmarks.

    src is replicated; dst is sharded along its first dim over target_axes.
    """
    axes = tuple(target_axes)

    def body(src_rep, dst_local):
        m_local = dst_local.shape[0]
        d2, idx_local = nn_search(src_rep, dst_local, chunk=chunk)
        # global index = shard offset + local index
        offset = jnp.zeros((), jnp.int32)
        stride = m_local
        for ax in reversed(axes):
            offset = offset + jax.lax.axis_index(ax).astype(jnp.int32) * stride
            stride = stride * _axis_size(ax)
        cand = jnp.concatenate(
            [d2[:, None], (idx_local + offset)[:, None].astype(d2.dtype)], axis=1)
        for ax in axes:
            g = jax.lax.all_gather(cand, ax)                      # (S, n, 2)
            win = jnp.argmin(g[..., 0], axis=0)
            cand = jnp.take_along_axis(g, win[None, :, None], axis=0)[0]
        return cand[:, 0], cand[:, 1].astype(jnp.int32)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(), P(axes)),
                   out_specs=(P(), P()), check_vma=False)
    return fn(src, dst)


def icp_sharded(mesh: Mesh, source: jax.Array, target: jax.Array,
                params: ICPParams = ICPParams(),
                *, target_axes: Sequence[str] = ("data", "model"),
                fixed_iterations: bool = False,
                dst_normals: jax.Array | None = None) -> ICPResult:
    """LEGACY giant-frame ICP: one registration, target sharded over
    target_axes (city-scale map-to-scan; see module docstring for when to
    prefer stream sharding).

    ``dst_normals`` (M, 3) — required for ``minimizer="point_to_plane"`` —
    is sharded alongside the target; estimate it on the *unsharded* cloud
    (shard-local estimation would degrade at shard boundaries).
    """
    axes = tuple(target_axes)
    if params.minimizer == "point_to_plane" and dst_normals is None:
        raise ValueError("icp_sharded with minimizer='point_to_plane' "
                         "needs dst_normals (estimate on the full target)")

    def body(src_rep, dst_local, nrm_local=None):
        cfn = functools.partial(_local_correspond, dst_local=dst_local,
                                chunk=params.chunk, axis_names=axes,
                                score_dtype=params.score_dtype,
                                normals_local=nrm_local)
        runner = icp_fixed_iterations if fixed_iterations else icp
        return runner(src_rep, None, params, correspond_fn=cfn)

    out_specs = ICPResult(T=P(), rmse=P(), iterations=P(), converged=P(),
                          inlier_frac=P(), degenerate=P())
    if dst_normals is None:
        fn = shard_map(body, mesh=mesh, in_specs=(P(), P(axes)),
                       out_specs=out_specs, check_vma=False)
        return fn(source, target)
    fn = shard_map(body, mesh=mesh, in_specs=(P(), P(axes), P(axes)),
                   out_specs=out_specs, check_vma=False)
    return fn(source, target, dst_normals)


def batched_icp_sharded(mesh: Mesh, src_batch: jax.Array,
                        dst_batch: jax.Array,
                        params: ICPParams = ICPParams(),
                        *, frame_axes: Sequence[str] = ("data",),
                        target_axes: Sequence[str] = ("model",),
                        fixed_iterations: bool = True,
                        src_valid: jax.Array | None = None,
                        dst_normals: jax.Array | None = None) -> ICPResult:
    """LEGACY point-sharded fleet mode: (F, N, 3) sources, (F, M, 3)
    targets. Kept (and regression-tested against the xla engine) for
    frames whose individual target cloud outgrows one device; for
    fleet-scale serving use :func:`stream_sharded_icp` / the
    ``sharded-slots`` engine, which shards *streams* with zero
    collectives instead of paying the per-iteration winner all-gather.

    Frames shard over ``frame_axes`` (use ("pod", "data") on the multi-pod
    mesh); each frame's target shards over ``target_axes``. Defaults to the
    scan-based fixed-iteration ICP: under vmap a while_loop would run every
    frame for the worst frame's trip count anyway, and the static schedule
    is what the dry-run/roofline analyses.

    ``src_valid`` (F, N) zero-weights bucket-padded source rows (see
    ``repro.data.collate``); padded *target* rows must carry far-sentinel
    coordinates so the local argmin never picks them — the per-shard winner
    combine has no mask channel by design (the (d2, xyz) tuple stays dense).
    ``dst_normals`` (F, M, 3) — required for the plane minimiser — shards
    like the targets and rides the winner combine as three extra columns.
    """
    f_axes, t_axes = tuple(frame_axes), tuple(target_axes)
    if src_valid is None:
        src_valid = jnp.ones(src_batch.shape[:2], dtype=src_batch.dtype)
    if params.minimizer == "point_to_plane" and dst_normals is None:
        raise ValueError("batched_icp_sharded with "
                         "minimizer='point_to_plane' needs dst_normals "
                         "(estimate per frame on the unsharded targets)")

    def body(src_b, dst_b, sv_b, nrm_b=None):
        def one(src, dst_local, sv, nrm_local):
            cfn = functools.partial(_local_correspond, dst_local=dst_local,
                                    chunk=params.chunk, axis_names=t_axes,
                                    score_dtype=params.score_dtype,
                                    normals_local=nrm_local)
            runner = icp_fixed_iterations if fixed_iterations else icp
            return runner(src, None, params, correspond_fn=cfn, src_valid=sv)
        if nrm_b is None:
            return jax.vmap(lambda s, d, v: one(s, d, v, None))(
                src_b, dst_b, sv_b)
        return jax.vmap(one)(src_b, dst_b, sv_b, nrm_b)

    out_specs = ICPResult(T=P(f_axes), rmse=P(f_axes), iterations=P(f_axes),
                          converged=P(f_axes), inlier_frac=P(f_axes),
                          degenerate=P(f_axes))
    if dst_normals is None:
        fn = shard_map(body, mesh=mesh,
                       in_specs=(P(f_axes), P(f_axes, t_axes), P(f_axes)),
                       out_specs=out_specs, check_vma=False)
        return fn(src_batch, dst_batch, src_valid)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(f_axes), P(f_axes, t_axes), P(f_axes),
                             P(f_axes, t_axes)),
                   out_specs=out_specs, check_vma=False)
    return fn(src_batch, dst_batch, src_valid, dst_normals)


def shard_inputs(mesh: Mesh, src_batch, dst_batch,
                 frame_axes=("data",), target_axes=("model",)):
    """Place host arrays with the shardings batched_icp_sharded expects."""
    s_src = NamedSharding(mesh, P(tuple(frame_axes)))
    s_dst = NamedSharding(mesh, P(tuple(frame_axes), tuple(target_axes)))
    return jax.device_put(src_batch, s_src), jax.device_put(dst_batch, s_dst)
