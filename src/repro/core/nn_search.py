"""Brute-force exact nearest-neighbour search — the heart of FPPS.

The paper replaces k-d trees with a fully parallel exact search (Discussion
§V-A: tree traversal is sequential/branchy; brute force is dense, regular and
pipelineable). On TPU this argument is even stronger: the pairwise-distance
expansion

    ||p - q||² = ||p||² + ||q||² - 2 p·q

turns the O(N·M) distance grid into an (N,3)x(3,M) matmul — MXU work — plus
rank-1 updates, and the argmin is a lane reduction on the VPU.

Two implementations:
  * this module — pure XLA (jnp) with explicit target-chunking so the peak
    memory stays bounded; used by the default path, the distributed path, and
    the dry-run (it lowers on any backend).
  * ``repro.kernels.nn_search`` — the Pallas TPU kernel with explicit VMEM
    BlockSpec tiling and a fused transform prologue (validated in interpret
    mode against ``repro.kernels.ref``).

Both return (min_dist_sq, argmin_index) exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def pairwise_sq_dists(src: jax.Array, dst: jax.Array) -> jax.Array:
    """(N,3),(M,3) -> (N,M) squared distances via the matmul expansion."""
    # fp32 accumulation: metric data; see DESIGN.md §2 (precision note).
    sn = jnp.sum(src * src, axis=-1, keepdims=True)          # (N,1)
    dn = jnp.sum(dst * dst, axis=-1, keepdims=True).T        # (1,M)
    cross = src @ dst.T                                       # MXU
    d2 = sn + dn - 2.0 * cross
    return jnp.maximum(d2, 0.0)  # clamp fp roundoff


def nn_search(src: jax.Array, dst: jax.Array, *, chunk: int = 2048,
              dst_valid: jax.Array | None = None,
              score_dtype: str = "fp32",
              return_points: bool = False):
    """Exact NN of each src point in dst.

    Args:
      src: (N, 3) query points.
      dst: (M, 3) target cloud.
      chunk: target-cloud chunk size — bounds the (N, chunk) live tile, the
        XLA analogue of the kernel's BlockSpec. M need not divide chunk.
      dst_valid: optional (M,) bool mask for padded target slots.
      score_dtype: "fp32" (exact, default) or "bf16" — halves the distance
        -tile HBM traffic (§Perf iteration A2). bf16 scores can mis-rank
        near-tied candidates (~1e-2 relative); ICP accuracy parity under
        bf16 is validated empirically in the benchmark suite and it stays
        opt-in.
      return_points: additionally return the gathered winner points
        ``dst[idx]``. The exact-d2 epilogue already gathers them, so this
        lets ICP's correspondence stage reuse that gather instead of
        issuing a second ``jnp.take`` over the target cloud.

    Returns:
      (d2, idx[, points]): (N,) squared distance to NN, (N,) int32 index
      into dst, and with ``return_points`` the (N, 3) matched points.
    """
    n = src.shape[0]
    m = dst.shape[0]
    pad = (-m) % chunk
    if pad:
        # Large-but-FINITE padding: inf coords would produce inf-inf = NaN in
        # the matmul expansion and force a full NaN-scrub read+write pass
        # over every (N, chunk) distance tile (~1/3 of the sweep's HBM
        # traffic — §Perf iteration A1). 1e15 keeps padded d2 ~1e30, far
        # beyond any metric scene, with no NaN path.
        dst = jnp.concatenate(
            [dst, jnp.full((pad, 3), jnp.asarray(1e15, dst.dtype))], axis=0)
        if dst_valid is not None:
            dst_valid = jnp.concatenate(
                [dst_valid, jnp.zeros((pad,), dtype=bool)], axis=0)
    m_padded = dst.shape[0]
    n_chunks = m_padded // chunk
    dst_chunks = dst.reshape(n_chunks, chunk, 3)
    valid_chunks = (dst_valid.reshape(n_chunks, chunk)
                    if dst_valid is not None else None)

    sn = jnp.sum(src * src, axis=-1)  # (N,) hoisted out of the scan
    lowp = score_dtype == "bf16"
    src_c = src.astype(jnp.bfloat16) if lowp else src

    def body(carry, xs):
        best_d2, best_idx = carry
        if valid_chunks is None:
            dchunk, base = xs
            valid = None
        else:
            dchunk, base, valid = xs
        dn = jnp.sum(dchunk * dchunk, axis=-1)                # (chunk,)
        if lowp:
            # bf16 tile end-to-end: the MXU emits bf16, the (N, chunk)
            # buffer and its argmin read are half-width.
            cross = jax.lax.dot_general(
                src_c, dchunk.astype(jnp.bfloat16).T,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.bfloat16)
            d2 = (sn.astype(jnp.bfloat16)[:, None]
                  + dn.astype(jnp.bfloat16)[None, :] - 2.0 * cross)
        else:
            cross = src @ dchunk.T                             # (N, chunk) MXU
            d2 = sn[:, None] + dn[None, :] - 2.0 * cross
        if valid is not None:
            d2 = jnp.where(valid[None, :], d2, jnp.asarray(jnp.inf, d2.dtype))
        local_idx = jnp.argmin(d2, axis=1)
        local_d2 = jnp.take_along_axis(d2, local_idx[:, None],
                                       axis=1)[:, 0].astype(jnp.float32)
        improved = local_d2 < best_d2
        best_d2 = jnp.where(improved, local_d2, best_d2)
        best_idx = jnp.where(improved, base + local_idx.astype(jnp.int32), best_idx)
        return (best_d2, best_idx), None

    # Carry pinned to fp32: local_d2 is always cast to fp32, so an
    # src.dtype carry would silently upcast (or mis-compare) for bf16
    # callers.
    init = (jnp.full((n,), jnp.inf, dtype=jnp.float32),
            jnp.zeros((n,), dtype=jnp.int32))
    bases = jnp.arange(n_chunks, dtype=jnp.int32) * chunk
    xs = ((dst_chunks, bases) if valid_chunks is None
          else (dst_chunks, bases, valid_chunks))
    (best_d2, best_idx), _ = jax.lax.scan(body, init, xs)
    # The expansion picks the right argmin but its cancellation
    # (sn + dn - 2·cross at scene scale) costs ~1e-4 absolute in the
    # distances; recompute the O(N) winner distances directly so the
    # returned d2 is exact. Keep inf where nothing was valid.
    matched = jnp.take(dst, best_idx, axis=0)
    diff = src - matched
    exact = jnp.sum(diff * diff, axis=-1).astype(jnp.float32)
    best_d2 = jnp.where(jnp.isinf(best_d2), best_d2, exact)
    if return_points:
        return jnp.maximum(best_d2, 0.0), best_idx, matched
    return jnp.maximum(best_d2, 0.0), best_idx


@functools.partial(jax.jit, static_argnames=("chunk",))
def nn_search_jit(src, dst, chunk: int = 2048):
    return nn_search(src, dst, chunk=chunk)
