"""Version-compatibility shims for jax APIs that moved or were renamed.

The repo targets current jax but must run on the container's older
runtime; every cross-version touchpoint lives here so call sites stay
written against the modern spelling:

  * ``shard_map``      — ``jax.shard_map`` (public from 0.8) vs
                         ``jax.experimental.shard_map`` whose kwarg is
                         ``check_rep`` instead of ``check_vma``.
  * ``make_mesh``      — ``axis_types=`` only exists on newer jax.
  * ``axis_size``      — ``jax.lax.axis_size`` is new; ``psum(1, ax)``
                         is the portable spelling.
  * ``cost_analysis``  — ``compiled.cost_analysis()`` returns a dict on
                         new jax, a one-element list of dicts before.
"""
from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - jax < 0.8
    from jax.experimental.shard_map import shard_map as _shard_map_experimental

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
        if check_vma is not None:
            kwargs["check_rep"] = check_vma
        return _shard_map_experimental(f, mesh=mesh, in_specs=in_specs,
                                       out_specs=out_specs, **kwargs)


def make_mesh(shape, axes):
    """jax.make_mesh, with Auto axis_types where the API supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def axis_size(ax: str):
    """Size of a named mapped axis, inside shard_map/vmap."""
    try:
        return jax.lax.axis_size(ax)
    except AttributeError:  # pragma: no cover - jax < 0.6
        return jax.lax.psum(1, ax)


def cost_analysis(compiled) -> dict:
    """Normalise compiled.cost_analysis() to a dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
