"""input_specs(): ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, zero allocation. The dry-run lowers against
these; the drivers build real arrays with the same shapes/shardings.

Per assignment: [audio]/[vlm] archs get precomputed frame/patch embeddings
from the (stubbed) modality frontend instead of token ids.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch.mesh import batch_axes_for


def batch_specs(cfg: ArchConfig, shape: ShapeConfig):
    """Abstract inputs for the step kind. Returns (specs dict, logical axes
    dict) where axes name the leading dims for sharding."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train",):
        if cfg.embed_inputs:
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
            axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
        else:
            specs = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                    jnp.bfloat16),
                     "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
            axes = {"embeds": ("batch", "seq", None),
                    "labels": ("batch", "seq")}
        return specs, axes
    if shape.kind == "prefill":
        if cfg.embed_inputs:
            return ({"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)},
                    {"tokens": ("batch", "seq")})
        return ({"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                jnp.bfloat16)},
                {"embeds": ("batch", "seq", None)})
    if shape.kind == "decode":
        if cfg.embed_inputs:
            return ({"token": jax.ShapeDtypeStruct((b,), jnp.int32),
                     "pos": jax.ShapeDtypeStruct((), jnp.int32)},
                    {"token": ("batch",), "pos": ()})
        return ({"embed": jax.ShapeDtypeStruct((b, cfg.d_model), jnp.bfloat16),
                 "pos": jax.ShapeDtypeStruct((), jnp.int32)},
                {"embed": ("batch", None), "pos": ()})
    raise ValueError(shape.kind)


def resolve_batch_rules(mesh, shape: ShapeConfig) -> dict:
    """Per-shape logical rules: batch axes chosen by divisibility."""
    return {"batch": batch_axes_for(mesh, shape.global_batch)}


def sharding_for_axes(mesh, axes, rules: dict):
    def one(names):
        specs = []
        for n in names:
            v = rules.get(n) if n else None
            if v is None:
                specs.append(None)
            else:
                cand = (v,) if isinstance(v, str) else tuple(
                    a for a in v if a in mesh.axis_names)
                specs.append(cand if cand else None)
        return NamedSharding(mesh, P(*specs))
    return jax.tree_util.tree_map(
        one, axes,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            isinstance(e, (str, type(None))) for e in t))
