"""Point-cloud registration driver — the paper's application, end to end.

    PYTHONPATH=src python -m repro.launch.registration --seq 0 --frames 5
    PYTHONPATH=src python -m repro.launch.registration --mode scan_to_map

``--mode pairwise`` (default) replicates the FPPS evaluation protocol
(§IV-A): per frame, 4096 points sampled from the source cloud, full target
cloud as the NN space, max 50 iterations, 1.0 m gate, 1e-5 epsilon;
reports RMSE + latency for our engine and the k-d tree CPU baseline. The
whole sequence runs through the unified engine layer as ONE batched
registration (``RegistrationEngine.register_pairs``): frames are collated
into shape buckets and registered by a single compiled executable, so
per-frame numbers below share one compile. ``--per-frame`` falls back to
the looped Table-I API path for comparison.

``--mode scan_to_map`` runs the streaming odometry pipeline
(``repro.core.odometry``): rolling submap target, constant-velocity warm
starts, per-frame diagnostics — the production stream shape of the
paper's KITTI workload.

``--mode serve`` runs a scripted *fleet*: ``--streams`` concurrent
odometry streams multiplexed through the multi-stream registration
service (``repro.serve.registration_service``, DESIGN.md §13) — every
frame wave is one compiled fleet round, and the summary reports
per-stream drift/health plus aggregate frames/s and the engine trace
count (constant after warmup). ``--faults`` in this mode degrades only
the first stream, demonstrating that one sick vehicle quarantines
without touching its peers (the default fleet already includes the
fast-highway outlier seq 1 as a natural degraded stream):

    PYTHONPATH=src python -m repro.launch.registration \\
        --mode serve --streams 4 --frames 6
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import FppsICP, ICPParams, get_engine
from repro.core.baseline import kdtree_icp
from repro.data.pointcloud import (SceneConfig, frame_pair_from_world,
                                   gt_pose, make_world, sequence_scans)


def run_scan_to_map(args, cfg, params):
    """Streaming scan-to-map odometry over a resampled scan stream."""
    from repro.core.odometry import OdometryConfig, OdometryPipeline
    from repro.data.corruption import apply_faults, parse_fault_spec

    faults = parse_fault_spec(args.faults) if args.faults else None
    scans = sequence_scans(args.seq, args.frames + 1, cfg)
    pipe = OdometryPipeline(OdometryConfig(
        engine=args.engine, params=params._replace(max_iterations=30)))
    gt = gt_pose(args.seq)
    pipe.process(scans[0])           # frame 0 initialises the map, clean
    rows = []
    for frame in range(1, args.frames + 1):
        scan, valid = scans[frame], None
        if faults is not None:
            scan, valid = apply_faults(scan, faults, seed=args.fault_seed,
                                       frame=frame)
        t0 = time.time()
        pose, diag = pipe.process(scan, valid=valid)
        t_frame = time.time() - t0
        drift = float(np.linalg.norm(pose[:3, 3] - gt(frame)[:3, 3]))
        rows.append((frame, diag.iterations, diag.inlier_frac, t_frame, drift))
        flags = diag.health + (" tier %d" % diag.recovery_tier
                               if diag.recovery_tier else "")
        if diag.quarantined:
            flags += " quarantined"
        print(f"frame {frame}: iters {diag.iterations:2d} "
              f"inliers {diag.inlier_frac:.2f} "
              f"map occ {diag.map_occupancy:.2f} | t {t_frame * 1e3:7.1f}ms | "
              f"drift {drift:.3f} m | {flags}")
    steady = [r[3] for r in rows[2:]] or [rows[-1][3]]
    health = pipe.health_counts()
    tiers = pipe.tier_counts()
    print(f"\nscan_to_map engine={args.engine}: {args.frames} frames, "
          f"steady-state {np.mean(steady) * 1e3:.1f} ms/frame "
          f"({1.0 / np.mean(steady):.2f} frames/s), "
          f"final drift {rows[-1][4]:.3f} m, "
          f"rejected {pipe.rejected_frames()}")
    print(f"health ok/suspect/failed: {health['ok']}/{health['suspect']}/"
          f"{health['failed']} | tiers "
          + " ".join(f"{t}:{n}" for t, n in sorted(tiers.items()))
          + f" | recovered {pipe.recovery_count}"
          f" quarantined {pipe.quarantined_count}"
          + (f" | faults '{args.faults}'" if faults is not None else ""))
    return rows


def run_serve(args, cfg, params):
    """Scripted fleet through the multi-stream registration service:
    one compiled round per frame wave, per-stream verdicts host-side."""
    from repro.core.odometry import OdometryConfig
    from repro.data.corruption import apply_faults, parse_fault_spec
    from repro.data.submap import SubmapParams
    from repro.serve.registration_service import (RegistrationService,
                                                  ServiceConfig)

    faults = parse_fault_spec(args.faults) if args.faults else None
    # Fleet-sized scene regardless of --reduced: the round multiplies
    # every shape by ``--streams``. Vehicles scan distinct worlds
    # (``--seq + s``) at each sequence's own ground-truth speed, so the
    # fleet mixes easy urban streams with the 2.5 m/frame highway
    # outlier (seq 1) whose cold start outruns the 1 m gate — the demo's
    # point is that its SUSPECT verdicts stay confined to that stream.
    cfg = SceneConfig(n_ground=2500, n_walls=1800, n_poles=450,
                      n_clutter=450, extent=25.0, sensor_range=30.0)
    fleet = {}
    for s in range(args.streams):
        scans = sequence_scans(args.seq + s, args.frames + 1, cfg)
        frames = [(scans[0], None)]      # frame 0 seeds the map, clean
        for f, scan in enumerate(scans[1:], start=1):
            if faults is not None and s == 0:
                # degrade ONLY the first stream: the service story is
                # that its quarantine never leaks into the peers
                frames.append(apply_faults(scan, faults,
                                           seed=args.fault_seed, frame=f))
            else:
                frames.append((scan, None))
        fleet[f"veh{s}"] = frames

    odo = OdometryConfig(
        params=params._replace(max_iterations=30),
        submap=SubmapParams(voxel_size=0.75, capacity=8192,
                            dims=(96, 96, 24), evict_radius=25.0),
        scan_budget=4096)
    cap = max(sc.shape[0] for frames in fleet.values() for sc, _ in frames)
    svc = RegistrationService(ServiceConfig(
        slots=args.streams, scan_capacity=cap, odometry=odo))
    for sid in fleet:
        svc.admit(sid)

    times, last = [], {}
    for f in range(args.frames + 1):
        t0 = time.time()
        for sid, frames in fleet.items():
            svc.submit(sid, *frames[f])
        last.update(svc.step())
        svc.sync()
        times.append(time.time() - t0)

    gts = {f"veh{s}": gt_pose(args.seq + s) for s in range(args.streams)}
    reports = []
    for sid in fleet:
        rep = svc.report(sid)
        pose, _ = last[sid]
        drift = float(np.linalg.norm(pose[:3, 3]
                                     - gts[sid](args.frames)[:3, 3]))
        hc = rep.health_counts
        reports.append(rep)
        print(f"{sid}: drift {drift:.3f} m | health ok/suspect/failed "
              f"{hc['ok']}/{hc['suspect']}/{hc['failed']} | "
              f"quarantined {rep.frames_quarantined} "
              f"dropped {rep.frames_dropped} "
              f"escapes {rep.cascade_escapes}")
    steady = times[2:] or times          # first rounds pay compilation
    sr = svc.service_report()
    print(f"\nserve: {args.streams} streams x {args.frames} frames, "
          f"steady-state {np.mean(steady) * 1e3:.1f} ms/round "
          f"({args.streams / np.mean(steady):.1f} frames/s aggregate) | "
          f"rounds {sr['rounds']} traces {sr['trace_count']} "
          f"dropped {sr['frames_dropped']}"
          + (f" | faults '{args.faults}' on veh0" if faults is not None
             else ""))
    return reports


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--frames", type=int, default=5)
    ap.add_argument("--samples", type=int, default=4096)
    ap.add_argument("--engine", default="xla",
                    choices=["xla", "pallas", "distributed", "pyramid"])
    ap.add_argument("--minimizer", default="point_to_point",
                    choices=["point_to_point", "point_to_plane"],
                    help="error metric: paper's point-to-point Kabsch or "
                         "the plane-aware Gauss-Newton step (DESIGN.md §9)")
    ap.add_argument("--robust", default=None,
                    choices=["none", "huber", "tukey"],
                    help="IRLS robust reweighting on top of the gate "
                         "(default: none for pairwise, huber for "
                         "scan_to_map — DESIGN.md §10)")
    ap.add_argument("--robust-scale", type=float, default=None,
                    help="robust kernel scale in metres (default: 0.5 "
                         "pairwise, 0.3 scan_to_map)")
    ap.add_argument("--mode", default="pairwise",
                    choices=["pairwise", "scan_to_map", "serve"],
                    help="pairwise: batched frame-pair protocol (§IV-A); "
                         "scan_to_map: streaming odometry pipeline; "
                         "serve: --streams concurrent streams through the "
                         "multi-stream registration service (always on "
                         "the slot engine; --engine is ignored)")
    ap.add_argument("--streams", type=int, default=4,
                    help="serve mode: fleet width (= service slots)")
    ap.add_argument("--fused", action="store_true",
                    help="single-pass fused iteration kernel "
                         "(ICPParams.fused, DESIGN.md §11)")
    ap.add_argument("--faults", default=None,
                    help="scan_to_map only: comma-separated fault spec "
                         "injected into every streamed frame, e.g. "
                         "'dropout:0.3,occlusion:90deg,nan:10' "
                         "(repro.data.corruption)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the deterministic fault injectors")
    ap.add_argument("--per-frame", action="store_true",
                    help="loop FppsICP.align() per frame instead of one batch")
    ap.add_argument("--reduced", action="store_true",
                    help="smaller synthetic scenes (fast CI)")
    args = ap.parse_args(argv)

    cfg = (SceneConfig(n_ground=9000, n_walls=6000, n_poles=1800,
                       n_clutter=1700, extent=40.0, sensor_range=45.0)
           if args.reduced else SceneConfig())
    # Per-mode defaults, overridden only by an *explicit* flag: huber
    # bounds the map-frontier pull in the streaming regime (DESIGN.md
    # §10), while the pairwise protocol (§IV-A) stays unweighted.
    streaming = args.mode in ("scan_to_map", "serve")
    robust = args.robust if args.robust is not None else (
        "huber" if streaming else "none")
    robust_scale = args.robust_scale if args.robust_scale is not None else (
        0.3 if streaming else 0.5)
    params = ICPParams(max_iterations=50, max_correspondence_distance=1.0,
                       transformation_epsilon=1e-5,
                       minimizer=args.minimizer, robust_kernel=robust,
                       robust_scale=robust_scale, fused=args.fused)

    if args.mode == "serve":
        return run_serve(args, cfg, params)
    if args.mode == "scan_to_map":
        return run_scan_to_map(args, cfg, params)

    world = make_world(args.seq, cfg)  # built once for the whole sequence
    pairs = [frame_pair_from_world(world, args.seq, f, cfg, args.samples)
             for f in range(args.frames)]

    if args.per_frame:
        reg = FppsICP(engine=args.engine)  # one adapter: caches persist
        Ts, rmses = [], []
        t0 = time.time()
        for src, dst, _ in pairs:
            reg.setInputSource(src)
            reg.setInputTarget(dst)
            reg.setMaxCorrespondenceDistance(1.0)
            reg.setMaxIterationCount(50)
            reg.setTransformationEpsilon(1e-5)
            reg.setMinimizer(args.minimizer)
            reg.setRobustKernel(robust, robust_scale)
            Ts.append(reg.align())
            rmses.append(reg.getFitnessScore())
        t_ours = time.time() - t0
    else:
        engine = get_engine(args.engine)
        t0 = time.time()
        res, _batch = engine.register_pairs([(s, d) for s, d, _ in pairs],
                                            params)
        import jax
        jax.block_until_ready(res.T)
        t_ours = time.time() - t0
        Ts = [np.asarray(res.T[i]) for i in range(args.frames)]
        rmses = [float(res.rmse[i]) for i in range(args.frames)]

    rows = []
    t_base_total = 0.0
    for frame, (src, dst, T_gt) in enumerate(pairs):
        t0 = time.time()
        base = kdtree_icp(src, dst)
        t_base = time.time() - t0
        t_base_total += t_base
        t_err = float(np.linalg.norm(Ts[frame][:3, 3] - T_gt[:3, 3]))
        rows.append((frame, rmses[frame], base.rmse, t_ours / args.frames,
                     t_base, t_err))
        print(f"frame {frame}: rmse ours={rows[-1][1]:.4f} "
              f"kdtree={rows[-1][2]:.4f} | t ours={t_ours/args.frames*1e3:7.1f}ms "
              f"kdtree={t_base*1e3:7.1f}ms | trans err {t_err:.3f} m")
    d = np.array([[r[1], r[2]] for r in rows])
    mode = "per-frame loop" if args.per_frame else "batched"
    print(f"\nmean RMSE ours={d[:,0].mean():.4f} kdtree={d[:,1].mean():.4f} "
          f"delta={abs(d[:,0].mean()-d[:,1].mean()):.4f} (paper: <=0.01)")
    print(f"{mode} engine={args.engine}: {args.frames} frames in {t_ours:.2f}s "
          f"({args.frames/t_ours:.2f} frames/s) vs kdtree {t_base_total:.2f}s")
    return rows


if __name__ == "__main__":
    main()
