"""Point-cloud registration driver — the paper's application, end to end.

    PYTHONPATH=src python -m repro.launch.registration --seq 0 --frames 5

Replicates the FPPS evaluation protocol (§IV-A): per frame, 4096 points
sampled from the source cloud, full target cloud as the NN space,
max 50 iterations, 1.0 m gate, 1e-5 epsilon; reports RMSE + latency for
our engine and the k-d tree CPU baseline.

The whole sequence runs through the unified engine layer as ONE batched
registration (``RegistrationEngine.register_pairs``): frames are collated
into shape buckets and registered by a single compiled executable, so
per-frame numbers below share one compile. ``--per-frame`` falls back to
the looped Table-I API path for comparison.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import FppsICP, ICPParams, get_engine
from repro.core.baseline import kdtree_icp
from repro.data.pointcloud import SceneConfig, frame_pair


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--frames", type=int, default=5)
    ap.add_argument("--samples", type=int, default=4096)
    ap.add_argument("--engine", default="xla",
                    choices=["xla", "pallas", "distributed", "pyramid"])
    ap.add_argument("--minimizer", default="point_to_point",
                    choices=["point_to_point", "point_to_plane"],
                    help="error metric: paper's point-to-point Kabsch or "
                         "the plane-aware Gauss-Newton step (DESIGN.md §9)")
    ap.add_argument("--robust", default="none",
                    choices=["none", "huber", "tukey"],
                    help="IRLS robust reweighting on top of the gate")
    ap.add_argument("--robust-scale", type=float, default=0.5,
                    help="robust kernel scale in metres")
    ap.add_argument("--per-frame", action="store_true",
                    help="loop FppsICP.align() per frame instead of one batch")
    ap.add_argument("--reduced", action="store_true",
                    help="smaller synthetic scenes (fast CI)")
    args = ap.parse_args(argv)

    cfg = (SceneConfig(n_ground=9000, n_walls=6000, n_poles=1800,
                       n_clutter=1700, extent=40.0, sensor_range=45.0)
           if args.reduced else SceneConfig())
    params = ICPParams(max_iterations=50, max_correspondence_distance=1.0,
                       transformation_epsilon=1e-5,
                       minimizer=args.minimizer, robust_kernel=args.robust,
                       robust_scale=args.robust_scale)

    pairs = [frame_pair(args.seq, f, cfg, args.samples)
             for f in range(args.frames)]

    if args.per_frame:
        reg = FppsICP(engine=args.engine)  # one adapter: caches persist
        Ts, rmses = [], []
        t0 = time.time()
        for src, dst, _ in pairs:
            reg.setInputSource(src)
            reg.setInputTarget(dst)
            reg.setMaxCorrespondenceDistance(1.0)
            reg.setMaxIterationCount(50)
            reg.setTransformationEpsilon(1e-5)
            reg.setMinimizer(args.minimizer)
            reg.setRobustKernel(args.robust, args.robust_scale)
            Ts.append(reg.align())
            rmses.append(reg.getFitnessScore())
        t_ours = time.time() - t0
    else:
        engine = get_engine(args.engine)
        t0 = time.time()
        res, _batch = engine.register_pairs([(s, d) for s, d, _ in pairs],
                                            params)
        import jax
        jax.block_until_ready(res.T)
        t_ours = time.time() - t0
        Ts = [np.asarray(res.T[i]) for i in range(args.frames)]
        rmses = [float(res.rmse[i]) for i in range(args.frames)]

    rows = []
    t_base_total = 0.0
    for frame, (src, dst, T_gt) in enumerate(pairs):
        t0 = time.time()
        base = kdtree_icp(src, dst)
        t_base = time.time() - t0
        t_base_total += t_base
        t_err = float(np.linalg.norm(Ts[frame][:3, 3] - T_gt[:3, 3]))
        rows.append((frame, rmses[frame], base.rmse, t_ours / args.frames,
                     t_base, t_err))
        print(f"frame {frame}: rmse ours={rows[-1][1]:.4f} "
              f"kdtree={rows[-1][2]:.4f} | t ours={t_ours/args.frames*1e3:7.1f}ms "
              f"kdtree={t_base*1e3:7.1f}ms | trans err {t_err:.3f} m")
    d = np.array([[r[1], r[2]] for r in rows])
    mode = "per-frame loop" if args.per_frame else "batched"
    print(f"\nmean RMSE ours={d[:,0].mean():.4f} kdtree={d[:,1].mean():.4f} "
          f"delta={abs(d[:,0].mean()-d[:,1].mean()):.4f} (paper: <=0.01)")
    print(f"{mode} engine={args.engine}: {args.frames} frames in {t_ours:.2f}s "
          f"({args.frames/t_ours:.2f} frames/s) vs kdtree {t_base_total:.2f}s")
    return rows


if __name__ == "__main__":
    main()
