"""Point-cloud registration driver — the paper's application, end to end.

    PYTHONPATH=src python -m repro.launch.registration --seq 0 --frames 5

Replicates the FPPS evaluation protocol (§IV-A): per frame, 4096 points
sampled from the source cloud, full target cloud as the NN space,
max 50 iterations, 1.0 m gate, 1e-5 epsilon; reports RMSE + latency for
our engine and the k-d tree CPU baseline.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import FppsICP
from repro.core.baseline import kdtree_icp
from repro.data.pointcloud import SceneConfig, frame_pair


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--frames", type=int, default=5)
    ap.add_argument("--samples", type=int, default=4096)
    ap.add_argument("--engine", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--reduced", action="store_true",
                    help="smaller synthetic scenes (fast CI)")
    args = ap.parse_args(argv)

    cfg = (SceneConfig(n_ground=9000, n_walls=6000, n_poles=1800,
                       n_clutter=1700, extent=40.0, sensor_range=45.0)
           if args.reduced else SceneConfig())

    rows = []
    for frame in range(args.frames):
        src, dst, T_gt = frame_pair(args.seq, frame, cfg, args.samples)
        reg = FppsICP(engine=args.engine)
        reg.setInputSource(src)
        reg.setInputTarget(dst)
        reg.setMaxCorrespondenceDistance(1.0)
        reg.setMaxIterationCount(50)
        reg.setTransformationEpsilon(1e-5)
        t0 = time.time()
        T = reg.align()
        t_ours = time.time() - t0
        t0 = time.time()
        base = kdtree_icp(src, dst)
        t_base = time.time() - t0
        t_err = float(np.linalg.norm(T[:3, 3] - T_gt[:3, 3]))
        rows.append((frame, reg.getFitnessScore(), base.rmse, t_ours, t_base,
                     t_err))
        print(f"frame {frame}: rmse ours={rows[-1][1]:.4f} "
              f"kdtree={rows[-1][2]:.4f} | t ours={t_ours*1e3:7.1f}ms "
              f"kdtree={t_base*1e3:7.1f}ms | trans err {t_err:.3f} m")
    d = np.array([[r[1], r[2]] for r in rows])
    print(f"\nmean RMSE ours={d[:,0].mean():.4f} kdtree={d[:,1].mean():.4f} "
          f"delta={abs(d[:,0].mean()-d[:,1].mean()):.4f} (paper: <=0.01)")
    return rows


if __name__ == "__main__":
    main()
