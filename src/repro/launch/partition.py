"""Logical-axis partitioning rules (MaxText-style) + activation constraints.

Parameters and activations are annotated with *logical* axis names; a rule
table maps logical names to mesh axes. Swapping a whole sharding strategy
(e.g. expert-parallel vs expert-tensor-parallel MoE) is a one-line rule
change — this is what the §Perf iterations toggle.

Models call ``aconstraint(x, (..logical names..))``; outside a rules context
it is a no-op, so the same model code runs on one CPU device in tests.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical name -> mesh axis (or tuple of axes, or None)
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),     # DP across pods and the data axis
    "tokens": ("pod", "data"),    # flattened batch*seq dim (MoE dispatch)
    "seq": None,                  # SP toggled per-shape in the perf loop
    "embed": None,                # activation d_model dim
    "heads": "model",             # TP: attention heads
    "kv_heads": "model",
    "qk_lora": None,
    "mlp": "model",               # TP: FFN hidden
    "vocab": "model",             # TP: embedding/logits vocab dim
    "expert": "model",            # EP: expert dim of MoE weights/buffers
    "expert_mlp": None,           # alternative: TP inside experts
    "fsdp": "data",               # weight-shard dim for FSDP
    "conv": None,
    "state": None,
    # decode KV-cache sequence dim. Sharding it over "model" splits the
    # cache (and the attention contraction: GSPMD turns the softmax
    # normalizer into a tiny all-reduce — flash-decoding-style split-K)
    # across chips whose kv-head count is below the TP degree.
    "kv_seq": "model",
    # implementation selectors (not axis names):
    #   gspmd_sort    — single-program sort dispatch, GSPMD infers comms
    #                    (fallback; baseline tables use this via --rule)
    #   shard_map_ep  — explicit local-sort + all-to-all expert parallelism
    #                    (production default; §Perf B3: 5.2x step-bound win)
    "moe_impl": "shard_map_ep",
}


def active_context():
    """(mesh, rules) of the innermost partitioning() context, or None."""
    return _active.get()

_active: contextvars.ContextVar = contextvars.ContextVar(
    "partition_ctx", default=None)  # (mesh, rules) or None


@contextlib.contextmanager
def partitioning(mesh: Mesh, rules: Mapping[str, object] | None = None):
    """Activate a mesh + logical rule table for model-internal constraints."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    # Drop axis names the mesh doesn't have (e.g. "pod" on the single-pod
    # mesh). Keys starting with "impl" carry implementation selectors
    # (e.g. moe_impl), not axis names — passed through untouched.
    def _clean(k, v):
        if k.endswith("_impl"):
            return v[0] if isinstance(v, tuple) and v else v
        if v is None:
            return None
        axes = (v,) if isinstance(v, str) else tuple(v)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        return axes if axes else None
    merged = {k: _clean(k, v) for k, v in merged.items()}
    token = _active.set((mesh, merged))
    try:
        with mesh:
            yield merged
    finally:
        _active.reset(token)


def logical_to_spec(names: Sequence[str | None]) -> P:
    ctx = _active.get()
    if ctx is None:
        return P()
    _, rules = ctx
    return P(*[rules.get(n) if n else None for n in names])


def aconstraint(x: jax.Array, names: Sequence[str | None]) -> jax.Array:
    """Activation sharding constraint by logical names; no-op outside a
    partitioning() context. Divisibility-aware: a mesh axis is dropped for
    any dim it does not divide evenly (e.g. 14 heads on a 16-way model
    axis) instead of forcing padded/replicated shardings."""
    ctx = _active.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    specs = []
    used: set = set()  # a mesh axis may appear on at most one dim
    for dim, n in zip(x.shape, tuple(names)[:x.ndim]):
        v = rules.get(n) if n else None
        if v is None:
            specs.append(None)
            continue
        axes = (v,) if isinstance(v, str) else tuple(v)
        kept, size = [], 1
        for a in axes:
            if (a in mesh.axis_names and a not in used
                    and dim % (size * mesh.shape[a]) == 0):
                kept.append(a)
                size *= mesh.shape[a]
        used.update(kept)
        specs.append(tuple(kept) if kept else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*specs)))


def param_sharding(logical_tree, mesh: Mesh,
                   rules: Mapping[str, object] | None = None,
                   abstract_tree=None):
    """Map a pytree of logical-name tuples to NamedShardings.

    When ``abstract_tree`` (matching ShapeDtypeStructs) is given, mesh axes
    that do not divide the corresponding dim are dropped (e.g. a 50280
    vocab on a 16-way model axis stays replicated)."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    is_names = lambda t: isinstance(t, tuple) and all(
        isinstance(e, (str, type(None))) for e in t)

    def one(names, leaf=None):
        axes = []
        used: set = set()
        for i, n in enumerate(names):
            v = merged.get(n) if n else None
            if v is None:
                axes.append(None)
                continue
            cand = (v,) if isinstance(v, str) else tuple(v)
            kept, size = [], 1
            dim = leaf.shape[i] if leaf is not None else None
            for a in cand:
                if a not in mesh.axis_names or a in used:
                    continue
                if dim is not None and dim % (size * mesh.shape[a]) != 0:
                    continue
                kept.append(a)
                size *= mesh.shape[a]
            used.update(kept)
            axes.append(tuple(kept) if kept else None)
        return NamedSharding(mesh, P(*axes))

    if abstract_tree is None:
        return jax.tree_util.tree_map(one, logical_tree, is_leaf=is_names)
    return jax.tree_util.tree_map(one, logical_tree, abstract_tree,
                                  is_leaf=is_names)
