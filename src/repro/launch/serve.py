"""Batched LM serving driver (legacy lockstep decode path).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --batch 4 --prompt-len 32 --gen 32

Drives :class:`repro.serve.engine.Engine` — the LM-zoo decode loop, not
the paper's workload. The point-cloud fleet service (continuous
batching over odometry streams) is driven by
``python -m repro.launch.registration --mode serve`` instead.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, get_smoke, list_archs
from repro.models import lm
from repro.serve.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.embed_inputs:
        raise SystemExit(f"{args.arch} takes precomputed embeddings; serve "
                         "via examples/odometry.py-style drivers instead")
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = Engine(cfg, params, max_len=args.prompt_len + args.gen)
    prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    out = engine.generate(prompts, args.gen, temperature=args.temperature)
    out.block_until_ready()
    dt = time.time() - t0
    total_tokens = args.batch * args.gen
    print(f"generated {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s incl. compile)")
    print("sample:", out[0][:16].tolist())
    return out


if __name__ == "__main__":
    main()
