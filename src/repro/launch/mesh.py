"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any device query, and tests must keep seeing 1 CPU device.
"""
from __future__ import annotations

from repro.compat import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16,16)=(data,model), 256 chips (one v5e pod's worth).
    Multi-pod: (2,16,16)=(pod,data,model), 512 chips across 2 pods; the
    ``pod`` axis is the DCN/cross-pod dimension."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for subprocess tests (8 host devices)."""
    return _make_mesh(shape, axes)


def batch_axes_for(mesh, global_batch: int):
    """Largest prefix of (pod, data) axes that divides the global batch.

    decode batch 1 (long_500k) -> () = replicated; batch 128 on the
    multi-pod mesh -> ("pod","data") = 32-way; etc."""
    candidates = [ax for ax in ("pod", "data") if ax in mesh.axis_names]
    chosen: list[str] = []
    size = 1
    for ax in candidates:
        ax_size = mesh.shape[ax]
        if global_batch % (size * ax_size) == 0:
            chosen.append(ax)
            size *= ax_size
    return tuple(chosen)
