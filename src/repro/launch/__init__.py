"""Launch substrate: meshes, partitioning rules, dry-run, drivers."""
