import os
if __name__ == "__main__":
    # Placeholder 512-device fleet for the dry-run CLI only. Guarded so that
    # *importing* this module (test_partition_rules, breakdown) never forces
    # the flag onto an in-process suite — conftest.py promises smoke tests
    # and benchmarks see the real device count.
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: when run as the CLI, the lines above MUST execute before any
# jax-importing module — jax locks the device count at first init.
import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import pathlib           # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.configs.registry import cells, get_shape, list_archs, runnable_cell  # noqa: E402
from repro.launch.mesh import batch_axes_for, make_production_mesh  # noqa: E402
from repro.launch.partition import DEFAULT_RULES, param_sharding, partitioning  # noqa: E402
from repro.launch.specs import batch_specs, sharding_for_axes  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.optim import cosine_schedule, pick_optimizer  # noqa: E402
from repro.roofline import analyze_hlo  # noqa: E402
from repro.roofline.report import V5E, model_flops, roofline_terms  # noqa: E402
from repro.train import train_step as ts  # noqa: E402

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# The paper's own workload, as first-class dry-run cells (DESIGN.md §4).
ICP_SHAPES = {
    # fleet: one KITTI-like frame-pair per vehicle, paper-sized clouds
    "fleet_130k": dict(frames=256, n_src=4096, m_dst=131072, iters=50),
    # giant-frame: scan-to-city-map registration, target over every chip
    "giant_134m": dict(frames=1, n_src=65536, m_dst=2 ** 27, iters=50),
}


def _mesh_for(name: str):
    return make_production_mesh(multi_pod=(name == "multi"))


def _trim_batch_axes(mesh, axes, global_batch: int):
    """Longest prefix of ``axes`` (present in mesh) dividing global_batch."""
    chosen, size = [], 1
    for ax in axes or ():
        if ax not in mesh.axis_names:
            continue
        if global_batch % (size * mesh.shape[ax]) == 0:
            chosen.append(ax)
            size *= mesh.shape[ax]
        else:
            break
    return tuple(chosen)


def _rules_for(mesh, global_batch: int, overrides: dict | None = None,
               cfg=None):
    rules = dict(DEFAULT_RULES)
    rules["batch"] = batch_axes_for(mesh, global_batch)
    if cfg is not None:
        for k, v in cfg.sharding_override_rules.items():
            if k == "batch":
                rules[k] = _trim_batch_axes(mesh, v, global_batch)
            else:
                rules[k] = v
    rules["tokens"] = rules["batch"]  # flattened (B*S) dim follows batch
    if overrides:
        rules.update(overrides)
    return rules


from repro.compat import cost_analysis as _cost_analysis  # noqa: E402


def _collect(compiled, label: str, n_devices: int, cfg=None, shape=None,
             model_flops_override=None) -> dict:
    mem = compiled.memory_analysis()
    naive = _cost_analysis(compiled)
    hlo = compiled.as_text()
    cost = analyze_hlo(hlo)
    terms = roofline_terms(cost, cfg, shape, n_devices,
                           model_flops_override=model_flops_override)
    out = {
        "label": label,
        "n_devices": n_devices,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            "alias_bytes": mem.alias_size_in_bytes,
            "fits_v5e_16g": (mem.argument_size_in_bytes
                             + mem.temp_size_in_bytes) < V5E["hbm_bytes"],
        },
        "naive_cost_analysis": {
            "flops": naive.get("flops"),
            "bytes_accessed": naive.get("bytes accessed"),
        },
        "analyzed": cost.to_json(),
        "roofline": terms.to_json(),
    }
    return out


def _auto_accum(cfg, shape, mesh, rules) -> int:
    """Gradient-accumulation depth: keep per-device microbatch tokens small
    enough that checkpointed activations fit HBM (width-dependent)."""
    axes = rules.get("batch") or ()
    shards = 1
    for a in axes:
        shards *= mesh.shape[a]
    b_loc = max(1, shape.global_batch // max(shards, 1))
    tokens_loc = b_loc * shape.seq_len
    if cfg.d_model >= 12288:
        target = 4096
    elif cfg.d_model >= 4096:
        target = 8192
    else:
        target = 16384
    accum = max(1, tokens_loc // target)
    while b_loc % accum:  # accum must divide the local batch
        accum -= 1
    return accum


def _lower_lm_cell(arch: str, shape_name: str, mesh_name: str,
                   rules_overrides: dict | None = None,
                   remat: str = "full", accum: int | None = None,
                   kv_quant: bool = False) -> dict:
    cfg = get_config(arch)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    shape = get_shape(shape_name)
    mesh = _mesh_for(mesh_name)
    n_dev = mesh.devices.size
    rules = _rules_for(mesh, shape.global_batch, rules_overrides, cfg)
    specs, axes = batch_specs(cfg, shape)
    in_sh = sharding_for_axes(mesh, axes, rules)

    t0 = time.time()
    with partitioning(mesh, rules):
        if shape.kind == "train":
            if accum is None:
                accum = _auto_accum(cfg, shape, mesh, rules)
            opt = pick_optimizer(cfg.total_params(), cosine_schedule(3e-4))
            state_abs = ts.abstract_state(cfg, opt)
            state_axes = ts.state_logical_axes(cfg, opt)
            state_sh = param_sharding(state_axes, mesh, rules, state_abs)
            step = ts.make_train_step(cfg, opt, remat=remat,
                                      accum_steps=accum,
                                      grad_shardings=state_sh.params)
            jf = jax.jit(step, in_shardings=(state_sh, in_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
            lowered = jf.lower(state_abs, specs)
        elif shape.kind == "prefill":
            params_abs = lm.init_abstract(cfg)
            p_axes = lm.param_logical_axes(params_abs)
            p_sh = param_sharding(p_axes, mesh, rules, params_abs)

            def prefill_fn(params, inputs):
                return lm.prefill(params, cfg, max_len=shape.seq_len,
                                  remat=remat, **inputs)

            jf = jax.jit(prefill_fn, in_shardings=(p_sh, in_sh))
            lowered = jf.lower(params_abs, specs)
        else:  # decode
            params_abs = lm.init_abstract(cfg)
            p_axes = lm.param_logical_axes(params_abs)
            p_sh = param_sharding(p_axes, mesh, rules, params_abs)
            cache_abs = jax.eval_shape(
                lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len))
            c_axes = lm.cache_logical_axes(cache_abs)
            c_sh = param_sharding(c_axes, mesh, rules, cache_abs)

            def serve_step(params, cache, inputs):
                pos = inputs["pos"]
                kw = ({"token": inputs["token"]} if cfg.embed_inputs
                      else {"embed": inputs["embed"]})
                logits, new_cache = lm.decode_step(params, cfg, pos, cache,
                                                   **kw)
                return logits, new_cache

            jf = jax.jit(serve_step, in_shardings=(p_sh, c_sh, in_sh),
                         out_shardings=(None, c_sh), donate_argnums=(1,))
            lowered = jf.lower(params_abs, cache_abs, specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    out = _collect(compiled, f"{arch}/{shape_name}/{mesh_name}", n_dev,
                   cfg=cfg, shape=shape)
    out["timing"] = {"lower_s": t_lower, "compile_s": t_compile}
    out["remat"] = remat
    out["rules"] = {k: list(v) if isinstance(v, tuple) else v
                    for k, v in rules.items()}
    print(compiled.memory_analysis())
    ca = _cost_analysis(compiled)
    print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
    return out


def _lower_icp_cell(shape_name: str, mesh_name: str,
                    score_dtype: str = "fp32") -> dict:
    from repro.core.distributed import batched_icp_sharded
    from repro.core.icp import ICPParams

    spec = ICP_SHAPES[shape_name]
    mesh = _mesh_for(mesh_name)
    n_dev = mesh.devices.size
    f, n, m = spec["frames"], spec["n_src"], spec["m_dst"]
    frame_axes = batch_axes_for(mesh, f)
    # giant frame: spread the target over every remaining axis too
    target_axes = tuple(ax for ax in ("data", "model")
                        if ax not in frame_axes or f == 1)
    if f == 1:
        frame_axes = ()
        target_axes = tuple(mesh.axis_names)
    params = ICPParams(max_iterations=spec["iters"], chunk=2048,
                       score_dtype=score_dtype)

    def step(src_b, dst_b):
        return batched_icp_sharded(mesh, src_b, dst_b, params,
                                   frame_axes=frame_axes,
                                   target_axes=target_axes,
                                   fixed_iterations=True)

    src_abs = jax.ShapeDtypeStruct((f, n, 3), jnp.float32)
    dst_abs = jax.ShapeDtypeStruct((f, m, 3), jnp.float32)
    in_sh = (NamedSharding(mesh, P(frame_axes or None)),
             NamedSharding(mesh, P(frame_axes or None, target_axes)))
    t0 = time.time()
    with mesh:
        jf = jax.jit(step, in_shardings=in_sh)
        lowered = jf.lower(src_abs, dst_abs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    # useful flops: the xyz distance cross-term (2*3*N*M per iteration) —
    # augmentation/argmin overheads count against the engine, not the task
    useful = spec["iters"] * f * (2.0 * 3 * n * m) / n_dev
    out = _collect(compiled, f"fpps-icp/{shape_name}/{mesh_name}", n_dev,
                   model_flops_override=useful)
    out["timing"] = {"lower_s": t_lower, "compile_s": t_compile}
    out["icp_spec"] = spec
    out["sharding"] = {"frame_axes": list(frame_axes),
                       "target_axes": list(target_axes)}
    print(compiled.memory_analysis())
    return out


def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: pathlib.Path, remat: str = "full",
             rules_overrides: dict | None = None,
             accum: int | None = None,
             icp_score_dtype: str = "fp32",
             kv_quant: bool = False) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    try:
        if arch == "fpps-icp":
            rec = _lower_icp_cell(shape_name, mesh_name,
                                  score_dtype=icp_score_dtype)
        else:
            ok, reason = runnable_cell(arch, shape_name)
            if not ok:
                rec = {"label": f"{arch}/{shape_name}/{mesh_name}",
                       "skipped": True, "reason": reason}
                path.write_text(json.dumps(rec, indent=2))
                print(f"SKIP {rec['label']}: {reason}")
                return rec
            rec = _lower_lm_cell(arch, shape_name, mesh_name,
                                 rules_overrides, remat, accum=accum,
                                 kv_quant=kv_quant)
        rec["status"] = "ok"
    except Exception as e:  # record failures as artifacts, don't hide them
        rec = {"label": f"{arch}/{shape_name}/{mesh_name}", "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    path.write_text(json.dumps(rec, indent=2, default=str))
    status = rec.get("status")
    print(f"[{status}] {rec['label']} -> {path}")
    if status == "ok" and "roofline" in rec:
        r = rec["roofline"]
        print(f"  compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
              f"collective={r['collective_s']:.4f}s dominant={r['dominant']} "
              f"useful_frac={r['useful_fraction']:.3f}")
    return rec


def run_all(out_dir: pathlib.Path, meshes=("single", "multi"),
            only_missing: bool = True, timeout_s: int = 3600):
    """Spawn one subprocess per cell — isolates compile memory and keeps a
    single bad cell from killing the sweep."""
    all_cells = [(a, s) for (a, s) in cells()]
    all_cells += [("fpps-icp", s) for s in ICP_SHAPES]
    results = []
    for mesh_name in meshes:
        for arch, shape in all_cells:
            path = out_dir / f"{arch}__{shape}__{mesh_name}.json"
            if only_missing and path.exists():
                rec = json.loads(path.read_text())
                if rec.get("status") == "ok" or rec.get("skipped"):
                    continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_name,
                   "--out-dir", str(out_dir)]
            print("==>", " ".join(cmd), flush=True)
            t0 = time.time()
            proc = subprocess.run(cmd, timeout=timeout_s,
                                  capture_output=True, text=True)
            dt = time.time() - t0
            if proc.returncode != 0:
                err = {"label": f"{arch}/{shape}/{mesh_name}",
                       "status": "error",
                       "error": f"subprocess rc={proc.returncode}",
                       "stderr": proc.stderr[-4000:]}
                path.write_text(json.dumps(err, indent=2))
                print(f"[error rc={proc.returncode} {dt:.0f}s] "
                      f"{arch}/{shape}/{mesh_name}", flush=True)
            else:
                print(f"[done {dt:.0f}s] {arch}/{shape}/{mesh_name}",
                      flush=True)
            results.append(path)
    return results


def main():
    ap = argparse.ArgumentParser(description="FPPS multi-pod dry-run")
    ap.add_argument("--arch", choices=list_archs() + ["fpps-icp"])
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="with --all: re-run cells that already have results")
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots"])
    ap.add_argument("--accum", type=int, default=None,
                    help="gradient-accumulation depth (default: auto)")
    ap.add_argument("--icp-score-dtype", default="fp32",
                    choices=["fp32", "bf16"])
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache for decode cells")
    ap.add_argument("--rule", action="append", default=[],
                    help="logical-axis rule override, e.g. seq=data or "
                         "expert=; repeatable")
    ap.add_argument("--out-dir", default=str(RESULTS_DIR))
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    if args.all:
        run_all(out_dir, only_missing=not args.force)
        return
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    overrides = {}
    for r in args.rule:
        k, _, v = r.partition("=")
        overrides[k] = tuple(x for x in v.split(",") if x) or None
    run_cell(args.arch, args.shape, args.mesh, out_dir,
             remat=args.remat, rules_overrides=overrides or None,
             accum=args.accum, icp_score_dtype=args.icp_score_dtype,
             kv_quant=args.kv_quant)


if __name__ == "__main__":
    main()
