"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Production path: builds the mesh (when >1 device), shards state via the
logical rules, streams deterministic data with prefetch, checkpoints
atomically (async, keep-k), resumes from the latest checkpoint if present,
and runs the straggler watchdog. On this CPU container it runs reduced
configs (--smoke or --layers/--d-model overrides) — the same code path the
dry-run proves out at the production mesh sizes.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, get_smoke, list_archs
from repro.data.tokens import PrefetchLoader, TokenStream
from repro.optim import cosine_schedule, pick_optimizer
from repro.train import checkpoint as ckpt_lib
from repro.train import train_step as ts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    opt = pick_optimizer(cfg.total_params(),
                         cosine_schedule(args.lr, warmup_steps=20,
                                         total_steps=max(args.steps, 21)))
    step_fn = ts.make_train_step(cfg, opt, remat=args.remat,
                                 accum_steps=args.accum)

    devices = jax.devices()
    mesh = None
    if len(devices) > 1:
        from repro.compat import make_mesh
        mesh = make_mesh((len(devices),), ("data",))

    state = ts.init_state(jax.random.PRNGKey(args.seed), cfg, opt)
    start_step = 0
    manager = None
    if args.ckpt_dir:
        manager = ckpt_lib.CheckpointManager(args.ckpt_dir, keep_last_k=3,
                                             save_interval_steps=args.ckpt_every)
        if ckpt_lib.latest_step(args.ckpt_dir) is not None:
            state, start_step, _ = manager.restore_latest(
                jax.eval_shape(lambda: state))
            print(f"resumed from step {start_step}")

    stream = TokenStream(cfg.vocab_size, args.batch, args.seq, seed=args.seed,
                         embed_dim=None if cfg.embed_inputs else cfg.d_model)
    loader = PrefetchLoader(stream, start_step=start_step)
    watchdog = ckpt_lib.StragglerWatchdog()
    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    if manager is not None:
        ckpt_lib.install_preemption_handler(
            manager, lambda: (state, start_step))

    t_start = time.time()
    losses = []
    try:
        for step, batch in loader:
            if step >= args.steps:
                break
            t0 = time.time()
            state, metrics = jit_step(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            if watchdog.observe(step, dt):
                print(f"[watchdog] step {step} straggled: {dt:.2f}s "
                      f"(ema {watchdog.ema:.2f}s)")
            if step % args.log_every == 0:
                tok_s = args.batch * args.seq / dt
                print(f"step {step:5d} loss {loss:.4f} {dt*1e3:7.1f} ms "
                      f"{tok_s:9.0f} tok/s")
            if manager is not None and manager.should_save(step + 1):
                manager.save_async(state, step + 1)
            start_step = step + 1
    finally:
        loader.close()
        if manager is not None:
            manager.save_sync(state, start_step)
            manager.wait()
    total = time.time() - t_start
    print(f"done: {start_step} steps in {total:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
