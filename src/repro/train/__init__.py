"""Training substrate: step functions, checkpointing, fault tolerance."""
