"""Fault-tolerant checkpointing: atomic, async, keep-k, elastic restore.

Production behaviours implemented (and unit-tested in
tests/test_checkpoint.py):

  * **Atomicity** — writes go to ``step_N.tmp`` and are ``os.rename``d into
    place only after every payload + manifest is flushed; a crash mid-write
    can never leave a readable-but-corrupt checkpoint.
  * **Async save** — device arrays are fetched (device_get) synchronously
    (cheap; the training step owns the devices anyway), then serialisation
    happens on a background thread so the step loop is not blocked on disk.
  * **keep_last_k** — bounded disk usage with monotonic cleanup; the newest
    complete checkpoint is never deleted.
  * **Elastic restore** — checkpoints store full (unsharded) arrays plus a
    tree manifest; ``restore`` takes target shardings for *any* mesh shape,
    so a 512-chip run can restart on 256 chips (node failure) and reshard
    on load. For multi-host deployments the same layout works with
    process-0-coordinated gather (jax.experimental.multihost_utils);
    this container is single-process so device_get is already global.
  * **Preemption hook** — ``install_preemption_handler`` saves on
    SIGTERM/SIGINT before re-raising, the standard cloud-TPU eviction
    protocol.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import signal
import threading
from typing import Any, Callable

import jax
import numpy as np

_SEP = "::"


def _flatten(state) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(state)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", e))))
                        for e in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save(path: str | os.PathLike, state, step: int,
         extra: dict | None = None) -> pathlib.Path:
    """Atomic synchronous save. Returns the final checkpoint dir."""
    root = pathlib.Path(path)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:010d}"
    tmp = root / f"step_{step:010d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    arrays = _flatten(state)
    manifest = {"step": step, "extra": extra or {},
                "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                         for k, v in arrays.items()}}
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(path: str | os.PathLike) -> int | None:
    root = pathlib.Path(path)
    if not root.exists():
        return None
    steps = [int(m.group(1)) for p in root.iterdir()
             if (m := re.fullmatch(r"step_(\d+)", p.name))]
    return max(steps) if steps else None


def restore(path: str | os.PathLike, abstract_state, step: int | None = None,
            shardings=None):
    """Rebuild ``abstract_state``'s pytree from disk; place with
    ``shardings`` (same tree structure) if given — this is the elastic
    reshard path: the target mesh need not match the saving mesh."""
    root = pathlib.Path(path)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    ckpt = root / f"step_{step:010d}"
    data = np.load(ckpt / "arrays.npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
    sh_flat = (jax.tree_util.tree_leaves(shardings)
               if shardings is not None else [None] * len(flat))
    leaves = []
    for (pathk, leaf), sh in zip(flat, sh_flat):
        key = _SEP.join(str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", e))))
                        for e in pathk)
        arr = data[key]
        expect = tuple(leaf.shape)
        if tuple(arr.shape) != expect:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {expect}")
        arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      # tracecheck: ignore[TS004]  # dtype restored from leaf
                      else jax.numpy.asarray(arr))
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    manifest = json.loads((ckpt / "manifest.json").read_text())
    return state, manifest["step"], manifest.get("extra", {})


class CheckpointManager:
    """Async keep-k manager with preemption handling."""

    def __init__(self, directory: str | os.PathLike, keep_last_k: int = 3,
                 save_interval_steps: int = 100):
        self.dir = pathlib.Path(directory)
        self.keep = keep_last_k
        self.interval = save_interval_steps
        self._thread: threading.Thread | None = None
        self._last_saved: int | None = latest_step(self.dir)

    def should_save(self, step: int) -> bool:
        return step % self.interval == 0

    def save_async(self, state, step: int, extra: dict | None = None):
        """Fetch to host now; serialise + publish on a worker thread."""
        self.wait()  # one in-flight save at a time
        host_state = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            save(self.dir, host_state, step, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        self._last_saved = step

    def save_sync(self, state, step: int, extra: dict | None = None):
        self.wait()
        save(self.dir, state, step, extra)
        self._last_saved = step
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, abstract_state, shardings=None):
        self.wait()
        return restore(self.dir, abstract_state, shardings=shardings)

    def _gc(self):
        steps = sorted(int(m.group(1)) for p in self.dir.iterdir()
                       if (m := re.fullmatch(r"step_(\d+)", p.name)))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)
        for p in self.dir.glob("step_*.tmp"):  # crashed partial writes
            shutil.rmtree(p, ignore_errors=True)


def install_preemption_handler(manager: CheckpointManager,
                               get_state: Callable[[], tuple[Any, int]]):
    """SIGTERM/SIGINT -> synchronous save -> re-raise default behaviour."""
    def handler(signum, frame):
        state, step = get_state()
        manager.save_sync(state, step, extra={"preempted": True})
        signal.signal(signum, signal.SIG_DFL)
        os.kill(os.getpid(), signum)

    signal.signal(signal.SIGTERM, handler)
    signal.signal(signal.SIGINT, handler)
    return handler


class StragglerWatchdog:
    """Step-time EMA monitor: flags steps slower than ``threshold`` x the
    running mean — on a real fleet this triggers hot-spare swap /
    checkpoint-restart; here it logs and counts (tested in unit tests)."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.1):
        self.threshold = threshold
        self.alpha = alpha
        self.ema: float | None = None
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, duration_s: float) -> bool:
        is_straggler = (self.ema is not None
                        and duration_s > self.threshold * self.ema)
        if is_straggler:
            self.flagged.append((step, duration_s))
        self.ema = (duration_s if self.ema is None
                    else (1 - self.alpha) * self.ema + self.alpha * duration_s)
        return is_straggler
