"""Train step: value_and_grad + optimizer update, with microbatch gradient
accumulation and configurable remat. One function, jit/pjit-able; the
dry-run lowers exactly this."""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.optim import Optimizer


class TrainState(NamedTuple):
    params: Any
    opt_state: Any


def init_state(key, cfg: ArchConfig, optimizer: Optimizer,
               dtype=jnp.float32) -> TrainState:
    params = lm.init_params(key, cfg, dtype)
    return TrainState(params=params, opt_state=optimizer.init(params))


def abstract_state(cfg: ArchConfig, optimizer: Optimizer,
                   dtype=jnp.float32) -> TrainState:
    """ShapeDtypeStruct pytree — never allocates (dry-run path)."""
    return jax.eval_shape(
        functools.partial(init_state, cfg=cfg, optimizer=optimizer,
                          dtype=dtype), jax.random.PRNGKey(0))


def state_logical_axes(cfg: ArchConfig, optimizer: Optimizer,
                       dtype=jnp.float32) -> TrainState:
    abs_state = abstract_state(cfg, optimizer, dtype)
    p_axes = lm.param_logical_axes(abs_state.params)
    return TrainState(params=p_axes,
                      opt_state=optimizer.state_logical_axes(p_axes))


def make_train_step(cfg: ArchConfig, optimizer: Optimizer,
                    remat: str = "full", accum_steps: int = 1,
                    grad_shardings=None):
    """-> train_step(state, batch) -> (state, metrics).

    accum_steps > 1 splits the batch's leading dim into microbatches and
    accumulates grads in fp32 via lax.scan — the standard way to fit a large
    global batch per-device while keeping the matmul shapes big.

    grad_shardings (optional, params-shaped tree of NamedShardings): pins
    the accumulation buffer to the parameter shardings. Without it GSPMD
    left the fp32 accumulator unsharded and resolved every microbatch's
    weight-gradient partial sums with full all-reduces — 143 TB/step/device
    measured on llama3-405b (§Perf C1); constrained, each becomes a
    reduce-scatter onto the FSDP shard.
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True)(params, cfg, batch, remat)
        return loss, metrics, grads

    def train_step(state: TrainState, batch):
        if accum_steps == 1:
            loss, metrics, grads = grads_of(state.params, batch)
        else:
            def split(x):
                from repro.launch.partition import aconstraint
                b = x.shape[0]
                assert b % accum_steps == 0, (b, accum_steps)
                y = x.reshape((accum_steps, b // accum_steps) + x.shape[1:])
                # reshard once on the (small) input ids/embeds so every
                # microbatch is evenly batch-sharded
                return aconstraint(y, (None, "batch") + (None,) * (y.ndim - 2))
            micro = jax.tree_util.tree_map(split, batch)

            def _pin(tree):
                if grad_shardings is None:
                    return tree
                return jax.tree_util.tree_map(
                    lambda t, sh: jax.lax.with_sharding_constraint(t, sh),
                    tree, grad_shardings)

            def acc_fn(carry, mb):
                g_acc, loss_acc = carry
                loss, _, grads = grads_of(state.params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc,
                    _pin(grads))
                return (_pin(g_acc), loss_acc + loss), None

            zeros = _pin(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params))
            (grads, loss), _ = jax.lax.scan(
                acc_fn, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
            metrics = {}
        new_params, new_opt = optimizer.update(grads, state.opt_state,
                                               state.params)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return TrainState(params=new_params, opt_state=new_opt), metrics

    return train_step
