"""Voxel-grid hashing: jit-compatible spatial structure for point clouds.

Two structures, both fully static-shape so they compose with jit / vmap /
the shape-bucket collator (DESIGN.md §8):

  * :func:`voxel_downsample` — centroid reduction over occupied voxels.
    Cells are compacted by a sort + first-occurrence cumsum (no dense cell
    table needed), centroids accumulate via ``segment_sum``, and the output
    has a fixed ``max_points`` capacity with a validity mask — the same
    masking convention as ``repro.data.collate`` (invalid rows carry the
    far ``PAD_SENTINEL`` so even mask-unaware consumers stay correct).

  * :func:`build_voxel_grid` — a sorted-cell-id index over the cloud plus a
    dense per-cell (start, count) table, the classic GPU "counting sort"
    grid. Cell ids linearize a static ``dims`` lattice anchored at a
    per-cloud origin; the table supports O(1) lookup of any cell's point
    range, which is what the 27-neighbourhood gather in
    ``repro.core.nn_search_grid`` consumes.

Static-capacity semantics (everything here is a *bounded* structure):

  * ``voxel_downsample`` drops occupied cells beyond ``max_points``
    (deterministically, in cell-id sort order) — callers size the capacity
    for their scene, and the validity mask reports the real occupancy.
  * ``build_voxel_grid`` stores every valid point; capacity truncation
    happens at *query* time (``max_per_cell`` in the searcher), not here.
  * *Stored* points outside the ``dims`` lattice clip into the boundary
    cells (their coordinates stay exact, so distances computed from them
    are still right; only their neighbourhood membership degrades — size
    ``dims`` to the scene). *Queries* are different: the grid searcher
    resolves them with ``cell_coords(..., clip=False)`` so an
    out-of-lattice query sees an (honest) empty neighbourhood and is
    reported / brute-falled-back, never silently matched through a
    boundary cell it does not belong to. The rolling submap
    (``repro.data.submap``) re-anchors its origin so streaming queries
    stay inside the lattice in the first place.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.data.collate import PAD_SENTINEL


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class VoxelGrid:
    """Sorted counting-sort grid over one point cloud.

    ``points``/``point_ids`` are the cloud reordered by linearized cell id
    (invalid/padded rows sort to the tail and are unreachable through the
    table); ``start``/``count`` are dense per-cell tables of length
    ``prod(dims)``. ``dims`` is static (pytree aux data) so a VoxelGrid can
    cross jit boundaries without retracing on metadata.
    """

    points: jax.Array      # (M, 3) f32, sorted by cell id
    point_ids: jax.Array   # (M,) i32 — original row of each sorted point
    start: jax.Array       # (C,) i32 — first sorted row of each cell
    count: jax.Array       # (C,) i32 — valid points in each cell
    origin: jax.Array      # (3,) f32 — lattice anchor (cell [0,0,0] corner)
    voxel_size: jax.Array  # scalar f32
    dims: tuple[int, int, int]  # static lattice extent (nx, ny, nz)

    def tree_flatten(self):
        return ((self.points, self.point_ids, self.start, self.count,
                 self.origin, self.voxel_size), self.dims)

    @classmethod
    def tree_unflatten(cls, dims, leaves):
        return cls(*leaves, dims=dims)

    @property
    def num_cells(self) -> int:
        nx, ny, nz = self.dims
        return nx * ny * nz


def cell_coords(points: jax.Array, origin: jax.Array, voxel_size,
                dims: tuple[int, int, int], *, clip: bool = True) -> jax.Array:
    """(…,3) points -> (…,3) int32 lattice coords.

    With ``clip=True`` (the build-time convention) coordinates clip into
    ``dims``. ``clip=False`` keeps the true (possibly out-of-range) coords
    so query-side consumers can *detect* out-of-lattice points instead of
    silently treating them as boundary-cell residents — the searcher bug
    this distinction fixes (see ``repro.core.nn_search_grid``). The float
    coordinate is pre-clamped to the int32-safe range so far sentinels
    (±1e6 pads, 1e15 mask coords) stay finite, ordinary out-of-range ints.
    """
    ic_f = jnp.floor((points - origin) / voxel_size)
    ic = jnp.clip(ic_f, -2.0 ** 30, 2.0 ** 30).astype(jnp.int32)
    if clip:
        ic = jnp.clip(ic, 0, jnp.asarray(dims, jnp.int32) - 1)
    return ic


def linear_cell_ids(ic: jax.Array, dims: tuple[int, int, int]) -> jax.Array:
    """(…,3) lattice coords -> (…,) linearized ids (row-major, z fastest)."""
    _, ny, nz = dims
    return (ic[..., 0] * ny + ic[..., 1]) * nz + ic[..., 2]


def _masked_min(points: jax.Array, valid: jax.Array | None) -> jax.Array:
    """(M,3) min over valid rows; +inf rows never win."""
    if valid is None:
        return jnp.min(points, axis=0)
    big = jnp.asarray(jnp.inf, points.dtype)
    return jnp.min(jnp.where(valid[:, None], points, big), axis=0)


def _default_origin(points, valid, voxel_size):
    """Snap the valid-point minimum down to the voxel lattice, with half a
    voxel of slack so boundary points never land at a negative coord."""
    v = jnp.asarray(voxel_size, points.dtype)
    lo = _masked_min(points, valid) - 0.5 * v
    return jnp.floor(lo / v) * v


def voxel_downsample(points: jax.Array, voxel_size, *,
                     max_points: int,
                     valid: jax.Array | None = None,
                     origin: jax.Array | None = None,
                     with_stats: bool = False):
    """Centroid voxel downsample with static output capacity.

    Args:
      points: (M, 3) cloud.
      voxel_size: cell edge length (metres); may be traced.
      max_points: static output capacity. Occupied cells beyond it are
        dropped deterministically (highest cell ids first — the sort tail),
        so an undersized capacity degrades to a subsample, never an error.
      valid: optional (M,) bool — padded rows (``repro.data.collate``) are
        excluded from every centroid.
      origin: optional (3,) lattice anchor; default snaps the valid min to
        the voxel lattice.
      with_stats: also return the number of occupied cells that did NOT
        fit the capacity — the saturation signal a bare validity mask
        cannot express (a full mask reads the same whether the budget
        exactly fit or silently truncated).

    Returns:
      (centroids, out_valid): ((max_points, 3) f32, (max_points,) bool).
      Invalid output rows carry ``PAD_SENTINEL`` coordinates, matching the
      collator's convention, so downstream searchers need no special cases.
      With ``with_stats=True``, ``(centroids, out_valid, dropped)`` where
      ``dropped`` is an int32 scalar (0 when every occupied cell fit).
    """
    m = points.shape[0]
    cap = min(int(max_points), m)
    v = jnp.asarray(voxel_size, jnp.float32)
    pts = points.astype(jnp.float32)
    if origin is None:
        origin = _default_origin(pts, valid, v)
    ic = jnp.floor((pts - origin) / v).astype(jnp.int32)
    if valid is not None:
        # Push padded rows past every real cell so they sort to the tail.
        ic = jnp.where(valid[:, None], ic, jnp.int32(2 ** 30))
    # lexsort: last key is primary -> (x, y, z) major-to-minor cell order.
    order = jnp.lexsort((ic[:, 2], ic[:, 1], ic[:, 0]))
    ics = ic[order]
    ps = pts[order]
    vs = (valid[order] if valid is not None
          else jnp.ones((m,), dtype=bool))
    prev = jnp.roll(ics, 1, axis=0)
    new_cell = jnp.any(ics != prev, axis=-1).at[0].set(True)
    seg = jnp.cumsum(new_cell.astype(jnp.int32)) - 1      # compacted cell idx
    # Occupied-cell count BEFORE the capacity scatter drops the overflow:
    # valid rows carry compacted indices 0..occupied-1 (invalid rows sit in
    # tail cells of their own and are masked out here).
    occupied = jnp.max(jnp.where(vs, seg, -1)) + 1
    dropped = jnp.maximum(occupied - cap, 0).astype(jnp.int32)
    # Invalid rows (and overflow cells) scatter out of range -> dropped.
    seg = jnp.where(vs, seg, cap)
    ones = vs.astype(jnp.float32)
    sums = jax.ops.segment_sum(ps * ones[:, None], seg, num_segments=cap)
    cnt = jax.ops.segment_sum(ones, seg, num_segments=cap)
    out_valid = cnt > 0
    centroids = sums / jnp.maximum(cnt, 1.0)[:, None]
    centroids = jnp.where(out_valid[:, None], centroids,
                          jnp.asarray(PAD_SENTINEL, jnp.float32))
    if cap < int(max_points):  # honour the requested static capacity
        pad = int(max_points) - cap
        centroids = jnp.concatenate(
            [centroids, jnp.full((pad, 3), PAD_SENTINEL, jnp.float32)])
        out_valid = jnp.concatenate([out_valid, jnp.zeros((pad,), bool)])
    if with_stats:
        return centroids, out_valid, dropped
    return centroids, out_valid


def build_voxel_grid(points: jax.Array, voxel_size,
                     dims: tuple[int, int, int], *,
                     valid: jax.Array | None = None,
                     origin: jax.Array | None = None) -> VoxelGrid:
    """Counting-sort voxel grid over ``points`` (the once-per-frame build).

    ``dims`` is static (it sizes the dense tables); ``origin`` defaults to
    the valid-point minimum snapped to the lattice, so a ``dims`` lattice of
    ``dims * voxel_size`` metres anchored at the cloud covers the scene.
    Invalid rows are excluded from the tables entirely — they can never be
    returned as candidates.
    """
    nx, ny, nz = dims
    num_cells = nx * ny * nz
    v = jnp.asarray(voxel_size, jnp.float32)
    pts = points.astype(jnp.float32)
    if origin is None:
        origin = _default_origin(pts, valid, v)
    ids = linear_cell_ids(cell_coords(pts, origin, v, dims), dims)
    if valid is not None:
        ids = jnp.where(valid, ids, num_cells)  # tail id: dropped below
        ones = valid.astype(jnp.int32)
    else:
        ones = jnp.ones(ids.shape, jnp.int32)
    order = jnp.argsort(ids)  # stable: within-cell order = original order
    count = jax.ops.segment_sum(ones, ids, num_segments=num_cells)
    start = jnp.concatenate(
        [jnp.zeros((1,), count.dtype), jnp.cumsum(count)[:-1]])
    return VoxelGrid(points=pts[order], point_ids=order.astype(jnp.int32),
                     start=start.astype(jnp.int32),
                     count=count.astype(jnp.int32),
                     origin=origin.astype(jnp.float32), voxel_size=v,
                     dims=(nx, ny, nz))
