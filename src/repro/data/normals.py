"""Per-point surface-normal estimation over voxel-grid neighbourhoods.

Point-to-plane ICP (DESIGN.md §9) needs a unit normal per *target* point.
This module estimates them the classic way — fit a plane to each point's
local neighbourhood and take the plane normal — but with the repo's
static-shape discipline so the whole thing jits, vmaps over frame batches,
and composes with the shape-bucket collator:

  * neighbourhoods come from the PR-2 counting-sort
    :class:`repro.data.voxelize.VoxelGrid` via
    :func:`repro.core.nn_search_grid.gather_candidates` — the same bounded
    (2·rings+1)³ candidate machinery the grid NN searcher uses, so the
    per-point cost is O(27·K), never O(M);
  * the local covariance is accumulated in *query-relative* coordinates
    (``x - p``), which kills the catastrophic cancellation a raw-moment
    accumulation would suffer at scene scale (coords ~50 m, covariances
    ~voxel² — six fp32 digits apart);
  * the smallest-eigenvalue direction comes from the custom-call-free 3×3
    Jacobi SVD (``repro.core.svd3x3``) — symmetric PSD input, so the last
    right-singular vector is the minimal-variance axis;
  * outputs follow the collate conventions: a fixed (N, 3) normal array
    plus an (N,) validity mask. Invalid rows (too few neighbours, padded
    input rows, degenerate neighbourhoods) carry **zero** normals, so even
    mask-unaware consumers are safe — a zero normal contributes nothing to
    the point-to-plane normal equations.

Two neighbourhood modes:

  * ``"knn"`` (default) — the k nearest candidates (PCL's
    ``setKSearch``), selected by ``lax.top_k`` over the candidate ring;
  * ``"radius"`` — every candidate within ``radius`` metres. This is the
    mode the Pallas moment-sweep kernel (``repro.kernels.normals``)
    implements, since a fixed gate streams; parity between the two
    implementations is pinned in ``tests/test_normals.py``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.nn_search_grid import gather_candidates
from repro.core.svd3x3 import svd3x3
from repro.data.voxelize import VoxelGrid, build_voxel_grid

# Candidate slots whose d2 exceeds this are sentinel/masked slots (their
# coordinates sit at ~1e15; real scene distances are < 1e4 m²).
_SENTINEL_D2 = 1.0e12

# Default lattice: matches the pyramid's finest-level grid so a target
# frame can share one VoxelGrid between normal estimation and grid NN.
DEFAULT_GRID_DIMS: tuple[int, int, int] = (128, 128, 32)


class NormalParams(NamedTuple):
    """Static normal-estimation configuration (hashable — engines key their
    jit caches on it alongside ``ICPParams``)."""

    k: int = 16                    # neighbours per point ("knn" mode)
    radius: float = 1.0            # gate in metres ("radius" mode)
    neighborhood: str = "knn"      # "knn" | "radius"
    voxel_size: float = 1.0        # candidate-grid cell edge
    grid_dims: tuple[int, int, int] = DEFAULT_GRID_DIMS
    max_per_cell: int = 32         # candidate capacity per cell
    rings: int = 1                 # neighbourhood half-width in cells
    min_neighbors: int = 3         # plane fit needs >= 3 points
    chunk: int = 2048              # query rows processed per sweep


def accumulate_moments(rel: jax.Array, w: jax.Array):
    """Weighted moment sums of query-relative offsets.

    Args:
      rel: (..., C, 3) candidate offsets ``x_j - p`` for each query.
      w:   (..., C) weights (0/1 masks or robust weights).

    Returns:
      (cnt, s, ss): (...,) Σw, (..., 3) Σw·rel, (..., 3, 3) Σw·rel·relᵀ.
    """
    wf = w.astype(jnp.float32)
    relf = rel.astype(jnp.float32)
    cnt = jnp.sum(wf, axis=-1)
    s = jnp.sum(relf * wf[..., None], axis=-2)
    ss = jnp.einsum("...ci,...cj->...ij", relf * wf[..., None], relf)
    return cnt, s, ss


def moments_to_normals(cnt: jax.Array, s: jax.Array, ss: jax.Array, *,
                       min_neighbors: int = 3):
    """Covariance eigen-decomposition: moment sums -> (normals, valid).

    The covariance ``E[rel·relᵀ] - mean·meanᵀ`` is shift-invariant, so the
    same epilogue serves both the XLA path and the Pallas moment kernel
    (which accumulates in query-relative coordinates). Invalid rows (fewer
    than ``min_neighbors`` samples, or a neighbourhood too degenerate to
    define a plane) return a **zero** normal.
    """
    denom = jnp.maximum(cnt, 1.0)
    mean = s / denom[..., None]
    cov = ss / denom[..., None, None] - mean[..., :, None] * mean[..., None, :]
    # Symmetrise fp roundoff; Jacobi assumes nothing but it keeps U ~ V.
    cov = 0.5 * (cov + jnp.swapaxes(cov, -1, -2))
    _, sing, Vt = jax.vmap(svd3x3)(cov.reshape(-1, 3, 3))
    sing = sing.reshape(cov.shape[:-2] + (3,))
    normal = Vt[:, 2, :].reshape(cov.shape[:-2] + (3,))
    norm = jnp.linalg.norm(normal, axis=-1, keepdims=True)
    normal = normal / jnp.maximum(norm, 1e-30)
    # A plane needs spread in two directions: the middle singular value of
    # a collinear (or empty) neighbourhood collapses to ~0. The threshold
    # is *relative* to the dominant spread — fp32 covariance roundoff
    # leaves an absolute floor of ~eps·σ₀² on σ₁ even for exact lines.
    valid = ((cnt >= min_neighbors)
             & (sing[..., 0] > 1e-12)
             & (sing[..., 1] > 1e-5 * sing[..., 0]))
    return jnp.where(valid[..., None], normal, 0.0), valid


def orient_normals(points: jax.Array, normals: jax.Array,
                   viewpoint: jax.Array | None = None) -> jax.Array:
    """Flip each normal toward ``viewpoint`` (default: the sensor origin).

    Scans are in the sensor frame here, so orienting toward the origin is
    PCL's ``flipNormalTowardsViewpoint`` with the default viewpoint.
    """
    if viewpoint is None:
        viewpoint = jnp.zeros((3,), points.dtype)
    to_vp = viewpoint - points
    flip = jnp.sum(normals * to_vp, axis=-1) < 0.0
    return jnp.where(flip[..., None], -normals, normals)


def _chunk_moments(points, grid: VoxelGrid, params: NormalParams):
    """Moment sums for every query row, swept ``params.chunk`` rows at a
    time so the (chunk, 27K, 3) candidate tile — not an (N, 27K, 3)
    monster — is the peak live buffer (the normals analogue of the brute
    searcher's target chunking)."""
    n = points.shape[0]
    chunk = min(params.chunk, n)
    pad = (-n) % chunk
    pts = jnp.concatenate(
        [points, jnp.full((pad, 3), 1e15, points.dtype)], axis=0)
    blocks = pts.reshape(-1, chunk, 3)

    def one_block(blk):
        cand_pts, _, cand_valid = gather_candidates(
            blk, grid, params.max_per_cell, params.rings)
        rel = cand_pts - blk[:, None, :].astype(jnp.float32)
        d2 = jnp.sum(rel * rel, axis=-1)
        if params.neighborhood == "knn":
            k = min(params.k, d2.shape[1])
            neg_d2, sel = jax.lax.top_k(-d2, k)
            w = (-neg_d2) < _SENTINEL_D2
            rel_sel = jnp.take_along_axis(rel, sel[..., None], axis=1)
        elif params.neighborhood == "radius":
            w = cand_valid & (d2 <= params.radius ** 2)
            rel_sel = rel
        else:
            raise ValueError(
                f"unknown neighborhood {params.neighborhood!r}; "
                f"expected 'knn' or 'radius'")
        return accumulate_moments(rel_sel, w)

    cnt, s, ss = jax.lax.map(one_block, blocks)
    return (cnt.reshape(-1)[:n], s.reshape(-1, 3)[:n],
            ss.reshape(-1, 3, 3)[:n])


def estimate_normals(points: jax.Array,
                     params: NormalParams = NormalParams(), *,
                     valid: jax.Array | None = None,
                     viewpoint: jax.Array | None = None,
                     grid: VoxelGrid | None = None):
    """Estimate a unit normal per point of one (N, 3) cloud.

    Args:
      points: (N, 3) cloud (tolerates collate padding when ``valid`` marks
        it — padded rows get zero normals and ``False`` validity).
      params: static :class:`NormalParams`.
      valid: optional (N,) mask of real rows.
      viewpoint: (3,) orientation viewpoint; default sensor origin.
      grid: optional pre-built :class:`VoxelGrid` over ``points`` (reuse
        the pyramid's resident grid); built here when absent.

    Returns:
      (normals, normal_valid): ((N, 3) f32 unit normals — zero rows where
      invalid — and the (N,) bool mask).
    """
    pts = points.astype(jnp.float32)
    if grid is None:
        grid = build_voxel_grid(pts, params.voxel_size, params.grid_dims,
                                valid=valid)
    cnt, s, ss = _chunk_moments(pts, grid, params)
    normals, nvalid = moments_to_normals(cnt, s, ss,
                                         min_neighbors=params.min_neighbors)
    normals = orient_normals(pts, normals, viewpoint)
    if valid is not None:
        nvalid = nvalid & valid
        normals = jnp.where(nvalid[..., None], normals, 0.0)
    return normals, nvalid


def default_target_normals(target: jax.Array,
                           valid: jax.Array | None = None) -> jax.Array:
    """Trace-scope target normals with the default config — the shared
    entry point for every ICP path that auto-estimates when the plane
    minimiser is selected without explicit normals (``core.icp`` and the
    engines; the pyramid uses its own grid-matched params instead).

    Must run on the *true* cloud with its *true* valid mask, before any
    sentinel-masking of padded rows — sentinel rows at 1e6 m would
    otherwise pollute boundary-cell neighbourhoods in the grid.
    """
    normals, _ = estimate_normals(target, NormalParams(), valid=valid)
    return normals


def estimate_normals_batch(points: jax.Array,
                           params: NormalParams = NormalParams(), *,
                           valid: jax.Array | None = None,
                           viewpoint: jax.Array | None = None):
    """vmap of :func:`estimate_normals` over a (B, N, 3) frame batch."""

    def one(pts, v):
        return estimate_normals(pts, params, valid=v, viewpoint=viewpoint)

    if valid is None:
        valid = jnp.ones(points.shape[:2], dtype=bool)
    return jax.vmap(one)(points, valid)
