"""Deterministic sensor-fault injectors for point-cloud streams (DESIGN.md §12).

FPPS targets embedded autonomous platforms where LiDAR input is routinely
degraded — occlusion by close traffic, random dropout from low-reflectance
surfaces, heavy-tailed range noise in rain, ghost returns off dynamic
objects, duplicated points from firmware glitches, whole NaN/Inf rows from
driver faults, and dropped frames on a saturated bus. This module is the
*fault model* those scenarios compile down to: a small algebra of pure,
seeded injectors over ``(points, valid)`` clouds.

Conventions (shared with ``repro.data.collate``):

  * Every injector is a **pure function** of its inputs and an integer
    ``seed`` — same seed, same cloud in, byte-identical cloud out. No
    global RNG state is read or written, so injectors compose and the
    whole fault matrix is reproducible from one base seed.
  * Injectors take and return ``(points (N,3) float32, valid (N,) bool)``.
    Rows an injector *removes* (occlusion, dropout, crop, frame drop) are
    masked invalid and moved to the far ``PAD_SENTINEL``, so downstream
    consumers that ignore masks stay correct — identical to collate pads.
  * Rows an injector *adds* (ghosts, duplicates) are appended, flagged
    valid: the sensor reports them as real returns, and it is the
    pipeline's job to survive them.
  * ``inject_nonfinite`` is the deliberate exception: corrupted rows keep
    ``valid=True`` while carrying NaN/Inf coordinates — a faulty driver
    does not mark its garbage, so neither does the injector. The engine
    boundary's scrub (``repro.core.icp.scrub_nonfinite``) is what must
    catch these.

Fault specs: the compact string form the drivers and benchmarks share,
``"dropout:0.3,occlusion:90deg,nan:10"`` — see :func:`parse_fault_spec`
and :func:`apply_faults`. Per-frame seeds derive deterministically from
``(seed, frame, injector name)``, so a stream replays exactly.
"""
from __future__ import annotations

import zlib
from typing import Callable, NamedTuple, Sequence

import numpy as np

from repro.data.collate import PAD_SENTINEL


def _as_cloud(points, valid):
    pts = np.asarray(points, dtype=np.float32)
    if valid is None:
        valid = np.ones((pts.shape[0],), dtype=bool)
    else:
        valid = np.asarray(valid, dtype=bool).copy()
    return pts.copy(), valid


def _mask_rows(pts: np.ndarray, valid: np.ndarray,
               drop: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Invalidate ``drop`` rows and park them at the collate sentinel."""
    valid = valid & ~drop
    pts[~valid] = PAD_SENTINEL
    return pts, valid


# -- removal faults ----------------------------------------------------------

def sector_occlusion(points, valid=None, *, seed: int = 0,
                     width_deg: float = 90.0,
                     center_deg: float | None = None):
    """Occlude an azimuth sector (a truck beside the ego, a tunnel wall).

    ``width_deg`` of azimuth centred at ``center_deg`` (drawn from ``seed``
    when None) vanishes from the scan. Sensor-frame clouds put the ego at
    the origin, so azimuth is ``atan2(y, x)``.
    """
    pts, valid = _as_cloud(points, valid)
    rng = np.random.default_rng(seed)
    center = (rng.uniform(-180.0, 180.0) if center_deg is None
              else float(center_deg))
    az = np.degrees(np.arctan2(pts[:, 1], pts[:, 0]))
    # Wrapped angular distance to the sector centre.
    dist = np.abs((az - center + 180.0) % 360.0 - 180.0)
    return _mask_rows(pts, valid, valid & (dist <= width_deg / 2.0))


def random_dropout(points, valid=None, *, seed: int = 0, frac: float = 0.3):
    """Drop a random ``frac`` of the valid returns (low-reflectance loss)."""
    pts, valid = _as_cloud(points, valid)
    rng = np.random.default_rng(seed)
    drop = valid & (rng.random(pts.shape[0]) < float(frac))
    return _mask_rows(pts, valid, drop)


def low_overlap_crop(points, valid=None, *, seed: int = 0,
                     keep_frac: float = 0.4):
    """Keep only a contiguous azimuth window covering ``keep_frac`` of the
    sweep — the low-overlap regime where correspondence-starved ICP slides
    (the failure mode the correspondence-free FPGA lines target)."""
    pts, valid = _as_cloud(points, valid)
    rng = np.random.default_rng(seed)
    center = rng.uniform(-180.0, 180.0)
    half = 180.0 * float(keep_frac)
    az = np.degrees(np.arctan2(pts[:, 1], pts[:, 0]))
    dist = np.abs((az - center + 180.0) % 360.0 - 180.0)
    return _mask_rows(pts, valid, valid & (dist > half))


def frame_drop(points, valid=None, *, seed: int = 0):
    """Lose the whole frame (bus saturation): every row masked invalid.

    The shape survives so stream collation is undisturbed; registration
    against an all-invalid source is the degenerate case the zero-inlier
    freeze already handles — the recovery cascade's tier-4 coast is what
    turns it into a survivable event.
    """
    pts, valid = _as_cloud(points, valid)
    return _mask_rows(pts, valid, valid.copy())


# -- perturbation faults -----------------------------------------------------

def range_noise(points, valid=None, *, seed: int = 0, std: float = 0.05,
                heavy_tail: bool = False, df: float = 2.0):
    """Range (radial) noise: each return slides along its own ray.

    ``heavy_tail=True`` draws Student-t(``df``) steps instead of Gaussian —
    the rain/spray regime where a fat tail of multi-metre outliers rides a
    small-sigma core. Invalid rows are untouched (they are sentinels).
    """
    pts, valid = _as_cloud(points, valid)
    rng = np.random.default_rng(seed)
    n = pts.shape[0]
    step = (rng.standard_t(float(df), n) if heavy_tail
            else rng.standard_normal(n)) * float(std)
    r = np.linalg.norm(pts, axis=1)
    ray = pts / np.maximum(r, 1e-6)[:, None]
    pts = np.where(valid[:, None], pts + ray * step[:, None], pts)
    return pts.astype(np.float32), valid


# -- additive faults ---------------------------------------------------------

def ghost_points(points, valid=None, *, seed: int = 0, count: int = 256,
                 radius: float = 8.0, offset: float = 6.0):
    """Append a ghost cluster (dynamic object / multipath blob).

    ``count`` points in a ``radius``-sized cluster ``offset`` metres from
    the ego, flagged valid — the sensor believes them. Clustered (not
    uniform) on purpose: a coherent blob biases registration the way a
    passing vehicle does, where uniform noise would mostly be gated out.
    """
    pts, valid = _as_cloud(points, valid)
    rng = np.random.default_rng(seed)
    az = rng.uniform(-np.pi, np.pi)
    center = np.array([offset * np.cos(az), offset * np.sin(az),
                       rng.uniform(0.0, 2.0)], dtype=np.float32)
    blob = center + rng.normal(0.0, radius / 4.0,
                               (int(count), 3)).astype(np.float32)
    return (np.concatenate([pts, blob.astype(np.float32)], axis=0),
            np.concatenate([valid, np.ones(int(count), bool)]))


def duplicate_points(points, valid=None, *, seed: int = 0, count: int = 256):
    """Append exact duplicates of random valid rows (firmware echo)."""
    pts, valid = _as_cloud(points, valid)
    rng = np.random.default_rng(seed)
    idx = np.flatnonzero(valid)
    if idx.size == 0:
        return pts, valid
    sel = rng.choice(idx, size=int(count), replace=True)
    return (np.concatenate([pts, pts[sel]], axis=0),
            np.concatenate([valid, np.ones(int(count), bool)]))


def inject_nonfinite(points, valid=None, *, seed: int = 0, count: int = 8,
                     inf_frac: float = 0.25):
    """Corrupt ``count`` valid rows to NaN (or ±Inf for ``inf_frac`` of
    them) — **leaving them flagged valid**, like the driver fault they
    model. This is the poison the engine-boundary scrub must neutralise."""
    pts, valid = _as_cloud(points, valid)
    rng = np.random.default_rng(seed)
    idx = np.flatnonzero(valid)
    if idx.size == 0:
        return pts, valid
    sel = rng.choice(idx, size=min(int(count), idx.size), replace=False)
    is_inf = rng.random(sel.size) < float(inf_frac)
    pts[sel] = np.nan
    pts[sel[is_inf]] = np.inf
    pts[sel[is_inf], 1] = -np.inf
    return pts, valid


# -- fault specs -------------------------------------------------------------

class FaultSpec(NamedTuple):
    """One parsed injector invocation: ``fn(points, valid, seed=...)``."""
    name: str
    fn: Callable
    kwargs: dict


def _parse_value(raw: str) -> float:
    return float(raw.rstrip("degm"))


# spec key -> (injector, value -> kwargs). Values are single scalars in the
# compact string form; call injectors directly for the full kwarg surface.
_SPEC_TABLE: dict[str, tuple[Callable, Callable[[float], dict]]] = {
    "occlusion": (sector_occlusion, lambda v: {"width_deg": v}),
    "dropout": (random_dropout, lambda v: {"frac": v}),
    "crop": (low_overlap_crop, lambda v: {"keep_frac": v}),
    "noise": (range_noise, lambda v: {"std": v}),
    "tnoise": (range_noise, lambda v: {"std": v, "heavy_tail": True}),
    "ghost": (ghost_points, lambda v: {"count": int(v)}),
    "dup": (duplicate_points, lambda v: {"count": int(v)}),
    "nan": (inject_nonfinite, lambda v: {"count": int(v)}),
    "drop": (frame_drop, lambda v: {}),
}

FAULT_NAMES = tuple(sorted(_SPEC_TABLE))


def parse_fault_spec(spec: str | Sequence[FaultSpec]) -> tuple[FaultSpec, ...]:
    """Parse ``"dropout:0.3,occlusion:90deg,nan:10"`` into injector calls.

    Each comma-separated entry is ``name[:value]``; the value's meaning is
    per-injector (fraction, degrees, count, metres — units suffixes
    ``deg``/``m`` are accepted and ignored). Already-parsed specs pass
    through, so callers can hand either form around.
    """
    if not isinstance(spec, str):
        return tuple(spec)
    out = []
    for entry in spec.split(","):
        entry = entry.strip()
        if not entry:
            continue
        name, _, raw = entry.partition(":")
        name = name.strip()
        if name not in _SPEC_TABLE:
            raise ValueError(f"unknown fault {name!r}; "
                             f"expected one of {FAULT_NAMES}")
        fn, to_kwargs = _SPEC_TABLE[name]
        kwargs = to_kwargs(_parse_value(raw.strip())) if raw.strip() else {}
        out.append(FaultSpec(name=name, fn=fn, kwargs=kwargs))
    return tuple(out)


def fault_seed(seed: int, frame: int, name: str) -> int:
    """Deterministic per-(stream, frame, injector) seed — crc32 keeps it
    stable across processes/platforms (unlike ``hash``)."""
    key = f"{seed}/{frame}/{name}".encode()
    return int(zlib.crc32(key))


def apply_faults(points, spec: str | Sequence[FaultSpec], *, seed: int = 0,
                 frame: int = 0, valid=None):
    """Run every injector of ``spec`` over the cloud, in spec order.

    Seeds derive from ``(seed, frame, injector name)``, so one base seed
    replays an entire faulted stream deterministically and two injectors in
    one frame never share a random stream.
    """
    pts, valid = _as_cloud(points, valid)
    for fault in parse_fault_spec(spec):
        pts, valid = fault.fn(pts, valid,
                              seed=fault_seed(seed, frame, fault.name),
                              **fault.kwargs)
    return pts, valid
