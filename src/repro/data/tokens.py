"""Synthetic LM token pipeline: deterministic, sharding-aware, prefetched.

Stands in for a production data loader: per-step batches are generated from
a seeded Zipf-ish unigram stream on the host, placed onto the mesh with the
trainer's batch sharding, and prefetched on a background thread so host
data work overlaps device compute (the standard input-pipeline overlap
trick). Determinism: batch content is a pure function of (seed, step), so
restart-after-crash resumes bit-identically from a checkpointed step.
"""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np


class TokenStream:
    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, embed_dim: int | None = None):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        self.embed_dim = embed_dim  # set for embeds-input (vlm/audio) archs
        # Zipf-like unigram distribution (fixed across steps).
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._p = p / p.sum()

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step) -> host numpy batch."""
        rng = np.random.default_rng((self.seed * 1_000_003 + step) & 0x7FFFFFFF)
        toks = rng.choice(self.vocab, size=(self.batch, self.seq + 1),
                          p=self._p).astype(np.int32)
        out = {"labels": toks[:, 1:]}
        if self.embed_dim is None:
            out["tokens"] = toks[:, :-1]
        else:
            # frontend stub: precomputed frame/patch embeddings
            out["embeds"] = rng.standard_normal(
                (self.batch, self.seq, self.embed_dim)).astype(np.float32) * 0.1
        return out


class PrefetchLoader:
    """Background-thread prefetch + device placement."""

    def __init__(self, stream: TokenStream, shardings: dict | None = None,
                 start_step: int = 0, prefetch: int = 2):
        self.stream = stream
        self.shardings = shardings or {}
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _place(self, batch: dict):
        out = {}
        for k, v in batch.items():
            sh = self.shardings.get(k)
            out[k] = jax.device_put(v, sh) if sh is not None else v
        return out

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            b = self.stream.batch_at(step)
            try:
                self._q.put((step, self._place(b)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
