"""Synthetic KITTI-like LiDAR scene generator.

KITTI itself is not redistributable inside this offline container, so the
benchmark harness synthesises structurally similar scenes: a ground plane,
building facades, poles and scattered clutter, scanned with range-limited
sensor noise from a moving ego pose. Ten seeded "sequences" with different
motion profiles stand in for KITTI odometry 00-09 (DESIGN.md §7). All the
paper's *relative* claims (accuracy parity vs k-d tree baseline, speedup,
convergence behaviour) are evaluated on these.

Frame generation is pure numpy (host data path, like a real loader);
samplers return float32 (N,3) arrays.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Motion profiles per synthetic sequence: (speed m/frame, yaw-rate rad/frame).
# Loosely shaped on KITTI odometry: 01 is highway (fast), 03 suburban turns, etc.
_SEQ_PROFILES = {
    0: (0.8, 0.010), 1: (2.5, 0.002), 2: (1.0, 0.008), 3: (0.7, 0.020),
    4: (1.8, 0.001), 5: (0.9, 0.012), 6: (1.5, 0.006), 7: (0.6, 0.015),
    8: (1.1, 0.009), 9: (1.6, 0.005),
}


@dataclasses.dataclass
class SceneConfig:
    n_ground: int = 60_000
    n_walls: int = 45_000
    n_poles: int = 12_000
    n_clutter: int = 13_000     # total ≈ 130k, the paper's per-frame NN candidate count
    extent: float = 60.0        # half-width of the scene, metres
    sensor_range: float = 55.0
    noise_std: float = 0.02     # LiDAR range noise, metres


def _rot_z(yaw: float) -> np.ndarray:
    c, s = np.cos(yaw), np.sin(yaw)
    return np.array([[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]])


def make_world(seed: int, cfg: SceneConfig = SceneConfig(),
               point_seed: int | None = None) -> np.ndarray:
    """Build a world point set (float64 internally for pose math).

    ``point_seed=None`` (default) reproduces the original static world
    byte-for-byte: one rng stream draws both the scene *layout* (building
    placement, pole positions, heights) and the *surface sample points*.

    With ``point_seed`` set, surface points draw from a separate stream
    while the layout stays pinned by ``seed`` — the same scene, freshly
    sampled. Real LiDAR never hits the same surface points twice; a
    static world therefore hands frame-to-frame ICP an unrealistic
    point-identity correspondence. Odometry streams should draw one
    ``point_seed`` per frame (:func:`sequence_scans`) so consecutive
    frames share *surfaces*, not samples.
    """
    rng = np.random.default_rng(1000 + seed)
    # prng draws surface samples; aliasing it to rng keeps the legacy
    # single-stream draw order exactly (baseline scenes are pinned by it).
    prng = (rng if point_seed is None
            else np.random.default_rng(2_000_000_000 + point_seed))
    e = cfg.extent
    # Ground plane with gentle undulation (z is a function of x, y, so
    # resampled grounds lie on the same surface).
    g_xy = prng.uniform(-2 * e, 2 * e, size=(cfg.n_ground, 2))
    g_z = 0.05 * np.sin(0.08 * g_xy[:, 0]) * np.cos(0.05 * g_xy[:, 1])
    ground = np.column_stack([g_xy, g_z])
    # Building facades: vertical planes along the corridor.
    walls = []
    n_buildings = 14
    per = cfg.n_walls // n_buildings
    for _ in range(n_buildings):
        cx = rng.uniform(-2 * e, 2 * e)
        cy = rng.uniform(-e, e) + np.sign(rng.standard_normal()) * rng.uniform(8, 20)
        w, h = rng.uniform(8, 25), rng.uniform(4, 12)
        axis = rng.integers(0, 2)
        u = prng.uniform(-w / 2, w / 2, per)
        z = prng.uniform(0, h, per)
        if axis == 0:
            pts = np.column_stack([cx + u, np.full(per, cy), z])
        else:
            pts = np.column_stack([np.full(per, cx), cy + u, z])
        walls.append(pts)
    walls = np.concatenate(walls, axis=0)
    # Poles (trees / signs): thin vertical cylinders.
    n_poles_obj = 60
    per_pole = cfg.n_poles // n_poles_obj
    px = rng.uniform(-2 * e, 2 * e, n_poles_obj)
    py = rng.uniform(-e, e, n_poles_obj)
    poles = []
    for i in range(n_poles_obj):
        theta = prng.uniform(0, 2 * np.pi, per_pole)
        r = rng.uniform(0.05, 0.25)
        z = prng.uniform(0, rng.uniform(2, 6), per_pole)
        poles.append(np.column_stack([px[i] + r * np.cos(theta),
                                      py[i] + r * np.sin(theta), z]))
    poles = np.concatenate(poles, axis=0)
    clutter = np.column_stack([
        prng.uniform(-2 * e, 2 * e, cfg.n_clutter),
        prng.uniform(-e, e, cfg.n_clutter),
        np.abs(prng.normal(0.5, 0.5, cfg.n_clutter)),
    ])
    return np.concatenate([ground, walls, poles, clutter], axis=0)


def ego_pose(seq: int, frame: int) -> tuple[np.ndarray, np.ndarray]:
    """Ground-truth pose (R, t) of the ego vehicle at ``frame``."""
    speed, yaw_rate = _SEQ_PROFILES[seq % 10]
    yaw = yaw_rate * frame
    # Integrate an arc (constant curvature per profile).
    if abs(yaw_rate) < 1e-9:
        x, y = speed * frame, 0.0
    else:
        radius = speed / yaw_rate
        x = radius * np.sin(yaw)
        y = radius * (1.0 - np.cos(yaw))
    return _rot_z(yaw), np.array([x, y, 0.0])


def gt_pose(seq: int):
    """Frame-0-anchored ground-truth pose lookup for a sequence.

    Returns ``gt(frame) -> (4, 4)``: the pose of ``frame``'s sensor in
    frame-0 coordinates — the trajectory every odometry driver measures
    drift against. The frame-0 anchor is computed once; it is
    loop-invariant across a whole trajectory evaluation.
    """
    R0, t0 = ego_pose(seq, 0)

    def gt(frame: int) -> np.ndarray:
        R1, t1 = ego_pose(seq, frame)
        T = np.eye(4)
        T[:3, :3] = R0.T @ R1
        T[:3, 3] = R0.T @ (t1 - t0)
        return T

    return gt


def sample_consecutive_pairs(scans, samples: int, seed: int = 0):
    """(sampled_source, full_target) pairs of consecutive stream frames.

    The frame-to-frame protocol's pair construction (§IV-A source
    sampling), shared by the odometry example and the drift benchmark so
    they measure the same thing by construction.
    """
    rng = np.random.default_rng(seed)
    pairs = []
    for f in range(len(scans) - 1):
        sel = rng.choice(scans[f].shape[0],
                         min(samples, scans[f].shape[0]), replace=False)
        pairs.append((scans[f][sel], scans[f + 1]))
    return pairs


def scan_frame(world: np.ndarray, seq: int, frame: int,
               cfg: SceneConfig = SceneConfig(), seed: int = 0) -> np.ndarray:
    """Scan the world from the ego pose at ``frame``: sensor-frame points.

    Range-gated, with additive noise — what a registration stack sees.
    """
    rng = np.random.default_rng(seed * 100_003 + seq * 1009 + frame)
    R, t = ego_pose(seq, frame)
    local = (world - t) @ R            # world -> sensor frame (R is orthogonal)
    r = np.linalg.norm(local, axis=1)
    keep = r <= cfg.sensor_range
    pts = local[keep]
    pts = pts + rng.normal(0.0, cfg.noise_std, pts.shape)
    return pts.astype(np.float32)


def sequence_scans(seq: int, frames: int, cfg: SceneConfig = SceneConfig(),
                   resample: bool = True, seed: int = 0) -> list[np.ndarray]:
    """Sensor-frame scan stream for frames ``0..frames-1`` of a sequence.

    ``resample=True`` (the odometry protocol) redraws surface sample
    points per frame from the pinned scene layout — consecutive frames
    then share surfaces but not samples, like a real spinning LiDAR.
    ``resample=False`` scans one static world (the legacy protocol —
    identical points across frames give pairwise ICP an exact
    point-identity correspondence no real sensor provides).
    """
    if not resample:
        world = make_world(seq, cfg)
        return [scan_frame(world, seq, f, cfg, seed) for f in range(frames)]
    out = []
    for f in range(frames):
        world = make_world(seq, cfg, point_seed=seed * 65_537 + f)
        out.append(scan_frame(world, seq, f, cfg, seed))
    return out


def frame_pair(seq: int, frame: int, cfg: SceneConfig = SceneConfig(),
               n_source_samples: int = 4096, seed: int = 0):
    """(source_sampled, target_full, T_gt): consecutive-frame registration task.

    Matches the paper's protocol (§IV-A): 4096 points randomly sampled from
    the source frame; the full target cloud is the NN search space. T_gt maps
    frame ``frame``'s sensor coordinates onto frame ``frame+1``'s.

    Builds the world per call; sequence drivers should build it once and
    use :func:`frame_pair_from_world`.
    """
    world = make_world(seq, cfg)
    return frame_pair_from_world(world, seq, frame, cfg, n_source_samples,
                                 seed)


def frame_pair_from_world(world: np.ndarray, seq: int, frame: int,
                          cfg: SceneConfig = SceneConfig(),
                          n_source_samples: int = 4096, seed: int = 0):
    """:func:`frame_pair` against a prebuilt world — identical outputs,
    amortises the world build over a whole sequence (odometry drivers)."""
    src = scan_frame(world, seq, frame, cfg, seed)
    dst = scan_frame(world, seq, frame + 1, cfg, seed)
    rng = np.random.default_rng(seed * 7 + seq * 31 + frame)
    sel = rng.choice(src.shape[0], size=min(n_source_samples, src.shape[0]),
                     replace=False)
    src_s = src[sel]
    R0, t0 = ego_pose(seq, frame)
    R1, t1 = ego_pose(seq, frame + 1)
    # x_sensor1 = R1ᵀ(x_world - t1); x_world = R0 x_sensor0 + t0
    R_gt = R1.T @ R0
    t_gt = R1.T @ (t0 - t1)
    T_gt = np.eye(4)
    T_gt[:3, :3] = R_gt
    T_gt[:3, 3] = t_gt
    return src_s.astype(np.float32), dst.astype(np.float32), T_gt.astype(np.float32)
