"""Shape-bucket collation for batched registration (DESIGN.md §3).

Real LiDAR frames have variable point counts (range gating drops a
different subset every scan), but one compiled executable needs fixed
shapes. The collator pads every cloud up to a *bucket* size from a small
geometric ladder, so an entire sequence lands in one (B, N_b, 3)/(B, M_b, 3)
batch and the jit cache sees a handful of shapes instead of one per frame.

Padding uses a finite far-away sentinel (±1e6 m): padded *target* rows can
never win a nearest-neighbour argmin against real scene points, and padded
*source* rows always fail the correspondence-distance gate — so even an
engine that ignores the masks stays correct. The masks are still produced
and threaded (``dst_valid`` into the exact searcher, ``src_valid`` into the
Kabsch weights) so results are bit-comparable to the unpadded run.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np

# Far outside any metric scene, but finite: inf coordinates would turn the
# matmul distance expansion into inf - inf = NaN (see core.nn_search).
PAD_SENTINEL = 1.0e6

# Geometric ~1.5x ladder (all multiples of 128, so every bucket is
# tile-aligned for the Pallas kernel); worst-case padding waste ~33%.
# Sizes above the top round up to the top's multiple.
DEFAULT_BUCKETS: tuple[int, ...] = (256, 384, 512, 768, 1024, 1536, 2048,
                                    3072, 4096, 6144, 8192, 12288, 16384,
                                    24576, 32768, 49152, 65536, 98304, 131072)


def bucket_size(n: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket >= n (multiples of the largest bucket beyond the top)."""
    if n <= 0:
        raise ValueError(f"cloud must be non-empty, got n={n}")
    for b in buckets:
        if n <= b:
            return b
    top = buckets[-1]
    return ((n + top - 1) // top) * top


def pad_cloud(points: np.ndarray, size: int):
    """Pad (n,3) -> ((size,3) float32, (size,) bool valid mask)."""
    points = np.asarray(points, dtype=np.float32)
    n = points.shape[0]
    if n > size:
        raise ValueError(f"cloud of {n} points does not fit bucket {size}")
    out = np.full((size, 3), PAD_SENTINEL, dtype=np.float32)
    out[:n] = points
    valid = np.zeros((size,), dtype=bool)
    valid[:n] = True
    return out, valid


class CollatedBatch(NamedTuple):
    """A padded frame-pair batch ready for ``icp_batch`` / ``register_batch``."""
    src: np.ndarray        # (B, N_b, 3) float32
    dst: np.ndarray        # (B, M_b, 3) float32
    src_valid: np.ndarray  # (B, N_b) bool
    dst_valid: np.ndarray  # (B, M_b) bool
    src_sizes: tuple[int, ...]  # true per-frame point counts
    dst_sizes: tuple[int, ...]


def collate_pairs(pairs: Sequence[tuple[np.ndarray, np.ndarray]],
                  buckets: Sequence[int] = DEFAULT_BUCKETS) -> CollatedBatch:
    """Collate [(src, dst), ...] into one fixed-shape batch.

    All sources share one bucket (the smallest fitting the largest source)
    and likewise all targets, so the whole sequence is served by a single
    compiled executable.
    """
    if not pairs:
        raise ValueError("collate_pairs needs at least one frame pair")
    src_sizes = tuple(int(np.asarray(s).shape[0]) for s, _ in pairs)
    dst_sizes = tuple(int(np.asarray(d).shape[0]) for _, d in pairs)
    n_b = bucket_size(max(src_sizes), buckets)
    m_b = bucket_size(max(dst_sizes), buckets)
    srcs, dsts, svs, dvs = [], [], [], []
    for s, d in pairs:
        sp, sv = pad_cloud(s, n_b)
        dp, dv = pad_cloud(d, m_b)
        srcs.append(sp)
        dsts.append(dp)
        svs.append(sv)
        dvs.append(dv)
    return CollatedBatch(src=np.stack(srcs), dst=np.stack(dsts),
                         src_valid=np.stack(svs), dst_valid=np.stack(dvs),
                         src_sizes=src_sizes, dst_sizes=dst_sizes)
