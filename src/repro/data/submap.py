"""Rolling local voxel submap for streaming scan-to-map odometry.

Frame-to-frame odometry chains per-pair errors into an unbounded random
walk; the classic fix (and the regime the paper's KITTI numbers live in)
is registering each scan against a persistent *local map*. This module is
that map, built from the repo's own static-shape primitives:

  * **insert** — each registered scan is fused into the map by one
    ``voxel_downsample`` pass over ``concat(map, scan)``: per occupied
    voxel the centroid of old map points and new scan points, i.e. the
    map both *grows* (new cells) and *refines* (revisited cells average
    across frames, beating single-scan sensor noise). Capacity is static
    (``SubmapParams.capacity`` rows + validity mask, collate sentinel
    conventions), so the fuse is one jitted executable for the whole
    stream.
  * **eviction** — cells farther than ``evict_radius`` from the current
    ego position drop out of the fuse, bounding memory to the local
    neighbourhood exactly like the paper's on-chip target residency
    bounds the NN search space.
  * **re-anchoring** — the lattice origin snaps to the voxel grid centred
    on the current ego position every insert. This is what makes the
    out-of-lattice fix (``cell_coords(..., clip=False)``) matter at
    system scale: queries from a moving ego stay *inside* ``dims``, so
    the grid searcher never has to fall back, while anything the ego
    outran is reported honestly instead of matched to a boundary cell.

**Storage modes** (``SubmapParams.storage``, DESIGN.md §14): the resident
cell payload is either

  * ``"fp32"`` — world-frame points + a bool validity mask (the seed
    layout, byte-for-byte: 13 B per cell row), or
  * ``"fp16"`` — half-precision offsets *relative to the fp32 lattice
    origin*, with invalid rows parked at +inf so validity derives from
    ``isfinite`` and no separate mask is stored: 6 B per cell row,
    2.17x more resident submaps per byte. Offsets are guaranteed
    non-negative and bounded by ``dims * voxel_size`` (the fuse's
    in-lattice filter), so the half-precision quantization error is
    ≤ half an ulp at the far lattice edge (~1.6 cm at 64 m for the
    default lattice) — sensor-noise scale, averaged out further by the
    centroid fuse. Accumulation always runs in fp32: every fuse decodes
    to world-frame fp32, runs the exact fp32 fuse math, and re-encodes.

The state itself is a plain tuple of arrays (``empty_state`` /
``fuse_state`` / ``state_views``) so fleet-scale consumers — the sharded
registration service — can hold thousands of submaps as stacked,
device-sharded leaves without going through per-stream objects; the
:class:`Submap` class is the single-stream host-facing wrapper over the
same functions.

The map lives in map/world frame (frame 0 of the stream); callers
transform scans by their estimated pose before inserting
(``repro.core.odometry.OdometryPipeline`` does this per frame).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.data.collate import PAD_SENTINEL
from repro.data.voxelize import VoxelGrid, build_voxel_grid, voxel_downsample

STORAGE_MODES = ("fp32", "fp16")


class SubmapParams(NamedTuple):
    """Static submap configuration (hashable: jit-cache friendly).

    ``dims * voxel_size`` is the lattice extent in metres — size it to
    cover the eviction sphere (``2 * evict_radius``) or the in-lattice
    filter will evict before the distance filter does. ``capacity`` is the
    static point budget; occupied voxels beyond it are dropped
    deterministically by ``voxel_downsample`` (the sticky
    ``Submap.dropped_cells`` counter reports it — a saturated budget no
    longer hides behind a healthy-looking 1.0 occupancy). ``storage``
    picks the resident payload layout (module docstring): ``"fp32"`` is
    the seed-exact layout, ``"fp16"`` the memory-lean one.
    """

    voxel_size: float = 0.5
    capacity: int = 16384
    dims: tuple[int, int, int] = (192, 192, 48)   # 96 m x 96 m x 24 m
    evict_radius: float = 45.0
    storage: str = "fp32"


# -- functional state API (fleet-batchable) ---------------------------------
#
# A submap's device state is a tuple of arrays:
#   fp32: (points (cap,3) f32 world-frame, valid (cap,) bool, origin (3,))
#   fp16: (store  (cap,3) f16 origin-relative offsets,        origin (3,))
# The origin is always the LAST leaf; fp16 validity derives from isfinite
# on the stored offsets (+inf rows are the invalid sentinels). Every
# function here is jit-safe with ``params`` static, and vmaps cleanly —
# the sharded service stacks these leaves into (S, ...) fleet arrays.

def empty_state(params: SubmapParams) -> tuple:
    """The idle (no points) state tuple for ``params``."""
    cap = int(params.capacity)
    origin = jnp.zeros((3,), jnp.float32)
    if params.storage == "fp16":
        store = jnp.full((cap, 3), jnp.inf, jnp.float16)
        return store, origin
    points = jnp.full((cap, 3), PAD_SENTINEL, jnp.float32)
    valid = jnp.zeros((cap,), bool)
    return points, valid, origin


def state_views(state: tuple, params: SubmapParams):
    """Decode a state tuple to registration-target form:
    ``(points f32 world-frame, valid bool, origin)``. Invalid rows carry
    ``PAD_SENTINEL`` (collate conventions) in both modes. The fp32 mode
    returns its leaves untouched — zero device ops, bit-identity with the
    seed layout; fp16 decodes ``origin + offset`` in fp32."""
    if params.storage == "fp16":
        store, origin = state
        valid = jnp.isfinite(store[:, 0])
        points = jnp.where(valid[:, None],
                           origin + store.astype(jnp.float32),
                           jnp.asarray(PAD_SENTINEL, jnp.float32))
        return points, valid, origin
    points, valid, origin = state
    return points, valid, origin


def encode_state(points, valid, origin, params: SubmapParams) -> tuple:
    """Pack decoded ``(points, valid, origin)`` into the storage layout."""
    if params.storage == "fp16":
        store = jnp.where(valid[:, None], points - origin,
                          jnp.asarray(jnp.inf, jnp.float32))
        return store.astype(jnp.float16), origin
    return points, valid, origin


def _fuse_core(map_pts, map_valid, new_pts, new_valid, center,
               params: SubmapParams):
    """One insert+evict+re-anchor step on decoded fp32 state, fully
    static-shape. Returns ``(points, valid, origin, dropped_cells)`` at
    ``params.capacity`` rows — the exact seed fuse math plus the
    occupied-cell overflow count."""
    v = jnp.asarray(params.voxel_size, jnp.float32)
    dims = jnp.asarray(params.dims, jnp.float32)
    # Re-anchor: lattice centred on the ego, snapped to the voxel grid so
    # cell membership is stable across inserts that don't move far.
    origin = jnp.floor((center - 0.5 * dims * v) / v) * v
    pts = jnp.concatenate([map_pts, new_pts.astype(jnp.float32)], axis=0)
    valid = jnp.concatenate([map_valid, new_valid], axis=0)
    # Evict by distance from the ego (sentinel pad rows are far anyway)…
    d2 = jnp.sum((pts - center) ** 2, axis=-1)
    valid = valid & (d2 <= params.evict_radius ** 2)
    # …and drop anything outside the re-anchored lattice, so every stored
    # point has honest cell membership (no build-time boundary clipping).
    ic = jnp.floor((pts - origin) / v)
    valid = valid & jnp.all((ic >= 0) & (ic < dims), axis=-1)
    fused, fused_valid, dropped = voxel_downsample(
        pts, v, max_points=params.capacity, valid=valid, origin=origin,
        with_stats=True)
    return fused, fused_valid, origin, dropped


def fuse_state(state: tuple, new_pts, new_valid, center,
               params: SubmapParams):
    """Fuse a world-frame scan into a state tuple. Returns
    ``(state', occupied, dropped)`` — occupied is the post-fuse valid-cell
    count, dropped the occupied cells the capacity could not hold. The
    fuse math runs in fp32 in both storage modes (fp16 decodes first and
    re-encodes after), so the only fp16-vs-fp32 divergence is the stored
    offsets' quantization."""
    map_pts, map_valid, _ = state_views(state, params)
    fused, fused_valid, origin, dropped = _fuse_core(
        map_pts, map_valid, new_pts, new_valid, center, params)
    new_state = encode_state(fused, fused_valid, origin, params)
    return new_state, jnp.sum(fused_valid), dropped


@functools.partial(jax.jit, static_argnames=("params",))
def _fuse_state_jit(state, new_pts, new_valid, center,
                    params: SubmapParams):
    return fuse_state(state, new_pts, new_valid, center, params)


@functools.partial(jax.jit, static_argnames=("params",))
def _fuse(map_pts, map_valid, new_pts, new_valid, center,
          params: SubmapParams):
    """Seed-signature fuse (fp32 layout in, fp32 layout out) — kept for
    callers that manage bare (points, valid, origin) triples."""
    fused, fused_valid, origin, _ = _fuse_core(
        map_pts, map_valid, new_pts, new_valid, center, params)
    return fused, fused_valid, origin


def state_bytes(params: SubmapParams) -> int:
    """Device bytes of one resident submap's cell payload (origin leaf
    excluded — 12 B either way). The fp32/fp16 ratio here is the
    memory-lean headline: 13 B/row -> 6 B/row."""
    return sum(leaf.nbytes for leaf in empty_state(params)[:-1])


class Submap:
    """Rolling local map: static-capacity fused cloud + validity mask.

    Host-facing stateful wrapper over the jitted fuse step; one instance
    per stream. ``points``/``valid`` follow collate conventions (invalid
    rows carry ``PAD_SENTINEL``), so the map drops straight into the
    engine layer as a registration target, mask-aware or not. Both are
    decoded views over :attr:`state` (identity in fp32 mode).

    ``dropped_cells`` is the sticky saturation counter: the running total
    of occupied voxels the capacity budget could not hold across every
    insert. A healthy map keeps it at 0; a saturated one grows it while
    ``occupancy()`` sits at a deceptively clean 1.0.
    """

    def __init__(self, params: SubmapParams = SubmapParams()):
        if params.storage not in STORAGE_MODES:
            raise ValueError(f"storage must be one of {STORAGE_MODES}, "
                             f"got {params.storage!r}")
        self.params = params
        self.state = empty_state(params)
        self.frames_inserted = 0
        self.dropped_cells = 0

    def insert(self, points, center, valid=None) -> None:
        """Fuse a (N, 3) map-frame cloud; evict + re-anchor around
        ``center`` (the current ego position in map frame, (3,))."""
        pts = jnp.asarray(points, jnp.float32)
        if valid is None:
            valid = jnp.ones((pts.shape[0],), bool)
        else:
            valid = jnp.asarray(valid, bool)
        self.state, _, dropped = _fuse_state_jit(
            self.state, pts, valid, jnp.asarray(center, jnp.float32),
            self.params)
        self.frames_inserted += 1
        self.dropped_cells += int(dropped)

    # -- decoded views -----------------------------------------------------
    @property
    def points(self) -> jax.Array:
        """Decoded (capacity, 3) f32 cell centroids (invalid rows junk)."""
        return state_views(self.state, self.params)[0]

    @property
    def valid(self) -> jax.Array:
        """(capacity,) bool mask of occupied cells."""
        return state_views(self.state, self.params)[1]

    @property
    def origin(self) -> jax.Array:
        """Lattice anchor of the rolling window, (3,) f32 world coords."""
        return self.state[-1]

    # -- registration-target views ----------------------------------------
    def target(self):
        """(points, valid) — feed to ``RegistrationEngine.register``."""
        pts, valid, _ = state_views(self.state, self.params)
        return pts, valid

    def grid(self) -> VoxelGrid:
        """Counting-sort grid over the live map (anchored at the rolling
        origin, so in-radius queries are guaranteed in-lattice)."""
        return build_voxel_grid(self.points, self.params.voxel_size,
                                self.params.dims, valid=self.valid,
                                origin=self.origin)

    # -- diagnostics -------------------------------------------------------
    @property
    def size(self) -> int:
        """Occupied voxels (valid map points)."""
        return int(jnp.sum(self.valid))

    def occupancy(self) -> float:
        """Fraction of the static capacity in use (1.0 = budget saturated
        — check ``dropped_cells`` to tell an exact fit from silent
        truncation; grow ``capacity`` or shrink ``evict_radius``)."""
        return self.size / int(self.params.capacity)
