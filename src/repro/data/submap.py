"""Rolling local voxel submap for streaming scan-to-map odometry.

Frame-to-frame odometry chains per-pair errors into an unbounded random
walk; the classic fix (and the regime the paper's KITTI numbers live in)
is registering each scan against a persistent *local map*. This module is
that map, built from the repo's own static-shape primitives:

  * **insert** — each registered scan is fused into the map by one
    ``voxel_downsample`` pass over ``concat(map, scan)``: per occupied
    voxel the centroid of old map points and new scan points, i.e. the
    map both *grows* (new cells) and *refines* (revisited cells average
    across frames, beating single-scan sensor noise). Capacity is static
    (``SubmapParams.capacity`` rows + validity mask, collate sentinel
    conventions), so the fuse is one jitted executable for the whole
    stream.
  * **eviction** — cells farther than ``evict_radius`` from the current
    ego position drop out of the fuse, bounding memory to the local
    neighbourhood exactly like the paper's on-chip target residency
    bounds the NN search space.
  * **re-anchoring** — the lattice origin snaps to the voxel grid centred
    on the current ego position every insert. This is what makes the
    out-of-lattice fix (``cell_coords(..., clip=False)``) matter at
    system scale: queries from a moving ego stay *inside* ``dims``, so
    the grid searcher never has to fall back, while anything the ego
    outran is reported honestly instead of matched to a boundary cell.

The map lives in map/world frame (frame 0 of the stream); callers
transform scans by their estimated pose before inserting
(``repro.core.odometry.OdometryPipeline`` does this per frame).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.data.collate import PAD_SENTINEL
from repro.data.voxelize import VoxelGrid, build_voxel_grid, voxel_downsample


class SubmapParams(NamedTuple):
    """Static submap configuration (hashable: jit-cache friendly).

    ``dims * voxel_size`` is the lattice extent in metres — size it to
    cover the eviction sphere (``2 * evict_radius``) or the in-lattice
    filter will evict before the distance filter does. ``capacity`` is the
    static point budget; occupied voxels beyond it are dropped
    deterministically by ``voxel_downsample`` (watch ``occupancy()``
    saturate toward 1.0 as the budget fills).
    """

    voxel_size: float = 0.5
    capacity: int = 16384
    dims: tuple[int, int, int] = (192, 192, 48)   # 96 m x 96 m x 24 m
    evict_radius: float = 45.0


@functools.partial(jax.jit, static_argnames=("params",))
def _fuse(map_pts, map_valid, new_pts, new_valid, center,
          params: SubmapParams):
    """One insert+evict+re-anchor step, fully static-shape.

    Returns (points, valid, origin) at ``params.capacity`` rows.
    """
    v = jnp.asarray(params.voxel_size, jnp.float32)
    dims = jnp.asarray(params.dims, jnp.float32)
    # Re-anchor: lattice centred on the ego, snapped to the voxel grid so
    # cell membership is stable across inserts that don't move far.
    origin = jnp.floor((center - 0.5 * dims * v) / v) * v
    pts = jnp.concatenate([map_pts, new_pts.astype(jnp.float32)], axis=0)
    valid = jnp.concatenate([map_valid, new_valid], axis=0)
    # Evict by distance from the ego (sentinel pad rows are far anyway)…
    d2 = jnp.sum((pts - center) ** 2, axis=-1)
    valid = valid & (d2 <= params.evict_radius ** 2)
    # …and drop anything outside the re-anchored lattice, so every stored
    # point has honest cell membership (no build-time boundary clipping).
    ic = jnp.floor((pts - origin) / v)
    valid = valid & jnp.all((ic >= 0) & (ic < dims), axis=-1)
    fused, fused_valid = voxel_downsample(pts, v,
                                          max_points=params.capacity,
                                          valid=valid, origin=origin)
    return fused, fused_valid, origin


class Submap:
    """Rolling local map: static-capacity fused cloud + validity mask.

    Host-facing stateful wrapper over the jitted fuse step; one instance
    per stream. ``points``/``valid`` follow collate conventions (invalid
    rows carry ``PAD_SENTINEL``), so the map drops straight into the
    engine layer as a registration target, mask-aware or not.
    """

    def __init__(self, params: SubmapParams = SubmapParams()):
        self.params = params
        cap = int(params.capacity)
        self.points = jnp.full((cap, 3), PAD_SENTINEL, jnp.float32)
        self.valid = jnp.zeros((cap,), bool)
        self.origin = jnp.zeros((3,), jnp.float32)
        self.frames_inserted = 0

    def insert(self, points, center, valid=None) -> None:
        """Fuse a (N, 3) map-frame cloud; evict + re-anchor around
        ``center`` (the current ego position in map frame, (3,))."""
        pts = jnp.asarray(points, jnp.float32)
        if valid is None:
            valid = jnp.ones((pts.shape[0],), bool)
        else:
            valid = jnp.asarray(valid, bool)
        self.points, self.valid, self.origin = _fuse(
            self.points, self.valid, pts, valid,
            jnp.asarray(center, jnp.float32), self.params)
        self.frames_inserted += 1

    # -- registration-target views ----------------------------------------
    def target(self):
        """(points, valid) — feed to ``RegistrationEngine.register``."""
        return self.points, self.valid

    def grid(self) -> VoxelGrid:
        """Counting-sort grid over the live map (anchored at the rolling
        origin, so in-radius queries are guaranteed in-lattice)."""
        return build_voxel_grid(self.points, self.params.voxel_size,
                                self.params.dims, valid=self.valid,
                                origin=self.origin)

    # -- diagnostics -------------------------------------------------------
    @property
    def size(self) -> int:
        """Occupied voxels (valid map points)."""
        return int(jnp.sum(self.valid))

    def occupancy(self) -> float:
        """Fraction of the static capacity in use (1.0 = budget saturated,
        inserts are dropping cells — grow ``capacity`` or shrink
        ``evict_radius``)."""
        return self.size / int(self.params.capacity)
