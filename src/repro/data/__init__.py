"""Data substrate: synthetic LiDAR scenes + LM token pipelines."""
