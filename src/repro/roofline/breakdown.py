"""Per-instruction breakdown of a dry-run cell — the 'profile' for the
hypothesis->change->measure loop (no real hardware; the lowered IR is the
profiler).

    PYTHONPATH=src python -m repro.roofline.breakdown --arch deepseek-moe-16b \
        --shape train_4k [--mesh single] [--top 20] [--rule expert=]

Prints the top collectives and top HBM-traffic instructions with their
loop multipliers and source op_names (metadata) so changes can be traced
back to model code.
"""
from __future__ import annotations

import os

if "--no-devices" not in os.sys.argv:  # parity with dryrun: 512 host devices
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import re        # noqa: E402

from repro.roofline.hlo_analysis import (_SKIP_BYTES, _called,  # noqa: E402
                                         _instr_traffic, _parse_computations,
                                         _trip_count, _virtual_set, _dot_flops)


def collect_rows(hlo_text: str):
    comps, entry = _parse_computations(hlo_text)
    coll_rows, hbm_rows, flop_rows = [], [], []

    def metadata(ins):
        m = re.search(r'op_name="([^"]+)"', ins.attrs)
        return m.group(1)[-90:] if m else ""

    def walk(cname, mult, seen):
        comp = comps.get(cname)
        if comp is None or cname in seen:
            return
        virtual = _virtual_set(comp)
        rm: dict = {}
        for iname in comp.order:
            ins = comp.instructions[iname]
            m2 = mult * ((_trip_count(ins) or 1.0)
                         if ins.opcode == "while" else 1.0)
            for sub in _called(ins):
                walk(sub, m2, seen)
            base = ins.opcode.replace("-start", "")
            if base in ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"):
                ob = sum(comp.instructions[o].bytes for o in ins.operands
                         if o in comp.instructions)
                coll_rows.append((mult * ob, mult, base, ins.type_str[:40],
                                  metadata(ins)))
            if ins.opcode == "dot":
                flop_rows.append((mult * _dot_flops(ins, comp), mult,
                                  ins.type_str[:40], metadata(ins)))
            if ins.opcode not in _SKIP_BYTES and iname not in virtual:
                b = _instr_traffic(ins, comp, virtual, rm, comps)
                hbm_rows.append((mult * b, mult, ins.opcode,
                                 ins.type_str[:40], metadata(ins)))

    walk(entry, 1.0, set())
    return coll_rows, hbm_rows, flop_rows


def print_top(rows, title, top, unit=1e9, suffix="GB"):
    print(f"\n== top {title} ==")
    for row in sorted(rows, reverse=True)[:top]:
        val, mult, *rest = row
        print(f"{val / unit:12.2f} {suffix} x{mult:6.0f}  " +
              "  ".join(str(r) for r in rest))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--rule", action="append", default=[])
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--remat", default="full")
    args = ap.parse_args()

    from repro.launch import dryrun as dr
    overrides = {}
    for r in args.rule:
        k, _, v = r.partition("=")
        overrides[k] = tuple(x for x in v.split(",") if x) or None

    # reuse the dryrun lowering, but grab the compiled text
    import jax
    from repro.configs import get_config
    from repro.configs.registry import get_shape
    from repro.launch.partition import param_sharding, partitioning
    from repro.launch.specs import batch_specs, sharding_for_axes
    from repro.optim import cosine_schedule, pick_optimizer
    from repro.train import train_step as ts

    cfg = get_config(args.arch)
    shape = get_shape(args.shape)
    mesh = dr._mesh_for(args.mesh)
    rules = dr._rules_for(mesh, shape.global_batch, overrides or None, cfg)
    specs, axes = batch_specs(cfg, shape)
    in_sh = sharding_for_axes(mesh, axes, rules)
    with partitioning(mesh, rules):
        if shape.kind == "train":
            accum = args.accum or dr._auto_accum(cfg, shape, mesh, rules)
            opt = pick_optimizer(cfg.total_params(), cosine_schedule(3e-4))
            state_abs = ts.abstract_state(cfg, opt)
            state_sh = param_sharding(ts.state_logical_axes(cfg, opt), mesh,
                                      rules, state_abs)
            step = ts.make_train_step(cfg, opt, remat=args.remat,
                                      accum_steps=accum,
                                      grad_shardings=state_sh.params)
            compiled = jax.jit(step, in_shardings=(state_sh, in_sh),
                               out_shardings=(state_sh, None),
                               donate_argnums=(0,)).lower(state_abs,
                                                          specs).compile()
            print(f"accum={accum}")
        else:
            raise SystemExit("breakdown currently supports train shapes")
    coll, hbm, flops = collect_rows(compiled.as_text())
    print_top(coll, "collectives", args.top)
    print_top(hbm, "HBM traffic", args.top)
    print_top(flops, "dot FLOPs", args.top, unit=1e12, suffix="TF")


if __name__ == "__main__":
    main()
