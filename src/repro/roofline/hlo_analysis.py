"""While-aware HLO cost analysis.

``compiled.cost_analysis()`` counts each while/scan body ONCE (verified
empirically — a 10-iteration scan of a matmul reports the flops of one
matmul). Our models scan over layer groups, so naive cost_analysis
undercounts a 126-layer model by ~40x. This module parses the post-SPMD
HLO text (``compiled.as_text()``, the per-device module) and computes:

  * FLOPs — dots exactly (2 * result_elems * contraction_size), elementwise
    ops at 1 flop/elem (transcendentals 8), reductions at 1/input-elem,
    sorts at n·log n — recursively through fusions/calls, with while bodies
    multiplied by their ``known_trip_count`` backend_config (emitted by XLA
    for lax.scan / fori_loop; missing counts default to 1 and are flagged),
  * an HBM-traffic model — operand + result bytes at *fusion boundaries*
    (buffers internal to a fusion never touch HBM); parameters / tuple
    plumbing / constants excluded,
  * collective bytes per kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute, sync and -start async forms): operand
    bytes summed (the spec'd convention), loop-multiplied.

All numbers are per-device (the module is post-SPMD-partitioning).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_EW1 = {"add", "subtract", "multiply", "divide", "maximum", "minimum",
        "compare", "select", "and", "or", "xor", "negate", "abs", "floor",
        "ceil", "round-nearest-afz", "clamp", "sign", "iota", "convert"}
_EWT = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power", "logistic",
        "sine", "cosine", "expm1", "log1p", "atan2", "erf", "cbrt",
        "exponential-minus-one"}

# plumbing that moves no HBM bytes of its own
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "after-all",
               "partition-id", "replica-id"}


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    bytes: float
    elems: float


@dataclasses.dataclass
class Computation:
    name: str
    instructions: Dict[str, Instruction]
    order: List[str]


def _shape_bytes_elems(type_str: str) -> tuple[float, float]:
    """'f32[256,12]{1,0}' or '(s32[], f32[4]{0})' -> (bytes, elems)."""
    total_b, total_e = 0.0, 0.0
    for m in re.finditer(r"([a-z0-9]+)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1.0
        for d in dims.split(","):
            if d:
                elems *= int(d)
        total_b += _DTYPE_BYTES[dt] * elems
        total_e += elems
    return total_b, total_e


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[a-z0-9].*?(?:\{[\d,]*\})?\)?)\s+"
    r"([\w\-]+)\((.*)$")


def _parse_computations(hlo: str) -> tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in hlo.splitlines():
        header = re.match(r"^(ENTRY\s+)?%([\w.\-]+)\s*\((.*)\)\s*->", line)
        if header and line.rstrip().endswith("{"):
            cur = Computation(name=header.group(2), instructions={}, order=[])
            comps[cur.name] = cur
            if header.group(1):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        # operand segment ends at the matching ')' of the call parens
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = re.findall(r"%([\w.\-]+)", rest[:end])
        attrs = rest[end + 1:]
        b, e = _shape_bytes_elems(type_str)
        ins = Instruction(name=name, type_str=type_str, opcode=opcode,
                          operands=operands, attrs=attrs, bytes=b, elems=e)
        cur.instructions[name] = ins
        cur.order.append(name)
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry or ""


def _dot_flops(ins: Instruction, comp: Computation) -> float:
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    lhs = comp.instructions.get(ins.operands[0]) if ins.operands else None
    if m is None or lhs is None:
        return 2.0 * ins.elems
    dims_m = re.search(r"\[([\d,]*)\]", lhs.type_str)
    if not dims_m:
        return 2.0 * ins.elems
    lhs_dims = [int(d) for d in dims_m.group(1).split(",") if d]
    csize = 1.0
    for d in m.group(1).split(","):
        if d and int(d) < len(lhs_dims):
            csize *= lhs_dims[int(d)]
    return 2.0 * ins.elems * csize


def _trip_count(ins: Instruction):
    m = re.search(r'known_trip_count[":{\s]+n[":\s]+"?(\d+)', ins.attrs)
    return float(m.group(1)) if m else None


def _called(ins: Instruction) -> list[str]:
    out = []
    for key in ("calls", "to_apply", "condition", "body",
                "true_computation", "false_computation"):
        m = re.search(key + r"=%([\w.\-]+)", ins.attrs)
        if m:
            out.append(m.group(1))
    m = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs)
    if m:
        out.extend(re.findall(r"%([\w.\-]+)", m.group(1)))
    return out


def _fusion_param_read_bytes(comps, called_name: str, param_idx: int,
                             full_bytes: float) -> float:
    """Bytes a fusion actually reads from operand ``param_idx``.

    XLA fuses (dynamic-)slice ops into kLoop fusions; when a fusion
    parameter is consumed only through slices, the fusion touches
    slice-sized data, not the whole buffer (measured 126x overcount on
    llama3's scan-saved activation stack before this fix)."""
    body = comps.get(called_name)
    if body is None:
        return full_bytes
    # parameters are named param_N (or positional by appearance order)
    params = [body.instructions[n] for n in body.order
              if body.instructions[n].opcode == "parameter"]
    target = None
    for p in params:
        m = re.match(r"param_(\d+)", p.name)  # param_0, param_0.1, ...
        idx = int(m.group(1)) if m else params.index(p)
        if idx == param_idx:
            target = p
            break
    if target is None and param_idx < len(params):
        target = params[param_idx]
    if target is None:
        return full_bytes
    consumers = [body.instructions[n] for n in body.order
                 if target.name in body.instructions[n].operands]
    if not consumers:
        return 0.0
    if all(c.opcode in ("dynamic-slice", "slice") for c in consumers):
        return sum(c.bytes for c in consumers)
    return full_bytes


def _instr_traffic(ins: Instruction, comp: Computation, virtual: set,
                   read_memo: dict, comps=None) -> float:
    """HBM bytes moved by one instruction execution.

    Slicing/scatter ops only touch the slice/update region, not the whole
    buffer (in-place on TPU): counting full operands overestimated scan-xs
    saving by ~100x (observed on the MoE cell before this fix)."""
    if ins.opcode == "dynamic-update-slice":
        upd = (comp.instructions.get(ins.operands[1])
               if len(ins.operands) > 1 else None)
        return 2.0 * upd.bytes if upd is not None else ins.bytes
    if ins.opcode in ("dynamic-slice", "gather"):
        return 2.0 * ins.bytes  # read the touched region + write result
    if ins.opcode in ("scatter", "scatter-add", "select-and-scatter"):
        upd = (comp.instructions.get(ins.operands[2])
               if len(ins.operands) > 2 else None)
        return 2.0 * upd.bytes if upd is not None else ins.bytes
    b = ins.bytes  # write
    called = _called(ins) if (ins.opcode == "fusion" and comps) else []
    for i, o in enumerate(ins.operands):
        rb = _resolve_reads(comp, virtual, o, read_memo)
        if called and rb > 0:
            rb = min(rb, _fusion_param_read_bytes(comps, called[0], i, rb))
        b += rb
    return b


@dataclasses.dataclass
class HLOCostModel:
    flops: float = 0.0
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_detail: dict = dataclasses.field(default_factory=dict)
    unknown_trip_whiles: int = 0
    # Full-carry-buffer ops inside loop bodies (per-execution traffic above
    # _LOOP_ARTIFACT_THRESHOLD). The CPU backend sometimes schedules e.g. a
    # whole-scan-stack convert inside the layer loop — 190 GB/iteration ops
    # a TPU compile does not emit. Reported separately so the memory term
    # can be read with and without them (llama3-405b §Perf C4).
    loop_artifact_bytes: float = 0.0

    @property
    def hbm_bytes_corrected(self) -> float:
        return self.hbm_bytes - self.loop_artifact_bytes

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["hbm_bytes_corrected"] = self.hbm_bytes_corrected
        return d


_LOOP_ARTIFACT_THRESHOLD = 10e9  # bytes per single execution


@dataclasses.dataclass
class _Cost:
    fl: float = 0.0
    dfl: float = 0.0
    hb: float = 0.0
    cb: float = 0.0
    art: float = 0.0          # loop-artifact bytes (subset of hb)
    coll: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "_Cost", mult: float = 1.0, include_hb: bool = True):
        self.fl += mult * other.fl
        self.dfl += mult * other.dfl
        if include_hb:
            self.hb += mult * other.hb
            self.art += mult * other.art
        self.cb += mult * other.cb
        for k, v in other.coll.items():
            slot = self.coll.setdefault(k, {"count": 0.0, "bytes": 0.0})
            slot["count"] += mult * v["count"]
            slot["bytes"] += mult * v["bytes"]


# ops fusable into consumers for traffic purposes. NOTE: "slice" must NOT
# be here — resolving reads *through* a slice would charge the consumer the
# full pre-slice buffer (measured 100x overcount via scan-saved
# activations on llama3-405b, §Perf C).
_EWLIKE = _EW1 | _EWT | {"broadcast", "transpose", "reverse", "pad",
                         "concatenate"}


def _virtual_set(comp: Computation) -> set[str]:
    """Instructions treated as fused away for HBM-traffic purposes:
    elementwise ops / kLoop fusions with exactly one consumer. This
    approximates TPU fusion granularity — the CPU backend emits long chains
    of small kLoop fusions whose boundary buffers never exist on TPU."""
    consumers: dict[str, int] = {}
    for iname in comp.order:
        for o in comp.instructions[iname].operands:
            consumers[o] = consumers.get(o, 0) + 1
    virtual = set()
    root = comp.order[-1] if comp.order else None
    for iname in comp.order:
        ins = comp.instructions[iname]
        fusable = (ins.opcode in _EWLIKE
                   or (ins.opcode == "fusion" and "kind=kLoop" in ins.attrs))
        if fusable and consumers.get(iname, 0) == 1 and iname != root:
            virtual.add(iname)
    return virtual


def _resolve_reads(comp: Computation, virtual: set[str], name: str,
                   memo: dict) -> float:
    """Bytes read when consuming ``name``: through virtual chains, the reads
    are the chain's ultimate real inputs."""
    if name in memo:
        return memo[name]
    ins = comp.instructions.get(name)
    if ins is None:
        return 0.0
    if ins.opcode == "constant":
        memo[name] = 0.0
        return 0.0
    if name not in virtual:
        memo[name] = ins.bytes
        return ins.bytes
    memo[name] = 0.0  # cycle guard
    total = sum(_resolve_reads(comp, virtual, o, memo) for o in ins.operands)
    memo[name] = total
    return total


def analyze_hlo(hlo_text: str) -> HLOCostModel:
    comps, entry = _parse_computations(hlo_text)
    unknown_whiles = [0]

    memo: dict[tuple, _Cost] = {}

    def comp_cost(cname: str, in_loop: bool = False) -> _Cost:
        key = (cname, in_loop)
        if key in memo:
            return memo[key]
        comp = comps.get(cname)
        if comp is None:
            return _Cost()
        memo[key] = _Cost()  # cycle guard
        virtual = _virtual_set(comp)
        read_memo: dict = {}
        c = _Cost()
        for iname in comp.order:
            ins = comp.instructions[iname]
            called = _called(ins)
            mult = 1.0
            if ins.opcode == "while":
                tc = _trip_count(ins)
                if tc is None:
                    unknown_whiles[0] += 1
                    tc = 1.0
                mult = tc
            # flops of this instruction itself
            if ins.opcode == "dot":
                f = _dot_flops(ins, comp)
                c.fl += f
                c.dfl += f
            elif ins.opcode in _EW1:
                c.fl += ins.elems
            elif ins.opcode in _EWT:
                c.fl += 8.0 * ins.elems
            elif ins.opcode in ("reduce", "reduce-window"):
                in_elems = max((comp.instructions[o].elems
                                for o in ins.operands
                                if o in comp.instructions),
                               default=ins.elems)
                c.fl += in_elems
            elif ins.opcode == "sort":
                n = max(ins.elems, 2.0)
                c.fl += n * math.log2(n)
            # recurse into called computations; fusion bodies contribute
            # flops/collectives but no HBM traffic (internal buffers)
            sub_in_loop = in_loop or ins.opcode == "while"
            for sub in called:
                include_hb = ins.opcode not in ("fusion", "reduce",
                                                "reduce-window", "sort",
                                                "scatter", "select-and-scatter",
                                                "map", "all-reduce",
                                                "reduce-scatter")
                c.add(comp_cost(sub, sub_in_loop), mult=mult,
                      include_hb=include_hb)
            # collectives
            base = ins.opcode.replace("-start", "")
            if base in _COLLECTIVES:
                ob = sum(comp.instructions[o].bytes for o in ins.operands
                         if o in comp.instructions)
                c.cb += mult * ob
                slot = c.coll.setdefault(base, {"count": 0.0, "bytes": 0.0})
                slot["count"] += mult
                slot["bytes"] += mult * ob
            # HBM traffic at (approximated TPU) fusion-boundary granularity:
            # virtual (single-consumer elementwise/kLoop) producers are
            # fused away; reads resolve through them to real inputs.
            if ins.opcode not in _SKIP_BYTES and iname not in virtual:
                traffic = _instr_traffic(ins, comp, virtual, read_memo,
                                         comps)
                c.hb += mult * traffic
                if in_loop and traffic > _LOOP_ARTIFACT_THRESHOLD:
                    c.art += mult * traffic
        memo[key] = c
        return c

    total = comp_cost(entry)
    return HLOCostModel(flops=total.fl, dot_flops=total.dfl,
                        hbm_bytes=total.hb, collective_bytes=total.cb,
                        collective_detail=total.coll,
                        unknown_trip_whiles=unknown_whiles[0],
                        loop_artifact_bytes=total.art)
