"""Roofline analysis from compiled HLO (dry-run artifacts)."""
from repro.roofline.hlo_analysis import HLOCostModel, analyze_hlo
from repro.roofline.report import roofline_terms, V5E

__all__ = ["analyze_hlo", "HLOCostModel", "roofline_terms", "V5E"]
