"""Three-term roofline report from dry-run artifacts.

Hardware model: TPU v5e (the deployment target; see assignment constants):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

Terms (seconds, per step, per device — the HLO module is per-device):
  compute    = analyzed FLOPs / 197e12
  memory     = modeled HBM bytes / 819e9
  collective = collective bytes / 50e9
The dominant term approximates step time under perfect overlap; the ratio
MODEL_FLOPS / analyzed FLOPs flags remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ShapeConfig
from repro.roofline.hlo_analysis import HLOCostModel

V5E = {
    "peak_flops_bf16": 197e12,
    "hbm_bw": 819e9,
    "ici_bw_per_link": 50e9,
    "hbm_bytes": 16 * 2 ** 30,
}


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_device: float
    analyzed_flops_per_device: float
    useful_fraction: float      # MODEL_FLOPS / analyzed
    roofline_fraction: float    # compute_s / max(term)  (MFU-vs-bound proxy)
    step_time_s: float          # max of terms (perfect-overlap bound)

    def to_json(self):
        return dataclasses.asdict(self)


def model_flops(cfg: ArchConfig, shape: ShapeConfig, n_devices: int) -> float:
    """Useful FLOPs per step per device: 6·N_active·D train, 2·N_active·D
    inference (D = tokens processed per step)."""
    n_active = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        total = 2.0 * n_active * tokens
    return total / n_devices


def roofline_terms(cost: HLOCostModel, cfg: ArchConfig | None,
                   shape: ShapeConfig | None, n_devices: int,
                   model_flops_override: float | None = None) -> RooflineTerms:
    compute_s = cost.flops / V5E["peak_flops_bf16"]
    # memory term uses the loop-artifact-corrected bytes (full-carry-buffer
    # ops the CPU backend schedules inside loop bodies; a TPU compile does
    # not emit them — both raw and corrected are in the JSON artifacts)
    memory_s = cost.hbm_bytes_corrected / V5E["hbm_bw"]
    collective_s = cost.collective_bytes / V5E["ici_bw_per_link"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    if model_flops_override is not None:
        mf = model_flops_override
    else:
        assert cfg is not None and shape is not None
        mf = model_flops(cfg, shape, n_devices)
    step = max(terms.values())
    return RooflineTerms(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        model_flops_per_device=mf,
        analyzed_flops_per_device=cost.flops,
        useful_fraction=mf / cost.flops if cost.flops else 0.0,
        roofline_fraction=(mf / V5E["peak_flops_bf16"]) / step if step else 0.0,
        step_time_s=step)
