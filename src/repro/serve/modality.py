"""Modality frontend stubs for the [vlm]/[audio] archs — and the one place
the paper's technique genuinely transfers to the LM zoo.

Chameleon's image tokenizer (VQ-VAE) and MusicGen's EnCodec (residual VQ)
both perform nearest-codebook search: for each patch/frame latent, find the
closest codebook vector. That is *exactly* the FPPS NN-search problem
(DESIGN.md §5 Arch-applicability), so the frontends here run on the FPPS
engine — the Pallas kernel on TPU, its XLA twin elsewhere.

These are STUBS per the assignment: the conv encoders that would produce
latents are out of scope; latents arrive precomputed. What is real is the
quantisation math and the NN search.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp



def _pad3(x: jax.Array, d: int) -> jax.Array:
    """Embed d-dim VQ vectors into the kernel's 3-D point space when d<=3,
    else keep native d (the XLA engine handles any d; the Pallas kernel's
    augmented layout is 3-D — higher-d codebooks use the XLA path)."""
    if x.shape[-1] == d:
        return x
    raise ValueError


def vq_encode(latents: jax.Array, codebook: jax.Array, *, chunk: int = 2048,
              use_pallas: bool = False):
    """latents (..., D), codebook (K, D) -> (codes (...), quantised)."""
    flat = latents.reshape(-1, latents.shape[-1])
    if use_pallas and latents.shape[-1] == 3:
        from repro.kernels.ops import nn_search_pallas
        d2, idx = nn_search_pallas(flat, codebook, None, interpret=None)
    else:
        d2, idx = _nn_anyd(flat, codebook, chunk)
    quant = jnp.take(codebook, idx, axis=0).reshape(latents.shape)
    return idx.reshape(latents.shape[:-1]), quant


def _nn_anyd(src: jax.Array, dst: jax.Array, chunk: int):
    """FPPS brute-force NN generalised to D dims (same matmul expansion)."""
    sn = jnp.sum(src * src, axis=-1, keepdims=True)
    dn = jnp.sum(dst * dst, axis=-1, keepdims=True).T
    d2 = jnp.maximum(sn + dn - 2.0 * (src @ dst.T), 0.0)
    idx = jnp.argmin(d2, axis=1).astype(jnp.int32)
    return jnp.take_along_axis(d2, idx[:, None], axis=1)[:, 0], idx


def rvq_encode(latents: jax.Array, codebooks: jax.Array, *, chunk: int = 2048):
    """Residual VQ (EnCodec-style): codebooks (L, K, D). Returns
    (codes (L, ...), reconstruction)."""
    residual = latents
    codes, recon = [], jnp.zeros_like(latents)
    for li in range(codebooks.shape[0]):
        idx, quant = vq_encode(residual, codebooks[li], chunk=chunk)
        codes.append(idx)
        recon = recon + quant
        residual = residual - quant
    return jnp.stack(codes, axis=0), recon


def chameleon_image_stub(key, batch: int, n_patches: int, d_latent: int = 256,
                         codebook_size: int = 8192):
    """Precomputed-patch-latent stand-in for the Chameleon VQ-VAE encoder;
    returns (image token ids, codebook) via FPPS NN search."""
    k1, k2 = jax.random.split(key)
    codebook = jax.random.normal(k1, (codebook_size, d_latent))
    latents = jax.random.normal(k2, (batch, n_patches, d_latent))
    codes, _ = vq_encode(latents, codebook)
    return codes, codebook


def musicgen_frame_stub(key, batch: int, n_frames: int, d_latent: int = 128,
                        n_books: int = 4, codebook_size: int = 2048):
    """EnCodec-style RVQ stand-in: returns (codes (L,B,T), recon)."""
    k1, k2 = jax.random.split(key)
    books = jax.random.normal(k1, (n_books, codebook_size, d_latent))
    latents = jax.random.normal(k2, (batch, n_frames, d_latent))
    return rvq_encode(latents, books)
