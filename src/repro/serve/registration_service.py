"""Multi-stream registration service: N odometry streams, one compiled
program per round (DESIGN.md §13), optionally sharded over a device mesh
(DESIGN.md §14).

The paper's headline number is a *runtime-weighted* speedup across a
workload mix (§IV) — a shared-accelerator framing. This module is that
layer for the repo: a fleet of vehicles (streams) funnels scans into a
fixed set of ``slots``, and every service round runs the whole fleet's
data plane as three batched executables (vmapped scrub + downsample, one
``SlotEngine`` fleet registration, vmapped submap fuse with buffer
donation) regardless of how many streams are live. The control plane —
health verdicts, the recovery cascade, accept/quarantine bookkeeping —
stays host-side per stream, reusing :class:`~repro.core.odometry.
OdometryPipeline` verbatim, so the service inherits every robustness
behaviour of PR 5–7 without forking the policy code.

**Sharded mode** (``ServiceConfig.devices=D``): the same round runs under
``shard_map`` over a 1-D ``("streams",)`` device mesh. Each device owns a
contiguous block of ``slots / D`` slot lanes AND their resident submaps —
the fleet's map state lives device-resident as sharded ``(S, ...)``
arrays (``repro.data.submap`` state tuples) instead of per-stream host
objects, and the prepare/register/probe/fuse executables all run inside
the shard body with **zero cross-device collectives** (streams are
independent by construction). Host-boundary traffic per round is the
bulk classification fetch, ONE bulk registration+probe health fetch, and
the fuse's occupancy epilogue — all batched, none per-stream. The host
control plane is unchanged: per-stream pipelines see the fleet state
through :class:`_LaneSubmap` views. Admission is mesh-aware (least-loaded
device block) and a retired slot's lane state is reset in place, so
join/retire churn never retraces and never leaks a predecessor's map.

Retrace avoidance is structural, not best-effort: all device arrays are
fixed-shape — ``(slots, scan_capacity, 3)`` staged scans,
``(slots, scan_budget, 3)`` downsampled sources, ``(slots, capacity, 3)``
map targets — and idle or non-registering lanes ride along with all-False
validity masks (they degenerate-freeze after one ICP iteration inside the
batched ``while_loop``). Admitting a stream, retiring one, or dropping
frames under backpressure therefore never changes a traced shape; after
the first round, ``engine.trace_count`` is constant by construction and
the tests assert it.

Bit-exactness contract: a standalone ``OdometryPipeline`` built from
:attr:`RegistrationService.stream_config` and fed the same (staged)
frames produces bit-identical poses and diagnostics — the single-frame
path embeds into the *same* S-lane executable (``SlotEngine.register``),
and a vmapped lane is bitwise independent of lane index and of the other
lanes' contents. In sharded mode the contract extends across mesh sizes
at equal block width: the per-device program is fixed by
``slots / devices`` alone, so a D=8, one-lane-per-device fleet
reproduces a single-device one-lane reference's per-stream poses
bit-for-bit (weak-scaling parity — see ``ShardedSlotEngine``; across
*different* block widths agreement is fp-tolerance, since XLA may tile a
lane's point-axis reductions differently).

Typical use::

    svc = RegistrationService(ServiceConfig(slots=8))      # single-device
    svc = RegistrationService(ServiceConfig(slots=16, devices=8))
    for vid in vehicle_ids:
        svc.admit(vid)
    while streaming:
        for vid, scan in poll_sensors():
            svc.submit(vid, scan)            # staging (async)
        for vid, (pose, diag) in svc.step().items():
            publish(vid, pose, diag)
"""
from __future__ import annotations

import functools
from collections import deque
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.engine import get_engine
from repro.core.icp import scrub_nonfinite
from repro.core.odometry import (KIND_REGISTER, FrameDiagnostics,
                                 OdometryConfig, OdometryPipeline)
from repro.core.transform import transform_points
from repro.data.collate import PAD_SENTINEL, bucket_size, pad_cloud
from repro.data.submap import (SubmapParams, empty_state, fuse_state,
                               state_views)
from repro.data.voxelize import voxel_downsample


class ServiceConfig(NamedTuple):
    """Service-level configuration on top of a shared per-stream
    :class:`~repro.core.odometry.OdometryConfig`.

    ``slots`` is the fleet width of every batched executable — admitted
    streams bind to a slot, further admissions wait (``admission=
    "queue"``) or fail (``"reject"``). ``scan_capacity`` is the staged
    raw-scan row budget (rounded up to a collate bucket); larger scans
    are rejected at ``submit``. ``max_queue`` bounds the per-stream
    staging queue; on overflow ``drop_policy`` evicts the ``"oldest"``
    staged frame (keep freshest — the odometry default) or refuses the
    ``"newest"`` submission. All streams share one odometry config —
    one config means one ``ICPParams``/shape family, which is what keeps
    the fleet inside a single compiled program.

    ``devices`` switches the service to device-sharded mode (module
    docstring): the fleet round runs under ``shard_map`` over the first
    ``devices`` local devices, each owning ``slots / devices`` lanes and
    their resident submaps. ``None`` (default) is the single-device
    path, byte-for-byte the pre-sharding service.
    """

    slots: int = 8
    scan_capacity: int = 4096
    max_queue: int = 4
    drop_policy: str = "oldest"
    admission: str = "queue"
    odometry: OdometryConfig = OdometryConfig()
    devices: int | None = None


class StreamReport(NamedTuple):
    """Per-stream service accounting, returned by ``report``/``close``:
    submit/process/drop counters, quarantine + cascade-escape totals, the
    health-verdict histogram, and the last output pose (None before the
    first processed frame)."""

    stream_id: str
    frames_submitted: int
    frames_processed: int
    frames_dropped: int
    frames_quarantined: int
    cascade_escapes: int
    health_counts: dict
    final_pose: np.ndarray | None


class _StagedFrame(NamedTuple):
    # staged scan padded to (scan_capacity, 3) + mask. Single-device mode
    # stages device-resident (async transfer overlaps the in-flight
    # round); sharded mode stages host-side so each round issues ONE
    # sharded transfer that lands every lane on its owning device.
    pts: object
    valid: object
    seq: int


class _Stream:
    """Host-side stream record: its pipeline, staging queue, counters."""

    def __init__(self, stream_id: str):
        self.id = stream_id
        self.pipe: OdometryPipeline | None = None
        self.queue: deque[_StagedFrame] = deque()
        self.slot: int | None = None
        self.submitted = 0
        self.dropped = 0
        self.cascade_escapes = 0


class _LaneSubmap:
    """Duck-typed Submap view over one lane of the sharded fleet state.

    The host control plane (cascade tiers, lattice probes, occupancy
    diagnostics) reads per-stream map state through the same attribute
    surface as :class:`~repro.data.submap.Submap`; this view resolves
    those reads against the service's sharded ``(S, ...)`` fleet arrays
    at the stream's *current* slot (rebinding-safe). Occupancy and the
    sticky ``dropped_cells`` counter are host caches updated from each
    batched fuse's epilogue, so control-plane reads cost no device
    fetch. All writes go through the service's batched fuse —
    ``insert`` is therefore a usage error here."""

    def __init__(self, svc: "RegistrationService", stream: _Stream):
        self._svc = svc
        self._stream = stream
        self.params: SubmapParams = svc.stream_config.submap
        self.frames_inserted = 0
        self.dropped_cells = 0
        self._occupied = 0

    def _lane_state(self) -> tuple:
        lane = self._stream.slot
        if lane is None:
            raise RuntimeError(f"stream {self._stream.id!r} has no slot "
                               f"bound; its lane state does not exist yet")
        return tuple(leaf[lane] for leaf in self._svc._fleet)

    @property
    def origin(self):
        return self._lane_state()[-1]

    @property
    def points(self):
        return state_views(self._lane_state(), self.params)[0]

    @property
    def valid(self):
        return state_views(self._lane_state(), self.params)[1]

    def target(self):
        pts, valid, _ = state_views(self._lane_state(), self.params)
        return pts, valid

    @property
    def size(self) -> int:
        return self._occupied

    def occupancy(self) -> float:
        return self._occupied / int(self.params.capacity)

    def insert(self, *a, **k):
        raise RuntimeError("sharded service submaps are fused in the "
                           "batched fleet round, never inserted per-stream")


# -- shared one-lane bodies --------------------------------------------------
# The single source of the per-lane math, used by BOTH the single-device
# jits and the sharded (shard_map) factories: one definition means the two
# modes are bit-identical per lane by construction.

def _prepare_one(pts, valid, voxel, budget):
    pts, valid = scrub_nonfinite(pts, valid)
    return voxel_downsample(pts, voxel, max_points=budget, valid=valid)


def _lattice_one(T, src, sv, origin, params: SubmapParams):
    pts = transform_points(T, src)
    c = jnp.floor((pts - origin) / params.voxel_size)
    inb = jnp.all((c >= 0) & (c < jnp.asarray(params.dims, jnp.float32)),
                  axis=-1)
    n_valid = jnp.maximum(jnp.sum(sv), 1)
    return jnp.sum(jnp.logical_and(sv, ~inb)) / n_valid


def _fuse_one(state, src, sv, pose, acc, params: SubmapParams):
    """One lane's accept-gated submap fuse on a storage-mode state tuple.
    Non-accepted lanes pass their state through bit-unchanged (and
    contribute zero dropped cells); occupancy reports the KEPT state."""
    world = transform_points(pose, src)
    fused, occ, dropped = fuse_state(state, world, sv, pose[:3, 3], params)
    kept = jax.tree_util.tree_map(
        lambda new, old: jnp.where(acc, new, old), fused, state)
    occ_kept = jnp.where(acc, occ, jnp.sum(state_views(kept, params)[1]))
    return kept, occ_kept, jnp.where(acc, dropped, 0)


# -- single-device executables ----------------------------------------------

@functools.partial(jax.jit, static_argnames=("voxel", "budget"))
def _prepare_batch(pts_b, valid_b, voxel: float, budget: int):
    """Vmapped sensor-boundary stage: scrub NaN/Inf rows and voxel-
    downsample every staged lane in one executable. Returns
    ``(src_b, sv_b, n_valid_b)`` — each lane bit-identical to the eager
    per-frame path in ``OdometryPipeline.prepare_frame``."""
    src_b, sv_b = jax.vmap(
        lambda p, v: _prepare_one(p, v, voxel, budget))(pts_b, valid_b)
    return src_b, sv_b, jnp.sum(sv_b, axis=1)


@functools.partial(jax.jit, static_argnames=("params",))
def _lattice_batch(T_b, src_b, sv_b, origin_b, params: SubmapParams):
    """Vmapped out-of-lattice probe — the batched spelling of
    ``OdometryPipeline._out_of_lattice_frac`` over every fleet lane."""
    return jax.vmap(
        lambda T, s, v, o: _lattice_one(T, s, v, o, params))(
            T_b, src_b, sv_b, origin_b)


@functools.partial(jax.jit, static_argnames=("params",),
                   donate_argnums=(0,))
def _fuse_batch(state_b, src_b, sv_b, pose_b, accept_b,
                params: SubmapParams):
    """Vmapped submap fuse with per-lane accept select over stacked
    storage-mode state tuples. The incoming map state is donated — the
    largest arrays in the service reuse their device allocation in
    place, the ring-buffer idiom of the on-chip designs this layer
    mirrors. Returns ``(state_b', occupied_b, dropped_b)``."""
    return jax.vmap(
        lambda st, s, v, p, a: _fuse_one(st, s, v, p, a, params))(
            state_b, src_b, sv_b, pose_b, accept_b)


# -- sharded executables (one per mesh + static config, cached) -------------

_SPEC = P("streams")


@functools.lru_cache(maxsize=None)
def _sharded_prepare(mesh, voxel: float, budget: int):
    def body(pts_l, valid_l):
        src, sv = jax.vmap(
            lambda p, v: _prepare_one(p, v, voxel, budget))(pts_l, valid_l)
        return src, sv, jnp.sum(sv, axis=1)

    return jax.jit(shard_map(body, mesh=mesh, in_specs=(_SPEC, _SPEC),
                             out_specs=(_SPEC, _SPEC, _SPEC),
                             check_vma=False))


@functools.lru_cache(maxsize=None)
def _sharded_lattice(mesh, params: SubmapParams):
    def body(T_l, src_l, sv_l, origin_l):
        return jax.vmap(
            lambda T, s, v, o: _lattice_one(T, s, v, o, params))(
                T_l, src_l, sv_l, origin_l)

    return jax.jit(shard_map(body, mesh=mesh, in_specs=(_SPEC,) * 4,
                             out_specs=_SPEC, check_vma=False))


def _state_spec(params: SubmapParams) -> tuple:
    return tuple(_SPEC for _ in empty_state(params))


@functools.lru_cache(maxsize=None)
def _sharded_fuse(mesh, params: SubmapParams):
    sspec = _state_spec(params)

    def body(state_l, src_l, sv_l, pose_l, acc_l):
        return jax.vmap(
            lambda st, s, v, p, a: _fuse_one(st, s, v, p, a, params))(
                state_l, src_l, sv_l, pose_l, acc_l)

    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(sspec, _SPEC, _SPEC, _SPEC, _SPEC),
                             out_specs=(sspec, _SPEC, _SPEC),
                             check_vma=False),
                   donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _sharded_target_views(mesh, params: SubmapParams):
    """Sharded decode of fleet state to registration-target form. The
    fp32 layout needs no decode (the service uses its leaves directly);
    this executable exists for fp16, where the engine's target is
    ``origin + offset`` per lane — the same ``state_views`` formula the
    standalone pipeline evaluates, so lanes stay bit-identical."""
    def body(state_l):
        pts, valid, _ = jax.vmap(
            lambda st: state_views(st, params))(state_l)
        return pts, valid

    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(_state_spec(params),),
                             out_specs=(_SPEC, _SPEC), check_vma=False))


@functools.lru_cache(maxsize=None)
def _sharded_reset(mesh, params: SubmapParams):
    """Reset one lane of the sharded fleet state to idle (retire path).
    Elementwise select along the lane axis — shard-local, no collectives;
    ``lane`` is traced so every retire reuses one executable."""
    out_sh = tuple(NamedSharding(mesh, _SPEC) for _ in empty_state(params))

    def run(state_b, lane):
        idle = empty_state(params)
        S = state_b[-1].shape[0]
        hit = jnp.arange(S) == lane
        return tuple(
            jnp.where(hit.reshape((S,) + (1,) * (leaf.ndim - 1)),
                      idle_leaf[None], leaf)
            for leaf, idle_leaf in zip(state_b, idle))

    return jax.jit(run, donate_argnums=(0,), out_shardings=out_sh)


class RegistrationService:
    """Continuous-batching front end over the odometry stack: admit
    streams into slots, stage frames, and run the whole fleet's round as
    one compiled step (see module docstring for the lifecycle and the
    sharded mode).

    The service is single-threaded and deterministic: ``step()`` pops at
    most one staged frame per active stream in slot order, so identical
    submission sequences produce identical outputs, drops included.
    """

    def __init__(self, config: ServiceConfig = ServiceConfig()):
        if config.drop_policy not in ("oldest", "newest"):
            raise ValueError(f"drop_policy must be 'oldest' or 'newest', "
                             f"got {config.drop_policy!r}")
        if config.admission not in ("queue", "reject"):
            raise ValueError(f"admission must be 'queue' or 'reject', "
                             f"got {config.admission!r}")
        cap = bucket_size(config.scan_capacity)
        self.config = config._replace(scan_capacity=cap)
        self._sharded = config.devices is not None
        sp = self.stream_config.submap
        if self._sharded:
            D = int(config.devices)
            if D < 1 or D > jax.device_count():
                raise ValueError(f"devices must be in "
                                 f"[1, {jax.device_count()}], got {D}")
            if config.slots % D:
                raise ValueError(f"slots={config.slots} must divide evenly "
                                 f"over devices={D}")
            self.engine = get_engine("sharded-slots",
                                     lanes_per_device=config.slots // D,
                                     devices=D)
            self._mesh = self.engine.mesh
            self._sharding = self.engine.sharding()
            # fleet-resident sharded map state: each device holds its lane
            # block's submaps for the whole service lifetime
            idle_np = [np.asarray(leaf) for leaf in empty_state(sp)]
            S = config.slots
            self._fleet = tuple(
                jax.device_put(
                    np.broadcast_to(leaf, (S,) + leaf.shape).copy(),
                    self._sharding)
                for leaf in idle_np)
            # host-side staged-scan fillers (one sharded transfer per round)
            self._idle_pts = np.full((cap, 3), PAD_SENTINEL, np.float32)
            self._idle_valid = np.zeros((cap,), bool)
        else:
            self.engine = get_engine("slots", slots=config.slots)
            self._mesh = self._sharding = None
            self._fleet = None
            # device-resident idle-lane fillers (staged-scan shaped)
            self._idle_pts = jnp.full((cap, 3), PAD_SENTINEL, jnp.float32)
            self._idle_valid = jnp.zeros((cap,), bool)
        self._idle_state = empty_state(sp)   # one idle lane (map shaped)
        self._streams: dict[str, _Stream] = {}
        self._slots: list[str | None] = [None] * config.slots
        self._pending: deque[str] = deque()
        self.rounds = 0
        self.frames_processed = 0
        self.frames_dropped = 0
        self.cascade_escapes = 0
        self._eye = np.eye(4, dtype=np.float32)

    @property
    def stream_config(self) -> OdometryConfig:
        """The per-stream odometry config, normalized onto the shared
        slot engine (sharded or not). A standalone
        ``OdometryPipeline(stream_config)`` is the service's bit-exact
        single-stream reference in either mode."""
        if self.config.devices is not None:
            D = int(self.config.devices)
            return self.config.odometry._replace(
                engine="sharded-slots",
                engine_kwargs=(("lanes_per_device", self.config.slots // D),
                               ("devices", D)))
        return self.config.odometry._replace(
            engine="slots",
            engine_kwargs=(("slots", self.config.slots),))

    # -- admission ---------------------------------------------------------
    def _free_lane(self) -> int | None:
        """Pick the slot a new stream binds. Single-device: first free.
        Sharded: first free lane on the least-loaded device block, so
        live streams spread across the mesh instead of saturating device
        0's block while the rest idle (ties break toward the lower
        device index — deterministic)."""
        if not self._sharded:
            return next((i for i, s in enumerate(self._slots) if s is None),
                        None)
        L = self.config.slots // int(self.config.devices)
        best = None
        for d in range(int(self.config.devices)):
            block = self._slots[d * L:(d + 1) * L]
            free = next((d * L + i for i, s in enumerate(block)
                         if s is None), None)
            if free is None:
                continue
            load = sum(1 for s in block if s is not None)
            if best is None or load < best[0]:
                best = (load, free)
        return None if best is None else best[1]

    def _make_stream(self, stream_id: str) -> _Stream:
        stream = _Stream(stream_id)
        submap = _LaneSubmap(self, stream) if self._sharded else None
        stream.pipe = OdometryPipeline(self.stream_config, submap=submap)
        return stream

    def admit(self, stream_id: str) -> bool:
        """Admit a new stream. Returns True if a slot was bound now,
        False if the stream was queued behind a full fleet
        (``admission="queue"``); raises RuntimeError when the fleet is
        full under ``admission="reject"``. Frames may be submitted while
        queued — they stage and wait."""
        if stream_id in self._streams:
            raise ValueError(f"stream {stream_id!r} already admitted")
        stream = self._make_stream(stream_id)
        lane = self._free_lane()
        if lane is None:
            if self.config.admission == "reject":
                raise RuntimeError(
                    f"service full: {self.config.slots} slots bound, "
                    f"admission policy is 'reject'")
            self._streams[stream_id] = stream
            self._pending.append(stream_id)
            return False
        self._streams[stream_id] = stream
        self._slots[lane] = stream_id
        stream.slot = lane
        return True

    def close(self, stream_id: str) -> StreamReport:
        """Retire a stream: free its slot (rebinding the oldest pending
        stream, if any), drop its state, and return the final
        :class:`StreamReport`. Un-stepped staged frames are discarded
        (counted as dropped). In sharded mode the lane's resident map
        state is reset to idle in place — the next stream bound to this
        slot must never see its predecessor's map."""
        stream = self._streams.pop(stream_id)
        stream.dropped += len(stream.queue)
        self.frames_dropped += len(stream.queue)
        report = self._report(stream)
        if stream.slot is not None:
            if self._sharded:
                reset = _sharded_reset(self._mesh, self.stream_config.submap)
                self._fleet = reset(self._fleet,
                                    jnp.int32(stream.slot))
            self._slots[stream.slot] = None
            while self._pending:
                nxt = self._pending.popleft()
                if nxt in self._streams:
                    self._slots[stream.slot] = nxt
                    self._streams[nxt].slot = stream.slot
                    break
        else:
            # stream was still pending; drop it from the wait queue lazily
            self._pending = deque(s for s in self._pending
                                  if s != stream_id)
        return report

    # -- staging -----------------------------------------------------------
    def stage_scan(self, scan, valid=None):
        """Pad a raw (n, 3) scan to the service's ``scan_capacity`` rows
        (collate sentinel conventions); returns host ``(padded, valid)``.
        This is exactly what ``submit`` stages, exposed so a reference
        ``OdometryPipeline`` can be fed bit-identical input."""
        pts = np.asarray(scan, np.float32)
        if pts.ndim != 2 or pts.shape[1] != 3:
            raise ValueError(f"scan must be (n, 3), got {pts.shape}")
        cap = self.config.scan_capacity
        if pts.shape[0] > cap:
            raise ValueError(f"scan of {pts.shape[0]} points exceeds "
                             f"scan_capacity={cap}")
        padded, pvalid = pad_cloud(pts, cap)
        if valid is not None:
            pvalid = pvalid.copy()
            pvalid[:pts.shape[0]] &= np.asarray(valid, bool)
        return padded, pvalid

    def submit(self, stream_id: str, scan, valid=None) -> bool:
        """Stage one sensor-frame scan for ``stream_id``. Single-device
        mode transfers the padded scan to the device immediately (JAX
        dispatch is async, so staging overlaps the in-flight round's
        compute — the double-buffering half of the transfer story; the
        fuse's buffer donation is the other half). Sharded mode stages
        host-side: the round start issues ONE sharded transfer that
        lands every lane directly on its owning device, instead of
        bouncing per-frame copies through the default device. Returns
        True if the frame is queued; False if backpressure dropped it
        (``drop_policy="newest"``). Dropping the *oldest* staged frame
        still returns True — the submitted frame survived, an older one
        paid."""
        stream = self._streams[stream_id]
        padded, pvalid = self.stage_scan(scan, valid)
        if self._sharded:
            staged = _StagedFrame(pts=padded, valid=pvalid,
                                  seq=stream.submitted)
        else:
            staged = _StagedFrame(pts=jax.device_put(padded),
                                  valid=jax.device_put(pvalid),
                                  seq=stream.submitted)
        stream.submitted += 1
        if len(stream.queue) >= self.config.max_queue:
            stream.dropped += 1
            self.frames_dropped += 1
            if self.config.drop_policy == "newest":
                return False
            stream.queue.popleft()
        stream.queue.append(staged)
        return True

    # -- the fleet round ---------------------------------------------------
    def _stack_states(self, work, S):
        """Per-round stack of every lane's map state (single-device mode
        only — sharded mode's fleet state is already device-resident)."""
        n_leaves = len(self._idle_state)
        return tuple(
            jnp.stack([work[i][0].pipe.submap.state[k] if i in work
                       else self._idle_state[k] for i in range(S)])
            for k in range(n_leaves))

    def step(self) -> dict:
        """Run one service round: pop at most one staged frame per active
        stream (slot order), execute the batched data plane — vmapped
        prepare, one fleet registration, vmapped probe, one bulk fetch,
        per-stream completion, one vmapped fuse — and return
        ``{stream_id: (pose, FrameDiagnostics)}`` for every frame
        processed this round. Streams with empty queues idle at zero
        marginal device cost (their lanes are mask-dead). In sharded
        mode every stage runs inside the shard body over the streams
        mesh; the structure is identical."""
        cfg = self.config
        odo = self.stream_config
        S = cfg.slots
        sharded = self._sharded
        work = {}
        for lane, sid in enumerate(self._slots):
            if sid is None:
                continue
            stream = self._streams[sid]
            if stream.queue:
                work[lane] = (stream, stream.queue.popleft())
        if not work:
            return {}
        self.rounds += 1

        # 1. staged-scan stack -> vmapped scrub + downsample (data plane)
        if sharded:
            pts_b = jax.device_put(
                np.stack([work[i][1].pts if i in work else self._idle_pts
                          for i in range(S)]), self._sharding)
            valid_b = jax.device_put(
                np.stack([work[i][1].valid if i in work
                          else self._idle_valid for i in range(S)]),
                self._sharding)
            prepare = _sharded_prepare(self._mesh, odo.scan_voxel,
                                       odo.scan_budget)
            src_b, sv_b, nv_b = prepare(pts_b, valid_b)
        else:
            pts_b = jnp.stack([work[i][1].pts if i in work
                               else self._idle_pts for i in range(S)])
            valid_b = jnp.stack([work[i][1].valid if i in work
                                 else self._idle_valid for i in range(S)])
            src_b, sv_b, nv_b = _prepare_batch(pts_b, valid_b,
                                               odo.scan_voxel,
                                               odo.scan_budget)
        n_valid = np.asarray(nv_b)

        # 2. host classification: which lanes register this round
        preps = {}
        for lane, (stream, _) in work.items():
            preps[lane] = stream.pipe.prepare_frame(
                None, downsampled=(src_b[lane], sv_b[lane],
                                   int(n_valid[lane])))
        reg_lanes = [lane for lane, p in preps.items()
                     if p.kind == KIND_REGISTER and not p.skip_primary]

        res_host = lat_host = None
        if reg_lanes:
            # 3. one fleet registration through the slot executable
            active = np.zeros((S,), bool)
            active[reg_lanes] = True
            if sharded:
                active_d = jax.device_put(active, self._sharding)
                sub = self.stream_config.submap
                if sub.storage == "fp32":
                    dst_b, dv_b = self._fleet[0], self._fleet[1]
                else:
                    views = _sharded_target_views(self._mesh, sub)
                    dst_b, dv_b = views(self._fleet)
                origin_b = self._fleet[-1]
            else:
                active_d = jnp.asarray(active, bool)
                dst_b = jnp.stack([
                    work[i][0].pipe.submap.points if i in work
                    else state_views(self._idle_state, odo.submap)[0]
                    for i in range(S)])
                dv_b = jnp.stack([
                    work[i][0].pipe.submap.valid if i in work
                    else state_views(self._idle_state, odo.submap)[1]
                    for i in range(S)])
                origin_b = jnp.stack([
                    work[i][0].pipe.submap.origin if i in work
                    else self._idle_state[-1] for i in range(S)])
            T0_b = np.stack([preps[i].T0 if i in preps else self._eye
                             for i in range(S)])
            res = self.engine.register_batch(
                src_b, dst_b, odo.params,
                src_valid=jnp.logical_and(sv_b, active_d[:, None]),
                dst_valid=jnp.logical_and(dv_b, active_d[:, None]),
                initial_transforms=T0_b)
            # 4. batched health probe + ONE bulk device->host fetch
            if sharded:
                probe = _sharded_lattice(self._mesh, odo.submap)
                lat_b = probe(res.T, src_b, sv_b, origin_b)
            else:
                lat_b = _lattice_batch(res.T, src_b, sv_b, origin_b,
                                       odo.submap)
            res_host, lat_host = jax.device_get((res, lat_b))

        # 5. host control plane: per-stream completion (cascade, accept,
        #    quarantine) with the fuse deferred into one batched call
        outputs = {}
        fuse_reqs = {}
        for lane, (stream, _) in work.items():
            prep = preps[lane]
            if lane in reg_lanes:
                lane_res = jax.tree_util.tree_map(lambda x: x[lane],
                                                  res_host)
                lat = float(lat_host[lane])
            else:
                lane_res, lat = None, None
            pose, diag, fuse_req = stream.pipe.complete_frame(
                prep, lane_res, lattice_frac=lat, defer_fuse=True,
                defer_bootstrap=sharded)
            if prep.kind == KIND_REGISTER and diag.recovery_tier > 0:
                stream.cascade_escapes += 1
                self.cascade_escapes += 1
            if fuse_req is not None:
                fuse_reqs[lane] = fuse_req
            outputs[stream.id] = (pose, diag)
            self.frames_processed += 1

        # 6. one vmapped fuse over the fleet's submaps (donated buffers)
        if fuse_reqs:
            accept = np.zeros((S,), bool)
            accept[list(fuse_reqs)] = True
            pose_np = np.stack([fuse_reqs[i].pose if i in fuse_reqs
                                else self._eye for i in range(S)])
            mcap = int(odo.submap.capacity)
            if sharded:
                # the fuse sources ARE this round's prepared batch
                # (every FuseRequest.src is its lane's src_b slice)
                fuse = _sharded_fuse(self._mesh, odo.submap)
                self._fleet, occ_b, drop_b = fuse(
                    self._fleet, src_b, sv_b,
                    jax.device_put(pose_np, self._sharding),
                    jax.device_put(accept, self._sharding))
                occ, drop = np.asarray(occ_b), np.asarray(drop_b)
                for lane, req in fuse_reqs.items():
                    stream = work[lane][0]
                    view = stream.pipe.submap
                    view.frames_inserted += 1
                    view._occupied = int(occ[lane])
                    view.dropped_cells += int(drop[lane])
                    pose, diag = outputs[stream.id]
                    diag = stream.pipe.amend_diagnostics(
                        diag.frame,
                        map_occupancy=float(occ[lane]) / mcap,
                        dropped_cells=view.dropped_cells)
                    outputs[stream.id] = (pose, diag)
            else:
                state_b, occ_b, drop_b = _fuse_batch(
                    self._stack_states(work, S),
                    jnp.stack([fuse_reqs[i].src if i in fuse_reqs
                               else src_b[i] for i in range(S)]),
                    jnp.stack([fuse_reqs[i].sv if i in fuse_reqs
                               else sv_b[i] for i in range(S)]),
                    jnp.asarray(pose_np, jnp.float32),
                    jnp.asarray(accept, bool), odo.submap)
                occ, drop = np.asarray(occ_b), np.asarray(drop_b)
                for lane, req in fuse_reqs.items():
                    stream = work[lane][0]
                    sub = stream.pipe.submap
                    sub.state = tuple(leaf[lane] for leaf in state_b)
                    sub.frames_inserted += 1
                    sub.dropped_cells += int(drop[lane])
                    pose, diag = outputs[stream.id]
                    diag = stream.pipe.amend_diagnostics(
                        diag.frame,
                        map_occupancy=float(occ[lane]) / mcap,
                        dropped_cells=sub.dropped_cells)
                    outputs[stream.id] = (pose, diag)
        return outputs

    def sync(self) -> None:
        """Block until every in-flight device computation for the fleet
        (registration, fuse writebacks) has completed. Outputs returned by
        ``step`` are already host-side; this exists for benchmarks that
        must charge the async fuse tail to the round that issued it."""
        if self._sharded:
            jax.block_until_ready(self._fleet)
            return
        for sid in self._slots:
            if sid is not None:
                sub = self._streams[sid].pipe.submap
                jax.block_until_ready(sub.state)

    def drain(self, max_rounds: int | None = None) -> dict:
        """Step until every active stream's queue is empty (or
        ``max_rounds``); returns ``{stream_id: [(pose, diag), ...]}``
        accumulated in round order."""
        out: dict[str, list] = {}
        rounds = 0
        while any(self._streams[sid].queue for sid in self._slots
                  if sid is not None):
            if max_rounds is not None and rounds >= max_rounds:
                break
            for sid, res in self.step().items():
                out.setdefault(sid, []).append(res)
            rounds += 1
        return out

    # -- observability -----------------------------------------------------
    def _report(self, stream: _Stream) -> StreamReport:
        pipe = stream.pipe
        return StreamReport(
            stream_id=stream.id,
            frames_submitted=stream.submitted,
            frames_processed=len(pipe.diagnostics),
            frames_dropped=stream.dropped,
            frames_quarantined=pipe.quarantined_count,
            cascade_escapes=stream.cascade_escapes,
            health_counts=pipe.health_counts(),
            final_pose=pipe.poses[-1] if pipe.poses else None)

    def report(self, stream_id: str) -> StreamReport:
        """Current :class:`StreamReport` for one stream (active or
        pending), without retiring it."""
        return self._report(self._streams[stream_id])

    def service_report(self) -> dict:
        """Fleet-level counters: rounds run, frames processed/dropped,
        cascade escapes, live/pending stream counts, the device count the
        fleet is sharded over (1 = single-device mode), and the slot
        engine's trace count (constant after warmup = the retrace-free
        invariant)."""
        return {
            "rounds": self.rounds,
            "frames_processed": self.frames_processed,
            "frames_dropped": self.frames_dropped,
            "cascade_escapes": self.cascade_escapes,
            "active_streams": sum(1 for s in self._slots if s is not None),
            "pending_streams": len(self._pending),
            "devices": (int(self.config.devices) if self._sharded else 1),
            "trace_count": self.engine.trace_count,
        }

    def diagnostics(self, stream_id: str) -> list[FrameDiagnostics]:
        """The per-frame diagnostics history of one stream."""
        return list(self._streams[stream_id].pipe.diagnostics)
