"""Multi-stream registration service: N odometry streams, one compiled
program per round (DESIGN.md §13).

The paper's headline number is a *runtime-weighted* speedup across a
workload mix (§IV) — a shared-accelerator framing. This module is that
layer for the repo: a fleet of vehicles (streams) funnels scans into a
fixed set of ``slots``, and every service round runs the whole fleet's
data plane as three batched executables (vmapped scrub + downsample, one
``SlotEngine`` fleet registration, vmapped submap fuse with buffer
donation) regardless of how many streams are live. The control plane —
health verdicts, the recovery cascade, accept/quarantine bookkeeping —
stays host-side per stream, reusing :class:`~repro.core.odometry.
OdometryPipeline` verbatim, so the service inherits every robustness
behaviour of PR 5–7 without forking the policy code.

Retrace avoidance is structural, not best-effort: all device arrays are
fixed-shape — ``(slots, scan_capacity, 3)`` staged scans,
``(slots, scan_budget, 3)`` downsampled sources, ``(slots, capacity, 3)``
map targets — and idle or non-registering lanes ride along with all-False
validity masks (they degenerate-freeze after one ICP iteration inside the
batched ``while_loop``). Admitting a stream, retiring one, or dropping
frames under backpressure therefore never changes a traced shape; after
the first round, ``engine.trace_count`` is constant by construction and
the tests assert it.

Bit-exactness contract: a standalone ``OdometryPipeline`` built from
:attr:`RegistrationService.stream_config` and fed the same (staged)
frames produces bit-identical poses and diagnostics — the single-frame
path embeds into the *same* S-lane executable (``SlotEngine.register``),
and a vmapped lane is bitwise independent of lane index and of the other
lanes' contents.

Typical use::

    svc = RegistrationService(ServiceConfig(slots=8))
    for vid in vehicle_ids:
        svc.admit(vid)
    while streaming:
        for vid, scan in poll_sensors():
            svc.submit(vid, scan)            # host->device staging (async)
        for vid, (pose, diag) in svc.step().items():
            publish(vid, pose, diag)
"""
from __future__ import annotations

import functools
from collections import deque
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import get_engine
from repro.core.icp import scrub_nonfinite
from repro.core.odometry import (KIND_REGISTER, FrameDiagnostics,
                                 OdometryConfig, OdometryPipeline)
from repro.core.transform import transform_points
from repro.data.collate import PAD_SENTINEL, bucket_size, pad_cloud
from repro.data.submap import SubmapParams
from repro.data.submap import _fuse as _submap_fuse
from repro.data.voxelize import voxel_downsample


class ServiceConfig(NamedTuple):
    """Service-level configuration on top of a shared per-stream
    :class:`~repro.core.odometry.OdometryConfig`.

    ``slots`` is the fleet width of every batched executable — admitted
    streams bind to a slot, further admissions wait (``admission=
    "queue"``) or fail (``"reject"``). ``scan_capacity`` is the staged
    raw-scan row budget (rounded up to a collate bucket); larger scans
    are rejected at ``submit``. ``max_queue`` bounds the per-stream
    staging queue; on overflow ``drop_policy`` evicts the ``"oldest"``
    staged frame (keep freshest — the odometry default) or refuses the
    ``"newest"`` submission. All streams share one odometry config —
    one config means one ``ICPParams``/shape family, which is what keeps
    the fleet inside a single compiled program.
    """

    slots: int = 8
    scan_capacity: int = 4096
    max_queue: int = 4
    drop_policy: str = "oldest"
    admission: str = "queue"
    odometry: OdometryConfig = OdometryConfig()


class StreamReport(NamedTuple):
    """Per-stream service accounting, returned by ``report``/``close``:
    submit/process/drop counters, quarantine + cascade-escape totals, the
    health-verdict histogram, and the last output pose (None before the
    first processed frame)."""

    stream_id: str
    frames_submitted: int
    frames_processed: int
    frames_dropped: int
    frames_quarantined: int
    cascade_escapes: int
    health_counts: dict
    final_pose: np.ndarray | None


class _StagedFrame(NamedTuple):
    # device-resident staged scan: padded to (scan_capacity, 3) + mask
    pts: jax.Array
    valid: jax.Array
    seq: int


class _Stream:
    """Host-side stream record: its pipeline, staging queue, counters."""

    def __init__(self, stream_id: str, pipe: OdometryPipeline):
        self.id = stream_id
        self.pipe = pipe
        self.queue: deque[_StagedFrame] = deque()
        self.slot: int | None = None
        self.submitted = 0
        self.dropped = 0
        self.cascade_escapes = 0


@functools.partial(jax.jit, static_argnames=("voxel", "budget"))
def _prepare_batch(pts_b, valid_b, voxel: float, budget: int):
    """Vmapped sensor-boundary stage: scrub NaN/Inf rows and voxel-
    downsample every staged lane in one executable. Returns
    ``(src_b, sv_b, n_valid_b)`` — each lane bit-identical to the eager
    per-frame path in ``OdometryPipeline.prepare_frame``."""
    def one(pts, valid):
        pts, valid = scrub_nonfinite(pts, valid)
        return voxel_downsample(pts, voxel, max_points=budget, valid=valid)

    src_b, sv_b = jax.vmap(one)(pts_b, valid_b)
    return src_b, sv_b, jnp.sum(sv_b, axis=1)


@functools.partial(jax.jit, static_argnames=("params",))
def _lattice_batch(T_b, src_b, sv_b, origin_b, params: SubmapParams):
    """Vmapped out-of-lattice probe — the batched spelling of
    ``OdometryPipeline._out_of_lattice_frac`` over every fleet lane."""
    def one(T, src, sv, origin):
        pts = transform_points(T, src)
        c = jnp.floor((pts - origin) / params.voxel_size)
        inb = jnp.all((c >= 0) & (c < jnp.asarray(params.dims, jnp.float32)),
                      axis=-1)
        n_valid = jnp.maximum(jnp.sum(sv), 1)
        return jnp.sum(jnp.logical_and(sv, ~inb)) / n_valid

    return jax.vmap(one)(T_b, src_b, sv_b, origin_b)


@functools.partial(jax.jit, static_argnames=("params",),
                   donate_argnums=(0, 1))
def _fuse_batch(map_pts_b, map_valid_b, origin_b, src_b, sv_b, pose_b,
                accept_b, params: SubmapParams):
    """Vmapped submap fuse with per-lane accept select. The incoming map
    buffers are donated — the largest arrays in the service reuse their
    device allocation in place, the ring-buffer idiom of the on-chip
    designs this layer mirrors. Non-accepted lanes pass their map state
    through bit-unchanged."""
    def one(mp, mv, origin, src, sv, pose, acc):
        world = transform_points(pose, src)
        fp, fv, forigin = _submap_fuse(mp, mv, world, sv, pose[:3, 3],
                                       params)
        return (jnp.where(acc, fp, mp), jnp.where(acc, fv, mv),
                jnp.where(acc, forigin, origin))

    fp_b, fv_b, fo_b = jax.vmap(one)(map_pts_b, map_valid_b, origin_b,
                                     src_b, sv_b, pose_b, accept_b)
    return fp_b, fv_b, fo_b, jnp.sum(fv_b, axis=1)


class RegistrationService:
    """Continuous-batching front end over the odometry stack: admit
    streams into slots, stage frames, and run the whole fleet's round as
    one compiled step (see module docstring for the lifecycle).

    The service is single-threaded and deterministic: ``step()`` pops at
    most one staged frame per active stream in slot order, so identical
    submission sequences produce identical outputs, drops included.
    """

    def __init__(self, config: ServiceConfig = ServiceConfig()):
        if config.drop_policy not in ("oldest", "newest"):
            raise ValueError(f"drop_policy must be 'oldest' or 'newest', "
                             f"got {config.drop_policy!r}")
        if config.admission not in ("queue", "reject"):
            raise ValueError(f"admission must be 'queue' or 'reject', "
                             f"got {config.admission!r}")
        cap = bucket_size(config.scan_capacity)
        self.config = config._replace(scan_capacity=cap)
        self.engine = get_engine("slots", slots=config.slots)
        self._streams: dict[str, _Stream] = {}
        self._slots: list[str | None] = [None] * config.slots
        self._pending: deque[str] = deque()
        self.rounds = 0
        self.frames_processed = 0
        self.frames_dropped = 0
        self.cascade_escapes = 0
        # device-resident idle-lane filler (staged-scan shaped + map shaped)
        self._idle_pts = jnp.full((cap, 3), PAD_SENTINEL, jnp.float32)
        self._idle_valid = jnp.zeros((cap,), bool)
        mcap = int(self.stream_config.submap.capacity)
        self._idle_map = jnp.full((mcap, 3), PAD_SENTINEL, jnp.float32)
        self._idle_map_valid = jnp.zeros((mcap,), bool)
        self._idle_origin = jnp.zeros((3,), jnp.float32)
        self._eye = np.eye(4, dtype=np.float32)

    @property
    def stream_config(self) -> OdometryConfig:
        """The per-stream odometry config, normalized onto the shared
        ``SlotEngine``. A standalone ``OdometryPipeline(stream_config)``
        is the service's bit-exact single-stream reference."""
        return self.config.odometry._replace(
            engine="slots",
            engine_kwargs=(("slots", self.config.slots),))

    # -- admission ---------------------------------------------------------
    def admit(self, stream_id: str) -> bool:
        """Admit a new stream. Returns True if a slot was bound now,
        False if the stream was queued behind a full fleet
        (``admission="queue"``); raises RuntimeError when the fleet is
        full under ``admission="reject"``. Frames may be submitted while
        queued — they stage and wait."""
        if stream_id in self._streams:
            raise ValueError(f"stream {stream_id!r} already admitted")
        stream = _Stream(stream_id, OdometryPipeline(self.stream_config))
        lane = next((i for i, s in enumerate(self._slots) if s is None),
                    None)
        if lane is None:
            if self.config.admission == "reject":
                raise RuntimeError(
                    f"service full: {self.config.slots} slots bound, "
                    f"admission policy is 'reject'")
            self._streams[stream_id] = stream
            self._pending.append(stream_id)
            return False
        self._streams[stream_id] = stream
        self._slots[lane] = stream_id
        stream.slot = lane
        return True

    def close(self, stream_id: str) -> StreamReport:
        """Retire a stream: free its slot (rebinding the oldest pending
        stream, if any), drop its state, and return the final
        :class:`StreamReport`. Un-stepped staged frames are discarded
        (counted as dropped)."""
        stream = self._streams.pop(stream_id)
        stream.dropped += len(stream.queue)
        self.frames_dropped += len(stream.queue)
        report = self._report(stream)
        if stream.slot is not None:
            self._slots[stream.slot] = None
            while self._pending:
                nxt = self._pending.popleft()
                if nxt in self._streams:
                    self._slots[stream.slot] = nxt
                    self._streams[nxt].slot = stream.slot
                    break
        else:
            # stream was still pending; drop it from the wait queue lazily
            self._pending = deque(s for s in self._pending
                                  if s != stream_id)
        return report

    # -- staging -----------------------------------------------------------
    def stage_scan(self, scan, valid=None):
        """Pad a raw (n, 3) scan to the service's ``scan_capacity`` rows
        (collate sentinel conventions); returns host ``(padded, valid)``.
        This is exactly what ``submit`` stages, exposed so a reference
        ``OdometryPipeline`` can be fed bit-identical input."""
        pts = np.asarray(scan, np.float32)
        if pts.ndim != 2 or pts.shape[1] != 3:
            raise ValueError(f"scan must be (n, 3), got {pts.shape}")
        cap = self.config.scan_capacity
        if pts.shape[0] > cap:
            raise ValueError(f"scan of {pts.shape[0]} points exceeds "
                             f"scan_capacity={cap}")
        padded, pvalid = pad_cloud(pts, cap)
        if valid is not None:
            pvalid = pvalid.copy()
            pvalid[:pts.shape[0]] &= np.asarray(valid, bool)
        return padded, pvalid

    def submit(self, stream_id: str, scan, valid=None) -> bool:
        """Stage one sensor-frame scan for ``stream_id``. The padded scan
        is transferred to the device immediately (JAX dispatch is async,
        so staging overlaps the in-flight round's compute — the
        double-buffering half of the transfer story; the fuse's buffer
        donation is the other half). Returns True if the frame is queued;
        False if backpressure dropped it (``drop_policy="newest"``).
        Dropping the *oldest* staged frame still returns True — the
        submitted frame survived, an older one paid."""
        stream = self._streams[stream_id]
        padded, pvalid = self.stage_scan(scan, valid)
        staged = _StagedFrame(pts=jax.device_put(padded),
                              valid=jax.device_put(pvalid),
                              seq=stream.submitted)
        stream.submitted += 1
        if len(stream.queue) >= self.config.max_queue:
            stream.dropped += 1
            self.frames_dropped += 1
            if self.config.drop_policy == "newest":
                return False
            stream.queue.popleft()
        stream.queue.append(staged)
        return True

    # -- the fleet round ---------------------------------------------------
    def step(self) -> dict:
        """Run one service round: pop at most one staged frame per active
        stream (slot order), execute the batched data plane — vmapped
        prepare, one fleet registration, vmapped probe, one bulk fetch,
        per-stream completion, one vmapped fuse — and return
        ``{stream_id: (pose, FrameDiagnostics)}`` for every frame
        processed this round. Streams with empty queues idle at zero
        marginal device cost (their lanes are mask-dead)."""
        cfg = self.config
        odo = self.stream_config
        S = cfg.slots
        work = {}
        for lane, sid in enumerate(self._slots):
            if sid is None:
                continue
            stream = self._streams[sid]
            if stream.queue:
                work[lane] = (stream, stream.queue.popleft())
        if not work:
            return {}
        self.rounds += 1

        # 1. staged-scan stack -> vmapped scrub + downsample (data plane)
        pts_b = jnp.stack([work[i][1].pts if i in work else self._idle_pts
                           for i in range(S)])
        valid_b = jnp.stack([work[i][1].valid if i in work
                             else self._idle_valid for i in range(S)])
        src_b, sv_b, nv_b = _prepare_batch(pts_b, valid_b, odo.scan_voxel,
                                           odo.scan_budget)
        n_valid = np.asarray(nv_b)

        # 2. host classification: which lanes register this round
        preps = {}
        for lane, (stream, _) in work.items():
            preps[lane] = stream.pipe.prepare_frame(
                None, downsampled=(src_b[lane], sv_b[lane],
                                   int(n_valid[lane])))
        reg_lanes = [lane for lane, p in preps.items()
                     if p.kind == KIND_REGISTER and not p.skip_primary]

        res_host = lat_host = None
        if reg_lanes:
            # 3. one fleet registration through the slot executable
            active = np.zeros((S,), bool)
            active[reg_lanes] = True
            active_d = jnp.asarray(active)
            dst_b = jnp.stack([
                work[i][0].pipe.submap.points if i in work
                else self._idle_map for i in range(S)])
            dv_b = jnp.stack([
                work[i][0].pipe.submap.valid if i in work
                else self._idle_map_valid for i in range(S)])
            origin_b = jnp.stack([
                work[i][0].pipe.submap.origin if i in work
                else self._idle_origin for i in range(S)])
            T0_b = np.stack([preps[i].T0 if i in preps else self._eye
                             for i in range(S)])
            res = self.engine.register_batch(
                src_b, dst_b, odo.params,
                src_valid=jnp.logical_and(sv_b, active_d[:, None]),
                dst_valid=jnp.logical_and(dv_b, active_d[:, None]),
                initial_transforms=T0_b)
            # 4. batched health probe + ONE bulk device->host fetch
            lat_b = _lattice_batch(res.T, src_b, sv_b, origin_b,
                                   odo.submap)
            res_host, lat_host = jax.device_get((res, lat_b))

        # 5. host control plane: per-stream completion (cascade, accept,
        #    quarantine) with the fuse deferred into one batched call
        outputs = {}
        fuse_reqs = {}
        for lane, (stream, _) in work.items():
            prep = preps[lane]
            if lane in reg_lanes:
                lane_res = jax.tree_util.tree_map(lambda x: x[lane],
                                                  res_host)
                lat = float(lat_host[lane])
            else:
                lane_res, lat = None, None
            pose, diag, fuse_req = stream.pipe.complete_frame(
                prep, lane_res, lattice_frac=lat, defer_fuse=True)
            if prep.kind == KIND_REGISTER and diag.recovery_tier > 0:
                stream.cascade_escapes += 1
                self.cascade_escapes += 1
            if fuse_req is not None:
                fuse_reqs[lane] = fuse_req
            outputs[stream.id] = (pose, diag)
            self.frames_processed += 1

        # 6. one vmapped fuse over the fleet's submaps (donated buffers)
        if fuse_reqs:
            accept = np.zeros((S,), bool)
            accept[list(fuse_reqs)] = True
            fp_b, fv_b, fo_b, occ_b = _fuse_batch(
                jnp.stack([work[i][0].pipe.submap.points if i in work
                           else self._idle_map for i in range(S)]),
                jnp.stack([work[i][0].pipe.submap.valid if i in work
                           else self._idle_map_valid for i in range(S)]),
                jnp.stack([work[i][0].pipe.submap.origin if i in work
                           else self._idle_origin for i in range(S)]),
                jnp.stack([fuse_reqs[i].src if i in fuse_reqs
                           else src_b[i] for i in range(S)]),
                jnp.stack([fuse_reqs[i].sv if i in fuse_reqs
                           else sv_b[i] for i in range(S)]),
                jnp.asarray(np.stack([fuse_reqs[i].pose if i in fuse_reqs
                                      else self._eye for i in range(S)])),
                jnp.asarray(accept), odo.submap)
            occ = np.asarray(occ_b)
            mcap = int(odo.submap.capacity)
            for lane, req in fuse_reqs.items():
                stream = work[lane][0]
                sub = stream.pipe.submap
                sub.points, sub.valid = fp_b[lane], fv_b[lane]
                sub.origin = fo_b[lane]
                sub.frames_inserted += 1
                pose, diag = outputs[stream.id]
                diag = stream.pipe.amend_diagnostics(
                    diag.frame, map_occupancy=float(occ[lane]) / mcap)
                outputs[stream.id] = (pose, diag)
        return outputs

    def sync(self) -> None:
        """Block until every in-flight device computation for the fleet
        (registration, fuse writebacks) has completed. Outputs returned by
        ``step`` are already host-side; this exists for benchmarks that
        must charge the async fuse tail to the round that issued it."""
        for sid in self._slots:
            if sid is not None:
                sub = self._streams[sid].pipe.submap
                jax.block_until_ready((sub.points, sub.valid))

    def drain(self, max_rounds: int | None = None) -> dict:
        """Step until every active stream's queue is empty (or
        ``max_rounds``); returns ``{stream_id: [(pose, diag), ...]}``
        accumulated in round order."""
        out: dict[str, list] = {}
        rounds = 0
        while any(self._streams[sid].queue for sid in self._slots
                  if sid is not None):
            if max_rounds is not None and rounds >= max_rounds:
                break
            for sid, res in self.step().items():
                out.setdefault(sid, []).append(res)
            rounds += 1
        return out

    # -- observability -----------------------------------------------------
    def _report(self, stream: _Stream) -> StreamReport:
        pipe = stream.pipe
        return StreamReport(
            stream_id=stream.id,
            frames_submitted=stream.submitted,
            frames_processed=len(pipe.diagnostics),
            frames_dropped=stream.dropped,
            frames_quarantined=pipe.quarantined_count,
            cascade_escapes=stream.cascade_escapes,
            health_counts=pipe.health_counts(),
            final_pose=pipe.poses[-1] if pipe.poses else None)

    def report(self, stream_id: str) -> StreamReport:
        """Current :class:`StreamReport` for one stream (active or
        pending), without retiring it."""
        return self._report(self._streams[stream_id])

    def service_report(self) -> dict:
        """Fleet-level counters: rounds run, frames processed/dropped,
        cascade escapes, live/pending stream counts, and the slot
        engine's trace count (constant after warmup = the retrace-free
        invariant)."""
        return {
            "rounds": self.rounds,
            "frames_processed": self.frames_processed,
            "frames_dropped": self.frames_dropped,
            "cascade_escapes": self.cascade_escapes,
            "active_streams": sum(1 for s in self._slots if s is not None),
            "pending_streams": len(self._pending),
            "trace_count": self.engine.trace_count,
        }

    def diagnostics(self, stream_id: str) -> list[FrameDiagnostics]:
        """The per-frame diagnostics history of one stream."""
        return list(self._streams[stream_id].pipe.diagnostics)
