"""Batched serving engine: prefill + decode loop with a persistent KV cache.

Simplification (documented): the batch decodes in lockstep (uniform
positions) — the standard benchmark-serving shape (decode_32k cell). A
continuous-batching scheduler would sit one level above this engine and is
out of scope for the paper's workload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm


class Engine:
    def __init__(self, cfg: ArchConfig, params, max_len: int = 2048):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        def _step(params, pos, cache, token):
            return lm.decode_step(params, cfg, pos, cache, token=token)

        self._decode = jax.jit(_step, donate_argnums=(2,))

    def generate(self, prompts: jax.Array, n_steps: int,
                 temperature: float = 0.0, key=None):
        """prompts: (B, S) int32 -> (B, n_steps) int32 generated tokens."""
        cfg = self.cfg
        b, s = prompts.shape
        assert s + n_steps <= self.max_len
        logits, cache = lm.prefill(self.params, cfg, tokens=prompts,
                                   max_len=self.max_len)
        key = key if key is not None else jax.random.PRNGKey(0)
        out = []
        tok = self._sample(logits, temperature, key)
        out.append(tok)
        for i in range(1, n_steps):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, pos=jnp.asarray(s + i - 1),
                                         cache=cache, token=tok)
            tok = self._sample(logits, temperature, sub)
            out.append(tok)
        return jnp.stack(out, axis=1)

    @staticmethod
    def _sample(logits, temperature, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1
                                      ).astype(jnp.int32)
