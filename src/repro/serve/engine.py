"""LEGACY LM-decode path: batched generate with a persistent KV cache.

This is the seed-era *language-model* serving engine — prefill + decode
in lockstep (uniform positions), the standard benchmark-serving shape
(decode_32k cell). It predates the repo's actual serving layer and is
kept for the LM-zoo archs only.

For the paper's workload — point-cloud registration — continuous
batching is NOT out of scope anymore: it lives in
:mod:`repro.serve.registration_service` (DESIGN.md §13), where N
odometry streams join/retire mid-flight through fixed-shape fleet
rounds. New serving work belongs there; this module stays the lockstep
LM reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm


class Engine:
    """Lockstep LM generate engine: one jitted decode step (donated KV
    cache) driven by a host loop at uniform batch positions. Streams
    cannot join or leave mid-generation — for that (on the registration
    workload) see :class:`repro.serve.registration_service.
    RegistrationService`."""

    def __init__(self, cfg: ArchConfig, params, max_len: int = 2048):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        def _step(params, pos, cache, token):
            return lm.decode_step(params, cfg, pos, cache, token=token)

        self._decode = jax.jit(_step, donate_argnums=(2,))

    def generate(self, prompts: jax.Array, n_steps: int,
                 temperature: float = 0.0, key=None):
        """prompts: (B, S) int32 -> (B, n_steps) int32 generated tokens."""
        cfg = self.cfg
        b, s = prompts.shape
        assert s + n_steps <= self.max_len
        logits, cache = lm.prefill(self.params, cfg, tokens=prompts,
                                   max_len=self.max_len)
        key = key if key is not None else jax.random.PRNGKey(0)
        out = []
        tok = self._sample(logits, temperature, key)
        out.append(tok)
        for i in range(1, n_steps):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, pos=jnp.asarray(s + i - 1),
                                         cache=cache, token=tok)
            tok = self._sample(logits, temperature, sub)
            out.append(tok)
        return jnp.stack(out, axis=1)

    @staticmethod
    def _sample(logits, temperature, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1
                                      ).astype(jnp.int32)
