"""Serving substrate: batched generate engine + modality frontends."""
