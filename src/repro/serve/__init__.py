"""Serving layer: the multi-stream registration service (the paper's
workload — ``registration_service``, DESIGN.md §13), the legacy lockstep
LM generate engine (``engine``), and the VQ modality frontends
(``modality``)."""
