"""Sharded optimizers + schedules (pure JAX, no optax dependency)."""
from repro.optim.optimizers import (OptState, Optimizer, adafactor, adamw,
                                    clip_by_global_norm, pick_optimizer)
from repro.optim.schedule import cosine_schedule

__all__ = ["Optimizer", "OptState", "adamw", "adafactor", "pick_optimizer",
           "clip_by_global_norm", "cosine_schedule"]
