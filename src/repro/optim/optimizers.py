"""AdamW and Adafactor, shard-friendly pure-JAX implementations.

Optimizer states are pytrees mirroring the params tree, so the same
NamedShardings apply (launch/partition). Adafactor keeps factored second
moments — O(m+n) per (m,n) matrix instead of O(mn) — which is what lets the
405B/235B configs hold optimizer state inside the v5e HBM budget
(EXPERIMENTS.md §Dry-run memory table); this is a standard production trick
(T5/PaLM trained with it), not an approximation we invented.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    inner: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], tuple[Any, OptState]]
    name: str = ""

    def state_logical_axes(self, param_axes):
        """Optimizer-state logical axes mirroring param axes."""
        return self._axes_fn(param_axes)  # type: ignore[attr-defined]


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def adamw(lr_fn, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        inner={"m": jax.tree_util.tree_map(zeros, params),
                               "v": jax.tree_util.tree_map(zeros, params)})

    def update(grads, state: OptState, params):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr = lr_fn(step)
        b1c = 1.0 - b1 ** step.astype(jnp.float32)
        b2c = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / b1c
            vh = v / b2c
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p - lr * delta).astype(p.dtype), m, v

        out = jax.tree_util.tree_map(upd, grads, state.inner["m"],
                                     state.inner["v"], params)
        new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                            is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        return new_params, OptState(step=step, inner={"m": new_m, "v": new_v})

    opt = Optimizer(init=init, update=update, name="adamw")
    object.__setattr__(opt, "_axes_fn", lambda param_axes: OptState(
        step=(), inner={"m": param_axes, "v": param_axes}))
    return opt


def adafactor(lr_fn, decay: float = 0.99, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0,
              clip_norm: float = 1.0) -> Optimizer:
    """Factored-second-moment Adafactor (Shazeer & Stern, 2018), no momentum.

    For ndim>=2 leaves: row/col running means of g² over the last two dims
    (leading stack dims kept). For vectors/scalars: full second moment.
    """
    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def one(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return OptState(step=jnp.zeros((), jnp.int32),
                        inner=jax.tree_util.tree_map(one, params))

    def update(grads, state: OptState, params):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr = lr_fn(step)
        # bias-corrected decay (Adafactor's \hat{\beta}_t)
        t = step.astype(jnp.float32)
        beta = jnp.minimum(decay, 1.0 - t ** -0.8)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rms_row = vr / jnp.mean(vr, axis=-1, keepdims=True)
                denom = jnp.sqrt(rms_row[..., None] * vc[..., None, :])
                u = g / jnp.maximum(denom, 1e-30)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(v)
                new_s = {"v": v}
            # update clipping by RMS
            urms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, urms / clip_threshold)
            delta = u + weight_decay * p.astype(jnp.float32)
            return (p - lr * delta).astype(p.dtype), new_s

        # tree_map recurses over grads' structure; the matching state.inner
        # subtree ({"vr","vc"} or {"v"}) arrives whole at each grad leaf.
        out = jax.tree_util.tree_map(upd, grads, state.inner, params)
        is_pair = lambda t: isinstance(t, tuple) and len(t) == 2
        new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=is_pair)
        new_inner = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=is_pair)
        return new_params, OptState(step=step, inner=new_inner)

    opt = Optimizer(init=init, update=update, name="adafactor")

    def axes_fn(param_axes):
        def one(names):
            names = tuple(names)
            if len(names) >= 2:
                return {"vr": names[:-1], "vc": names[:-2] + names[-1:]}
            return {"v": names}
        inner = jax.tree_util.tree_map(
            one, param_axes,
            is_leaf=lambda t: isinstance(t, tuple) and all(
                isinstance(e, (str, type(None))) for e in t))
        return OptState(step=(), inner=inner)

    object.__setattr__(opt, "_axes_fn", axes_fn)
    return opt


def pick_optimizer(total_params: int, lr_fn) -> Optimizer:
    """Production default: AdamW below 100B total params, Adafactor above
    (fp32 m+v for 405B/235B would blow the v5e HBM budget)."""
    if total_params >= 100e9:
        return adafactor(lr_fn)
    return adamw(lr_fn)
