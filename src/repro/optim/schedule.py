"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(peak_lr: float, warmup_steps: int = 2000,
                    total_steps: int = 100_000, min_ratio: float = 0.1):
    def lr_fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) /
                        max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr_fn
