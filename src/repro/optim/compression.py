"""Int8 error-feedback gradient compression for data-parallel all-reduce.

The distributed-optimization trick (1-bit-Adam / PowerSGD family, int8
variant): inside a shard_map data-parallel region, replace the fp32 ring
all-reduce of gradients with

    1. add the error-feedback residual from the previous step,
    2. quantize to int8 with a per-tensor scale,
    3. REDUCE via all_to_all: each device sums one 1/n chunk
       (wire: ~1 byte/elem instead of ~8),
    4. re-quantize the summed chunk, all_gather int8 chunks back
       (wire: ~1 byte/elem),
    5. dequantize; keep (local_grad - dequant(local_quant)) as the new
       error-feedback residual so quantization error accumulates into the
       next step instead of being lost.

Net wire bytes ≈ 2/8 = 4x less than fp32 ring all-reduce. Error feedback
makes the *accumulated* gradient unbiased — convergence matches fp32 within
noise (tests/test_grad_compression.py trains a model both ways).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def _quantize(g: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), _EPS) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(g: jax.Array, axis: str, ef: jax.Array):
    """Mean-reduce ``g`` over mesh axis ``axis`` with int8 compression.

    g: fp32 array (any shape; padded internally to n_dev chunks);
    ef: error-feedback residual, same shape. Returns (g_mean, new_ef).
    Must run inside shard_map with ``axis`` manual."""
    from repro.compat import axis_size
    n = axis_size(axis)
    shape = g.shape
    orig = 1
    for d in shape:
        orig *= d
    flat = (g + ef).reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    q, scale = _quantize(flat)
    new_ef = (flat - _dequantize(q, scale))[:orig].reshape(shape)
    # 3. all_to_all: device j receives everyone's chunk j -> sum locally
    chunks = q.reshape(n, -1)
    recv = jax.lax.all_to_all(chunks, axis, split_axis=0,
                              concat_axis=0)                     # (n, chunk)
    recv_scales = jax.lax.all_gather(scale, axis)                # (n,)
    summed = jnp.sum(recv.astype(jnp.float32)
                     * recv_scales[:, None], axis=0) / n         # mean chunk
    # 4. re-quantize my chunk, gather all chunks
    q2, s2 = _quantize(summed)
    all_q = jax.lax.all_gather(q2, axis)                         # (n, chunk)
    all_s = jax.lax.all_gather(s2, axis)                         # (n,)
    out = (all_q.astype(jnp.float32) * all_s[:, None]).reshape(-1)
    return out[:orig].reshape(shape), new_ef


def init_error_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_grad_reduce(grads, axis: str, ef_state):
    """Tree-wise compressed mean-reduction. Returns (grads_mean, new_ef)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(ef_state)
    outs, efs = [], []
    for g, e in zip(flat_g, flat_e):
        gm, ne = compressed_psum_mean(g.astype(jnp.float32), axis, e)
        outs.append(gm)
        efs.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, outs),
            jax.tree_util.tree_unflatten(treedef, efs))
