"""granite-34b — IBM Granite Code 34B [arXiv:2405.04324; hf].

GPTBigCode-family code model; MQA (kv=1), non-gated (2-matrix) GELU MLP.
88L, d_model 6144, 48 heads, d_ff 24576, vocab 49152. Deviation noted in
DESIGN.md: learned positions -> RoPE (uniform backbone; dims unchanged).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_head=128,
    d_ff=24576, vocab_size=49152,
    block_pattern=("attn",), ffn="gelu",
    rope_theta=10000.0, q_block=1024,
    sharding_overrides=(("kv_heads", None),),  # MQA: replicate the single KV head
    source="arXiv:2405.04324; hf:ibm-granite/granite-34b-code-base",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="granite-34b-smoke", family="dense",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=1, d_head=32,
        d_ff=256, vocab_size=512, block_pattern=("attn",), ffn="gelu")
