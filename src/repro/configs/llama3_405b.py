"""llama3-405b — Llama 3.1 405B [arXiv:2407.21783; unverified].

126L, d_model 16384, 128 heads (GQA kv=8), d_ff 53248, vocab 128256.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_head=128,
    d_ff=53248, vocab_size=128256,
    block_pattern=("attn",), ffn="swiglu",
    rope_theta=500000.0, q_block=1024,
    sharding_overrides=(("kv_heads", None),),  # 8 kv heads < TP=16: replicate
    source="arXiv:2407.21783",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="llama3-405b-smoke", family="dense",
        n_layers=4, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
        d_ff=320, vocab_size=512, block_pattern=("attn",), ffn="swiglu",
        rope_theta=500000.0, q_block=32)
