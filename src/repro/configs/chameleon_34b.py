"""chameleon-34b — Meta Chameleon 34B [arXiv:2405.09818; unverified].

Early-fusion VLM over a unified token space (text + VQ-VAE image tokens,
vocab 65536); llama-like backbone with QK-norm. 48L, d_model 8192, 64 heads
(GQA kv=8), d_ff 22016.

Frontend stub per assignment: ``input_specs()`` provides precomputed
patch/token embeddings (B, S, d_model); the backbone is what we build. The
VQ-VAE nearest-codebook stage itself is exactly an FPPS NN search — the
kernel integration is demonstrated in repro/serve/modality.py and tests.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22016, vocab_size=65536,
    block_pattern=("attn",), ffn="swiglu",
    qk_norm=True, embed_inputs=False, q_block=1024,
    sharding_overrides=(("kv_heads", None),),
    source="arXiv:2405.09818",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="chameleon-34b-smoke", family="vlm",
        n_layers=3, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
        d_ff=256, vocab_size=512, block_pattern=("attn",), ffn="swiglu",
        qk_norm=True, embed_inputs=False)
