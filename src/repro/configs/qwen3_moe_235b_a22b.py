"""qwen3-moe-235b-a22b — Qwen3 MoE [hf:Qwen/Qwen3-30B-A3B scaled family].

128 routed experts, top-8, d_expert 1536, no shared experts, renormalised
top-k. 94L, d_model 4096, 64 heads (GQA kv=4, d_head 128), QK-norm,
vocab 151936.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab_size=151936,
    block_pattern=("attn",), ffn="moe",
    n_experts=128, top_k=8, n_shared_experts=0, d_expert=1536,
    normalize_topk=True, qk_norm=True, rope_theta=1000000.0, q_block=1024,
    sharding_overrides=(("kv_heads", None),),  # 4 kv heads < TP=16
    source="hf:Qwen/Qwen3-235B-A22B",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-smoke", family="moe",
        n_layers=3, d_model=96, n_heads=4, n_kv_heads=2, d_head=24,
        d_ff=64, vocab_size=512, block_pattern=("attn",), ffn="moe",
        n_experts=8, top_k=2, n_shared_experts=0, d_expert=48,
        normalize_topk=True, qk_norm=True, capacity_factor=8.0)
