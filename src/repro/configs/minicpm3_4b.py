"""minicpm3-4b — MiniCPM3 4B [hf:openbmb/MiniCPM3-4B].

MLA (multi-head latent attention), DeepSeek-V2 style: q_lora 768, kv_lora
256, qk_nope 64, qk_rope 32, v_head 64. 62L, d_model 2560, 40 heads,
d_ff 6400, vocab 73448.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_head=96,
    d_ff=6400, vocab_size=73448,
    block_pattern=("mla",), ffn="swiglu",
    q_lora_rank=768, kv_lora_rank=256,
    qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64, q_block=512,
    # 4B + 40 heads (indivisible by 16) + vocab 73448 (indivisible): DP/FSDP
    sharding_overrides=(("heads", None), ("vocab", None),
                        ("batch", ("pod", "data", "model"))),
    source="hf:openbmb/MiniCPM3-4B",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="minicpm3-4b-smoke", family="dense",
        n_layers=3, d_model=96, n_heads=4, n_kv_heads=4, d_head=24,
        d_ff=192, vocab_size=512, block_pattern=("mla",), ffn="swiglu",
        q_lora_rank=48, kv_lora_rank=32,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
