"""Assigned architecture configs + registry (--arch lookup)."""
from repro.configs.base import SHAPES, ArchConfig, ShapeConfig
from repro.configs.registry import get_config, get_smoke, list_archs

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "get_config", "get_smoke",
           "list_archs"]
