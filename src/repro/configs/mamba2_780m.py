"""mamba2-780m — Mamba-2 SSD [arXiv:2405.21060; unverified].

Attention-free SSM: 48 SSD layers, d_model 1536 (d_inner 3072, headdim 64
-> 48 ssm heads), d_state 128, chunk 256, conv 4, vocab 50280, tied
embeddings. No FFN (the Mamba block is the whole layer).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab_size=50280,
    block_pattern=("ssd",), ffn="swiglu",  # ffn unused: ssd layers have none
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=256, conv_width=4,
    tie_embeddings=True,
    # 780M: DP-only; the fused in_proj concat dim must stay unsharded
    sharding_overrides=(("mlp", None), ("vocab", "model"),
                        ("batch", ("pod", "data", "model"))),
    source="arXiv:2405.21060; hf:state-spaces/mamba2-780m",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="mamba2-780m-smoke", family="ssm",
        n_layers=4, d_model=96, n_heads=0, n_kv_heads=0, d_head=0,
        d_ff=0, vocab_size=512, block_pattern=("ssd",), ffn="swiglu",
        ssm_state=16, ssm_expand=2, ssm_headdim=24, ssm_chunk=16,
        conv_width=4, tie_embeddings=True)
