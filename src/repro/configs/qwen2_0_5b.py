"""qwen2-0.5b — Qwen2 0.5B [arXiv:2407.10671; hf].

24L, d_model 896, 14 heads (GQA kv=2), d_ff 4864, vocab 151936; QKV bias;
tied embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_head=64,
    d_ff=4864, vocab_size=151936,
    block_pattern=("attn",), ffn="swiglu",
    qkv_bias=True, tie_embeddings=True, rope_theta=1000000.0, q_block=512,
    # 0.5B: DP-only over the whole mesh (14 heads indivisible by TP=16)
    sharding_overrides=(("heads", None), ("kv_heads", None), ("mlp", None),
                        ("vocab", "model"),
                        ("batch", ("pod", "data", "model"))),
    source="arXiv:2407.10671; hf:Qwen/Qwen2-0.5B",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen2-0.5b-smoke", family="dense",
        n_layers=3, d_model=96, n_heads=6, n_kv_heads=2, d_head=16,
        d_ff=256, vocab_size=512, block_pattern=("attn",), ffn="swiglu",
        qkv_bias=True, tie_embeddings=True)
