"""deepseek-moe-16b — DeepSeekMoE 16B [arXiv:2401.06066; hf].

Fine-grained MoE: 64 routed experts (top-6, d_expert 1408) + 2 shared
experts; first layer dense (d_ff 10944). 28L, d_model 2048, 16 MHA heads
(kv=16, d_head 128), vocab 102400. Router: softmax -> top-k, no weight
renormalisation (norm_topk_prob=False).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1408, vocab_size=102400,
    block_pattern=("attn",), ffn="moe",
    n_experts=64, top_k=6, n_shared_experts=2, d_expert=1408,
    first_k_dense=1, dense_d_ff=10944, normalize_topk=False, q_block=1024,
    source="arXiv:2401.06066; hf:deepseek-ai/deepseek-moe-16b-base",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b-smoke", family="moe",
        n_layers=3, d_model=96, n_heads=4, n_kv_heads=4, d_head=24,
        d_ff=64, vocab_size=512, block_pattern=("attn",), ffn="moe",
        n_experts=8, top_k=2, n_shared_experts=2, d_expert=64,
        first_k_dense=1, dense_d_ff=192, normalize_topk=False,
        capacity_factor=8.0)
