"""Architecture config schema + input-shape registry.

Every assigned architecture is an ``ArchConfig`` instance in its own module
(``repro.configs.<id>``), with the exact published dimensions, plus a
``smoke()`` reduced config of the same family for CPU tests. The four
input-shape cells (train_4k / prefill_32k / decode_32k / long_500k) are
global and combined with archs by the registry/dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense|vlm|hybrid|ssm|moe|audio
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 128
    # block layout: tiled over layers; entries: attn|local_attn|mla|rglru|ssd
    block_pattern: Tuple[str, ...] = ("attn",)
    # ffn per block kind: swiglu|gelu|moe|none
    ffn: str = "swiglu"
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: int = 0                  # local_attn window
    q_block: int = 0                 # query-blocked attention (0 = full)
    # MLA
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # ssm (mamba2 / rg-lru)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    lru_width: int = 0
    # moe
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    first_k_dense: int = 0           # leading layers use a dense FFN
    dense_d_ff: int = 0              # width of those dense FFN layers
    normalize_topk: bool = False
    capacity_factor: float = 1.25
    # embeddings / head
    tie_embeddings: bool = False
    embed_inputs: bool = True        # False: frontend stub feeds embeddings
    kv_quant: bool = False           # int8 KV cache (serving memory, §Beyond)
    logit_soft_cap: float = 0.0
    rms_eps: float = 1e-5
    # per-arch logical-rule overrides, e.g. small models go DP-only:
    # (("heads", None), ("batch", ("pod","data","model")), ...).
    # Stored as a tuple-of-pairs to keep the config hashable.
    sharding_overrides: tuple = ()
    # notes for DESIGN/EXPERIMENTS (provenance, deviations)
    source: str = ""

    @property
    def sharding_override_rules(self) -> dict:
        return dict(self.sharding_overrides)

    @property
    def sub_quadratic(self) -> bool:
        """True when decode state is O(1)/O(window): long_500k is runnable."""
        return all(k in ("rglru", "ssd", "local_attn")
                   for k in self.block_pattern)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        reps = -(-self.n_layers // len(self.block_pattern))
        return (self.block_pattern * reps)[: self.n_layers]

    def active_params(self) -> int:
        """Approximate active (per-token) parameter count (MoE-aware)."""
        return _param_count(self, active_only=True)

    def total_params(self) -> int:
        return _param_count(self, active_only=False)


def _param_count(c: ArchConfig, active_only: bool) -> int:
    total = 0
    if c.embed_inputs:
        total += c.vocab_size * c.d_model
    if not c.tie_embeddings:
        total += c.vocab_size * c.d_model
    for kind in c.layer_kinds:
        total += 2 * c.d_model  # norms
        if kind in ("attn", "local_attn"):
            total += c.d_model * c.d_head * (c.n_heads + 2 * c.n_kv_heads)
            total += c.n_heads * c.d_head * c.d_model
        elif kind == "mla":
            dqk = c.qk_nope_head_dim + c.qk_rope_head_dim
            total += c.d_model * c.q_lora_rank
            total += c.q_lora_rank * c.n_heads * dqk
            total += c.d_model * (c.kv_lora_rank + c.qk_rope_head_dim)
            total += c.kv_lora_rank * c.n_heads * (c.qk_nope_head_dim
                                                   + c.v_head_dim)
            total += c.n_heads * c.v_head_dim * c.d_model
        elif kind == "ssd":
            d_in = c.ssm_expand * c.d_model
            nh = d_in // c.ssm_headdim
            total += c.d_model * (2 * d_in + 2 * c.ssm_state + nh)
            total += d_in * c.d_model
        elif kind == "rglru":
            w = c.lru_width or c.d_model
            total += 2 * c.d_model * w + 2 * w * w + w * c.d_model
    # FFN
    for li, kind in enumerate(c.layer_kinds):
        if kind == "ssd":
            continue  # mamba2 blocks have no separate FFN
        if c.ffn == "moe" and li >= c.first_k_dense:
            e_active = c.top_k if active_only else c.n_experts
            total += 3 * c.d_model * c.d_expert * e_active
            total += 3 * c.d_model * c.d_expert * c.n_shared_experts
            total += c.d_model * c.n_experts  # router
        else:
            width = (c.dense_d_ff if (c.ffn == "moe" and li < c.first_k_dense)
                     else c.d_ff)
            mult = 3 if c.ffn in ("swiglu", "moe") else 2
            total += mult * c.d_model * width
    return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
