"""musicgen-medium — MusicGen 1.5B [arXiv:2306.05284; hf].

Decoder-only transformer over EnCodec residual-VQ tokens (4 codebooks,
2048 entries each -> vocab 2048 per head; assignment specifies the single
2048-vocab backbone head). 48L, d_model 1536, 24 MHA heads (kv=24),
GELU d_ff 6144.

Frontend stub per assignment: ``input_specs()`` provides precomputed frame
embeddings (the EnCodec + codebook-sum stage). The EnCodec RVQ
nearest-codebook search is an FPPS NN search — see repro/serve/modality.py.
Deviation noted: original uses learned sinusoidal positions; we use RoPE
(uniform backbone); dims/FLOPs unchanged.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_head=64,
    d_ff=6144, vocab_size=2048,
    block_pattern=("attn",), ffn="gelu",
    embed_inputs=False, q_block=512,
    # 1.5B, 24 heads indivisible by 16: DP-dominant
    sharding_overrides=(("heads", None), ("kv_heads", None),
                        ("batch", ("pod", "data", "model"))),
    source="arXiv:2306.05284; hf:facebook/musicgen-medium",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="musicgen-medium-smoke", family="audio",
        n_layers=3, d_model=96, n_heads=6, n_kv_heads=6, d_head=16,
        d_ff=192, vocab_size=256, block_pattern=("attn",), ffn="gelu",
        embed_inputs=False)
