"""--arch registry: name -> (full config, smoke config)."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig

_MODULES = {
    "granite-34b": "granite_34b",
    "llama3-405b": "llama3_405b",
    "qwen2-0.5b": "qwen2_0_5b",
    "minicpm3-4b": "minicpm3_4b",
    "chameleon-34b": "chameleon_34b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mamba2-780m": "mamba2_780m",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "musicgen-medium": "musicgen_medium",
}


def list_archs() -> list[str]:
    return list(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _module(name).smoke()


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def cells() -> list[tuple[str, str]]:
    """All 40 (arch, shape) dry-run cells. long_500k on full-attention archs
    is marked by runnable_cell() as skipped (see DESIGN.md §5)."""
    return [(a, s) for a in _MODULES for s in SHAPES]


def runnable_cell(arch: str, shape: str) -> tuple[bool, str]:
    cfg = get_config(arch)
    sh = get_shape(shape)
    if sh.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: pure full-attention arch; 500k dense decode "
                       "needs sub-quadratic attention (DESIGN.md §5)")
    return True, ""
