"""recurrentgemma-9b — Griffin architecture [arXiv:2402.19427; unverified].

Hybrid: repeating (RG-LRU, RG-LRU, local attention) — the paper's 1 attn :
2 recurrent ratio. 38L, d_model 4096, 16 heads MQA (kv=1, d_head 256),
GeGLU d_ff 12288, vocab 256000, window 2048, logit soft cap 30.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_head=256,
    d_ff=12288, vocab_size=256000,
    block_pattern=("rglru", "rglru", "local_attn"), ffn="geglu",
    window=2048, lru_width=4096, conv_width=4, q_block=1024,
    tie_embeddings=True, logit_soft_cap=30.0,
    sharding_overrides=(("kv_heads", None),),  # MQA
    source="arXiv:2402.19427",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-9b-smoke", family="hybrid",
        n_layers=5, d_model=96, n_heads=4, n_kv_heads=1, d_head=24,
        d_ff=192, vocab_size=512,
        block_pattern=("rglru", "rglru", "local_attn"), ffn="geglu",
        window=16, lru_width=96, conv_width=4,
        tie_embeddings=True, logit_soft_cap=30.0)
