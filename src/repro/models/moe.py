"""Mixture-of-Experts FFN: fine-grained routed experts + shared experts.

Covers both assigned MoE archs:
  * deepseek-moe-16b — 64 routed (top-6) + 2 shared experts, softmax→top-k
    router without weight renormalisation (DeepSeekMoE, arXiv:2401.06066).
  * qwen3-moe-235b-a22b — 128 routed (top-8), no shared, renormalised top-k.

Dispatch is sort-based with a static per-expert capacity (GShard-style drop
semantics, MegaBlocks-style grouped layout): tokens are sorted by assigned
expert, packed into an (E, C, d) buffer, processed by a batched expert
SwiGLU (one einsum — MXU), and scattered back with router weights. Dropped
tokens (beyond capacity) pass through with zero expert contribution — their
residual stream is untouched, matching standard capacity-drop behaviour.

Sharding intent (launch/partition.py): the expert dim of expert weights maps
to the ``model`` mesh axis (expert parallelism); the (E, C, d) buffer then
shards on E and GSPMD inserts the token all-to-all. An alternative
expert-tensor-parallel layout (shard d_expert) is expressible by remapping
one logical axis — compared in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int
    top_k: int
    d_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    normalize_topk: bool = False      # True for qwen3
    aux_loss_coef: float = 0.001
    z_loss_coef: float = 0.001


def moe_init(key, cfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_expert
    init = L.default_kernel_init
    p = {
        "router": {"kernel": init(ks[0], (d, e), jnp.float32)},
        "wi": init(ks[1], (e, d, f), dtype),
        "wg": init(ks[2], (e, d, f), dtype),
        "wo": init(ks[3], (e, f, d), dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.swiglu_init(ks[4], d, cfg.n_shared_experts * f, dtype)
    return p


def route(logits: jax.Array, cfg: MoEConfig):
    """logits (T,E) fp32 -> (weights (T,k), idx (T,k), aux_metrics)."""
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.normalize_topk:
        weights = weights / jnp.maximum(
            jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss + router z-loss.
    e = cfg.n_experts
    me = jnp.mean(probs, axis=0)                                  # (E,)
    assigned = jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(1)   # (T,E)
    fe = jnp.mean(assigned, axis=0) / cfg.top_k
    aux = e * jnp.sum(fe * me)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return weights, idx, {"load_balance_loss": aux, "router_z_loss": z}


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU sublane alignment


def expert_mlp(p, buf: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    """buf: (E, C, d) -> (E, C, d), batched SwiGLU over the expert dim."""
    xb = buf.astype(compute_dtype)
    wi = p["wi"].astype(compute_dtype)
    wg = p["wg"].astype(compute_dtype)
    wo = p["wo"].astype(compute_dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, wg)) * \
        jnp.einsum("ecd,edf->ecf", xb, wi)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def moe_forward(p, x: jax.Array, cfg: MoEConfig):
    """x: (B,S,D) -> (out (B,S,D), metrics)."""
    b, s, d = x.shape
    t = b * s
    k, e = cfg.top_k, cfg.n_experts
    c = capacity(t, cfg)
    flat = x.reshape(t, d)
    logits = (flat.astype(jnp.float32)
              @ p["router"]["kernel"].astype(jnp.float32))
    weights, idx, metrics = route(logits, cfg)

    pair_e = idx.reshape(t * k)                          # expert of each pair
    pair_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    pair_w = weights.reshape(t * k)
    order = jnp.argsort(pair_e)                          # stable
    se, st_tok, sw = pair_e[order], pair_t[order], pair_w[order]
    counts = jnp.bincount(pair_e, length=e)              # (E,)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos < c
    slot = jnp.where(keep, se * c + pos, e * c)          # overflow -> trash row
    buf = jnp.zeros((e * c + 1, d), x.dtype).at[slot].set(flat[st_tok])
    # NOTE (§Perf B2, refuted): forcing expert/token sharding constraints on
    # buf/flat here makes GSPMD's resolution *worse* (123 s vs 28 s
    # collective on deepseek train). This single-program path is the
    # fallback for shapes the explicit-EP path can't take (1-token decode);
    # production MoE runs via moe_ep.moe_forward_ep (rules: moe_impl).
    h = expert_mlp(p, buf[:e * c].reshape(e, c, d))      # (E,C,d)
    rows = h.reshape(e * c, d)[jnp.where(keep, se * c + pos, 0)]
    rows = rows * (sw * keep).astype(rows.dtype)[:, None]
    out = jnp.zeros((t, d), rows.dtype).at[st_tok].add(rows)
    if cfg.n_shared_experts:
        out = out + L.swiglu(p["shared"], flat)
    metrics["dropped_frac"] = 1.0 - jnp.mean(keep.astype(jnp.float32))
    metrics["moe_aux_total"] = (cfg.aux_loss_coef * metrics["load_balance_loss"]
                                + cfg.z_loss_coef * metrics["router_z_loss"])
    return out.reshape(b, s, d), metrics


def moe_forward_dense(p, x: jax.Array, cfg: MoEConfig):
    """Exact dense reference (every expert computes every token) — O(E·T·d·f);
    for parity tests on small configs only."""
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    logits = (flat.astype(jnp.float32)
              @ p["router"]["kernel"].astype(jnp.float32))
    weights, idx, metrics = route(logits, cfg)
    # combine weights (T, E): sum of top-k weights landing on each expert
    comb = jnp.zeros_like(logits)
    comb = comb.at[jnp.arange(flat.shape[0])[:, None], idx].add(weights)
    xb = flat.astype(jnp.bfloat16)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xb, p["wg"].astype(jnp.bfloat16)))
    h = h * jnp.einsum("td,edf->tef", xb, p["wi"].astype(jnp.bfloat16))
    y = jnp.einsum("tef,efd->ted", h, p["wo"].astype(jnp.bfloat16))
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), comb)
    if cfg.n_shared_experts:
        out = out + L.swiglu(p["shared"], flat).astype(jnp.float32)
    return out.astype(x.dtype).reshape(b, s, d), metrics
