"""Explicit expert-parallel MoE: shard_map local-sort + all-to-all.

Why this exists (§Perf iteration B3): the single-program sort-based
dispatch in ``moe.py`` leaves the token shuffle to GSPMD, which resolves
the data-sharded-tokens -> expert-sharded-buffer scatter with replicated
all-reduces of the full pair-expanded activations (measured: 28 s
collective term on deepseek-moe-16b/train_4k; forcing buffer shardings
made it 123 s). The production pattern (GShard/DeepSpeed-MoE) is explicit:

  per device (tokens local over the data axes, experts local over model):
    1. route + sort my tokens into an (E, C_src, d) send buffer,
    2. all_to_all over the expert axis: send slab e to expert-owner(e),
       receive my experts' slabs from every token shard,
    3. dense local expert GEMMs on (E_loc, S_src*C_src, d),
    4. reverse all_to_all, weighted combine back to my tokens.

Collective volume per layer ≈ 2 x T_loc*k*cf*d — the all-to-all the
algorithm actually requires, nothing more. Differentiates cleanly
(shard_map transposes the collectives).

Capacity note: C_src is per (source shard, expert); overflow drops follow
the same semantics as the gspmd_sort path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.models import layers as L
from repro.models.moe import MoEConfig, capacity, route


def _local_dispatch(flat, weights, idx, e: int, c: int):
    """Sort local tokens into (E, C, d) slabs. Returns (buf, combine info)."""
    t, d = flat.shape
    k = idx.shape[1]
    pair_e = idx.reshape(t * k)
    pair_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    pair_w = weights.reshape(t * k)
    order = jnp.argsort(pair_e)
    se, st_tok, sw = pair_e[order], pair_t[order], pair_w[order]
    counts = jnp.bincount(pair_e, length=e)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos < c
    slot = jnp.where(keep, se * c + pos, e * c)
    buf = jnp.zeros((e * c + 1, d), flat.dtype).at[slot].set(flat[st_tok])
    return buf[:e * c].reshape(e, c, d), (se, st_tok, sw, pos, keep)


def _local_combine(h, info, t: int, c: int):
    se, st_tok, sw, pos, keep = info
    d = h.shape[-1]
    rows = h.reshape(-1, d)[jnp.where(keep, se * c + pos, 0)]
    rows = rows * (sw * keep).astype(rows.dtype)[:, None]
    return jnp.zeros((t, d), rows.dtype).at[st_tok].add(rows)


def moe_forward_ep(p, x: jax.Array, cfg: MoEConfig, mesh, rules):
    """shard_map expert-parallel forward. x: (B,S,D) -> (out, metrics)."""
    expert_axes = rules.get("expert") or ()
    expert_axes = ((expert_axes,) if isinstance(expert_axes, str)
                   else tuple(expert_axes))
    token_axes = tuple(rules.get("tokens") or ())
    assert len(expert_axes) == 1, "EP wants exactly one expert axis"
    ax = expert_axes[0]
    n_ep = mesh.shape[ax]
    fsdp_axes = rules.get("fsdp") or ()
    fsdp_axes = ((fsdp_axes,) if isinstance(fsdp_axes, str)
                 else tuple(fsdp_axes))
    fsdp = tuple(a for a in fsdp_axes if a in mesh.axis_names and a != ax)
    e = cfg.n_experts
    assert e % n_ep == 0, (e, n_ep)

    from jax.sharding import PartitionSpec as P

    def body(xb, router_k, wi, wg, wo):
        # xb: (B_loc, S_loc, d) — batch sharded over the token (data) axes,
        # sequence sharded over the expert axis (a free local slice: the
        # activations were replicated along it), so every device routes a
        # distinct token set.
        b, s, d = xb.shape
        t = b * s
        flat = xb.reshape(t, d)
        logits = flat.astype(jnp.float32) @ router_k.astype(jnp.float32)
        weights, idx, metrics = route(logits, cfg)
        c = capacity(t, cfg)
        buf, info = _local_dispatch(flat, weights, idx, e, c)   # (E,C,d)
        # all-to-all over the expert axis: dim0 E = n_ep * E_loc
        recv = jax.lax.all_to_all(
            buf.reshape(n_ep, e // n_ep, c, d), ax,
            split_axis=0, concat_axis=0, tiled=False)           # (n_ep,E/n_ep,C,d)
        mine = recv.transpose(1, 0, 2, 3).reshape(
            e // n_ep, n_ep * c, d)                             # (E_loc, n_ep*C, d)
        # FSDP gather of my experts' weights
        if fsdp:
            for a in fsdp:
                wi = jax.lax.all_gather(wi, a, axis=1, tiled=True)
                wg = jax.lax.all_gather(wg, a, axis=1, tiled=True)
                wo = jax.lax.all_gather(wo, a, axis=2, tiled=True)
        xb16 = mine.astype(jnp.bfloat16)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb16,
                                   wg.astype(jnp.bfloat16)))
        h = h * jnp.einsum("ecd,edf->ecf", xb16, wi.astype(jnp.bfloat16))
        y = jnp.einsum("ecf,efd->ecd", h, wo.astype(jnp.bfloat16))
        # reverse all-to-all
        back = jax.lax.all_to_all(
            y.reshape(e // n_ep, n_ep, c, d).transpose(1, 0, 2, 3), ax,
            split_axis=0, concat_axis=0, tiled=False)           # (n_ep,E/n_ep,C,d)
        y_local = back.reshape(e, c, d)
        out = _local_combine(y_local, info, t, c)
        # aux metrics: average over every token-holding axis
        for a in tok + (ax,):
            metrics = {k: jax.lax.pmean(v, a) for k, v in metrics.items()}
        return out.reshape(b, s, d), metrics

    tok = tuple(a for a in token_axes if a in mesh.axis_names and a != ax)
    in_specs = (P(tok if tok else None, ax, None),       # x: (batch, seq, d)
                P(None, None),                           # router (replicated)
                P(ax, fsdp if fsdp else None, None),     # wi (E, d, f)
                P(ax, fsdp if fsdp else None, None),     # wg
                P(ax, None, fsdp if fsdp else None))     # wo (E, f, d)
    metrics_spec = {k: P() for k in
                    ("load_balance_loss", "router_z_loss")}
    out_specs = (P(tok if tok else None, ax, None), metrics_spec)
    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=False)
    out, metrics = fn(x, p["router"]["kernel"], p["wi"], p["wg"], p["wo"])
    metrics["dropped_frac"] = jnp.zeros((), jnp.float32)  # tracked locally
    metrics["moe_aux_total"] = (cfg.aux_loss_coef * metrics["load_balance_loss"]
                                + cfg.z_loss_coef * metrics["router_z_loss"])
    if cfg.n_shared_experts:
        out = out + L.swiglu(p["shared"], x.reshape(-1, x.shape[-1])).reshape(
            x.shape)
    return out, metrics
