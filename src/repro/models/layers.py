"""Shared primitive layers: norms, RoPE, dense MLPs, embeddings.

Conventions:
  * params are dicts of arrays; initialisers take an rng key and return the
    dict. Matmul weights are stored (d_in, d_out).
  * activations default to bf16, params to fp32 master (cast at use); math
    that is precision-sensitive (norm reductions, softmax, rope) runs fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Initializer = jax.nn.initializers.Initializer

default_kernel_init = jax.nn.initializers.normal(stddev=0.02)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               bias: bool = False):
    p = {"kernel": default_kernel_init(key, (d_in, d_out), dtype)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, compute_dtype=jnp.bfloat16):
    y = x.astype(compute_dtype) @ p["kernel"].astype(compute_dtype)
    if "bias" in p:
        y = y + p["bias"].astype(compute_dtype)
    return y


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def rms_head_norm(scale, x, eps: float = 1e-6):
    """Per-head QK-norm (scale shaped (d_head,)), fp32 math."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------------
def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               has_head_dim: bool = True) -> jax.Array:
    """x: (..., S, H, d_head) if has_head_dim else (..., S, d_head);
    positions: (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # (d/2,)
    angles = positions[:, None].astype(jnp.float32) * freqs     # (S, d/2)
    if has_head_dim:
        angles = angles[:, None, :]                     # (S, 1, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------------
def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wi": dense_init(k1, d_model, d_ff, dtype),
            "wg": dense_init(k2, d_model, d_ff, dtype),
            "wo": dense_init(k3, d_ff, d_model, dtype)}


def swiglu(p, x, compute_dtype=jnp.bfloat16):
    h = jax.nn.silu(dense(p["wg"], x, compute_dtype)) * dense(p["wi"], x, compute_dtype)
    return dense(p["wo"], h, compute_dtype)


def geglu(p, x, compute_dtype=jnp.bfloat16):
    """Gated-GELU MLP over swiglu-layout params (Gemma family)."""
    h = jax.nn.gelu(dense(p["wg"], x, compute_dtype)) * dense(p["wi"], x, compute_dtype)
    return dense(p["wo"], h, compute_dtype)


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"wi": dense_init(k1, d_model, d_ff, dtype),
            "wo": dense_init(k2, d_ff, d_model, dtype)}


def gelu_mlp(p, x, compute_dtype=jnp.bfloat16):
    return dense(p["wo"], jax.nn.gelu(dense(p["wi"], x, compute_dtype)),
                 compute_dtype)


# ----------------------------------------------------------------------------
# Embedding / LM head
# ----------------------------------------------------------------------------
def embedding_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {"table": default_kernel_init(key, (vocab, d_model), dtype)}


def embed(p, tokens, compute_dtype=jnp.bfloat16):
    return jnp.take(p["table"].astype(compute_dtype), tokens, axis=0)


def unembed(p, x, compute_dtype=jnp.bfloat16):
    """Tied head: logits = x @ tableᵀ (fp32 logits for a stable softmax)."""
    return (x.astype(compute_dtype)
            @ p["table"].astype(compute_dtype).T).astype(jnp.float32)
