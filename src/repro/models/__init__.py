"""Assigned-architecture substrate: a config-driven decoder-LM zoo.

Pure-function JAX models (no flax): params are pytrees of jnp arrays with a
stacked leading layer dim for scanned blocks. Sharding is applied externally
via logical-axis rules (repro.launch.partition)."""
